"""LDBC-SNB-style end-to-end queries (paper §6.5): IS-3, IC-8, BI-2.

Runs both engines (GraphAr hand-written vs Acero-like join plans), checks
result equivalence, and reports wall + modeled-ESSD time.

Run:  PYTHONPATH=src python examples/ldbc_queries.py
"""
import time

import numpy as np

from repro.core import IOMeter
from repro.core.query import (bi2_acero, bi2_graphar, build_snb_baseline,
                              build_snb_graphar, ic8_acero, ic8_graphar,
                              is3_acero, is3_graphar)
from repro.core.storage import ESSD
from repro.data.synthetic import ldbc_like


def timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0


def main():
    print("generating LDBC-like graph (scale 2)...")
    snb = ldbc_like(scale=2, seed=0)
    g = build_snb_graphar(snb)
    base = build_snb_baseline(snb)
    deg = np.bincount(snb.knows_src, minlength=snb.num_persons)
    person = int(np.argmax(deg))

    def essd(fn):
        m = IOMeter()
        fn(m)
        return m.seconds(ESSD)

    print(f"\nIS-3: friends of person {person}, newest friendships first")
    (f1, d1), t_g = timed(lambda: is3_graphar(g, person))
    (f2, d2), t_a = timed(lambda: is3_acero(base, person))
    assert set(f1) == set(f2)
    eg = t_g + essd(lambda m: is3_graphar(g, person, m))
    ea = t_a + essd(lambda m: is3_acero(base, person, m))
    print(f"  graphar {t_g*1e3:7.2f} ms | acero {t_a*1e3:7.2f} ms | "
          f"{len(f1)} friends | cpu {t_a/t_g:.1f}x | essd {ea/eg:.1f}x")

    print(f"\nIC-8: latest replies to person {person}'s messages")
    (r1, _), t_g = timed(lambda: ic8_graphar(g, person))
    (r2, _), t_a = timed(lambda: ic8_acero(base, person))
    np.testing.assert_array_equal(r1, r2)
    eg = t_g + essd(lambda m: ic8_graphar(g, person, meter=m))
    ea = t_a + essd(lambda m: ic8_acero(base, person, meter=m))
    print(f"  graphar {t_g*1e3:7.2f} ms | acero {t_a*1e3:7.2f} ms | "
          f"{len(r1)} replies | cpu {t_a/t_g:.1f}x | essd {ea/eg:.1f}x")

    print("\nBI-2: message counts per tag in TagClass1 (label filtering)")
    c1, t_g = timed(lambda: bi2_graphar(g, "TagClass1"))
    c2, t_a = timed(lambda: bi2_acero(base, "TagClass1"))
    assert c1 == c2
    m_g, m_a = IOMeter(), IOMeter()
    bi2_graphar(g, "TagClass1", m_g)
    bi2_acero(base, "TagClass1", m_a)
    print(f"  graphar {t_g*1e3:7.2f} ms | acero {t_a*1e3:7.2f} ms | "
          f"{len(c1)} tags | cpu speedup {t_a/t_g:.1f}x | "
          f"modeled ESSD speedup "
          f"{(t_a+m_a.seconds(ESSD))/(t_g+m_g.seconds(ESSD)):.1f}x")


if __name__ == "__main__":
    main()
