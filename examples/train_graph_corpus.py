"""End-to-end driver: train a ~100M-param LM on a GraphAr document lake.

Demonstrates the full production path at laptop scale:
  synthetic corpus -> GraphAr storage -> label-filtered, link-expanded
  data pipeline -> smollm-family model -> AdamW + cosine + grad-accum
  trainer with checkpointing and simulated failure recovery.

Run:  PYTHONPATH=src python examples/train_graph_corpus.py [--steps 200]
(defaults are sized for a few minutes on CPU; --full_100m uses the real
 ~100M config.)
"""
import argparse
import os
import tempfile

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import (EdgeTypeSchema, GraphArBuilder, L, PropertySchema,
                        VertexTypeSchema)
from repro.data.pipeline import GraphCorpusPipeline, PipelineConfig
from repro.data.synthetic import document_graph
from repro.models import build_model, param_count
from repro.train.optimizer import adamw
from repro.train.schedule import warmup_cosine
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq_len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--full_100m", action="store_true",
                    help="use a true ~100M-param config (slow on CPU)")
    ap.add_argument("--fail_at", type=int, default=None,
                    help="simulate a crash at this step (FT demo)")
    args = ap.parse_args()

    # -- the lake -----------------------------------------------------------
    lake = document_graph(num_docs=4000, vocab=4096, mean_len=200, seed=0)
    b = GraphArBuilder("corpus")
    b.add_vertices(
        VertexTypeSchema("doc", [PropertySchema("tokens", "tokens")],
                         labels=list(lake.labels), page_size=1024),
        {"tokens": lake.tokens}, lake.labels)
    b.add_edges(EdgeTypeSchema("doc", "links", "doc", page_size=1024),
                lake.links_src, lake.links_dst)
    graph = b.build()

    # -- the pipeline: quality-filtered + link-expanded ----------------------
    cond = (L("HighQuality") | L("News")) & ~L("Spam")
    pcfg = PipelineConfig(seq_len=args.seq_len, batch_size=args.batch)
    pipe = GraphCorpusPipeline(graph, cond, pcfg)
    print(f"pipeline: {len(pipe.eligible)} eligible docs after filtering")
    stream = pipe.batches()
    batches = {}

    def batch_fn(step):
        while step not in batches:
            nxt = next(stream)
            batches[nxt["step"]] = {
                "tokens": jnp.asarray(nxt["tokens"]),
                "labels": jnp.asarray(nxt["labels"])}
            if len(batches) > 64:
                batches.pop(min(batches))
        return batches[step]

    # -- the model ------------------------------------------------------------
    if args.full_100m:
        cfg = get_config("smollm-360m").with_(
            n_units=12, d_model=768, num_heads=12, num_kv_heads=4,
            head_dim=64, d_ff=2048, vocab_size=4096,
            param_dtype="float32", compute_dtype="float32", remat="none")
    else:
        cfg = get_config("smollm-360m").reduced().with_(
            vocab_size=4096, n_units=4)
    model = build_model(cfg)
    n_params = param_count(model.init(0))
    print(f"model: {cfg.name} derivative, {n_params/1e6:.1f}M params")

    opt = adamw(warmup_cosine(3e-4, 20, args.steps))
    ckpt_dir = os.path.join(tempfile.gettempdir(), "graphar_train_ckpt")
    tcfg = TrainerConfig(total_steps=args.steps, checkpoint_every=50,
                         checkpoint_dir=ckpt_dir, log_every=10)
    trainer = Trainer(model, opt, tcfg, batch_fn)
    out = trainer.run(simulate_failure_at=args.fail_at)
    for h in out["history"]:
        print(f"  step {h['step']:>5}  loss {h['loss']:.4f}  "
              f"gnorm {h['grad_norm']:.2f}  {h['sec_per_step']:.2f}s/step")
    first, last = out["history"][0], out["history"][-1]
    print(f"loss {first['loss']:.3f} -> {last['loss']:.3f} "
          f"({out['failures']} failures recovered)")
    io = pipe.io_stats()
    print(f"lake I/O: {io.nbytes/1e6:.1f} MB in {io.nrequests} requests")


if __name__ == "__main__":
    main()
