"""GraphAr quickstart: build an LPG, store it, query it.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import tempfile

import numpy as np

from repro.core import (BY_SRC, EdgeTypeSchema, GraphArBuilder, GraphStore,
                        IOMeter, L, PropertySchema, VertexTypeSchema,
                        filter_rle_interval, intervals_to_ids,
                        neighbor_properties, retrieve_neighbors)
from repro.core.storage import ESSD
from repro.data.synthetic import clustered_labels, powerlaw_graph


def main():
    # -- 1. raw data: a small social graph with labeled persons ------------
    n = 20_000
    src, dst = powerlaw_graph(n, avg_degree=10, seed=0)
    labels = clustered_labels(n, ["Asian", "Enrollee", "Student"],
                              density=0.3, run_scale=512, seed=1)
    age = np.random.default_rng(0).integers(18, 90, n).astype(np.int64)

    # -- 2. build the GraphAr layout (sort -> offset -> encode) ------------
    b = GraphArBuilder("quickstart")
    b.add_vertices(
        VertexTypeSchema("person", [PropertySchema("age", "int64")],
                         labels=list(labels)),
        {"age": age}, labels)
    b.add_edges(EdgeTypeSchema("person", "knows", "person",
                               adjacency=["by_src", "by_dst"]), src, dst)
    g = b.build()
    print(f"built graph: {n} vertices, {len(src)} edges "
          f"(sort {b.timing.sort:.3f}s, encode {b.timing.output:.3f}s)")

    # -- 3. persist + reload ------------------------------------------------
    root = os.path.join(tempfile.gettempdir(), "graphar_quickstart")
    g.save(root)
    store = GraphStore(root)
    print(f"saved to {root}: tables = {store.list_tables()}")

    # -- 4. neighbor retrieval (CSR-like: offset + delta + PAC) -------------
    adj = g.adjacency("person-knows-person", BY_SRC)
    meter = IOMeter()
    v = int(src[0])
    pac = retrieve_neighbors(adj, v, g.vertex("person").page_size, meter)
    ages = neighbor_properties(adj, v, g.vertex("person"), "age")
    print(f"vertex {v}: {pac.count()} neighbors across {len(pac)} pages, "
          f"mean age {ages.mean():.1f}; bytes touched {meter.nbytes} "
          f"(~{meter.seconds(ESSD)*1e3:.2f} ms on ESSD)")

    # -- 5. label filtering: (Asian & Enrollee) | Student -------------------
    cond = (L("Asian") & L("Enrollee")) | L("Student")
    ids = intervals_to_ids(filter_rle_interval(g.vertex("person"), cond))
    print(f"label filter {cond}: {len(ids)} matching vertices")


if __name__ == "__main__":
    main()
