"""Batched serving demo: continuous batching over a fixed-slot KV cache,
with label-scoped retrieval-augmented prompts pulled from a GraphAr lake.

Context is gathered through the batched retrieval plane: each engine tick
issues ONE batched neighbor retrieval (vectorized offsets gather +
page-deduplicated decode) for every request admitted in that tick, instead
of a per-request loop over the lake.  The retrieval is **label-scoped**
(PR 3): a compiled label predicate -- here "HighQuality and not Spam" --
rides on the retriever, so only passages satisfying it contribute RAG
context; the predicate bitmap is evaluated once by the filtering plane and
cached across ticks, and `ServeEngine.stats()` surfaces both the
decoded-page LRU counters and the filter's considered/kept counters.

Admission is **multi-tenant** (PR 9): a latency-sensitive `prod` class
and a rate-limited, deadline-bearing `batch` class share the slot pool
under deficit-weighted round-robin; oversubmitting `batch` draws typed
rejections with `retry_after` hints instead of an unbounded queue, and
`stats()["tenants"]` breaks admission/fairness down per tenant.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import numpy as np

from repro.core import (BY_SRC, EdgeTypeSchema, GraphArBuilder, IOMeter, L,
                        PropertySchema, VertexTypeSchema)
from repro.configs import get_config
from repro.data.synthetic import document_graph
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine
from repro.serve.retrieval import GraphRetriever
from repro.serve.tenancy import TenantConfig


def main():
    # -- lake with passage tokens (retrieval source) -------------------------
    lake = document_graph(num_docs=1000, vocab=512, mean_len=48, seed=2)
    b = GraphArBuilder("passages")
    b.add_vertices(
        VertexTypeSchema("doc", [PropertySchema("tokens", "tokens")],
                         labels=list(lake.labels), page_size=512),
        {"tokens": lake.tokens}, lake.labels)
    b.add_edges(EdgeTypeSchema("doc", "links", "doc", page_size=512),
                lake.links_src, lake.links_dst)
    graph = b.build()
    adj = graph.adjacency("doc-links-doc", BY_SRC)
    tokens_col = graph.vertex("doc").table["tokens"]

    # -- model + engine with a label-scoped batched lake retriever -----------
    cfg = get_config("smollm-360m").reduced().with_(
        n_units=2, vocab_size=512)
    model = build_model(cfg)
    params = model.init(0)
    meter = IOMeter()
    retriever = GraphRetriever(adj, tokens_col, max_neighbors=2,
                               tokens_per_neighbor=16, meter=meter,
                               filter_vt=graph.vertex("doc"),
                               filter_cond=L("HighQuality") & ~L("Spam"))
    eng = ServeEngine(model, params, max_slots=4, max_len=256, eos_id=-1,
                      context_fn=retriever,
                      tenants=[TenantConfig("prod", weight=4, max_queue=16),
                               TenantConfig("batch", weight=1, rate=0.5,
                                            burst=4.0, max_queue=4,
                                            deadline_ticks=64)])

    # -- requests: prompt = seed doc; labeled neighbor passages per tick -----
    # prod submits 8; batch floods 12 against a rate of 0.5 req/tick with
    # burst 4 -- the excess is shed with typed retry_after hints
    rng = np.random.default_rng(0)
    shed = []
    for rid in range(20):
        doc = int(rng.integers(0, lake.num_docs))
        prompt = tokens_col.get(doc)[:24].astype(np.int32)
        req = Request(rid, prompt, max_new_tokens=12,
                      temperature=0.0, context_vertex=doc)
        req.tenant = "prod" if rid < 8 else "batch"
        out = eng.submit(req)
        if not out.admitted:
            shed.append(out)

    finished = eng.run_until_drained(max_ticks=500)
    ctx = sum(r.context_tokens for r in finished)
    print(f"served {len(finished)} requests in {eng.steps} batched decode "
          f"steps; {retriever.calls} batched retrievals for "
          f"{retriever.vertices_seen} seeds ({ctx} context tokens, "
          f"{meter.nbytes} lake bytes)")
    # cross-tick decoded-page LRU + filtering-plane counters: warm ticks
    # stop re-paying hot-page decode, and only predicate-passing neighbors
    # contribute context
    stats = eng.stats()["retrieval"]
    print("page cache:", stats["page_cache"])
    print("label filter:", stats["filter"])
    # multi-tenant admission: the batch flood was shed, prod untouched
    print(f"shed {len(shed)} batch requests "
          f"(reasons: {sorted({o.reason.value for o in shed})}, "
          f"retry_after hints: {sorted({o.retry_after for o in shed})})")
    for name, t in eng.stats()["tenants"].items():
        print(f"tenant {name}: weight={t['weight']} "
              f"admitted={t['admitted']}/{t['submitted']} "
              f"ok={t['finished_ok']} expired={t['expired']}")


if __name__ == "__main__":
    main()
