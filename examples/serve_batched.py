"""Batched serving demo: continuous batching over a fixed-slot KV cache,
with retrieval-augmented prompts pulled from a GraphAr lake.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import numpy as np

from repro.configs import get_config
from repro.core import (BY_SRC, EdgeTypeSchema, GraphArBuilder,
                        PropertySchema, VertexTypeSchema)
from repro.data.synthetic import document_graph
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine


def main():
    # -- lake with passage tokens (retrieval source) -------------------------
    lake = document_graph(num_docs=1000, vocab=512, mean_len=48, seed=2)
    b = GraphArBuilder("passages")
    b.add_vertices(
        VertexTypeSchema("doc", [PropertySchema("tokens", "tokens")],
                         labels=list(lake.labels), page_size=512),
        {"tokens": lake.tokens}, lake.labels)
    b.add_edges(EdgeTypeSchema("doc", "links", "doc", page_size=512),
                lake.links_src, lake.links_dst)
    graph = b.build()
    adj = graph.adjacency("doc-links-doc", BY_SRC)
    tokens_col = graph.vertex("doc").table["tokens"]

    # -- model + engine -------------------------------------------------------
    cfg = get_config("smollm-360m").reduced().with_(
        n_units=2, vocab_size=512)
    model = build_model(cfg)
    params = model.init(0)
    eng = ServeEngine(model, params, max_slots=4, max_len=256, eos_id=-1)

    # -- requests: prompt = seed doc + neighbor passages (RAG-style) ----------
    rng = np.random.default_rng(0)
    for rid in range(8):
        doc = int(rng.integers(0, lake.num_docs))
        prompt = [tokens_col.get(doc)[:24]]
        for nb in adj.neighbor_ids(doc)[:2]:
            prompt.append(tokens_col.get(int(nb))[:16])
        prompt = np.concatenate(prompt).astype(np.int32)
        eng.submit(Request(rid, prompt, max_new_tokens=12,
                           temperature=0.0))

    ticks = 0
    while eng.queue or any(s is not None for s in eng.slots):
        active = eng.step()
        ticks += 1
        if ticks % 5 == 0:
            print(f"tick {ticks}: {active} active, {len(eng.queue)} queued")
        if ticks > 500:
            break
    print(f"served 8 requests in {ticks} engine ticks "
          f"({eng.steps} batched decode steps)")


if __name__ == "__main__":
    main()
