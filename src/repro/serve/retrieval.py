"""Batched RAG context retrieval from a GraphAr lake.

The serving engine admits several requests per tick; each may name a seed
vertex whose neighborhood provides context passages.  A
:class:`GraphRetriever` turns the whole admitted batch into **one** batched
neighbor retrieval (vectorized offsets gather + page-deduplicated decode)
plus one batched token fetch -- the per-tick unit of work of the batched
retrieval plane, instead of a per-request Python loop over the lake.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.core.edge import AdjacencyTable
from repro.core.neighbor import decode_edge_ranges
from repro.core.table import TokensColumn


class GraphRetriever:
    """Callable ``vs -> per-request context token arrays``.

    Per call (= per engine tick): one vectorized offsets gather over all
    seed vertices, one multi-range decode of the adjacency value column
    (pages shared between requests fetched once), one batched read of the
    neighbors' token lists, then a cheap per-request assembly.
    """

    def __init__(self, adj: AdjacencyTable, tokens_col: TokensColumn,
                 max_neighbors: int = 2, tokens_per_neighbor: int = 16,
                 meter=None, engine: str = "numpy"):
        self.adj = adj
        self.tokens_col = tokens_col
        self.max_neighbors = max_neighbors
        self.tokens_per_neighbor = tokens_per_neighbor
        self.meter = meter
        self.engine = engine
        self.calls = 0          # batched retrievals issued (one per tick)
        self.vertices_seen = 0  # requests served across all calls

    def __call__(self, vs: np.ndarray) -> List[np.ndarray]:
        vs = np.asarray(vs, np.int64)
        self.calls += 1
        self.vertices_seen += int(vs.size)
        if vs.size == 0:
            return []
        los, his = self.adj.edge_ranges_batch(vs, self.meter)
        his = np.minimum(his, los + self.max_neighbors)
        nbrs = decode_edge_ranges(self.adj, los, his, self.meter,
                                  self.engine)
        lengths = np.maximum(his - los, 0)
        token_lists = self.tokens_col.read_rows(nbrs, self.meter) \
            if nbrs.size else []
        out: List[np.ndarray] = []
        pos = 0
        for k in lengths:
            parts = [np.asarray(t[:self.tokens_per_neighbor], np.int32)
                     for t in token_lists[pos:pos + int(k)]]
            pos += int(k)
            out.append(np.concatenate(parts) if parts
                       else np.zeros(0, np.int32))
        return out
