"""Batched RAG context retrieval from a GraphAr lake.

The serving engine admits several requests per tick; each may name a seed
vertex whose neighborhood provides context passages.  A
:class:`GraphRetriever` turns the whole admitted batch into **one** batched
neighbor retrieval (vectorized offsets gather + page-deduplicated decode)
plus one batched token fetch -- the per-tick unit of work of the batched
retrieval plane, instead of a per-request Python loop over the lake.

Two cross-tick layers ride on top (PR 2):

* a **decoded-page LRU** on the adjacency value column
  (:mod:`repro.core.page_cache`): serving re-touches the same hot pages
  tick after tick, so every decode after the first consults the cache and
  IOMeter-charges only the miss pages -- warm ticks are observably cheaper
  (``stats()``/``ServeEngine.stats()`` surface the hit/miss counters);
* the token fetch reads each **unique** neighbor once (the merged
  neighbor set -- the same set the fused decode->bitmap kernel's PAC
  represents) and fans the lists back out per request, so pages shared
  between requests are charged once.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.edge import AdjacencyTable
from repro.core.labels import Cond, LabelFilter
from repro.core.neighbor import decode_edge_ranges
from repro.core.page_cache import DecodedPageCache, attach_page_cache
from repro.core.table import DeltaIntColumn, TokensColumn


class GraphRetriever:
    """Callable ``vs -> per-request context token arrays``.

    Per call (= per engine tick): one vectorized offsets gather over all
    seed vertices, one multi-range decode of the adjacency value column
    (cache-miss pages only, once the LRU is warm), one batched read of the
    unique neighbors' token lists, then a cheap per-request assembly.

    Label-scoped retrieval (PR 3): with ``filter_cond`` (a label
    :class:`~repro.core.labels.Cond` over ``filter_vt``, the value-side
    vertex table) only neighbors satisfying the predicate contribute
    context.  The predicate compiles once into the filtering plane; its
    whole-table bitmap is evaluated on the configured engine at first use,
    cached across ticks (label columns are immutable; the metadata I/O is
    charged once, mirroring the decoded-page LRU's miss-only convention),
    and each tick's decoded neighbors are masked by a vectorized bitmap
    probe.  ``stats()`` reports considered/kept counters.
    """

    def __init__(self, adj: AdjacencyTable, tokens_col: TokensColumn,
                 max_neighbors: int = 2, tokens_per_neighbor: int = 16,
                 meter=None, engine: str = "numpy",
                 page_cache_pages: Optional[int] = 256,
                 filter_vt=None, filter_cond: Optional[Cond] = None,
                 partitions: Optional[int] = None,
                 hops: int = 1):
        self.adj = adj
        self.tokens_col = tokens_col
        self.max_neighbors = max_neighbors
        self.tokens_per_neighbor = tokens_per_neighbor
        self.meter = meter
        self.engine = engine
        # deep context (PR 6): with hops > 1 each tick additionally runs
        # ONE k-hop traversal over the whole admitted batch -- fused on
        # kernel engines (kernels/traversal), the shared frontier plane
        # staying on device between hops -- and requests with spare
        # neighbor slots draw from that shared deep pool
        self.hops = int(hops)
        self.deep_pool_last = 0  # deep-context pool size of the last tick
        self.calls = 0          # batched retrievals issued (one per tick)
        self.vertices_seen = 0  # requests served across all calls
        self.ingest_calls = 0   # ingest() batches accepted
        self.ingest_rows = 0    # edges ingested across all batches
        self.knob_changes = 0   # overload-ladder knob turns (set_knob)
        if filter_cond is not None and filter_vt is None:
            raise ValueError("filter_cond requires filter_vt (the "
                             "value-side vertex table)")
        self.label_filter = (LabelFilter(filter_vt, filter_cond)
                             if filter_cond is not None else None)
        self._filter_charged = False
        self.filter_considered = 0  # neighbors decoded while filtering
        self.filter_kept = 0        # neighbors that passed the predicate
        col = adj.table[adj.value_col]
        self._cache_col = col if isinstance(col, DeltaIntColumn) else None
        if self._cache_col is not None:
            if partitions is not None:
                # explicit partition count for the adjacency value column:
                # every decode this retriever issues shards across the
                # partition plane's device mesh (None keeps whatever is
                # attached / the REPRO_PARTITIONS default)
                from repro.core.partition import partition_column
                partition_column(self._cache_col.encoded, partitions)
            if page_cache_pages is not None:
                attach_page_cache(self._cache_col, page_cache_pages)
            else:
                # explicit opt-out detaches: the decode paths consult the
                # column's cache, so leaving one attached would silently
                # keep serving (and under-charging) from it
                self._cache_col.encoded.page_cache = None

    @property
    def page_cache(self) -> Optional[DecodedPageCache]:
        """The cache the decode paths actually consult *now* -- read from
        the column so a later re-attach (e.g. with another capacity)
        doesn't leave stats() reporting a detached object's counters."""
        if self._cache_col is None:
            return None
        return self._cache_col.encoded.page_cache

    def __call__(self, vs: np.ndarray) -> List[np.ndarray]:
        vs = np.asarray(vs, np.int64)
        self.calls += 1
        self.vertices_seen += int(vs.size)
        if vs.size == 0:
            return []
        los, his = self.adj.edge_ranges_batch(vs, self.meter)
        his = np.minimum(his, los + self.max_neighbors)
        nbrs = decode_edge_ranges(self.adj, los, his, self.meter,
                                  self.engine)
        lengths = np.maximum(his - los, 0)
        from repro.core.delta_segment import live_delta
        delta = live_delta(self.adj)
        if delta is not None:
            # mutable plane: merge each request's pending delta neighbors
            # into its (sorted) base list, then keep the first
            # ``max_neighbors`` of the merge -- correct because the first
            # k of a merge of sorted lists draws only from the first k of
            # each input, and the base list is already clamped to k above
            dvals, dlens = delta.lookup_batch(vs)
            if dvals.size:
                allseg = np.concatenate(
                    [np.repeat(np.arange(lengths.size), lengths),
                     np.repeat(np.arange(dlens.size), dlens)])
                allv = np.concatenate([nbrs, dvals])
                order = np.lexsort((allv, allseg))
                allseg, allv = allseg[order], allv[order]
                counts = lengths + dlens
                starts = np.concatenate(
                    [[0], np.cumsum(counts)[:-1]]).astype(np.int64)
                within = np.arange(allv.size) - starts[allseg]
                keep = within < self.max_neighbors
                nbrs = allv[keep]
                lengths = np.minimum(counts, self.max_neighbors)
        if self.label_filter is not None and nbrs.size:
            if not self._filter_charged:
                # charged once: the bitmap is evaluated at first use and
                # cached across ticks (miss-only convention, like the LRU)
                self.label_filter.charge(self.meter)
                self._filter_charged = True
            keep = self.label_filter.mask_ids(nbrs, self.engine)
            self.filter_considered += int(nbrs.size)
            self.filter_kept += int(keep.sum())
            seg = np.repeat(np.arange(lengths.size), lengths)
            nbrs = nbrs[keep]
            lengths = np.bincount(seg[keep], minlength=lengths.size)
        if self.hops > 1:
            # one fused k-hop over the whole tick's seeds; the per-hop
            # label predicate keeps the pool inside the filtered scope
            from repro.core.neighbor import k_hop
            pool = k_hop(self.adj, vs, self.hops, self.meter, self.engine,
                         include_seeds=False, filter=self.label_filter)
            self.deep_pool_last = int(pool.size)
            if pool.size:
                seg = np.repeat(np.arange(lengths.size), lengths)
                per = [nbrs[seg == i] for i in range(lengths.size)]
                for i, own in enumerate(per):
                    need = self.max_neighbors - own.size
                    if need > 0:
                        per[i] = np.concatenate(
                            [own, pool[~np.isin(pool, own)][:need]])
                lengths = np.asarray([p.size for p in per], np.int64)
                nbrs = np.concatenate(per) if per \
                    else np.zeros(0, np.int64)
        if nbrs.size:
            # fetch each unique neighbor's tokens once for the whole tick
            uniq, inv = np.unique(nbrs, return_inverse=True)
            uniq_lists = self.tokens_col.read_rows(uniq, self.meter)
            token_lists = [uniq_lists[i] for i in inv]
        else:
            token_lists = []
        out: List[np.ndarray] = []
        pos = 0
        for k in lengths:
            parts = [np.asarray(t[:self.tokens_per_neighbor], np.int32)
                     for t in token_lists[pos:pos + int(k)]]
            pos += int(k)
            out.append(np.concatenate(parts) if parts
                       else np.zeros(0, np.int32))
        return out

    # -- overload degradation knobs (PR 9) ------------------------------------
    #: knobs the overload controller may turn: each trades context
    #: quality for tick latency and is fully reversible (the controller
    #: saves and restores the old value)
    DEGRADABLE = ("hops", "max_neighbors")

    def set_knob(self, name: str, value: int) -> int:
        """Set a degradation knob, returning the previous value.  Only
        the knobs in :data:`DEGRADABLE` are legal -- the controller must
        not be able to silently mutate arbitrary retrieval state."""
        if name not in self.DEGRADABLE:
            raise ValueError(f"not a degradable knob: {name!r} "
                             f"(want one of {self.DEGRADABLE})")
        old = int(getattr(self, name))
        value = int(value)
        if value < 1:
            raise ValueError(f"{name} must stay >= 1 (got {value})")
        setattr(self, name, value)
        if value != old:
            self.knob_changes += 1
        return old

    # -- speculative prefetch support (pipelined serving, PR 8) ---------------
    def snapshot(self) -> Dict[str, object]:
        """Point-in-time state of everything a retrieval call mutates:
        the IOMeter, the decoded-page LRU (contents *and* recency order),
        and this retriever's counters.  The pipelined engine snapshots
        before every speculative prefetch; a mis-speculation restores and
        replays the synchronous path, so meter and cache evolve exactly
        as the sequential engine's would -- bit-identical accounting is a
        property of the rollback, not of the prediction."""
        state: Dict[str, object] = {
            "calls": self.calls, "vertices_seen": self.vertices_seen,
            "filter_considered": self.filter_considered,
            "filter_kept": self.filter_kept,
            "filter_charged": self._filter_charged,
            "deep_pool_last": self.deep_pool_last,
        }
        if self.meter is not None:
            state["meter"] = (self.meter.nbytes, self.meter.nrequests)
        cache = self.page_cache
        if cache is not None:
            state["cache"] = cache.snapshot()
        return state

    def restore(self, state: Dict[str, object]) -> None:
        """Rewind to a :meth:`snapshot` (undo one speculative call)."""
        self.calls = state["calls"]
        self.vertices_seen = state["vertices_seen"]
        self.filter_considered = state["filter_considered"]
        self.filter_kept = state["filter_kept"]
        self._filter_charged = state["filter_charged"]
        self.deep_pool_last = state["deep_pool_last"]
        if self.meter is not None and "meter" in state:
            self.meter.nbytes, self.meter.nrequests = state["meter"]
        cache = self.page_cache
        if cache is not None and "cache" in state:
            cache.restore(state["cache"])

    def mutation_epoch(self) -> Tuple[int, int, int]:
        """Graph-state fingerprint a prefetched retrieval is only valid
        under: the adjacency column's write version, the mutable plane's
        pending row count, and the ingests routed through this retriever.
        Any movement between prefetch and consumption means the
        speculative contexts could be stale -- the engine falls back."""
        from repro.core.delta_segment import live_delta
        version = (self._cache_col.encoded.version
                   if self._cache_col is not None else 0)
        delta = live_delta(self.adj)
        pending = delta.pending_rows() if delta is not None else 0
        return (version, pending, self.ingest_calls)

    def ingest(self, src, dst):
        """Ingest an edge batch into the adjacency's mutable plane.

        Edges land in the delta segments (RAM-resident memtable) and are
        served from the very next tick, unioned with the packed base at
        dispatch time; a later compaction folds them into new packed
        partitions without interrupting serving.  Returns the
        :class:`~repro.core.delta_segment.DeltaSegments` plane.
        """
        from repro.core.delta_segment import attach_delta
        delta = attach_delta(self.adj)
        delta.ingest(src, dst)
        self.ingest_calls += 1
        self.ingest_rows += int(np.asarray(src).size)
        return delta

    def stats(self) -> Dict[str, object]:
        """Per-tick batching + decoded-page cache + device-mirror
        counters (for ``ServeEngine.stats()``)."""
        s: Dict[str, object] = {"calls": self.calls,
                                "vertices_seen": self.vertices_seen}
        if self.knob_changes:
            # overload ladder engaged at least once: current knob values
            s["knobs"] = {"hops": self.hops,
                          "max_neighbors": self.max_neighbors,
                          "changes": self.knob_changes}
        delta = getattr(self.adj, "delta", None)
        if delta is not None:
            # mutable plane: pending rows, zone-map pruning, compactions
            mut = dict(delta.stats())
            mut["ingest_calls"] = self.ingest_calls
            mut["ingest_rows"] = self.ingest_rows
            s["mutable"] = mut
        if self.page_cache is not None:
            s["page_cache"] = self.page_cache.stats()
        if self._cache_col is not None:
            packed = self._cache_col.encoded.packed_cache
            if packed is not None and packed.device_transfers:
                # transfers stay at one per engine across ticks: the
                # packed column crosses to the device once per epoch,
                # not once per dispatch (kernel engines only)
                s["device_mirror"] = packed.device_stats()
            from repro.core.partition import live_partitions
            parts = live_partitions(self._cache_col.encoded)
            if parts is not None:
                # partition plane: shard count, per-dispatch pruning
                # (partitions_pruned counts partitions skipped because
                # their range or statistics hull missed the batch)
                s["partitions"] = parts.stats()
        if self.label_filter is not None:
            s["filter"] = {"cond": repr(self.label_filter.cond),
                           "considered": self.filter_considered,
                           "kept": self.filter_kept}
        pruning = self._pruning_stats()
        if pruning is not None:
            s["pruning"] = pruning
        from repro.kernels.traversal.ops import traversal_stats
        trav = traversal_stats(self.adj)
        if trav is not None:
            # traversal plane: fused dispatches, hops folded into them,
            # host round-trips, and the last dispatch's per-hop frontier
            # sizes
            trav["hops"] = self.hops
            trav["deep_pool_last"] = self.deep_pool_last
            s["traversal"] = trav
        return s

    def _pruning_stats(self) -> "Dict[str, object] | None":
        """The statistics-pushdown plane's three granularities in one
        section: partition hulls skipped whole partitions
        (``partitions_stats_pruned``), page zone maps dropped individual
        pages before staging (``pages_*`` / ``io_saved_bytes``), and the
        mutable plane's segment zone maps skipped pending-row segments
        (``delta_segments_pruned``).  ``None`` until a predicate pushes
        down."""
        if self._cache_col is None:
            return None
        out: Dict[str, object] = \
            dict(self._cache_col.encoded.prune_stats.as_dict())
        from repro.core.partition import live_partitions
        parts = live_partitions(self._cache_col.encoded)
        out["partitions_stats_pruned"] = \
            parts.stats_pruned if parts is not None else 0
        delta = getattr(self.adj, "delta", None)
        out["delta_segments_pruned"] = \
            delta.segments_pruned if delta is not None else 0
        if not any(out.values()):
            return None
        return out
