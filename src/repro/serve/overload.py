"""Overload control: graceful, counted, reversible degradation.

Admission control bounds the queues; the overload controller bounds the
*tick*.  It watches the engine's per-tick latency (the PR 8 breakdown's
``tick_ms``) over a sliding window and, when the window's p99 exceeds
the configured target for ``patience`` consecutive ticks, steps down a
fixed degradation ladder -- each step a named, reversible knob turn that
trades context quality for tick latency:

1. ``cap_hops``       -- deep retrieval collapses to 1 hop (the k-hop
                         traversal is the most expensive optional work a
                         tick does);
2. ``no_speculation`` -- the speculative prefetch is skipped (under
                         overload mis-speculation rollbacks are pure
                         waste);
3. ``shrink_context`` -- the retriever's per-request neighbor budget is
                         halved (smaller decodes, smaller prompts).

When the window's p99 falls back below ``recovery * target`` for
``patience`` ticks, the most recent step is reverted -- the ladder is a
stack, climbed back up one rung at a time.  Every transition is counted
and timestamped (``stats()["overload"]``) so a saturation bench can
assert the controller engaged and disengaged rather than hope it did.

The controller never reads a wall clock of its own: it observes the
latencies the engine hands it, so a recorded sequence of tick latencies
replays to the same degradation trace.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class OverloadConfig:
    """``target_p99_ms`` is the tick-latency objective; ``window`` the
    sliding sample count the p99 is estimated over; ``patience`` the
    consecutive over/under observations required before acting (debounce
    -- a single slow tick, e.g. a compile, must not shed work)."""
    target_p99_ms: float
    window: int = 32
    patience: int = 4
    recovery: float = 0.6     # revert threshold, as a fraction of target

    def __post_init__(self):
        if self.target_p99_ms <= 0:
            raise ValueError("target_p99_ms must be > 0")
        if self.window < 4 or self.patience < 1:
            raise ValueError("want window >= 4 and patience >= 1")
        if not (0.0 < self.recovery < 1.0):
            raise ValueError("recovery must be in (0, 1)")


LADDER = ("cap_hops", "no_speculation", "shrink_context")


class OverloadController:
    """Applies/reverts the degradation ladder on a live engine.

    Constructed by :class:`~repro.serve.engine.ServeEngine` when an
    :class:`OverloadConfig` is passed; ``observe(tick_ms)`` is called at
    the end of every tick.
    """

    def __init__(self, engine, cfg: OverloadConfig):
        self.engine = engine
        self.cfg = cfg
        self._lat: deque = deque(maxlen=cfg.window)
        self.level = 0                  # rungs currently applied
        self.degrade_steps = 0          # transitions down, cumulative
        self.restore_steps = 0          # transitions up, cumulative
        self._over = 0
        self._under = 0
        self._saved: Dict[str, object] = {}
        self.history: List[Dict[str, object]] = []
        self.last_p99 = 0.0

    def observe(self, tick_ms: float) -> None:
        self._lat.append(float(tick_ms))
        if len(self._lat) < max(4, self.cfg.window // 4):
            return
        p99 = float(np.percentile(np.asarray(self._lat), 99))
        self.last_p99 = p99
        # the windowed p99 holds a single spike over target for a full
        # window -- require the *current* tick to also be slow, so the
        # patience counter measures consecutive slow ticks, not the
        # echo of one outlier
        if p99 > self.cfg.target_p99_ms and tick_ms > self.cfg.target_p99_ms:
            self._over += 1
            self._under = 0
            if self._over >= self.cfg.patience and self.level < len(LADDER):
                self._apply(LADDER[self.level])
                self._over = 0
                # degraded work changes the latency mix: restart the
                # window so the next decision reflects the new regime
                self._lat.clear()
        elif p99 < self.cfg.recovery * self.cfg.target_p99_ms:
            self._under += 1
            self._over = 0
            if self._under >= self.cfg.patience and self.level > 0:
                self._revert(LADDER[self.level - 1])
                self._under = 0
                self._lat.clear()
        else:
            self._over = self._under = 0

    # -- the ladder ------------------------------------------------------------
    def _retr(self):
        """The degradable retrieval plane, if the engine has one."""
        fn = self.engine.context_fn
        return fn if fn is not None and hasattr(fn, "set_knob") else None

    def _apply(self, step: str) -> None:
        # any in-flight speculative contexts were computed under the
        # old knobs -- discard (and rewind) before changing them
        self.engine._discard_prefetch()
        retr = self._retr()
        if step == "cap_hops":
            self._saved[step] = (retr.set_knob("hops", 1)
                                 if retr is not None else None)
        elif step == "no_speculation":
            self._saved[step] = self.engine.spec_disabled
            self.engine.spec_disabled = True
        elif step == "shrink_context":
            if retr is not None:
                old = retr.max_neighbors
                self._saved[step] = retr.set_knob(
                    "max_neighbors", max(1, old // 2))
            else:
                self._saved[step] = None
        self.level += 1
        self.degrade_steps += 1
        self.history.append({"tick": self.engine.tick_no, "step": step,
                             "dir": "degrade", "p99_ms": round(self.last_p99, 3)})

    def _revert(self, step: str) -> None:
        self.engine._discard_prefetch()
        retr = self._retr()
        saved = self._saved.pop(step, None)
        if step == "cap_hops":
            if retr is not None and saved is not None:
                retr.set_knob("hops", saved)
        elif step == "no_speculation":
            self.engine.spec_disabled = bool(saved)
        elif step == "shrink_context":
            if retr is not None and saved is not None:
                retr.set_knob("max_neighbors", saved)
        self.level -= 1
        self.restore_steps += 1
        self.history.append({"tick": self.engine.tick_no, "step": step,
                             "dir": "restore", "p99_ms": round(self.last_p99, 3)})

    def stats(self) -> Dict[str, object]:
        return {
            "level": self.level,
            "active_steps": list(LADDER[:self.level]),
            "degrade_steps": self.degrade_steps,
            "restore_steps": self.restore_steps,
            "p99_ms": round(self.last_p99, 3),
            "target_p99_ms": self.cfg.target_p99_ms,
            "transitions": list(self.history),
        }
