"""Serving engine: continuous batching over a fixed-slot KV cache.

A vLLM-style (slot-based) scheduler adapted to the TPU static-shape world:
the engine owns ``max_slots`` cache rows; requests are admitted into free
slots, prefilled (per-request prefill into the slot), then all active
slots decode together with one batched ``decode_step`` per tick.  Finished
slots (EOS or max_tokens) are retired and immediately refilled from the
queue -- decode utilization stays high without dynamic shapes.

Retrieval-augmented requests name a ``context_vertex`` in the lake; the
engine gathers context for **all** requests admitted in a tick via one
batched neighbor retrieval (``context_fn``, e.g.
:class:`repro.serve.retrieval.GraphRetriever`) before prefill.

Pipelined serving (PR 8)
------------------------

Retrieval and decode are *independent* device programs, so a tick does
not have to run them back to back.  With ``pipeline=True`` (the
``REPRO_PIPELINE`` default) each tick becomes a two-stage pipeline::

    admit(t)                  consume tick t's prefetched contexts,
      |                       prefill admitted slots
    dispatch decode(t)        jax async dispatch -- returns immediately
      |
    prefetch retrieval(t+1)   speculate next tick's admissions from the
      |                       queue + deterministic retirements and run
      |                       their batched retrieval (lake pages land in
      |                       the decoded-page LRU) while decode executes
    sample(t)                 first host read of the logits = the only
                              sync point of the tick

Speculation is *checked, not trusted*: the retrieval plane's state
(meter, LRU, counters) is snapshotted before every prefetch, and if the
next tick's actual admission batch differs -- a slot retired early on
EOS, a request jumped the queue, or the graph mutated under the
prediction -- the snapshot is restored and the tick falls back to the
synchronous retrieval path.  Ids, tokens, and IOMeter are therefore
**bit-identical** to the sequential engine on every tick, speculation
hit or miss; the pipeline only moves wall time.

Admission-controlled multi-tenant serving (PR 9)
------------------------------------------------

With ``tenants=[TenantConfig(...), ...]`` the unbounded FIFO becomes a
:class:`~repro.serve.tenancy.TenantScheduler`: ``submit`` gates each
request through its tenant's token bucket and bounded queue and returns
a typed :class:`~repro.serve.tenancy.SubmitOutcome` (``ADMITTED``, or
``REJECTED`` with a retry-after computed from the bucket refill), and
free slots are filled by deficit-weighted round-robin so no tenant
starves while idle tenants donate their share.  Per-request deadlines
(``Request.deadline_ticks`` or the tenant default) are enforced at tick
boundaries: an expired request -- queued or in-slot -- finishes with the
typed ``DEADLINE_EXCEEDED`` status and frees its slot immediately.

An optional :class:`~repro.serve.overload.OverloadController`
(``overload=OverloadConfig(...)``) watches the per-tick latency
breakdown and degrades in counted, reversible steps (cap hops ->
disable speculation -> shrink context) instead of letting latency grow
without bound; and an attached :class:`~repro.ft.faults.FaultPlan`
(``faults=``) injects crashes at the serving boundaries
(``serve.retrieval`` / ``serve.prefill`` / ``serve.spec_commit`` /
``serve.ingest``), which the engine survives via snapshot-rewind +
seeded-backoff retries -- the chaos tests assert every admitted request
either finishes bit-identical to an unthrottled sequential oracle or
carries a typed failure status, with the engine still ticking.
"""
from __future__ import annotations

import dataclasses
import itertools
import os
import time
from collections import deque
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.ft.backoff import Backoff, retry_call
from repro.ft.faults import FaultPlan, InjectedFault
from repro.ft.faults import check as fault_check
from repro.models.model import LM
from .overload import OverloadConfig, OverloadController
from .sampling import sample
from .tenancy import (RequestStatus, SubmitOutcome, SubmitStatus,
                      TenantConfig, TenantScheduler)


def _pipeline_default() -> bool:
    """``REPRO_PIPELINE`` default (read at engine construction so tests
    can flip it per engine): pipelined serving is on unless disabled."""
    return os.environ.get("REPRO_PIPELINE", "1") \
        .strip().lower() not in ("0", "false", "no", "off")


#: model id -> jitted decode_step / prefill, shared across engine
#: instances (the sequential oracle and the pipelined engine under test
#: would otherwise each pay a full lowering+compile of the same program).
_DECODE_JITS: Dict[int, Callable] = {}
_PREFILL_JITS: Dict[int, Callable] = {}


def _decode_jit(model: LM) -> Callable:
    fn = _DECODE_JITS.get(id(model))
    if fn is None:
        fn = jax.jit(model.decode_step)
        _DECODE_JITS[id(model)] = fn
    return fn


def _prefill_jit(model: LM) -> Callable:
    # an eager prefill costs ~1000x the compiled program on the reduced
    # test models and dominates every admission tick; compiled per
    # prompt-length bucket (jit retraces on new shapes)
    fn = _PREFILL_JITS.get(id(model))
    if fn is None:
        fn = jax.jit(model.prefill)
        _PREFILL_JITS[id(model)] = fn
    return fn


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray                 # int32 tokens
    max_new_tokens: int = 32
    temperature: float = 0.0
    context_vertex: Optional[int] = None   # RAG seed vertex in the lake
    tenant: str = "default"            # request class (multi-tenant mode)
    deadline_ticks: Optional[int] = None   # ticks from submit to finish
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    context_tokens: int = 0            # context appended by the engine
    status: Optional[RequestStatus] = None  # terminal status at retirement
    submitted_tick: Optional[float] = None
    deadline_at: Optional[float] = None    # absolute tick budget
    finished_tick: Optional[float] = None


class UndrainedError(RuntimeError):
    """``run_until_drained`` exhausted ``max_ticks`` with work still in
    flight.  Carries the stuck request ids instead of silently returning
    a partial result that looks like a drain."""

    def __init__(self, queued_ids: List[int], active_ids: List[int],
                 max_ticks: int):
        self.queued_ids = list(queued_ids)
        self.active_ids = list(active_ids)
        self.max_ticks = max_ticks
        super().__init__(
            f"undrained after {max_ticks} ticks: "
            f"{len(self.queued_ids)} queued {self.queued_ids}, "
            f"{len(self.active_ids)} active {self.active_ids}")


class ServeEngine:
    def __init__(self, model: LM, params, max_slots: int = 4,
                 max_len: int = 512, eos_id: int = 2, seed: int = 0,
                 context_fn: Optional[
                     Callable[[np.ndarray], List[np.ndarray]]] = None,
                 pipeline: Optional[bool] = None, batched: bool = True,
                 tenants: Optional[List[TenantConfig]] = None,
                 overload: Optional[OverloadConfig] = None,
                 faults: Optional[FaultPlan] = None):
        self.model = model
        # ``batched=False`` keeps the pre-pipeline per-request tick
        # (one prefill dispatch+sync per admitted request, one sample
        # read per active slot) as the benchmark baseline the serving
        # suite measures the restructured tick against
        self.batched = bool(batched)
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.context_fn = context_fn
        self.queue: deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * max_slots
        self.finished: List[Request] = []
        # per-slot positions (vector index): slots advance independently
        self.cache = model.init_cache(max_slots, max_len,
                                      dtype=jnp.float32, vector_index=True)
        self.slot_pos = np.zeros(max_slots, np.int32)   # python-side mirror
        self.rng = jax.random.PRNGKey(seed)
        self._decode = _decode_jit(model)
        self._prefill_fn = _prefill_jit(model)
        self._tmp_caches: Dict[int, object] = {}  # k -> prefill template
        self._write_jit = jax.jit(self._write_slots)
        self.steps = 0
        # -- pipelined serving state ------------------------------------------
        self.pipeline = _pipeline_default() if pipeline is None \
            else bool(pipeline)
        # speculative prefetch needs to undo a wrong guess exactly: only
        # a context_fn exposing snapshot/restore can be prefetched against
        self._can_prefetch = (context_fn is not None
                              and hasattr(context_fn, "snapshot")
                              and hasattr(context_fn, "restore"))
        self._prefetch: Optional[Dict[str, object]] = None
        self.prefetch_issued = 0    # speculative retrievals launched
        self.prefetch_hits = 0      # consumed by the predicted admission
        self.mis_speculations = 0   # restored + synchronous fallback
        self.pipeline_overlap_ms = 0.0  # prefetch time spent under decode
        self.last_tick: Dict[str, float] = {}   # last tick's latency split
        self.tick_totals: Dict[str, float] = {}  # cumulative latency split
        self._last_retrieval_ms = 0.0
        # -- multi-tenant admission control (PR 9) ----------------------------
        self.tick_no = 0        # the admission/deadline clock (1 per step)
        self.scheduler = (TenantScheduler(tenants, now=0.0)
                          if tenants is not None else None)
        self.rejected: List[Request] = []   # shed at submit (typed outcome)
        self.deadline_exceeded = 0          # typed deadline failures
        self.expired_in_queue = 0           # ...of which never held a slot
        self.spec_disabled = False          # overload rung 2 gates prefetch
        self.overload = (OverloadController(self, overload)
                         if overload is not None else None)
        # -- serving-plane fault injection (PR 9) -----------------------------
        self.faults = faults
        self._fault_backoff = Backoff(seed=0)   # deterministic retry delays
        self.fault_hits: Dict[str, int] = {}    # boundary -> injected count
        self.faults_recovered = 0
        self.fault_backoff_s = 0.0              # simulated, never slept

    # -- admission -------------------------------------------------------------
    def submit(self, req: Request) -> SubmitOutcome:
        """Offer ``req`` to the engine.  Multi-tenant mode gates it
        through the tenant's token bucket and bounded queue and returns
        the typed outcome (``REJECTED`` outcomes carry a retry-after
        hint and the request is recorded in ``self.rejected`` with
        ``status=REJECTED``); legacy single-queue mode always admits."""
        if self.scheduler is not None:
            out = self.scheduler.submit(req, self.tick_no)
            if not out.admitted:
                req.status = RequestStatus.REJECTED
                self.rejected.append(req)
            return out
        req.submitted_tick = self.tick_no
        if req.deadline_ticks is not None:
            req.deadline_at = self.tick_no + req.deadline_ticks
        self.queue.append(req)
        return SubmitOutcome(SubmitStatus.ADMITTED, req.tenant)

    # -- serving-plane fault injection helpers ---------------------------------
    def _note_fault(self, attempt: int, delay: float, exc) -> None:
        """``retry_call`` observer: count the injected fault, accumulate
        the (simulated, never slept) backoff delay."""
        b = getattr(exc, "boundary", "?")
        self.fault_hits[b] = self.fault_hits.get(b, 0) + 1
        self.faults_recovered += 1
        self.fault_backoff_s += delay

    def _fault_retry(self, fn):
        """Run ``fn`` under the seeded retry loop, treating injected
        faults (and only those) as retryable.  Delays are recorded, not
        slept -- a chaos tick must not block the suite."""
        return retry_call(fn, retries=8, backoff=self._fault_backoff,
                          sleep=lambda d: None,
                          retry_on=(InjectedFault,),
                          on_retry=self._note_fault)

    def _retrieve_contexts(self, vs: np.ndarray) -> List[np.ndarray]:
        """The tick's batched context retrieval, crash-checked at the
        ``serve.retrieval`` boundary (pre-dispatch and at commit).  A
        commit-side fault rewinds the retrieval plane's snapshot before
        the retry, so meter/LRU accounting replays exactly once."""
        if self.faults is None:
            return self.context_fn(vs)

        def attempt():
            snap = (self.context_fn.snapshot()
                    if self._can_prefetch else None)
            fault_check(self.faults, "serve.retrieval")
            try:
                out = self.context_fn(vs)
                fault_check(self.faults, "serve.retrieval")
            except InjectedFault:
                if snap is not None:
                    self.context_fn.restore(snap)
                raise
            return out

        return self._fault_retry(attempt)

    def ingest(self, src, dst):
        """Forward an edge batch to the retrieval plane's mutable graph.

        Requires an ingest-capable ``context_fn`` (e.g.
        :class:`~repro.serve.retrieval.GraphRetriever`); ingested edges
        are visible to context retrieval from the next tick on.  With a
        fault plan attached the ``serve.ingest`` boundary is checked
        before the batch is forwarded (the delta plane's own
        ``ingest.append`` boundary keeps the batch all-or-nothing), and
        the engine retries through the seeded backoff.
        """
        if self.context_fn is None or not hasattr(self.context_fn,
                                                  "ingest"):
            raise ValueError("no ingest-capable context_fn attached")
        # getattr: tests exercise this forwarder on a bare engine shell
        if getattr(self, "faults", None) is None:
            return self.context_fn.ingest(src, dst)

        def attempt():
            fault_check(self.faults, "serve.ingest")
            return self.context_fn.ingest(src, dst)

        return self._fault_retry(attempt)

    def _clamp_admission(self, req: Request) -> None:
        """``max_len`` is the slot's hard cache-row budget: prompt rows
        plus decode writes must fit.  A request admitted near capacity
        (long prompt, or ``max_new_tokens`` past the remaining rows)
        would otherwise write past the cache -- clamp both at admission,
        before the context budget is computed from them."""
        prompt = np.asarray(req.prompt, np.int32)
        cap = self.max_len - 2          # leave >= 1 decode row
        if len(prompt) > cap:
            req.prompt = prompt[:cap]
        room = self.max_len - 1 - len(req.prompt)
        if req.max_new_tokens > room:
            req.max_new_tokens = int(room)

    def _graph_epoch(self):
        fn = getattr(self.context_fn, "mutation_epoch", None)
        return fn() if fn is not None else None

    def _discard_prefetch(self) -> None:
        """A prefetched retrieval that cannot be consumed: rewind the
        retrieval plane to its pre-prefetch state (meter, LRU, counters)
        so the synchronous path replays from exactly where the
        sequential engine would stand."""
        pf = self._prefetch
        self._prefetch = None
        if pf is not None:
            self.mis_speculations += 1
            self.context_fn.restore(pf["snapshot"])

    def _take_prefetch(self, vs: np.ndarray) -> Optional[List[np.ndarray]]:
        """Prefetched contexts for exactly this admission batch, or None
        (after restoring) when the speculation missed."""
        pf = self._prefetch
        if pf is None:
            return None
        self._prefetch = None
        if np.array_equal(pf["vs"], vs) \
                and self._graph_epoch() == pf["epoch"]:
            self.prefetch_hits += 1
            return pf["contexts"]
        self.mis_speculations += 1
        self.context_fn.restore(pf["snapshot"])
        return None

    def _attach_context(self, admitted: List[Request]) -> None:
        """One batched lake retrieval for every admitted request's seed
        (served from the previous tick's prefetch when the speculation
        predicted this exact batch)."""
        need = [r for r in admitted if r.context_vertex is not None]
        if not need or self.context_fn is None:
            self._discard_prefetch()
            return
        vs = np.asarray([r.context_vertex for r in need], np.int64)
        contexts = self._take_prefetch(vs)
        if contexts is None:
            contexts = self._retrieve_contexts(vs)
        for req, ctx in zip(need, contexts):
            ctx = np.asarray(ctx, np.int32)
            # leave room for generation within the slot's cache rows
            budget = self.max_len - 1 - req.max_new_tokens - len(req.prompt)
            ctx = ctx[:max(budget, 0)]
            if ctx.size:
                req.prompt = np.concatenate(
                    [np.asarray(req.prompt, np.int32), ctx])
                req.context_tokens = int(ctx.size)

    def _pending_count(self) -> int:
        """Requests waiting for a slot (whichever queue plane is live)."""
        if self.scheduler is not None:
            return self.scheduler.pending()
        return len(self.queue)

    def _peek_admissions(self, width: int) -> List[Request]:
        """The next ``width`` requests admission would take, without
        taking them -- the speculative prefetch's prediction.  In
        multi-tenant mode this previews the DWRR pop order exactly."""
        if self.scheduler is not None:
            return self.scheduler.peek(width)
        return list(itertools.islice(self.queue, 0, width))

    def _admit(self) -> None:
        free = [i for i in range(self.max_slots) if self.slots[i] is None]
        admitted: List[tuple] = []
        if self.scheduler is not None:
            for req in self.scheduler.pop(len(free), self.tick_no):
                admitted.append((free.pop(0), req))
        else:
            while free and self.queue:
                admitted.append((free.pop(0), self.queue.popleft()))
        for _, req in admitted:
            self._clamp_admission(req)
        t0 = time.perf_counter()
        self._attach_context([r for _, r in admitted])
        self._last_retrieval_ms = (time.perf_counter() - t0) * 1e3
        # grouped prefill: all admitted prompts of one length run as ONE
        # batched forward + one vectorized multi-slot cache write, instead
        # of per-request dispatch/sync round-trips (the admission stage
        # was the tick's fixed-cost floor before the pipeline can help)
        if self.batched:
            groups: Dict[int, List[tuple]] = {}
            for slot, req in admitted:
                groups.setdefault(len(req.prompt), []).append((slot, req))
            grouped = list(groups.values())
        else:
            grouped = [[(slot, req)] for slot, req in admitted]
        for grp in grouped:
            self._prefill_group(grp)
        for slot, req in admitted:
            self.slots[slot] = req

    def _write_slots(self, cache, tmp_cache, slots):
        """One fused program writing a batch-k prefill cache's rows into
        the engine cache's ``slots`` rows (jitted: the eager per-leaf
        ``.at[].set`` dispatches were most of the admission cost).
        ``tmp_cache`` row j lands in engine slot ``slots[j]``."""
        ms, k = self.max_slots, len(slots)

        def write(slot_arr, one_arr):
            if one_arr.ndim == slot_arr.ndim:
                # scan-stacked leaves: (n_units, batch, ...) -- requires
                # the leading (unit) axis to agree, so it cannot misfire
                # on a plain batch-leading leaf
                if one_arr.ndim >= 2 and one_arr.shape[1] == k \
                        and slot_arr.shape[1] == ms \
                        and slot_arr.shape[0] == one_arr.shape[0]:
                    return slot_arr.at[:, slots].set(one_arr)
                # batch-leading leaves: (k, ...) vs (max_slots, ...)
                if one_arr.ndim >= 1 and one_arr.shape[0] == k \
                        and slot_arr.shape[0] == ms:
                    return slot_arr.at[slots].set(one_arr)
                return slot_arr
            # shared scalar index (tmp) -> per-slot vector index
            # (engine); one prefill group = one prompt length, so the
            # scalar broadcasts to every written slot
            if one_arr.ndim + 1 == slot_arr.ndim:
                if slot_arr.ndim == 1:
                    return slot_arr.at[slots].set(one_arr)
                if slot_arr.ndim >= 2 and slot_arr.shape[1] == ms \
                        and slot_arr.shape[0] == one_arr.shape[0]:
                    return slot_arr.at[:, slots].set(one_arr[:, None])
            return slot_arr

        return jax.tree.map(write, cache, tmp_cache)

    def _prefill_group(self, grp: List[tuple]) -> None:
        """Batched prefill of same-length prompts: one forward over the
        stacked ``(k, L)`` prompt matrix, one multi-slot cache write, one
        host sync for the k argmax tokens."""
        k = len(grp)
        prompts = np.stack([np.asarray(req.prompt, np.int32)
                            for _, req in grp])
        # the empty batch-k cache is a constant per engine: build once
        # per k and reuse (jax arrays are immutable; prefill returns new
        # leaves)
        tmpl = self._tmp_caches.get(k)
        if tmpl is None:
            tmpl = self.model.init_cache(k, self.max_len,
                                         dtype=jnp.float32)
            self._tmp_caches[k] = tmpl
        if self.faults is None:
            logits, tmp_cache = self._prefill_fn(
                self.params, {"tokens": jnp.asarray(prompts)}, tmpl)
        else:
            # ``serve.prefill`` boundary: the forward is pure (the engine
            # cache is only written below), so a crash on either side of
            # the dispatch retries to identical logits/cache rows
            def attempt():
                fault_check(self.faults, "serve.prefill")
                out = self._prefill_fn(
                    self.params, {"tokens": jnp.asarray(prompts)}, tmpl)
                fault_check(self.faults, "serve.prefill")
                return out

            logits, tmp_cache = self._fault_retry(attempt)
        self.cache = self._write_jit(
            self.cache, tmp_cache,
            jnp.asarray([s for s, _ in grp], jnp.int32))
        toks = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for (slot, req), tok in zip(grp, toks):
            self.slot_pos[slot] = len(req.prompt)
            req.output.append(int(tok))
            # the prefill token counts toward the budget:
            # max_new_tokens=1 (e.g. a clamped near-capacity admission)
            # retires right here
            if int(tok) == self.eos_id or \
                    len(req.output) >= req.max_new_tokens:
                req.done = True

    # -- speculative prefetch (the pipeline's second stage) --------------------
    def _predict_retiring(self, active: List[int]) -> int:
        """Slots certain to retire this tick, *before* sampling: the
        length/position bounds are deterministic; only EOS is not (a
        wrong guess is caught and rolled back at the next admission)."""
        n = 0
        for i in active:
            req = self.slots[i]
            if len(req.output) + 1 >= req.max_new_tokens or \
                    int(self.slot_pos[i]) + 1 >= self.max_len - 1 or \
                    (req.deadline_at is not None
                     and self.tick_no + 1 > req.deadline_at):
                n += 1
        return n

    def _speculate_prefetch(self, active: List[int]) -> None:
        """Issue tick t+1's batched retrieval while tick t's decode is in
        flight.  The predicted admission batch is the queue's head, as
        wide as the slots certain to free; the retrieval runs through the
        real plane (pages land in the decoded-page LRU, the meter is
        charged miss-only -- exactly what the synchronous path would do
        one tick later), guarded by a snapshot for the fallback."""
        if self._prefetch is not None or not self._can_prefetch \
                or self.spec_disabled or not self._pending_count():
            return
        # certain frees: empty slots, slots already done (EOS at
        # prefill, retired at tick end), and deterministic retirements
        width = sum(1 for s in self.slots if s is None or s.done) \
            + self._predict_retiring(active)
        if width <= 0:
            return
        admits = self._peek_admissions(width)
        vs = np.asarray([r.context_vertex for r in admits
                         if r.context_vertex is not None], np.int64)
        if vs.size == 0:
            return
        snapshot = self.context_fn.snapshot()
        epoch = self._graph_epoch()
        try:
            # ``serve.spec_commit`` boundary: a crash at the speculative
            # commit restores the snapshot and skips this prefetch --
            # speculation is optional work, the synchronous path next
            # tick serves the identical result
            fault_check(self.faults, "serve.spec_commit")
            contexts = self.context_fn(vs)
            fault_check(self.faults, "serve.spec_commit")
        except InjectedFault as e:
            self.context_fn.restore(snapshot)
            self.fault_hits[e.boundary] = \
                self.fault_hits.get(e.boundary, 0) + 1
            self.faults_recovered += 1
            return
        self.prefetch_issued += 1
        self._prefetch = {"vs": vs, "contexts": contexts,
                          "snapshot": snapshot, "epoch": epoch}

    # -- deadlines -------------------------------------------------------------
    def _expire_deadlines(self) -> None:
        """Deadlines are enforced at tick boundaries (start of tick
        ``now``: the request had every tick up to and including its
        budget to finish).  Queued requests past their deadline finish
        with the typed ``DEADLINE_EXCEEDED`` status without ever holding
        a slot; in-slot requests are marked done and their slot frees
        *immediately* -- this same tick's admission reuses it."""
        now = self.tick_no

        def _expire(req: Request) -> None:
            req.status = RequestStatus.DEADLINE_EXCEEDED
            req.done = True
            req.finished_tick = now
            self.deadline_exceeded += 1
            self.expired_in_queue += 1
            if self.scheduler is not None:
                self.scheduler.note_finished(req,
                                             RequestStatus.DEADLINE_EXCEEDED)
            self.finished.append(req)

        if self.scheduler is not None:
            for req in self.scheduler.expire(now):
                _expire(req)
        elif self.queue and any(r.deadline_at is not None
                                for r in self.queue):
            kept: deque[Request] = deque()
            for req in self.queue:
                if req.deadline_at is not None and now > req.deadline_at:
                    _expire(req)
                else:
                    kept.append(req)
            self.queue = kept
        expired_slot = False
        for req in self.slots:
            if req is not None and not req.done \
                    and req.deadline_at is not None \
                    and now > req.deadline_at:
                req.status = RequestStatus.DEADLINE_EXCEEDED
                req.done = True
                self.deadline_exceeded += 1
                expired_slot = True
        if expired_slot:
            self._retire()

    # -- decode tick -------------------------------------------------------------
    def _active(self) -> List[int]:
        return [i for i, r in enumerate(self.slots)
                if r is not None and not r.done]

    def step(self) -> int:
        """One engine tick: admit + one batched decode. Returns #active.

        Pipelined mode dispatches the decode, runs the speculative
        prefetch in the decode's shadow, and only then samples (the
        logits read is the tick's one host sync)."""
        t0 = time.perf_counter()
        self.tick_no += 1
        self._expire_deadlines()
        self._admit()
        t_admit = time.perf_counter()
        active = self._active()
        if not active:
            self._retire()
            return 0
        tokens = np.zeros((self.max_slots, 1), np.int32)
        for i in active:
            tokens[i, 0] = self.slots[i].output[-1]
        logits, self.cache = self._decode(self.params,
                                          jnp.asarray(tokens), self.cache)
        t_dispatch = time.perf_counter()
        self.steps += 1
        if self.pipeline:
            self._speculate_prefetch(active)
        t_prefetch = time.perf_counter()
        self.rng, sub = jax.random.split(self.rng)
        # greedy slots sample as ONE batched argmax + host read (row-wise
        # argmax is independent per row, so batching is bit-identical);
        # temperature>0 slots keep the per-slot draw -- a batched
        # categorical would change each row's stream under the shared key
        tok_of: Dict[int, int] = {}
        greedy = [i for i in active if self.slots[i].temperature <= 0.0] \
            if self.batched else []
        if greedy:
            toks = np.asarray(sample(sub, logits[jnp.asarray(greedy), 0]))
            tok_of.update((i, int(t)) for i, t in zip(greedy, toks))
        for i in active:
            req = self.slots[i]
            tok = tok_of.get(i)
            if tok is None:
                tok = int(sample(sub, logits[i:i + 1, 0],
                                 temperature=req.temperature)[0])
            req.output.append(tok)
            self.slot_pos[i] += 1
            if tok == self.eos_id or \
                    len(req.output) >= req.max_new_tokens or \
                    int(self.slot_pos[i]) >= self.max_len - 1:
                req.done = True
        t_sample = time.perf_counter()
        self._retire()
        overlap = (t_prefetch - t_dispatch) * 1e3
        self.pipeline_overlap_ms += overlap
        self.last_tick = {
            "admit_ms": (t_admit - t0) * 1e3,
            "retrieval_ms": self._last_retrieval_ms,
            "dispatch_ms": (t_dispatch - t_admit) * 1e3,
            "prefetch_ms": overlap,
            "decode_sample_ms": (t_sample - t_prefetch) * 1e3,
            "tick_ms": (t_sample - t0) * 1e3,
        }
        for k, v in self.last_tick.items():
            self.tick_totals[k] = self.tick_totals.get(k, 0.0) + v
        if self.overload is not None:
            self.overload.observe(self.last_tick["tick_ms"])
        return len(self._active())

    def _retire(self) -> None:
        for i, req in enumerate(self.slots):
            if req is not None and req.done:
                if req.status is None:
                    req.status = RequestStatus.OK
                if req.finished_tick is None:
                    req.finished_tick = self.tick_no
                if self.scheduler is not None:
                    self.scheduler.note_finished(req, req.status)
                self.finished.append(req)
                self.slots[i] = None
                self.slot_pos[i] = 0

    def stats(self) -> Dict[str, object]:
        """Engine counters, including the retrieval plane's per-tick
        batching and decoded-page cache hit/miss counters when the
        context_fn exposes them (e.g. :class:`GraphRetriever`) -- the
        observable signal that warm-tick serving stops re-paying decode
        and lake I/O for hot pages -- plus the pipeline's speculation
        counters and per-tick latency breakdown."""
        s: Dict[str, object] = {
            "steps": self.steps,
            "finished": len(self.finished),
            "queued": self._pending_count(),
            "active": len(self._active()),
        }
        if self.scheduler is not None:
            s["tenants"] = self.scheduler.stats()
            s["rejected"] = len(self.rejected)
        if self.deadline_exceeded:
            s["deadline_exceeded"] = self.deadline_exceeded
            s["expired_in_queue"] = self.expired_in_queue
        if self.overload is not None:
            s["overload"] = self.overload.stats()
        if self.faults is not None:
            s["faults"] = {
                "injected": dict(self.fault_hits),
                "recovered": self.faults_recovered,
                "backoff_s": round(self.fault_backoff_s, 3),
                "plan": self.faults.stats(),
            }
        s["pipeline"] = {
            "enabled": self.pipeline,
            "prefetch_issued": self.prefetch_issued,
            "prefetch_hits": self.prefetch_hits,
            "mis_speculations": self.mis_speculations,
            "pipeline_overlap_ms": round(self.pipeline_overlap_ms, 3),
            "last_tick": {k: round(v, 3)
                          for k, v in self.last_tick.items()},
            "totals": {k: round(v, 3)
                       for k, v in self.tick_totals.items()},
        }
        if self.context_fn is not None and hasattr(self.context_fn, "stats"):
            s["retrieval"] = self.context_fn.stats()
        return s

    def run_until_drained(self, max_ticks: int = 10_000) -> List[Request]:
        """Tick until queue and slots are empty; returns the requests
        retired during this call (in retirement order).

        Exhausting ``max_ticks`` with work still in flight raises
        :class:`UndrainedError` naming the stuck request ids -- a
        partial result must never masquerade as a drain."""
        start = len(self.finished)
        for _ in range(max_ticks):
            self.step()
            if not self._pending_count() \
                    and all(s is None for s in self.slots):
                return self.finished[start:]
        if self._pending_count() or any(s is not None for s in self.slots):
            queued = (self.scheduler.pending_ids()
                      if self.scheduler is not None
                      else [r.request_id for r in self.queue])
            active = [r.request_id for r in self.slots if r is not None]
            raise UndrainedError(queued, active, max_ticks)
        return self.finished[start:]
