"""Serving engine: continuous batching over a fixed-slot KV cache.

A vLLM-style (slot-based) scheduler adapted to the TPU static-shape world:
the engine owns ``max_slots`` cache rows; requests are admitted into free
slots, prefilled (per-request prefill into the slot), then all active
slots decode together with one batched ``decode_step`` per tick.  Finished
slots (EOS or max_tokens) are retired and immediately refilled from the
queue -- decode utilization stays high without dynamic shapes.

Retrieval-augmented requests name a ``context_vertex`` in the lake; the
engine gathers context for **all** requests admitted in a tick via one
batched neighbor retrieval (``context_fn``, e.g.
:class:`repro.serve.retrieval.GraphRetriever`) before prefill.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import LM
from .sampling import sample


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray                 # int32 tokens
    max_new_tokens: int = 32
    temperature: float = 0.0
    context_vertex: Optional[int] = None   # RAG seed vertex in the lake
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    context_tokens: int = 0            # context appended by the engine


class ServeEngine:
    def __init__(self, model: LM, params, max_slots: int = 4,
                 max_len: int = 512, eos_id: int = 2, seed: int = 0,
                 context_fn: Optional[
                     Callable[[np.ndarray], List[np.ndarray]]] = None):
        self.model = model
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.context_fn = context_fn
        self.queue: deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * max_slots
        self.finished: List[Request] = []
        # per-slot positions (vector index): slots advance independently
        self.cache = model.init_cache(max_slots, max_len,
                                      dtype=jnp.float32, vector_index=True)
        self.slot_pos = np.zeros(max_slots, np.int32)   # python-side mirror
        self.rng = jax.random.PRNGKey(seed)
        self._decode = jax.jit(model.decode_step)
        self.steps = 0

    # -- admission -------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def ingest(self, src, dst):
        """Forward an edge batch to the retrieval plane's mutable graph.

        Requires an ingest-capable ``context_fn`` (e.g.
        :class:`~repro.serve.retrieval.GraphRetriever`); ingested edges
        are visible to context retrieval from the next tick on.
        """
        if self.context_fn is None or not hasattr(self.context_fn,
                                                  "ingest"):
            raise ValueError("no ingest-capable context_fn attached")
        return self.context_fn.ingest(src, dst)

    def _attach_context(self, admitted: List[Request]) -> None:
        """One batched lake retrieval for every admitted request's seed."""
        need = [r for r in admitted if r.context_vertex is not None]
        if not need or self.context_fn is None:
            return
        contexts = self.context_fn(
            np.asarray([r.context_vertex for r in need], np.int64))
        for req, ctx in zip(need, contexts):
            ctx = np.asarray(ctx, np.int32)
            # leave room for generation within the slot's cache rows
            budget = self.max_len - 1 - req.max_new_tokens - len(req.prompt)
            ctx = ctx[:max(budget, 0)]
            if ctx.size:
                req.prompt = np.concatenate(
                    [np.asarray(req.prompt, np.int32), ctx])
                req.context_tokens = int(ctx.size)

    def _admit(self) -> None:
        free = [i for i in range(self.max_slots) if self.slots[i] is None]
        admitted: List[tuple] = []
        while free and self.queue:
            admitted.append((free.pop(0), self.queue.popleft()))
        self._attach_context([r for _, r in admitted])
        for slot, req in admitted:
            self._prefill_slot(slot, req)
            self.slots[slot] = req

    def _prefill_slot(self, slot: int, req: Request) -> None:
        """Per-slot prefill: runs the prompt through the model and writes
        this slot's cache rows (batch-1 prefill into a batched cache)."""
        prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
        tmp_cache = self.model.init_cache(1, self.max_len,
                                          dtype=jnp.float32)
        logits, tmp_cache = self.model.prefill(
            self.params, {"tokens": prompt}, tmp_cache)

        ms = self.max_slots

        def write(slot_arr, one_arr):
            # same rank: batch axis carries size 1 (tmp) vs max_slots
            if one_arr.ndim == slot_arr.ndim:
                if one_arr.ndim >= 1 and one_arr.shape[0] == 1 \
                        and slot_arr.shape[0] == ms:
                    return slot_arr.at[slot].set(one_arr[0])
                if one_arr.ndim >= 2 and one_arr.shape[1] == 1 \
                        and slot_arr.shape[1] == ms:  # scan-stacked leaves
                    return slot_arr.at[:, slot].set(one_arr[:, 0])
                return slot_arr
            # scalar index (tmp) -> per-slot vector index (engine)
            if one_arr.ndim + 1 == slot_arr.ndim:
                if slot_arr.ndim == 1:
                    return slot_arr.at[slot].set(one_arr)
                if slot_arr.ndim >= 2 and slot_arr.shape[1] == ms \
                        and slot_arr.shape[0] == one_arr.shape[0]:
                    return slot_arr.at[:, slot].set(one_arr)
            return slot_arr

        self.cache = jax.tree.map(write, self.cache, tmp_cache)
        self.slot_pos[slot] = len(req.prompt)
        tok = int(jnp.argmax(logits[0, -1]))
        req.output.append(tok)
        if tok == self.eos_id:
            req.done = True

    # -- decode tick -------------------------------------------------------------
    def _active(self) -> List[int]:
        return [i for i, r in enumerate(self.slots)
                if r is not None and not r.done]

    def step(self) -> int:
        """One engine tick: admit + one batched decode. Returns #active."""
        self._admit()
        active = self._active()
        if not active:
            self._retire()
            return 0
        tokens = np.zeros((self.max_slots, 1), np.int32)
        for i in active:
            tokens[i, 0] = self.slots[i].output[-1]
        logits, self.cache = self._decode(self.params,
                                          jnp.asarray(tokens), self.cache)
        self.steps += 1
        self.rng, sub = jax.random.split(self.rng)
        for i in active:
            req = self.slots[i]
            temp = req.temperature
            tok = int(sample(sub, logits[i:i + 1, 0], temperature=temp)[0])
            req.output.append(tok)
            self.slot_pos[i] += 1
            if tok == self.eos_id or \
                    len(req.output) >= req.max_new_tokens or \
                    int(self.slot_pos[i]) >= self.max_len - 1:
                req.done = True
        self._retire()
        return len(self._active())

    def _retire(self) -> None:
        for i, req in enumerate(self.slots):
            if req is not None and req.done:
                self.finished.append(req)
                self.slots[i] = None
                self.slot_pos[i] = 0

    def stats(self) -> Dict[str, object]:
        """Engine counters, including the retrieval plane's per-tick
        batching and decoded-page cache hit/miss counters when the
        context_fn exposes them (e.g. :class:`GraphRetriever`) -- the
        observable signal that warm-tick serving stops re-paying decode
        and lake I/O for hot pages."""
        s: Dict[str, object] = {
            "steps": self.steps,
            "finished": len(self.finished),
            "queued": len(self.queue),
            "active": len(self._active()),
        }
        if self.context_fn is not None and hasattr(self.context_fn, "stats"):
            s["retrieval"] = self.context_fn.stats()
        return s

    def run_until_drained(self, max_ticks: int = 10_000) -> List[Request]:
        """Tick until queue and slots are empty; returns the requests
        retired during this call (in retirement order)."""
        start = len(self.finished)
        for _ in range(max_ticks):
            self.step()
            if not self.queue and all(s is None for s in self.slots):
                break
        return self.finished[start:]
