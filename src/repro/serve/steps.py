"""Serving step builders (prefill / decode) for jit + sharding."""
from __future__ import annotations

from typing import Callable

from repro.models.model import LM


def make_prefill_step(model: LM) -> Callable:
    def prefill_step(params, batch, cache):
        return model.prefill(params, batch, cache)
    return prefill_step


def make_decode_step(model: LM) -> Callable:
    def decode_step(params, tokens, cache):
        return model.decode_step(params, tokens, cache)
    return decode_step
