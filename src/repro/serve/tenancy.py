"""Multi-tenant admission control for the serving plane.

The engine's single unbounded FIFO becomes, per tenant, a **token-bucket
admission gate** over a **bounded queue**, scheduled into free slots by
**deficit-weighted round-robin** (DWRR) -- backpressure, isolation, and
fairness as typed, testable mechanisms instead of a queue that grows
until the host dies:

* :class:`TenantConfig` -- one tenant's weight, rate/burst, queue bound,
  and default deadline;
* :func:`TenantScheduler.submit` returns a typed :class:`SubmitOutcome`:
  ``ADMITTED``, or ``REJECTED`` with a ``retry_after`` computed from the
  bucket's refill (rate rejection) or the queue bound (shed rejection) --
  the caller is *told* when trying again can work, it never just blocks;
* :meth:`TenantScheduler.pop` serves queued requests into free slots by
  DWRR: each visit credits ``quantum * weight`` deficit and serves one
  request per unit.  With every tenant backlogged, a full round serves
  *exactly* ``weight`` requests per tenant -- fairness is an equality the
  tests assert, not an emergent hope -- and any tenant with pending work
  is visited every round (starvation-free), while idle tenants donate
  their share (work-conserving);
* :meth:`TenantScheduler.peek` previews the next ``k`` pops without
  mutating anything, so the pipelined engine's speculative prefetch can
  predict the DWRR admission order exactly (a wrong prediction is caught
  by the engine's snapshot/rollback, as in PR 8).

All clocks are the engine's **tick counter** -- no wall-clock reads, so
every admission decision replays deterministically under a seeded test.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from collections import deque
from typing import Dict, List, Optional, Sequence

from repro.ft.backoff import TokenBucket


class SubmitStatus(enum.Enum):
    ADMITTED = "admitted"
    REJECTED = "rejected"


class RejectReason(enum.Enum):
    RATE_LIMITED = "rate_limited"    # token bucket empty
    QUEUE_FULL = "queue_full"        # bounded tenant queue at capacity
    UNKNOWN_TENANT = "unknown_tenant"


class RequestStatus(enum.Enum):
    """Terminal status of a request that entered the engine."""
    OK = "ok"                               # finished generating
    DEADLINE_EXCEEDED = "deadline_exceeded"  # expired (queued or in-slot)
    REJECTED = "rejected"                   # never admitted (shed at submit)


@dataclasses.dataclass(frozen=True)
class SubmitOutcome:
    """Typed result of ``submit``: admitted, or rejected with a reason
    and a ``retry_after`` hint in ticks (rate rejections compute it from
    the bucket's refill; ``None`` means retrying cannot help)."""
    status: SubmitStatus
    tenant: str
    reason: Optional[RejectReason] = None
    retry_after: Optional[float] = None

    @property
    def admitted(self) -> bool:
        return self.status is SubmitStatus.ADMITTED


@dataclasses.dataclass(frozen=True)
class TenantConfig:
    """One tenant's admission contract.

    ``weight``    -- DWRR share (integer >= 1): with all tenants
                     backlogged, tenant i receives weight_i / sum(weights)
                     of the admitted slots;
    ``rate``      -- token-bucket refill in requests/tick (``None`` =
                     unmetered: admission limited only by the queue bound);
    ``burst``     -- bucket capacity (defaults to ``max(rate, 1)``);
    ``max_queue`` -- bounded queue depth; submits beyond it shed with
                     ``QUEUE_FULL`` (backpressure to the client, not an
                     unbounded backlog);
    ``deadline_ticks`` -- default per-request deadline (ticks from
                     submission to completion); ``None`` = no deadline.
    """
    name: str
    weight: int = 1
    rate: Optional[float] = None
    burst: Optional[float] = None
    max_queue: int = 64
    deadline_ticks: Optional[int] = None

    def __post_init__(self):
        if self.weight < 1:
            raise ValueError("weight must be >= 1")
        if self.max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        if self.rate is not None and self.rate <= 0:
            raise ValueError("rate must be > 0 (None for unmetered)")


class _TenantState:
    """Scheduler-internal per-tenant state: bounded queue, bucket,
    counters."""

    def __init__(self, cfg: TenantConfig, now: float):
        self.cfg = cfg
        self.queue: deque = deque()
        self.bucket = (TokenBucket(cfg.rate, cfg.burst or max(cfg.rate, 1.0),
                                   now=now)
                       if cfg.rate is not None else None)
        self.deficit = 0.0
        self.submitted = 0
        self.admitted = 0
        self.rejected_rate = 0
        self.rejected_queue = 0
        self.expired = 0
        self.popped = 0
        self.finished_ok = 0
        self.finished_failed = 0


class TenantScheduler:
    """Per-tenant token-bucket admission + DWRR scheduling (see module
    docstring).  The clock is whatever monotone counter the caller
    passes (the engine's tick number)."""

    def __init__(self, tenants: Sequence[TenantConfig],
                 quantum: float = 1.0, now: float = 0.0):
        if not tenants:
            raise ValueError("need at least one TenantConfig")
        names = [t.name for t in tenants]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant names in {names}")
        if quantum <= 0:
            raise ValueError("quantum must be > 0")
        self.quantum = float(quantum)
        self._state: Dict[str, _TenantState] = {
            t.name: _TenantState(t, now) for t in tenants}
        #: tenants with pending work, in DWRR visit order
        self._active: deque = deque()
        #: True while the head tenant's current visit has already been
        #: credited its quantum -- a pop() that fills k mid-visit resumes
        #: the visit on the next call *without* crediting again (else a
        #: stream of pop(1) calls would grant the head unbounded credit)
        self._head_credited = False

    # -- admission gate --------------------------------------------------------
    def submit(self, req, now: float) -> SubmitOutcome:
        """Gate ``req`` (an engine ``Request`` with a ``tenant`` field)
        through its tenant's bucket and queue bound at tick ``now``.
        On admission the request's ``submitted_tick``/``deadline_at``
        are stamped and it joins the tenant's queue."""
        name = getattr(req, "tenant", None) or "default"
        st = self._state.get(name)
        if st is None:
            return SubmitOutcome(SubmitStatus.REJECTED, name,
                                 RejectReason.UNKNOWN_TENANT, None)
        st.submitted += 1
        if len(st.queue) >= st.cfg.max_queue:
            st.rejected_queue += 1
            # the queue drains at most one request per tick per slot; the
            # honest hint is the bucket-style one: one refill period (or
            # one tick when unmetered) before a slot can have opened
            wait = 1.0 / st.cfg.rate if st.cfg.rate else 1.0
            return SubmitOutcome(SubmitStatus.REJECTED, name,
                                 RejectReason.QUEUE_FULL,
                                 math.ceil(wait))
        if st.bucket is not None:
            ok, wait = st.bucket.try_take(now)
            if not ok:
                st.rejected_rate += 1
                return SubmitOutcome(
                    SubmitStatus.REJECTED, name, RejectReason.RATE_LIMITED,
                    math.ceil(wait) if math.isfinite(wait) else None)
        req.submitted_tick = now
        dl = (req.deadline_ticks if req.deadline_ticks is not None
              else st.cfg.deadline_ticks)
        if dl is not None:
            req.deadline_at = now + dl
        st.admitted += 1
        if not st.queue:
            self._active.append(name)
        st.queue.append(req)
        return SubmitOutcome(SubmitStatus.ADMITTED, name)

    # -- deadline expiry -------------------------------------------------------
    def expire(self, now: float) -> List:
        """Remove and return queued requests whose deadline has passed
        (``now > deadline_at``: the request had every tick up to and
        including its budget) -- they finish with a typed
        ``DEADLINE_EXCEEDED`` status without ever occupying a slot."""
        out = []
        for name, st in self._state.items():
            if not st.queue:
                continue
            kept = deque()
            for req in st.queue:
                da = getattr(req, "deadline_at", None)
                if da is not None and now > da:
                    st.expired += 1
                    out.append(req)
                else:
                    kept.append(req)
            if len(kept) != len(st.queue):
                st.queue = kept
                if not kept:
                    st.deficit = 0.0
                    if self._active and self._active[0] == name:
                        # the mid-visit head vanished: its residual
                        # credit dies with it
                        self._head_credited = False
                    self._active = deque(n for n in self._active
                                         if n != name)
        return out

    # -- DWRR service ----------------------------------------------------------
    def pop(self, k: int, now: Optional[float] = None) -> List:
        """Serve up to ``k`` requests by deficit-weighted round-robin.
        Work-conserving: returns ``min(k, pending())`` requests."""
        out: List = []
        while len(out) < k and self._active:
            name = self._active[0]
            st = self._state[name]
            if not self._head_credited:
                st.deficit += self.quantum * st.cfg.weight
                self._head_credited = True
            while st.queue and st.deficit >= 1.0 and len(out) < k:
                out.append(st.queue.popleft())
                st.deficit -= 1.0
                st.popped += 1
            if not st.queue:
                # an emptied tenant forfeits residual deficit -- credit
                # must not accumulate while idle (classic DWRR)
                st.deficit = 0.0
                self._active.popleft()
                self._head_credited = False
            elif st.deficit < 1.0:
                self._active.rotate(-1)
                self._head_credited = False
            # else: k filled mid-visit (queue and deficit both remain) --
            # the tenant stays at the head, still credited; the next pop
            # resumes exactly here without granting a second quantum
        return out

    def peek(self, k: int) -> List:
        """The next ``k`` requests :meth:`pop` would return, without
        mutating any state -- the pipelined engine's speculative
        admission preview."""
        deficit = {n: st.deficit for n, st in self._state.items()}
        active = deque(self._active)
        idx = {n: 0 for n in self._state}
        credited = self._head_credited    # resume state of the head visit
        out: List = []
        while len(out) < k and active:
            name = active[0]
            st = self._state[name]
            q = st.queue
            if not credited:
                deficit[name] += self.quantum * st.cfg.weight
            credited = False              # later visits are fresh
            while idx[name] < len(q) and deficit[name] >= 1.0 \
                    and len(out) < k:
                out.append(q[idx[name]])
                idx[name] += 1
                deficit[name] -= 1.0
            if idx[name] >= len(q):
                active.popleft()
            elif deficit[name] < 1.0:
                active.rotate(-1)
            else:
                break                     # k filled mid-visit
        return out

    # -- introspection ---------------------------------------------------------
    def pending(self) -> int:
        return sum(len(st.queue) for st in self._state.values())

    def pending_ids(self) -> List[int]:
        return [req.request_id for st in self._state.values()
                for req in st.queue]

    def queue_depth(self, tenant: str) -> int:
        return len(self._state[tenant].queue)

    def configs(self) -> Dict[str, TenantConfig]:
        return {n: st.cfg for n, st in self._state.items()}

    def note_finished(self, req, status: RequestStatus) -> None:
        """Engine callback at retirement: per-tenant outcome counters."""
        st = self._state.get(getattr(req, "tenant", None) or "default")
        if st is None:
            return
        if status is RequestStatus.OK:
            st.finished_ok += 1
        else:
            st.finished_failed += 1

    def stats(self) -> Dict[str, Dict[str, object]]:
        """Per-tenant admission/fairness counters (``stats()["tenants"]``)."""
        out: Dict[str, Dict[str, object]] = {}
        for name, st in self._state.items():
            out[name] = {
                "weight": st.cfg.weight,
                "rate": st.cfg.rate,
                "max_queue": st.cfg.max_queue,
                "queue_depth": len(st.queue),
                "bucket_level": (round(st.bucket.level, 3)
                                 if st.bucket is not None else None),
                "deficit": round(st.deficit, 3),
                "submitted": st.submitted,
                "admitted": st.admitted,
                "rejected_rate": st.rejected_rate,
                "rejected_queue_full": st.rejected_queue,
                "expired": st.expired,
                "scheduled": st.popped,
                "finished_ok": st.finished_ok,
                "finished_failed": st.finished_failed,
            }
        return out
