"""Elastic resharding: restore a checkpoint onto a different mesh/topology.

Checkpoints store *global* logical arrays (host-side numpy), so moving
between meshes is a metadata problem, not a data problem: the restore path
re-chunks each leaf for the new mesh's NamedShardings without ever
materializing more than one leaf at a time (bounded host memory).  This is
the mechanism behind elastic scale-down (lose a pod, resume on one) and
scale-up.

``plan_reshard`` additionally reports, per leaf, which byte ranges each new
device needs -- on a real cluster this drives host-to-host transfer
planning; here it documents/tests the chunking math.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.distributed.sharding import shard_params


def device_put_resharded(tree, mesh: Mesh):
    """Place a host pytree onto ``mesh`` with the framework sharding rules."""
    shardings = shard_params(tree, mesh)
    return jax.tree.map(
        lambda leaf, sh: jax.device_put(np.asarray(leaf), sh),
        tree, shardings)


def plan_reshard(shape: Tuple[int, ...], old_spec_shards: int,
                 new_spec_shards: int, axis: int = 0) -> List[Dict]:
    """Chunk-movement plan for one leaf resharded along ``axis``.

    Returns, for each new shard, the list of (old_shard, slice) pairs it
    reads -- the host transfer schedule for elastic restore.
    """
    n = shape[axis]
    assert n % old_spec_shards == 0 and n % new_spec_shards == 0
    old_sz = n // old_spec_shards
    new_sz = n // new_spec_shards
    plan = []
    for new_i in range(new_spec_shards):
        lo, hi = new_i * new_sz, (new_i + 1) * new_sz
        reads = []
        o = lo // old_sz
        while o * old_sz < hi:
            s = max(lo, o * old_sz)
            e = min(hi, (o + 1) * old_sz)
            reads.append({"old_shard": o,
                          "offset": s - o * old_sz,
                          "length": e - s})
            o += 1
        plan.append({"new_shard": new_i, "reads": reads,
                     "bytes_factor": sum(r["length"] for r in reads) / n})
    return plan


def elastic_restore(directory: str, step: int, like, new_mesh: Mesh):
    """Restore a checkpoint saved on any mesh onto ``new_mesh``."""
    from .checkpointer import restore_checkpoint
    host_tree, extra = restore_checkpoint(directory, step, like=like)
    return device_put_resharded(host_tree, new_mesh), extra
