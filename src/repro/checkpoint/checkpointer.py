"""Sharded checkpointing with atomic commit.

Layout: ``<dir>/step_<N>/`` containing one ``.npy``-encoded shard file per
host plus ``manifest.json`` describing the pytree, the mesh each leaf was
sharded over, and a content checksum.  A checkpoint is *committed* by
atomically renaming ``step_<N>.tmp -> step_<N>`` after every shard and the
manifest are fsync'd -- the restore path only ever sees committed
checkpoints, which is the invariant the FT coordinator restarts against.

On this single-process container each "host" shard is a slice of the
global array; on a real multi-host pod the same code writes
``jax.experimental.multihost_utils``-style per-host shards (the manifest
format carries ``process_index``).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flat_with_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        out.append((path, leaf))
    return out


def save_checkpoint(directory: str, step: int, tree,
                    extra: Optional[Dict] = None) -> str:
    """Write + atomically commit one checkpoint. Returns final path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "created": time.time(),
                "extra": extra or {}, "leaves": []}
    for i, (path, leaf) in enumerate(_flat_with_paths(tree)):
        arr = np.asarray(leaf)
        fname = f"shard_{i:05d}.npy"
        fpath = os.path.join(tmp, fname)
        np.save(fpath, arr)
        with open(fpath, "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()[:16]
        manifest["leaves"].append({
            "path": path, "file": fname, "shape": list(arr.shape),
            "dtype": str(arr.dtype), "sha": digest,
            "process_index": jax.process_index()})
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


def list_checkpoints(directory: str) -> List[int]:
    """Committed checkpoints only (ignores .tmp)."""
    if not os.path.isdir(directory):
        return []
    out = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, d, "manifest.json")):
                out.append(int(d.split("_")[1]))
    return sorted(out)


def latest_checkpoint(directory: str) -> Optional[int]:
    steps = list_checkpoints(directory)
    return steps[-1] if steps else None


def restore_checkpoint(directory: str, step: int, like=None,
                       verify: bool = True) -> Tuple[Any, Dict]:
    """Restore into the structure of ``like`` (or a flat dict by path)."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_path: Dict[str, np.ndarray] = {}
    for leaf in manifest["leaves"]:
        fpath = os.path.join(path, leaf["file"])
        if verify:
            with open(fpath, "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()[:16]
            if digest != leaf["sha"]:
                raise IOError(
                    f"checksum mismatch in {fpath} (corrupt checkpoint)")
        by_path[leaf["path"]] = np.load(fpath)
    if like is None:
        return by_path, manifest["extra"]
    flat = _flat_with_paths(like)
    leaves = []
    for p, ref in flat:
        if p not in by_path:
            raise KeyError(f"checkpoint missing leaf {p}")
        arr = by_path[p]
        if list(arr.shape) != list(ref.shape):
            raise ValueError(
                f"{p}: checkpoint shape {arr.shape} != expected {ref.shape}"
                " (use reshard.py for elastic restore)")
        leaves.append(arr.astype(ref.dtype) if hasattr(ref, "dtype") else arr)
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)
    return tree, manifest["extra"]


def prune_checkpoints(directory: str, keep: int = 3) -> None:
    steps = list_checkpoints(directory)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"))
