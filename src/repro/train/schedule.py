"""Learning-rate schedules (warmup-cosine / linear / rsqrt)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(peak: float, warmup_steps: int, total_steps: int,
                  floor: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = s / max(warmup_steps, 1)
        prog = jnp.clip((s - warmup_steps) /
                        max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return peak * jnp.where(s < warmup_steps, warm, cos)
    return fn


def warmup_linear(peak: float, warmup_steps: int, total_steps: int):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = s / max(warmup_steps, 1)
        prog = jnp.clip((s - warmup_steps) /
                        max(total_steps - warmup_steps, 1), 0.0, 1.0)
        return peak * jnp.where(s < warmup_steps, warm, 1.0 - prog)
    return fn


def warmup_rsqrt(peak: float, warmup_steps: int):
    def fn(step):
        s = jnp.maximum(step.astype(jnp.float32), 1.0)
        warm = s / max(warmup_steps, 1)
        return peak * jnp.where(s < warmup_steps, warm,
                                jnp.sqrt(warmup_steps / s))
    return fn
