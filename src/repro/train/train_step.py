"""Train-step builder: grad accumulation, mixed precision, clipping.

``make_train_step(model, opt, n_micro)`` returns a pure function
``(params, opt_state, batch) -> (params, opt_state, metrics)`` suitable for
``jax.jit`` with FSDP/TP shardings.  The global batch is split into
``n_micro`` microbatches consumed by an internal ``lax.scan`` -- activation
memory is bounded by one microbatch while arithmetic matches large-batch
training exactly (gradients are mean-accumulated in fp32).

Optional cross-pod gradient compression (int8 + error feedback) lives in
repro.distributed.collectives and is applied by the trainer loop, not here:
under ``jit`` + GSPMD the all-reduce is implicit in the sharding, so
compression is expressed by quantizing the *accumulated* gradient leaves
before the optimizer on the slow axis (see DESIGN.md §6).
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import LM
from .optimizer import Optimizer


def _split_micro(batch: Dict, n_micro: int) -> Dict:
    from repro.distributed.sharding import constrain

    def one(x):
        b = x.shape[0]
        assert b % n_micro == 0, f"batch {b} % micro {n_micro}"
        out = x.reshape((n_micro, b // n_micro) + x.shape[1:])
        # keep microbatches batch-sharded over data axes after the reshape
        return constrain(out, None, "dp", *([None] * (out.ndim - 2)))
    return jax.tree.map(one, batch)


def make_train_step(model: LM, opt: Optimizer, n_micro: int = 1,
                    accum_dtype=jnp.float32) -> Callable:
    def loss_fn(params, mb):
        loss, metrics = model.loss(params, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        from repro.distributed.sharding import constrain_like_params
        if n_micro == 1:
            (loss, inner), grads = grad_fn(params, batch)
            grads = constrain_like_params(
                jax.tree.map(lambda g: g.astype(accum_dtype), grads))
        else:
            micro = _split_micro(batch, n_micro)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)

            def body(carry, mb):
                gsum, lsum = carry
                (l, inner), g = grad_fn(params, mb)
                g = constrain_like_params(
                    jax.tree.map(lambda a: a.astype(accum_dtype), g))
                gsum = jax.tree.map(lambda a, b: a + b, gsum, g)
                return (gsum, lsum + l), inner

            (gsum, lsum), inners = jax.lax.scan(
                body, (zero, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            loss = lsum / n_micro
            inner = jax.tree.map(lambda x: x[-1], inners)
        new_params, new_state, stats = opt.update(grads, opt_state, params)
        metrics = {"loss": loss, **stats,
                   "ce": inner.get("ce", loss), "aux": inner.get("aux", 0.0)}
        return new_params, new_state, metrics

    return train_step
