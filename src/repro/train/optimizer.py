"""Optimizers built from scratch (no optax): AdamW + Adafactor.

Production-memory features:

* **moment dtype policy** -- AdamW first/second moments in fp32, bf16, or
  **int8 block-quantized** (128-value blocks with an fp32 scale each).
  bf16/int8 moments are what let the 398B Jamba fit a 256-chip v5e pod
  (EXPERIMENTS.md §Dry-run).
* global-norm clipping, decoupled weight decay, bias correction.
* Adafactor (factored second moment) for memory-constrained fallbacks.

States are plain pytrees -> they shard with the same FSDP rules as params
and checkpoint/reshard transparently.  Update returns
``(new_params, new_state, stats)`` with a structure-stable state.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

QBLOCK = 128


# ---------------------------------------------------------------------------
# int8 blockwise quantization for optimizer moments
# ---------------------------------------------------------------------------

def _quantize_int8(x: jnp.ndarray) -> Dict:
    """Blockwise int8 along the LAST axis only.

    A global ``reshape(-1)`` of an FSDP/TP-sharded matrix destroys its
    sharding (GSPMD replicates the full fp32 tensor and moves it through
    weight-shaped collectives -- measured ~19 GB/layer on the 123B dense
    config, EXPERIMENTS.md §Perf iter 5).  Splitting only the last dim
    into (n_blocks, 128) keeps every leading-dim sharding intact; odd
    last dims (small replicated vectors) are zero-padded locally.
    """
    if x.ndim == 0:
        x = x[None]
    last = x.shape[-1]
    pad = (-last) % QBLOCK
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    blocks = x.reshape(x.shape[:-1] + (-1, QBLOCK))
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32)}


def _dequantize_int8(s: Dict, like: jnp.ndarray) -> jnp.ndarray:
    full = (s["q"].astype(jnp.float32) * s["scale"])
    full = full.reshape(full.shape[:-2] + (-1,))
    shape = like.shape if like.ndim else (1,)
    out = full[..., : shape[-1]]
    return out.reshape(like.shape)


def _moment_init(p: jnp.ndarray, dtype: str):
    if dtype == "int8":
        return _quantize_int8(jnp.zeros(p.shape, jnp.float32))
    return jnp.zeros_like(
        p, dtype={"float32": jnp.float32, "bfloat16": jnp.bfloat16}[dtype])


def _moment_read(m, like: jnp.ndarray, dtype: str) -> jnp.ndarray:
    if dtype == "int8":
        return _dequantize_int8(m, like)
    return m.astype(jnp.float32)


def _moment_write(x: jnp.ndarray, dtype: str):
    if dtype == "int8":
        return _quantize_int8(x)
    return x.astype({"float32": jnp.float32,
                     "bfloat16": jnp.bfloat16}[dtype])


# ---------------------------------------------------------------------------
# Optimizer interface
# ---------------------------------------------------------------------------

class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any, Dict]]
    # update(grads, state, params) -> (new_params, new_state, stats)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    """Dtype-preserving clip: the norm is an f32 *reduction* (fused, no
    materialized copy), the scale is applied in each leaf's own dtype --
    casting leaves to f32 here forced GSPMD to move fp32 weight-shaped
    gradients through every collective (2x bytes; EXPERIMENTS.md §Perf
    iter 5)."""
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def _is_arr(x):
    return hasattr(x, "shape") and hasattr(x, "dtype")


def adamw(lr: Callable[[jnp.ndarray], jnp.ndarray] | float,
          b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, max_grad_norm: float = 1.0,
          moment_dtype: str = "float32") -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))
    is_q = (lambda x: isinstance(x, dict) and set(x) == {"q", "scale"})

    def init(params):
        return {
            "m": jax.tree.map(lambda p: _moment_init(p, moment_dtype),
                              params, is_leaf=_is_arr),
            "v": jax.tree.map(lambda p: _moment_init(p, moment_dtype),
                              params, is_leaf=_is_arr),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        lr_t = lr_fn(step)
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)  # local elementwise cast (fuses)
            mf = b1 * _moment_read(m, p, moment_dtype) + (1 - b1) * g
            vf = b2 * _moment_read(v, p, moment_dtype) + (1 - b2) * g * g
            delta = (mf / bc1) / (jnp.sqrt(vf / bc2) + eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                delta = delta + weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype)
            return new_p, _moment_write(mf, moment_dtype), \
                _moment_write(vf, moment_dtype)

        leaves_p, treedef = jax.tree_util.tree_flatten(params)
        leaves_g = treedef.flatten_up_to(grads)
        flat_m = jax.tree_util.tree_flatten(state["m"], is_leaf=is_q)[0] \
            if moment_dtype == "int8" else treedef.flatten_up_to(state["m"])
        flat_v = jax.tree_util.tree_flatten(state["v"], is_leaf=is_q)[0] \
            if moment_dtype == "int8" else treedef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v
               in zip(leaves_p, leaves_g, flat_m, flat_v)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "step": step}, \
            {"grad_norm": gnorm, "lr": lr_t}

    return Optimizer(init, update)


def adafactor(lr: Callable | float = 1e-3, eps: float = 1e-30,
              decay: float = 0.8, max_grad_norm: float = 1.0) -> Optimizer:
    """Factored second-moment optimizer (rows+cols for 2D+; full for 1D)."""
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params):
        def one(p):
            if p.ndim >= 2:
                return {"row": jnp.zeros(p.shape[:-1], jnp.float32),
                        "col": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                         jnp.float32)}
            return {"full": jnp.zeros(p.shape, jnp.float32)}
        return {"v": jax.tree.map(one, params, is_leaf=_is_arr),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        lr_t = lr_fn(step)
        beta = 1.0 - step.astype(jnp.float32) ** (-decay)

        def upd(p, g, v):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if p.ndim >= 2:
                row = beta * v["row"] + (1 - beta) * g2.mean(-1)
                col = beta * v["col"] + (1 - beta) * g2.mean(-2)
                rms = (row[..., :, None] * col[..., None, :]
                       / jnp.maximum(row.mean(-1, keepdims=True)[..., None],
                                     eps))
                delta = g * jax.lax.rsqrt(jnp.maximum(rms, eps))
                nv = {"row": row, "col": col}
            else:
                full = beta * v["full"] + (1 - beta) * g2
                delta = g * jax.lax.rsqrt(jnp.maximum(full, eps))
                nv = {"full": full}
            new_p = (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype)
            return new_p, nv

        is_v = (lambda x: isinstance(x, dict)
                and set(x) <= {"row", "col", "full"})
        leaves_p, treedef = jax.tree_util.tree_flatten(params)
        leaves_g = treedef.flatten_up_to(grads)
        leaves_v = jax.tree_util.tree_flatten(state["v"], is_leaf=is_v)[0]
        out = [upd(p, g, v) for p, g, v
               in zip(leaves_p, leaves_g, leaves_v)]
        return treedef.unflatten([o[0] for o in out]), \
            {"v": treedef.unflatten([o[1] for o in out]), "step": step}, \
            {"grad_norm": gnorm, "lr": lr_t}

    return Optimizer(init, update)
