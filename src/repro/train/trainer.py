"""Training loop: data pipeline + train_step + checkpoints + FT hooks.

The loop is deliberately host-driven and restartable: every piece of
mutable state (params, opt state, data cursor) either lives in the
checkpoint or is derived from (seed, step).  ``Trainer.run`` survives a
mid-run ``simulate_failure_at`` by restoring the latest committed
checkpoint and replaying the data cursor -- the exact behaviour the FT
coordinator triggers on real failures.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Iterator, List, Optional

import jax
import numpy as np

from repro.checkpoint.checkpointer import (latest_checkpoint,
                                           prune_checkpoints,
                                           restore_checkpoint,
                                           save_checkpoint)
from repro.ft.coordinator import Action, Coordinator
from repro.models.model import LM
from .optimizer import Optimizer
from .train_step import make_train_step


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    log_every: int = 10
    n_micro: int = 1


class Trainer:
    def __init__(self, model: LM, opt: Optimizer, cfg: TrainerConfig,
                 batch_fn: Callable[[int], Dict],
                 coordinator: Optional[Coordinator] = None):
        self.model = model
        self.opt = opt
        self.cfg = cfg
        self.batch_fn = batch_fn
        self.coordinator = coordinator
        self.step_fn = jax.jit(make_train_step(model, opt, cfg.n_micro))
        self.history: List[Dict] = []

    def _init_state(self):
        params = self.model.init(0)
        opt_state = self.opt.init(params)
        return params, opt_state, 0

    def _try_restore(self, params, opt_state):
        step = latest_checkpoint(self.cfg.checkpoint_dir)
        if step is None:
            return params, opt_state, 0
        tree, extra = restore_checkpoint(
            self.cfg.checkpoint_dir, step,
            like={"params": params, "opt": opt_state})
        return tree["params"], tree["opt"], int(extra["next_step"])

    def run(self, resume: bool = True,
            simulate_failure_at: Optional[int] = None) -> Dict:
        params, opt_state, start = self._init_state()
        if resume:
            params, opt_state, start = self._try_restore(params, opt_state)
        step = start
        failures = 0
        while step < self.cfg.total_steps:
            t0 = time.perf_counter()
            if simulate_failure_at is not None and step == simulate_failure_at:
                simulate_failure_at = None
                failures += 1
                # crash-restart: drop live state, restore committed ckpt
                params, opt_state, step = self._init_state()
                params, opt_state, step = self._try_restore(params,
                                                            opt_state)
                continue
            batch = self.batch_fn(step)
            params, opt_state, metrics = self.step_fn(params, opt_state,
                                                      batch)
            dt = time.perf_counter() - t0
            if self.coordinator is not None:
                self.coordinator.heartbeat(0, step, dt)
                decision = self.coordinator.tick(
                    latest_checkpoint(self.cfg.checkpoint_dir))
                if decision.action in (Action.RESTART_FROM_CHECKPOINT,
                                       Action.ELASTIC_SCALE_DOWN):
                    params, opt_state, step = self._init_state()
                    params, opt_state, step = self._try_restore(params,
                                                                opt_state)
                    failures += 1
                    continue
            step += 1
            if step % self.cfg.log_every == 0 or step == self.cfg.total_steps:
                self.history.append(
                    {"step": step,
                     "loss": float(metrics["loss"]),
                     "grad_norm": float(metrics["grad_norm"]),
                     "sec_per_step": dt})
            if step % self.cfg.checkpoint_every == 0:
                save_checkpoint(self.cfg.checkpoint_dir, step,
                                {"params": params, "opt": opt_state},
                                extra={"next_step": step})
                prune_checkpoints(self.cfg.checkpoint_dir,
                                  self.cfg.keep_checkpoints)
        return {"params": params, "opt_state": opt_state,
                "history": self.history, "failures": failures,
                "final_step": step}
