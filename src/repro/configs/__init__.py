"""Architecture registry: ``get_config("<arch-id>")`` / ``list_archs()``."""
from .base import (FULL_WINDOW, LayerSpec, ModelConfig, MoESpec, SSMSpec,
                   get_config, list_archs, register)

_LOADED = False


def _load_all():
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    from . import (deepseek_moe_16b, gemma3_4b, jamba_1_5_large_398b,  # noqa
                   llama32_vision_11b, mamba2_2_7b, mistral_large_123b,
                   qwen3_moe_30b_a3b, smollm_360m, stablelm_1_6b,
                   whisper_small)


ASSIGNED_ARCHS = (
    "jamba-1.5-large-398b", "gemma3-4b", "smollm-360m", "stablelm-1.6b",
    "mistral-large-123b", "whisper-small", "llama-3.2-vision-11b",
    "qwen3-moe-30b-a3b", "deepseek-moe-16b", "mamba2-2.7b",
)
