"""smollm-360m [dense]: 32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152.

Llama-architecture small model [hf:HuggingFaceTB/SmolLM-360M].
Pure full attention -> long_500k skipped (DESIGN.md §Arch-applicability).
"""
from .base import LayerSpec, ModelConfig, register


@register("smollm-360m")
def make_config() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m", family="dense",
        d_model=960, vocab_size=49152,
        num_heads=15, num_kv_heads=5, head_dim=64,
        d_ff=2560,
        unit=(LayerSpec(kind="attn"),), n_units=32,
        tie_embeddings=True,
        param_dtype="bfloat16", compute_dtype="bfloat16",
        remat="dots", supports_long=False, train_microbatches=4)
