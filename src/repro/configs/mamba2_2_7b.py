"""mamba2-2.7b [ssm]: 64L d_model=2560 attn-free, ssm_state=128
[arXiv:2405.21060].  d_inner = 2*d_model, 64-dim SSD heads (80 heads),
no FFN sub-layer (pure mixer stack).  Runs long_500k (O(1)-state decode).
"""
from .base import LayerSpec, ModelConfig, SSMSpec, register


@register("mamba2-2.7b")
def make_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b", family="ssm",
        d_model=2560, vocab_size=50280,
        unit=(LayerSpec(kind="ssm", mlp=False),), n_units=64,
        ssm=SSMSpec(num_heads=80, head_dim=64, state_dim=128, n_groups=1,
                    conv_width=4, chunk_len=256),
        tie_embeddings=True, use_rope=False,
        param_dtype="bfloat16", compute_dtype="bfloat16",
        remat="dots", supports_long=True, train_microbatches=4)
