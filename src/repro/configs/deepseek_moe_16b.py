"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (MHA kv=16) fine-grained
MoE: 64 routed experts top-6 (d_expert=1408) + 2 shared experts, dense
first layer (d_ff=10944) [arXiv:2401.06066].

Layer program: prefix = 1 dense-FFN attention layer (unrolled), then a
27-unit scan of attention+MoE layers.
"""
from .base import LayerSpec, ModelConfig, MoESpec, register


@register("deepseek-moe-16b")
def make_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b", family="moe",
        d_model=2048, vocab_size=102400,
        num_heads=16, num_kv_heads=16, head_dim=128,
        d_ff=1408, prefix_d_ff=10944,
        prefix=(LayerSpec(kind="attn", moe=False),),
        unit=(LayerSpec(kind="attn", moe=True),), n_units=27,
        moe=MoESpec(num_experts=64, top_k=6, d_expert=1408,
                    num_shared=2, d_shared=2816),
        param_dtype="bfloat16", compute_dtype="bfloat16",
        remat="dots", supports_long=False, train_microbatches=4)
