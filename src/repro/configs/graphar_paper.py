"""The paper's own workload configurations (§6): graph scales, layout
parameters, and media constants -- the knobs the GraphAr benchmarks run
with, registered alongside the LM architectures for the CLI.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple


@dataclasses.dataclass(frozen=True)
class GraphArConfig:
    name: str
    page_size: int = 2048          # rows per data page (paper: 1MB pages)
    row_group: int = 1024 * 1024   # rows per row group (paper default)
    miniblock: int = 32            # delta miniblock (Parquet default)
    bmi_max_width: int = 4         # kernel path for widths 1..4 (paper §4.3)
    adjacency: Tuple[str, ...] = ("by_src", "by_dst")   # CSR + CSC
    label_encoding: str = "rle"


#: scaled stand-ins for the paper's Table 1 / LDBC SNB graphs
PAPER_WORKLOADS: Dict[str, Dict] = {
    "snb-sf-small": {"scale": 1, "queries": ("is3", "ic8", "bi2")},
    "snb-sf-medium": {"scale": 2, "queries": ("is3", "ic8", "bi2")},
    "topology-suite": {"graphs": ("CI", "OL", "HW", "WK")},
    "label-suite": {"graphs": ("BL", "AX", "MA", "PO")},
}


def default_config() -> GraphArConfig:
    return GraphArConfig(name="graphar-default")
