"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.

5:1 local:global attention (1024-token sliding windows, every 6th layer
global), qk-norm, tied + scaled embeddings [hf:google/gemma-3-4b-pt].
The per-layer window pattern is carried as a traced array so the 34-layer
stack scans homogeneously.  Runs long_500k: decode is O(L) and 29/34 layers
are O(window) -- see DESIGN.md §Arch-applicability.
"""
from .base import LayerSpec, ModelConfig, register

LOCAL_WINDOW = 1024


@register("gemma3-4b")
def make_config() -> ModelConfig:
    n_layers = 34
    # pattern: L L L L L G repeated (global at indices 5, 11, 17, 23, 29)
    windows = tuple(0 if (i % 6) == 5 else LOCAL_WINDOW
                    for i in range(n_layers))
    return ModelConfig(
        name="gemma3-4b", family="dense",
        d_model=2560, vocab_size=262144,
        num_heads=8, num_kv_heads=4, head_dim=256,
        d_ff=10240, act="gelu",
        qk_norm=True, tie_embeddings=True, scale_embeddings=True,
        unit=(LayerSpec(kind="attn"),), n_units=n_layers,
        window_pattern=windows,
        rope_theta=1_000_000.0,
        param_dtype="bfloat16", compute_dtype="bfloat16",
        remat="dots", supports_long=True, train_microbatches=4)
