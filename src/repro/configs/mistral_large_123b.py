"""mistral-large-123b [dense]: 88L d_model=12288 96H (GQA kv=8) d_ff=28672
vocab=32768 [hf:mistralai/Mistral-Large-Instruct-2407]."""
from .base import LayerSpec, ModelConfig, register


@register("mistral-large-123b")
def make_config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-123b", family="dense",
        d_model=12288, vocab_size=32768,
        num_heads=96, num_kv_heads=8, head_dim=128,
        d_ff=28672,
        unit=(LayerSpec(kind="attn"),), n_units=88,
        rope_theta=1_000_000.0,
        param_dtype="bfloat16", compute_dtype="bfloat16",
        remat="full", supports_long=False, train_microbatches=4)
