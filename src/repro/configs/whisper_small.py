"""whisper-small [audio]: 12L enc + 12L dec, d_model=768 12H d_ff=3072
vocab=51865, encoder-decoder [arXiv:2212.04356].

The conv frontend is a STUB: ``input_specs()`` supplies precomputed frame
embeddings [B, frames, d_model].  Deviations noted in DESIGN.md: rotary
positions instead of learned/sinusoidal.  Full attention -> long_500k
skipped; decode shapes exercise self-KV + cross-KV caches.
"""
from .base import LayerSpec, ModelConfig, register


@register("whisper-small")
def make_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small", family="encdec",
        d_model=768, vocab_size=51865,
        num_heads=12, num_kv_heads=12, head_dim=64,
        d_ff=3072, norm="layer", act="gelu", gated_mlp=False,
        unit=(LayerSpec(kind="attn", cross=True),), n_units=12,
        encoder_layers=12, default_encoder_len=1500,
        param_dtype="bfloat16", compute_dtype="bfloat16",
        remat="dots", supports_long=False, train_microbatches=2)
