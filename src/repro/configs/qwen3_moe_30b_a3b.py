"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4, head_dim 128)
128 experts top-8, d_expert=768, vocab=151936 [hf:Qwen/Qwen3-30B-A3B]."""
from .base import LayerSpec, ModelConfig, MoESpec, register


@register("qwen3-moe-30b-a3b")
def make_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b", family="moe",
        d_model=2048, vocab_size=151936,
        num_heads=32, num_kv_heads=4, head_dim=128,
        d_ff=768,
        qk_norm=True,
        unit=(LayerSpec(kind="attn", moe=True),), n_units=48,
        moe=MoESpec(num_experts=128, top_k=8, d_expert=768),
        rope_theta=1_000_000.0,
        param_dtype="bfloat16", compute_dtype="bfloat16",
        remat="dots", supports_long=False, train_microbatches=4)
