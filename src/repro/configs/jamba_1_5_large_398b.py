"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2, Mamba:attn 7:1 [arXiv:2403.19887].

Layer program: repeating 8-layer unit -- attention at position 4, Mamba
elsewhere; MoE FFN on odd positions, dense FFN on even (MoE every 2nd
layer).  72 = 9 units x 8.  Runs long_500k (hybrid: only 9/72 layers
keep a KV cache).
"""
from .base import LayerSpec, ModelConfig, MoESpec, SSMSpec, register


@register("jamba-1.5-large-398b")
def make_config() -> ModelConfig:
    unit = tuple(
        LayerSpec(kind=("attn" if j == 4 else "ssm"), moe=(j % 2 == 1))
        for j in range(8))
    return ModelConfig(
        name="jamba-1.5-large-398b", family="hybrid",
        d_model=8192, vocab_size=65536,
        num_heads=64, num_kv_heads=8, head_dim=128,
        d_ff=24576,
        unit=unit, n_units=9,
        moe=MoESpec(num_experts=16, top_k=2, d_expert=24576),
        ssm=SSMSpec(num_heads=256, head_dim=64, state_dim=64, n_groups=8,
                    conv_width=4, chunk_len=256),
        use_rope=False,  # jamba uses no positional encoding in attn layers
        param_dtype="bfloat16", compute_dtype="bfloat16",
        remat="full", supports_long=True, train_microbatches=4)
