"""Model configuration schema + arch registry.

A config fully describes an architecture as a *layer program*:

* ``prefix``  -- explicitly-parameterized leading layers (unrolled), e.g.
  deepseek-moe's dense first layer;
* ``unit``    -- the repeating block pattern (scan unit), e.g. jamba's
  8-layer [7x mamba + 1x attn, MoE on odd positions] unit;
* ``n_units`` -- scan length; total layers = len(prefix) + n_units*len(unit);
* ``window_pattern`` -- per-scanned-layer attention window (0 = full), e.g.
  gemma3's 5 local : 1 global interleave, kept *traced* so the scan stays
  homogeneous.

``reduced()`` produces the CPU smoke-test configuration of the same family.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

FULL_WINDOW = 0  # sentinel: full (unwindowed) attention


@dataclasses.dataclass(frozen=True)
class MoESpec:
    num_experts: int
    top_k: int
    d_expert: int
    num_shared: int = 0
    d_shared: Optional[int] = None
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    num_heads: int
    head_dim: int
    state_dim: int
    n_groups: int = 1
    conv_width: int = 4
    chunk_len: int = 256


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str = "attn"      # "attn" | "ssm"
    moe: bool = False       # FFN is a MoE
    cross: bool = False     # followed by a cross-attention sub-layer
    mlp: bool = True        # has an FFN at all (mamba2 blocks do not)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str             # dense | moe | ssm | hybrid | encdec | vlm
    d_model: int
    vocab_size: int
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    norm: str = "rms"
    act: str = "silu"
    gated_mlp: bool = True
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    use_rope: bool = True
    tie_embeddings: bool = False
    scale_embeddings: bool = False
    # layer program
    prefix: Tuple[LayerSpec, ...] = ()
    unit: Tuple[LayerSpec, ...] = (LayerSpec(),)
    n_units: int = 0
    window_pattern: Tuple[int, ...] = ()   # per scanned layer; () = all full
    prefix_d_ff: int = 0                   # d_ff override for prefix layers
    # specs
    moe: Optional[MoESpec] = None
    ssm: Optional[SSMSpec] = None
    # encoder-decoder (whisper): encoder is a homogeneous attn stack
    encoder_layers: int = 0
    default_encoder_len: int = 1500
    # vlm
    num_vision_tokens: int = 0
    # numerics / execution
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: str = "none"                    # none | full | dots
    use_flash: bool = False
    # shape support
    supports_long: bool = False            # sub-quadratic -> run long_500k
    # microbatching for train_4k (grad accumulation inside train_step)
    train_microbatches: int = 1
    # execution: unroll the unit scan (used by roofline cost probes --
    # XLA's cost_analysis counts while-loop bodies ONCE, so per-unit costs
    # are measured on unrolled 1/2-unit probes and extrapolated affinely)
    unroll_units: bool = False

    # ---- derived -----------------------------------------------------------
    @property
    def num_layers(self) -> int:
        return len(self.prefix) + self.n_units * len(self.unit)

    @property
    def unit_size(self) -> int:
        return len(self.unit)

    def windows(self) -> Tuple[int, ...]:
        """Per-scanned-layer window sizes (0 = full)."""
        n = self.n_units * self.unit_size
        if not self.window_pattern:
            return tuple([FULL_WINDOW] * n)
        assert len(self.window_pattern) == n, \
            f"{self.name}: window_pattern len {len(self.window_pattern)} != {n}"
        return self.window_pattern

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def probe(self, n_units: int) -> "ModelConfig":
        """Cost-probe variant: full layer dims, ``n_units`` unrolled units,
        single microbatch.  See launch/dryrun.py roofline methodology."""
        wp = self.window_pattern
        if wp:
            wp = tuple(wp[: n_units * self.unit_size])
        return self.with_(n_units=n_units, window_pattern=wp,
                          unroll_units=True, train_microbatches=1,
                          encoder_layers=min(self.encoder_layers, n_units),
                          remat=self.remat)

    def reduced(self) -> "ModelConfig":
        """Small same-family config for CPU smoke tests."""
        scale_heads = max(self.num_heads // 4, 2) if self.num_heads else 0
        scale_kv = max(self.num_kv_heads // 4, 1) if self.num_kv_heads else 0
        if self.num_heads and scale_heads % scale_kv:
            scale_heads = scale_kv * max(scale_heads // scale_kv, 1)
        n_units = min(self.n_units, 2)
        wp = self.window_pattern
        if wp:
            wp = tuple(min(w, 64) if w else 0
                       for w in wp[: n_units * self.unit_size])
        moe = self.moe
        if moe:
            moe = dataclasses.replace(
                moe, num_experts=min(moe.num_experts, 8),
                top_k=min(moe.top_k, 2), d_expert=64,
                d_shared=64 if moe.num_shared else None)
        ssm = self.ssm
        if ssm:
            ssm = dataclasses.replace(ssm, num_heads=4, head_dim=16,
                                      state_dim=16, n_groups=min(ssm.n_groups, 2),
                                      chunk_len=32)
        return self.with_(
            d_model=128, vocab_size=512,
            num_heads=scale_heads, num_kv_heads=scale_kv,
            head_dim=32 if self.head_dim else 0,
            d_ff=256 if self.d_ff else 0, prefix_d_ff=256 if self.prefix_d_ff else 0,
            n_units=n_units, window_pattern=wp, moe=moe, ssm=ssm,
            encoder_layers=min(self.encoder_layers, 2),
            default_encoder_len=64,
            num_vision_tokens=min(self.num_vision_tokens, 16) or 0,
            param_dtype="float32", compute_dtype="float32",
            remat="none", train_microbatches=1)


# ----------------------------- registry -------------------------------------

_REGISTRY: Dict[str, object] = {}


def register(arch_id: str):
    def deco(fn):
        _REGISTRY[arch_id] = fn
        return fn
    return deco


def get_config(arch_id: str, **overrides) -> ModelConfig:
    from . import _load_all  # noqa: F401  (populate registry)
    _load_all()
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(_REGISTRY)}")
    cfg = _REGISTRY[arch_id]()
    return cfg.with_(**overrides) if overrides else cfg


def list_archs():
    from . import _load_all
    _load_all()
    return sorted(_REGISTRY)
