"""llama-3.2-vision-11b [vlm]: 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256, gated cross-attention image layers every 5th layer
[hf:meta-llama/Llama-3.2-11B-Vision].

The vision tower is a STUB: ``input_specs()`` supplies pre-projected patch
embeddings [B, n_vision, d_model].  Cross layers sit at position 3 of each
5-layer unit (real model: layers 3, 8, 13, ..., 38).
"""
from .base import LayerSpec, ModelConfig, register


@register("llama-3.2-vision-11b")
def make_config() -> ModelConfig:
    unit = tuple(LayerSpec(kind="attn", cross=(j == 3)) for j in range(5))
    return ModelConfig(
        name="llama-3.2-vision-11b", family="vlm",
        d_model=4096, vocab_size=128256,
        num_heads=32, num_kv_heads=8, head_dim=128,
        d_ff=14336,
        unit=unit, n_units=8,
        num_vision_tokens=1600,
        rope_theta=500_000.0,
        param_dtype="bfloat16", compute_dtype="bfloat16",
        remat="full", supports_long=False, train_microbatches=4)
