"""stablelm-1.6b [dense]: 24L d_model=2048 32H (MHA kv=32) d_ff=5632
vocab=100352 [hf:stabilityai/stablelm-2-1_6b]."""
from .base import LayerSpec, ModelConfig, register


@register("stablelm-1.6b")
def make_config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b", family="dense",
        d_model=2048, vocab_size=100352,
        num_heads=32, num_kv_heads=32, head_dim=64,
        d_ff=5632,
        unit=(LayerSpec(kind="attn"),), n_units=24,
        param_dtype="bfloat16", compute_dtype="bfloat16",
        remat="dots", supports_long=False, train_microbatches=4)
