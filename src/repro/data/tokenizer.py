"""Byte-pair-free toy tokenizer: hashed word-piece over bytes.

Deterministic, vocabulary-bounded, reversible enough for pipeline tests --
the framework treats tokenization as a pluggable stage; production would
swap in SentencePiece without touching the pipeline.
"""
from __future__ import annotations

from typing import Iterable, List

import numpy as np

BOS = 1
EOS = 2
PAD = 0
_RESERVED = 4


class HashTokenizer:
    def __init__(self, vocab_size: int = 4096):
        self.vocab_size = vocab_size

    def encode(self, text: str) -> np.ndarray:
        toks = [BOS]
        for w in text.split():
            h = 0
            for ch in w.encode("utf-8"):
                h = (h * 131 + ch) % (self.vocab_size - _RESERVED)
            toks.append(_RESERVED + h)
        toks.append(EOS)
        return np.asarray(toks, np.int32)

    def encode_batch(self, texts: Iterable[str]) -> List[np.ndarray]:
        return [self.encode(t) for t in texts]
