"""Data pipeline: GraphAr lake -> packed token batches.

This is where the paper's two hot operations become the *inner loop of
pre-training ingestion*:

  1. **label filtering** selects the training subset (e.g.
     ``HighQuality & !Spam``) via the O(|P|) interval path;
  2. **neighbor retrieval** expands each selected document with its linked
     context (citations / replies) through the <offset>+delta CSR layout
     with PAC-bitmap property pushdown;
  3. documents + context are packed into fixed-length sequences with EOS
     separators (standard LM packing), sharded per data-parallel host.

The pipeline is deterministic given (seed, step) -- restartable from a
checkpointed cursor, which is what the FT layer relies on.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional

import numpy as np

from repro.core import BY_SRC, Graph, IOMeter
from repro.core.labels import Cond, filter_rle_interval, intervals_to_ids
from repro.data.tokenizer import EOS


@dataclasses.dataclass
class PipelineConfig:
    seq_len: int = 512
    batch_size: int = 8
    context_hops: int = 1
    max_context_docs: int = 4
    shard_id: int = 0
    num_shards: int = 1
    seed: int = 0


class GraphCorpusPipeline:
    """Streams packed LM batches from a GraphAr document graph."""

    def __init__(self, graph: Graph, cond: Optional[Cond],
                 cfg: PipelineConfig, doc_type: str = "doc",
                 edge_name: str = "doc-links-doc",
                 tokens_prop: str = "tokens"):
        self.graph = graph
        self.cfg = cfg
        self.meter = IOMeter()
        self.vt = graph.vertex(doc_type)
        self.adj = graph.adjacency(edge_name, BY_SRC)
        self.tokens_col = self.vt.table[tokens_prop]
        # label filtering -> eligible doc ids (interval fast path)
        if cond is not None:
            iv = filter_rle_interval(self.vt, cond, self.meter)
            self.eligible = intervals_to_ids(iv)
        else:
            self.eligible = np.arange(self.vt.num_vertices, dtype=np.int64)
        # shard the eligible set across data-parallel hosts
        self.eligible = self.eligible[cfg.shard_id::cfg.num_shards]
        if len(self.eligible) == 0:
            raise ValueError("no eligible documents after filtering")

    def _doc_with_context(self, doc: int, rng) -> List[np.ndarray]:
        chunks = [self.tokens_col.read_rows(np.array([doc]), self.meter)[0]]
        ctx = self.adj.neighbor_ids(int(doc), self.meter)
        if len(ctx):
            take = min(self.cfg.max_context_docs, len(ctx))
            sel = rng.choice(ctx, size=take, replace=False)
            chunks.extend(
                self.tokens_col.read_rows(np.sort(sel), self.meter))
        return chunks

    def batches(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        """Infinite deterministic stream; resumable via ``start_step``."""
        cfg = self.cfg
        step = start_step
        need = cfg.seq_len + 1
        while True:
            rng = np.random.default_rng(
                (cfg.seed * 1_000_003 + step) % (2 ** 63))
            buf: List[int] = []
            out = np.zeros((cfg.batch_size, need), np.int32)
            row = 0
            while row < cfg.batch_size:
                doc = int(rng.choice(self.eligible))
                for chunk in self._doc_with_context(doc, rng):
                    buf.extend(chunk.tolist())
                    buf.append(EOS)
                while len(buf) >= need and row < cfg.batch_size:
                    out[row] = buf[:need]
                    buf = buf[need:]
                    row += 1
            yield {"tokens": out[:, :-1], "labels": out[:, 1:],
                   "step": step}
            step += 1

    def io_stats(self) -> IOMeter:
        return self.meter
