"""Synthetic graph generators.

The paper evaluates on web graphs / social networks (Table 1) whose two key
statistical properties drive GraphAr's wins:

* **sparsity + locality** (§4.2, citing Gemini / Facebook-Graph): a vertex's
  neighbors cluster within ID ranges, so deltas of sorted adjacency are
  small -> few bits per delta;
* **label clustering** (§5.1): vertices with equal labels appear in runs,
  so RLE interval lists are short (``|P| << n``).

``powerlaw_graph`` produces a degree-skewed graph with tunable locality;
``ldbc_like`` produces an LDBC-SNB-flavoured property graph (persons,
messages, tags with tagclass labels) used by the end-to-end benchmarks;
``document_graph`` produces a corpus-with-links lake used by the LM data
pipeline.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np


def powerlaw_graph(num_vertices: int, avg_degree: float,
                   locality: float = 0.9, alpha: float = 2.1,
                   seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Edge list (src, dst) with Zipf-ish out-degrees and ID locality.

    ``locality`` is the fraction of edges whose endpoint is drawn from a
    narrow window around the source ID (log-normal offsets), matching the
    clustering the paper exploits; the rest are uniform (long-range links).
    """
    rng = np.random.default_rng(seed)
    num_edges = int(num_vertices * avg_degree)
    # power-law out-degree: sample sources via Zipf ranks
    ranks = rng.zipf(alpha, size=num_edges).astype(np.int64)
    src = (ranks * 9973 + rng.integers(0, num_vertices, num_edges)) \
        % num_vertices
    local = rng.random(num_edges) < locality
    offs = np.maximum(rng.lognormal(3.0, 1.5, num_edges).astype(np.int64), 1)
    sign = rng.choice([-1, 1], num_edges)
    dst_local = (src + sign * offs) % num_vertices
    dst_rand = rng.integers(0, num_vertices, num_edges)
    dst = np.where(local, dst_local, dst_rand)
    keep = src != dst
    return src[keep].astype(np.int64), dst[keep].astype(np.int64)


def clustered_labels(num_vertices: int, names: List[str],
                     density: float = 0.3, run_scale: int = 4096,
                     seed: int = 0) -> Dict[str, np.ndarray]:
    """Boolean label columns arranged in runs (short RLE interval lists)."""
    rng = np.random.default_rng(seed)
    out: Dict[str, np.ndarray] = {}
    for k, name in enumerate(names):
        col = np.zeros(num_vertices, bool)
        pos = 0
        r = np.random.default_rng(seed * 1000003 + k)
        while pos < num_vertices:
            run = max(int(r.exponential(run_scale)), 32)
            val = r.random() < density
            col[pos:pos + run] = val
            pos += run
        out[name] = col
    return out


def scattered_labels(num_vertices: int, names: List[str],
                     density: float = 0.3, seed: int = 0
                     ) -> Dict[str, np.ndarray]:
    """Adversarial (unclustered) labels -- worst case for RLE (Fig. 14)."""
    rng = np.random.default_rng(seed)
    return {n: rng.random(num_vertices) < density for n in names}


# --------------------------------------------------------------------------
# LDBC-SNB-like social graph (paper §6.5)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class SnbGraph:
    """Raw arrays of a scaled-down LDBC-SNB-like interactive dataset."""

    num_persons: int
    num_messages: int
    num_tags: int
    num_tagclasses: int
    # edges
    knows_src: np.ndarray
    knows_dst: np.ndarray
    knows_creation: np.ndarray       # creationDate per knows edge
    has_creator_msg: np.ndarray      # message -> person
    has_creator_person: np.ndarray
    reply_of_src: np.ndarray         # message -> message (reply -> parent)
    reply_of_dst: np.ndarray
    has_tag_msg: np.ndarray          # message -> tag
    has_tag_tag: np.ndarray
    # vertex properties
    person_first_name: List[str]
    person_birthday: np.ndarray
    message_creation: np.ndarray
    message_length: np.ndarray
    tag_class_of_tag: np.ndarray     # tag -> tagclass id
    tagclass_names: List[str]
    # labels (tagclass labels attached to messages, paper §6.5)
    message_labels: Dict[str, np.ndarray]
    person_labels: Dict[str, np.ndarray]


def ldbc_like(scale: int = 1, seed: int = 0) -> SnbGraph:
    """Scale 1 ~ 10k persons / 80k messages; grows linearly with ``scale``."""
    rng = np.random.default_rng(seed)
    n_person = 10_000 * scale
    n_msg = 80_000 * scale
    n_tagclass = 8
    n_tag = 64

    # person-knows-person: power-law + community locality
    ks, kd = powerlaw_graph(n_person, avg_degree=12, locality=0.85,
                            seed=seed + 1)
    # dedup self/duplicate edges cheaply
    key = ks * n_person + kd
    _, idx = np.unique(key, return_index=True)
    ks, kd = ks[idx], kd[idx]
    k_creation = rng.integers(2010_00_00, 2023_00_00, len(ks)).astype(np.int64)

    # messages: creator follows a power law over persons; creation dates
    # clustered per creator so message ids correlate with persons.
    creator = np.sort(
        (rng.zipf(1.9, n_msg) * 7919 + rng.integers(0, n_person, n_msg))
        % n_person).astype(np.int64)
    msg_creation = (2019_00_00
                    + np.cumsum(rng.integers(0, 3, n_msg))
                    % 5_00_00).astype(np.int64)
    msg_length = rng.integers(5, 2000, n_msg).astype(np.int64)

    # replyOf: a reply points to an earlier message (~60% of messages)
    is_reply = rng.random(n_msg) < 0.6
    reply_src = np.flatnonzero(is_reply & (np.arange(n_msg) > 10))
    reply_dst = (reply_src
                 - np.maximum(rng.lognormal(2.0, 1.2, len(reply_src))
                              .astype(np.int64), 1))
    ok = reply_dst >= 0
    reply_src, reply_dst = reply_src[ok], reply_dst[ok]

    # hasTag: 1-3 tags per message; tag choice is *topically clustered* --
    # consecutive messages (threads) share tags, the locality GraphAr's RLE
    # label columns exploit (paper §5.1: |P| << n in real graphs).
    tags_per = rng.integers(1, 4, n_msg)
    ht_msg = np.repeat(np.arange(n_msg, dtype=np.int64), tags_per)
    topic_block = (ht_msg // 512) * 13  # slowly-varying topic per thread blk
    ht_tag = ((topic_block + (rng.zipf(1.6, len(ht_msg)) - 1))
              % n_tag).astype(np.int64)

    tag_class = rng.integers(0, n_tagclass, n_tag).astype(np.int64)
    tagclass_names = [f"TagClass{c}" for c in range(n_tagclass)]

    # message labels: tagclass c attached iff any of the message's tags is
    # in class c (this is the 'static type info as labels' trick of §6.5).
    message_labels: Dict[str, np.ndarray] = {}
    msg_tagclass = np.zeros((n_msg, n_tagclass), bool)
    msg_tagclass[ht_msg, tag_class[ht_tag]] = True
    for c, nm in enumerate(tagclass_names):
        message_labels[nm] = msg_tagclass[:, c]

    person_labels = clustered_labels(
        n_person, ["Asian", "Enrollee", "Student"],
        density=0.35, run_scale=512, seed=seed + 7)

    first_names = [f"p{i % 997}" for i in range(n_person)]
    birthday = rng.integers(1950_00_00, 2005_00_00, n_person).astype(np.int64)

    return SnbGraph(
        num_persons=n_person, num_messages=n_msg, num_tags=n_tag,
        num_tagclasses=n_tagclass,
        knows_src=ks, knows_dst=kd, knows_creation=k_creation,
        has_creator_msg=np.arange(n_msg, dtype=np.int64),
        has_creator_person=creator,
        reply_of_src=reply_src, reply_of_dst=reply_dst,
        has_tag_msg=ht_msg, has_tag_tag=ht_tag,
        person_first_name=first_names, person_birthday=birthday,
        message_creation=msg_creation, message_length=msg_length,
        tag_class_of_tag=tag_class, tagclass_names=tagclass_names,
        message_labels=message_labels, person_labels=person_labels)


# --------------------------------------------------------------------------
# document-link lake for LM pre-training (data pipeline substrate)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class DocumentLake:
    num_docs: int
    tokens: List[np.ndarray]            # ragged token arrays per doc
    links_src: np.ndarray               # citation/link graph
    links_dst: np.ndarray
    labels: Dict[str, np.ndarray]       # quality / topic / source labels
    quality: np.ndarray                 # float score property


def document_graph(num_docs: int = 5000, vocab: int = 4096,
                   mean_len: int = 256, seed: int = 0) -> DocumentLake:
    rng = np.random.default_rng(seed)
    lens = np.maximum(rng.poisson(mean_len, num_docs), 16)
    # Zipf token distribution (natural-language-like)
    tokens = [((rng.zipf(1.3, l) - 1) % vocab).astype(np.int32)
              for l in lens]
    src, dst = powerlaw_graph(num_docs, avg_degree=8, locality=0.8,
                              seed=seed + 3)
    labels = clustered_labels(
        num_docs, ["HighQuality", "Spam", "Code", "News", "Reference"],
        density=0.25, run_scale=256, seed=seed + 11)
    quality = rng.random(num_docs).astype(np.float32)
    return DocumentLake(num_docs, tokens, src, dst, labels, quality)
