"""Fault-tolerance coordinator: heartbeats, failure detection, restart.

Models the control plane of a multi-pod training job.  Worker processes
(simulated in-process here; separate hosts in production) report
heartbeats per step; the coordinator:

* declares a worker failed after ``heartbeat_timeout`` without progress,
* on failure, halts the step barrier, selects the restart plan
  (same-size restart from the latest *committed* checkpoint, or an
  elastic scale-down onto the surviving mesh via checkpoint/reshard.py),
* tracks stragglers: workers whose step latency exceeds
  ``straggler_factor`` x the cluster median get flagged; persistent
  stragglers trigger (simulated) hot-spare promotion -- the scheduling
  decision is real, the hardware swap is the cluster's job.

The same class drives the test harness (tests/test_ft.py) and the trainer
loop's failure hooks -- the trainer calls ``tick`` each step and obeys the
actions returned.

Liveness and strike bookkeeping live in :mod:`repro.ft.backoff`
(:class:`~repro.ft.backoff.HeartbeatTracker`,
:class:`~repro.ft.backoff.StrikeCounter`) -- shared with the mutable
graph plane's compaction runner, which retries via the same module's
:class:`~repro.ft.backoff.Backoff`.
"""
from __future__ import annotations

import dataclasses
import enum
import time
from typing import Dict, List, Optional

from .backoff import HeartbeatTracker, StrikeCounter


class WorkerState(enum.Enum):
    HEALTHY = "healthy"
    STRAGGLING = "straggling"
    FAILED = "failed"
    EVICTED = "evicted"


class Action(enum.Enum):
    CONTINUE = "continue"
    RESTART_FROM_CHECKPOINT = "restart"
    ELASTIC_SCALE_DOWN = "elastic_scale_down"
    PROMOTE_SPARE = "promote_spare"


@dataclasses.dataclass
class Worker:
    worker_id: int
    state: WorkerState = WorkerState.HEALTHY
    last_step: int = -1
    step_latencies: List[float] = dataclasses.field(default_factory=list)
    strikes: StrikeCounter = dataclasses.field(
        default_factory=lambda: StrikeCounter(3))

    @property
    def slow_strikes(self) -> int:
        return self.strikes.strikes


@dataclasses.dataclass
class Decision:
    action: Action
    failed_workers: List[int]
    stragglers: List[int]
    restore_step: Optional[int] = None
    surviving_workers: Optional[List[int]] = None


class Coordinator:
    def __init__(self, num_workers: int, heartbeat_timeout: float = 30.0,
                 straggler_factor: float = 2.0, strike_limit: int = 3,
                 spares: int = 1, clock=time.monotonic):
        self.heartbeat_timeout = heartbeat_timeout
        self.straggler_factor = straggler_factor
        self.strike_limit = strike_limit
        self.spares = spares
        self.clock = clock
        self.beats = HeartbeatTracker(heartbeat_timeout, clock)
        self.workers = {i: self._new_worker(i) for i in range(num_workers)}

    def _new_worker(self, wid: int) -> Worker:
        self.beats.register(wid)
        return Worker(wid, strikes=StrikeCounter(self.strike_limit))

    # ---- worker-side API ----------------------------------------------------
    def heartbeat(self, worker_id: int, step: int,
                  step_latency: Optional[float] = None) -> None:
        w = self.workers[worker_id]
        if w.state in (WorkerState.FAILED, WorkerState.EVICTED):
            return
        self.beats.beat(worker_id)
        w.last_step = max(w.last_step, step)
        if step_latency is not None:
            w.step_latencies.append(step_latency)
            if len(w.step_latencies) > 32:
                w.step_latencies = w.step_latencies[-32:]

    # ---- control plane ------------------------------------------------------
    def _median_latency(self) -> Optional[float]:
        lats = [w.step_latencies[-1] for w in self.workers.values()
                if w.step_latencies
                and w.state not in (WorkerState.FAILED, WorkerState.EVICTED)]
        if not lats:
            return None
        lats = sorted(lats)
        return lats[len(lats) // 2]

    def tick(self, latest_committed_step: Optional[int]) -> Decision:
        now = self.clock()
        failed, stragglers = [], []
        median = self._median_latency()
        for w in self.workers.values():
            if w.state in (WorkerState.FAILED, WorkerState.EVICTED):
                continue
            if self.beats.is_expired(w.worker_id, now):
                w.state = WorkerState.FAILED
                failed.append(w.worker_id)
                continue
            if median and w.step_latencies and \
                    w.step_latencies[-1] > self.straggler_factor * median:
                w.strikes.strike()
                w.state = WorkerState.STRAGGLING
                stragglers.append(w.worker_id)
            elif w.state == WorkerState.STRAGGLING:
                w.state = WorkerState.HEALTHY
                w.strikes.clear()

        # persistent stragglers: promote a spare (hot swap)
        for wid in list(stragglers):
            w = self.workers[wid]
            if w.strikes.tripped and self.spares > 0:
                self.spares -= 1
                w.state = WorkerState.EVICTED
                nid = max(self.workers) + 1
                self.workers[nid] = self._new_worker(nid)
                return Decision(Action.PROMOTE_SPARE, failed, stragglers,
                                restore_step=latest_committed_step)

        if failed:
            survivors = [w.worker_id for w in self.workers.values()
                         if w.state == WorkerState.HEALTHY
                         or w.state == WorkerState.STRAGGLING]
            if self.spares >= len(failed):
                self.spares -= len(failed)
                for _ in failed:
                    nid = max(self.workers) + 1
                    self.workers[nid] = self._new_worker(nid)
                return Decision(Action.RESTART_FROM_CHECKPOINT, failed,
                                stragglers,
                                restore_step=latest_committed_step)
            return Decision(Action.ELASTIC_SCALE_DOWN, failed, stragglers,
                            restore_step=latest_committed_step,
                            surviving_workers=survivors)
        return Decision(Action.CONTINUE, [], stragglers)

    def healthy_count(self) -> int:
        return sum(1 for w in self.workers.values()
                   if w.state in (WorkerState.HEALTHY,
                                  WorkerState.STRAGGLING))
