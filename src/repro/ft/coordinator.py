"""Fault-tolerance coordinator: heartbeats, failure detection, restart.

Models the control plane of a multi-pod training job.  Worker processes
(simulated in-process here; separate hosts in production) report
heartbeats per step; the coordinator:

* declares a worker failed after ``heartbeat_timeout`` without progress,
* on failure, halts the step barrier, selects the restart plan
  (same-size restart from the latest *committed* checkpoint, or an
  elastic scale-down onto the surviving mesh via checkpoint/reshard.py),
* tracks stragglers: workers whose step latency exceeds
  ``straggler_factor`` x the cluster median get flagged; persistent
  stragglers trigger (simulated) hot-spare promotion -- the scheduling
  decision is real, the hardware swap is the cluster's job.

The same class drives the test harness (tests/test_ft.py) and the trainer
loop's failure hooks -- the trainer calls ``tick`` each step and obeys the
actions returned.
"""
from __future__ import annotations

import dataclasses
import enum
import time
from typing import Dict, List, Optional


class WorkerState(enum.Enum):
    HEALTHY = "healthy"
    STRAGGLING = "straggling"
    FAILED = "failed"
    EVICTED = "evicted"


class Action(enum.Enum):
    CONTINUE = "continue"
    RESTART_FROM_CHECKPOINT = "restart"
    ELASTIC_SCALE_DOWN = "elastic_scale_down"
    PROMOTE_SPARE = "promote_spare"


@dataclasses.dataclass
class Worker:
    worker_id: int
    state: WorkerState = WorkerState.HEALTHY
    last_heartbeat: float = 0.0
    last_step: int = -1
    step_latencies: List[float] = dataclasses.field(default_factory=list)
    slow_strikes: int = 0


@dataclasses.dataclass
class Decision:
    action: Action
    failed_workers: List[int]
    stragglers: List[int]
    restore_step: Optional[int] = None
    surviving_workers: Optional[List[int]] = None


class Coordinator:
    def __init__(self, num_workers: int, heartbeat_timeout: float = 30.0,
                 straggler_factor: float = 2.0, strike_limit: int = 3,
                 spares: int = 1, clock=time.monotonic):
        self.workers = {i: Worker(i) for i in range(num_workers)}
        self.heartbeat_timeout = heartbeat_timeout
        self.straggler_factor = straggler_factor
        self.strike_limit = strike_limit
        self.spares = spares
        self.clock = clock
        now = clock()
        for w in self.workers.values():
            w.last_heartbeat = now

    # ---- worker-side API ----------------------------------------------------
    def heartbeat(self, worker_id: int, step: int,
                  step_latency: Optional[float] = None) -> None:
        w = self.workers[worker_id]
        if w.state in (WorkerState.FAILED, WorkerState.EVICTED):
            return
        w.last_heartbeat = self.clock()
        w.last_step = max(w.last_step, step)
        if step_latency is not None:
            w.step_latencies.append(step_latency)
            if len(w.step_latencies) > 32:
                w.step_latencies = w.step_latencies[-32:]

    # ---- control plane ------------------------------------------------------
    def _median_latency(self) -> Optional[float]:
        lats = [w.step_latencies[-1] for w in self.workers.values()
                if w.step_latencies
                and w.state not in (WorkerState.FAILED, WorkerState.EVICTED)]
        if not lats:
            return None
        lats = sorted(lats)
        return lats[len(lats) // 2]

    def tick(self, latest_committed_step: Optional[int]) -> Decision:
        now = self.clock()
        failed, stragglers = [], []
        median = self._median_latency()
        for w in self.workers.values():
            if w.state in (WorkerState.FAILED, WorkerState.EVICTED):
                continue
            if now - w.last_heartbeat > self.heartbeat_timeout:
                w.state = WorkerState.FAILED
                failed.append(w.worker_id)
                continue
            if median and w.step_latencies and \
                    w.step_latencies[-1] > self.straggler_factor * median:
                w.slow_strikes += 1
                w.state = WorkerState.STRAGGLING
                stragglers.append(w.worker_id)
            elif w.state == WorkerState.STRAGGLING:
                w.state = WorkerState.HEALTHY
                w.slow_strikes = 0

        # persistent stragglers: promote a spare (hot swap)
        for wid in list(stragglers):
            w = self.workers[wid]
            if w.slow_strikes >= self.strike_limit and self.spares > 0:
                self.spares -= 1
                w.state = WorkerState.EVICTED
                nid = max(self.workers) + 1
                self.workers[nid] = Worker(nid, last_heartbeat=now)
                return Decision(Action.PROMOTE_SPARE, failed, stragglers,
                                restore_step=latest_committed_step)

        if failed:
            survivors = [w.worker_id for w in self.workers.values()
                         if w.state == WorkerState.HEALTHY
                         or w.state == WorkerState.STRAGGLING]
            if self.spares >= len(failed):
                self.spares -= len(failed)
                now = self.clock()
                for _ in failed:
                    nid = max(self.workers) + 1
                    self.workers[nid] = Worker(nid, last_heartbeat=now)
                return Decision(Action.RESTART_FROM_CHECKPOINT, failed,
                                stragglers,
                                restore_step=latest_committed_step)
            return Decision(Action.ELASTIC_SCALE_DOWN, failed, stragglers,
                            restore_step=latest_committed_step,
                            surviving_workers=survivors)
        return Decision(Action.CONTINUE, [], stragglers)

    def healthy_count(self) -> int:
        return sum(1 for w in self.workers.values()
                   if w.state in (WorkerState.HEALTHY,
                                  WorkerState.STRAGGLING))
