"""Shared fault-tolerance primitives: backoff, retry, liveness, strikes.

Extracted from the training-plane coordinator so every component that
retries or tracks liveness -- the coordinator's worker bookkeeping, the
mutable-graph-plane compaction runner, future ingestion pipelines --
consumes one implementation instead of growing its own:

* :class:`Backoff` -- jittered exponential delay schedule, deterministic
  under a seed (fault-injection tests replay identical schedules);
* :func:`retry_call` -- call-with-retries around a ``Backoff``, with an
  injectable ``sleep`` so simulated components never block a test;
* :class:`HeartbeatTracker` -- last-beat bookkeeping + timeout expiry;
* :class:`StrikeCounter` -- N-strikes-and-out accumulator (straggler
  eviction, poisoned-mirror demotion, any "repeated offender" policy);
* :class:`TokenBucket` -- rate/burst admission bucket on an injectable
  clock (the serving plane's per-tenant backpressure; deterministic
  under the engine's tick counter, no wall-clock reads).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Iterator, Optional, Tuple

import numpy as np


class Backoff:
    """Jittered exponential backoff schedule.

    ``delay(attempt)`` returns ``min(base * factor**attempt, max_delay)``
    scaled by a uniform jitter in ``[1 - jitter, 1 + jitter]``.  Jitter
    draws come from a seeded generator, so a seeded schedule is exactly
    reproducible (the fault-injection tests assert on it) while still
    decorrelating real retry storms.
    """

    def __init__(self, base: float = 0.05, factor: float = 2.0,
                 max_delay: float = 2.0, jitter: float = 0.5,
                 seed: Optional[int] = None):
        if base < 0 or factor < 1.0 or not (0.0 <= jitter < 1.0):
            raise ValueError("want base >= 0, factor >= 1, 0 <= jitter < 1")
        self.base = base
        self.factor = factor
        self.max_delay = max_delay
        self.jitter = jitter
        self._rng = np.random.default_rng(seed)

    def delay(self, attempt: int) -> float:
        d = min(self.base * self.factor ** attempt, self.max_delay)
        if self.jitter:
            d *= 1.0 + self.jitter * (2.0 * self._rng.random() - 1.0)
        return d

    def delays(self) -> Iterator[float]:
        """Infinite generator of successive delays (attempt 0, 1, ...)."""
        attempt = 0
        while True:
            yield self.delay(attempt)
            attempt += 1


def retry_call(fn: Callable, retries: int = 5,
               backoff: Optional[Backoff] = None,
               sleep: Callable[[float], None] = time.sleep,
               retry_on: Tuple[type, ...] = (Exception,),
               on_retry: Optional[Callable] = None):
    """Call ``fn()``; on a retryable exception sleep the next backoff
    delay and try again, up to ``retries`` retries (``retries + 1``
    attempts total).  The final failure propagates.

    ``sleep`` is injectable so simulated components (tests, the in-process
    compaction runner) record delays instead of blocking; ``on_retry``
    (``attempt, delay, exc``) observes each retry decision.
    """
    bo = backoff if backoff is not None else Backoff()
    for attempt in range(retries + 1):
        try:
            return fn()
        except retry_on as e:
            if attempt == retries:
                raise
            d = bo.delay(attempt)
            if on_retry is not None:
                on_retry(attempt, d, e)
            sleep(d)


class HeartbeatTracker:
    """Last-beat bookkeeping and timeout detection for a set of members.

    The clock is injectable (the coordinator tests drive a fake clock);
    ``expired(now)`` names members whose last beat is older than
    ``timeout`` -- detection only, acting on it is the caller's policy.
    """

    def __init__(self, timeout: float, clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout
        self.clock = clock
        self._last: Dict[object, float] = {}

    def register(self, member, now: Optional[float] = None) -> None:
        self._last[member] = self.clock() if now is None else now

    def beat(self, member, now: Optional[float] = None) -> None:
        self._last[member] = self.clock() if now is None else now

    def last(self, member) -> float:
        return self._last[member]

    def drop(self, member) -> None:
        self._last.pop(member, None)

    def is_expired(self, member, now: Optional[float] = None) -> bool:
        now = self.clock() if now is None else now
        return now - self._last[member] > self.timeout

    def expired(self, now: Optional[float] = None) -> list:
        now = self.clock() if now is None else now
        return [m for m, t in self._last.items() if now - t > self.timeout]


class TokenBucket:
    """Rate/burst token bucket over an *explicit* clock.

    Every operation takes ``now`` (any monotone number -- the serving
    plane passes its tick counter), so a bucket's behavior is a pure
    function of the (config, operation sequence) pair: replaying the
    same submits at the same ticks yields the same admit/reject
    decisions and the same retry hints.  No wall-clock reads anywhere.

    ``try_take(now)`` refills ``rate * elapsed`` (capped at ``burst``)
    and either takes ``cost`` tokens or reports how long until the
    refill covers the deficit -- the caller's typed retry-after.
    """

    def __init__(self, rate: float, burst: float, now: float = 0.0):
        if rate < 0 or burst <= 0:
            raise ValueError("want rate >= 0 and burst > 0")
        self.rate = float(rate)
        self.burst = float(burst)
        self.level = float(burst)     # a fresh bucket is full
        self.last = float(now)

    def refill(self, now: float) -> None:
        if now > self.last:
            self.level = min(self.burst,
                             self.level + (now - self.last) * self.rate)
            self.last = now

    def try_take(self, now: float, cost: float = 1.0) -> Tuple[bool, float]:
        """``(True, 0.0)`` when ``cost`` tokens were taken; otherwise
        ``(False, wait)`` with ``wait`` = time until the refill covers
        the deficit (``inf`` for a zero-rate bucket)."""
        self.refill(now)
        if self.level + 1e-9 >= cost:
            self.level -= cost
            return True, 0.0
        deficit = cost - self.level
        wait = deficit / self.rate if self.rate > 0 else float("inf")
        return False, wait


class StrikeCounter:
    """N-strikes-and-out: ``strike()`` accumulates, ``clear()`` forgives,
    ``tripped`` reports whether the limit has been reached."""

    def __init__(self, limit: int):
        if limit < 1:
            raise ValueError("limit must be >= 1")
        self.limit = limit
        self.strikes = 0

    def strike(self) -> bool:
        self.strikes += 1
        return self.tripped

    def clear(self) -> None:
        self.strikes = 0

    @property
    def tripped(self) -> bool:
        return self.strikes >= self.limit

    def __repr__(self) -> str:
        return f"StrikeCounter({self.strikes}/{self.limit})"
