"""Deterministic fault-injection harness for the mutable graph plane.

Components on the write path (delta-segment ingest, the compaction
runner, durable storage writes) call :func:`check` at **named
boundaries**; an armed :class:`FaultPlan` raises :class:`InjectedFault`
there a configured number of times, simulating a crash at exactly that
point.  Because a plan is just per-boundary trip counts, a run under any
plan is deterministic and replayable -- the invariant tests assert that
serving results are bit-identical to a fault-free run for *every*
boundary.

Boundaries (the write path's crash points):

* ``ingest.append``      -- mid segment append, before the batch publishes
                            (an ingest batch is all-or-nothing);
* ``compact.merge``      -- while merging base + delta into the new layout;
* ``compact.pre_swap``   -- new generation built/persisted, swap not yet
                            committed (the manifest still names the old
                            generation);
* ``compact.post_swap``  -- swap committed, superseded files not yet
                            collected;
* ``compact.mid_gc``     -- between garbage-collection unlinks;
* ``store.write``        -- mid table write (the temp file is torn, the
                            destination untouched).

Serving-plane boundaries (PR 9) -- the engine's per-tick crash points,
checked by :class:`~repro.serve.engine.ServeEngine` when a plan is
attached.  The serving chaos invariant rides on them: under any
boundary x seed, every admitted request either finishes bit-identical
to an unthrottled sequential oracle or carries a typed failure status,
and the engine keeps ticking:

* ``serve.retrieval``    -- around the tick's batched context retrieval
                            (pre-dispatch and at commit; a commit-side
                            fault rewinds the retrieval plane's snapshot
                            before the retry so meter/LRU accounting
                            replays exactly once);
* ``serve.prefill``      -- around the grouped admission prefill (the
                            forward is pure, so a retry recomputes the
                            same logits/cache rows);
* ``serve.spec_commit``  -- at the speculative prefetch's commit point
                            (a fault restores the snapshot and degrades
                            that tick to the synchronous path -- the
                            speculation is optional work, never retried);
* ``serve.ingest``       -- before an ingest-during-serve batch is
                            forwarded to the mutable plane (the delta
                            plane's own ``ingest.append`` boundary keeps
                            the batch all-or-nothing under retry).

``REPRO_FAULT_SEED`` seeds :meth:`FaultPlan.from_env` -- the CI
fault-injection matrix runs the ingest/compaction suites under several
seeds, each deriving a different trip pattern over these boundaries;
the serving-chaos matrix does the same over ``SERVE_BOUNDARIES``.
"""
from __future__ import annotations

import os
from typing import Dict, List, Mapping, Optional, Sequence

import numpy as np

ENV_SEED = "REPRO_FAULT_SEED"

BOUNDARIES = (
    "ingest.append",
    "compact.merge",
    "compact.pre_swap",
    "compact.post_swap",
    "compact.mid_gc",
    "store.write",
)

#: serving-plane boundaries (PR 9): the engine's per-tick crash points.
SERVE_BOUNDARIES = (
    "serve.retrieval",
    "serve.prefill",
    "serve.spec_commit",
    "serve.ingest",
)

ALL_BOUNDARIES = BOUNDARIES + SERVE_BOUNDARIES


class InjectedFault(RuntimeError):
    """A simulated crash at a named boundary."""

    def __init__(self, boundary: str, hit: int):
        super().__init__(f"injected fault at {boundary!r} (hit {hit})")
        self.boundary = boundary
        self.hit = hit


class FaultPlan:
    """Per-boundary trip counts; ``check(b)`` raises while trips remain.

    A plan is consumed: each check at an armed boundary decrements its
    remaining trips, so retry loops make progress and every run
    terminates.  ``history`` records the order faults actually fired.
    """

    def __init__(self, trips: Optional[Mapping[str, int]] = None):
        self.trips: Dict[str, int] = {k: int(v) for k, v in
                                      (trips or {}).items() if int(v) > 0}
        self.fired: Dict[str, int] = {}
        self.history: List[str] = []

    @classmethod
    def from_seed(cls, seed: int, boundaries: Sequence[str] = BOUNDARIES,
                  max_trips: int = 2) -> "FaultPlan":
        """Deterministic plan: each boundary gets 0..max_trips trips."""
        rng = np.random.default_rng(seed)
        return cls({b: int(rng.integers(0, max_trips + 1))
                    for b in boundaries})

    @classmethod
    def from_env(cls, default_seed: Optional[int] = None,
                 **kw) -> "Optional[FaultPlan]":
        """Plan from ``REPRO_FAULT_SEED`` (or ``default_seed``); None when
        neither is set -- the unfaulted configuration."""
        raw = os.environ.get(ENV_SEED, "").strip()
        if raw:
            return cls.from_seed(int(raw), **kw)
        if default_seed is not None:
            return cls.from_seed(default_seed, **kw)
        return None

    def check(self, boundary: str) -> None:
        remaining = self.trips.get(boundary, 0)
        if remaining > 0:
            self.trips[boundary] = remaining - 1
            hit = self.fired.get(boundary, 0) + 1
            self.fired[boundary] = hit
            self.history.append(boundary)
            raise InjectedFault(boundary, hit)

    def total_fired(self) -> int:
        return sum(self.fired.values())

    def remaining(self) -> int:
        return sum(self.trips.values())

    def stats(self) -> Dict[str, object]:
        return {"fired": dict(self.fired), "remaining": self.remaining(),
                "history": list(self.history)}

    def __repr__(self) -> str:
        return f"FaultPlan(trips={self.trips}, fired={self.fired})"


def check(plan: "Optional[FaultPlan]", boundary: str) -> None:
    """None-safe boundary check (components hold ``faults=None`` by
    default -- production configuration, no injection overhead)."""
    if plan is not None:
        plan.check(boundary)
