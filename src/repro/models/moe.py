"""Mixture-of-Experts: top-k routing with capacity-based dispatch.

GShard/Switch-style static-shape dispatch, the TPU idiom: tokens are ranked
into per-expert slots of a fixed capacity, gathered into an ``[E, C, d]``
buffer, transformed by batched per-expert FFNs (one einsum -- EP-shardable
on the ``model``/expert axis), and combined back weighted by router probs.
Supports DeepSeek-style shared experts and a load-balance auxiliary loss.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import linear_init


def moe_init(rng, d_model: int, d_expert: int, num_experts: int,
             num_shared: int = 0, d_shared: Optional[int] = None,
             dtype=jnp.float32) -> Dict:
    keys = jax.random.split(rng, 5)
    scale_in = 1.0 / (d_model ** 0.5)
    scale_out = 1.0 / (d_expert ** 0.5)

    def expert_bank(key, d_in, d_out, scale):
        return (jax.random.normal(key, (num_experts, d_in, d_out),
                                  jnp.float32) * scale).astype(dtype)

    p = {
        "router": linear_init(keys[0], d_model, num_experts, dtype,
                              scale=0.02),
        "w_gate": expert_bank(keys[1], d_model, d_expert, scale_in),
        "w_up": expert_bank(keys[2], d_model, d_expert, scale_in),
        "w_down": expert_bank(keys[3], d_expert, d_model, scale_out),
    }
    if num_shared:
        d_sh = d_shared or d_expert * num_shared
        from .layers import mlp_init
        p["shared"] = mlp_init(keys[4], d_model, d_sh, gated=True,
                               dtype=dtype)
    return p


def moe_apply(params: Dict, x: jnp.ndarray, *, num_experts: int,
              top_k: int, capacity_factor: float = 1.25,
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, d] -> (y, aux_loss).

    Static shapes throughout: capacity C = ceil(T * top_k / E * factor).
    Tokens overflowing an expert's capacity are dropped (their weight is
    re-normalized over surviving assignments), standard for TPU MoE.
    """
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    logits = (xf @ params["router"]).astype(jnp.float32)     # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)      # [T, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    capacity = int(max(1, round(t * top_k / num_experts * capacity_factor)))

    # flatten assignments; rank tokens within their expert by priority.
    # Sort-based ranking: O(n log n) keys instead of the classic
    # cumsum-of-one-hot (O(T*E) elementwise work that GSPMD cannot shard
    # along the token axis -- measured 14x flop inflation on the 128-expert
    # config; EXPERIMENTS.md §Perf iter 2).
    flat_expert = expert_idx.reshape(-1)                     # [T*k]
    n_flat = flat_expert.shape[0]
    sort_idx = jnp.argsort(flat_expert, stable=True)
    sorted_e = flat_expert[sort_idx]
    starts = jnp.searchsorted(sorted_e,
                              jnp.arange(num_experts, dtype=sorted_e.dtype))
    slot_sorted = (jnp.arange(n_flat, dtype=jnp.int32)
                   - jnp.take(starts, sorted_e).astype(jnp.int32))
    slot = jnp.zeros((n_flat,), jnp.int32).at[sort_idx].set(slot_sorted)
    keep = slot < capacity

    token_of = jnp.repeat(jnp.arange(t), top_k)              # [T*k]
    w = gate_vals.reshape(-1) * keep                          # [T*k]

    # dispatch: GATHER-based (no [n,d] scatter).  A scatter of [T*k, d]
    # rows made GSPMD materialize u32[T*k, d] index maps and all-gather
    # them (2 x 8.6 GB/device on the 128-expert config; EXPERIMENTS.md
    # §Perf iter 3).  Instead: invert the slot permutation with a tiny
    # int32 scatter ([E*C] values), then build the buffer with a row
    # gather.  The buffer is explicitly sharded: experts over 'model'
    # (EP), capacity over the data axes; the cross-shard row gather is the
    # canonical MoE all-to-all.
    from repro.distributed.sharding import constrain
    addr = jnp.where(keep, flat_expert * capacity + slot,
                     num_experts * capacity)
    inv = jnp.full((num_experts * capacity,), n_flat, jnp.int32) \
        .at[addr].set(jnp.arange(n_flat, dtype=jnp.int32), mode="drop")
    valid_slot = inv < n_flat
    token_src = jnp.where(valid_slot, inv // top_k, 0)  # flat idx -> token
    buf = xf[token_src] * valid_slot[:, None].astype(xf.dtype)
    buf = constrain(buf.reshape(num_experts, capacity, d),
                    "model", "dp", None)

    # batched per-expert SwiGLU (one einsum per matrix; EP shards dim e)
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"]))
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    y = jnp.einsum("ecf,efd->ecd", g * u, params["w_down"])
    y = constrain(y, "model", "dp", None)
    y = y.reshape(num_experts * capacity, d)

    # combine: gather + token-major reshape + weighted sum over k --
    # no scatter at all (flat assignment i belongs to token i // top_k).
    gathered = y[jnp.where(keep, addr, 0)] * w[:, None].astype(x.dtype)
    out = gathered.reshape(t, top_k, d).sum(axis=1)

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    me = probs.mean(axis=0)                                   # router prob mass
    counts = jnp.zeros((num_experts,), jnp.float32).at[flat_expert].add(1.0)
    ce = counts / max(t * top_k, 1)
    aux = num_experts * jnp.sum(me * ce)

    if "shared" in params:
        from .layers import mlp
        out = out + mlp(params["shared"], xf)
    return out.reshape(b, s, d), aux
