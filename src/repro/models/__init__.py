"""Pure-JAX model zoo (decoder-only / enc-dec / VLM / SSM / MoE / hybrid)."""
from .model import LM, build_model, param_count
