"""Model assembly: decoder-only / encoder-decoder / VLM language models.

The layer program from :class:`repro.configs.base.ModelConfig` is executed
as: unrolled ``prefix`` layers, then ``jax.lax.scan`` over ``n_units``
repeating units (parameters and KV/SSM caches stacked on the scan axis,
optionally wrapped in ``jax.checkpoint`` for remat).  Scan keeps HLO size
and compile time O(unit) instead of O(layers) -- essential for the 88-layer
123B and 72-layer 398B dry-runs.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LayerSpec, ModelConfig
from .blocks import layer_apply, layer_cache_init, layer_init
from .layers import apply_norm, embed_init, norm_init, softmax_cross_entropy

Params = Dict
Cache = Dict


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


class LM:
    """Functional model wrapper: config -> init / apply / prefill / decode."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ init
    def init(self, seed: int = 0) -> Params:
        cfg = self.cfg
        dt = _dtype(cfg.param_dtype)
        root = jax.random.PRNGKey(seed)
        n_groups = 6 + len(cfg.prefix)
        keys = jax.random.split(root, n_groups)
        params: Params = {
            "embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dt),
            "final_norm": norm_init(cfg.norm, cfg.d_model, dt),
        }
        # The output head is always materialized as its own parameter --
        # "tied" configs initialize it from the embedding.  Decoupling is a
        # deliberate TP/sharding decision (DESIGN.md §6): the lookup wants
        # d_model sharded (gather stays collective-free) while the head
        # wants vocab sharded (logits come out vocab-parallel); one array
        # cannot satisfy both without involuntary replication.
        params["lm_head"] = (params["embed"].T if cfg.tie_embeddings
                             else embed_init(keys[1], cfg.vocab_size,
                                             cfg.d_model, dt).T)
        for i, spec in enumerate(cfg.prefix):
            params[f"prefix_{i}"] = layer_init(
                keys[6 + i], cfg, spec, d_ff_override=cfg.prefix_d_ff,
                dtype=dt)
        if cfg.n_units:
            unit_keys = jax.random.split(keys[2], cfg.n_units)

            def one_unit(k):
                lk = jax.random.split(k, cfg.unit_size)
                return {f"l{j}": layer_init(lk[j], cfg, spec, dtype=dt)
                        for j, spec in enumerate(cfg.unit)}

            params["units"] = jax.vmap(one_unit)(unit_keys)
        if cfg.encoder_layers:
            enc_keys = jax.random.split(keys[3], cfg.encoder_layers)
            enc_spec = LayerSpec(kind="attn")

            def one_enc(k):
                return {"l0": layer_init(k, cfg, enc_spec, dtype=dt)}

            params["enc_units"] = jax.vmap(one_enc)(enc_keys)
            params["enc_norm"] = norm_init(cfg.norm, cfg.d_model, dt)
        return params

    # -------------------------------------------------------------- decoder
    def _windows(self) -> jnp.ndarray:
        cfg = self.cfg
        return jnp.asarray(cfg.windows(), jnp.int32).reshape(
            cfg.n_units, cfg.unit_size)

    def _decoder(self, params: Params, x: jnp.ndarray,
                 positions: jnp.ndarray,
                 cross_ctx: Optional[jnp.ndarray],
                 caches: Optional[Cache],
                 causal: bool = True) -> Tuple[jnp.ndarray, Optional[Cache],
                                               jnp.ndarray]:
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        new_caches: Optional[Cache] = {} if caches is not None else None
        big = jnp.int32(0)
        for i, spec in enumerate(cfg.prefix):
            c = caches.get(f"prefix_{i}") if caches is not None else None
            x, nc, a = layer_apply(cfg, spec, params[f"prefix_{i}"], x,
                                   positions=positions, window=big,
                                   causal=causal, cross_ctx=cross_ctx,
                                   cache=c)
            aux = aux + a
            if caches is not None:
                new_caches[f"prefix_{i}"] = nc
        if cfg.n_units:
            windows = self._windows()
            has_cache = caches is not None

            def body(carry, xs):
                xc, auxc = carry
                if has_cache:
                    unit_params, win_u, cache_u = xs
                else:
                    unit_params, win_u = xs
                out_cache = {}
                for j, spec in enumerate(cfg.unit):
                    cj = cache_u[f"l{j}"] if has_cache else None
                    xc, c, a = layer_apply(cfg, spec, unit_params[f"l{j}"],
                                           xc, positions=positions,
                                           window=win_u[j], causal=causal,
                                           cross_ctx=cross_ctx, cache=cj)
                    auxc = auxc + a
                    if has_cache:
                        out_cache[f"l{j}"] = c
                return (xc, auxc), (out_cache if has_cache else None)

            if cfg.remat != "none" and not has_cache:
                policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                          if cfg.remat == "dots" else None)
                body = jax.checkpoint(body, policy=policy,
                                      prevent_cse=False)
            xs = ((params["units"], windows, caches["units"]) if has_cache
                  else (params["units"], windows))
            if cfg.unroll_units:
                carry = (x, aux)
                cache_outs = []
                for u in range(cfg.n_units):
                    xs_u = jax.tree.map(lambda a: a[u], xs)
                    carry, yc = body(carry, xs_u)
                    if has_cache:
                        cache_outs.append(yc)
                x, aux = carry
                unit_caches = (jax.tree.map(
                    lambda *a: jnp.stack(a), *cache_outs)
                    if has_cache else None)
            else:
                (x, aux), unit_caches = jax.lax.scan(body, (x, aux), xs)
            if has_cache:
                new_caches["units"] = unit_caches
        return x, new_caches, aux

    # -------------------------------------------------------------- encoder
    def _encoder(self, params: Params, frames: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        b, t, _ = frames.shape
        pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
        enc_spec = LayerSpec(kind="attn")

        def body(xc, unit_params):
            xc, _, _ = layer_apply(cfg, enc_spec, unit_params["l0"], xc,
                                   positions=pos, window=jnp.int32(0),
                                   causal=False, cache=None)
            return xc, None

        if cfg.unroll_units:
            x = frames
            for u in range(cfg.encoder_layers):
                x, _ = body(x, jax.tree.map(lambda a: a[u],
                                            params["enc_units"]))
        else:
            x, _ = jax.lax.scan(body, frames, params["enc_units"])
        return apply_norm(cfg.norm, params["enc_norm"], x)

    # ------------------------------------------------------------ embeddings
    def _embed(self, params: Params, tokens: jnp.ndarray) -> jnp.ndarray:
        from repro.distributed.sharding import constrain
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0)
        x = x.astype(_dtype(cfg.compute_dtype))
        if cfg.scale_embeddings:
            x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
        return constrain(x, "dp", None, None)

    def _head(self, params: Params, x: jnp.ndarray) -> jnp.ndarray:
        from repro.distributed.sharding import constrain
        logits = x @ params["lm_head"].astype(x.dtype)
        return constrain(logits, "dp", None, "model")

    def _cross_context(self, params: Params,
                       batch: Dict) -> Optional[jnp.ndarray]:
        cfg = self.cfg
        if cfg.encoder_layers:
            frames = batch["frames"].astype(_dtype(cfg.compute_dtype))
            return self._encoder(params, frames)
        if cfg.num_vision_tokens:
            return batch["vision"].astype(_dtype(cfg.compute_dtype))
        return None

    # ----------------------------------------------------------------- apply
    def apply(self, params: Params, batch: Dict) -> Tuple[jnp.ndarray,
                                                          jnp.ndarray]:
        """Full forward (training): returns (logits, aux_loss)."""
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = batch.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32),
                                         (b, s))
        x = self._embed(params, tokens)
        ctx = self._cross_context(params, batch)
        x, _, aux = self._decoder(params, x, positions, ctx, None)
        x = apply_norm(self.cfg.norm, params["final_norm"], x)
        return self._head(params, x), aux

    def loss(self, params: Params, batch: Dict
             ) -> Tuple[jnp.ndarray, Dict]:
        logits, aux = self.apply(params, batch)
        ce, ntok = softmax_cross_entropy(logits, batch["labels"],
                                         batch.get("mask"))
        total = ce + 0.01 * aux
        return total, {"ce": ce, "aux": aux, "tokens": ntok}

    # ----------------------------------------------------------------- cache
    def init_cache(self, batch_size: int, max_len: int,
                   ctx_len: int = 0, dtype=jnp.bfloat16,
                   vector_index: bool = False) -> Cache:
        """``vector_index=True`` gives per-slot positions (continuous
        batching); the default scalar index keeps all slots aligned."""
        cfg = self.cfg
        caches: Cache = {"index": (jnp.zeros((batch_size,), jnp.int32)
                                   if vector_index
                                   else jnp.zeros((), jnp.int32))}

        def one(spec: LayerSpec) -> Dict:
            c = layer_cache_init(cfg, spec, batch_size, max_len, dtype,
                                 vector_index)
            if spec.cross:
                c["cross"] = {
                    "k": jnp.zeros((batch_size, ctx_len, cfg.num_kv_heads,
                                    cfg.head_dim), dtype),
                    "v": jnp.zeros((batch_size, ctx_len, cfg.num_kv_heads,
                                    cfg.head_dim), dtype),
                }
            return c

        for i, spec in enumerate(cfg.prefix):
            caches[f"prefix_{i}"] = one(spec)
        if cfg.n_units:
            unit_cache = {f"l{j}": one(spec)
                          for j, spec in enumerate(cfg.unit)}
            caches["units"] = jax.tree.map(
                lambda a: jnp.zeros((cfg.n_units,) + a.shape, a.dtype),
                unit_cache)
        return caches

    def prefill(self, params: Params, batch: Dict, cache: Cache
                ) -> Tuple[jnp.ndarray, Cache]:
        """Run the prompt through the model, filling the cache.

        Returns (logits_last, cache)."""
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        x = self._embed(params, tokens)
        ctx = self._cross_context(params, batch)
        x, new_cache, _ = self._decoder(params, x, positions, ctx, cache)
        new_cache["index"] = cache["index"] + s
        x = apply_norm(self.cfg.norm, params["final_norm"], x)
        return self._head(params, x[:, -1:]), new_cache

    def decode_step(self, params: Params, tokens: jnp.ndarray, cache: Cache
                    ) -> Tuple[jnp.ndarray, Cache]:
        """One decode step.  tokens: [B, 1]."""
        b = tokens.shape[0]
        idx = cache["index"]
        positions = (idx[:, None] if idx.ndim == 1
                     else jnp.broadcast_to(idx, (b, 1))).astype(jnp.int32)
        x = self._embed(params, tokens)
        x, new_cache, _ = self._decoder(params, x, positions, None, cache)
        new_cache["index"] = cache["index"] + 1
        x = apply_norm(self.cfg.norm, params["final_norm"], x)
        return self._head(params, x), new_cache


def build_model(cfg: ModelConfig) -> LM:
    return LM(cfg)


def param_count(params: Params) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
