"""Primitive layers: norms, projections, rotary embeddings, MLPs.

Pure-functional: every layer is ``init(rng, ...) -> params`` plus an
``apply(params, x, ...)`` free function operating on jnp arrays.  Parameter
trees are plain nested dicts so they stack cleanly along a scan axis and
shard with simple path-based rules (repro.distributed.sharding).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def linear_init(rng, d_in: int, d_out: int, dtype=jnp.float32,
                scale: Optional[float] = None) -> jnp.ndarray:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(rng, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def embed_init(rng, vocab: int, d: int, dtype=jnp.float32) -> jnp.ndarray:
    return (jax.random.normal(rng, (vocab, d), jnp.float32) * 0.02
            ).astype(dtype)


# ------------------------------- norms -----------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
    return out.astype(dt)


def layernorm_init(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * params["scale"].astype(jnp.float32) \
        + params["bias"].astype(jnp.float32)
    return out.astype(dt)


def norm_init(kind: str, d: int, dtype=jnp.float32) -> dict:
    return rmsnorm_init(d, dtype) if kind == "rms" else layernorm_init(d, dtype)


def apply_norm(kind: str, params: dict, x: jnp.ndarray) -> jnp.ndarray:
    return rmsnorm(params, x) if kind == "rms" else layernorm(params, x)


# ------------------------------- rotary -----------------------------------

def rope_frequencies(head_dim: int, theta: float = 10_000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10_000.0) -> jnp.ndarray:
    """x: [B, S, H, dh]; positions: [B, S] (int32)."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)                     # [dh/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B,S,dh/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------- MLP ------------------------------------

def mlp_init(rng, d_model: int, d_ff: int, gated: bool = True,
             act: str = "silu", dtype=jnp.float32) -> dict:
    r1, r2, r3 = jax.random.split(rng, 3)
    p = {"up": linear_init(r1, d_model, d_ff, dtype),
         "down": linear_init(r2, d_ff, d_model, dtype)}
    if gated:
        p["gate"] = linear_init(r3, d_model, d_ff, dtype)
    return p


def _act(x: jnp.ndarray, act: str) -> jnp.ndarray:
    if act == "silu":
        return jax.nn.silu(x)
    if act == "gelu":
        return jax.nn.gelu(x)
    if act == "relu":
        return jax.nn.relu(x)
    raise ValueError(act)


def mlp(params: dict, x: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    up = x @ params["up"]
    if "gate" in params:
        up = _act(x @ params["gate"], act) * up
    else:
        up = _act(up, act)
    return up @ params["down"]


def softmax_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                          mask: Optional[jnp.ndarray] = None
                          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Token-mean CE; logits [.., V] fp32 math.  Returns (loss, n_tokens).

    The gold logit is extracted with a one-hot masked reduction rather
    than ``take_along_axis``: a gather over the vocab dim forces GSPMD to
    replicate vocab-sharded logits, while iota-compare + reduce stays
    vocab-parallel (a psum of per-shard partial sums).
    """
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    onehot = vocab_iota == labels[..., None]
    gold = jnp.where(onehot, logits, 0.0).sum(axis=-1)
    nll = logz - gold
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    total = jnp.maximum(mask.sum(), 1.0)
    return (nll * mask).sum() / total, total
