"""Attention: GQA / sliding-window / cross, with KV-cache decode paths.

The einsum formulation keeps GSPMD free to shard heads over the ``model``
axis and sequence/batch over ``data``; the optional Pallas flash-attention
path (repro.kernels.flash_attention) is a config flag used by benchmarks.

Sliding windows are expressed with a *traced* window size so layers with
different windows (gemma3's 5:1 local:global) stay homogeneous under
scan-over-layers.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import apply_rope, linear_init, rmsnorm, rmsnorm_init

NEG_INF = -1e30


def attention_init(rng, d_model: int, num_heads: int, num_kv_heads: int,
                   head_dim: int, qk_norm: bool = False,
                   dtype=jnp.float32) -> Dict:
    rq, rk, rv, ro = jax.random.split(rng, 4)
    p = {
        "q": linear_init(rq, d_model, num_heads * head_dim, dtype),
        "k": linear_init(rk, d_model, num_kv_heads * head_dim, dtype),
        "v": linear_init(rv, d_model, num_kv_heads * head_dim, dtype),
        "o": linear_init(ro, num_heads * head_dim, d_model, dtype),
    }
    if qk_norm:
        p["q_norm"] = rmsnorm_init(head_dim, dtype)
        p["k_norm"] = rmsnorm_init(head_dim, dtype)
    return p


def _split_heads(x: jnp.ndarray, n: int, dh: int) -> jnp.ndarray:
    b, s, _ = x.shape
    return x.reshape(b, s, n, dh)


def _sdpa(q, k, v, q_pos, k_pos, window, causal: bool):
    """q: [B,S,H,dh]; k/v: [B,T,KV,dh]; positions int32 [B,S]/[B,T].

    ``window`` is a traced int32 scalar: key t attends iff
    ``0 <= q_pos - k_pos < window`` (causal) -- window >= seq means full.

    Sharding is chosen *adaptively against the ambient mesh*
    (EXPERIMENTS.md §Perf iters 4+6):
      * prefill/train, heads divide the model axis (96/64/32/16 heads on
        the 16-way mesh): classic head-parallel -- free, no resharding;
      * prefill/train, heads do NOT divide (8/12/15): sequence-parallel --
        queries shard S over 'model', K/V gathered (small), scores stay
        S-sharded.  (Blanket head_dim sharding here would make GSPMD
        all-reduce the full [B,H,S,T] score matrix: measured 128 GB on
        the 32k prefill.  Blanket sequence-parallel costs divisible-head
        archs 5x collective bytes: measured on the 123B config.)
      * decode (S == 1): flash-decode -- the KV length shards over
        'model', softmax/combine reduce over the sharded T with small
        psums.
    """
    from repro.distributed.sharding import _context_mesh, constrain
    b, s, h, dh = q.shape
    kv = k.shape[2]
    if kv != h:
        k = jnp.repeat(k, h // kv, axis=2)
        v = jnp.repeat(v, h // kv, axis=2)
    mesh = _context_mesh()
    heads_parallel = (mesh is not None and "model" in mesh.axis_names
                      and h % mesh.shape["model"] == 0)
    if s > 1:
        if heads_parallel:
            q = constrain(q, "dp", None, "model", None)
            k = constrain(k, "dp", None, "model", None)
            v = constrain(v, "dp", None, "model", None)
        else:
            q = constrain(q, "dp", "model", None, None)
            k = constrain(k, "dp", None, None, None)
            v = constrain(v, "dp", None, None, None)
    else:
        k = constrain(k, "dp", "model", None, None)
        v = constrain(v, "dp", "model", None, None)
    scale = 1.0 / (dh ** 0.5)
    logits = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if s > 1:
        logits = (constrain(logits, "dp", "model", None, None)
                  if heads_parallel
                  else constrain(logits, "dp", None, "model", None))
    else:
        logits = constrain(logits, "dp", None, None, "model")
    if causal:
        diff = q_pos[:, None, :, None] - k_pos[:, None, None, :]
        mask = (diff >= 0) & (diff < window)
        logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs.astype(v.dtype), v)
    return out.reshape(b, s, h * dh)


def attention_apply(params: Dict, x: jnp.ndarray, *,
                    num_heads: int, num_kv_heads: int, head_dim: int,
                    positions: jnp.ndarray,
                    window: jnp.ndarray,
                    rope_theta: float = 10_000.0,
                    causal: bool = True,
                    use_rope: bool = True,
                    kv_override: Optional[Tuple[jnp.ndarray, jnp.ndarray,
                                                jnp.ndarray]] = None,
                    cache: Optional[Dict] = None,
                    use_flash: bool = False) -> Tuple[jnp.ndarray,
                                                      Optional[Dict]]:
    """Self/cross attention with optional KV cache.

    * training / prefill: ``cache=None`` -> returns (out, None) or
      (out, fresh_cache) when ``cache`` is a dict with ``max_len``.
    * decode: ``cache={'k','v','index'}`` -> appends current kv, attends
      over the cache prefix, returns (out, updated_cache).
    * cross attention: ``kv_override=(k, v, k_pos)`` (already headed).
    """
    q = _split_heads(x @ params["q"], num_heads, head_dim)
    if kv_override is None:
        k = _split_heads(x @ params["k"], num_kv_heads, head_dim)
        v = _split_heads(x @ params["v"], num_kv_heads, head_dim)
        k_pos = positions
    else:
        k, v, k_pos = kv_override
    if "q_norm" in params:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k) if kv_override is None else k
    if use_rope:
        q = apply_rope(q, positions, rope_theta)
        if kv_override is None:
            k = apply_rope(k, k_pos, rope_theta)

    new_cache = None
    if cache is not None and kv_override is None:
        idx = cache["index"]          # int32 scalar OR per-slot vector [B]
        s = x.shape[1]
        if idx.ndim == 0:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, idx, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, idx, 0, 0))
        else:
            # per-slot write positions (continuous-batching engine)
            b = x.shape[0]
            rows = jnp.arange(b, dtype=jnp.int32)[:, None]
            cols = idx[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
            ck = cache["k"].at[rows, cols].set(k.astype(cache["k"].dtype),
                                               mode="drop")
            cv = cache["v"].at[rows, cols].set(v.astype(cache["v"].dtype),
                                               mode="drop")
        new_cache = {"k": ck, "v": cv, "index": idx + s}
        k, v = ck, cv
        t = ck.shape[1]
        k_pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32),
                                 (x.shape[0], t))
        # entries beyond `index + s` are masked by causality w.r.t. q_pos

    if use_flash and cache is None and kv_override is None:
        from repro.kernels.flash_attention import ops as fa
        out = fa.mha(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                     v.transpose(0, 2, 1, 3), causal=causal)
        out = out.transpose(0, 2, 1, 3).reshape(x.shape[0], x.shape[1], -1)
    else:
        out = _sdpa(q, k, v, positions, k_pos, window, causal)
    return out @ params["o"], new_cache


def init_kv_cache(batch: int, max_len: int, num_kv_heads: int,
                  head_dim: int, dtype=jnp.bfloat16,
                  vector_index: bool = False) -> Dict:
    return {
        "k": jnp.zeros((batch, max_len, num_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, num_kv_heads, head_dim), dtype),
        "index": (jnp.zeros((batch,), jnp.int32) if vector_index
                  else jnp.zeros((), jnp.int32)),
    }
