"""Unified decoder block: pre-norm residual layers driven by LayerSpec.

One ``layer_init`` / ``layer_apply`` pair covers every assigned family:
attention (full / windowed / GQA), optional cross-attention sub-layer (VLM,
enc-dec decoders), Mamba-2 SSD mixers, and dense-MLP or MoE FFNs.  Layers of
the same spec are parameter-homogeneous, so a repeating unit stacks along a
scan axis (models/model.py).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FULL_WINDOW, LayerSpec, ModelConfig
from .attention import attention_apply, attention_init, init_kv_cache
from .layers import apply_norm, linear_init, mlp, mlp_init, norm_init
from .moe import moe_apply, moe_init
from .ssm import init_ssm_cache, ssm_apply, ssm_init

BIG_WINDOW = 1 << 30  # "full attention" as a window size


def layer_init(rng, cfg: ModelConfig, spec: LayerSpec,
               d_ff_override: int = 0, dtype=jnp.float32) -> Dict:
    keys = jax.random.split(rng, 8)
    p: Dict = {"ln1": norm_init(cfg.norm, cfg.d_model, dtype)}
    if spec.kind == "attn":
        p["attn"] = attention_init(keys[0], cfg.d_model, cfg.num_heads,
                                   cfg.num_kv_heads, cfg.head_dim,
                                   cfg.qk_norm, dtype)
    else:
        s = cfg.ssm
        p["ssm"] = ssm_init(keys[0], cfg.d_model, s.num_heads, s.head_dim,
                            s.state_dim, s.n_groups, s.conv_width, dtype)
    if spec.cross:
        p["ln_x"] = norm_init(cfg.norm, cfg.d_model, dtype)
        p["xattn"] = attention_init(keys[1], cfg.d_model, cfg.num_heads,
                                    cfg.num_kv_heads, cfg.head_dim,
                                    False, dtype)
        p["x_gate"] = jnp.zeros((1,), dtype)  # tanh-gated injection (llama-v)
    if spec.mlp:
        p["ln2"] = norm_init(cfg.norm, cfg.d_model, dtype)
        if spec.moe:
            m = cfg.moe
            p["moe"] = moe_init(keys[2], cfg.d_model, m.d_expert,
                                m.num_experts, m.num_shared, m.d_shared,
                                dtype)
        else:
            p["mlp"] = mlp_init(keys[2], cfg.d_model,
                                d_ff_override or cfg.d_ff,
                                cfg.gated_mlp, cfg.act, dtype)
    return p


def layer_cache_init(cfg: ModelConfig, spec: LayerSpec, batch: int,
                     max_len: int, dtype=jnp.bfloat16,
                     vector_index: bool = False) -> Dict:
    c: Dict = {}
    if spec.kind == "attn":
        c["kv"] = init_kv_cache(batch, max_len, cfg.num_kv_heads,
                                cfg.head_dim, dtype, vector_index)
    else:
        s = cfg.ssm
        c["ssm"] = init_ssm_cache(batch, s.num_heads, s.head_dim,
                                  s.state_dim, s.n_groups, s.conv_width,
                                  dtype)
    if spec.cross:
        # cross K/V are computed once from the context at prefill
        c["cross"] = {
            "k": jnp.zeros((batch, 0, cfg.num_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((batch, 0, cfg.num_kv_heads, cfg.head_dim), dtype),
        }
    return c


def _cross_kv(params: Dict, ctx: jnp.ndarray, cfg: ModelConfig):
    from .attention import _split_heads
    k = _split_heads(ctx @ params["xattn"]["k"], cfg.num_kv_heads,
                     cfg.head_dim)
    v = _split_heads(ctx @ params["xattn"]["v"], cfg.num_kv_heads,
                     cfg.head_dim)
    return k, v


def layer_apply(cfg: ModelConfig, spec: LayerSpec, params: Dict,
                x: jnp.ndarray, *, positions: jnp.ndarray,
                window: jnp.ndarray,
                causal: bool = True,
                cross_ctx: Optional[jnp.ndarray] = None,
                cache: Optional[Dict] = None
                ) -> Tuple[jnp.ndarray, Optional[Dict], jnp.ndarray]:
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict = {} if cache is not None else None
    h = apply_norm(cfg.norm, params["ln1"], x)
    if spec.kind == "attn":
        win = jnp.where(window == FULL_WINDOW, BIG_WINDOW, window)
        out, kvc = attention_apply(
            params["attn"], h, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=cfg.head_dim,
            positions=positions, window=win, rope_theta=cfg.rope_theta,
            causal=causal, use_rope=cfg.use_rope,
            cache=cache.get("kv") if cache else None,
            use_flash=cfg.use_flash)
        if cache is not None:
            new_cache["kv"] = kvc
    else:
        s = cfg.ssm
        out, sc = ssm_apply(params["ssm"], h, num_heads=s.num_heads,
                            head_dim=s.head_dim, state_dim=s.state_dim,
                            n_groups=s.n_groups, chunk_len=s.chunk_len,
                            cache=cache.get("ssm") if cache else None)
        if cache is not None:
            new_cache["ssm"] = sc
    x = x + out

    if spec.cross:
        hx = apply_norm(cfg.norm, params["ln_x"], x)
        if cache is not None and cross_ctx is None:
            kx, vx = cache["cross"]["k"], cache["cross"]["v"]
        else:
            kx, vx = _cross_kv(params, cross_ctx, cfg)
            if cache is not None:
                new_cache["cross"] = {"k": kx, "v": vx}
        t = kx.shape[1]
        k_pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32),
                                 (x.shape[0], t))
        out, _ = attention_apply(
            params["xattn"], hx, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=cfg.head_dim,
            positions=positions, window=jnp.int32(BIG_WINDOW),
            causal=False, use_rope=False,
            kv_override=(kx, vx, k_pos))
        x = x + jnp.tanh(params["x_gate"]).astype(x.dtype) * out
        if cache is not None and "cross" not in new_cache:
            new_cache["cross"] = {"k": kx, "v": vx}

    if spec.mlp:
        h2 = apply_norm(cfg.norm, params["ln2"], x)
        if spec.moe:
            m = cfg.moe
            out2, a = moe_apply(params["moe"], h2, num_experts=m.num_experts,
                                top_k=m.top_k,
                                capacity_factor=m.capacity_factor)
            aux = aux + a
        else:
            out2 = mlp(params["mlp"], h2, cfg.act)
        x = x + out2
    return x, new_cache, aux
