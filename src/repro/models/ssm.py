"""Mamba-2 SSD (state-space duality) mixer [arXiv:2405.21060].

Chunked SSD: the sequence is split into chunks of ``chunk_len``; the
intra-chunk term is a masked quadratic form (MXU-friendly), the inter-chunk
term passes a compact [H, P, N] state through a ``lax.scan`` over chunks --
sub-quadratic in sequence length, O(1)-state decode.  A naive sequential
reference (``ssd_reference``) validates the chunked path.

Parameters follow mamba2: fused in_proj -> (z, x, B, C, dt), depthwise
causal conv over (x, B, C), per-head A/D/dt_bias, gated RMSNorm, out_proj.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import linear_init


def ssm_init(rng, d_model: int, num_heads: int, head_dim: int,
             state_dim: int, n_groups: int = 1, conv_width: int = 4,
             dtype=jnp.float32) -> Dict:
    d_inner = num_heads * head_dim
    conv_dim = d_inner + 2 * n_groups * state_dim
    d_in_proj = 2 * d_inner + 2 * n_groups * state_dim + num_heads
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    return {
        "in_proj": linear_init(k1, d_model, d_in_proj, dtype),
        "conv_w": (jax.random.normal(k2, (conv_width, conv_dim), jnp.float32)
                   * (1.0 / conv_width)).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, num_heads)).astype(dtype),
        "D": jnp.ones((num_heads,), dtype),
        "dt_bias": jnp.zeros((num_heads,), dtype),
        "norm_scale": jnp.ones((d_inner,), dtype),
        "out_proj": linear_init(k3, d_inner, d_model, dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv1d.  x: [B, L, C]; w: [W, C].

    Returns (y, new_state) where state is the trailing (W-1) inputs.
    """
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    # y[t] = sum_i w[i] * xp[t + i]
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(width))
    new_state = xp[:, -(width - 1):, :] if width > 1 else state
    return jax.nn.silu(y + b), new_state


def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """Stable segment-sum: out[..., t, s] = sum_{s < r <= t} a[..., r].

    Lower-triangular (t >= s); -inf above diagonal."""
    T = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, D, chunk_len: int):
    """SSD forward.

    x: [b, l, h, p]; dt: [b, l, h] (post-softplus); A: [h] (negative);
    B, C: [b, l, g, n] (g groups broadcast over h).  Returns (y, final_state)
    with final_state [b, h, p, n].
    """
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hpg = h // g
    assert l % chunk_len == 0
    nc = l // chunk_len
    q = chunk_len

    xb = (x * dt[..., None]).reshape(b, nc, q, h, p)
    a = (dt * A[None, None, :]).reshape(b, nc, q, h)        # log-decay
    Bc = B.reshape(b, nc, q, g, n)
    Cc = C.reshape(b, nc, q, g, n)

    a_t = a.transpose(0, 1, 3, 2)                            # [b,nc,h,q]
    L = jnp.exp(_segsum(a_t))                                # [b,nc,h,q,q]
    a_cum = jnp.cumsum(a_t, axis=-1)                         # [b,nc,h,q]

    # intra-chunk (quadratic within chunk, MXU einsums)
    CB = jnp.einsum("bcqgn,bcsgn->bcgqs", Cc, Bc)            # [b,nc,g,q,s]
    CB = jnp.repeat(CB, hpg, axis=2)                         # [b,nc,h,q,s]
    y_intra = jnp.einsum("bchqs,bcshp->bcqhp", CB * L,
                         xb.astype(jnp.float32))

    # per-chunk right state: S_c = sum_s exp(a_cum[-1]-a_cum[s]) B_s xb_s^T
    decay_r = jnp.exp(a_cum[..., -1:] - a_cum)               # [b,nc,h,q]
    Bc_heads = jnp.repeat(Bc, hpg, axis=3) if g != h else Bc  # [b,nc,s,h,n]
    S = jnp.einsum("bcshn,bchs,bcshp->bchpn",
                   Bc_heads, decay_r, xb.astype(jnp.float32))  # [b,nc,h,p,n]

    # inter-chunk scan over chunk states
    chunk_decay = jnp.exp(a_t.sum(-1))                       # [b,nc,h]

    def scan_fn(hprev, inp):
        S_c, dec = inp                                       # [b,h,p,n],[b,h]
        hnew = hprev * dec[..., None, None] + S_c
        return hnew, hprev                                   # emit state *before* chunk

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    hlast, hprevs = jax.lax.scan(
        scan_fn, h0,
        (S.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    hprevs = hprevs.transpose(1, 0, 2, 3, 4)                 # [b,nc,h,p,n]

    # inter-chunk contribution: y_t += exp(a_cum[t]) C_t . h_prev
    decay_l = jnp.exp(a_cum)                                 # [b,nc,h,q]
    Ch_heads = jnp.repeat(Cc, hpg, axis=3) if g != h else Cc  # [b,nc,q,h,n]
    Ch = jnp.einsum("bcqhn,bchpn->bcqhp", Ch_heads, hprevs)
    y_inter = Ch * decay_l.transpose(0, 1, 3, 2)[..., None]

    y = (y_intra + y_inter).reshape(b, l, h, p)
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype), hlast


def ssm_apply(params: Dict, xin: jnp.ndarray, *, num_heads: int,
              head_dim: int, state_dim: int, n_groups: int = 1,
              chunk_len: int = 256,
              cache: Optional[Dict] = None
              ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Full mamba2 mixer.  xin: [B, L, d_model].

    cache = {'conv': [B, W-1, conv_dim], 'state': [B, H, P, N]} for decode
    (L == 1); None for train/prefill (a fresh cache is returned when L>1
    and the caller asked by passing cache={'init': True}).
    """
    b, l, _ = xin.shape
    h, p, n, g = num_heads, head_dim, state_dim, n_groups
    d_inner = h * p
    zxbcdt = xin @ params["in_proj"]
    z, xbc, dt_raw = jnp.split(
        zxbcdt, [d_inner, d_inner + d_inner + 2 * g * n], axis=-1)
    conv_state = cache.get("conv") if isinstance(cache, dict) and \
        "conv" in cache else None
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                 conv_state)
    x, B, C = jnp.split(xbc, [d_inner, d_inner + g * n], axis=-1)
    x = x.reshape(b, l, h, p)
    B = B.reshape(b, l, g, n)
    C = C.reshape(b, l, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    if cache is not None and "state" in cache and l == 1:
        # single-step decode: h' = exp(dt*A) h + dt * B x^T ; y = C h' + D x
        s_prev = cache["state"]                              # [b,h,p,n]
        dt1 = dt[:, 0]                                       # [b,h]
        decay = jnp.exp(dt1 * A[None, :])                    # [b,h]
        hpg = h // g
        B1 = jnp.repeat(B[:, 0], hpg, axis=1) if g != h else B[:, 0]
        C1 = jnp.repeat(C[:, 0], hpg, axis=1) if g != h else C[:, 0]
        Bx = jnp.einsum("bhn,bhp->bhpn", B1.astype(jnp.float32),
                        (x[:, 0] * dt1[..., None]).astype(jnp.float32))
        s_new = s_prev * decay[..., None, None] + Bx
        y = jnp.einsum("bhn,bhpn->bhp", C1.astype(jnp.float32), s_new)
        y = y + x[:, 0].astype(jnp.float32) * params["D"][None, :, None]
        y = y[:, None].astype(xin.dtype)                     # [b,1,h,p]
        new_cache = {"conv": new_conv, "state": s_new}
    else:
        pad = (-l) % chunk_len
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
            C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        y, s_last = ssd_chunked(x, dt, A, B, C, params["D"], chunk_len)
        y = y[:, :l]
        new_cache = ({"conv": new_conv, "state": s_last}
                     if cache is not None else None)

    # gated RMSNorm (mamba2): y * silu(z), normalized
    yf = y.reshape(b, l, d_inner).astype(jnp.float32)
    zf = z.astype(jnp.float32)
    yf = yf * jax.nn.silu(zf)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + 1e-6) * \
        params["norm_scale"].astype(jnp.float32)
    out = yf.astype(xin.dtype) @ params["out_proj"]
    return out, new_cache


def ssd_reference(x, dt, A, B, C, D):
    """Naive O(L) sequential oracle for ssd_chunked (tests)."""
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hpg = h // g
    s = jnp.zeros((b, h, p, n), jnp.float32)
    ys = []
    for t in range(l):
        Bt = jnp.repeat(B[:, t], hpg, axis=1) if g != h else B[:, t]
        Ct = jnp.repeat(C[:, t], hpg, axis=1) if g != h else C[:, t]
        decay = jnp.exp(dt[:, t] * A[None, :])               # [b,h]
        Bx = jnp.einsum("bhn,bhp->bhpn", Bt.astype(jnp.float32),
                        (x[:, t] * dt[:, t][..., None]).astype(jnp.float32))
        s = s * decay[..., None, None] + Bx
        y = jnp.einsum("bhn,bhpn->bhp", Ct.astype(jnp.float32), s)
        ys.append(y + x[:, t].astype(jnp.float32) * D[None, :, None])
    return jnp.stack(ys, axis=1).astype(x.dtype), s


def init_ssm_cache(batch: int, num_heads: int, head_dim: int,
                   state_dim: int, n_groups: int, conv_width: int,
                   dtype=jnp.bfloat16) -> Dict:
    conv_dim = num_heads * head_dim + 2 * n_groups * state_dim
    return {
        "conv": jnp.zeros((batch, conv_width - 1, conv_dim), dtype),
        "state": jnp.zeros((batch, num_heads, head_dim, state_dim),
                           jnp.float32),
    }
