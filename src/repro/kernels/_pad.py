"""Shared kernel-dispatch helpers: padding size classes + trace counters.

Every jitted kernel entry retraces once per distinct input-shape tuple, so
the dispatch layer pads variable-length inputs (page-index vectors, the
requested-row position vector, id lists) up to a small set of shared
**power-of-two size classes**.  The helpers here are the single home for
that policy (they were previously copy-pasted across the pac_decode and
label_filter op layers).

The module also keeps a lightweight **trace counter**: each jitted entry
calls :func:`note_trace` from inside its Python body, which only executes
when jax actually (re)traces -- a cache hit dispatches the compiled
executable without re-running the body.  Benchmarks and tests use
:func:`trace_count` to assert that steady-state serving dispatches hit
the jit cache (zero retraces); when available the event is also forwarded
to ``jax.monitoring`` so external collectors see the same signal.
"""
from __future__ import annotations

from typing import Dict


def next_multiple(x: int, m: int) -> int:
    """Smallest multiple of ``m`` >= ``x``."""
    return -(-x // m) * m


def next_pow2(x: int) -> int:
    """Smallest power of two >= ``x`` (``next_pow2(0) == 1``)."""
    return 1 << max(x - 1, 0).bit_length()


def size_class(x: int, minimum: int = 1) -> int:
    """Shared pow2 padding class: smallest power of two >= max(x, minimum).

    The ``minimum`` floor collapses the long tail of tiny frontier shapes
    into one bucket, so steady-state serving dispatches stop retracing
    per distinct (small) batch shape.
    """
    return max(next_pow2(x), next_pow2(minimum))


# --------------------------------------------------------------------------
# trace counting (retrace tripwire for steady-state dispatch benchmarks)
# --------------------------------------------------------------------------

_TRACES: Dict[str, int] = {}


def note_trace(name: str) -> None:
    """Record one (re)trace of the named jitted entry.

    Call from inside the jitted function's Python body: the body runs only
    on a jit-cache miss, so the counter equals the number of traces.
    """
    _TRACES[name] = _TRACES.get(name, 0) + 1
    try:  # best-effort mirror into jax's own monitoring stream
        from jax import monitoring
        monitoring.record_event(f"/repro/kernels/trace/{name}")
    except Exception:
        pass


def trace_count(prefix: str = "") -> int:
    """Total traces recorded for entries whose name starts with ``prefix``."""
    return sum(v for k, v in _TRACES.items() if k.startswith(prefix))


def trace_counts() -> Dict[str, int]:
    """Per-entry trace counts (a copy)."""
    return dict(_TRACES)


def reset_trace_counts() -> None:
    _TRACES.clear()
