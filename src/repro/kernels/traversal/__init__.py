"""Fused on-device multi-hop traversal kernels.

The frontier stays on device across hops: one ``lax.scan``-stepped
dispatch expands the current frontier plane through the resident edge
value column (``TraversalPlan``), ANDs per-hop predicate bitmaps in
place, and accumulates the visited plane -- no host-side id
materialization between hops.  See :mod:`repro.kernels.traversal.ops`.
"""
