"""jnp reference implementations of the fused traversal entries.

Shared representation (all entries):

* the **resident expansion plan** -- ``key_sorted`` int32[rows_pad] (the
  CSR key of every edge row, re-ordered so rows group by *value* id and
  padded to a word multiple with the key-space size) and ``voff``
  int32[n_value + 1] (each value id's row segment in that order) -- lives
  on device across dispatches
  (:class:`repro.kernels.traversal.ops.TraversalPlan`);
* frontiers are dense int32 0/1 **planes** over the vertex id space,
  built on device from padded seed-id vectors (``mode="drop"`` discards
  the out-of-range padding), so a dispatch ships O(seeds) ids, never a
  plane;
* per-hop predicates arrive as uint32 **bitmap words** (the
  label-filter plane's convention, ~n/32 ints per hop) and are expanded
  and ANDed in place inside the hop body.

One hop is: gather each edge row's frontier bit through ``key_sorted``,
pack the bits to uint32 words, take a word-level popcount prefix, and
read each value id's count as a **rank difference** at its segment
bounds -- then AND the hop's predicate bits, ANDNOT the visited plane,
and fold the survivors into ``visited``.  ``lax.scan`` steps the hop
``k`` times inside one jitted dispatch.

The rank formulation is the load-bearing trick: the obvious
``.at[vals].max(sel)`` scatter-OR is exact but serializes on CPU XLA
(~45x slower than a gather of the same width); gathers + a short
word-level prefix sum vectorize, and double as the multiplicity-exact
counting expansion (BI-2) since the rank difference *is* the segment's
edge count.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels._pad import note_trace


def _shifts():
    # built in-trace (an iota) rather than captured as a module-level
    # device constant: pallas kernel bodies cannot close over arrays
    return jnp.arange(32, dtype=jnp.uint32)


def _seed_plane(seed_ids, n: int):
    """Padded seed ids -> dense 0/1 plane (padding == n drops out)."""
    return jnp.zeros((n,), jnp.int32).at[seed_ids].set(1, mode="drop")


def _filter_bits(words, n: int):
    """uint32 bitmap words -> dense 0/1 plane over [0, n)."""
    ids = jnp.arange(n, dtype=jnp.int32)
    return ((jnp.take(words, ids >> 5)
             >> (ids & 31).astype(jnp.uint32)) & 1).astype(jnp.int32)


def expand_counts(key_sorted, voff, frontier):
    """Per-value-id count of frontier-selected in-rows (scatter-free).

    ``key_sorted`` groups edge rows by value id (padding keys >= the key
    space size select nothing); ``voff[v]:voff[v+1]`` is value ``v``'s
    segment.  The gathered 0/1 row selection is bit-packed to uint32
    words, a popcount prefix runs over the words, and each segment's
    count is the rank difference at its bounds.
    """
    nk = frontier.shape[0]
    sel = (jnp.take(frontier, jnp.minimum(key_sorted, nk - 1))
           * (key_sorted < nk))
    words = (sel.reshape(-1, 32).astype(jnp.uint32)
             << _shifts()[None, :]).sum(axis=1, dtype=jnp.uint32)
    csw = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32),
         jnp.cumsum(jax.lax.population_count(words).astype(jnp.int32))])

    def rank(i):
        w = i >> 5
        part = (jnp.take(words, w, mode="clip")
                & ((jnp.uint32(1) << (i & 31).astype(jnp.uint32)) - 1))
        return (jnp.take(csw, w)
                + jax.lax.population_count(part).astype(jnp.int32))

    return rank(voff[1:]) - rank(voff[:-1])


def expand_plane_ref(key_sorted, voff, frontier):
    """One frontier expansion: 0/1 plane of every value id reachable by
    an edge whose key is on the frontier (count > 0 == OR)."""
    return (expand_counts(key_sorted, voff, frontier) > 0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("n_out",))
def khop_scan_ref(key_sorted, voff, seed_ids, filt_words, *, n_out: int):
    """Fused k-hop: ``filt_words`` uint32[hops, n_words] steps the scan.

    Returns ``(visited, hop_planes, hop_sizes)``: the final visited 0/1
    plane (seeds included), each hop's newly-discovered plane
    int32[hops, n_out], and per-hop frontier sizes int32[hops].
    """
    note_trace("khop_ref")
    f0 = _seed_plane(seed_ids, n_out)

    def hop(carry, fw):
        frontier, visited = carry
        plane = expand_plane_ref(key_sorted, voff, frontier)
        nxt = plane * _filter_bits(fw, n_out) * (1 - visited)
        return (nxt, visited + nxt), nxt

    (_, visited), planes = jax.lax.scan(hop, (f0, f0), filt_words)
    return visited, planes, planes.sum(axis=1)


def _pack_words(plane, n_words: int):
    """Dense 0/1 plane -> uint32 bitmap words (on device)."""
    padded = jnp.zeros((n_words * 32,), jnp.int32).at[: plane.shape[0]] \
        .set(plane)
    return (padded.reshape(n_words, 32).astype(jnp.uint32)
            << _shifts()[None, :]).sum(axis=1, dtype=jnp.uint32)


@functools.partial(jax.jit,
                   static_argnames=("n_key", "n_mid", "n_out", "n_words"))
def two_hop_ref(ks_a, voff_a, ks_b, voff_b, seed_ids, filt_words, *,
                n_key: int, n_mid: int, n_out: int, n_words: int):
    """Heterogeneous two-hop chain (IC-8's shape): seeds in adjacency
    A's key space expand to a mid plane, which expands through adjacency
    B; the predicate words AND the result in place.  Returns
    ``(mid_plane, out_words)`` -- the output already packed to uint32
    bitmap words for ``PAC.from_dense_bitmap``.
    """
    note_trace("twohop_ref")
    f0 = _seed_plane(seed_ids, n_key)
    mid = expand_plane_ref(ks_a, voff_a, f0)
    out = expand_plane_ref(ks_b, voff_b, mid)
    return mid, _pack_words(out, n_words) & filt_words


@functools.partial(jax.jit, static_argnames=("n_key", "n_out"))
def count_hop_ref(key_sorted, voff, starts, ends, *,
                  n_key: int, n_out: int):
    """Counting expansion (BI-2's shape): the frontier arrives as sorted
    disjoint id intervals over the key space (padding index ``n_key + 1``
    drops); the rank difference at each target's segment bounds *is* its
    edge count, so multiplicity survives.  Returns int32[n_out] counts."""
    note_trace("counthop_ref")
    delta = jnp.zeros((n_key + 1,), jnp.int32) \
        .at[starts].add(1, mode="drop").at[ends].add(-1, mode="drop")
    plane = (jnp.cumsum(delta)[:n_key] > 0).astype(jnp.int32)
    return expand_counts(key_sorted, voff, plane)
