"""Pallas traversal kernels (interpret-mode on CPU, like pac_decode).

Same contracts as :mod:`repro.kernels.traversal.ref` -- the hop body
(frontier gather through ``key_sorted`` -> bit-pack -> popcount-rank
expand -> predicate AND -> visited ANDNOT) runs inside a
``pallas_call``; the ``lax.scan`` over hops, seed-plane construction,
and word packing stay in the surrounding jitted entry, so k hops are
still one dispatch with no host round-trips between hops.

A TPU build would tile the rank expansion over the value id space the
way ``_bitmap_tile`` tiles the PAC kernels; on CPU/interpret the
single-grid body is exact and fast enough to beat the per-hop host
loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._pad import note_trace

from .ref import _filter_bits, _pack_words, _seed_plane, expand_counts


def _hop_kernel(ks_ref, voff_ref, f_ref, vis_ref, fw_ref, nxt_ref, *,
                n_out):
    """One hop: rank-expand the frontier plane, AND the predicate bits,
    ANDNOT the visited plane.  All planes live in VMEM; only the
    newly-discovered plane is written out."""
    plane = (expand_counts(ks_ref[...], voff_ref[...], f_ref[...])
             > 0).astype(jnp.int32)
    bits = _filter_bits(fw_ref[...], n_out)
    nxt_ref[...] = plane * bits * (1 - vis_ref[...])


def _hop_pallas(key_sorted, voff, frontier, visited, fwords, *,
                n_out: int, interpret: bool = True):
    kern = functools.partial(_hop_kernel, n_out=n_out)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((n_out,), jnp.int32),
        interpret=interpret,
    )(key_sorted, voff, frontier, visited, fwords)


@functools.partial(jax.jit, static_argnames=("n_out", "interpret"))
def khop_scan_pallas(key_sorted, voff, seed_ids, filt_words, *,
                     n_out: int, interpret: bool = True):
    """Fused k-hop (see :func:`...ref.khop_scan_ref`): one scan-stepped
    dispatch, the hop body a pallas kernel."""
    note_trace("khop_pallas")
    f0 = _seed_plane(seed_ids, n_out)

    def hop(carry, fw):
        frontier, visited = carry
        nxt = _hop_pallas(key_sorted, voff, frontier, visited, fw,
                          n_out=n_out, interpret=interpret)
        return (nxt, visited + nxt), nxt

    (_, visited), planes = jax.lax.scan(hop, (f0, f0), filt_words)
    return visited, planes, planes.sum(axis=1)


def _expand_kernel(ks_ref, voff_ref, f_ref, out_ref):
    out_ref[...] = (expand_counts(ks_ref[...], voff_ref[...], f_ref[...])
                    > 0).astype(jnp.int32)


def _expand_pallas(key_sorted, voff, frontier, *, n_out: int,
                   interpret: bool = True):
    return pl.pallas_call(
        _expand_kernel,
        out_shape=jax.ShapeDtypeStruct((n_out,), jnp.int32),
        interpret=interpret,
    )(key_sorted, voff, frontier)


@functools.partial(jax.jit, static_argnames=("n_key", "n_mid", "n_out",
                                             "n_words", "interpret"))
def two_hop_pallas(ks_a, voff_a, ks_b, voff_b, seed_ids, filt_words, *,
                   n_key: int, n_mid: int, n_out: int, n_words: int,
                   interpret: bool = True):
    """Heterogeneous two-hop chain, both expansions pallas kernels in
    one dispatch (see :func:`...ref.two_hop_ref`)."""
    note_trace("twohop_pallas")
    f0 = _seed_plane(seed_ids, n_key)
    mid = _expand_pallas(ks_a, voff_a, f0, n_out=n_mid,
                         interpret=interpret)
    out = _expand_pallas(ks_b, voff_b, mid, n_out=n_out,
                         interpret=interpret)
    return mid, _pack_words(out, n_words) & filt_words


def _count_kernel(ks_ref, voff_ref, starts_ref, ends_ref, out_ref, *,
                  n_key):
    delta = jnp.zeros((n_key + 1,), jnp.int32) \
        .at[starts_ref[...]].add(1, mode="drop") \
        .at[ends_ref[...]].add(-1, mode="drop")
    plane = (jnp.cumsum(delta)[:n_key] > 0).astype(jnp.int32)
    out_ref[...] = expand_counts(ks_ref[...], voff_ref[...], plane)


@functools.partial(jax.jit, static_argnames=("n_key", "n_out", "interpret"))
def count_hop_pallas(key_sorted, voff, starts, ends, *, n_key: int,
                     n_out: int, interpret: bool = True):
    """Counting expansion (see :func:`...ref.count_hop_ref`) as one
    pallas kernel: interval frontier -> per-target edge counts."""
    note_trace("counthop_pallas")
    kern = functools.partial(_count_kernel, n_key=n_key)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((n_out,), jnp.int32),
        interpret=interpret,
    )(key_sorted, voff, starts, ends)
