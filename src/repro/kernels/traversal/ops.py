"""Dispatch layer of the fused traversal plane.

A :class:`TraversalPlan` is the adjacency's device-resident expansion
structure: the whole edge value column decoded **once** through the
resident unpack plans (``pac_decode._decode_page_matrix`` -- on a
partitioned column that routes through the sharded decode, so the plan
build itself is a partition-plane dispatch), re-ordered so edge rows
group by value id (``key_sorted`` + the segment index ``voff``, the
scatter-free rank-expansion layout -- see
:func:`repro.kernels.traversal.ref.expand_counts`).  The plan crosses
to the device once per (column version, partitioning, engine);
traversal dispatches then ship only padded seed-id vectors and per-hop
predicate bitmap words.

``k_hop_fused`` runs k hops as **one** ``lax.scan``-stepped dispatch
(jnp ref or pallas hop kernels); with a partition plane attached and a
multi-device mesh it dispatches through ``shard.sharded_khop_entry`` --
edge rows sharded partition-major, per-hop planes ``pmax``-combined
across the mesh.  ``two_hop_pac`` (IC-8's heterogeneous chain) and
``frontier_edge_counts`` (BI-2's counting expansion) reuse the same
plans.

Accounting: the host loop (``core.neighbor.k_hop`` with
``fused=False``) is the bit-identical oracle.  When a meter or a
decoded-page LRU is attached, the fused path **replays** the oracle's
I/O after its single dispatch -- per hop: predicate metadata charge,
offsets gather, LRU split, miss-page charge, cache backfill from the
plan's host decode -- so meters and cache evolution match the oracle
exactly; with neither attached, nothing but the final visited plane
(and the per-hop size vector) ever crosses back to the host.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence, Tuple

import numpy as np
import jax.numpy as jnp

from repro.core.encoding import DeltaColumn, prune_page_list
from repro.core.frontier import Frontier
from repro.core.pac import PAC
from repro.core.page_cache import live_cache
from repro.core.partition import ensure_default_partitions, live_partitions
from repro.kernels._pad import size_class
from repro.kernels.pac_decode import ops as pac_ops

from . import kernel as K
from . import ref as R

#: pow2 floor for the padded seed-id vector (same role as
#: ``pac_ops.RANGE_CLASS_MIN``: steady-state traversals with small,
#: varying seed batches share one jit size class).
SEED_CLASS_MIN = 64

#: pow2 floor for BI-2's padded interval vectors.
INTERVAL_CLASS_MIN = 8


def _kernel_column(adj) -> DeltaColumn:
    from repro.core.table import DeltaIntColumn
    col = adj.table[adj.value_col]
    if not isinstance(col, DeltaIntColumn):
        raise TypeError("traversal plans require a delta-encoded column")
    ensure_default_partitions(col.encoded)
    return col.encoded


def plan_supported(adj) -> bool:
    """Whether the fused traversal plane can serve this adjacency."""
    from repro.core.table import DeltaIntColumn
    return (adj.offsets is not None
            and adj.num_value_vertices is not None
            and isinstance(adj.table[adj.value_col], DeltaIntColumn))


@dataclasses.dataclass
class TraversalPlan:
    """Device-resident expansion structure of one adjacency."""

    col: DeltaColumn
    n_key: int
    n_value: int
    host_vals: np.ndarray       # int64 [rows] -- decoded value column
    key_of_row: np.ndarray      # int32 [rows] -- CSR key of each row
    key_sorted: np.ndarray      # int32 [rows_pad] -- keys grouped by value
    voff: np.ndarray            # int32 [n_value+1] -- value segments
    #: engine -> (key_sorted, voff) on device (int32, monolithic).
    _device: Dict[str, Tuple] = dataclasses.field(
        default_factory=dict, repr=False, compare=False)
    #: (engine, partition version, n_parts) -> (mesh, skey_sorted, svoff):
    #: per-partition rank layouts stacked partition-major and sharded
    #: across the mesh.
    _sharded: Dict[Tuple, Tuple] = dataclasses.field(
        default_factory=dict, repr=False, compare=False)
    device_transfers: int = 0
    # -- traversal counters (surfaced via traversal_stats) ------------------
    dispatches: int = 0
    hops_fused: int = 0
    device_roundtrips: int = 0
    last_frontier_sizes: "np.ndarray | None" = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def rows(self) -> int:
        return len(self.host_vals)

    def device(self, engine: str) -> Tuple:
        plan = self._device.get(engine)
        if plan is None:
            plan = (jnp.asarray(self.key_sorted), jnp.asarray(self.voff))
            self._device[engine] = plan
            self.device_transfers += 1
        return plan

    def sharded_arrays(self, engine: str, parts) -> Tuple:
        """Partition-major stacked ``(mesh, key_sorted, voff)``, sharded
        ``P('part')`` -- each shard gets its partitions' rows in its own
        rank layout (padding keys == ``n_key`` select nothing) and a
        full-size segment index over the value space, so every shard
        expands a partial plane the mesh then ``pmax``-combines."""
        key = (engine, parts.version, parts.n_parts)
        cached = self._sharded.get(key)
        if cached is None:
            import jax
            from jax.sharding import Mesh, NamedSharding, PartitionSpec
            devs = parts.mesh_devices(jax.devices())
            mesh = Mesh(np.array(devs), ("part",))
            ps = self.col.page_size
            rmax = -(-parts.pmax * ps // 32) * 32
            nseg = self.n_value + 1
            skey = np.full(parts.n_parts * rmax, self.n_key, np.int32)
            svoff = np.zeros(parts.n_parts * nseg, np.int32)
            for k, p in enumerate(parts.parts):
                lo, hi = p.row_lo, p.row_hi
                order = np.argsort(self.host_vals[lo:hi], kind="stable")
                skey[k * rmax: k * rmax + (hi - lo)] = \
                    self.key_of_row[lo:hi][order]
                svoff[k * nseg + 1: (k + 1) * nseg] = np.cumsum(
                    np.bincount(self.host_vals[lo:hi],
                                minlength=self.n_value))
            spec = NamedSharding(mesh, PartitionSpec("part"))
            cached = (mesh, jax.device_put(skey, spec),
                      jax.device_put(svoff, spec))
            self._sharded[key] = cached
            self.device_transfers += 1
        return cached

    def stats(self) -> Dict[str, object]:
        return {"rows": self.rows, "transfers": self.device_transfers,
                "dispatches": self.dispatches,
                "hops_fused": self.hops_fused,
                "device_roundtrips": self.device_roundtrips}


def traversal_plan(adj, engine: str) -> TraversalPlan:
    """The adjacency's plan, built once per (column version,
    partitioning) -- a repartition or version bump rebuilds; the build's
    whole-column decode goes through the resident (and, when
    partitioned, sharded) decode paths, so it *is* a partition-plane
    dispatch."""
    col = _kernel_column(adj)
    key = (col.version, getattr(col, "partitions", 0) or 0)
    plans = getattr(adj, "_traversal_plans", None)
    if plans is None:
        plans = {}
        adj._traversal_plans = plans
    plan = plans.get(key)
    if plan is None:
        n_pages = len(col.pages)
        mat = pac_ops._decode_page_matrix(col, list(range(n_pages)), engine)
        counts = np.asarray([p.count for p in col.pages], np.int64)
        mask = np.arange(col.page_size)[None, :] < counts[:, None]
        host_vals = mat[mask]
        off = np.asarray(adj.offsets["<offset>"].values, np.int64)
        key_of_row = np.repeat(
            np.arange(adj.num_key_vertices, dtype=np.int32), np.diff(off))
        if len(key_of_row) != len(host_vals):
            raise ValueError("offset index disagrees with value column "
                             f"({len(key_of_row)} vs {len(host_vals)} rows)")
        n_key = int(adj.num_key_vertices)
        n_value = int(adj.num_value_vertices)
        # the rank-expansion layout: rows grouped by value id, padded to
        # a word multiple with keys that select nothing
        order = np.argsort(host_vals, kind="stable")
        key_sorted = np.full(-(-len(host_vals) // 32) * 32, n_key,
                             np.int32)
        key_sorted[:len(host_vals)] = key_of_row[order]
        voff = np.zeros(n_value + 1, np.int32)
        voff[1:] = np.cumsum(np.bincount(host_vals, minlength=n_value))
        plan = TraversalPlan(col, n_key, n_value, host_vals, key_of_row,
                             key_sorted, voff)
        plans[key] = plan
    return plan


def traversal_stats(adj) -> "Dict[str, object] | None":
    """Aggregated traversal counters across the adjacency's live plans
    (for ``GraphRetriever.stats()`` / ``ServeEngine.stats()``), plus the
    graceful host-loop fallbacks taken while deltas were pending."""
    plans = getattr(adj, "_traversal_plans", None)
    fallbacks = getattr(adj, "_traversal_fallbacks", 0)
    if not plans and not fallbacks:
        return None
    plans = plans or {}
    out = {"dispatches": sum(p.dispatches for p in plans.values()),
           "hops_fused": sum(p.hops_fused for p in plans.values()),
           "device_transfers": sum(p.device_transfers
                                   for p in plans.values()),
           "traversal_device_roundtrips": sum(p.device_roundtrips
                                              for p in plans.values()),
           "fallbacks": fallbacks}
    last = [p.last_frontier_sizes for p in plans.values()
            if p.last_frontier_sizes is not None]
    if last:
        out["frontier_sizes"] = [int(x) for x in last[-1]]
    return out


def _filter_words(filts: Sequence, hops: int, n_words: int, n: int,
                  engine: str) -> np.ndarray:
    """Per-hop predicate bitmap words (all-ones rows where unfiltered)."""
    fw = np.empty((hops, n_words), np.uint32)
    for h in range(hops):
        f = filts[h]
        if f is None:
            fw[h] = np.uint32(0xFFFFFFFF)
        else:
            if f.vt.num_vertices != n:
                raise ValueError(
                    f"hop-{h} filter covers {f.vt.num_vertices} vertices "
                    f"but the traversal id space has {n}")
            fw[h] = f.bitmap(engine)
    return fw


def _seed_vector(seeds: np.ndarray, sentinel: int) -> np.ndarray:
    s_pad = size_class(len(seeds), SEED_CLASS_MIN)
    out = np.full(s_pad, sentinel, np.int32)
    out[:len(seeds)] = seeds
    return out


def _charge_ranges(col: DeltaColumn, plan: TraversalPlan,
                   los, his, meter, cache, parts, qual=None) -> None:
    """Replay the page I/O of decoding ``[los, his)`` exactly as the
    host oracle incurs it: page-granular statistics pruning against the
    hop predicate's qualifying hull ``qual``, LRU split, miss-page
    charge (bytes once, requests per contiguous run), cache backfill
    from the plan's host decode."""
    ps = col.page_size
    pages, _ = pac_ops.page_set_for_ranges(los, his, ps)
    pages, _ = prune_page_list(col, pages, qual)
    if not len(pages):
        return
    owner = parts.part_of_pages(pages) if parts is not None else None
    if cache is None:
        pac_ops._charge_pages(col, pages, meter)
        return
    _, miss = cache.split(pages, owner=owner)
    pac_ops._charge_pages(col, miss, meter)
    pos = {int(p): i for i, p in enumerate(pages)}
    for p in miss:
        rows = plan.host_vals[p * ps: p * ps + col.pages[p].count]
        cache.put(p, rows.copy(),
                  part=None if owner is None else int(owner[pos[p]]))


def _charge_expansion(adj, col: DeltaColumn, plan: TraversalPlan,
                      ids: np.ndarray, meter, cache, parts,
                      qual=None) -> None:
    """One hop's oracle I/O: offsets gather + value-page charges
    (zone-map-pruned by the hop predicate's hull, like the oracle's)."""
    los, his = adj.edge_ranges_batch(ids, meter)
    _charge_ranges(col, plan, los, his, meter, cache, parts, qual=qual)


def _shard_width(parts) -> int:
    """Mesh width for a traversal dispatch: the partition plane's mesh,
    taken only when every device's share of the column clears the
    adaptive SPMD threshold (same policy knob as the retrieval plane --
    ``pac_ops.SHARD_MIN_PAGES``, read at call time so forced-SPMD test
    environments see it)."""
    g = parts.mesh_size(pac_ops._n_devices())
    if g <= 1:
        return 1
    per_dev_pages = -(-len(parts.col.pages) // g)
    if per_dev_pages < pac_ops.SHARD_MIN_PAGES:
        return 1
    return g


def note_traversal_fallback(adj) -> None:
    """Count one graceful degradation to the host-loop oracle (surfaced
    as ``fallbacks`` in :func:`traversal_stats`)."""
    adj._traversal_fallbacks = getattr(adj, "_traversal_fallbacks", 0) + 1


def k_hop_fused(adj, seeds, hops: int, filts: Sequence, meter=None,
                engine: str = "jax",
                include_seeds: bool = True) -> np.ndarray:
    """Fused k-hop: one scan-stepped dispatch, ids bit-identical to the
    host oracle (``core.neighbor.k_hop`` with ``fused=False``)."""
    from repro.core.delta_segment import live_delta
    if live_delta(adj) is not None:
        # the traversal plan is built over the packed base only -- it
        # cannot see pending delta rows.  Degrade gracefully to the
        # bit-identical host-loop oracle (which unions the mutable plane
        # per hop) instead of erroring mid-ingest: serving must never
        # fail because a compaction has not folded the backlog yet.  The
        # degradation is counted (``fallbacks``) but invisible in ids
        # and IOMeter.
        note_traversal_fallback(adj)
        from repro.core.neighbor import k_hop
        return k_hop(adj, seeds, hops, meter=meter, engine=engine,
                     include_seeds=include_seeds, filter=list(filts),
                     fused=False)
    col = _kernel_column(adj)
    plan = traversal_plan(adj, engine)
    n = plan.n_value
    seeds = np.unique(np.asarray(seeds, np.int64))
    if seeds.size == 0 or hops <= 0:
        return seeds if include_seeds else np.zeros(0, np.int64)
    n_words = -(-n // 32)
    seed_ids = _seed_vector(seeds, n)
    fw = _filter_words(filts, hops, n_words, n, engine)
    parts = live_partitions(col)
    g = _shard_width(parts) if parts is not None else 1
    if parts is not None:
        # the traversal runs over the partition plane's stacked rows
        # (sharded across the mesh when wide enough) -- count it
        parts.dispatches += 1
    if g > 1:
        from repro.kernels.shard import sharded_khop_entry
        mesh, skey, svoff = plan.sharded_arrays(engine, parts)
        fn = sharded_khop_entry(mesh, engine, n)
        vis, planes, sizes = fn(skey, svoff, jnp.asarray(seed_ids),
                                jnp.asarray(fw))
        vis, planes, sizes = vis[0], planes[0], sizes[0]
    else:
        jkey, jvoff = plan.device(engine)
        fn = K.khop_scan_pallas if engine == "pallas" else R.khop_scan_ref
        vis, planes, sizes = fn(jkey, jvoff, jnp.asarray(seed_ids),
                                jnp.asarray(fw), n_out=n)
    plan.dispatches += 1
    plan.hops_fused += int(hops)
    plan.device_roundtrips += 1  # the one fused dispatch
    plan.last_frontier_sizes = np.asarray(sizes, np.int64)
    cache = live_cache(col)
    if meter is not None or cache is not None:
        # oracle-accounting replay: per-hop frontiers come back once
        planes_host = None
        ids = seeds
        for h in range(hops):
            if ids.size == 0:
                break
            if filts[h] is not None:
                filts[h].charge(meter)
            _charge_expansion(
                adj, col, plan, ids, meter, cache, parts,
                qual=filts[h].qual_range() if filts[h] is not None else None)
            if h + 1 < hops:
                if planes_host is None:
                    planes_host = np.asarray(planes)
                    plan.device_roundtrips += 1
                ids = np.flatnonzero(planes_host[h]).astype(np.int64)
    visited = Frontier.from_dense_plane(np.asarray(vis), n)
    if not include_seeds:
        visited.andnot(Frontier.from_ids(seeds, n))
    return visited.to_ids()


def two_hop_pac(adj_a, adj_b, seeds, target_page_size: int, filt=None,
                meter=None, engine: str = "jax") -> PAC:
    """IC-8's heterogeneous two-hop chain as one fused dispatch.

    Seeds (adjacency A's key space) expand through A into a mid plane
    (A's value space == B's key space), the mid plane expands through B,
    and the predicate bitmap ANDs the result in place; the host receives
    packed bitmap words and builds the merged PAC directly.  Accounting
    replays the staged host path (hop-1 decode, filter charge, hop-2
    batched retrieval) when a meter or LRU is attached.
    """
    col_a, col_b = _kernel_column(adj_a), _kernel_column(adj_b)
    plan_a = traversal_plan(adj_a, engine)
    plan_b = traversal_plan(adj_b, engine)
    if plan_a.n_value != plan_b.n_key:
        raise ValueError("adjacencies do not chain: A's value space "
                         f"({plan_a.n_value}) != B's key space "
                         f"({plan_b.n_key})")
    n_out = plan_b.n_value
    n_words = -(-n_out // 32)
    seeds = np.unique(np.asarray(seeds, np.int64))
    if seeds.size == 0:
        return PAC(target_page_size)
    seed_ids = _seed_vector(seeds, plan_a.n_key)
    if filt is not None:
        if filt.vt.num_vertices != n_out:
            raise ValueError("filter id space mismatch")
        fwords = filt.bitmap(engine)
    else:
        fwords = np.full(n_words, np.uint32(0xFFFFFFFF), np.uint32)
    fn = K.two_hop_pallas if engine == "pallas" else R.two_hop_ref
    mid, words = fn(*plan_a.device(engine), *plan_b.device(engine),
                    jnp.asarray(seed_ids), jnp.asarray(fwords),
                    n_key=plan_a.n_key, n_mid=plan_a.n_value,
                    n_out=n_out, n_words=n_words)
    for plan in (plan_a, plan_b):
        plan.dispatches += 1
        plan.hops_fused += 1
        plan.device_roundtrips += 1
    cache_a, cache_b = live_cache(col_a), live_cache(col_b)
    if meter is not None or cache_a is not None or cache_b is not None:
        _charge_expansion(adj_a, col_a, plan_a, seeds, meter, cache_a,
                          live_partitions(col_a))
        if filt is not None:
            filt.charge(meter)
        created = np.flatnonzero(np.asarray(mid)).astype(np.int64)
        if created.size:
            _charge_expansion(adj_b, col_b, plan_b, created, meter,
                              cache_b, live_partitions(col_b),
                              qual=filt.qual_range()
                              if filt is not None else None)
    return PAC.from_dense_bitmap(np.asarray(words), target_page_size)


def frontier_edge_counts(adj, starts, ends, los, his, meter=None,
                         engine: str = "jax") -> np.ndarray:
    """BI-2's counting expansion: an interval frontier over the key
    space -> per-target **edge counts** (multiplicity preserved -- the
    scatter adds instead of ORing), one fused dispatch.  ``los``/``his``
    are the intervals' already-gathered edge-row ranges, used only to
    replay the oracle's page charges."""
    col = _kernel_column(adj)
    plan = traversal_plan(adj, engine)
    starts = np.asarray(starts, np.int64)
    ends = np.asarray(ends, np.int64)
    i_pad = size_class(len(starts), INTERVAL_CLASS_MIN)
    sentinel = plan.n_key + 1
    s = np.full(i_pad, sentinel, np.int32)
    e = np.full(i_pad, sentinel, np.int32)
    s[:len(starts)] = starts
    e[:len(ends)] = ends
    fn = K.count_hop_pallas if engine == "pallas" else R.count_hop_ref
    counts = fn(*plan.device(engine), jnp.asarray(s), jnp.asarray(e),
                n_key=plan.n_key, n_out=plan.n_value)
    plan.dispatches += 1
    plan.hops_fused += 1
    plan.device_roundtrips += 1
    cache = live_cache(col)
    if meter is not None or cache is not None:
        _charge_ranges(col, plan, los, his, meter, cache,
                       live_partitions(col))
    return np.asarray(counts, np.int64)
