"""Pure-jnp oracle for rle_filter."""
from __future__ import annotations

import jax.numpy as jnp


def rle_to_bitmap_ref(positions, meta, n_words: int):
    positions = positions[0]
    first_value, want, count = meta[0, 0], meta[0, 1], meta[0, 2]
    lanes = jnp.arange(n_words * 32, dtype=jnp.int32)
    run = jnp.searchsorted(positions, lanes, side="right").astype(jnp.int32) - 1
    value = (first_value ^ (run & 1)).astype(jnp.int32)
    bits = (value == want) & (lanes < count)
    b = bits.reshape(n_words, 32).astype(jnp.uint32)
    pows = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return (b * pows[None, :]).sum(axis=1, dtype=jnp.uint32)
