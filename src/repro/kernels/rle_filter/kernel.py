"""RLE interval list -> page-aligned bitmap Pallas kernel (paper §5.1).

Input: the interval position list ``P`` of a label column and the first
run's value.  Output: the label's boolean column as bitmap words, built a
word-tile at a time: each bit position finds its run via an in-VMEM binary
search (``searchsorted``) over ``P`` -- O(log |P|) per lane, lane-parallel
across the tile -- then bits are packed to words with a power-of-two dot.
This keeps the O(|P|) storage advantage while producing the bitmap form
that the selection-pushdown kernels consume.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

WORD_TILE = 64  # words per grid step = 2048 bits


def _rle_kernel(pos_ref, meta_ref, out_ref, *, n_pos):
    wt = pl.program_id(0)
    positions = pos_ref[0]
    first_value = meta_ref[0, 0]      # 1 if first run is True
    want = meta_ref[0, 1]             # filter for label == want
    count = meta_ref[0, 2]            # number of rows
    bit_base = wt * WORD_TILE * 32
    lanes = bit_base + jnp.arange(WORD_TILE * 32, dtype=jnp.int32)
    run = jnp.searchsorted(positions, lanes, side="right").astype(jnp.int32) - 1
    value = (first_value ^ (run & 1)).astype(jnp.int32)
    bits = (value == want) & (lanes < count)
    # pack: [WORD_TILE, 32] x 2^b  (sum of distinct powers == OR)
    b = bits.reshape(WORD_TILE, 32).astype(jnp.uint32)
    pows = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    out_ref[0] = (b * pows[None, :]).sum(axis=1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("n_words", "interpret"))
def rle_to_bitmap_pallas(positions, meta, n_words: int,
                         interpret: bool = True):
    """positions int32[1, n_pos] (padded with ``count``), meta int32[1, 3] =
    (first_value, want, count). Returns uint32[n_words]."""
    assert n_words % WORD_TILE == 0
    n_pos = positions.shape[1]
    kern = functools.partial(_rle_kernel, n_pos=n_pos)
    return pl.pallas_call(
        kern,
        grid=(n_words // WORD_TILE,),
        in_specs=[
            pl.BlockSpec((1, n_pos), lambda wt: (0, 0)),
            pl.BlockSpec((1, 3), lambda wt: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, WORD_TILE), lambda wt: (0, wt)),
        out_shape=jax.ShapeDtypeStruct((1, n_words), jnp.uint32),
        interpret=interpret,
    )(positions, meta)[0]
