"""Jit'd wrapper: RleColumn -> bitmap words (kernel or jnp oracle)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.encoding import RleColumn
from repro.kernels._pad import next_multiple

from . import kernel as K
from . import ref as R


def rle_to_bitmap(col: RleColumn, want: bool = True,
                  use_pallas: bool = True) -> np.ndarray:
    """Whole-column bitmap of ``label == want``; uint32 words."""
    n_words = next_multiple(-(-col.count // 32) or 1, K.WORD_TILE)
    n_pos = next_multiple(col.positions.size, 128)
    pos = np.full((1, n_pos), col.count, np.int32)
    pos[0, :col.positions.size] = col.positions
    meta = np.array([[int(col.first_value), int(want), col.count]], np.int32)
    if use_pallas:
        bm = K.rle_to_bitmap_pallas(jnp.asarray(pos), jnp.asarray(meta),
                                    n_words=n_words)
    else:
        bm = R.rle_to_bitmap_ref(jnp.asarray(pos), jnp.asarray(meta), n_words)
    return np.asarray(bm)[: -(-col.count // 32)]
