"""Pure-jnp oracle for flash_attention."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, causal: bool = True):
    """Naive softmax attention; q/k/v: [bh, seq, d] (fp32 math)."""
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", qf, kf) / (d ** 0.5)
    if causal:
        seq_q, seq_k = s.shape[-2], s.shape[-1]
        mask = (jnp.arange(seq_q)[:, None] >= jnp.arange(seq_k)[None, :])
        s = jnp.where(mask[None], s, -1e30)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", p, vf).astype(q.dtype)
