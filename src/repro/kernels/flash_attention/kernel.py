"""Blockwise online-softmax (flash) attention Pallas kernel.

The LM-side compute hot spot of the framework's training/prefill path.
Grid = (batch*heads, q_blocks, k_blocks) with the running max / denominator
/ accumulator carried in VMEM scratch across the innermost (k) grid
dimension -- the canonical TPU flash-attention schedule: K/V blocks stream
through VMEM while the MXU consumes (block_q x d) @ (d x block_k) tiles.

Supports causal masking (blocks entirely above the diagonal are skipped
via ``pl.when``) and GQA is handled by the caller (K/V heads broadcast).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale, causal, block_q, block_k):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    should_run = True
    if causal:
        # skip blocks strictly above the diagonal
        should_run = qi * block_q + block_q - 1 >= ki * block_k

    @pl.when(should_run)
    def _body():
        q = q_ref[0].astype(jnp.float32)          # [bq, d]
        k = k_ref[0].astype(jnp.float32)          # [bk, d]
        v = v_ref[0].astype(jnp.float32)          # [bk, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [bq, bk]
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_cur)
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, causal: bool = True,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = True):
    """q/k/v: [bh, seq, d] (same seq for q and kv). Returns [bh, seq, d]."""
    bh, seq_q, d = q.shape
    seq_k = k.shape[1]
    block_q = min(block_q, seq_q)
    block_k = min(block_k, seq_k)
    assert seq_q % block_q == 0 and seq_k % block_k == 0
    grid = (bh, seq_q // block_q, seq_k // block_k)
    scale = 1.0 / (d ** 0.5)
    kern = functools.partial(_flash_kernel, scale=scale, causal=causal,
                             block_q=block_q, block_k=block_k)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
