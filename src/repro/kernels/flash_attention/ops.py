"""Jit'd wrapper exposing flash attention over [batch, heads, seq, d]."""
from __future__ import annotations

import jax.numpy as jnp

from . import kernel as K
from . import ref as R


def mha(q, k, v, causal: bool = True, use_pallas: bool = True,
        block_q: int = K.DEFAULT_BLOCK_Q, block_k: int = K.DEFAULT_BLOCK_K,
        interpret: bool = True):
    """q: [b, h, sq, d]; k/v: [b, h_kv, sk, d] (h_kv divides h: GQA)."""
    b, h, sq, d = q.shape
    h_kv = k.shape[1]
    if h_kv != h:
        rep = h // h_kv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * h, -1, d)
    vf = v.reshape(b * h, -1, d)
    if use_pallas:
        o = K.flash_attention(qf, kf, vf, causal=causal, block_q=block_q,
                              block_k=block_k, interpret=interpret)
    else:
        o = R.attention_ref(qf, kf, vf, causal=causal)
    return o.reshape(b, h, sq, d)
