"""Sharded dispatch entries for the partition plane.

The device-resident fused kernels of ``pac_decode`` / ``label_filter``
run unchanged on every shard of a 1-D ``("part",)`` device mesh: the
partitioned column's stacked unpack plan is sharded partition-major
across the mesh (``PartitionedColumn.device_plan``), the host buckets
each dispatch's page-index / row-position vectors per device into one
``staged`` matrix (row ``i`` = device ``i``'s ``[idx | gidx | total]``
vector, the same one-put layout as the monolithic resident path --
under a pushed-down predicate those vectors arrive already statistics-
pruned: partition hulls first, then per-page zone maps, so pruned
pages never appear in any shard's staged block), and
``shard_map`` runs the per-shard body -- gather, decode, sorted-scatter
bitmap, optional resident-filter AND -- on every device concurrently.
Each shard emits a full ``[n_words]`` bitmap plane over the target id
space; the host OR-merges the ``g`` planes into one PAC (partitions may
contribute the same target id, so the merge is OR, not concat).

Entries are built once per static configuration and memoized
(``lru_cache`` keyed on mesh + shapes), so steady-state serving
dispatches hit the jit cache exactly like the monolithic path --
``note_trace`` fires only on a (re)trace.

``check_rep=False`` is required: pallas_call has no replication rule
under shard_map (the kernels never cross shards, so replication
checking has nothing to verify anyway).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.kernels._pad import note_trace

_PART = P("part")
_REPL = P()


@functools.lru_cache(maxsize=None)
def sharded_fused_entry(mesh, engine: str, page_size: int, n_words: int,
                        p_pad: int, want_ids: bool, filtered: bool):
    """Jitted sharded fused decode->bitmap entry (memoized per config).

    Returns a callable ``(first, pos, mind, packed, staged[, fwords]) ->
    words [g, n_words]`` (plus ``ids [g, p_pad, page_size]`` under
    ``want_ids``).  The four plan arrays are the partition-major stacked
    device plan (sharded ``P('part')``); ``staged`` is ``int32[g, L]``
    with device ``i``'s block-local ``[idx | gidx | total]`` vector in
    row ``i``; ``fwords`` (``filtered`` only) is the predicate's
    device-resident bitmap plane, replicated across the mesh so every
    shard ANDs it locally -- no label bytes move per dispatch.
    """
    from repro.kernels.label_filter import kernel as LK
    from repro.kernels.label_filter import ref as LR
    from repro.kernels.pac_decode import kernel as K
    from repro.kernels.pac_decode import ref as R

    if filtered:
        inner = (LK.fused_gather_decode_filter_bitmap_batch
                 if engine == "pallas" else LR.fused_gather_filter_batch_ref)
    else:
        inner = (K.fused_gather_decode_bitmap_batch
                 if engine == "pallas" else R.fused_gather_batch_ref)

    def body(first, pos, mind, packed, staged, *fwords):
        note_trace("sharded_fused")
        winit = jnp.zeros((n_words,), jnp.uint32)
        out = inner(first, pos, mind, packed, staged[0], *fwords, winit,
                    page_size=page_size, n_words=n_words, p_pad=p_pad,
                    want_ids=want_ids)
        if want_ids:
            words, ids = out
            return words[None], ids[None]
        return out[None]

    in_specs = (_PART,) * 5 + ((_REPL,) if filtered else ())
    out_specs = (_PART, _PART) if want_ids else _PART
    return jax.jit(shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=False))


@functools.lru_cache(maxsize=None)
def sharded_khop_entry(mesh, engine: str, n_out: int):
    """Jitted sharded fused k-hop (memoized per mesh + id space).

    ``(skey_sorted, svoff, seed_ids, filt_words) -> (visited [g, n_out],
    hop_planes [g, hops, n_out], hop_sizes [g, hops])``: the traversal
    plan's per-partition rank layouts are stacked partition-major and
    sharded ``P('part')`` (``TraversalPlan.sharded_arrays``); seed ids
    and the per-hop predicate words are replicated.  Each hop every
    shard rank-expands its partitions' rows into a full-size frontier
    plane (padding keys select nothing), an all-reduce ``pmax`` merges
    the planes across the mesh (a vertex may be reached via several
    partitions), and the filter-AND / visited-ANDNOT / scan step run
    replicated -- so the hop-to-hop frontier never leaves the device
    mesh.  Every shard returns identical planes; the host takes row 0.
    """
    from repro.kernels.traversal import kernel as TK
    from repro.kernels.traversal import ref as TR

    def body(skey_sorted, svoff, seed_ids, filt_words):
        note_trace("sharded_khop")
        f0 = TR._seed_plane(seed_ids, n_out)

        def hop(carry, fw):
            frontier, visited = carry
            if engine == "pallas":
                plane = TK._expand_pallas(skey_sorted, svoff, frontier,
                                          n_out=n_out)
            else:
                plane = TR.expand_plane_ref(skey_sorted, svoff, frontier)
            plane = jax.lax.pmax(plane, "part")
            nxt = plane * TR._filter_bits(fw, n_out) * (1 - visited)
            return (nxt, visited + nxt), nxt

        (_, visited), planes = jax.lax.scan(hop, (f0, f0), filt_words)
        return visited[None], planes[None], planes.sum(axis=1)[None]

    return jax.jit(shard_map(body, mesh=mesh,
                             in_specs=(_PART, _PART, _REPL, _REPL),
                             out_specs=(_PART, _PART, _PART),
                             check_rep=False))


@functools.lru_cache(maxsize=None)
def sharded_decode_entry(mesh, engine: str, page_size: int, p_pad: int):
    """Jitted sharded page-matrix decode (the non-fused batched path).

    ``(first, pos, mind, packed, idx [g, p_pad]) ->
    ids [g, p_pad, page_size]``: each shard gathers its block-local page
    indices from its partitions' plan rows and decodes them; the host
    reassembles the global page matrix from the per-device slices.
    """
    from repro.kernels.pac_decode import kernel as K
    from repro.kernels.pac_decode import ref as R

    inner = (K.gather_decode_pallas if engine == "pallas"
             else R.gather_decode_ref)

    def body(first, pos, mind, packed, idx):
        note_trace("sharded_decode")
        return inner(first, pos, mind, packed, idx[0],
                     page_size=page_size)[None]

    return jax.jit(shard_map(body, mesh=mesh, in_specs=(_PART,) * 5,
                             out_specs=_PART, check_rep=False))
