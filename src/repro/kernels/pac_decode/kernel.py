"""Fused delta-unpack -> prefix-scan -> page-bitmap Pallas kernels.

TPU adaptation of the paper's BMI/SIMD decoding strategy (§4.3).  The CPU
version breaks the serial delta dependency with PEXT-compacted bit-shift
encodings; the TPU version breaks it with a **vectorized in-VMEM prefix
scan** after a lane-parallel variable-shift unpack, and builds PAC bitmaps
by lane-parallel word compares instead of serial bit appends.  The fusion
insight is preserved: the decoded ID list never leaves VMEM in the fused
kernel; only page bitmaps are written to HBM.

Power-of-two miniblock bit widths guarantee no packed value straddles a
32-bit word, so the unpack is a single gather + variable shift per lane --
the same alignment argument the paper uses for its SIMD path.

Kernels:
  * ``delta_decode_kernel``  -- decode a batch of delta pages to int32 IDs.
  * ``bitmap_kernel``        -- sorted IDs -> bitmap words over a target
                                range, OR-accumulated across ID tiles.
  * ``fused_decode_bitmap``  -- both, without materializing IDs in HBM
                                (single page-aligned range).
  * ``fused_decode_bitmap_batch`` -- the batched retrieval plane's fused
                                entry: an arbitrary deduplicated page list
                                + merged range bounds -> one dense target
                                bitmap, in one dispatch.  Unsorted /
                                duplicated IDs (a page interleaves many
                                vertices' neighbor lists) are handled by an
                                in-kernel sort + rank lookup, which is
                                exact under any multiplicity (sum==OR
                                tricks are not); a TPU build would use a
                                bitonic in-VMEM sort and the word-tiled
                                compare of ``_bitmap_tile``.
  * ``gather_decode_pallas`` / ``fused_gather_decode_bitmap_batch`` --
                                the device-resident entries (PR 4): the
                                whole column's per-delta unpack plan
                                (``PackedPages.device_plan``) lives on
                                device; dispatches ship only an int32
                                page-index vector, gather rows with
                                ``jnp.take``, decode with one
                                ``take_along_axis`` + cumsum, and build
                                the bitmap with the O(t) sorted scatter
                                (``_bitmap_scatter``) instead of the
                                O(num_targets) rank lookup.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.encoding import (DEFAULT_PAGE_SIZE, MINIBLOCK, POS_BW_MASK,
                                 POS_SHIFT_SHIFT, POS_WIDX_SHIFT)
from repro.kernels._pad import note_trace


def _unpack_and_scan(first, min_deltas, bit_widths, word_offsets, packed,
                     count, page_size):
    """Shared in-kernel body: packed miniblocks -> decoded int32 IDs.

    All inputs are the per-page arrays (leading page axis already sliced
    away by the BlockSpec).  Returns ids[page_size] (positions >= count
    hold the last valid id, keeping downstream compares harmless).
    """
    n_deltas = page_size - 1
    idx = jnp.arange(n_deltas, dtype=jnp.int32)
    mini = idx // MINIBLOCK
    within = idx % MINIBLOCK
    bw = jnp.take(bit_widths, mini).astype(jnp.int32)
    woff = jnp.take(word_offsets, mini)
    # lane-parallel unpack: value i of a miniblock lives at bit
    # (within * bw) of the miniblock's word region -- never straddles words
    bit_pos = within * bw
    word_idx = woff + bit_pos // 32
    shift = (bit_pos % 32).astype(jnp.uint32)
    words = jnp.take(packed, word_idx)
    mask = jnp.where(bw >= 32, jnp.uint32(0xFFFFFFFF),
                     (jnp.uint32(1) << bw.astype(jnp.uint32)) - 1)
    resid = ((words >> shift) & mask).astype(jnp.int32)
    resid = jnp.where(bw == 0, 0, resid)
    deltas = resid + jnp.take(min_deltas, mini)
    deltas = jnp.where(idx < count - 1, deltas, 0)
    # the serial dependency becomes a parallel scan (TPU analogue of PEXT)
    ids = first + jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(deltas)])
    return ids


def _decode_kernel(first_ref, mind_ref, bw_ref, woff_ref, packed_ref,
                   count_ref, out_ref, *, page_size):
    ids = _unpack_and_scan(
        first_ref[0, 0], mind_ref[0], bw_ref[0], woff_ref[0],
        packed_ref[0], count_ref[0, 0], page_size)
    out_ref[0] = ids


@functools.partial(jax.jit, static_argnames=("page_size", "interpret"))
def delta_decode_pallas(first, min_deltas, bit_widths, word_offsets, packed,
                        counts, page_size: int = DEFAULT_PAGE_SIZE,
                        interpret: bool = True):
    """Decode a batch of pages.

    Shapes: first/counts int32[n,1]; min_deltas/bit_widths/word_offsets
    int32[n, n_mini]; packed uint32[n, max_words].  Returns int32[n, page_size].
    """
    note_trace("delta_decode_pallas")
    n, n_mini = min_deltas.shape
    max_words = packed.shape[1]
    kern = functools.partial(_decode_kernel, page_size=page_size)
    return pl.pallas_call(
        kern,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, n_mini), lambda i: (i, 0)),
            pl.BlockSpec((1, n_mini), lambda i: (i, 0)),
            pl.BlockSpec((1, n_mini), lambda i: (i, 0)),
            pl.BlockSpec((1, max_words), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, page_size), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, page_size), jnp.int32),
        interpret=interpret,
    )(first, min_deltas, bit_widths, word_offsets, packed, counts)


# --------------------------------------------------------------------------
# bitmap construction: sorted ids -> OR-accumulated bitmap words
# --------------------------------------------------------------------------

ID_TILE = 512     # ids per grid step
WORD_TILE = 64    # uint32 words per grid step (= 2048 bits = one page)


def _bitmap_tile(ids, valid, word_base):
    """Bitmap words for one (id tile x word tile): lane-parallel compare.

    ``sum`` of distinct powers of two == OR because ids are sorted and
    de-duplicated by ``valid`` -- each (word, bit) contributes once.
    """
    rel_word = (ids >> 5) - word_base                       # [ID_TILE]
    bit = (jnp.uint32(1) << (ids & 31).astype(jnp.uint32))  # [ID_TILE]
    cols = jnp.arange(WORD_TILE, dtype=jnp.int32)           # [WORD_TILE]
    hit = (rel_word[:, None] == cols[None, :]) & valid[:, None]
    contrib = jnp.where(hit, bit[:, None], jnp.uint32(0))
    return contrib.sum(axis=0, dtype=jnp.uint32)


def _bitmap_kernel(ids_ref, count_ref, base_ref, out_ref):
    it = pl.program_id(0)       # id-tile index (accumulation axis)
    wt = pl.program_id(1)       # word-tile index

    @pl.when(it == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ids = ids_ref[0]
    count = count_ref[0, 0]
    base = base_ref[0, 0]
    gidx = it * ID_TILE + jnp.arange(ID_TILE, dtype=jnp.int32)
    valid = gidx < count
    # sorted input: drop duplicates so sum == OR
    prev = jnp.concatenate([ids[:1] - 1, ids[:-1]])
    valid = valid & ((ids != prev) | (gidx == 0))
    word_base = base // 32 + wt * WORD_TILE
    out_ref[0] |= _bitmap_tile(ids, valid, word_base)


@functools.partial(jax.jit, static_argnames=("n_words", "interpret"))
def bitmap_pallas(ids, count, base, n_words: int, interpret: bool = True):
    """Sorted int32 ids -> uint32[n_words] bitmap for range starting at
    ``base`` (bit j of word w <=> id == base + 32*w + j).

    ``ids`` is padded to a multiple of ID_TILE; ``n_words`` to WORD_TILE.
    """
    note_trace("bitmap_pallas")
    n_ids = ids.shape[0]
    assert n_ids % ID_TILE == 0 and n_words % WORD_TILE == 0
    grid = (n_ids // ID_TILE, n_words // WORD_TILE)
    return pl.pallas_call(
        _bitmap_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, ID_TILE), lambda it, wt: (0, it)),
            pl.BlockSpec((1, 1), lambda it, wt: (0, 0)),
            pl.BlockSpec((1, 1), lambda it, wt: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, WORD_TILE), lambda it, wt: (0, wt)),
        out_shape=jax.ShapeDtypeStruct((1, n_words), jnp.uint32),
        interpret=interpret,
    )(ids.reshape(1, -1), count.reshape(1, 1), base.reshape(1, 1))[0]


# --------------------------------------------------------------------------
# fused: delta pages -> bitmap, IDs never leave VMEM
# --------------------------------------------------------------------------

def _fused_kernel(first_ref, mind_ref, bw_ref, woff_ref, packed_ref,
                  count_ref, base_ref, out_ref, *, page_size, words_out):
    pt = pl.program_id(0)   # page index (accumulation axis)

    @pl.when(pt == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ids = _unpack_and_scan(
        first_ref[0, 0], mind_ref[0], bw_ref[0], woff_ref[0],
        packed_ref[0], count_ref[0, 0], page_size)
    count = count_ref[0, 0]
    gidx = jnp.arange(page_size, dtype=jnp.int32)
    valid = gidx < count
    prev = jnp.concatenate([ids[:1] - 1, ids[:-1]])
    valid = valid & ((ids != prev) | (gidx == 0))
    base = base_ref[0, 0]
    word_base = base // 32
    rel_word = (ids >> 5) - word_base
    bit = (jnp.uint32(1) << (ids & 31).astype(jnp.uint32))
    cols = jnp.arange(words_out, dtype=jnp.int32)
    hit = (rel_word[:, None] == cols[None, :]) & valid[:, None]
    contrib = jnp.where(hit, bit[:, None], jnp.uint32(0))
    out_ref[0] |= contrib.sum(axis=0, dtype=jnp.uint32)


def _unpack_and_scan_batch(first, min_deltas, bit_widths, word_offsets,
                           packed, counts, page_size):
    """All pages' packed miniblocks -> decoded int32 IDs, one shot.

    Batched (leading page axis kept) version of :func:`_unpack_and_scan`:
    every step is an elementwise op, a row-gather, or a row-wise cumsum,
    so the whole page stack decodes in a single vectorized pass.  Returns
    ``ids[n_pages, page_size]`` (positions >= count hold the running last
    id -- downstream consumers mask by count / row validity).
    """
    n = min_deltas.shape[0]
    n_deltas = page_size - 1
    idx = jnp.arange(n_deltas, dtype=jnp.int32)
    mini = idx // MINIBLOCK
    within = idx % MINIBLOCK
    bw = jnp.take(bit_widths, mini, axis=1).astype(jnp.int32)     # [n, D]
    woff = jnp.take(word_offsets, mini, axis=1)                   # [n, D]
    bit_pos = within[None, :] * bw
    word_idx = woff + bit_pos // 32
    shift = (bit_pos % 32).astype(jnp.uint32)
    words = jnp.take_along_axis(packed, word_idx, axis=1,
                                mode="clip")
    mask = jnp.where(bw >= 32, jnp.uint32(0xFFFFFFFF),
                     (jnp.uint32(1) << bw.astype(jnp.uint32)) - 1)
    resid = ((words >> shift) & mask).astype(jnp.int32)
    resid = jnp.where(bw == 0, 0, resid)
    deltas = resid + jnp.take(min_deltas, mini, axis=1)
    deltas = jnp.where(idx[None, :] < counts - 1, deltas, 0)
    return first + jnp.concatenate(
        [jnp.zeros((n, 1), jnp.int32), jnp.cumsum(deltas, axis=1)], axis=1)


def _bitmap_from_gather(ids, gidx, gcount, page_size, n_words):
    """Shared fused tail: decoded page matrix -> dense target bitmap.

    ``gidx`` holds the flat (block_row * page_size + offset) position of
    every requested row (zero-padded past ``gcount``) -- the host knows
    the requested-row *positions* without ever seeing the decoded ids.
    The requested ids are gathered, sorted with an out-of-range sentinel
    for the padding, and bit ``t`` of the output is set iff some sorted id
    equals ``t`` (rank lookup) -- exact under duplicate and unsorted ids,
    and O(total + targets) instead of a per-target scatter (slow on every
    backend here) or a full-page-matrix pass.
    """
    n_slots = n_words * 32
    flat = jnp.take(ids.reshape(-1), gidx, mode="clip")
    k = jnp.arange(gidx.shape[0], dtype=jnp.int32)
    s = jnp.sort(jnp.where(k < gcount, flat, n_slots))
    targets = jnp.arange(n_slots, dtype=jnp.int32)
    pos = jnp.searchsorted(s, targets, side="left")
    hit = jnp.take(s, pos, mode="clip") == targets
    bits = hit.astype(jnp.uint32).reshape(n_words, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, :]
    return (bits << shifts).sum(axis=1, dtype=jnp.uint32)


def _fused_batch_kernel(first_ref, mind_ref, bw_ref, woff_ref, packed_ref,
                        count_ref, cached_ref, gidx_ref, gcount_ref,
                        words_ref, ids_ref, *, page_size, n_words):
    ids = _unpack_and_scan_batch(
        first_ref[...], mind_ref[...], bw_ref[...], woff_ref[...],
        packed_ref[...], count_ref[...], page_size)
    ids_ref[...] = ids
    full = jnp.concatenate([ids, cached_ref[...]], axis=0)
    words_ref[...] = _bitmap_from_gather(full, gidx_ref[...],
                                         gcount_ref[0, 0], page_size,
                                         n_words)


@functools.partial(jax.jit, static_argnames=("page_size", "n_words",
                                             "interpret"))
def fused_decode_bitmap_batch(first, min_deltas, bit_widths, word_offsets,
                              packed, counts, cached, gidx, gcount,
                              page_size: int, n_words: int,
                              interpret: bool = True):
    """Deduplicated page list + requested-row positions -> target bitmap.

    One dispatch for the whole batch: batched unpack->scan decode of the
    LRU-**miss** pages (the only pages shipped packed), then bitmap
    construction over the target id space [0, 32 * n_words) from the
    ``gcount`` requested rows addressed by ``gidx`` (int32[t], flat
    ``row * page_size + offset`` positions into the [miss | cached] row
    order, zero-padded).  ``cached`` (int32[c, page_size]) carries the
    decoded rows of the LRU-hit pages straight from the host cache --
    hits skip the on-device unpack entirely instead of being re-decoded.
    Returns ``(words, ids)``: ``uint32[n_words]`` plus the decoded
    miss-page matrix ``int32[n, page_size]`` (a by-product of the decode
    -- callers feed it to the decoded-page LRU without a second dispatch;
    they simply skip the host transfer when no cache is attached).
    """
    note_trace("fused_decode_bitmap_batch")
    n, n_mini = min_deltas.shape
    max_words = packed.shape[1]
    c = cached.shape[0]
    t = gidx.shape[0]
    kern = functools.partial(_fused_batch_kernel, page_size=page_size,
                             n_words=n_words)
    return pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
            pl.BlockSpec((n, n_mini), lambda i: (0, 0)),
            pl.BlockSpec((n, n_mini), lambda i: (0, 0)),
            pl.BlockSpec((n, n_mini), lambda i: (0, 0)),
            pl.BlockSpec((n, max_words), lambda i: (0, 0)),
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
            pl.BlockSpec((c, page_size), lambda i: (0, 0)),
            pl.BlockSpec((t,), lambda i: (0,)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((n_words,), lambda i: (0,)),
            pl.BlockSpec((n, page_size), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_words,), jnp.uint32),
            jax.ShapeDtypeStruct((n, page_size), jnp.int32),
        ],
        interpret=interpret,
    )(first, min_deltas, bit_widths, word_offsets, packed, counts, cached,
      gidx, gcount)


# --------------------------------------------------------------------------
# device-resident entries: whole-column unpack plan + on-device gather
# --------------------------------------------------------------------------

def _gather_rows(idx, *arrays):
    """On-device row gather of resident column arrays by page index.

    ``idx`` is int32[p_pad] (pow2 size-classed, clip-padded with 0); the
    arrays stay on device across dispatches, so this gather is the only
    per-dispatch data movement the packed column requires -- the host
    ships the index vector, never page bytes.
    """
    return tuple(jnp.take(a, idx, axis=0, mode="clip") for a in arrays)


def _row_cumsum(a, chunk=128):
    """Row-wise inclusive prefix sum as a two-level blocked scan.

    ``jnp.cumsum`` lowers to an O(log d)-pass associative scan over the
    full row; scanning ``chunk``-wide blocks and then the per-block
    carries touches the data ~half as many times (measurably ~2x faster
    on the CPU backend at the decode plane's [pages, page_size] shapes).
    """
    n, d = a.shape
    pad = (-d) % chunk
    ap = jnp.pad(a, ((0, 0), (0, pad))).reshape(n, -1, chunk)
    within = jnp.cumsum(ap, axis=2)
    carry = jnp.cumsum(within[:, :, -1], axis=1)
    carry = jnp.concatenate(
        [jnp.zeros((n, 1), a.dtype), carry[:, :-1]], axis=1)
    return (within + carry[:, :, None]).reshape(n, -1)[:, :d]


def _decode_plan_rows(first, pos, mind, packed):
    """Decode gathered unpack-plan rows (``PackedPages.unpack_plan``).

    The per-delta expansion folded every query-independent decision
    (miniblock lookup, zero-width handling, count clamping) into the
    plan at column-build time: ``pos`` packs word index / shift /
    effective bit width into one int32 lane, so the in-dispatch decode
    is one gather + a few elementwise ops + the prefix scan.  Positions
    >= count hold the running last id, exactly like
    :func:`_unpack_and_scan_batch`.
    """
    word_idx = pos >> POS_WIDX_SHIFT
    shift = ((pos >> POS_SHIFT_SHIFT) & 31).astype(jnp.uint32)
    bw = (pos & POS_BW_MASK).astype(jnp.uint32)
    mask = jnp.where(bw >= 32, jnp.uint32(0xFFFFFFFF),
                     (jnp.uint32(1) << bw) - 1)
    words = jnp.take_along_axis(packed, word_idx, axis=1, mode="clip")
    resid = ((words >> shift) & mask).astype(jnp.int32)
    deltas = resid + mind
    n = first.shape[0]
    return first + jnp.concatenate(
        [jnp.zeros((n, 1), jnp.int32), _row_cumsum(deltas)], axis=1)


def _bitmap_scatter(ids, gidx, gcount, n_words):
    """Resident fused tail: requested rows -> bitmap, O(t log t).

    Sorts the ``gcount`` requested ids (padding sorts past the range
    sentinel), drops duplicates via the sorted-neighbor compare, and
    scatter-ORs one bit per distinct id (``sum`` of distinct powers of
    two == OR).  Replaces the O(num_targets) rank lookup of
    :func:`_bitmap_from_gather` on the resident path -- the dense
    searchsorted over every target id was a fixed per-dispatch cost the
    batch size never amortized.  A TPU build would keep the rank lookup
    (VMEM scatter is lane-hostile); on CPU/interpret the scatter wins
    and both produce identical words.
    """
    n_slots = n_words * 32
    flat = jnp.take(ids.reshape(-1), gidx, mode="clip")
    k = jnp.arange(gidx.shape[0], dtype=jnp.int32)
    s = jnp.sort(jnp.where(k < gcount, flat, n_slots))
    prev = jnp.concatenate([s[:1] - 1, s[:-1]])
    valid = (s != prev) & (s >= 0) & (s < n_slots)
    word = s >> 5
    bit = jnp.uint32(1) << (s & 31).astype(jnp.uint32)
    out = jnp.zeros(n_words, jnp.uint32)
    return out.at[jnp.where(valid, word, 0)].add(
        jnp.where(valid, bit, jnp.uint32(0)), mode="drop")


def _gather_decode_kernel(first_ref, pos_ref, mind_ref, packed_ref, out_ref):
    out_ref[...] = _decode_plan_rows(
        first_ref[...], pos_ref[...], mind_ref[...], packed_ref[...])


@functools.partial(jax.jit, static_argnames=("page_size", "interpret"))
def gather_decode_pallas(first, pos, mind, packed, idx,
                         page_size: int = DEFAULT_PAGE_SIZE,
                         interpret: bool = True):
    """Decode an arbitrary page subset of a device-resident column.

    Inputs are the column's device unpack plan
    (``PackedPages.device_plan`` -- whole-column arrays, constant shapes
    across dispatches); ``idx`` selects the pages.  Returns
    ``int32[p_pad, page_size]`` in ``idx`` order (clip-padded rows decode
    page 0 and are sliced off by the caller).
    """
    note_trace("gather_decode")
    g = _gather_rows(idx, first, pos, mind, packed)
    n = idx.shape[0]
    d = pos.shape[1]
    max_words = packed.shape[1]
    return pl.pallas_call(
        _gather_decode_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((n, max_words), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n, page_size), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, page_size), jnp.int32),
        interpret=interpret,
    )(*g)


def _fused_gather_kernel(first_ref, pos_ref, mind_ref, packed_ref,
                         gidx_ref, gcount_ref, winit_ref,
                         words_ref, ids_ref=None, *, page_size, n_words):
    del winit_ref  # aliased storage for words_ref; fully overwritten
    ids = _decode_plan_rows(
        first_ref[...], pos_ref[...], mind_ref[...], packed_ref[...])
    if ids_ref is not None:
        ids_ref[...] = ids
    words_ref[...] = _bitmap_scatter(ids, gidx_ref[...], gcount_ref[0, 0],
                                     n_words)


def _split_staged(staged, p_pad):
    """Split the one-put staging vector ``[idx | gidx | total]`` on
    device: three host->device transfers per dispatch become one."""
    idx = staged[:p_pad]
    gidx = staged[p_pad:-1]
    gcount = staged[-1:].reshape(1, 1)
    return idx, gidx, gcount


@functools.partial(jax.jit, static_argnames=("page_size", "n_words", "p_pad",
                                             "want_ids", "interpret"))
def fused_gather_decode_bitmap_batch(first, pos, mind, packed, staged,
                                     words_init,
                                     page_size: int, n_words: int,
                                     p_pad: int,
                                     want_ids: bool = True,
                                     interpret: bool = True):
    """Device-resident fused path: page indices -> target bitmap.

    Same bitmap contract as :func:`fused_decode_bitmap_batch`, but the
    packed column lives on device as its unpack plan
    (``PackedPages.device_plan``): the dispatch ships only ``staged``,
    one int32 vector packing ``idx`` (``p_pad`` clip-padded page
    indices), ``gidx`` (requested-row positions over the gathered row
    order, i.e. ``base_of_page[i] == i``), and the trailing range count
    -- one host->device put per dispatch.  There is no ``cached`` input
    -- with the column resident, re-decoding LRU-hit pages on device is
    cheaper than shipping their decoded rows across PCIe, and the
    IOMeter convention is untouched (misses charged host-side, hits
    free).  The bitmap tail is the O(t) sorted scatter
    (:func:`_bitmap_scatter`) instead of the O(num_targets) rank
    lookup.  ``words_init`` (uint32[n_words]) is aliased to the bitmap
    output, so serving ticks can hand the previous tick's plane back in
    and reuse the device buffer instead of allocating per dispatch.

    With ``want_ids`` the decoded page matrix is emitted as a second
    output (rows follow ``idx`` order -- miss backfill indexes by
    position in the page list) and ``(words, ids)`` is returned.  The
    matrix is only ever needed to backfill the decoded-page LRU, so
    callers with no cache attached -- and warm steady-state ticks with
    zero misses -- pass ``want_ids=False``: the ids then never leave
    VMEM (the original fusion contract) and the kernel skips
    materializing page_size * n_pages ints per dispatch, which is a
    large share of its fixed cost.  Returns ``words`` alone in that
    case.
    """
    note_trace("fused_gather_decode_bitmap_batch")
    idx, gidx, gcount = _split_staged(staged, p_pad)
    g = _gather_rows(idx, first, pos, mind, packed)
    n = idx.shape[0]
    d = pos.shape[1]
    max_words = packed.shape[1]
    t = gidx.shape[0]
    kern = functools.partial(_fused_gather_kernel, page_size=page_size,
                             n_words=n_words)
    out_specs = [pl.BlockSpec((n_words,), lambda i: (0,))]
    out_shape = [jax.ShapeDtypeStruct((n_words,), jnp.uint32)]
    if want_ids:
        out_specs.append(pl.BlockSpec((n, page_size), lambda i: (0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((n, page_size), jnp.int32))
    out = pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((n, max_words), lambda i: (0, 0)),
            pl.BlockSpec((t,), lambda i: (0,)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((n_words,), lambda i: (0,)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        input_output_aliases={6: 0},
        interpret=interpret,
    )(*g, gidx, gcount, words_init)
    return tuple(out) if want_ids else out[0]


@functools.partial(jax.jit,
                   static_argnames=("page_size", "words_out", "interpret"))
def fused_decode_bitmap(first, min_deltas, bit_widths, word_offsets, packed,
                        counts, base, page_size: int, words_out: int,
                        interpret: bool = True):
    """All pages' deltas -> one uint32[words_out] bitmap (base-relative)."""
    n, n_mini = min_deltas.shape
    max_words = packed.shape[1]
    kern = functools.partial(_fused_kernel, page_size=page_size,
                             words_out=words_out)
    return pl.pallas_call(
        kern,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, n_mini), lambda i: (i, 0)),
            pl.BlockSpec((1, n_mini), lambda i: (i, 0)),
            pl.BlockSpec((1, n_mini), lambda i: (i, 0)),
            pl.BlockSpec((1, max_words), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, words_out), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, words_out), jnp.uint32),
        interpret=interpret,
    )(first, min_deltas, bit_widths, word_offsets, packed, counts,
      base.reshape(1, 1))[0]
