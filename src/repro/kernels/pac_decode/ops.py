"""Jit'd wrappers + storage-plane integration for pac_decode kernels."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import numpy as np
import jax.numpy as jnp

from repro.core.encoding import DEFAULT_PAGE_SIZE, MINIBLOCK, DeltaColumn
from repro.core.pac import PAC

from . import kernel as K
from . import ref as R


def _next_multiple(x: int, m: int) -> int:
    return -(-x // m) * m


def pack_pages(col: DeltaColumn, p0: int, p1: int
               ) -> Tuple[np.ndarray, ...]:
    """Stack pages [p0, p1) of a DeltaColumn into fixed-shape batch arrays.

    Pads miniblock metadata to ``page_size // MINIBLOCK`` and packed words
    to the worst case (bw=32).  This is exactly the VMEM layout the kernel
    tiles over.
    """
    ps = col.page_size
    n_mini = ps // MINIBLOCK
    max_words = ps  # worst case: 32-bit deltas -> one word per delta
    pages = col.pages[p0:p1]
    n = len(pages)
    first = np.zeros((n, 1), np.int32)
    counts = np.zeros((n, 1), np.int32)
    mind = np.zeros((n, n_mini), np.int32)
    bw = np.zeros((n, n_mini), np.int32)
    woff = np.zeros((n, n_mini), np.int32)
    packed = np.zeros((n, max_words), np.uint32)
    for i, pg in enumerate(pages):
        first[i, 0] = pg.first_value
        counts[i, 0] = pg.count
        k = len(pg.min_deltas)
        mind[i, :k] = pg.min_deltas
        bw[i, :k] = pg.bit_widths
        woff[i, :k] = pg.word_offsets
        packed[i, :len(pg.packed)] = pg.packed
    return first, mind, bw, woff, packed, counts


def decode_pages(col: DeltaColumn, p0: int, p1: int,
                 use_pallas: bool = True) -> np.ndarray:
    """Decode pages [p0, p1) via the kernel (or jnp ref); returns flat ids."""
    ps = col.page_size
    args = pack_pages(col, p0, p1)
    if use_pallas:
        ids = K.delta_decode_pallas(*[jnp.asarray(a) for a in args],
                                    page_size=ps)
    else:
        ids = R.decode_pages_ref(*[jnp.asarray(a) for a in args],
                                 page_size=ps)
    ids = np.asarray(ids)
    counts = args[5][:, 0]
    return np.concatenate([ids[i, :counts[i]] for i in range(len(counts))])


def retrieve_pac(col: DeltaColumn, lo: int, hi: int, target_page_size: int,
                 meter=None, use_pallas: bool = True) -> PAC:
    """Kernel-engine neighbor retrieval: rows [lo, hi) -> PAC.

    Charges the same page bytes as the numpy path (the I/O plane is
    identical; only the decode compute engine differs).
    """
    if hi <= lo:
        return PAC(target_page_size)
    ps = col.page_size
    p0, p1 = lo // ps, (hi - 1) // ps + 1
    if meter is not None:
        meter.record(sum(col.pages[p].nbytes() for p in range(p0, p1)), 1)
    flat = decode_pages(col, p0, p1, use_pallas)
    ids = flat[lo - p0 * ps: hi - p0 * ps]
    return PAC.from_ids(ids, target_page_size)


def decode_range_to_bitmap(col: DeltaColumn, lo: int, hi: int,
                           base: int, n_words: int,
                           use_pallas: bool = True) -> np.ndarray:
    """Fused path: delta rows [lo, hi) -> one uint32 bitmap over
    [base, base + 32 * n_words). ``base`` must be 32-aligned.

    The row mask is applied by decoding whole pages but marking rows
    outside [lo, hi) invalid via count clamping per page boundary -- for
    simplicity, rows outside the range are zeroed host-side by id slicing
    in the non-fused path; the fused path requires page-aligned [lo, hi)
    (the common case: whole-column label/bitmap scans).
    """
    assert base % 32 == 0
    ps = col.page_size
    assert lo % ps == 0 and (hi % ps == 0 or hi == col.count), \
        "fused path requires page-aligned ranges"
    p0, p1 = lo // ps, -(-hi // ps)
    args = [jnp.asarray(a) for a in pack_pages(col, p0, p1)]
    words_out = _next_multiple(n_words, K.WORD_TILE)
    if use_pallas:
        bm = K.fused_decode_bitmap(*args, jnp.int32(base), page_size=ps,
                                   words_out=words_out)
    else:
        bm = R.fused_ref(*args, jnp.int32(base), page_size=ps,
                         words_out=words_out)
    return np.asarray(bm)[:n_words]


def ids_to_bitmap(ids: np.ndarray, base: int, n_words: int,
                  use_pallas: bool = True) -> np.ndarray:
    """Standalone bitmap construction from sorted ids (32-aligned base)."""
    assert base % 32 == 0
    n = _next_multiple(max(len(ids), 1), K.ID_TILE)
    padded = np.zeros(n, np.int32)
    padded[:len(ids)] = ids
    words_out = _next_multiple(n_words, K.WORD_TILE)
    if use_pallas:
        bm = K.bitmap_pallas(jnp.asarray(padded), jnp.int32(len(ids)),
                             jnp.int32(base), n_words=words_out)
    else:
        bm = R.bitmap_ref(jnp.asarray(padded), jnp.int32(len(ids)),
                          jnp.int32(base), words_out)
    return np.asarray(bm)[:n_words]
