"""Jit'd wrappers + storage-plane integration for pac_decode kernels.

Two granularities:

* single-range (``retrieve_pac``): the original Definition-2 path for one
  vertex's edge rows;
* batched (``decode_row_ranges`` / ``retrieve_pac_batch``): an arbitrary
  set of row ranges decoded through **one** kernel dispatch over the
  page-deduplicated page set -- the unit of work of the batched
  neighbor-retrieval plane (whole-frontier expansion, IC-8/BI-2 multi-hop,
  per-tick serving retrieval).

Both paths read pages through the cached column-wide packed representation
(:func:`repro.core.encoding.pack_column`), so the VMEM-layout batch arrays
are materialized once per column instead of once per query.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np
import jax.numpy as jnp

from repro.core.encoding import DeltaColumn, delta_decode_page, pack_column
from repro.core.pac import PAC

from . import kernel as K
from . import ref as R

ENGINES = ("numpy", "jax", "pallas")


def _next_multiple(x: int, m: int) -> int:
    return -(-x // m) * m


def _next_pow2(x: int) -> int:
    return 1 << max(x - 1, 0).bit_length()


def pack_pages(col: DeltaColumn, p0: int, p1: int
               ) -> Tuple[np.ndarray, ...]:
    """Views of pages [p0, p1) of the cached packed representation.

    Kept for API compatibility; the batch arrays are no longer rebuilt per
    call -- they are zero-copy slices of :func:`pack_column`'s cache.
    """
    return pack_column(col).slice(p0, p1)


def pack_page_list(col: DeltaColumn, pages: Sequence[int]
                   ) -> Tuple[np.ndarray, ...]:
    """Row-gather of an arbitrary (sorted, deduplicated) page list."""
    return pack_column(col).gather(pages)


def decode_pages(col: DeltaColumn, p0: int, p1: int,
                 use_pallas: bool = True) -> np.ndarray:
    """Decode pages [p0, p1) via the kernel (or jnp ref); returns flat ids."""
    ps = col.page_size
    args = pack_pages(col, p0, p1)
    if use_pallas:
        ids = K.delta_decode_pallas(*[jnp.asarray(a) for a in args],
                                    page_size=ps)
    else:
        ids = R.decode_pages_ref(*[jnp.asarray(a) for a in args],
                                 page_size=ps)
    ids = np.asarray(ids)
    counts = args[5][:, 0]
    return np.concatenate([ids[i, :counts[i]] for i in range(len(counts))])


def decode_page_list(col: DeltaColumn, pages: Sequence[int],
                     engine: str = "pallas") -> np.ndarray:
    """Decode an arbitrary page list with one dispatch.

    Returns ``int64[len(pages), page_size]``; rows are zero-padded past
    each page's count (callers only index positions < count).  The page
    batch is padded to a power of two before the jax/pallas dispatch so
    the jitted kernels retrace O(log n) times, not once per distinct
    frontier size.
    """
    ps = col.page_size
    n = len(pages)
    if engine == "numpy":
        out = np.zeros((n, ps), np.int64)
        for i, p in enumerate(pages):
            d = delta_decode_page(col.pages[p])
            out[i, :len(d)] = d
        return out
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; want one of {ENGINES}")
    args = pack_page_list(col, pages)
    pad = _next_pow2(n) - n
    if pad:
        args = tuple(np.concatenate(
            [a, np.zeros((pad,) + a.shape[1:], a.dtype)]) for a in args)
    jargs = [jnp.asarray(a) for a in args]
    if engine == "pallas":
        ids = K.delta_decode_pallas(*jargs, page_size=ps)
    else:
        ids = R.decode_pages_ref(*jargs, page_size=ps)
    ids = np.asarray(ids[:n], np.int64)
    # zero out the padded tail of each page so all engines agree bit-exactly
    counts = args[5][:n, 0]
    cols = np.arange(ps)[None, :]
    return np.where(cols < counts[:, None], ids, 0)


# --------------------------------------------------------------------------
# batched multi-range decode (the batched retrieval plane's kernel entry)
# --------------------------------------------------------------------------

def page_set_for_ranges(los: np.ndarray, his: np.ndarray, page_size: int
                        ) -> Tuple[np.ndarray, int]:
    """(sorted unique pages, contiguous-run count) touched by the ranges.

    The run count models the read requests a real reader would issue:
    consecutive pages coalesce into one ranged GET.
    """
    los = np.asarray(los, np.int64)
    his = np.asarray(his, np.int64)
    keep = his > los
    if not keep.any():
        return np.zeros(0, np.int64), 0
    p0 = los[keep] // page_size
    p1 = his[keep] // page_size + ((his[keep] % page_size) != 0) - 1
    counts = p1 - p0 + 1
    total = int(counts.sum())
    within = np.arange(total) - np.repeat(np.cumsum(counts) - counts, counts)
    pages = np.unique(np.repeat(p0, counts) + within)
    runs = 1 + int(np.sum(np.diff(pages) > 1))
    return pages, runs


def decode_row_ranges(col: DeltaColumn, los, his, meter=None,
                      engine: str = "pallas") -> np.ndarray:
    """Concatenated rows over many [lo, hi) ranges, one decode dispatch.

    The deduplicated page set is decoded **once** (numpy / jnp ref /
    Pallas kernel -- same IOMeter accounting for all three: each touched
    page's bytes charged once, requests counted per contiguous page run),
    then every output element is gathered from the decoded page matrix.
    """
    los = np.asarray(los, np.int64)
    his = np.asarray(his, np.int64)
    lengths = np.maximum(his - los, 0)
    total = int(lengths.sum())
    if total == 0:
        return np.zeros(0, np.int64)
    ps = col.page_size
    pages, runs = page_set_for_ranges(los, his, ps)
    if meter is not None:
        meter.record(sum(col.pages[int(p)].nbytes() for p in pages), runs)
    mat = decode_page_list(col, pages, engine)
    # absolute row index of every output element
    keep = lengths > 0
    l = los[keep]
    k = lengths[keep]
    within = np.arange(total) - np.repeat(np.cumsum(k) - k, k)
    rows = np.repeat(l, k) + within
    page_of = rows // ps
    pidx = np.searchsorted(pages, page_of)
    return mat[pidx, rows - page_of * ps]


def retrieve_pac_batch(col: DeltaColumn, los, his, target_page_size: int,
                       meter=None, engine: str = "pallas") -> PAC:
    """Batched Definition 2: many row ranges -> one merged (unioned) PAC."""
    ids = decode_row_ranges(col, los, his, meter, engine)
    if ids.size == 0:
        return PAC(target_page_size)
    return PAC.from_ids(np.unique(ids), target_page_size)


def retrieve_pac(col: DeltaColumn, lo: int, hi: int, target_page_size: int,
                 meter=None, use_pallas: bool = True) -> PAC:
    """Kernel-engine neighbor retrieval: rows [lo, hi) -> PAC.

    Charges the same page bytes as the numpy path (the I/O plane is
    identical; only the decode compute engine differs).
    """
    return retrieve_pac_batch(col, np.array([lo]), np.array([hi]),
                              target_page_size, meter,
                              engine=("pallas" if use_pallas else "jax"))


def decode_range_to_bitmap(col: DeltaColumn, lo: int, hi: int,
                           base: int, n_words: int,
                           use_pallas: bool = True) -> np.ndarray:
    """Fused path: delta rows [lo, hi) -> one uint32 bitmap over
    [base, base + 32 * n_words). ``base`` must be 32-aligned.

    The row mask is applied by decoding whole pages but marking rows
    outside [lo, hi) invalid via count clamping per page boundary -- for
    simplicity, rows outside the range are zeroed host-side by id slicing
    in the non-fused path; the fused path requires page-aligned [lo, hi)
    (the common case: whole-column label/bitmap scans).
    """
    assert base % 32 == 0
    ps = col.page_size
    assert lo % ps == 0 and (hi % ps == 0 or hi == col.count), \
        "fused path requires page-aligned ranges"
    p0, p1 = lo // ps, -(-hi // ps)
    args = [jnp.asarray(a) for a in pack_pages(col, p0, p1)]
    words_out = _next_multiple(n_words, K.WORD_TILE)
    if use_pallas:
        bm = K.fused_decode_bitmap(*args, jnp.int32(base), page_size=ps,
                                   words_out=words_out)
    else:
        bm = R.fused_ref(*args, jnp.int32(base), page_size=ps,
                         words_out=words_out)
    return np.asarray(bm)[:n_words]


def ids_to_bitmap(ids: np.ndarray, base: int, n_words: int,
                  use_pallas: bool = True) -> np.ndarray:
    """Standalone bitmap construction from sorted ids (32-aligned base)."""
    assert base % 32 == 0
    n = _next_multiple(max(len(ids), 1), K.ID_TILE)
    padded = np.zeros(n, np.int32)
    padded[:len(ids)] = ids
    words_out = _next_multiple(n_words, K.WORD_TILE)
    if use_pallas:
        bm = K.bitmap_pallas(jnp.asarray(padded), jnp.int32(len(ids)),
                             jnp.int32(base), n_words=words_out)
    else:
        bm = R.bitmap_ref(jnp.asarray(padded), jnp.int32(len(ids)),
                          jnp.int32(base), words_out)
    return np.asarray(bm)[:n_words]
