"""Jit'd wrappers + storage-plane integration for pac_decode kernels.

Two granularities:

* single-range (``retrieve_pac``): the original Definition-2 path for one
  vertex's edge rows;
* batched (``decode_row_ranges`` / ``retrieve_pac_batch``): an arbitrary
  set of row ranges decoded through **one** kernel dispatch over the
  page-deduplicated page set -- the unit of work of the batched
  neighbor-retrieval plane (whole-frontier expansion, IC-8/BI-2 multi-hop,
  per-tick serving retrieval).

Both paths read pages through the cached column-wide packed representation
(:func:`repro.core.encoding.pack_column`), so the VMEM-layout batch arrays
are materialized once per column instead of once per query.

Two cross-cutting performance layers (PR 2):

* **decoded-page LRU** -- when a :class:`repro.core.page_cache.DecodedPageCache`
  is attached to the column, every decode path splits its page set into
  hits and misses, decodes and IOMeter-charges the **misses only**, and
  inserts the fresh decodes back (see ``decode_page_list``);
* **fused batched decode->bitmap** -- ``retrieve_pac_batch`` on the
  jax/pallas engines runs page-pack -> multi-range decode -> target-bitmap
  scatter in one kernel dispatch and builds the merged PAC straight from
  the returned bitmap planes (``PAC.from_dense_bitmap``), never
  materializing the concatenated per-range id list on the host.
"""
from __future__ import annotations

import os
from collections import deque
from typing import Dict, Optional, Sequence, Tuple

import numpy as np
import jax.numpy as jnp

from repro.core.encoding import (DeltaColumn, delta_decode_page, pack_column,
                                 prune_page_list)
from repro.core.labels import intervals_to_ids
from repro.core.pac import PAC
from repro.core.page_cache import live_cache, miss_runs
from repro.core.partition import live_partitions
from repro.kernels._pad import next_multiple, next_pow2, size_class

from . import kernel as K
from . import ref as R

ENGINES = ("numpy", "jax", "pallas")

#: auto-fused threshold: below this many ranges the host path's
#: O(neighbors) post-processing beats the fused tail's O(num_targets)
#: bitmap pass (crossover measured in bench_batch_scaling; the win
#: criterion is batch >= 64, so the default of 16 leaves comfortable
#: margin both ways).  Overridable via ``REPRO_FUSED_MIN_RANGES`` for
#: bench sweeps of the crossover.
FUSED_MIN_RANGES = int(os.environ.get("REPRO_FUSED_MIN_RANGES", "16"))

#: device-resident packed column plane (``PackedPages.device``): kernel
#: engines gather pages on-device by index instead of row-gathering on
#: the host and re-shipping packed bytes per dispatch.  On by default;
#: ``REPRO_DEVICE_RESIDENT=0`` restores the per-dispatch pack path
#: everywhere (the ``resident=`` arguments override per call).
DEVICE_RESIDENT = os.environ.get("REPRO_DEVICE_RESIDENT", "1") \
    .strip().lower() not in ("0", "false", "no", "off")

#: pow2 size-class floors for the per-dispatch index/position vectors --
#: small frontiers share one bucket instead of retracing per shape.
PAGE_CLASS_MIN = 8
RANGE_CLASS_MIN = 64

#: adaptive sharding threshold for the partition plane: the SPMD
#: (``shard_map``) dispatch pays a fixed multi-executable launch cost per
#: call, so partitioned columns shard across the device mesh only when
#: the busiest device gets at least this many pages to decode; below it
#: the plane takes its **degenerate single-shard dispatch** -- the
#: monolithic resident kernels over the stacked partition plan on one
#: device -- which costs what the unpartitioned path costs.  Results,
#: meters, and pruning are identical either way.  ``REPRO_SHARD_MIN_PAGES=0``
#: forces SPMD everywhere (the multi-device CI job does, so the sharded
#: path is validated without real accelerators).
SHARD_MIN_PAGES = int(os.environ.get("REPRO_SHARD_MIN_PAGES", "48"))

#: (engine, n_words) -> ring of the two most recent dispatches' bitmap
#: planes, handed back to the resident kernel as its aliased output
#: buffer so steady-state serving ticks reuse device allocations instead
#: of growing one per dispatch.  The ring is **double-buffered**: a
#: dispatch donates the *older* of the two pooled buffers, never the
#: most recent output -- so with pipelined serving (retrieval issued
#: asynchronously in the decode's shadow, host copy-out deferred until
#: the result is consumed) two in-flight dispatches can never alias one
#: plane.  Steady state settles at exactly two buffers per class.
_WORDS_POOL: Dict[Tuple[str, int], "deque"] = {}


def _words_buffer(engine: str, n_words: int):
    ring = _WORDS_POOL.get((engine, n_words))
    if ring is not None and len(ring) >= 2:
        # oldest pooled plane: its dispatch is two behind, its host copy
        # long consumed -- safe to donate even with one still in flight
        return ring.popleft()
    return jnp.zeros(n_words, jnp.uint32)


def _pool_words(engine: str, n_words: int, buf) -> None:
    ring = _WORDS_POOL.setdefault((engine, n_words), deque())
    ring.append(buf)
    while len(ring) > 2:
        ring.popleft()


def reset_dispatch_pools() -> None:
    """Drop pooled device buffers (tests / bench isolation)."""
    _WORDS_POOL.clear()


def pack_pages(col: DeltaColumn, p0: int, p1: int
               ) -> Tuple[np.ndarray, ...]:
    """Views of pages [p0, p1) of the cached packed representation.

    Kept for API compatibility; the batch arrays are no longer rebuilt per
    call -- they are zero-copy slices of :func:`pack_column`'s cache.
    """
    return pack_column(col).slice(p0, p1)


def pack_page_list(col: DeltaColumn, pages: Sequence[int]
                   ) -> Tuple[np.ndarray, ...]:
    """Row-gather of an arbitrary (sorted, deduplicated) page list."""
    return pack_column(col).gather(pages)


def decode_pages(col: DeltaColumn, p0: int, p1: int,
                 use_pallas: bool = True) -> np.ndarray:
    """Decode pages [p0, p1) via the kernel (or jnp ref); returns flat ids."""
    ps = col.page_size
    args = pack_pages(col, p0, p1)
    if use_pallas:
        ids = K.delta_decode_pallas(*[jnp.asarray(a) for a in args],
                                    page_size=ps)
    else:
        ids = R.decode_pages_ref(*[jnp.asarray(a) for a in args],
                                 page_size=ps)
    ids = np.asarray(ids)
    counts = args[5][:, 0]
    return np.concatenate([ids[i, :counts[i]] for i in range(len(counts))])


def _charge_pages(col: DeltaColumn, pages: Sequence[int], meter) -> None:
    """IOMeter charge for a (sorted) page list: each page's bytes once,
    requests per contiguous run (what a real ranged reader would issue)."""
    if meter is None or not len(pages):
        return
    meter.record(sum(col.pages[int(p)].nbytes() for p in pages),
                 miss_runs(pages))


def _page_index_vector(pages: Sequence[int], total_pages: int) -> np.ndarray:
    """int32 page-index vector padded to a shared pow2 size class (the
    only thing the host ships for a resident-column decode), capped at
    the (rounded) whole column -- a gather cannot name more distinct
    rows than the column has, so padding past it is pure wasted decode
    (the stacked-plan ladder cap of the sharded path, backported)."""
    idx = np.zeros(_page_class(len(pages), total_pages), np.int32)
    idx[:len(pages)] = pages
    return idx


def _stack_index(parts, pages: np.ndarray,
                 owner: np.ndarray) -> np.ndarray:
    """Flat row of each global page in the partition-major stacked plan
    (``owner * pmax + offset within partition``) -- the index space every
    partitioned gather consumes.  A device shard's block-local index is
    this minus the block's first row."""
    return (owner * parts.pmax
            + (pages - parts.bounds[owner])).astype(np.int32)


def _page_class(n: int, stack_rows: int) -> int:
    """Page-padding class for a resident dispatch: the shared pow2
    ladder, capped at the (PAGE_CLASS_MIN-rounded) whole plan --
    ``stack_rows`` is the stacked partition plan's row count on the
    sharded paths and the column's page count on the monolithic ones.
    The plan bounds how many distinct rows a gather can name, so padding
    past it is pure wasted decode -- at large page counts the uncapped
    pow2 ladder over-decodes by up to ~2x (e.g. 157 touched pages pad
    to 256 uncapped, 160 capped).  The cap adds at most one extra jit
    size class per column."""
    return min(size_class(n, PAGE_CLASS_MIN),
               next_multiple(stack_rows, PAGE_CLASS_MIN))


_N_DEVICES: "int | None" = None


def _n_devices() -> int:
    """Device count, resolved once (the PjRt device list is fixed for
    the process lifetime; ``jax.devices()`` is not free on the dispatch
    hot path)."""
    global _N_DEVICES
    if _N_DEVICES is None:
        import jax
        _N_DEVICES = len(jax.devices())
    return _N_DEVICES


def _shard_width(parts, owner: np.ndarray
                 ) -> Tuple[int, int, "np.ndarray | None",
                            "np.ndarray | None"]:
    """Adaptive mesh width for one dispatch.

    Returns ``(g, ppd, dev_of_page, per_dev)``; ``g == 1`` selects the
    degenerate single-shard dispatch (one-device host, or no device's
    page bucket reaches ``SHARD_MIN_PAGES`` -- the SPMD launch cost
    would not amortize), in which case the bucketing outputs are None.
    The one home for the policy: the fused and non-fused paths must
    shard under identical conditions.
    """
    g = parts.mesh_size(_n_devices())
    if g <= 1:
        return 1, 1, None, None
    ppd = parts.n_parts // g
    dev_of_page = owner // ppd
    per_dev = np.bincount(dev_of_page, minlength=g)
    if per_dev.max() < SHARD_MIN_PAGES:
        return 1, 1, None, None
    return g, ppd, dev_of_page, per_dev


def _sharded_decode_matrix(col: DeltaColumn, parts, pages: Sequence[int],
                           engine: str) -> np.ndarray:
    """Partitioned page-matrix decode (the non-fused batched path).

    Pages are re-addressed into the stacked partition plan; above the
    sharding threshold they are bucketed per device and decoded through
    one ``shard_map`` dispatch over the partition mesh, below it through
    the monolithic resident gather over the single-device stacked plan.
    Same contract as the monolithic resident decode --
    int64[len(pages), page_size], tails zeroed by the caller."""
    ps = col.page_size
    pages_arr = np.asarray(pages, np.int64)
    owner, _ = parts.prune(pages_arr)  # dispatch/pruning counters only
    stack_idx = _stack_index(parts, pages_arr, owner)
    g, ppd, dev_of_page, per_dev = _shard_width(parts, owner)
    if g == 1:
        arrays, _ = parts.device_plan_single(engine)
        idx = np.zeros(_page_class(len(pages_arr), parts.stack_rows),
                       np.int32)
        idx[:len(pages_arr)] = stack_idx
        fn = K.gather_decode_pallas if engine == "pallas" \
            else R.gather_decode_ref
        ids = fn(*arrays, jnp.asarray(idx), page_size=ps)
        return np.asarray(ids[:len(pages_arr)], np.int64)
    import jax
    from jax.sharding import NamedSharding, PartitionSpec
    from repro.kernels.shard import sharded_decode_entry
    mesh, plan, pmax = parts.device_plan(engine)
    block0 = dev_of_page * (ppd * pmax)      # first stacked row per block
    local_idx = (stack_idx - block0).astype(np.int32)
    p_pad = _page_class(int(per_dev.max()), ppd * pmax)
    idxmat = np.zeros((g, p_pad), np.int32)
    for i in range(g):
        sel = local_idx[dev_of_page == i]
        idxmat[i, :len(sel)] = sel
    jidx = jax.device_put(idxmat,
                          NamedSharding(mesh, PartitionSpec("part", None)))
    fn = sharded_decode_entry(mesh, engine, ps, p_pad)
    mat = np.asarray(fn(*plan, jidx), np.int64)  # [g, p_pad, ps]
    # row of page i = its appearance order within its device's bucket --
    # the same masks that filled idxmat, so correct for any page order
    within = np.empty(len(pages_arr), np.int64)
    for i in range(g):
        m = dev_of_page == i
        within[m] = np.arange(int(m.sum()))
    return mat[dev_of_page, within]


def _decode_page_matrix(col: DeltaColumn, pages: Sequence[int],
                        engine: str) -> np.ndarray:
    """Engine dispatch only -- no cache, no metering (see decode_page_list).

    Kernel engines follow the ``REPRO_DEVICE_RESIDENT`` default (the
    per-call ``resident=`` override exists on the fused entry points
    only).  Columns with a partition plane attached
    (:func:`repro.core.partition.partition_column`) decode through the
    sharded entry -- pages bucketed per partition, one dispatch across
    the device mesh -- with bit-identical output.
    """
    ps = col.page_size
    n = len(pages)
    parts = live_partitions(col)
    if engine == "numpy":
        if parts is not None:
            parts.prune(np.asarray(pages, np.int64))  # accounting only
        out = np.zeros((n, ps), np.int64)
        for i, p in enumerate(pages):
            d = delta_decode_page(col.pages[p])
            out[i, :len(d)] = d
        return out
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; want one of {ENGINES}")
    if parts is not None and DEVICE_RESIDENT:
        ids = _sharded_decode_matrix(col, parts, pages, engine)
        counts = np.asarray([col.pages[int(p)].count for p in pages],
                            np.int64)
        cols = np.arange(ps)[None, :]
        return np.where(cols < counts[:, None], ids, 0)
    if DEVICE_RESIDENT:
        # device-resident path: the unpack plan crossed the PCIe once;
        # the dispatch ships the int32 page-index vector and gathers +
        # decodes rows on device
        packed = pack_column(col)
        plan = packed.device_plan(engine)
        idx = _page_index_vector(pages, len(col.pages))
        if engine == "pallas":
            ids = K.gather_decode_pallas(*plan, jnp.asarray(idx),
                                         page_size=ps)
        else:
            ids = R.gather_decode_ref(*plan, jnp.asarray(idx),
                                      page_size=ps)
        counts = packed.counts[np.asarray(pages, np.int64), 0]
    else:
        args = pack_page_list(col, pages)
        pad = next_pow2(n) - n
        if pad:
            args = tuple(np.concatenate(
                [a, np.zeros((pad,) + a.shape[1:], a.dtype)]) for a in args)
        jargs = [jnp.asarray(a) for a in args]
        if engine == "pallas":
            ids = K.delta_decode_pallas(*jargs, page_size=ps)
        else:
            ids = R.decode_pages_ref(*jargs, page_size=ps)
        counts = args[5][:n, 0]
    ids = np.asarray(ids[:n], np.int64)
    # zero out the padded tail of each page so all engines agree bit-exactly
    cols = np.arange(ps)[None, :]
    return np.where(cols < counts[:, None], ids, 0)


def decode_page_list(col: DeltaColumn, pages: Sequence[int],
                     engine: str = "pallas", meter=None) -> np.ndarray:
    """Decode an arbitrary (sorted, deduplicated) page list, one dispatch.

    Returns ``int64[len(pages), page_size]``; rows are zero-padded past
    each page's count (callers only index positions < count).  The page
    batch is padded to a power of two before the jax/pallas dispatch so
    the jitted kernels retrace O(log n) times, not once per distinct
    frontier size.

    When the column carries a decoded-page LRU (``col.page_cache``,
    consulted through :func:`~repro.core.page_cache.live_cache` so a
    version-bumped column drops stale decodes first), only the cache-miss
    pages are decoded and IOMeter-charged; hit rows are assembled from
    the cache and cost no lake I/O.  Without a cache every page is a miss
    (the pre-LRU accounting, unchanged).

    On a partitioned column, cache entries live in the ``(partition,
    page)`` namespace (the same keying the sharded fused path uses), so
    fused and non-fused dispatches against one column share warm pages.
    """
    ps = col.page_size
    n = len(pages)
    if n == 0:
        return np.zeros((0, ps), np.int64)
    cache = live_cache(col)
    parts = live_partitions(col)
    pages_arr = np.asarray(pages, np.int64)
    owner = parts.part_of_pages(pages_arr) if parts is not None else None
    if cache is None:
        _charge_pages(col, pages, meter)
        return _decode_page_matrix(col, pages, engine)
    hits, miss = cache.split(pages, owner=owner)
    _charge_pages(col, miss, meter)
    out = np.zeros((n, ps), np.int64)
    if miss:
        mat = _decode_page_matrix(col, miss, engine)
        # miss preserves the sorted page order, so one fancy-index scatter
        # places every miss row (no per-row dict lookups)
        is_miss = np.isin(pages_arr, np.asarray(miss, np.int64))
        miss_idx = np.flatnonzero(is_miss)
        out[miss_idx] = mat
        for i, p in enumerate(miss):
            cache.put(p, mat[i, :col.pages[p].count].copy(),
                      part=None if owner is None
                      else int(owner[miss_idx[i]]))
        hit_idx = np.flatnonzero(~is_miss)
    else:
        hit_idx = np.arange(n)
    if hit_idx.size:
        rows = [hits[int(pages_arr[i])] for i in hit_idx]
        lens = np.fromiter((len(r) for r in rows), np.int64, len(rows))
        full = lens == ps
        if full.any():   # full-width hits stack into one scatter
            out[hit_idx[full]] = [rows[j] for j in np.flatnonzero(full)]
        for j in np.flatnonzero(~full):  # at most the last partial page
            out[hit_idx[j], :lens[j]] = rows[j]
    return out


# --------------------------------------------------------------------------
# batched multi-range decode (the batched retrieval plane's kernel entry)
# --------------------------------------------------------------------------

def page_set_for_ranges(los: np.ndarray, his: np.ndarray, page_size: int
                        ) -> Tuple[np.ndarray, int]:
    """(sorted unique pages, contiguous-run count) touched by the ranges.

    The run count models the read requests a real reader would issue:
    consecutive pages coalesce into one ranged GET.
    """
    los = np.asarray(los, np.int64)
    his = np.asarray(his, np.int64)
    keep = his > los
    if not keep.any():
        return np.zeros(0, np.int64), 0
    p0 = los[keep] // page_size
    p1 = his[keep] // page_size + ((his[keep] % page_size) != 0)
    pages = np.unique(intervals_to_ids((p0, p1)))
    return pages, miss_runs(pages)


def decode_row_ranges(col: DeltaColumn, los, his, meter=None,
                      engine: str = "pallas", qual=None) -> np.ndarray:
    """Concatenated rows over many [lo, hi) ranges, one decode dispatch.

    The deduplicated page set is decoded **once** (numpy / jnp ref /
    Pallas kernel -- same IOMeter accounting for all three: each
    cache-miss page's bytes charged once, requests counted per contiguous
    miss run), then every output element is gathered from the decoded
    page matrix.

    ``qual`` -- a predicate's half-open qualifying ``[lo, hi)`` id hull
    -- drops pages whose zone map cannot intersect it **before** the
    cache split and the decode (:func:`~repro.core.encoding
    .prune_page_list`): pruned pages are never gathered, decoded, or
    charged, and the rows they held are dropped from the output (every
    one of them provably fails the predicate, so callers that filter by
    ``qual``'s predicate see bit-identical ids).
    """
    los = np.asarray(los, np.int64)
    his = np.asarray(his, np.int64)
    lengths = np.maximum(his - los, 0)
    total = int(lengths.sum())
    if total == 0:
        return np.zeros(0, np.int64)
    ps = col.page_size
    pages, _ = page_set_for_ranges(los, his, ps)
    pages, pmask = prune_page_list(col, pages, qual)
    if len(pages) == 0:
        return np.zeros(0, np.int64)
    mat = decode_page_list(col, pages, engine, meter=meter)
    # absolute row index of every output element
    rows = intervals_to_ids((los, his))
    page_of = rows // ps
    pidx = np.searchsorted(pages, page_of)
    if pmask is not None:
        # rows addressed at a pruned page cannot pass the predicate
        ok = pidx < len(pages)
        ok &= pages[np.minimum(pidx, len(pages) - 1)] == page_of
        rows, page_of, pidx = rows[ok], page_of[ok], pidx[ok]
    return mat[pidx, rows - page_of * ps]


def _gather_positions(pages: np.ndarray, base_of_page: np.ndarray,
                      los: np.ndarray, his: np.ndarray,
                      page_size: int, pruned: bool = False
                      ) -> Tuple[np.ndarray, int]:
    """Flat (row * page_size + offset) position of every requested row,
    zero-padded to a power of two.

    These are row *positions* (derivable from the <offset> index alone),
    not decoded ids -- the host addresses the requested rows inside the
    kernel's [miss | cached] row order (``base_of_page[i]`` is the matrix
    row holding sorted page ``pages[i]``) without ever materializing the
    concatenated id list.  Returns ``(int32[t], total)``.

    ``pruned`` marks a statistics-pruned ``pages`` list: rows whose page
    was dropped are dropped with it (they cannot pass the predicate that
    derived the pruning hull).
    """
    rows = intervals_to_ids((los, his))
    n_rows = len(rows)
    page_of = rows // page_size
    pidx = np.searchsorted(pages, page_of)
    if pruned:
        ok = pidx < len(pages)
        ok &= pages[np.minimum(pidx, len(pages) - 1)] == page_of
        if not ok.all():
            rows, page_of, pidx = rows[ok], page_of[ok], pidx[ok]
    total = len(rows)
    gidx = (base_of_page[pidx] * page_size + (rows - page_of * page_size)) \
        .astype(np.int32)
    # pad to the *unpruned* request's size class: pruning must never mint
    # a new staged shape (the dropped rows ride out as masked padding
    # lanes under ``total``), so the jit-cache footprint is exactly the
    # unpruned path's
    pad = size_class(n_rows, RANGE_CLASS_MIN) - total
    if pad:
        gidx = np.concatenate([gidx, np.zeros(pad, np.int32)])
    return gidx, total


def _retrieve_pac_batch_sharded(col: DeltaColumn, parts, los, his, pages,
                                target_page_size: int, num_targets: int,
                                meter, engine: str, filter_plan=None) -> PAC:
    """Partition-sharded fused path: one ``shard_map`` dispatch across the
    partition mesh, per-partition bitmap planes OR-merged into one PAC.

    The host buckets the batch per partition: the deduplicated page set
    and the requested-row positions are split at partition boundaries
    (partitions are page-aligned, so a range spanning a boundary simply
    contributes rows to both sides), re-addressed into each device's
    block-local index space, and shipped as one ``staged`` matrix (row
    ``i`` = device ``i``'s ``[idx | gidx | total]`` vector).  Each shard
    gathers and decodes its partitions' pages from the sharded stacked
    plan and scatters its rows into a full target bitmap plane; the ``g``
    planes OR together on the host (a target id may be a neighbor via
    several partitions).

    Pruning happens before anything is charged or shipped: partitions
    holding none of the batch's pages are skipped (meter-neutral -- they
    had nothing to charge), and with a pushed-down filter, partitions
    whose min/max id hull cannot intersect the predicate's qualifying
    range are skipped too -- their neighbors would be ANDed away inside
    the kernel, so ids are unchanged while their page I/O is genuinely
    saved (statistics pushdown; the meter records the smaller read).

    Accounting is otherwise the monolithic resident path's, verbatim:
    the decoded-page LRU (entries namespaced ``(partition, page)``) is
    split over the global page set, misses are charged once with
    requests per contiguous global run, and the decode matrix backfills
    the cache only when there are misses to backfill.

    Dispatch is adaptive (``SHARD_MIN_PAGES``): above the threshold the
    SPMD tail runs, below it the **degenerate single-shard tail** --
    the monolithic resident kernels over the single-device stacked plan,
    with the cross-tick bitmap buffer pool and ``want_ids`` elision
    intact -- so small dispatches never pay the multi-executable launch
    cost.  Both tails produce identical planes.

    ``pages`` is the caller's already-deduplicated page set (the fused
    entry computes it for its empty-batch check; recomputing it here was
    a measurable per-dispatch cost).
    """
    ps = col.page_size
    qual = filter_plan.qual_range() if filter_plan is not None else None
    owner, mask = parts.prune(pages, qual)
    if mask is not None:
        pages = pages[mask]
        if pages.size == 0:  # every partition statistics-pruned
            return PAC(target_page_size)
    # page-granular zone maps inside the surviving partitions: a finer
    # sieve over the same hull (partition-pruned pages are a subset of
    # page-pruned ones, so the final page set -- and the meter -- equals
    # the monolithic path's at any partition count)
    kept, pmask = prune_page_list(col, pages, qual)
    if pmask is not None:
        pages, owner = kept, owner[pmask]
        if pages.size == 0:  # every page statistics-pruned
            return PAC(target_page_size)
    pruned = mask is not None or pmask is not None
    stack_idx = _stack_index(parts, pages, owner)
    cache = live_cache(col)
    if cache is None:
        hits, miss = {}, [int(p) for p in pages]
    else:
        hits, miss = cache.split(pages, owner=owner)
    _charge_pages(col, miss, meter)
    n_words = -(-num_targets // 32)
    want_ids = cache is not None and bool(miss)
    # requested rows: with statistics pruning, rows whose page was
    # dropped cannot pass the predicate and are dropped with it
    rows = intervals_to_ids((los, his))
    n_rows = len(rows)
    page_of = rows // ps
    pidx = np.searchsorted(pages, page_of)
    if pruned:
        ok = pidx < len(pages)
        ok &= pages[np.minimum(pidx, len(pages) - 1)] == page_of
        if not ok.all():
            rows, page_of, pidx = rows[ok], page_of[ok], pidx[ok]
    g, ppd, dev_of_page, per_dev = _shard_width(parts, owner)
    if g == 1:
        # single-shard tail: exactly the monolithic resident dispatch,
        # addressed through the stacked partition plan
        arrays, _ = parts.device_plan_single(engine)
        gidx = (pidx * ps + (rows - page_of * ps)).astype(np.int32)
        total = len(gidx)
        # pad to the unpruned request's class -- pruning never mints a
        # new staged shape (see _gather_positions)
        pad = size_class(n_rows, RANGE_CLASS_MIN) - total
        if pad:
            gidx = np.concatenate([gidx, np.zeros(pad, np.int32)])
        p_pad = _page_class(len(pages), parts.stack_rows)
        staged = np.zeros(p_pad + len(gidx) + 1, np.int32)
        staged[:len(pages)] = stack_idx
        staged[p_pad:-1] = gidx
        staged[-1] = total
        jargs = arrays + (jnp.asarray(staged),)
        if filter_plan is None:
            fn = (K.fused_gather_decode_bitmap_batch if engine == "pallas"
                  else R.fused_gather_batch_ref)
            out = fn(*jargs, _words_buffer(engine, n_words),
                     page_size=ps, n_words=n_words, p_pad=p_pad,
                     want_ids=want_ids)
        else:
            from repro.kernels.label_filter import kernel as LK
            from repro.kernels.label_filter import ref as LR
            fwords = filter_plan.device_bitmap(engine, n_words)
            fn = (LK.fused_gather_decode_filter_bitmap_batch
                  if engine == "pallas" else LR.fused_gather_filter_batch_ref)
            out = fn(*jargs, fwords, _words_buffer(engine, n_words),
                     page_size=ps, n_words=n_words, p_pad=p_pad,
                     want_ids=want_ids)
        if want_ids:
            words, ids = out
            mat = np.asarray(ids, np.int64)
            pos_of = {int(p): i for i, p in enumerate(pages)}
            for p in miss:
                i = pos_of[p]
                cache.put(p, mat[i, :col.pages[p].count].copy(),
                          part=int(owner[i]))
        else:
            words = out
        host_words = np.asarray(words)
        _pool_words(engine, n_words, words)  # reuse 2 dispatches later
        return PAC.from_dense_bitmap(host_words, target_page_size)
    # SPMD tail: bucket per device and dispatch across the mesh
    import jax
    from jax.sharding import NamedSharding, PartitionSpec
    from repro.kernels.shard import sharded_fused_entry
    mesh, plan, pmax = parts.device_plan(engine)
    block0 = dev_of_page * (ppd * pmax)
    local_idx = (stack_idx - block0).astype(np.int32)
    # pidx already maps each row to its page's slot; gather its device
    # from there instead of a second searchsorted over all rows
    dev_of_row = dev_of_page[pidx]
    dev_page_start = np.searchsorted(dev_of_page, np.arange(g))
    base_local = pidx - dev_page_start[dev_of_row]
    gidx = (base_local * ps + (rows - page_of * ps)).astype(np.int32)
    row_lists = [gidx[dev_of_row == i] for i in range(g)]
    p_pad = _page_class(int(per_dev.max()), ppd * pmax)
    t_pad = size_class(max(len(x) for x in row_lists), RANGE_CLASS_MIN)
    staged = np.zeros((g, p_pad + t_pad + 1), np.int32)
    for i in range(g):
        sel = local_idx[dev_of_page == i]
        staged[i, :len(sel)] = sel
        staged[i, p_pad:p_pad + len(row_lists[i])] = row_lists[i]
        staged[i, -1] = len(row_lists[i])
    jstaged = jax.device_put(
        staged, NamedSharding(mesh, PartitionSpec("part", None)))
    fargs = ()
    if filter_plan is not None:
        fargs = (filter_plan.device_bitmap_sharded(engine, n_words, mesh),)
    fn = sharded_fused_entry(mesh, engine, ps, n_words, p_pad, want_ids,
                             filter_plan is not None)
    out = fn(*plan, jstaged, *fargs)
    if want_ids:
        planes, ids = out
        mat = np.asarray(ids, np.int64)  # [g, p_pad, ps]
        pos = {int(p): (int(dev_of_page[i]),
                        i - int(dev_page_start[dev_of_page[i]]),
                        int(owner[i]))
               for i, p in enumerate(pages)}
        for p in miss:
            d, s, k = pos[p]
            cache.put(p, mat[d, s, :col.pages[p].count].copy(), part=k)
    else:
        planes = out
    merged = np.bitwise_or.reduce(np.asarray(planes, np.uint32), axis=0)
    return PAC.from_dense_bitmap(merged, target_page_size)


def _retrieve_pac_batch_fused(col: DeltaColumn, los, his,
                              target_page_size: int, num_targets: int,
                              meter, engine: str, filter_plan=None,
                              resident: Optional[bool] = None) -> PAC:
    """Fused path: one dispatch from packed pages to target bitmap planes.

    The decoded ids stay on the device; the host receives only the dense
    bitmap (``PAC.from_dense_bitmap`` keeps the non-empty planes).  With a
    decoded-page LRU attached, the IOMeter charges the **miss** pages only
    (hits are RAM/device-resident, no lake I/O) and the kernel's
    by-product decode matrix backfills the cache (the one case where the
    matrix is pulled to the host).  With ``filter_plan`` (a
    :class:`repro.kernels.label_filter.ops.FilterPlan` over the target
    vertex table) the label-predicate bitmap is ANDed into the rank-lookup
    inside the same dispatch.

    Two transfer regimes, identical results and accounting:

    * **device-resident** (default): the packed column's device mirror is
      populated once (``PackedPages.device``); the dispatch ships only the
      int32 page-index vector + range positions, pages are gathered and
      decoded on device (LRU hits re-decode there rather than shipping
      their decoded rows across PCIe), and with a filter the predicate
      plane comes from the plan's device-cached bitmap -- no label bytes
      move either.  The bitmap output buffer is reused across dispatches
      (aliased into the kernel).
    * **per-dispatch pack** (``resident=False`` /
      ``REPRO_DEVICE_RESIDENT=0``): the PR 3 path -- miss pages are
      row-gathered on the host and shipped packed each dispatch, LRU-hit
      rows are fed in pre-decoded via the ``cached`` input.
    """
    ps = col.page_size
    pages, _ = page_set_for_ranges(los, his, ps)
    if pages.size == 0:
        return PAC(target_page_size)
    if engine not in ("jax", "pallas"):
        raise ValueError(f"fused path requires a kernel engine, not "
                         f"{engine!r}")
    if resident is None:
        resident = DEVICE_RESIDENT
    parts = live_partitions(col)
    if parts is not None and resident:
        # partition plane attached: shard the fused dispatch across the
        # device mesh (the monolithic resident path is its 1-partition
        # degenerate case; resident=False keeps the per-dispatch pack
        # baseline below as the single-device oracle)
        return _retrieve_pac_batch_sharded(col, parts, los, his, pages,
                                           target_page_size, num_targets,
                                           meter, engine, filter_plan)
    # page-granular statistics pushdown: with a predicate pushed down,
    # pages whose zone map cannot intersect its qualifying hull drop out
    # *before* the cache split and the staging -- never gathered onto
    # the device, never decoded, never charged (the sharded path above
    # applies the same sieve after its partition-level prune)
    qual = filter_plan.qual_range() if filter_plan is not None else None
    pages, pmask = prune_page_list(col, pages, qual)
    if pages.size == 0:  # every page statistics-pruned
        return PAC(target_page_size)
    cache = live_cache(col)
    part_of = {}
    if cache is None:
        hits, miss = {}, [int(p) for p in pages]
    else:
        # a partitioned column's LRU entries live in the (partition,
        # page) namespace on every path -- the non-resident oracle must
        # probe/fill the same keys the sharded dispatches use, or one
        # column's cache splits into two disjoint namespaces
        # (double-charging warm pages)
        owner = parts.part_of_pages(pages) if parts is not None else None
        if owner is not None:
            part_of = {int(p): int(o) for p, o in zip(pages, owner)}
        hits, miss = cache.split(pages, owner=owner)
    _charge_pages(col, miss, meter)
    n_words = -(-num_targets // 32)
    if resident:
        # rows are in sorted-page order: base_of_page[i] == i
        gidx, total = _gather_positions(pages, np.arange(len(pages)),
                                        los, his, ps,
                                        pruned=pmask is not None)
        plan = pack_column(col).device_plan(engine)
        # one staging vector [idx | gidx | total] = one device put per
        # dispatch (three separate puts were a measurable fixed cost);
        # page padding capped at the whole column (sharded-path ladder
        # cap, backported to the monolithic resident dispatch)
        p_pad = _page_class(len(pages), len(col.pages))
        staged = np.zeros(p_pad + len(gidx) + 1, np.int32)
        staged[:len(pages)] = pages
        staged[p_pad:-1] = gidx
        staged[-1] = total
        jargs = plan + (jnp.asarray(staged),)
        # the decode matrix only exists to backfill the LRU: with no
        # cache -- or a warm one (zero misses) -- the ids never leave
        # the kernel, skipping the dominant output materialization
        want_ids = cache is not None and bool(miss)
        if filter_plan is None:
            fn = (K.fused_gather_decode_bitmap_batch if engine == "pallas"
                  else R.fused_gather_batch_ref)
            out = fn(*jargs, _words_buffer(engine, n_words),
                     page_size=ps, n_words=n_words, p_pad=p_pad,
                     want_ids=want_ids)
        else:
            from repro.kernels.label_filter import kernel as LK
            from repro.kernels.label_filter import ref as LR
            fwords = filter_plan.device_bitmap(engine, n_words)
            fn = (LK.fused_gather_decode_filter_bitmap_batch
                  if engine == "pallas" else LR.fused_gather_filter_batch_ref)
            out = fn(*jargs, fwords, _words_buffer(engine, n_words),
                     page_size=ps, n_words=n_words, p_pad=p_pad,
                     want_ids=want_ids)
        if want_ids:
            words, ids = out
            mat = np.asarray(ids, np.int64)
            pos_of = {int(p): i for i, p in enumerate(pages)}
            for p in miss:
                cache.put(p, mat[pos_of[p], :col.pages[p].count].copy(),
                          part=part_of.get(p))
        else:
            words = out
        host_words = np.asarray(words)
        _pool_words(engine, n_words, words)  # reuse 2 dispatches later
        return PAC.from_dense_bitmap(host_words, target_page_size)
    m = len(miss)
    m_pad = next_pow2(m)
    args = pack_page_list(col, miss)
    if m_pad - m:
        args = tuple(np.concatenate(
            [a, np.zeros((m_pad - m,) + a.shape[1:], a.dtype)])
            for a in args)
    hit_list = [int(p) for p in pages if int(p) in hits]
    cached = np.zeros((next_pow2(len(hit_list)), ps), np.int32)
    for i, p in enumerate(hit_list):
        d = hits[p]
        cached[i, :len(d)] = d
    # matrix row of each sorted page: misses first, then cached rows
    miss_set = set(miss)
    is_miss = np.fromiter((int(p) in miss_set for p in pages), bool,
                          len(pages))
    base_of_page = np.where(is_miss, np.cumsum(is_miss) - 1,
                            m_pad + np.cumsum(~is_miss) - 1)
    gidx, total = _gather_positions(pages, base_of_page, los, his, ps,
                                    pruned=pmask is not None)
    jargs = [jnp.asarray(a) for a in args] \
        + [jnp.asarray(cached), jnp.asarray(gidx),
           jnp.full((1, 1), total, np.int32)]
    if filter_plan is None:
        if engine == "pallas":
            words, ids = K.fused_decode_bitmap_batch(*jargs, page_size=ps,
                                                     n_words=n_words)
        else:
            words, ids = R.fused_batch_ref(*jargs, page_size=ps,
                                           n_words=n_words)
    else:
        from repro.kernels.label_filter import kernel as LK
        from repro.kernels.label_filter import ref as LR
        fargs = [jnp.asarray(filter_plan.pos), jnp.asarray(filter_plan.meta)]
        fn = (LK.fused_decode_filter_bitmap_batch if engine == "pallas"
              else LR.fused_filter_batch_ref)
        words, ids = fn(*jargs, *fargs, page_size=ps, n_words=n_words,
                        ops=filter_plan.program.ops)
    if cache is not None and miss:
        mat = np.asarray(ids, np.int64)
        for i, p in enumerate(miss):
            cache.put(p, mat[i, :col.pages[p].count].copy(),
                      part=part_of.get(p))
    return PAC.from_dense_bitmap(np.asarray(words), target_page_size)


def retrieve_pac_batch(col: DeltaColumn, los, his, target_page_size: int,
                       meter=None, engine: str = "pallas",
                       num_targets: Optional[int] = None,
                       fused: Optional[bool] = None,
                       label_filter=None,
                       resident: Optional[bool] = None,
                       delta_ids=None) -> PAC:
    """Batched Definition 2: many row ranges -> one merged (unioned) PAC.

    Kernel engines take the fused decode->bitmap path whenever the target
    id space is known (``num_targets``), the target page size is
    word-aligned, and the batch is large enough to amortize the fused
    tail's O(num_targets) bitmap pass (small batches keep the host path,
    which is O(neighbors) and faster there -- see bench_batch_scaling);
    ``fused`` forces the choice either way (the host path -- decode +
    ``PAC.from_ids`` -- is kept as the oracle and numpy route).

    ``label_filter`` (:class:`repro.core.labels.LabelFilter` over the
    target vertex table) pushes a label predicate down: the fused path
    ANDs the predicate bitmap inside the kernel dispatch; the host path
    intersects with the host-evaluated filter PAC (the oracle).  Label
    metadata I/O is the caller's to charge (see
    ``neighbor.retrieve_neighbors_batch``), keeping accounting identical
    on every path.

    ``resident`` picks the fused path's transfer regime (see
    :func:`_retrieve_pac_batch_fused`); None follows the
    ``REPRO_DEVICE_RESIDENT`` default.  Residency is purely a transfer
    optimization -- ids, PAC, and IOMeter are bit-identical either way.

    ``delta_ids`` -- the batch's pending neighbor ids from the mutable
    plane (already predicate-filtered by the caller) -- are unioned into
    the returned PAC after the base dispatch: the memtable rows are
    RAM-resident, so they cost no lake I/O and never touch the kernel.
    """
    los = np.asarray(los, np.int64)
    his = np.asarray(his, np.int64)
    if fused is None:
        fused = (engine != "numpy" and num_targets is not None
                 and target_page_size % 32 == 0
                 and len(los) >= FUSED_MIN_RANGES)
    if fused:
        if num_targets is None:
            raise ValueError("fused=True requires num_targets")
        plan = None
        if label_filter is not None:
            plan = label_filter.plan()
            if plan.count != int(num_targets):
                raise ValueError(
                    f"filter covers {plan.count} vertices but the target "
                    f"id space has {num_targets}")
        pac = _retrieve_pac_batch_fused(col, los, his, target_page_size,
                                        int(num_targets), meter, engine,
                                        plan, resident=resident)
    else:
        # non-fused oracle: the same page-granular pruning hull applies
        # (pruned pages hold no qualifying ids, and the intersect below
        # removes exactly those ids on the unpruned path), so meters
        # agree with the fused dispatches bit for bit
        qual = label_filter.qual_range() if label_filter is not None else None
        ids = decode_row_ranges(col, los, his, meter, engine, qual=qual)
        pac = PAC.from_ids(np.unique(ids), target_page_size) if ids.size \
            else PAC(target_page_size)
        if label_filter is not None:
            pac = pac.intersect(label_filter.pac(target_page_size))
    if delta_ids is not None and len(delta_ids):
        pac = pac.union(PAC.from_ids(np.asarray(delta_ids, np.int64),
                                     target_page_size))
    return pac


def retrieve_pac(col: DeltaColumn, lo: int, hi: int, target_page_size: int,
                 meter=None, use_pallas: bool = True) -> PAC:
    """Kernel-engine neighbor retrieval: rows [lo, hi) -> PAC.

    Charges the same page bytes as the numpy path (the I/O plane is
    identical; only the decode compute engine differs).
    """
    return retrieve_pac_batch(col, np.array([lo]), np.array([hi]),
                              target_page_size, meter,
                              engine=("pallas" if use_pallas else "jax"))


def decode_range_to_bitmap(col: DeltaColumn, lo: int, hi: int,
                           base: int, n_words: int,
                           use_pallas: bool = True) -> np.ndarray:
    """Fused path: delta rows [lo, hi) -> one uint32 bitmap over
    [base, base + 32 * n_words). ``base`` must be 32-aligned.

    The row mask is applied by decoding whole pages but marking rows
    outside [lo, hi) invalid via count clamping per page boundary -- for
    simplicity, rows outside the range are zeroed host-side by id slicing
    in the non-fused path; the fused path requires page-aligned [lo, hi)
    (the common case: whole-column label/bitmap scans).
    """
    assert base % 32 == 0
    ps = col.page_size
    assert lo % ps == 0 and (hi % ps == 0 or hi == col.count), \
        "fused path requires page-aligned ranges"
    p0, p1 = lo // ps, -(-hi // ps)
    args = [jnp.asarray(a) for a in pack_pages(col, p0, p1)]
    words_out = next_multiple(n_words, K.WORD_TILE)
    if use_pallas:
        bm = K.fused_decode_bitmap(*args, jnp.int32(base), page_size=ps,
                                   words_out=words_out)
    else:
        bm = R.fused_ref(*args, jnp.int32(base), page_size=ps,
                         words_out=words_out)
    return np.asarray(bm)[:n_words]


def ids_to_bitmap(ids: np.ndarray, base: int, n_words: int,
                  use_pallas: bool = True) -> np.ndarray:
    """Standalone bitmap construction from sorted ids (32-aligned base)."""
    assert base % 32 == 0
    n = next_multiple(max(len(ids), 1), K.ID_TILE)
    padded = np.zeros(n, np.int32)
    padded[:len(ids)] = ids
    words_out = next_multiple(n_words, K.WORD_TILE)
    if use_pallas:
        bm = K.bitmap_pallas(jnp.asarray(padded), jnp.int32(len(ids)),
                             jnp.int32(base), n_words=words_out)
    else:
        bm = R.bitmap_ref(jnp.asarray(padded), jnp.int32(len(ids)),
                          jnp.int32(base), words_out)
    return np.asarray(bm)[:n_words]
