"""Pure-jnp oracle for the pac_decode kernels (same padded inputs)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.encoding import MINIBLOCK
from repro.kernels._pad import note_trace


def decode_pages_ref(first, min_deltas, bit_widths, word_offsets, packed,
                     counts, page_size: int):
    """jnp reference of delta_decode_pallas (vmapped over pages)."""

    def one(first1, mind, bw_arr, woff, pk, count):
        n_deltas = page_size - 1
        idx = jnp.arange(n_deltas, dtype=jnp.int32)
        mini = idx // MINIBLOCK
        within = idx % MINIBLOCK
        bw = jnp.take(bw_arr, mini).astype(jnp.int32)
        bit_pos = within * bw
        word_idx = jnp.take(woff, mini) + bit_pos // 32
        shift = (bit_pos % 32).astype(jnp.uint32)
        words = jnp.take(pk, word_idx)
        mask = jnp.where(bw >= 32, jnp.uint32(0xFFFFFFFF),
                         (jnp.uint32(1) << bw.astype(jnp.uint32)) - 1)
        resid = ((words >> shift) & mask).astype(jnp.int32)
        resid = jnp.where(bw == 0, 0, resid)
        deltas = resid + jnp.take(mind, mini)
        deltas = jnp.where(idx < count - 1, deltas, 0)
        return first1 + jnp.concatenate(
            [jnp.zeros((1,), jnp.int32), jnp.cumsum(deltas)])

    return jax.vmap(one)(first[:, 0], min_deltas, bit_widths, word_offsets,
                         packed, counts[:, 0])


def bitmap_ref(ids, count, base, n_words: int):
    """jnp reference of bitmap_pallas."""
    ids = ids.astype(jnp.int32)
    n = ids.shape[0]
    gidx = jnp.arange(n, dtype=jnp.int32)
    valid = gidx < count
    prev = jnp.concatenate([ids[:1] - 1, ids[:-1]])
    valid = valid & ((ids != prev) | (gidx == 0))
    rel = ids - base
    word = rel >> 5
    bit = jnp.uint32(1) << (rel & 31).astype(jnp.uint32)
    in_range = (rel >= 0) & (word < n_words) & valid
    out = jnp.zeros(n_words, jnp.uint32)
    contrib = jnp.where(in_range, bit, 0)
    return out.at[jnp.where(in_range, word, 0)].add(
        contrib, mode="drop").astype(jnp.uint32)


@functools.partial(jax.jit, static_argnames=("page_size", "n_words"))
def fused_batch_ref(first, min_deltas, bit_widths, word_offsets, packed,
                    counts, cached, gidx, gcount, page_size: int,
                    n_words: int):
    """jnp reference of ``fused_decode_bitmap_batch`` (same outputs).

    Decode goes through the vmapped per-page oracle (miss pages only --
    LRU-hit rows arrive pre-decoded in ``cached``); the bitmap tail is
    the shared rank-lookup (validated against the numpy PAC oracle in
    tests, which is the ground truth for both engines).
    """
    from .kernel import _bitmap_from_gather
    note_trace("fused_batch_ref")
    ids = decode_pages_ref(first, min_deltas, bit_widths, word_offsets,
                           packed, counts, page_size)
    ids = ids.astype(jnp.int32)
    full = jnp.concatenate([ids, cached], axis=0)
    words = _bitmap_from_gather(full, gidx, gcount[0, 0], page_size, n_words)
    return words, ids


@functools.partial(jax.jit, static_argnames=("page_size",))
def gather_decode_ref(first, pos, mind, packed, idx, page_size: int):
    """jnp reference of ``gather_decode_pallas`` (resident-plan gather)."""
    from .kernel import _decode_plan_rows, _gather_rows
    note_trace("gather_decode_ref")
    del page_size  # implied by the plan's per-delta shape
    g = _gather_rows(idx, first, pos, mind, packed)
    return _decode_plan_rows(*g)


@functools.partial(jax.jit, static_argnames=("page_size", "n_words", "p_pad",
                                             "want_ids"))
def fused_gather_batch_ref(first, pos, mind, packed, staged, words_init,
                           page_size: int, n_words: int, p_pad: int,
                           want_ids: bool = True):
    """jnp reference of ``fused_gather_decode_bitmap_batch``.

    ``words_init`` is accepted for signature parity with the pallas
    entry's aliased output buffer and ignored (XLA allocates here).
    Without ``want_ids`` only the bitmap is returned (and XLA never
    materializes the full decode matrix).
    """
    from .kernel import (_bitmap_scatter, _decode_plan_rows, _gather_rows,
                         _split_staged)
    note_trace("fused_gather_batch_ref")
    del words_init, page_size
    idx, gidx, gcount = _split_staged(staged, p_pad)
    g = _gather_rows(idx, first, pos, mind, packed)
    ids = _decode_plan_rows(*g)
    words = _bitmap_scatter(ids, gidx, gcount[0, 0], n_words)
    return (words, ids) if want_ids else words


def fused_ref(first, min_deltas, bit_widths, word_offsets, packed, counts,
              base, page_size: int, words_out: int):
    ids = decode_pages_ref(first, min_deltas, bit_widths, word_offsets,
                           packed, counts, page_size)
    acc = jnp.zeros(words_out, jnp.uint32)
    for p in range(ids.shape[0]):
        acc |= bitmap_ref(ids[p], counts[p, 0], base, words_out)
    return acc
