"""Compiled label-predicate -> bitmap Pallas kernels (paper §5, pushed down).

The filtering plane's device entry points.  A :class:`~repro.core.labels.
CondProgram` is a static (hashable) postfix program over ``k`` RLE label
columns; the kernels specialize on it, so the whole And/Or/Not tree is
unrolled into straight-line word ops at trace time -- no recursion, no
interpretive dispatch on device.

* ``cond_bitmap_pallas`` -- evaluate the program over the label columns'
  interval position lists, a word tile at a time: each bit position finds
  its run per leaf via an in-VMEM binary search (O(log |P|) per lane,
  lane-parallel across the tile), leaf bit planes are combined by the
  unrolled program, and bits pack to uint32 words with a power-of-two dot.
  The O(|P|) storage advantage of the RLE interval lists is preserved; the
  dense per-vertex boolean column is never materialized.

* ``fused_decode_filter_bitmap_batch`` -- the filtering plane fused with
  the batched retrieval plane: miss-page delta decode (+ host-fed cached
  rows for LRU hits, which skip the on-device unpack entirely) -> neighbor
  rank-lookup bitmap -> AND with the predicate bitmap, all in ONE dispatch.
  "Neighbors of batch B having label L" leaves the kernel as bitmap
  planes; neither the decoded ids nor the unfiltered bitmap ever reach the
  host.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.labels import eval_program
from repro.kernels._pad import note_trace
from repro.kernels.pac_decode.kernel import (_bitmap_from_gather,
                                             _bitmap_scatter,
                                             _decode_plan_rows, _gather_rows,
                                             _unpack_and_scan_batch)

WORD_TILE = 64  # words per grid step = 2048 bits


def eval_cond_bits(pos, meta, lanes, ops: Tuple[Tuple, ...]):
    """Compiled program over leaf bit planes, statically unrolled.

    ``pos`` int32[k, n_pos] -- each label's interval position list, padded
    with ``count`` so out-of-range lanes land in the last run; ``meta``
    int32[k, 2] = (first_value, count); ``lanes`` int32[t] -- absolute bit
    positions.  Each label's plane is looked up once (in-VMEM binary
    search, O(log |P|) per lane); the op stream then runs through the one
    shared stack machine (:func:`repro.core.labels.eval_program`) over
    traced jnp planes.  Returns bool[t]; lanes >= count are forced False
    so NOT never sets bits past the row count.
    """
    leaves = []
    for i in range(pos.shape[0]):
        run = jnp.searchsorted(pos[i], lanes,
                               side="right").astype(jnp.int32) - 1
        leaves.append((meta[i, 0] ^ (run & 1)).astype(jnp.int32) == 1)
    return eval_program(ops, leaves) & (lanes < meta[0, 1])


def pack_bits(bits):
    """bool[n_words * 32] -> uint32[n_words] (sum of distinct powers == OR)."""
    b = bits.reshape(-1, 32).astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, :]
    return (b << shifts).sum(axis=1, dtype=jnp.uint32)


def _cond_kernel(pos_ref, meta_ref, out_ref, *, ops):
    wt = pl.program_id(0)
    lanes = wt * WORD_TILE * 32 + jnp.arange(WORD_TILE * 32, dtype=jnp.int32)
    bits = eval_cond_bits(pos_ref[...], meta_ref[...], lanes, ops)
    out_ref[0] = pack_bits(bits)


@functools.partial(jax.jit, static_argnames=("n_words", "ops", "interpret"))
def cond_bitmap_pallas(pos, meta, n_words: int, ops: Tuple[Tuple, ...],
                       interpret: bool = True):
    """pos int32[k, n_pos] (padded with count), meta int32[k, 2] =
    (first_value, count), ``ops`` the static postfix program.  Returns
    uint32[n_words]."""
    note_trace("cond_bitmap_pallas")
    assert n_words % WORD_TILE == 0
    k, n_pos = pos.shape
    kern = functools.partial(_cond_kernel, ops=ops)
    return pl.pallas_call(
        kern,
        grid=(n_words // WORD_TILE,),
        in_specs=[
            pl.BlockSpec((k, n_pos), lambda wt: (0, 0)),
            pl.BlockSpec((k, 2), lambda wt: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, WORD_TILE), lambda wt: (0, wt)),
        out_shape=jax.ShapeDtypeStruct((1, n_words), jnp.uint32),
        interpret=interpret,
    )(pos, meta)[0]


# --------------------------------------------------------------------------
# fused: miss-page decode + cached rows -> neighbor bitmap AND label bitmap
# --------------------------------------------------------------------------

def _fused_filter_kernel(first_ref, mind_ref, bw_ref, woff_ref, packed_ref,
                         count_ref, cached_ref, gidx_ref, gcount_ref,
                         fpos_ref, fmeta_ref, words_ref, ids_ref,
                         *, page_size, n_words, ops):
    ids = _unpack_and_scan_batch(
        first_ref[...], mind_ref[...], bw_ref[...], woff_ref[...],
        packed_ref[...], count_ref[...], page_size)
    ids_ref[...] = ids
    full = jnp.concatenate([ids, cached_ref[...]], axis=0)
    nbr = _bitmap_from_gather(full, gidx_ref[...], gcount_ref[0, 0],
                              page_size, n_words)
    lanes = jnp.arange(n_words * 32, dtype=jnp.int32)
    bits = eval_cond_bits(fpos_ref[...], fmeta_ref[...], lanes, ops)
    words_ref[...] = nbr & pack_bits(bits)


@functools.partial(jax.jit, static_argnames=("page_size", "n_words", "ops",
                                             "interpret"))
def fused_decode_filter_bitmap_batch(first, min_deltas, bit_widths,
                                     word_offsets, packed, counts, cached,
                                     gidx, gcount, fpos, fmeta,
                                     page_size: int, n_words: int,
                                     ops: Tuple[Tuple, ...],
                                     interpret: bool = True):
    """Predicate-pushdown batched retrieval, one dispatch.

    Same contract as ``pac_decode.kernel.fused_decode_bitmap_batch`` (miss
    pages packed, LRU-hit rows pre-decoded in ``cached``, requested-row
    positions in ``gidx`` over the [miss | cached] row order), plus the
    filter inputs of :func:`cond_bitmap_pallas`; the returned ``words``
    are the neighbor bitmap ANDed with the label-predicate bitmap.
    Returns ``(words, ids)`` with ``ids`` the decoded miss-page matrix
    (LRU backfill by-product).
    """
    note_trace("fused_decode_filter_bitmap_batch")
    n, n_mini = min_deltas.shape
    max_words = packed.shape[1]
    c = cached.shape[0]
    t = gidx.shape[0]
    k, n_pos = fpos.shape
    kern = functools.partial(_fused_filter_kernel, page_size=page_size,
                             n_words=n_words, ops=ops)
    return pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
            pl.BlockSpec((n, n_mini), lambda i: (0, 0)),
            pl.BlockSpec((n, n_mini), lambda i: (0, 0)),
            pl.BlockSpec((n, n_mini), lambda i: (0, 0)),
            pl.BlockSpec((n, max_words), lambda i: (0, 0)),
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
            pl.BlockSpec((c, page_size), lambda i: (0, 0)),
            pl.BlockSpec((t,), lambda i: (0,)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((k, n_pos), lambda i: (0, 0)),
            pl.BlockSpec((k, 2), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((n_words,), lambda i: (0,)),
            pl.BlockSpec((n, page_size), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_words,), jnp.uint32),
            jax.ShapeDtypeStruct((n, page_size), jnp.int32),
        ],
        interpret=interpret,
    )(first, min_deltas, bit_widths, word_offsets, packed, counts, cached,
      gidx, gcount, fpos, fmeta)


# --------------------------------------------------------------------------
# device-resident fused filter: page indices + resident predicate plane
# --------------------------------------------------------------------------

def _fused_gather_filter_kernel(first_ref, pos_ref, mind_ref, packed_ref,
                                gidx_ref, gcount_ref, fwords_ref, winit_ref,
                                words_ref, ids_ref=None,
                                *, page_size, n_words):
    del winit_ref  # aliased storage for words_ref; fully overwritten
    ids = _decode_plan_rows(
        first_ref[...], pos_ref[...], mind_ref[...], packed_ref[...])
    if ids_ref is not None:
        ids_ref[...] = ids
    nbr = _bitmap_scatter(ids, gidx_ref[...], gcount_ref[0, 0], n_words)
    words_ref[...] = nbr & fwords_ref[...]


@functools.partial(jax.jit, static_argnames=("page_size", "n_words", "p_pad",
                                             "want_ids", "interpret"))
def fused_gather_decode_filter_bitmap_batch(first, pos, mind, packed, staged,
                                            fwords, words_init,
                                            page_size: int, n_words: int,
                                            p_pad: int,
                                            want_ids: bool = True,
                                            interpret: bool = True):
    """Device-resident predicate-pushdown retrieval, one dispatch.

    Same contract as ``pac_decode.kernel.fused_gather_decode_bitmap_batch``
    (whole-column unpack plan + on-device page gather driven by the
    one-put ``staged`` index vector, decode matrix emitted only under
    ``want_ids`` for LRU backfill), with the
    label plane equally resident: ``fwords`` is the predicate's
    **device-cached bitmap plane** (``FilterPlan.device_bitmap`` -- built
    once per (engine, n_words) from the RLE interval lists, label columns
    are immutable), so the dispatch ships no label bytes and re-evaluates
    no per-lane binary searches -- the kernel ANDs the resident plane
    into the neighbor bitmap.  ``words_init`` is aliased to the ``words``
    output for cross-tick buffer reuse.  Returns ``(words, ids)``
    (``ids`` in ``idx`` order), or ``words`` alone without ``want_ids``.
    """
    note_trace("fused_gather_decode_filter_bitmap_batch")
    from repro.kernels.pac_decode.kernel import _split_staged
    idx, gidx, gcount = _split_staged(staged, p_pad)
    g = _gather_rows(idx, first, pos, mind, packed)
    n = idx.shape[0]
    d = pos.shape[1]
    max_words = packed.shape[1]
    t = gidx.shape[0]
    kern = functools.partial(_fused_gather_filter_kernel,
                             page_size=page_size, n_words=n_words)
    out_specs = [pl.BlockSpec((n_words,), lambda i: (0,))]
    out_shape = [jax.ShapeDtypeStruct((n_words,), jnp.uint32)]
    if want_ids:
        out_specs.append(pl.BlockSpec((n, page_size), lambda i: (0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((n, page_size), jnp.int32))
    out = pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((n, max_words), lambda i: (0, 0)),
            pl.BlockSpec((t,), lambda i: (0,)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((n_words,), lambda i: (0,)),
            pl.BlockSpec((n_words,), lambda i: (0,)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        input_output_aliases={7: 0},
        interpret=interpret,
    )(*g, gidx, gcount, fwords, words_init)
    return tuple(out) if want_ids else out[0]
