"""Pure-jnp oracle for label_filter (same padded inputs, same programs)."""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels._pad import note_trace
from repro.kernels.pac_decode.kernel import (_bitmap_from_gather,
                                             _bitmap_scatter,
                                             _decode_plan_rows, _gather_rows)
from repro.kernels.pac_decode.ref import decode_pages_ref

from .kernel import eval_cond_bits, pack_bits


@functools.partial(jax.jit, static_argnames=("n_words", "ops"))
def cond_bitmap_ref(pos, meta, n_words: int, ops: Tuple[Tuple, ...]):
    """jnp reference of ``cond_bitmap_pallas`` (whole bitmap in one pass)."""
    note_trace("cond_bitmap_ref")
    lanes = jnp.arange(n_words * 32, dtype=jnp.int32)
    return pack_bits(eval_cond_bits(pos, meta, lanes, ops))


@functools.partial(jax.jit, static_argnames=("page_size", "n_words", "ops"))
def fused_filter_batch_ref(first, min_deltas, bit_widths, word_offsets,
                           packed, counts, cached, gidx, gcount, fpos, fmeta,
                           page_size: int, n_words: int,
                           ops: Tuple[Tuple, ...]):
    """jnp reference of ``fused_decode_filter_bitmap_batch``."""
    note_trace("fused_filter_batch_ref")
    ids = decode_pages_ref(first, min_deltas, bit_widths, word_offsets,
                           packed, counts, page_size).astype(jnp.int32)
    full = jnp.concatenate([ids, cached], axis=0)
    nbr = _bitmap_from_gather(full, gidx, gcount[0, 0], page_size, n_words)
    lanes = jnp.arange(n_words * 32, dtype=jnp.int32)
    words = nbr & pack_bits(eval_cond_bits(fpos, fmeta, lanes, ops))
    return words, ids


@functools.partial(jax.jit, static_argnames=("page_size", "n_words", "p_pad",
                                             "want_ids"))
def fused_gather_filter_batch_ref(first, pos, mind, packed, staged, fwords,
                                  words_init, page_size: int, n_words: int,
                                  p_pad: int, want_ids: bool = True):
    """jnp reference of ``fused_gather_decode_filter_bitmap_batch``.

    ``words_init`` is accepted for signature parity with the pallas
    entry's aliased output buffer and ignored (XLA allocates here).
    Without ``want_ids`` only the bitmap is returned.
    """
    from repro.kernels.pac_decode.kernel import _split_staged
    note_trace("fused_gather_filter_batch_ref")
    del words_init, page_size
    idx, gidx, gcount = _split_staged(staged, p_pad)
    g = _gather_rows(idx, first, pos, mind, packed)
    ids = _decode_plan_rows(*g)
    nbr = _bitmap_scatter(ids, gidx, gcount[0, 0], n_words)
    return (nbr & fwords, ids) if want_ids else nbr & fwords
