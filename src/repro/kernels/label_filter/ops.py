"""Jit'd wrappers + storage-plane integration for the filtering plane.

Engine-dispatched label filtering over RLE label columns: a compiled
:class:`~repro.core.labels.CondProgram` evaluates

* on the ``numpy`` engine as the vectorized run-boundary merge
  (:func:`repro.core.labels.program_filter_intervals` -- the host oracle),
* on the ``jax``/``pallas`` engines as an on-device bitmap kernel
  (:mod:`.kernel` / :mod:`.ref`) over the interval position lists.

All engines charge the same I/O -- the referenced labels' RLE metadata --
through :func:`repro.core.labels.charge_label_metadata`, so meters agree
bit-for-bit regardless of where the predicate evaluates.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple, Union

import numpy as np
import jax.numpy as jnp

from repro.core.labels import (Cond, CondProgram, Intervals,
                               bitmap_to_intervals, charge_label_metadata,
                               compile_cond, interval_hull,
                               intervals_to_bitmap,
                               program_filter_intervals)
from repro.core.pac import PAC
from repro.core.vertex import VertexTable

from repro.kernels._pad import next_multiple

from . import kernel as K
from . import ref as R

ENGINES = ("numpy", "jax", "pallas")



@dataclasses.dataclass
class FilterPlan:
    """Padded kernel inputs for one (vertex table, program) pair.

    ``pos`` stacks every leaf label's interval position list, padded with
    ``count`` (the searchsorted sentinel); ``meta[i] = (first_value,
    count)``.  Built once per filter and reused across dispatches (the
    arrays are a few KB -- the whole point of the RLE interval lists).

    Label columns are immutable, so the plan also owns the filtering
    plane's **device residency**: :meth:`device` mirrors the RLE run
    arrays on device once per engine (filter dispatches ship no label
    bytes), and :meth:`device_bitmap` caches the fully evaluated
    predicate bitmap plane on device per (engine, n_words) -- the
    resident fused retrieval path ANDs that plane instead of re-running
    the per-lane run binary searches every dispatch.
    """

    program: CondProgram
    pos: np.ndarray    # int32 [k, n_pos]
    meta: np.ndarray   # int32 [k, 2]
    count: int         # number of rows (vertices)
    #: vertex table the plan was built over (for the lazy qualifying-hull
    #: evaluation; label columns are immutable).
    vt: "VertexTable | None" = dataclasses.field(
        default=None, repr=False, compare=False)
    #: lazily evaluated qualifying hull (see :meth:`qual_range`).
    _qual: "Tuple[int, int] | None" = dataclasses.field(
        default=None, repr=False, compare=False)
    #: engine -> (device pos, device meta); populated lazily, once each.
    _device: Dict[str, Tuple] = dataclasses.field(
        default_factory=dict, repr=False, compare=False)
    #: (engine, n_words[, mesh]) -> device uint32[n_words] predicate
    #: plane (the 3-tuple keys are the mesh-replicated copies consumed by
    #: the sharded dispatches).
    _device_bitmaps: Dict[Tuple, object] = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    @property
    def n_words(self) -> int:
        return -(-self.count // 32)

    def qual_range(self) -> Tuple[int, int]:
        """Half-open hull ``[lo, hi)`` of the qualifying ids.

        The partition plane's statistics pushdown compares partitions'
        min/max id hulls against it: a partition whose values cannot
        land inside the hull contributes nothing after the AND and is
        skipped.  Evaluated on the host (``program_filter_intervals``)
        **lazily, on first use**, and cached for the plan's lifetime --
        only the partition plane consumes it, so the one-shot kernel
        entries (``label_filter_bitmap`` et al.) never pay the host
        merge evaluation.  ``(0, 0)`` when nothing qualifies (every
        partition prunes -- correct: no id can pass the predicate).
        """
        if self._qual is None:
            self._qual = interval_hull(
                *program_filter_intervals(self.vt, self.program))
        return self._qual

    def device(self, engine: str) -> Tuple:
        """Device mirror of the RLE run arrays (once per engine)."""
        arrs = self._device.get(engine)
        if arrs is None:
            arrs = (jnp.asarray(self.pos), jnp.asarray(self.meta))
            self._device[engine] = arrs
        return arrs

    def device_bitmap(self, engine: str, n_words: int):
        """Device-resident predicate bitmap over ``[0, 32 * n_words)``.

        Evaluated once per (engine, n_words) by the cond kernel over the
        device-mirrored run arrays (tile-padded, then sliced); lanes past
        ``count`` are zero, matching the per-dispatch evaluation of the
        non-resident fused kernel bit for bit.
        """
        key = (engine, n_words)
        words = self._device_bitmaps.get(key)
        if words is None:
            pos, meta = self.device(engine)
            padded = next_multiple(max(n_words, 1), K.WORD_TILE)
            fn = K.cond_bitmap_pallas if engine == "pallas" \
                else R.cond_bitmap_ref
            words = fn(pos, meta, n_words=padded,
                       ops=self.program.ops)[:n_words]
            self._device_bitmaps[key] = words
        return words

    def device_bitmap_sharded(self, engine: str, n_words: int, mesh):
        """The predicate plane replicated across a partition mesh.

        Keyed per (engine, n_words, mesh) so the replication crosses the
        host->device boundary once: every shard of the sharded fused
        dispatch ANDs its local copy, and filtered sharded dispatches
        ship no label bytes -- the multi-device analogue of
        :meth:`device_bitmap`'s single-device residency.
        """
        key = (engine, n_words, mesh)
        words = self._device_bitmaps.get(key)
        if words is None:
            import jax
            from jax.sharding import NamedSharding, PartitionSpec
            words = jax.device_put(
                self.device_bitmap(engine, n_words),
                NamedSharding(mesh, PartitionSpec()))
            self._device_bitmaps[key] = words
        return words


def make_plan(vt: VertexTable, cond: Union[Cond, CondProgram]) -> FilterPlan:
    program = compile_cond(cond)
    if not program.labels:
        raise ValueError("condition references no labels")
    rles = [vt.label_rle(n) for n in program.labels]
    n = vt.num_vertices
    n_pos = next_multiple(max(r.positions.size for r in rles), 128)
    pos = np.full((len(rles), n_pos), n, np.int32)
    meta = np.zeros((len(rles), 2), np.int32)
    for i, r in enumerate(rles):
        pos[i, :r.positions.size] = r.positions
        meta[i] = (int(r.first_value), n)
    return FilterPlan(program, pos, meta, n, vt=vt)


def label_filter_bitmap(vt: VertexTable, cond: Union[Cond, CondProgram],
                        meter=None, engine: str = "pallas") -> np.ndarray:
    """Whole-table predicate bitmap: uint32 words over [0, num_vertices)."""
    program = compile_cond(cond)
    charge_label_metadata(vt, program.labels, meter)
    if engine == "numpy":
        return intervals_to_bitmap(program_filter_intervals(vt, program),
                                   vt.num_vertices)
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; want one of {ENGINES}")
    plan = make_plan(vt, program)
    n_words = next_multiple(plan.n_words or 1, K.WORD_TILE)
    if engine == "pallas":
        words = K.cond_bitmap_pallas(jnp.asarray(plan.pos),
                                     jnp.asarray(plan.meta),
                                     n_words=n_words, ops=program.ops)
    else:
        words = R.cond_bitmap_ref(jnp.asarray(plan.pos),
                                  jnp.asarray(plan.meta),
                                  n_words=n_words, ops=program.ops)
    return np.asarray(words)[:plan.n_words]


def label_filter_intervals(vt: VertexTable, cond: Union[Cond, CondProgram],
                           meter=None, engine: str = "pallas") -> Intervals:
    """Qualifying half-open intervals; engine-dispatched, same accounting."""
    program = compile_cond(cond)
    if engine == "numpy":
        charge_label_metadata(vt, program.labels, meter)
        return program_filter_intervals(vt, program)
    return bitmap_to_intervals(
        label_filter_bitmap(vt, program, meter, engine), vt.num_vertices)


def label_filter_pac(vt: VertexTable, cond: Union[Cond, CondProgram],
                     page_size: int, meter=None,
                     engine: str = "pallas") -> PAC:
    """Qualifying ids as a PAC over ``page_size`` pages (bitmap planes on
    kernel engines -- no host-side id materialization).  One-shot wrapper
    around :meth:`repro.core.labels.LabelFilter.pac`, which owns the
    plane-selection logic (and the memoization for long-lived filters)."""
    from repro.core.labels import LabelFilter
    f = LabelFilter(vt, cond)
    f.charge(meter)
    return f.pac(page_size, engine)
