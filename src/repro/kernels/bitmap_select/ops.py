"""Jit'd wrapper: PAC + property pages -> compacted selected values."""
from __future__ import annotations

from typing import Dict

import numpy as np
import jax.numpy as jnp

from repro.core.pac import PAC

from . import kernel as K
from . import ref as R


def select_from_pages(pac: PAC, page_values: Dict[int, np.ndarray],
                      use_pallas: bool = True) -> np.ndarray:
    """Batched selection pushdown over all of a PAC's non-empty pages."""
    pages = pac.pages()
    if not pages:
        return np.zeros(0, np.float32)
    ps = pac.page_size
    wpp = ps // 32
    vals = np.zeros((len(pages), ps), np.float32)
    words = np.zeros((len(pages), wpp), np.uint32)
    for i, p in enumerate(pages):
        pv = np.asarray(page_values[p], np.float32)
        vals[i, :len(pv)] = pv
        words[i, :] = pac.bitmaps[p][:wpp]
    fn = K.bitmap_select_pallas if use_pallas else \
        (lambda v, w, page_size, **kw: R.bitmap_select_ref(v, w, page_size))
    out, counts = fn(jnp.asarray(vals), jnp.asarray(words), page_size=ps)
    out, counts = np.asarray(out), np.asarray(counts)[:, 0]
    return np.concatenate([out[i, :counts[i]] for i in range(len(pages))])
