"""Bitmap selection pushdown Pallas kernel (paper §4.3 / [45]).

Given one data page of property values and the page's PAC bitmap, emit the
selected values *compacted to the front* plus the match count -- the TPU
form of selection pushdown: the page is scanned once in VMEM, the bitmap is
expanded to a lane mask, and an in-VMEM prefix sum computes each selected
value's output slot (scatter within the tile).  HBM sees only the page read
and the compacted write.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _select_kernel(vals_ref, words_ref, out_ref, cnt_ref, *, page_size):
    vals = vals_ref[0]
    words = words_ref[0]
    lanes = jnp.arange(page_size, dtype=jnp.int32)
    bit = (jnp.take(words, lanes >> 5) >> (lanes & 31).astype(jnp.uint32)) \
        & jnp.uint32(1)
    mask = bit.astype(jnp.int32)
    pos = jnp.cumsum(mask) - 1            # output slot per selected lane
    n = mask.sum()
    out = jnp.zeros_like(vals)
    out = out.at[jnp.where(mask == 1, pos, page_size)].set(
        vals, mode="drop")
    out_ref[0] = out
    cnt_ref[0, 0] = n


@functools.partial(jax.jit, static_argnames=("page_size", "interpret"))
def bitmap_select_pallas(vals, words, page_size: int, interpret: bool = True):
    """vals f32[n_pages, page_size]; words uint32[n_pages, page_size//32].

    Returns (compacted f32[n_pages, page_size], counts int32[n_pages, 1]).
    """
    n = vals.shape[0]
    wpp = page_size // 32
    kern = functools.partial(_select_kernel, page_size=page_size)
    return pl.pallas_call(
        kern,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, page_size), lambda i: (i, 0)),
            pl.BlockSpec((1, wpp), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, page_size), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, page_size), vals.dtype),
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
        ],
        interpret=interpret,
    )(vals, words)
