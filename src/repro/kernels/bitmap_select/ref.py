"""Pure-jnp oracle for bitmap_select."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bitmap_select_ref(vals, words, page_size: int):
    def one(v, w):
        lanes = jnp.arange(page_size, dtype=jnp.int32)
        bit = (jnp.take(w, lanes >> 5) >> (lanes & 31).astype(jnp.uint32)) \
            & jnp.uint32(1)
        mask = bit.astype(jnp.int32)
        pos = jnp.cumsum(mask) - 1
        out = jnp.zeros_like(v)
        out = out.at[jnp.where(mask == 1, pos, page_size)].set(v, mode="drop")
        return out, mask.sum()[None]

    outs, counts = jax.vmap(one)(vals, words)
    return outs, counts
