"""GraphAr core: the paper's storage scheme as a composable library."""
from .builder import Graph, GraphArBuilder, TransformTiming
from .edge import (BY_DST, BY_SRC, ENC_GRAPHAR, ENC_OFFSET, ENC_PLAIN,
                   AdjacencyTable, EdgeTable, build_adjacency)
from .encoding import (DEFAULT_PAGE_SIZE, DeltaColumn, DeltaPage,
                       PackedPages, PagePruneStats, RleColumn, build_packed,
                       delta_decode_column, delta_decode_page,
                       delta_encode_column, delta_encode_page,
                       hull_intersects, pack_column, page_hulls,
                       prune_page_list, rle_decode_bool, rle_encode_bool)
from .frontier import Frontier
from .labels import (And, Cond, CondProgram, L, LabelFilter, Not, Or,
                     bitmap_to_intervals, charge_label_metadata,
                     compile_cond, complex_filter_intervals, eval_program,
                     evaluate_filter_intervals, filter_binary_columns,
                     filter_rle_interval, filter_string, interval_hull,
                     intervals_count, intervals_to_bitmap, intervals_to_ids,
                     intervals_to_pac, program_filter_intervals,
                     simple_filter_intervals)
from .neighbor import (decode_edge_ranges, degrees_topk, fetch_properties,
                       fetch_properties_batch, k_hop, neighbor_ids_batch,
                       neighbor_properties, neighbor_properties_batch,
                       retrieve_neighbors, retrieve_neighbors_batch,
                       retrieve_neighbors_scan)
from .numeric import NumCmp, NumericFilter, NumProp
from .pac import (PAC, bitmap_to_ids, ids_to_bitmap, pages_union,
                  words_per_page)
from .page_cache import DecodedPageCache, attach_page_cache, live_cache
from .partition import (Partition, PartitionedColumn, ensure_default_partitions,
                        live_partitions, partition_bounds, partition_column)
from .schema import EdgeTypeSchema, GraphSchema, PropertySchema, VertexTypeSchema
from .storage import ESSD, MEDIA, OSS, TMPFS, GraphStore, IOMeter, MediaModel
from .table import (BoolPlainColumn, BoolRleColumn, DeltaIntColumn,
                    PlainColumn, StringColumn, Table, TokensColumn)
from .vertex import (LABEL_ENC_PLAIN, LABEL_ENC_RLE, LABEL_ENC_STRING,
                     VertexTable)
