"""Explicit graph partitions over delta columns (GraphAr chunk style).

GraphAr's layout is fundamentally partitioned: vertex chunks and edge
chunks are keyed by contiguous source-vertex ranges, and because edges
are sorted by source vertex, a source range maps to a contiguous edge-row
range -- i.e. to a contiguous **page range** of the edge value column.
This module makes that unit explicit: a :class:`Partition` is a
page-aligned contiguous slice of a :class:`~repro.core.encoding.DeltaColumn`
with its own packed-page batch arrays
(:func:`~repro.core.encoding.build_packed` over the slice) and value
statistics, and a :class:`PartitionedColumn` is the ordered list of
partitions covering the whole column.

The partition is the unit of device placement: the sharded retrieval
plane (``kernels/pac_decode/ops``) stacks the partitions' unpack plans
into one array sharded across a 1-D device mesh (partition ``k`` lives on
device ``k * g // n_parts``), buckets each dispatch's page-index and
row-position vectors per partition on the host, runs the fused
decode->bitmap kernels under ``shard_map``, and OR-merges the
per-partition bitmap planes into one PAC.  The monolithic PR 4 path is
exactly the degenerate 1-partition case (``partition_column(col, 1)``
routes straight back to it).

Partition pruning:

* **range pruning** -- partitions containing none of a dispatch's pages
  are skipped outright (their edge-row range cannot intersect the
  batch).  This is meter-neutral by construction: a pruned partition had
  nothing to charge.
* **statistics pruning** -- each partition (and page) records the
  min/max id hull of its values at pack time; with a label filter pushed
  down, partitions whose hull cannot intersect the predicate's
  qualifying id range are skipped too (their neighbors would be ANDed
  away inside the kernel).  This *reduces* I/O charged relative to the
  unpartitioned path -- the first step of the ROADMAP's
  statistics-pushdown item -- and is therefore observable in the meter
  (ids stay bit-identical).

Both kinds are counted in :attr:`PartitionedColumn.partitions_pruned`
(and ``stats_pruned`` for the second), surfaced through
``GraphRetriever.stats()`` / ``ServeEngine.stats()``.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .encoding import DeltaColumn, PackedPages, build_packed, hull_intersects

#: sharded-retrieval default: ``REPRO_PARTITIONS=N`` partitions every
#: column the retrieval plane packs (0 / unset keeps the monolithic
#: column; explicit ``partition_column`` / ``partitions=`` override).
DEFAULT_PARTITIONS = int(os.environ.get("REPRO_PARTITIONS", "0") or 0)


@dataclasses.dataclass
class Partition:
    """One page-aligned contiguous slice of a column.

    ``page_lo``/``page_hi`` are global page indices (half-open);
    ``row_lo``/``row_hi`` the covered rows; ``vmin``/``vmax`` the value
    hull over the slice's pages (empty hull = (0, -1)).  ``packed`` holds
    the slice's own batch arrays with **local** page numbering
    (0 .. page_hi - page_lo), the unit a device shard consumes.
    """

    index: int
    page_lo: int
    page_hi: int
    row_lo: int
    row_hi: int
    vmin: int
    vmax: int
    packed: PackedPages
    #: False when any non-empty page in the slice carries the empty-hull
    #: sentinel -- e.g. a column deserialized from a pre-stats ``.gar``
    #: file.  Unknown statistics must never prune: the hull then claims
    #: to intersect everything.
    stats_known: bool = True
    #: device this partition's plan shard lands on (set when the stacked
    #: device plan is placed; informational).
    device: "object | None" = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def n_pages(self) -> int:
        return self.page_hi - self.page_lo

    def intersects_range(self, lo: int, hi: int) -> bool:
        """Whether the value hull can intersect half-open ``[lo, hi)``.

        An unknown hull (``stats_known=False``) conservatively intersects
        everything -- pruning is an optimization and may only ever fire
        on hard evidence.  The intersection predicate itself is the
        shared :func:`repro.core.encoding.hull_intersects` (one
        definition across partition, page, and delta-segment
        granularities)."""
        if not self.stats_known:
            return True
        return hull_intersects(self.vmin, self.vmax, lo, hi)


def partition_bounds(n_pages: int, n_parts: int) -> np.ndarray:
    """Even page split: ``n_parts + 1`` boundaries over ``[0, n_pages]``.

    Mirrors GraphAr's fixed-size chunking: every partition gets
    ``ceil(n_pages / n_parts)`` pages except a short tail.  With fewer
    pages than partitions the trailing partitions are empty (degenerate
    but legal -- they never receive work).
    """
    span = -(-max(n_pages, 1) // n_parts)
    b = np.minimum(np.arange(n_parts + 1, dtype=np.int64) * span, n_pages)
    return b


@dataclasses.dataclass
class PartitionedColumn:
    """A delta column as an ordered list of page-aligned partitions.

    Built once per ``(column version, n_parts)`` by
    :func:`partition_column` and cached on the column.  Holds the
    per-partition :class:`~repro.core.encoding.PackedPages` (+ their
    unpack plans), the aggregate value statistics, the pruning/dispatch
    counters, and the engine-keyed **stacked device plan**: all
    partitions' unpack plans padded to a common page count and placed as
    one array sharded across a 1-D device mesh, so each device holds
    exactly its partitions' pages (the multi-device generalization of
    ``PackedPages.device_plan``).
    """

    col: DeltaColumn
    bounds: np.ndarray              # int64 [n_parts + 1], page units
    parts: List[Partition]
    version: int = 0
    # -- dispatch counters (reset via reset_stats) --------------------------
    dispatches: int = dataclasses.field(default=0, compare=False)
    partitions_pruned: int = dataclasses.field(default=0, compare=False)
    stats_pruned: int = dataclasses.field(default=0, compare=False)
    #: engine -> (mesh, stacked device plan, pmax); one placement each.
    _device_plans: Dict[str, Tuple] = dataclasses.field(
        default_factory=dict, repr=False, compare=False)
    #: host->device placements performed (one per engine populated).
    device_transfers: int = dataclasses.field(
        default=0, repr=False, compare=False)

    @property
    def n_parts(self) -> int:
        return len(self.parts)

    @property
    def page_size(self) -> int:
        return self.col.page_size

    @property
    def pmax(self) -> int:
        """Pages per partition slot in the stacked plan (the padding
        target: the largest partition's page count)."""
        return max((p.n_pages for p in self.parts), default=0) or 1

    @property
    def stack_rows(self) -> int:
        """Rows of the stacked plan (``n_parts * pmax``) -- the natural
        upper bound for any dispatch's page-padding class: padding a
        gather past the whole stack is pure wasted decode."""
        return self.n_parts * self.pmax

    # -- page bookkeeping ---------------------------------------------------
    def part_of_pages(self, pages: np.ndarray) -> np.ndarray:
        """Partition index of each global page (vectorized)."""
        pages = np.asarray(pages, np.int64)
        return np.searchsorted(self.bounds, pages, side="right") - 1

    def prune(self, pages: np.ndarray,
              qual_range: Optional[Tuple[int, int]] = None,
              owner: Optional[np.ndarray] = None
              ) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        """One dispatch's partition pruning (and counters).

        Returns ``(owner, mask)``: each kept page's partition index plus
        a kept-page mask, or ``mask=None`` when every page survives (the
        overwhelmingly common case, kept allocation-free -- this runs on
        the per-dispatch hot path).  Partitions holding none of ``pages``
        are range-pruned (counted only; their absence costs nothing);
        with ``qual_range`` (a predicate's qualifying id hull, half-open)
        partitions whose value hull cannot intersect it are
        statistics-pruned and their pages drop out of the mask -- they
        are neither decoded nor charged.
        """
        self.dispatches += 1
        if owner is None:
            owner = self.part_of_pages(pages)
        present = np.unique(owner)
        if qual_range is not None:
            lo, hi = qual_range
            keep = np.asarray([self.parts[int(k)].intersects_range(lo, hi)
                               for k in present], bool)
            self.stats_pruned += int((~keep).sum())
            live = present[keep]
            self.partitions_pruned += self.n_parts - int(live.size)
            if live.size < present.size:
                mask = np.isin(owner, live)
                return owner[mask], mask
            return owner, None
        self.partitions_pruned += self.n_parts - int(present.size)
        return owner, None

    # -- device plane -------------------------------------------------------
    _mesh_sizes: Dict[int, int] = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    def mesh_size(self, n_devices: int) -> int:
        """Mesh width for this partition count: the largest divisor ``g``
        of ``n_parts`` with ``g <= n_devices``, so every device owns
        exactly ``n_parts / g`` partitions.  One device (the degenerate
        mesh) is always legal.  Memoized -- this sits on the dispatch hot
        path."""
        g = self._mesh_sizes.get(n_devices)
        if g is None:
            n = self.n_parts
            g = max(d for d in range(1, n_devices + 1) if n % d == 0)
            self._mesh_sizes[n_devices] = g
        return g

    def mesh_devices(self, devices: Sequence) -> List:
        """Devices of the 1-D partition mesh (see :meth:`mesh_size`)."""
        return list(devices[:self.mesh_size(len(devices))])

    def stacked_plan_host(self) -> Tuple[np.ndarray, ...]:
        """All partitions' unpack plans stacked partition-major.

        Row ``k * pmax + j`` is partition ``k``'s plan row ``j`` (zero
        rows pad partitions shorter than ``pmax``); sharding this axis
        across the mesh gives each device exactly its partitions' pages.
        """
        pmax = self.pmax
        plans = [p.packed.unpack_plan() for p in self.parts]
        out = []
        for a_idx in range(4):  # (first, pos, mind, packed)
            ref = plans[0][a_idx]
            stack = np.zeros((self.n_parts * pmax,) + ref.shape[1:],
                             ref.dtype)
            for k, pl in enumerate(plans):
                stack[k * pmax: k * pmax + pl[a_idx].shape[0]] = pl[a_idx]
            out.append(stack)
        return tuple(out)

    def device_plan(self, engine: str) -> Tuple:
        """Engine-keyed sharded device plan: ``(mesh, arrays, pmax)``.

        Placed once per (column build, engine): the stacked plan crosses
        the host->device boundary a single time, sharded so partition
        ``k`` lives on mesh device ``k // (n_parts / g)``; every
        subsequent dispatch ships only the per-device staged index
        vectors.  Records each partition's device for observability.
        """
        plan = self._device_plans.get(engine)
        if plan is None:
            import jax
            from jax.sharding import Mesh, NamedSharding, PartitionSpec
            devs = self.mesh_devices(jax.devices())
            mesh = Mesh(np.array(devs), ("part",))
            arrays = tuple(
                jax.device_put(a, NamedSharding(
                    mesh, PartitionSpec("part", *(None,) * (a.ndim - 1))))
                for a in self.stacked_plan_host())
            ppd = self.n_parts // len(devs)
            for p in self.parts:
                p.device = devs[p.index // ppd]
            plan = (mesh, arrays, self.pmax)
            self._device_plans[engine] = plan
            self.device_transfers += 1
        return plan

    def device_plan_single(self, engine: str) -> Tuple:
        """The stacked plan on one (the default) device.

        The degenerate single-shard dispatch: below the sharding
        threshold -- or on a one-device host -- the partition plane
        dispatches the monolithic resident kernels directly over this
        placement with block-local page indices, paying no ``shard_map``
        launch overhead.  Placed once per engine.  When the sharded
        placement already exists on a one-device mesh it is reused
        outright (same bytes, same device); with a real multi-device
        mesh the two placements are distinct, so a workload whose
        dispatch sizes straddle ``SHARD_MIN_PAGES`` keeps both copies
        resident -- 2x the column's device footprint, a deliberate
        wall-time-for-memory trade (pin the threshold to 0 or huge to
        hold one copy)."""
        key = ("single", engine)
        plan = self._device_plans.get(key)
        if plan is None:
            sharded = self._device_plans.get(engine)
            if sharded is not None and sharded[0].devices.size == 1:
                plan = (sharded[1], sharded[2])  # same device, same bytes
            else:
                import jax.numpy as jnp
                arrays = tuple(jnp.asarray(a)
                               for a in self.stacked_plan_host())
                plan = (arrays, self.pmax)
                self.device_transfers += 1
            if self.parts and self.parts[0].device is None:
                import jax
                for p in self.parts:
                    p.device = jax.devices()[0]
            self._device_plans[key] = plan
        return plan

    # -- observability ------------------------------------------------------
    def reset_stats(self) -> None:
        self.dispatches = 0
        self.partitions_pruned = 0
        self.stats_pruned = 0

    def stats(self) -> Dict[str, object]:
        return {
            "n_parts": self.n_parts,
            "dispatches": self.dispatches,
            "partitions_pruned": self.partitions_pruned,
            "stats_pruned": self.stats_pruned,
            "devices": sorted({str(p.device) for p in self.parts
                               if p.device is not None}),
            "transfers": self.device_transfers,
            "version": self.version,
        }


def partition_column(col: DeltaColumn, n_parts: int) -> "PartitionedColumn | None":
    """Partition ``col`` into ``n_parts`` page-aligned slices (cached).

    Sets the column's requested partition count and builds (or returns)
    the cached :class:`PartitionedColumn` for the current version.
    ``n_parts <= 1`` detaches the partition plane -- the monolithic
    PR 4 path *is* the 1-partition case, so the retrieval plane routes
    straight to it -- and returns None.
    """
    if n_parts <= 1:
        col.partitions = 0
        col.partition_cache = None
        return None
    col.partitions = int(n_parts)
    return live_partitions(col)


def ensure_default_partitions(col: DeltaColumn) -> None:
    """Attach the ``REPRO_PARTITIONS`` environment default to a column
    with no explicit partitioning (an explicit :func:`partition_column`
    count wins)."""
    if DEFAULT_PARTITIONS > 1 and not getattr(col, "partitions", 0):
        partition_column(col, DEFAULT_PARTITIONS)


def live_partitions(col: DeltaColumn) -> "PartitionedColumn | None":
    """The column's partition plane, coherent with its current version.

    Rebuilds lazily after a version bump (writers only touch the column;
    derived partition packs follow), mirroring ``pack_column`` /
    ``live_cache`` keying.  Returns None when partitioning is off.
    """
    n_parts = getattr(col, "partitions", 0)
    if n_parts <= 1:
        return None
    cached = col.partition_cache
    if cached is not None and cached.version == col.version \
            and cached.n_parts == n_parts:
        return cached
    n_pages = len(col.pages)
    bounds = partition_bounds(n_pages, n_parts)
    ps = col.page_size
    parts: List[Partition] = []
    for k in range(n_parts):
        p0, p1 = int(bounds[k]), int(bounds[k + 1])
        pages = col.pages[p0:p1]
        packed = build_packed(pages, ps, version=col.version)
        nonempty = [p for p in pages if p.count]
        vmin = min((p.vmin for p in nonempty), default=0)
        vmax = max((p.vmax for p in nonempty), default=-1)
        # a non-empty page with the empty-hull sentinel means its stats
        # were never recorded (pre-stats serialized file): pruning on
        # such a partition would be guesswork, so mark the hull unknown
        known = all(p.vmax >= p.vmin for p in nonempty)
        row_hi = p1 * ps if p1 < n_pages else col.count
        parts.append(Partition(k, p0, p1, p0 * ps, row_hi, vmin, vmax,
                               packed, stats_known=known))
    col.partition_cache = PartitionedColumn(col, bounds, parts,
                                            version=col.version)
    return col.partition_cache
