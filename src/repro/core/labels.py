"""Label filtering (paper §5).

Simple conditions (Definition 3): the RLE interval list ``P`` of a label
column directly yields the qualifying intervals -- "select all odd intervals
or all even intervals" -- in ``O(|P|)`` instead of ``O(n)``.

Complex conditions (Definition 4): a UDF ``f`` over ``k`` labels.  Theorem 1:
if no interval-list position breaks ``[s, e)``, all vertices inside share all
``k`` label values, so one representative evaluation suffices.  The
merge-based algorithm merges the ``k`` sorted position lists into one list
``P`` (we use a vectorized sorted-union; the k-way heap merge of the paper is
a CPU idiom) and calls the UDF once per merged interval -- vectorized here as
a single batched evaluation over all representatives.

Baselines reproduced for the paper's figures:
* ``filter_string``        -- decode concatenated label strings, match per vertex
* ``filter_binary_plain``  -- per-vertex boolean column scan
* ``filter_binary_rle``    -- RLE decode to per-vertex booleans, then scan
* ``filter_rle_interval``  -- GraphAr: interval selection / merge (this module)
"""
from __future__ import annotations

from typing import Callable, Dict, List, Sequence, Tuple

import numpy as np

from .encoding import RleColumn
from .pac import PAC
from .vertex import VertexTable, label_col_name

Intervals = Tuple[np.ndarray, np.ndarray]  # (starts, ends), half-open


# --------------------------------------------------------------------------
# condition expression mini-language (Cypher/GQL label predicates)
# --------------------------------------------------------------------------

class Cond:
    """Label condition AST: (person:Asian&Enrollee), (A&!B)|C, ..."""

    def labels(self) -> List[str]:
        raise NotImplementedError

    def evaluate(self, env: Dict[str, np.ndarray]) -> np.ndarray:
        raise NotImplementedError

    def __and__(self, other: "Cond") -> "Cond":
        return And(self, other)

    def __or__(self, other: "Cond") -> "Cond":
        return Or(self, other)

    def __invert__(self) -> "Cond":
        return Not(self)


class L(Cond):
    def __init__(self, name: str):
        self.name = name

    def labels(self) -> List[str]:
        return [self.name]

    def evaluate(self, env):
        return env[self.name]

    def __repr__(self):
        return f":{self.name}"


class And(Cond):
    def __init__(self, a: Cond, b: Cond):
        self.a, self.b = a, b

    def labels(self):
        return self.a.labels() + self.b.labels()

    def evaluate(self, env):
        return self.a.evaluate(env) & self.b.evaluate(env)

    def __repr__(self):
        return f"({self.a}&{self.b})"


class Or(Cond):
    def __init__(self, a: Cond, b: Cond):
        self.a, self.b = a, b

    def labels(self):
        return self.a.labels() + self.b.labels()

    def evaluate(self, env):
        return self.a.evaluate(env) | self.b.evaluate(env)

    def __repr__(self):
        return f"({self.a}|{self.b})"


class Not(Cond):
    def __init__(self, a: Cond):
        self.a = a

    def labels(self):
        return self.a.labels()

    def evaluate(self, env):
        return ~self.a.evaluate(env)

    def __repr__(self):
        return f"!{self.a}"


# --------------------------------------------------------------------------
# GraphAr fast paths
# --------------------------------------------------------------------------

def simple_filter_intervals(rle: RleColumn, exists: bool = True) -> Intervals:
    """Definition 3 via odd/even interval selection -- O(|P|)."""
    return rle.interval_starts(exists)


def merge_positions(rles: Sequence[RleColumn]) -> np.ndarray:
    """Merged breakpoint list P of k interval lists (sorted unique union)."""
    parts = [r.positions for r in rles]
    return np.unique(np.concatenate(parts))


def label_values_at(rle: RleColumn, points: np.ndarray) -> np.ndarray:
    """Label value at each representative vertex (vectorized Theorem 1).

    Run index of point p is ``searchsorted(positions, p, 'right') - 1``;
    value = first_value ^ (run_idx & 1).
    """
    run = np.searchsorted(rle.positions, points, side="right") - 1
    return (np.asarray(rle.first_value, bool)
            ^ ((run & 1).astype(bool)))


def complex_filter_intervals(vt: VertexTable, cond: Cond) -> Intervals:
    """Merge-based complex filtering (paper §5.2, Fig. 7)."""
    names = list(dict.fromkeys(cond.labels()))
    rles = [vt.label_rle(n) for n in names]
    merged = merge_positions(rles)
    if merged.size < 2:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    reps = merged[:-1]  # representative = interval start (Theorem 1)
    env = {n: label_values_at(r, reps) for n, r in zip(names, rles)}
    keep = np.asarray(cond.evaluate(env), bool)
    return _coalesce(merged[:-1][keep], merged[1:][keep])


def _coalesce(starts: np.ndarray, ends: np.ndarray) -> Intervals:
    """Merge adjacent qualifying intervals (ends[i] == starts[i+1])."""
    if starts.size == 0:
        return starts.astype(np.int64), ends.astype(np.int64)
    new_run = np.ones(starts.size, bool)
    new_run[1:] = starts[1:] != ends[:-1]
    run_id = np.cumsum(new_run) - 1
    out_starts = starts[new_run]
    out_ends = np.zeros_like(out_starts)
    np.maximum.at(out_ends, run_id, ends)
    return out_starts.astype(np.int64), out_ends.astype(np.int64)


def intervals_to_pac(iv: Intervals, n: int, page_size: int) -> PAC:
    return PAC.from_intervals(iv[0], iv[1], n, page_size)


def intervals_to_ids(iv: Intervals) -> np.ndarray:
    """Concatenated ids of half-open intervals, fully vectorized.

    One repeat/cumsum construction instead of a Python loop of
    ``np.arange`` per interval: element ``j`` of the output is
    ``starts[i] + (j - offset[i])`` for its interval ``i``.
    """
    starts = np.asarray(iv[0], np.int64)
    ends = np.asarray(iv[1], np.int64)
    lengths = np.maximum(ends - starts, 0)
    total = int(lengths.sum())
    if total == 0:
        return np.zeros(0, np.int64)
    keep = lengths > 0
    s, k = starts[keep], lengths[keep]
    within = np.arange(total, dtype=np.int64) \
        - np.repeat(np.cumsum(k) - k, k)
    return np.repeat(s, k) + within


def intervals_count(iv: Intervals) -> int:
    return int((iv[1] - iv[0]).sum())


def filter_rle_interval(vt: VertexTable, cond: Cond, meter=None) -> Intervals:
    """GraphAr entry point: simple conditions take the O(|P|) path."""
    if meter is not None:
        for n in dict.fromkeys(cond.labels()):
            vt.label_column(n).read_range(0, 0, meter)  # charge metadata
    if isinstance(cond, L):
        return simple_filter_intervals(vt.label_rle(cond.name), True)
    if isinstance(cond, Not) and isinstance(cond.a, L):
        return simple_filter_intervals(vt.label_rle(cond.a.name), False)
    return complex_filter_intervals(vt, cond)


# --------------------------------------------------------------------------
# baselines (paper §6.3)
# --------------------------------------------------------------------------

def filter_string(vt: VertexTable, cond: Cond, meter=None) -> np.ndarray:
    """'string' baseline: split each vertex's label string, then match."""
    col = vt.table["<labels>"]
    strings = col.read_all(meter)
    names = list(dict.fromkeys(cond.labels()))
    n = vt.num_vertices
    env = {m: np.zeros(n, bool) for m in names}
    for i, s in enumerate(strings):
        if not s:
            continue
        present = s.split("|")
        for m in names:
            if m in present:
                env[m][i] = True
    return np.flatnonzero(cond.evaluate(env)).astype(np.int64)


def filter_binary_columns(vt: VertexTable, cond: Cond,
                          meter=None) -> np.ndarray:
    """'binary (plain)' / 'binary (RLE)' baselines: decode per-vertex bools
    for each referenced label column, evaluate per vertex."""
    names = list(dict.fromkeys(cond.labels()))
    env = {m: np.asarray(vt.label_column(m).read_all(meter), bool)
           for m in names}
    return np.flatnonzero(cond.evaluate(env)).astype(np.int64)
