"""Label filtering (paper §5).

Simple conditions (Definition 3): the RLE interval list ``P`` of a label
column directly yields the qualifying intervals -- "select all odd intervals
or all even intervals" -- in ``O(|P|)`` instead of ``O(n)``.

Complex conditions (Definition 4): a UDF ``f`` over ``k`` labels.  Theorem 1:
if no interval-list position breaks ``[s, e)``, all vertices inside share all
``k`` label values, so one representative evaluation suffices.  The
merge-based algorithm merges the ``k`` sorted position lists into one list
``P`` (we use a vectorized sorted-union; the k-way heap merge of the paper is
a CPU idiom) and calls the UDF once per merged interval -- vectorized here as
a single batched evaluation over all representatives.

The filtering plane (PR 3): a :class:`Cond` tree is **compiled** to a flat
postfix program (:func:`compile_cond`) evaluated by a stack machine with no
per-node recursion -- the same program runs over numpy boolean planes at run
representatives (host engine), uint32 bitmap words, or jnp planes inside the
``kernels/label_filter`` kernels.  :class:`LabelFilter` bundles a vertex
table with a compiled predicate so retrieval paths can push the filter down
into the fused decode->bitmap dispatch (see ``core/neighbor.py``).

Baselines reproduced for the paper's figures:
* ``filter_string``        -- decode concatenated label strings, match per vertex
* ``filter_binary_plain``  -- per-vertex boolean column scan
* ``filter_binary_rle``    -- RLE decode to per-vertex booleans, then scan
* ``filter_rle_interval``  -- GraphAr: interval selection / merge (this module)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence, Tuple

import numpy as np

from .encoding import RleColumn, hull_intersects  # noqa: F401 (re-export)
from .pac import PAC
from .vertex import VertexTable, label_col_name

Intervals = Tuple[np.ndarray, np.ndarray]  # (starts, ends), half-open


def interval_hull(starts, ends) -> Tuple[int, int]:
    """Half-open hull ``[lo, hi)`` of a sorted interval list.

    The one home for the qualifying-hull derivation shared by the label
    plane (``FilterPlan.qual_range``), the numeric plane
    (:mod:`repro.core.numeric`), and their consumers -- ``(0, 0)`` when
    nothing qualifies (everything prunes; no id can pass)."""
    return (int(starts[0]), int(ends[-1])) if len(starts) else (0, 0)




# --------------------------------------------------------------------------
# condition expression mini-language (Cypher/GQL label predicates)
# --------------------------------------------------------------------------

class Cond:
    """Label condition AST: (person:Asian&Enrollee), (A&!B)|C, ..."""

    def labels(self) -> List[str]:
        raise NotImplementedError

    def evaluate(self, env: Dict[str, np.ndarray]) -> np.ndarray:
        raise NotImplementedError

    def __and__(self, other: "Cond") -> "Cond":
        return And(self, other)

    def __or__(self, other: "Cond") -> "Cond":
        return Or(self, other)

    def __invert__(self) -> "Cond":
        return Not(self)


class L(Cond):
    def __init__(self, name: str):
        self.name = name

    def labels(self) -> List[str]:
        return [self.name]

    def evaluate(self, env):
        return env[self.name]

    def __repr__(self):
        return f":{self.name}"


class And(Cond):
    def __init__(self, a: Cond, b: Cond):
        self.a, self.b = a, b

    def labels(self):
        return self.a.labels() + self.b.labels()

    def evaluate(self, env):
        return self.a.evaluate(env) & self.b.evaluate(env)

    def __repr__(self):
        return f"({self.a}&{self.b})"


class Or(Cond):
    def __init__(self, a: Cond, b: Cond):
        self.a, self.b = a, b

    def labels(self):
        return self.a.labels() + self.b.labels()

    def evaluate(self, env):
        return self.a.evaluate(env) | self.b.evaluate(env)

    def __repr__(self):
        return f"({self.a}|{self.b})"


class Not(Cond):
    def __init__(self, a: Cond):
        self.a = a

    def labels(self):
        return self.a.labels()

    def evaluate(self, env):
        return ~self.a.evaluate(env)

    def __repr__(self):
        return f"!{self.a}"


# --------------------------------------------------------------------------
# compiled condition programs (the engine-dispatched filtering plane)
# --------------------------------------------------------------------------

OP_LEAF = "leaf"
OP_NOT = "not"
OP_AND = "and"
OP_OR = "or"


@dataclasses.dataclass(frozen=True)
class CondProgram:
    """A :class:`Cond` tree compiled to a flat postfix program.

    ``labels`` holds the distinct leaf labels in first-use order; ``ops``
    is the postfix op stream -- ``("leaf", i)`` pushes leaf plane ``i``,
    ``("not",)`` / ``("and",)`` / ``("or",)`` pop and combine.  Evaluation
    is a flat loop (:func:`eval_program`), not a per-node ``evaluate``
    recursion, and is polymorphic over the plane type: numpy boolean
    arrays at merged-run representatives, uint32 bitmap words, or jnp
    planes inside a kernel all evaluate the same program.  Frozen/hashable
    so kernels can specialize on it as a static argument.

    ``labels`` entries are strings for label leaves; numeric predicates
    (:mod:`repro.core.numeric`) store their frozen comparison leaves
    instead -- consumers that resolve labels by name only ever see
    label programs.
    """

    labels: Tuple
    ops: Tuple[Tuple, ...]


def compile_cond(cond: Cond) -> CondProgram:
    """Compile a condition tree into a :class:`CondProgram` (iterative
    postorder walk; the only tree traversal left in the plane).

    Leaves are label references (:class:`L`, keyed by name) or any node
    exposing a hashable ``leaf_key()`` -- the numeric comparison leaves
    of :mod:`repro.core.numeric` compile through the same program, so
    one stack machine evaluates label and numeric predicates alike."""
    if isinstance(cond, CondProgram):
        return cond
    labels: List = []
    index: Dict = {}
    ops: List[Tuple] = []
    stack: List[Tuple[Cond, bool]] = [(cond, False)]
    while stack:
        node, visited = stack.pop()
        key = (node.name if isinstance(node, L)
               else node.leaf_key() if hasattr(node, "leaf_key") else None)
        if key is not None:
            i = index.setdefault(key, len(labels))
            if i == len(labels):
                labels.append(key)
            ops.append((OP_LEAF, i))
        elif visited:
            ops.append((OP_NOT,) if isinstance(node, Not)
                       else (OP_AND,) if isinstance(node, And) else (OP_OR,))
        elif isinstance(node, Not):
            stack += [(node, True), (node.a, False)]
        elif isinstance(node, (And, Or)):
            stack += [(node, True), (node.b, False), (node.a, False)]
        else:
            raise TypeError(f"cannot compile {type(node).__name__}")
    return CondProgram(tuple(labels), tuple(ops))


def eval_program(ops: Sequence[Tuple], leaves: Sequence):
    """Stack-machine evaluation of a postfix op stream over leaf planes.

    Planes only need ``&``, ``|``, ``~`` -- numpy bool arrays, uint32
    words, and traced jnp arrays all qualify.  NOT over word planes sets
    tail bits past the row count; callers mask the final plane once.
    """
    stack: List = []
    for op in ops:
        if op[0] == OP_LEAF:
            stack.append(leaves[op[1]])
        elif op[0] == OP_NOT:
            stack.append(~stack.pop())
        else:
            b, a = stack.pop(), stack.pop()
            stack.append((a & b) if op[0] == OP_AND else (a | b))
    if len(stack) != 1:
        raise ValueError(f"malformed program: {len(stack)} planes left")
    return stack[0]


def charge_label_metadata(vt: VertexTable, names: Sequence[str],
                          meter) -> None:
    """IOMeter charge for reading the referenced labels' RLE metadata --
    the one I/O a label filter performs.  Shared by every engine so the
    accounting is identical by construction."""
    if meter is None:
        return
    for n in dict.fromkeys(names):
        vt.label_column(n).read_range(0, 0, meter)


# --------------------------------------------------------------------------
# interval plane <-> bitmap plane
# --------------------------------------------------------------------------

def intervals_to_bitmap(iv: Intervals, n: int) -> np.ndarray:
    """uint32 bitmap words over ``[0, n)`` with the intervals' bits set
    (vectorized boundary-marker cumsum; no per-interval loop)."""
    n_words = -(-n // 32)
    if n_words == 0:
        return np.zeros(0, np.uint32)
    starts = np.minimum(np.asarray(iv[0], np.int64), n)
    ends = np.minimum(np.asarray(iv[1], np.int64), n)
    mark = np.zeros(n_words * 32 + 1, np.int32)
    np.add.at(mark, starts, 1)
    np.add.at(mark, ends, -1)
    dense = np.cumsum(mark[:-1]) > 0
    return np.packbits(dense, bitorder="little").view(np.uint32)


def bitmap_to_intervals(words: np.ndarray, n: int) -> Intervals:
    """Coalesced half-open intervals of the set bits of a dense bitmap."""
    bits = np.unpackbits(np.ascontiguousarray(words, np.uint32)
                         .view(np.uint8), bitorder="little")[:n]
    edges = np.diff(bits.astype(np.int8), prepend=np.int8(0),
                    append=np.int8(0))
    return (np.flatnonzero(edges == 1).astype(np.int64),
            np.flatnonzero(edges == -1).astype(np.int64))


class LabelFilter:
    """A compiled label predicate bound to one vertex table.

    The unit the retrieval plane's ``filter=`` hook consumes: it owns the
    compiled program, lazily builds the kernel plane's padded input arrays
    (:func:`repro.kernels.label_filter.ops.make_plan`), and caches the
    whole-table bitmap per engine (label columns are immutable).  I/O
    charging is explicit (:meth:`charge`) so callers apply the same
    accounting on every execution path.
    """

    def __init__(self, vt: VertexTable, cond: Cond):
        self.vt = vt
        self.cond = cond
        self.program = compile_cond(cond)
        self._plan = None
        self._bitmaps: Dict[str, np.ndarray] = {}
        self._intervals: "Intervals | None" = None
        self._pacs: Dict[int, PAC] = {}

    def charge(self, meter) -> None:
        charge_label_metadata(self.vt, self.program.labels, meter)

    def qual_range(self) -> Tuple[int, int]:
        """Half-open hull ``[lo, hi)`` of the qualifying ids (evaluated
        lazily, once, on the plan).  The partition plane's statistics
        pushdown skips partitions whose value hull cannot intersect it."""
        return self.plan().qual_range()

    def plan(self):
        """Padded kernel inputs (positions/meta) + program, built once.

        The plan also carries the filtering plane's device residency
        (``FilterPlan.device`` / ``device_bitmap``): because the plan is
        cached here for the filter's lifetime, the RLE run arrays and the
        evaluated predicate bitmap cross to the device once and are
        reused by every subsequent fused dispatch."""
        if self._plan is None:
            from repro.kernels.label_filter import ops as lf_ops
            self._plan = lf_ops.make_plan(self.vt, self.program)
        return self._plan

    def intervals(self, engine: str = "numpy") -> Intervals:
        if engine == "numpy":
            if self._intervals is None:
                self._intervals = program_filter_intervals(self.vt,
                                                           self.program)
            return self._intervals
        return bitmap_to_intervals(self.bitmap(engine), self.vt.num_vertices)

    def bitmap(self, engine: str = "numpy") -> np.ndarray:
        """uint32 words over ``[0, num_vertices)``; cached per engine."""
        words = self._bitmaps.get(engine)
        if words is None:
            from repro.kernels.label_filter import ops as lf_ops
            words = lf_ops.label_filter_bitmap(self.vt, self.program,
                                               engine=engine)
            self._bitmaps[engine] = words
        return words

    def pac(self, page_size: int, engine: str = "numpy") -> PAC:
        """Filter PAC over ``page_size`` pages; memoized per page size
        (label columns are immutable).  Callers must treat the returned
        PAC as read-only -- derive with ``intersect``/``union``, never
        mutate it in place."""
        pac = self._pacs.get(page_size)
        if pac is None:
            if engine != "numpy" and page_size % 32 == 0:
                pac = PAC.from_dense_bitmap(self.bitmap(engine), page_size)
            else:
                pac = intervals_to_pac(self.intervals(engine),
                                       self.vt.num_vertices, page_size)
            self._pacs[page_size] = pac
        return pac

    def mask_ids(self, ids: np.ndarray, engine: str = "numpy") -> np.ndarray:
        """Boolean membership mask for internal ids (bitmap probe)."""
        ids = np.asarray(ids, np.int64)
        words = self.bitmap(engine)
        return ((words[ids >> 5] >> (ids & 31).astype(np.uint32)) & 1) \
            .astype(bool)

    def __repr__(self) -> str:
        return f"LabelFilter({self.vt.schema.name}, {self.cond})"


# --------------------------------------------------------------------------
# GraphAr fast paths
# --------------------------------------------------------------------------

def simple_filter_intervals(rle: RleColumn, exists: bool = True) -> Intervals:
    """Definition 3 via odd/even interval selection -- O(|P|)."""
    return rle.interval_starts(exists)


def merge_positions(rles: Sequence[RleColumn]) -> np.ndarray:
    """Merged breakpoint list P of k interval lists (sorted unique union)."""
    parts = [r.positions for r in rles]
    return np.unique(np.concatenate(parts))


def label_values_at(rle: RleColumn, points: np.ndarray) -> np.ndarray:
    """Label value at each representative vertex (vectorized Theorem 1).

    Run index of point p is ``searchsorted(positions, p, 'right') - 1``;
    value = first_value ^ (run_idx & 1).
    """
    run = np.searchsorted(rle.positions, points, side="right") - 1
    return (np.asarray(rle.first_value, bool)
            ^ ((run & 1).astype(bool)))


def program_filter_intervals(vt: VertexTable,
                             program: CondProgram) -> Intervals:
    """Merge-based complex filtering (paper §5.2, Fig. 7) over a compiled
    program: one vectorized run-boundary merge, leaf planes at the merged
    representatives (Theorem 1), then the flat stack machine -- the host
    engine of the filtering plane."""
    rles = [vt.label_rle(n) for n in program.labels]
    merged = merge_positions(rles)
    if merged.size < 2:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    reps = merged[:-1]  # representative = interval start (Theorem 1)
    leaves = [label_values_at(r, reps) for r in rles]
    keep = np.asarray(eval_program(program.ops, leaves), bool)
    return _coalesce(merged[:-1][keep], merged[1:][keep])


def complex_filter_intervals(vt: VertexTable, cond: Cond) -> Intervals:
    """Compiled merge-based complex filtering (compile + host engine)."""
    return program_filter_intervals(vt, compile_cond(cond))


def evaluate_filter_intervals(vt: VertexTable, cond: Cond) -> Intervals:
    """Legacy per-node ``evaluate(env)`` recursion -- kept as the oracle
    the compiled plane is validated against (tests/benchmarks only)."""
    names = list(dict.fromkeys(cond.labels()))
    rles = [vt.label_rle(n) for n in names]
    merged = merge_positions(rles)
    if merged.size < 2:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    reps = merged[:-1]
    env = {n: label_values_at(r, reps) for n, r in zip(names, rles)}
    keep = np.asarray(cond.evaluate(env), bool)
    return _coalesce(merged[:-1][keep], merged[1:][keep])


def _coalesce(starts: np.ndarray, ends: np.ndarray) -> Intervals:
    """Merge adjacent qualifying intervals (ends[i] == starts[i+1])."""
    if starts.size == 0:
        return starts.astype(np.int64), ends.astype(np.int64)
    new_run = np.ones(starts.size, bool)
    new_run[1:] = starts[1:] != ends[:-1]
    run_id = np.cumsum(new_run) - 1
    out_starts = starts[new_run]
    out_ends = np.zeros_like(out_starts)
    np.maximum.at(out_ends, run_id, ends)
    return out_starts.astype(np.int64), out_ends.astype(np.int64)


def intervals_to_pac(iv: Intervals, n: int, page_size: int) -> PAC:
    return PAC.from_intervals(iv[0], iv[1], n, page_size)


def intervals_to_ids(iv: Intervals) -> np.ndarray:
    """Concatenated ids of half-open intervals, fully vectorized.

    One repeat/cumsum construction instead of a Python loop of
    ``np.arange`` per interval: element ``j`` of the output is
    ``starts[i] + (j - offset[i])`` for its interval ``i``.
    """
    starts = np.asarray(iv[0], np.int64)
    ends = np.asarray(iv[1], np.int64)
    lengths = np.maximum(ends - starts, 0)
    total = int(lengths.sum())
    if total == 0:
        return np.zeros(0, np.int64)
    keep = lengths > 0
    s, k = starts[keep], lengths[keep]
    within = np.arange(total, dtype=np.int64) \
        - np.repeat(np.cumsum(k) - k, k)
    return np.repeat(s, k) + within


def intervals_count(iv: Intervals) -> int:
    return int((iv[1] - iv[0]).sum())


def filter_rle_interval(vt: VertexTable, cond: Cond, meter=None,
                        engine: str = "numpy") -> Intervals:
    """GraphAr entry point, engine-dispatched.

    ``numpy`` keeps the host plane (simple conditions take the O(|P|)
    odd/even path); kernel engines evaluate the compiled program on-device
    via :mod:`repro.kernels.label_filter` -- identical IOMeter accounting
    (the referenced labels' RLE metadata) either way."""
    if engine != "numpy":
        from repro.kernels.label_filter import ops as lf_ops
        return lf_ops.label_filter_intervals(vt, cond, meter, engine)
    charge_label_metadata(vt, compile_cond(cond).labels, meter)
    if isinstance(cond, L):
        return simple_filter_intervals(vt.label_rle(cond.name), True)
    if isinstance(cond, Not) and isinstance(cond.a, L):
        return simple_filter_intervals(vt.label_rle(cond.a.name), False)
    return complex_filter_intervals(vt, cond)


# --------------------------------------------------------------------------
# baselines (paper §6.3)
# --------------------------------------------------------------------------

def filter_string(vt: VertexTable, cond: Cond, meter=None) -> np.ndarray:
    """'string' baseline: split each vertex's label string, then match."""
    col = vt.table["<labels>"]
    strings = col.read_all(meter)
    names = list(dict.fromkeys(cond.labels()))
    n = vt.num_vertices
    env = {m: np.zeros(n, bool) for m in names}
    for i, s in enumerate(strings):
        if not s:
            continue
        present = s.split("|")
        for m in names:
            if m in present:
                env[m][i] = True
    return np.flatnonzero(cond.evaluate(env)).astype(np.int64)


def filter_binary_columns(vt: VertexTable, cond: Cond,
                          meter=None) -> np.ndarray:
    """'binary (plain)' / 'binary (RLE)' baselines: decode per-vertex bools
    for each referenced label column, evaluate per vertex."""
    names = list(dict.fromkeys(cond.labels()))
    env = {m: np.asarray(vt.label_column(m).read_all(meter), bool)
           for m in names}
    return np.flatnonzero(cond.evaluate(env)).astype(np.int64)
