"""Mutable graph plane: per-partition append-friendly delta segments.

The lake's packed columns are write-once; this module gives an
:class:`~repro.core.edge.AdjacencyTable` a numpy-side **memtable**: one
row-group-sized :class:`DeltaSegment` per partition of the value column,
holding the edges ingested since the last compaction as sorted
``(key, value)`` arrays.  Batched retrieval unions a batch's delta
neighbors with the device-resident base at dispatch time; the background
compactor (:mod:`repro.core.compaction`) merges the segments back into a
canonical packed layout and atomically swaps it in under the version
counter.

Design points:

* **Append-friendly, read-sorted.**  An ingest batch is merged into each
  touched segment's sorted order immediately (segments are row-group
  sized, so the re-sort is O(rows log rows) over a bounded array); every
  lookup is then a pair of ``searchsorted`` probes -- no per-read sort.
* **Zone maps maintained incrementally.**  Each segment tracks the
  min/max hull of its value ids, updated on every ingest; filtered
  retrieval prunes whole segments whose hull cannot intersect the
  predicate's qualifying range (the delta-side mirror of the partition
  plane's statistics pushdown), then exact-filters the survivors.
* **Crash-consistent ingest.**  A batch is staged fully before anything
  publishes; the ``ingest.append`` fault boundary sits between staging
  and publish, so an injected crash mid-append leaves the plane exactly
  as it was -- a retried batch can never half-apply or double-apply.
* **RAM-resident accounting.**  Delta reads charge no lake I/O (the
  memtable is the write buffer, not the lake -- the same convention the
  decoded-page LRU uses for hits).  The lake bytes are charged when the
  compactor rewrites the packed partitions.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.ft import faults as ft_faults

from .edge import BY_SRC, AdjacencyTable
from .encoding import hull_intersects
from .labels import intervals_to_ids
from .partition import live_partitions
from .table import DeltaIntColumn


@dataclasses.dataclass
class DeltaSegment:
    """Sorted ``(key, value)`` edge rows pending for one partition."""

    index: int
    keys: np.ndarray   # int64 [n], lexicographically sorted by (key, val)
    vals: np.ndarray   # int64 [n]
    #: incremental zone map over ``vals`` (empty hull = (0, -1)).
    vmin: int = 0
    vmax: int = -1

    def __len__(self) -> int:
        return len(self.keys)

    def nbytes(self) -> int:
        return self.keys.nbytes + self.vals.nbytes


def _sorted_merge(keys: np.ndarray, vals: np.ndarray,
                  add_k: np.ndarray, add_v: np.ndarray
                  ) -> Tuple[np.ndarray, np.ndarray]:
    k = np.concatenate([keys, add_k])
    v = np.concatenate([vals, add_v])
    order = np.lexsort((v, k))
    return k[order], v[order]


class DeltaSegments:
    """The mutable plane of one adjacency: partitioned delta segments.

    Attached via :func:`attach_delta`; the retrieval paths consult it
    through :func:`live_delta` (which reports None while the plane is
    drained, so the write-once fast paths -- including the fused
    traversal plan -- stay byte-for-byte untouched until the first
    ingest).
    """

    def __init__(self, adj: AdjacencyTable,
                 row_group_rows: Optional[int] = None,
                 faults: "Optional[ft_faults.FaultPlan]" = None):
        if adj.offsets is None:
            raise ValueError("the mutable plane requires the sorted "
                             "<offset> layout (graphar/offset encodings)")
        col = adj.table[adj.value_col]
        extra = [n for n in adj.table.columns
                 if n not in ("<src>", "<dst>")]
        if extra:
            raise ValueError(f"ingest supports topology-only edge tables; "
                             f"{extra} have no delta representation yet")
        self.adj = adj
        #: compaction-pressure unit: a segment holding this many rows is
        #: one row group -- by default the column's page size, so a
        #: compacted segment fills whole pages.
        self.row_group_rows = int(row_group_rows or col.page_size)
        self.faults = faults
        self.segments: Dict[int, DeltaSegment] = {}
        #: bumps on every published ingest batch and every compaction
        #: drain -- derived delta-side caches key on it.
        self.version = 0
        self.ingests = 0
        self.ingested_rows = 0
        self.lookups = 0
        self.segments_pruned = 0
        self.compactions = 0
        self._flat: "Optional[Tuple]" = None  # (version, ids, base, K, V)

    # -- geometry ----------------------------------------------------------

    def _part_of_keys(self, keys: np.ndarray) -> np.ndarray:
        """Owning segment of each key vertex: the partition holding the
        first base edge row of that key (partitions are page-aligned over
        the value column, immutable between compactions).  Unpartitioned
        columns use the single segment 0."""
        col = self.adj.table[self.adj.value_col]
        parts = live_partitions(col.encoded) \
            if isinstance(col, DeltaIntColumn) else None
        if parts is None:
            return np.zeros(len(keys), np.int64)
        off = self.adj.offsets["<offset>"].values
        pages = off[keys] // col.page_size
        return parts.part_of_pages(np.minimum(
            pages, parts.bounds[-1] - 1).astype(np.int64))

    # -- writes ------------------------------------------------------------

    def ingest(self, src, dst) -> int:
        """Append a batch of edges; returns rows ingested.

        All-or-nothing: the batch is staged against every touched
        segment first, the ``ingest.append`` fault boundary fires before
        anything publishes, and only then do the staged segments replace
        the live ones (plus one version bump).  An injected crash leaves
        the plane untouched, so the caller's retry is exact.
        """
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        if src.shape != dst.shape or src.ndim != 1:
            raise ValueError("src/dst must be equal-length 1-D arrays")
        if src.size == 0:
            return 0
        adj = self.adj
        keys, vals = (src, dst) if adj.order == BY_SRC else (dst, src)
        if keys.min() < 0 or keys.max() >= adj.num_key_vertices:
            raise ValueError("ingest names unknown key vertices (vertex "
                             "ingest is a separate plane)")
        if vals.min() < 0 or (adj.num_value_vertices is not None
                              and vals.max() >= adj.num_value_vertices):
            raise ValueError("ingest names unknown value vertices")
        owner = self._part_of_keys(keys)
        staged: List[DeltaSegment] = []
        for p in np.unique(owner):
            m = owner == p
            kp, vp = keys[m], vals[m]
            seg = self.segments.get(int(p))
            if seg is None:
                order = np.lexsort((vp, kp))
                k2, v2 = kp[order], vp[order]
                vmin, vmax = int(vp.min()), int(vp.max())
            else:
                k2, v2 = _sorted_merge(seg.keys, seg.vals, kp, vp)
                vmin = min(seg.vmin, int(vp.min())) if len(seg) \
                    else int(vp.min())
                vmax = max(seg.vmax, int(vp.max())) if len(seg) \
                    else int(vp.max())
            staged.append(DeltaSegment(int(p), k2, v2, vmin, vmax))
        # crash point: everything above is scratch state -- a fault here
        # (or anywhere earlier) publishes nothing
        ft_faults.check(self.faults, "ingest.append")
        for seg in staged:
            self.segments[seg.index] = seg
        self.ingests += 1
        self.ingested_rows += int(src.size)
        self.version += 1
        self._flat = None
        return int(src.size)

    # -- reads -------------------------------------------------------------

    def pending_rows(self) -> int:
        return sum(len(s) for s in self.segments.values())

    def nbytes(self) -> int:
        return sum(s.nbytes() for s in self.segments.values())

    def _flat_arrays(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                    np.ndarray]:
        """(segment ids, segment base offsets, flat keys, flat vals) --
        one concatenation per plane version, shared by every lookup."""
        if self._flat is not None and self._flat[0] == self.version:
            return self._flat[1:]
        ids = np.asarray(sorted(self.segments), np.int64)
        sizes = np.asarray([len(self.segments[int(p)]) for p in ids],
                           np.int64)
        base = np.zeros(len(ids) + 1, np.int64)
        np.cumsum(sizes, out=base[1:])
        if len(ids):
            K = np.concatenate([self.segments[int(p)].keys for p in ids])
            V = np.concatenate([self.segments[int(p)].vals for p in ids])
        else:
            K = V = np.zeros(0, np.int64)
        self._flat = (self.version, ids, base, K, V)
        return ids, base, K, V

    def lookup_batch(self, vs) -> Tuple[np.ndarray, np.ndarray]:
        """Per-vertex pending neighbor lists, in ``vs`` order.

        Returns ``(vals, lengths)`` -- the concatenation of each vertex's
        sorted delta values (multiplicity preserved) plus per-vertex
        lengths, mirroring the shape contract of the base plane's
        multi-range decode.  RAM-resident: charges no lake I/O.
        """
        vs = np.asarray(vs, np.int64)
        self.lookups += 1
        if vs.size == 0 or not self.segments:
            return np.zeros(0, np.int64), np.zeros(len(vs), np.int64)
        ids, base, K, V = self._flat_arrays()
        owner = self._part_of_keys(vs)
        seg_of = np.searchsorted(ids, owner)
        # vertices owned by a partition with no pending segment probe an
        # empty range (searchsorted may point at another segment's slot;
        # the equality mask voids it)
        seg_of = np.minimum(seg_of, len(ids) - 1)
        live = ids[seg_of] == owner
        lo = np.zeros(len(vs), np.int64)
        hi = np.zeros(len(vs), np.int64)
        for si in np.unique(seg_of[live]):
            m = live & (seg_of == si)
            b, e = base[si], base[si + 1]
            lo[m] = b + np.searchsorted(K[b:e], vs[m], "left")
            hi[m] = b + np.searchsorted(K[b:e], vs[m], "right")
        vals = V[intervals_to_ids((lo, hi))]
        return vals, hi - lo

    def unique_ids(self, vs, qual: Optional[Tuple[int, int]] = None
                   ) -> np.ndarray:
        """Sorted unique pending neighbor ids of the batch.

        ``qual`` -- a predicate's half-open qualifying ``[lo, hi)`` id
        hull (see ``LabelFilter.qual_range``) -- prunes whole segments
        whose zone map cannot intersect it (the shared
        :func:`repro.core.encoding.hull_intersects`, same predicate as
        partition and page pruning); surviving ids still need the
        caller's exact filter.  Pruning is counted in
        ``segments_pruned``.
        """
        vs = np.asarray(vs, np.int64)
        self.lookups += 1
        if vs.size == 0 or not self.segments:
            return np.zeros(0, np.int64)
        out: List[np.ndarray] = []
        owner = self._part_of_keys(vs)
        for p, seg in self.segments.items():
            if qual is not None and not hull_intersects(
                    seg.vmin, seg.vmax, qual[0], qual[1]):
                self.segments_pruned += 1
                continue
            sel = vs[owner == p]
            if sel.size == 0:
                continue
            lo = np.searchsorted(seg.keys, sel, "left")
            hi = np.searchsorted(seg.keys, sel, "right")
            out.append(seg.vals[intervals_to_ids((lo, hi))])
        if not out:
            return np.zeros(0, np.int64)
        return np.unique(np.concatenate(out))

    # -- compactor interface ----------------------------------------------

    def snapshot(self) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
        """Frozen copy of every segment's rows (the compaction input).
        Serving keeps ingesting into the live segments meanwhile."""
        return {p: (s.keys.copy(), s.vals.copy())
                for p, s in self.segments.items() if len(s)}

    def drop_rows(self, frozen: Dict[int, Tuple[np.ndarray, np.ndarray]]
                  ) -> None:
        """Remove exactly the snapshotted rows (multiset difference per
        segment) -- rows ingested after the snapshot survive, already in
        sorted order, and keep serving from the delta path."""
        for p, (fk, fv) in frozen.items():
            seg = self.segments.get(p)
            if seg is None:
                continue
            lim = max(int(seg.vals.max()), int(fv.max())) + 1 \
                if len(seg) else 1
            cur = seg.keys * lim + seg.vals
            sub = fk * lim + fv
            uc, cc = np.unique(cur, return_counts=True)
            uf, cf = np.unique(sub, return_counts=True)
            pos = np.searchsorted(uc, uf)
            if (pos >= len(uc)).any() or (uc[pos] != uf).any():
                raise ValueError("snapshot rows missing from live segment"
                                 " (snapshot/drop mismatch)")
            cc[pos] -= cf
            if (cc < 0).any():
                raise ValueError("snapshot holds more copies than live "
                                 "segment (snapshot/drop mismatch)")
            kept = np.repeat(uc, cc)
            if kept.size == 0:
                del self.segments[p]
                continue
            k2, v2 = kept // lim, kept % lim
            self.segments[p] = DeltaSegment(
                p, k2, v2, int(v2.min()), int(v2.max()))
        self.compactions += 1
        self.version += 1
        self._flat = None

    def stats(self) -> Dict[str, object]:
        return {"segments": len(self.segments),
                "pending_rows": self.pending_rows(),
                "row_group_rows": self.row_group_rows,
                "ingests": self.ingests,
                "ingested_rows": self.ingested_rows,
                "lookups": self.lookups,
                "segments_pruned": self.segments_pruned,
                "compactions": self.compactions,
                "version": self.version}

    def __repr__(self) -> str:
        return (f"DeltaSegments(segments={len(self.segments)}, "
                f"pending={self.pending_rows()}, v{self.version})")


# --------------------------------------------------------------------------
# attachment + plane-wide helpers
# --------------------------------------------------------------------------

def attach_delta(adj: AdjacencyTable,
                 row_group_rows: Optional[int] = None,
                 faults=None) -> DeltaSegments:
    """Attach (or return the attached) mutable plane of an adjacency."""
    if adj.delta is None:
        adj.delta = DeltaSegments(adj, row_group_rows, faults)
    return adj.delta


def live_delta(adj: AdjacencyTable) -> Optional[DeltaSegments]:
    """The adjacency's mutable plane iff it has pending rows -- the hot
    paths' single branch: None keeps the write-once code byte-identical
    (fused traversal plans, zero-retrace steady state) until the next
    ingest."""
    d = adj.delta
    if d is not None and d.segments:
        return d
    return None


def ingest_edges(adj: AdjacencyTable, src, dst,
                 row_group_rows: Optional[int] = None) -> int:
    """Convenience: attach-if-needed + ingest one batch of (src, dst)."""
    return attach_delta(adj, row_group_rows).ingest(src, dst)


def base_edges(adj: AdjacencyTable) -> Tuple[np.ndarray, np.ndarray]:
    """The packed base's (src, dst) edge list (physical row order)."""
    src = np.asarray(adj.table["<src>"].read_all(), np.int64)
    dst = np.asarray(adj.table["<dst>"].read_all(), np.int64)
    return src, dst


def all_edges(adj: AdjacencyTable) -> Tuple[np.ndarray, np.ndarray]:
    """Base + pending delta edges -- the edge list a from-scratch rebuild
    (and the compactor) starts from."""
    src, dst = base_edges(adj)
    d = adj.delta
    if d is None or not d.segments:
        return src, dst
    ks = [s.keys for s in d.segments.values()]
    vs = [s.vals for s in d.segments.values()]
    dk = np.concatenate(ks)
    dv = np.concatenate(vs)
    dsrc, ddst = (dk, dv) if adj.order == BY_SRC else (dv, dk)
    return np.concatenate([src, dsrc]), np.concatenate([dst, ddst])
