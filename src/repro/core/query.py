"""End-to-end LDBC-SNB-style workloads (paper §6.5): IS-3, IC-8, BI-2.

Each query has two implementations with identical results:

* ``*_graphar`` -- hand-written over the GraphAr APIs, exercising neighbor
  retrieval (offset + delta + PAC pushdown) and interval label filtering;
* ``*_acero``   -- the baseline over plain/unsorted tables via the
  scan/filter/hash-join/aggregate operators in :mod:`repro.core.acero`.

Graph layout (built by :func:`build_snb_graphar` from a
:class:`repro.data.synthetic.SnbGraph`):

  vertex types : person(firstName, birthday; labels Asian/Enrollee/Student)
                 message(creationDate, length; labels TagClass*)
                 tag(tagclass)
  edge types   : person-knows-person       (prop creationDate; by_src+by_dst)
                 message-hasCreator-person (by_src + by_dst)
                 message-replyOf-message   (by_src + by_dst)
                 message-hasTag-tag        (by_src + by_dst)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from . import acero
from .builder import Graph, GraphArBuilder
from .edge import BY_DST, BY_SRC, ENC_PLAIN, build_adjacency
from .labels import L, LabelFilter, filter_rle_interval, intervals_to_pac
from .neighbor import (decode_edge_ranges, fetch_properties,
                       fetch_properties_batch, retrieve_neighbors,
                       retrieve_neighbors_batch)
from .pac import PAC
from .schema import EdgeTypeSchema, PropertySchema, VertexTypeSchema
from .storage import IOMeter
from .vertex import LABEL_ENC_RLE, LABEL_ENC_STRING, VertexTable


# --------------------------------------------------------------------------
# construction
# --------------------------------------------------------------------------

def build_snb_graphar(snb, page_size: int = 2048) -> Graph:
    b = GraphArBuilder("snb")
    b.add_vertices(
        VertexTypeSchema("person",
                         [PropertySchema("firstName", "string"),
                          PropertySchema("birthday", "int64")],
                         labels=list(snb.person_labels),
                         page_size=page_size),
        {"firstName": snb.person_first_name, "birthday": snb.person_birthday},
        snb.person_labels)
    b.add_vertices(
        VertexTypeSchema("message",
                         [PropertySchema("creationDate", "int64"),
                          PropertySchema("length", "int64")],
                         labels=list(snb.message_labels),
                         page_size=page_size),
        {"creationDate": snb.message_creation, "length": snb.message_length},
        snb.message_labels)
    b.add_vertices(
        VertexTypeSchema("tag", [PropertySchema("tagclass", "int64")],
                         page_size=page_size),
        {"tagclass": snb.tag_class_of_tag})
    b.add_edges(EdgeTypeSchema("person", "knows", "person",
                               [PropertySchema("creationDate", "int64")],
                               adjacency=["by_src", "by_dst"],
                               page_size=page_size),
                snb.knows_src, snb.knows_dst,
                {"creationDate": snb.knows_creation})
    b.add_edges(EdgeTypeSchema("message", "hasCreator", "person",
                               adjacency=["by_src", "by_dst"],
                               page_size=page_size),
                snb.has_creator_msg, snb.has_creator_person)
    b.add_edges(EdgeTypeSchema("message", "replyOf", "message",
                               adjacency=["by_src", "by_dst"],
                               page_size=page_size),
                snb.reply_of_src, snb.reply_of_dst)
    b.add_edges(EdgeTypeSchema("message", "hasTag", "tag",
                               adjacency=["by_src", "by_dst"],
                               page_size=page_size),
                snb.has_tag_msg, snb.has_tag_tag)
    return b.build()


@dataclasses.dataclass
class SnbBaseline:
    """Plain/unsorted tables + string labels for the Acero engine."""

    person: VertexTable
    message: VertexTable
    tag: VertexTable
    knows: "acero.Table"
    has_creator: "acero.Table"
    reply_of: "acero.Table"
    has_tag: "acero.Table"


def build_snb_baseline(snb, page_size: int = 2048) -> SnbBaseline:
    from .table import PlainColumn, Table
    person = VertexTable.build(
        VertexTypeSchema("person",
                         [PropertySchema("firstName", "string"),
                          PropertySchema("birthday", "int64")],
                         labels=list(snb.person_labels), page_size=page_size),
        {"firstName": snb.person_first_name, "birthday": snb.person_birthday},
        snb.person_labels, LABEL_ENC_STRING)
    message = VertexTable.build(
        VertexTypeSchema("message",
                         [PropertySchema("creationDate", "int64"),
                          PropertySchema("length", "int64")],
                         labels=list(snb.message_labels),
                         page_size=page_size),
        {"creationDate": snb.message_creation, "length": snb.message_length},
        snb.message_labels, LABEL_ENC_STRING)
    tag = VertexTable.build(
        VertexTypeSchema("tag", [PropertySchema("tagclass", "int64")],
                         page_size=page_size),
        {"tagclass": snb.tag_class_of_tag})

    def coo(name, s, d, props=None):
        t = Table(name, len(s), page_size)
        t.add(PlainColumn("<src>", np.asarray(s, np.int64), page_size))
        t.add(PlainColumn("<dst>", np.asarray(d, np.int64), page_size))
        for k, v in (props or {}).items():
            t.add(PlainColumn(k, np.asarray(v), page_size))
        return t

    return SnbBaseline(
        person=person, message=message, tag=tag,
        knows=coo("knows", snb.knows_src, snb.knows_dst,
                  {"creationDate": snb.knows_creation}),
        has_creator=coo("hasCreator", snb.has_creator_msg,
                        snb.has_creator_person),
        reply_of=coo("replyOf", snb.reply_of_src, snb.reply_of_dst),
        has_tag=coo("hasTag", snb.has_tag_msg, snb.has_tag_tag))


# --------------------------------------------------------------------------
# IS-3: friends of a person with friendship creationDate, newest first
# --------------------------------------------------------------------------

def is3_graphar(g: Graph, person: int, meter: Optional[IOMeter] = None,
                engine: str = "numpy") -> Tuple[np.ndarray, np.ndarray]:
    adj = g.adjacency("person-knows-person", BY_SRC)
    vt = g.vertex("person")
    # batch-of-one through the shared batched plane
    los, his = adj.edge_ranges_batch(np.array([person]), meter)
    friends = decode_edge_ranges(adj, los, his, meter, engine)
    dates = np.asarray(
        adj.table["creationDate"].read_rows_concat(los, his, meter),
        np.int64)
    # bitmap-pushdown fetch of friend names (order restored by id below)
    pac = PAC.from_ids(friends, vt.page_size)
    _ = fetch_properties(pac, vt, "firstName", meter)
    order = np.argsort(-dates, kind="stable")
    return friends[order], dates[order]


def is3_acero(b: SnbBaseline, person: int,
              meter: Optional[IOMeter] = None
              ) -> Tuple[np.ndarray, np.ndarray]:
    rel = acero.scan(b.knows, ["<src>", "<dst>", "creationDate"], meter,
                     predicate=("<src>", "==", person))
    rel = acero.filter_rel(rel, rel["<src>"] == person)
    names = acero.Relation({
        "pid": np.arange(b.person.num_vertices, dtype=np.int64),
        "firstName": np.asarray(
            b.person.table["firstName"].read_all(meter), dtype=object)})
    joined = acero.hash_join(rel, names, "<dst>", "pid")
    joined = acero.order_by(joined, "creationDate", desc=True)
    return joined["<dst>"], joined["creationDate"]


# --------------------------------------------------------------------------
# IC-8: latest 20 replies to any message created by `person`
# --------------------------------------------------------------------------

def _traversal_fusable(adj) -> bool:
    """Whether the fused traversal plane may serve this adjacency under
    the session's transfer regime (``DEVICE_RESIDENT`` read at call time
    so env/monkeypatch overrides are honored)."""
    from repro.kernels.pac_decode import ops as pac_ops
    from repro.kernels.traversal.ops import plan_supported
    return pac_ops.DEVICE_RESIDENT and plan_supported(adj)


def _two_hop_fusable(adj_a, adj_b, vt: VertexTable) -> bool:
    return (_traversal_fusable(adj_a) and _traversal_fusable(adj_b)
            and adj_a.num_value_vertices == adj_b.num_key_vertices
            and vt.page_size % 32 == 0)


def ic8_graphar(g: Graph, person: int, limit: int = 20,
                meter: Optional[IOMeter] = None,
                engine: str = "numpy",
                reply_label: Optional[str] = None
                ) -> Tuple[np.ndarray, np.ndarray]:
    # hop 1: messages created by person  (hasCreator, incoming = by_dst)
    creator_adj = g.adjacency("message-hasCreator-person", BY_DST)
    # hop 2: replies to those messages (replyOf, incoming = by_dst)
    reply_adj = g.adjacency("message-replyOf-message", BY_DST)
    vt = g.vertex("message")
    filt = LabelFilter(vt, L(reply_label)) if reply_label else None
    if engine != "numpy" and _two_hop_fusable(creator_adj, reply_adj, vt):
        # both hops + the label AND as ONE device dispatch over the
        # adjacencies' resident traversal plans: the created-message
        # frontier never comes back to the host between hops
        # (kernels/traversal.two_hop_pac; oracle I/O replayed for the
        # meter)
        from repro.kernels.traversal.ops import two_hop_pac
        pac = two_hop_pac(creator_adj, reply_adj, [person], vt.page_size,
                          filt, meter, engine)
    else:
        # staged host path: hop-1 decode, then one batched hop-2
        # retrieval (vectorized offsets gather + page-deduplicated
        # multi-range decode) with the label predicate pushed down
        created = creator_adj.neighbor_ids(person, meter)
        pac = retrieve_neighbors_batch(reply_adj, created, vt.page_size,
                                       meter, engine, filter=filt)
    replies = pac.to_ids()
    if replies.size == 0:
        return replies, replies
    # fetch reply creationDate via PAC pushdown; top-`limit` newest
    dates = np.asarray(
        fetch_properties_batch(pac, vt, ["creationDate"],
                               meter)["creationDate"], np.int64)
    order = np.lexsort((-replies, -dates))[:limit]
    return replies[order], dates[order]


def ic8_acero(b: SnbBaseline, person: int, limit: int = 20,
              meter: Optional[IOMeter] = None,
              reply_label: Optional[str] = None
              ) -> Tuple[np.ndarray, np.ndarray]:
    created = acero.scan(b.has_creator, ["<src>", "<dst>"], meter,
                         predicate=("<dst>", "==", person))
    created = acero.filter_rel(created, created["<dst>"] == person)
    replies = acero.scan(b.reply_of, ["<src>", "<dst>"], meter)
    j = acero.hash_join(replies, created, "<dst>", "<src>")
    reply_ids = np.unique(j["<src>"])
    if reply_label is not None and reply_ids.size:
        strings = b.message.table["<labels>"].read_all(meter)
        mask = acero.string_label_mask(strings, reply_label)
        reply_ids = reply_ids[mask[reply_ids]]
    if reply_ids.size == 0:
        return reply_ids, reply_ids
    msg = acero.scan(b.message.table, ["creationDate"], meter)
    dates = msg["creationDate"][reply_ids]
    order = np.lexsort((-reply_ids, -dates))[:limit]
    return reply_ids[order], dates[order]


# --------------------------------------------------------------------------
# BI-2: per-tag message counts within one tag class (label filtering)
# --------------------------------------------------------------------------

def bi2_graphar(g: Graph, tagclass: str,
                meter: Optional[IOMeter] = None,
                engine: str = "numpy") -> Dict[int, int]:
    msg_vt = g.vertex("message")
    # interval label filter: messages labeled with the tag class,
    # engine-dispatched (kernel engines evaluate the compiled predicate
    # on-device and hand back interval planes; numpy keeps the host path)
    iv = filter_rle_interval(msg_vt, L(tagclass), meter, engine=engine)
    starts, ends = iv
    adj = g.adjacency("message-hasTag-tag", BY_SRC)
    tag_vt = g.vertex("tag")
    cls_id = int(tagclass.removeprefix("TagClass"))
    tag_classes = np.asarray(tag_vt.table["tagclass"].read_all(meter))
    if starts.size == 0:
        return {}
    # intervals of sorted messages -> contiguous edge-row ranges via one
    # deduplicated gather of the <offset> column; the ranges then flow
    # through the shared multi-range decode (multiplicity preserved --
    # BI-2 counts edges, so no PAC/set collapse here).
    bounds = adj.offsets_at(np.concatenate([starts, ends]), meter)
    los, his = bounds[:starts.size], bounds[starts.size:]
    if engine != "numpy" and _traversal_fusable(adj):
        # counting expansion over the resident traversal plan: the
        # interval frontier ships as O(intervals) ids and the per-tag
        # edge counts come back directly -- no per-edge id
        # materialization on the host
        from repro.kernels.traversal.ops import frontier_edge_counts
        counts = frontier_edge_counts(adj, starts, ends, los, his, meter,
                                      engine)
        counts[tag_classes != cls_id] = 0
        return {int(t): int(counts[t]) for t in np.flatnonzero(counts)}
    tags = decode_edge_ranges(adj, los, his, meter, engine)
    tags = tags[tag_classes[tags] == cls_id]
    keys, cnts = np.unique(tags, return_counts=True)
    return {int(t): int(c) for t, c in zip(keys, cnts)}


def bi2_acero(b: SnbBaseline, tagclass: str,
              meter: Optional[IOMeter] = None) -> Dict[int, int]:
    # string label filter over messages
    strings = b.message.table["<labels>"].read_all(meter)
    mask = acero.string_label_mask(strings, tagclass)
    msg_ids = np.flatnonzero(mask)
    msgs = acero.Relation({"mid": msg_ids.astype(np.int64)})
    ht = acero.scan(b.has_tag, ["<src>", "<dst>"], meter)
    j = acero.hash_join(msgs, ht, "mid", "<src>")
    tags_rel = acero.scan(b.tag.table, ["tagclass"], meter)
    cls_id = int(tagclass.removeprefix("TagClass"))
    tag_ids = np.flatnonzero(tags_rel["tagclass"] == cls_id)
    sel = np.isin(j["<dst>"], tag_ids)
    keys, counts = acero.aggregate_count(
        acero.Relation({"t": j["<dst>"][sel]}), "t")
    return {int(k): int(c) for k, c in zip(keys, counts)}
