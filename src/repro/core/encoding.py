"""Page-level codecs for GraphAr columns.

Three encodings, mirroring the paper (§3-§5):

* ``plain``      -- raw little-endian values (Parquet PLAIN).
* ``delta``      -- Parquet-style DELTA_BINARY_PACKED: per page, a first
                    value followed by miniblocks of 32 deltas; each miniblock
                    subtracts its own ``min_delta`` and bitpacks the residuals
                    with a per-miniblock bit width restricted to powers of two
                    (``{0,1,2,4,8,16,32}``) so that packed values never
                    straddle 32-bit word boundaries.  The paper requires
                    power-of-two widths "for data alignment purposes"; the
                    same restriction is what makes the TPU kernel's vectorized
                    variable-shift unpack possible (see kernels/pac_decode).
* ``rle``        -- boolean run-length encoding as an *interval position
                    list* ``P`` plus the first value (paper §5.1): run ``i``
                    covers ``[P[i], P[i+1])`` and has value
                    ``first_value ^ (i & 1)``.

All codecs are pure numpy (the storage plane); JAX/Pallas decode fast paths
live in ``repro.kernels`` and are validated against these.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

# Rows per data page.  2048 rows x 4B ids = 8 KiB of packed payload upper
# bound per page; bitmap for a page = 2048 bits = 64 uint32 words (one
# (8, 128)-lane VPU tile holds 16 pages' bitmaps).  Configurable per file.
DEFAULT_PAGE_SIZE = 2048
MINIBLOCK = 32

#: Bit widths allowed for delta miniblocks (powers of two only).
ALLOWED_WIDTHS = (0, 1, 2, 4, 8, 16, 32)

ENC_PLAIN = "plain"
ENC_DELTA = "delta"
ENC_RLE = "rle"

#: bit layout of the unpack plan's packed ``pos`` lane (see
#: :meth:`PackedPages.unpack_plan`): ``widx << 11 | shift << 6 | bw``.
#: shift < 32 (5 bits), bw <= 32 (6 bits), widx < 2^20 (asserted).
POS_WIDX_SHIFT = 11
POS_SHIFT_SHIFT = 6
POS_BW_MASK = 63


# --------------------------------------------------------------------------
# bitpacking (vectorized, power-of-two widths only)
# --------------------------------------------------------------------------

def _round_up_width(nbits: int) -> int:
    for w in ALLOWED_WIDTHS:
        if nbits <= w:
            return w
    raise ValueError(f"required width {nbits} > 32")


def bitpack(values: np.ndarray, bit_width: int) -> np.ndarray:
    """Pack ``values`` (non-negative, < 2**bit_width) into a uint32 word array.

    Values are laid out little-endian within each word; with power-of-two
    widths exactly ``32 // bit_width`` values occupy one word and no value
    straddles a word boundary.
    """
    if bit_width == 0:
        return np.zeros(0, dtype=np.uint32)
    if bit_width not in ALLOWED_WIDTHS:
        raise ValueError(f"bit width {bit_width} not in {ALLOWED_WIDTHS}")
    v = np.asarray(values, dtype=np.uint64)
    if v.size and bit_width < 64:
        assert int(v.max()) < (1 << bit_width), "value overflows bit width"
    per_word = 32 // bit_width
    pad = (-len(v)) % per_word
    if pad:
        v = np.concatenate([v, np.zeros(pad, dtype=np.uint64)])
    v = v.reshape(-1, per_word)
    shifts = (np.arange(per_word, dtype=np.uint64) * bit_width)
    words = np.bitwise_or.reduce(v << shifts, axis=1)
    return words.astype(np.uint32)


def bitunpack(words: np.ndarray, bit_width: int, count: int) -> np.ndarray:
    """Inverse of :func:`bitpack`; returns ``count`` uint32 values."""
    if bit_width == 0:
        return np.zeros(count, dtype=np.uint32)
    per_word = 32 // bit_width
    w = np.asarray(words, dtype=np.uint32)
    idx = np.arange(count, dtype=np.int64)
    word = w[idx // per_word].astype(np.uint64)
    shift = ((idx % per_word) * bit_width).astype(np.uint64)
    mask = np.uint64((1 << bit_width) - 1)
    return ((word >> shift) & mask).astype(np.uint32)


# --------------------------------------------------------------------------
# delta (DELTA_BINARY_PACKED-style)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class DeltaPage:
    """One delta-encoded data page.

    ``packed`` concatenates the miniblocks' word arrays;
    ``word_offsets[i]`` is the starting word of miniblock ``i``.

    ``vmin``/``vmax`` are the page's value statistics, recorded at encode
    time (the values are in hand then; recovering them later would cost a
    decode).  They feed the partition plane's statistics pushdown: a page
    (or partition) whose ``[vmin, vmax]`` hull cannot intersect a
    predicate's qualifying id range contributes nothing and can be
    skipped.  An empty page records the empty hull ``(0, -1)``.
    """

    count: int
    first_value: int
    min_deltas: np.ndarray     # int64 [n_mini]
    bit_widths: np.ndarray     # uint8 [n_mini]
    word_offsets: np.ndarray   # int32 [n_mini]
    packed: np.ndarray         # uint32 [n_words]
    vmin: int = 0              # min value in the page (0 if empty)
    vmax: int = -1             # max value in the page (-1 if empty)

    def nbytes(self) -> int:
        # Physical layout cost: header (count, first) + per-miniblock
        # (min_delta varint approximated as 4B, width 1B) + packed words.
        return (12 + self.min_deltas.size * 5 + self.packed.nbytes)

    def max_bit_width(self) -> int:
        return int(self.bit_widths.max()) if self.bit_widths.size else 0


def delta_encode_page(values: np.ndarray) -> DeltaPage:
    v = np.asarray(values, dtype=np.int64)
    n = len(v)
    if n == 0:
        return DeltaPage(0, 0, np.zeros(0, np.int64), np.zeros(0, np.uint8),
                         np.zeros(0, np.int32), np.zeros(0, np.uint32))
    deltas = np.diff(v)  # n-1 deltas
    n_mini = max(1, -(-len(deltas) // MINIBLOCK))
    min_deltas = np.zeros(n_mini, np.int64)
    widths = np.zeros(n_mini, np.uint8)
    offsets = np.zeros(n_mini, np.int32)
    chunks: List[np.ndarray] = []
    woff = 0
    for i in range(n_mini):
        blk = deltas[i * MINIBLOCK:(i + 1) * MINIBLOCK]
        if blk.size == 0:
            continue
        lo = int(blk.min())
        resid = (blk - lo).astype(np.uint64)
        hi = int(resid.max())
        bw = _round_up_width(int(hi).bit_length())
        min_deltas[i] = lo
        widths[i] = bw
        offsets[i] = woff
        words = bitpack(resid, bw)
        chunks.append(words)
        woff += len(words)
    packed = (np.concatenate(chunks) if chunks else np.zeros(0, np.uint32))
    return DeltaPage(n, int(v[0]), min_deltas, widths, offsets, packed,
                     vmin=int(v.min()), vmax=int(v.max()))


def delta_decode_page(page: DeltaPage) -> np.ndarray:
    """Pure-numpy decode, fully vectorized (same gather+variable-shift
    unpack as the Pallas kernel: power-of-two widths never straddle words).
    """
    if page.count == 0:
        return np.zeros(0, np.int64)
    n_deltas = page.count - 1
    if n_deltas == 0:
        return np.array([page.first_value], np.int64)
    idx = np.arange(n_deltas, dtype=np.int64)
    mini = idx // MINIBLOCK
    within = idx % MINIBLOCK
    bw = page.bit_widths[mini].astype(np.int64)
    bit_pos = within * bw
    word_idx = page.word_offsets[mini].astype(np.int64) + bit_pos // 32
    if page.packed.size:
        word_idx = np.minimum(word_idx, page.packed.size - 1)
        words = page.packed[word_idx].astype(np.uint64)
    else:
        words = np.zeros(n_deltas, np.uint64)
    shift = (bit_pos % 32).astype(np.uint64)
    mask = np.where(bw >= 32, np.uint64(0xFFFFFFFF),
                    (np.uint64(1) << bw.astype(np.uint64))
                    - np.uint64(1))
    resid = ((words >> shift) & mask).astype(np.int64)
    resid[bw == 0] = 0
    deltas = resid + page.min_deltas[mini]
    out = np.empty(page.count, np.int64)
    out[0] = page.first_value
    np.cumsum(deltas, out=out[1:])
    out[1:] += page.first_value
    return out


# --------------------------------------------------------------------------
# RLE for boolean label columns (interval position lists)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class RleColumn:
    """Whole-column RLE of a boolean array as interval positions.

    ``positions`` = [0, p1, p2, ..., n]; run ``i`` spans
    ``[positions[i], positions[i+1])`` with value ``first_value ^ (i & 1)``.
    """

    count: int
    first_value: bool
    positions: np.ndarray  # int64 [n_runs + 1]

    def nbytes(self) -> int:
        # 4B per position (ids < 2^32 in our graphs) + 1B header
        return 4 * self.positions.size + 5

    @property
    def n_runs(self) -> int:
        return max(0, self.positions.size - 1)

    def interval_starts(self, value: bool) -> Tuple[np.ndarray, np.ndarray]:
        """Intervals (starts, ends) where the column equals ``value``.

        Paper §5.1: "simply select all odd intervals or all even intervals".
        """
        p = self.positions
        start_idx = 0 if (value == self.first_value) else 1
        starts = p[start_idx:-1:2]
        ends = p[start_idx + 1::2]
        return starts, ends


def rle_encode_bool(values: np.ndarray) -> RleColumn:
    v = np.asarray(values, dtype=bool)
    n = len(v)
    if n == 0:
        return RleColumn(0, False, np.zeros(1, np.int64))
    change = np.flatnonzero(v[1:] != v[:-1]) + 1
    positions = np.concatenate([[0], change, [n]]).astype(np.int64)
    return RleColumn(n, bool(v[0]), positions)


def rle_decode_bool(col: RleColumn) -> np.ndarray:
    out = np.zeros(col.count, dtype=bool)
    starts, ends = col.interval_starts(True)
    for s, e in zip(starts, ends):
        out[s:e] = True
    return out


# --------------------------------------------------------------------------
# plain
# --------------------------------------------------------------------------

def plain_encode(values: np.ndarray) -> bytes:
    return np.ascontiguousarray(values).tobytes()


def plain_decode(buf: bytes, dtype: np.dtype, count: int) -> np.ndarray:
    return np.frombuffer(buf, dtype=dtype, count=count)


# --------------------------------------------------------------------------
# column-level delta encode/decode over pages
# --------------------------------------------------------------------------

@dataclasses.dataclass
class PackedPages:
    """Column-wide packed-page batch arrays (the kernels' VMEM layout).

    One row per data page, padded to the fixed shapes the pac_decode
    kernels tile over.  Built once per column and cached on
    :class:`DeltaColumn` so repeated queries stop re-materializing the
    batch arrays (a measurable hot-path cost at serving batch rates).

    ``version`` snapshots :attr:`DeltaColumn.version` at build time so a
    page write invalidates the cache even when the page count is
    unchanged (in-place mutation of the last partial page).

    :meth:`device` keeps a lazily-populated, engine-keyed **device
    mirror** of the batch arrays: the packed column is immutable per
    version, so it crosses the PCIe once and every subsequent dispatch
    ships only an int32 page-index vector (the kernels gather rows
    on-device with ``jnp.take``).  The mirror dies with this object, so
    a version bump (which rebuilds ``PackedPages``) also invalidates it.
    """

    first: np.ndarray         # int32  [n_pages, 1]
    min_deltas: np.ndarray    # int32  [n_pages, n_mini]
    bit_widths: np.ndarray    # int32  [n_pages, n_mini]
    word_offsets: np.ndarray  # int32  [n_pages, n_mini]
    packed: np.ndarray        # uint32 [n_pages, max_words]
    counts: np.ndarray        # int32  [n_pages, 1]
    #: rows per page (max_words == page_size by construction, but kept
    #: explicit so the unpack plan never guesses).
    page_size: int = 0
    #: :attr:`DeltaColumn.version` this build corresponds to.
    version: int = 0
    #: per-page value statistics (min/max id per page, int64[n_pages];
    #: empty pages record the empty hull (0, -1)).  Recorded at pack time
    #: from the pages' encode-time stats -- the first step of the
    #: statistics-pushdown plane (partition/page pruning against a
    #: predicate's qualifying id range).
    page_min: "np.ndarray | None" = dataclasses.field(
        default=None, repr=False, compare=False)
    page_max: "np.ndarray | None" = dataclasses.field(
        default=None, repr=False, compare=False)
    #: engine -> tuple of device arrays; populated lazily, once per engine.
    _device: Dict[str, Tuple] = dataclasses.field(
        default_factory=dict, repr=False, compare=False)
    #: host-cached per-delta unpack plan (see :meth:`unpack_plan`).
    _plan: "Tuple | None" = dataclasses.field(
        default=None, repr=False, compare=False)
    #: engine -> device unpack plan (see :meth:`device_plan`).
    _device_plans: Dict[str, Tuple] = dataclasses.field(
        default_factory=dict, repr=False, compare=False)
    #: host->device transfers performed (one per engine populated).
    device_transfers: int = dataclasses.field(
        default=0, repr=False, compare=False)
    #: a failed/corrupted device transfer marks the mirror poisoned; the
    #: dispatch layers then route to the host oracle path (identical ids
    #: and IOMeter) until a version bump rebuilds this object.
    poisoned: bool = dataclasses.field(
        default=False, repr=False, compare=False)
    #: dispatches that fell back to the host path because of poisoning.
    fallbacks: int = dataclasses.field(
        default=0, repr=False, compare=False)

    @property
    def n_pages(self) -> int:
        return self.first.shape[0]

    def host_arrays(self) -> Tuple[np.ndarray, ...]:
        return (self.first, self.min_deltas, self.bit_widths,
                self.word_offsets, self.packed, self.counts)

    def device(self, engine: str) -> Tuple:
        """Engine-keyed device mirror of the whole packed column.

        Populated lazily and exactly once per (column build, engine):
        repeated calls return the same device arrays.  The transfer is
        the only time packed page bytes cross to the device -- dispatch
        paths gather rows on-device by page index afterwards.

        This is the raw storage-layout mirror (the unit a multi-device
        shard would ship); the decode dispatch paths consume
        :meth:`device_plan`, its decode-ready expansion, instead -- do
        not populate both unless you need both.
        """
        mirror = self._device.get(engine)
        if mirror is None:
            import jax.numpy as jnp  # storage plane stays numpy otherwise
            mirror = tuple(jnp.asarray(a) for a in self.host_arrays())
            self._device[engine] = mirror
            self.device_transfers += 1
        return mirror

    def unpack_plan(self) -> Tuple[np.ndarray, ...]:
        """Per-delta unpack plan: everything about the variable-shift
        decode that does not depend on the query, precomputed once.

        The miniblock metadata (bit width, word offset, min delta) is
        expanded to per-delta resolution and folded together.  ``pos``
        packs the word index, within-word shift, and effective bit width
        of delta ``j`` of page ``i`` into one int32 lane
        (``widx << POS_WIDX_SHIFT | shift << POS_SHIFT_SHIFT | bw``) --
        one gathered array instead of three -- and the effective width
        is already forced to 0 past ``counts[i] - 1`` and for zero-width
        miniblocks (a zero width decodes a zero mask, so no per-dispatch
        count compare); ``min_delta`` is zeroed the same way.  A
        resident dispatch is then one ``take_along_axis`` + a few
        elementwise ops + row cumsum -- the miniblock-expansion gathers
        the kernels used to do per dispatch happen here, once per column
        build.

        Returns ``(first, pos, min_delta, packed)`` with the middle two
        shaped ``[n_pages, page_size - 1]``.
        """
        if self._plan is None:
            ps = self.page_size or self.packed.shape[1]
            d = np.arange(max(ps - 1, 1))
            n_mini = self.bit_widths.shape[1]
            mini = np.minimum(d // MINIBLOCK, n_mini - 1)
            within = d % MINIBLOCK
            bw = self.bit_widths[:, mini].astype(np.int64)
            bit_pos = within[None, :] * bw
            widx = (self.word_offsets[:, mini] + bit_pos // 32) \
                .astype(np.int64)
            assert widx.size == 0 or int(widx.max()) < (1 << 20), \
                "word offset overflows the packed position encoding"
            valid = d[None, :] < (self.counts - 1)
            bw_eff = np.where(valid, bw, 0)
            pos = ((widx << POS_WIDX_SHIFT)
                   | ((bit_pos % 32) << POS_SHIFT_SHIFT)
                   | bw_eff).astype(np.int32)
            mind = np.where(valid, self.min_deltas[:, mini], 0) \
                .astype(np.int32)
            self._plan = (self.first, pos, mind, self.packed)
        return self._plan

    def device_plan(self, engine: str) -> Tuple:
        """Engine-keyed device mirror of the unpack plan (once each)."""
        plan = self._device_plans.get(engine)
        if plan is None:
            import jax.numpy as jnp
            plan = tuple(jnp.asarray(a) for a in self.unpack_plan())
            self._device_plans[engine] = plan
            self.device_transfers += 1
        return plan

    def poison(self) -> None:
        """Mark the device mirror unusable (simulated transfer fault /
        corruption detection): consumers degrade to the host oracle; the
        next version bump rebuilds a clean mirror."""
        self.poisoned = True

    def device_stats(self) -> Dict[str, object]:
        return {"engines": sorted(set(self._device) | set(self._device_plans)),
                "transfers": self.device_transfers,
                "version": self.version,
                "poisoned": self.poisoned,
                "fallbacks": self.fallbacks}

    def slice(self, p0: int, p1: int) -> Tuple[np.ndarray, ...]:
        """Zero-copy views of pages [p0, p1)."""
        return (self.first[p0:p1], self.min_deltas[p0:p1],
                self.bit_widths[p0:p1], self.word_offsets[p0:p1],
                self.packed[p0:p1], self.counts[p0:p1])

    def gather(self, pages) -> Tuple[np.ndarray, ...]:
        """Row-gathered copies for an arbitrary (sorted) page list."""
        idx = np.asarray(pages, np.int64)
        return (self.first[idx], self.min_deltas[idx], self.bit_widths[idx],
                self.word_offsets[idx], self.packed[idx], self.counts[idx])


@dataclasses.dataclass
class PagePruneStats:
    """Counters for page-granular statistics pushdown on one column.

    ``io_saved_bytes`` sums the physical :meth:`DeltaPage.nbytes` of the
    pages a qualifying hull eliminated -- an upper bound on the lake I/O
    avoided (a pruned page may also have been a decoded-LRU hit, in
    which case the avoided cost is the decode, not the bytes)."""

    dispatches: int = 0
    pages_considered: int = 0
    pages_pruned: int = 0
    io_saved_bytes: int = 0

    def as_dict(self) -> Dict[str, int]:
        return {"dispatches": self.dispatches,
                "pages_considered": self.pages_considered,
                "pages_pruned": self.pages_pruned,
                "io_saved_bytes": self.io_saved_bytes}


@dataclasses.dataclass
class DeltaColumn:
    count: int
    page_size: int
    pages: List[DeltaPage]
    #: lazily built by :func:`pack_column`; not part of the storage format.
    packed_cache: "PackedPages | None" = dataclasses.field(
        default=None, repr=False, compare=False)
    #: optional decoded-page LRU (see :mod:`repro.core.page_cache`);
    #: attached by :func:`repro.core.page_cache.attach_page_cache`, consulted
    #: by every batched decode path, not part of the storage format.
    page_cache: "object | None" = dataclasses.field(
        default=None, repr=False, compare=False)
    #: monotonically increasing write counter; every derived cache
    #: (``packed_cache``, its device mirror, the decoded-page LRU, the
    #: partition plane) is keyed on it, so in-place page writes can never
    #: serve stale data.
    version: int = dataclasses.field(default=0, compare=False)
    #: requested partition count (0 = monolithic).  Set by
    #: :func:`repro.core.partition.partition_column`; the partition plane
    #: rebuilds :attr:`partition_cache` lazily after a version bump.
    partitions: int = dataclasses.field(default=0, compare=False)
    #: lazily built :class:`repro.core.partition.PartitionedColumn`
    #: (keyed on ``(version, partitions)``); not part of the storage
    #: format.
    partition_cache: "object | None" = dataclasses.field(
        default=None, repr=False, compare=False)
    #: page-granular statistics-pushdown counters (see
    #: :func:`prune_page_list`); observability only, never keyed on.
    prune_stats: PagePruneStats = dataclasses.field(
        default_factory=PagePruneStats, repr=False, compare=False)
    #: lazily built per-page hull arrays (see :func:`page_hulls`), keyed
    #: on ``(n_pages, version)`` like :attr:`packed_cache`.
    _hull_cache: "Tuple | None" = dataclasses.field(
        default=None, repr=False, compare=False)

    def nbytes(self) -> int:
        return sum(p.nbytes() for p in self.pages)

    def bump_version(self) -> None:
        """Mark the pages dirty.  Any code that writes a page in place
        (or replaces one) MUST call this -- :func:`pack_column` and the
        decoded-page LRU key their caches on :attr:`version`, and page
        count alone cannot see a rewrite of the last partial page."""
        self.version += 1

    def set_page(self, i: int, page: DeltaPage) -> None:
        """Replace page ``i`` and invalidate every derived cache.

        The row count follows the replacement (rewriting the last
        partial page may grow or shrink the column)."""
        self.count += page.count - self.pages[i].count
        self.pages[i] = page
        self.bump_version()

    def append_page(self, page: DeltaPage) -> None:
        """Append a page and invalidate every derived cache."""
        self.pages.append(page)
        self.count += page.count
        self.bump_version()


def build_packed(pages: "List[DeltaPage]", page_size: int,
                 version: int = 0) -> PackedPages:
    """Pack an arbitrary page list into the kernels' batch-array layout.

    Pads miniblock metadata to ``page_size // MINIBLOCK`` and packed words
    to the worst case (bw=32) -- exactly the layout the pac_decode kernels
    tile over.  Shared by the whole-column :func:`pack_column` and the
    partition plane's per-partition packs
    (:func:`repro.core.partition.partition_column`), which call it over
    contiguous page slices.  Per-page min/max id statistics ride along
    from the pages' encode-time stats.
    """
    ps = page_size
    n_mini = max(1, ps // MINIBLOCK)
    max_words = ps  # worst case: 32-bit deltas -> one word per delta
    n = len(pages)
    first = np.zeros((n, 1), np.int32)
    counts = np.zeros((n, 1), np.int32)
    mind = np.zeros((n, n_mini), np.int32)
    bw = np.zeros((n, n_mini), np.int32)
    woff = np.zeros((n, n_mini), np.int32)
    packed = np.zeros((n, max_words), np.uint32)
    pmin = np.zeros(n, np.int64)
    pmax = np.full(n, -1, np.int64)
    for i, pg in enumerate(pages):
        first[i, 0] = pg.first_value
        counts[i, 0] = pg.count
        k = len(pg.min_deltas)
        mind[i, :k] = pg.min_deltas
        bw[i, :k] = pg.bit_widths
        woff[i, :k] = pg.word_offsets
        packed[i, :len(pg.packed)] = pg.packed
        pmin[i], pmax[i] = pg.vmin, pg.vmax
    return PackedPages(first, mind, bw, woff, packed, counts,
                       page_size=ps, version=version,
                       page_min=pmin, page_max=pmax)


def pack_column(col: DeltaColumn) -> PackedPages:
    """Build (or return the cached) column-wide packed-page arrays.

    The cache is keyed on ``(n_pages, version)`` so both appended and
    in-place-rewritten pages rebuild it (and, transitively, the device
    mirror that lives on it).
    """
    if col.packed_cache is not None \
            and col.packed_cache.n_pages == len(col.pages) \
            and col.packed_cache.version == col.version:
        return col.packed_cache
    col.packed_cache = build_packed(col.pages, col.page_size,
                                    version=col.version)
    return col.packed_cache


def hull_intersects(vmin: int, vmax: int, lo: int, hi: int) -> bool:
    """Whether a closed value hull ``[vmin, vmax]`` can intersect the
    half-open qualifying range ``[lo, hi)``.

    The single intersection predicate behind all three statistics-pushdown
    granularities -- partition hulls (``partition.Partition
    .intersects_range``), page zone maps (:func:`prune_page_list`, the
    vectorized form), and delta-segment hulls
    (``delta_segment.DeltaSegments.unique_ids``).  An empty value hull
    (``vmax < vmin``) intersects nothing; an empty qualifying range
    (``hi <= lo``) is intersected by nothing."""
    return vmax >= vmin and hi > lo and vmin < hi and vmax >= lo


def page_hulls(col: DeltaColumn) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-page value hulls ``(pmin, pmax, prunable)`` for zone-map pruning.

    ``prunable[p]`` is True when page ``p``'s encode-time statistics are
    trustworthy: a non-empty hull (``vmax >= vmin``) or a provably empty
    page.  Pages with unknown stats (hand-built :class:`DeltaPage` objects
    that skipped the encoder, or a sentinel hull on non-empty data) are
    never pruned.  Cached on the column, keyed on ``(n_pages, version)``
    like :func:`pack_column`, and cheap enough to build eagerly -- it
    reads only the page headers, no packed words."""
    key = (len(col.pages), col.version)
    cached = col._hull_cache
    if cached is not None and cached[0] == key:
        return cached[1]
    n = len(col.pages)
    pmin = np.zeros(n, np.int64)
    pmax = np.full(n, -1, np.int64)
    counts = np.zeros(n, np.int64)
    for i, pg in enumerate(col.pages):
        pmin[i], pmax[i] = pg.vmin, pg.vmax
        counts[i] = pg.count
    prunable = (pmax >= pmin) | (counts == 0)
    hulls = (pmin, pmax, prunable)
    col._hull_cache = (key, hulls)
    return hulls


def prune_page_list(col: DeltaColumn, pages: np.ndarray,
                    qual: "Tuple[int, int] | None"
                    ) -> Tuple[np.ndarray, "np.ndarray | None"]:
    """Drop pages whose value hull cannot intersect the half-open
    qualifying range ``qual = [lo, hi)``.

    Returns ``(kept_pages, mask)`` where ``mask`` is the boolean keep
    mask over the input list, or ``None`` when nothing pruned (the
    allocation-free fast path -- callers skip their row-drop logic).
    Pages with unknown statistics are always kept, so pruning can only
    remove pages that provably contain no qualifying value: result ids
    stay bit-identical to the unpruned oracle.  Counters accumulate on
    ``col.prune_stats``; ``io_saved_bytes`` only counts actually-pruned
    dispatches."""
    pages = np.asarray(pages, np.int64)
    if qual is None or len(pages) == 0:
        return pages, None
    lo, hi = qual
    stats = col.prune_stats
    stats.dispatches += 1
    stats.pages_considered += len(pages)
    pmin, pmax, prunable = page_hulls(col)
    if hi <= lo:
        keep = ~prunable[pages]
    else:
        pmn, pmx = pmin[pages], pmax[pages]
        keep = ~prunable[pages] | ((pmx >= pmn) & (pmx >= lo) & (pmn < hi))
    if keep.all():
        return pages, None
    dropped = pages[~keep]
    stats.pages_pruned += len(dropped)
    stats.io_saved_bytes += int(sum(col.pages[p].nbytes() for p in dropped))
    return pages[keep], keep


def delta_encode_column(values: np.ndarray,
                        page_size: int = DEFAULT_PAGE_SIZE) -> DeltaColumn:
    v = np.asarray(values, dtype=np.int64)
    pages = [delta_encode_page(v[i:i + page_size])
             for i in range(0, max(len(v), 1), page_size)]
    if len(v) == 0:
        pages = [delta_encode_page(v)]
    return DeltaColumn(len(v), page_size, pages)


def delta_decode_column(col: DeltaColumn) -> np.ndarray:
    if col.count == 0:
        return np.zeros(0, np.int64)
    return np.concatenate([delta_decode_page(p) for p in col.pages])


def delta_decode_range(col: DeltaColumn, lo: int, hi: int) -> np.ndarray:
    """Decode rows [lo, hi) touching only the pages that overlap the range.

    This is the access pattern of neighbor retrieval: the <offset> index
    gives an edge-row range; only the overlapping delta pages are loaded
    and decoded (the bytes-touched accounting in storage.py keys off the
    pages visited here).
    """
    if hi <= lo:
        return np.zeros(0, np.int64)
    ps = col.page_size
    p0, p1 = lo // ps, (hi - 1) // ps
    parts = [delta_decode_page(col.pages[p]) for p in range(p0, p1 + 1)]
    joined = np.concatenate(parts)
    return joined[lo - p0 * ps: hi - p0 * ps]


def pages_touched(col: DeltaColumn, lo: int, hi: int) -> Tuple[int, int, int]:
    """(first_page, last_page_exclusive, bytes) for a row range."""
    if hi <= lo:
        return 0, 0, 0
    ps = col.page_size
    p0, p1 = lo // ps, (hi - 1) // ps + 1
    nbytes = sum(col.pages[p].nbytes() for p in range(p0, p1))
    return p0, p1, nbytes
