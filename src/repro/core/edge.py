"""Edge tables with CSR/CSC/COO-emulating layouts (paper §3.2).

Edges of one type are stored as a table with ``<src>``/``<dst>`` columns and
properties.  GraphAr sorts edges **dual-key** (primary, secondary) --
``by_src`` = (src, dst) ~ CSR; ``by_dst`` = (dst, src) ~ CSC -- and adds an
auxiliary ``<offset>`` index table aligned with the key vertex table so that
the edge range of vertex ``v`` is ``[offset[v], offset[v+1])``.  Row-wise
the layout doubles as COO.  Bubbles (paper footnote 2) are naturally
expressed as equal consecutive offsets.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from .encoding import DEFAULT_PAGE_SIZE
from .schema import EdgeTypeSchema
from .table import Column, DeltaIntColumn, PlainColumn, Table

BY_SRC = "by_src"
BY_DST = "by_dst"

ENC_PLAIN = "plain"     # baseline: PLAIN <src>/<dst>, unsorted (COO)
ENC_OFFSET = "offset"   # baseline: sorted + <offset>, PLAIN encoding
ENC_GRAPHAR = "graphar"  # sorted + <offset> + DELTA <src>/<dst>


@dataclasses.dataclass
class AdjacencyTable:
    """One sorted layout (CSR-like or CSC-like) of an edge type."""

    order: str                       # BY_SRC or BY_DST
    table: Table                     # <src>, <dst>, properties
    offsets: Optional[Table]         # single '<offset>' PlainColumn table
    num_key_vertices: int
    encoding: str = ENC_GRAPHAR
    #: size of the value-side vertex table -- the id space the fused
    #: decode->bitmap kernel scatters over; None disables the fused path.
    num_value_vertices: Optional[int] = None
    #: mutable plane (:class:`repro.core.delta_segment.DeltaSegments`):
    #: pending ingested edges, unioned with the packed base at dispatch
    #: time.  Attached lazily by ``attach_delta``; None = write-once.
    delta: Optional[object] = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def num_edges(self) -> int:
        return self.table.num_rows

    @property
    def key_col(self) -> str:
        return "<src>" if self.order == BY_SRC else "<dst>"

    @property
    def value_col(self) -> str:
        return "<dst>" if self.order == BY_SRC else "<src>"

    # -- index access ----------------------------------------------------------
    def edge_range(self, v: int, meter=None) -> Tuple[int, int]:
        """[lo, hi) edge rows of key vertex ``v`` via the <offset> table."""
        if self.offsets is None:
            raise ValueError("no <offset> table (plain layout)")
        col: PlainColumn = self.offsets["<offset>"]  # type: ignore
        pair = col.read_range(v, v + 2, meter)
        return int(pair[0]), int(pair[1])

    def offsets_at(self, rows, meter=None) -> np.ndarray:
        """Offset values at arbitrary rows, one page-deduplicated gather."""
        if self.offsets is None:
            raise ValueError("no <offset> table (plain layout)")
        rows = np.asarray(rows, np.int64)
        col = self.offsets["<offset>"]
        return np.asarray(col.read_rows_concat(rows, rows + 1, meter),
                          np.int64)

    def edge_ranges_batch(self, vs, meter=None
                          ) -> Tuple[np.ndarray, np.ndarray]:
        """Vectorized :meth:`edge_range` for a batch of key vertices.

        One deduplicated gather of the <offset> column yields every
        ``[lo, hi)`` pair; pages shared between vertices are charged once
        (vs. once per vertex in the scalar path).
        """
        if self.offsets is None:
            raise ValueError("no <offset> table (plain layout)")
        vs = np.asarray(vs, np.int64)
        if vs.size == 0:
            return np.zeros(0, np.int64), np.zeros(0, np.int64)
        col = self.offsets["<offset>"]
        pairs = np.asarray(col.read_rows_concat(vs, vs + 2, meter),
                           np.int64).reshape(-1, 2)
        return pairs[:, 0], pairs[:, 1]

    def neighbor_ids(self, v: int, meter=None) -> np.ndarray:
        """Sorted neighbor internal IDs of ``v`` (decodes touched pages only)."""
        lo, hi = self.edge_range(v, meter)
        return np.asarray(
            self.table[self.value_col].read_range(lo, hi, meter), np.int64)

    def neighbor_ids_scan(self, v: int, meter=None) -> np.ndarray:
        """Baseline 'plain': full scan of both columns, filter on key == v."""
        keys = np.asarray(self.table[self.key_col].read_all(meter))
        vals = np.asarray(self.table[self.value_col].read_all(meter))
        return np.sort(vals[keys == v]).astype(np.int64)

    def degrees(self) -> np.ndarray:
        col: PlainColumn = self.offsets["<offset>"]  # type: ignore
        off = col.values
        return np.diff(off)

    def topology_nbytes(self) -> int:
        n = self.table["<src>"].nbytes() + self.table["<dst>"].nbytes()
        if self.offsets is not None:
            n += self.offsets["<offset>"].nbytes()
        return n


@dataclasses.dataclass
class EdgeTable:
    """All materialized layouts of one edge type."""

    schema: EdgeTypeSchema
    layouts: Dict[str, AdjacencyTable]

    def adjacency(self, order: str = BY_SRC) -> AdjacencyTable:
        return self.layouts[order]

    @property
    def num_edges(self) -> int:
        return next(iter(self.layouts.values())).num_edges


def sort_edges(src: np.ndarray, dst: np.ndarray, order: str
               ) -> Tuple[np.ndarray, np.ndarray]:
    """Dual-key sort (paper: 'sorted first by source vertex IDs and then by
    destination vertex IDs'); returns permutation and sorted key array."""
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    if order == BY_SRC:
        perm = np.lexsort((dst, src))
    else:
        perm = np.lexsort((src, dst))
    return perm, (src[perm] if order == BY_SRC else dst[perm])


def build_offsets(sorted_keys: np.ndarray, num_key_vertices: int
                  ) -> np.ndarray:
    """<offset> array: offsets[v] = first edge row with key >= v."""
    return np.searchsorted(
        sorted_keys, np.arange(num_key_vertices + 1)).astype(np.int64)


def build_adjacency(src: np.ndarray, dst: np.ndarray,
                    num_src: int, num_dst: int,
                    order: str = BY_SRC,
                    encoding: str = ENC_GRAPHAR,
                    properties: Optional[Dict[str, np.ndarray]] = None,
                    page_size: int = DEFAULT_PAGE_SIZE,
                    name: str = "edges") -> AdjacencyTable:
    """Sort + offset + encode one adjacency layout (paper Fig. 10 pipeline)."""
    properties = properties or {}
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    n_edges = len(src)
    nkey = num_src if order == BY_SRC else num_dst
    nval = num_dst if order == BY_SRC else num_src

    if encoding == ENC_PLAIN:
        t = Table(f"{name}_{order}_plain", n_edges, page_size)
        t.add(PlainColumn("<src>", src.astype(np.int32), page_size))
        t.add(PlainColumn("<dst>", dst.astype(np.int32), page_size))
        for k, v in properties.items():
            t.add(PlainColumn(k, np.asarray(v), page_size))
        return AdjacencyTable(order, t, None, nkey, encoding, nval)

    perm, sorted_keys = sort_edges(src, dst, order)
    s, d = src[perm], dst[perm]
    off = build_offsets(sorted_keys, nkey)

    t = Table(f"{name}_{order}_{encoding}", n_edges, page_size)
    if encoding == ENC_GRAPHAR:
        t.add(DeltaIntColumn("<src>", s, page_size))
        t.add(DeltaIntColumn("<dst>", d, page_size))
    else:  # ENC_OFFSET: sorted but PLAIN-encoded topology
        t.add(PlainColumn("<src>", s.astype(np.int32), page_size))
        t.add(PlainColumn("<dst>", d.astype(np.int32), page_size))
    for k, v in properties.items():
        t.add(PlainColumn(k, np.asarray(v)[perm], page_size))

    ot = Table(f"{name}_{order}_offset", nkey + 1, page_size)
    ot.add(PlainColumn("<offset>", off, page_size))
    return AdjacencyTable(order, t, ot, nkey, encoding, nval)
