"""The compaction runner: merge -> persist -> swap -> gc, crash-safely.

One compaction folds a frozen snapshot of the pending delta rows into a
freshly built packed layout (the exact :func:`~repro.core.edge.build_adjacency`
pipeline, so the compacted layout is bit-identical to a from-scratch
rebuild over base + snapshot) and swaps it in **under the version
counter** while serving continues:

* the swap mutates the live column objects in place (pages, counts,
  offsets) and bumps ``DeltaColumn.version`` -- every derived cache
  (decoded-page LRU, packed device mirrors, partition packs, fused
  traversal plans) keys on the version and rebuilds lazily, so no
  reader ever holds a stale reference;
* on durable stores the new generation files are staged first and the
  committed state flips with **one** atomic manifest write -- the
  single commit point; a crash on either side of it leaves the store
  serving a consistent generation;
* the runner is a resumable stage machine retried with jittered
  exponential backoff (:mod:`repro.ft.backoff`); each injected fault
  (:mod:`repro.ft.faults` boundaries ``compact.merge`` /
  ``compact.pre_swap`` / ``compact.post_swap`` / ``compact.mid_gc`` /
  ``store.write``) aborts the current attempt at a well-defined point
  and the retry resumes from the last completed stage.  While a
  compaction is failing, the delta path keeps serving -- graceful
  degradation, never wrong answers.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.ft import faults as ft_faults
from repro.ft.backoff import Backoff, retry_call

from ..delta_segment import base_edges, live_delta
from ..edge import BY_SRC, AdjacencyTable, build_adjacency
from .gc import collect_garbage
from .policy import CompactionPolicy


class CompactionRunner:
    """Compacts one adjacency's mutable plane into new packed partitions.

    ``store`` is optional: without one the compaction is purely
    in-memory (swap only); with one, generation files are staged and the
    manifest flip is the durable commit point.  ``sleep`` is injectable
    so tests observe the backoff schedule without waiting it out.
    """

    def __init__(self, adj: AdjacencyTable, store=None,
                 policy: Optional[CompactionPolicy] = None,
                 faults: "Optional[ft_faults.FaultPlan]" = None,
                 backoff: Optional[Backoff] = None,
                 max_attempts: int = 5, sleep=None):
        self.adj = adj
        self.store = store
        self.policy = policy or CompactionPolicy()
        self.faults = faults
        self.backoff = backoff or Backoff(base=0.01, max_delay=0.25, seed=0)
        self.max_attempts = int(max_attempts)
        self.sleep = sleep if sleep is not None else (lambda _s: None)
        self._job: Optional[Dict[str, object]] = None
        self.compactions = 0   # completed merge->swap cycles
        self.attempts = 0      # _run invocations (first tries + retries)
        self.faults_hit = 0    # injected faults absorbed by retry
        self.gave_up = 0       # compact() calls that exhausted retries

    # -- policy gate -------------------------------------------------------
    def maybe_compact(self) -> bool:
        """Compact iff the policy says the backlog warrants it."""
        delta = live_delta(self.adj)
        if delta is None:
            return False
        if not self.policy.should_compact(delta.pending_rows(),
                                          self.adj.num_edges,
                                          delta.row_group_rows):
            return False
        return self.compact()

    # -- the resumable stage machine ---------------------------------------
    def compact(self) -> bool:
        """Run one full compaction; True when the swap committed.

        Injected faults are retried with backoff; after
        ``max_attempts`` total attempts the runner gives up gracefully
        -- the job (and its completed stages) is retained for a later
        ``compact()`` call and the delta path keeps serving meanwhile.
        """
        if live_delta(self.adj) is None and self._job is None:
            return False
        if self._job is None:
            self._job = {"stage": "merge"}
        try:
            retry_call(lambda: self._run(self._job),
                       retries=self.max_attempts - 1,
                       backoff=self.backoff, sleep=self.sleep,
                       retry_on=(ft_faults.InjectedFault,),
                       on_retry=self._note_fault)
        except ft_faults.InjectedFault:
            self.faults_hit += 1
            self.gave_up += 1
            return False
        self._job = None
        self.compactions += 1
        return True

    def _note_fault(self, attempt, delay, exc) -> None:
        self.faults_hit += 1

    def _run(self, job: Dict[str, object]) -> None:
        self.attempts += 1
        if job["stage"] == "merge":
            self._merge(job)
            job["stage"] = "persist"
        if job["stage"] == "persist":
            self._persist(job)
            job["stage"] = "swap"
        if job["stage"] == "swap":
            ft_faults.check(self.faults, "compact.pre_swap")
            self._swap(job)
            # swap is committed: a fault past this point must NOT redo it
            job["stage"] = "gc"
            ft_faults.check(self.faults, "compact.post_swap")
        if job["stage"] == "gc":
            if self.store is not None:
                collect_garbage(self.store, self.faults)
            job["stage"] = "done"

    def _merge(self, job: Dict[str, object]) -> None:
        """Snapshot the backlog and rebuild the packed layout over
        base + snapshot -- the identical ``build_adjacency`` pipeline a
        from-scratch rebuild runs, so pages come out bit-identical."""
        ft_faults.check(self.faults, "compact.merge")
        adj = self.adj
        delta = adj.delta
        frozen = delta.snapshot()
        ks = [k for k, _ in frozen.values()]
        vs = [v for _, v in frozen.values()]
        dk = np.concatenate(ks) if ks else np.zeros(0, np.int64)
        dv = np.concatenate(vs) if vs else np.zeros(0, np.int64)
        dsrc, ddst = (dk, dv) if adj.order == BY_SRC else (dv, dk)
        bsrc, bdst = base_edges(adj)
        nkey = adj.num_key_vertices
        nval = adj.num_value_vertices
        if adj.order == BY_SRC:
            num_src, num_dst = nkey, (nval if nval is not None else
                                      int(max(bdst.max(initial=0),
                                              ddst.max(initial=0))) + 1)
        else:
            num_dst, num_src = nkey, (nval if nval is not None else
                                      int(max(bsrc.max(initial=0),
                                              dsrc.max(initial=0))) + 1)
        new = build_adjacency(
            np.concatenate([bsrc, dsrc]), np.concatenate([bdst, ddst]),
            num_src, num_dst, order=adj.order, encoding=adj.encoding,
            page_size=adj.table.page_size)
        job["frozen"] = frozen
        job["new"] = new

    def _persist(self, job: Dict[str, object]) -> None:
        """Stage generation files -- invisible until the manifest flip.
        Idempotent: a retry rewrites the same staged files atomically."""
        if self.store is None:
            return
        new: AdjacencyTable = job["new"]  # type: ignore[assignment]
        if "generation" not in job:
            job["generation"] = self.store.current_generation() + 1
        gen = job["generation"]
        old = self.adj
        tables = {}
        manifest = self.store.manifest()
        if manifest is not None:
            tables.update(manifest.get("tables", {}))
        for logical, table in ((old.table.name, new.table),
                               (old.offsets.name, new.offsets)):
            # shallow rename so the store files carry the serving
            # table's logical name (columns shared by reference)
            staged = dataclasses.replace(table, name=logical)
            tables[logical] = self.store.write_generation(staged, gen)
        job["tables"] = tables

    def _swap(self, job: Dict[str, object]) -> None:
        """The commit: one atomic manifest flip (durable stores), then
        the in-place pointer swap under the version counter, then drop
        of exactly the frozen rows.  No fault boundary interleaves the
        in-memory steps, so readers see before-or-after, never between."""
        if self.store is not None:
            self.store.commit_manifest(job["tables"], job["generation"])
        adj = self.adj
        new: AdjacencyTable = job["new"]  # type: ignore[assignment]
        for name in ("<src>", "<dst>"):
            oldc = adj.table[name]
            newc = new.table[name]
            enc = oldc.encoded
            enc.pages = newc.encoded.pages
            enc.count = newc.encoded.count
            enc.packed_cache = None      # device mirrors re-ship next epoch
            enc.bump_version()           # every derived cache re-keys
            oldc.count = newc.count
        adj.table.num_rows = new.table.num_rows
        off = adj.offsets["<offset>"]
        off.values = new.offsets["<offset>"].values
        off._stats = None
        adj.delta.drop_rows(job["frozen"])
