"""Crash-consistent compaction of the mutable graph plane.

Folds pending delta-segment rows (:mod:`repro.core.delta_segment`) into
new packed partitions while serving continues, committing through a
single atomic manifest flip:

* :mod:`.policy` -- when to compact (pending rows vs. row-group size /
  base fraction);
* :mod:`.runner` -- the resumable merge -> persist -> swap -> gc stage
  machine, retried with jittered exponential backoff under injected
  faults (:mod:`repro.ft.faults`);
* :mod:`.gc` -- removal of files orphaned by a crash or superseded by a
  committed generation.
"""
from .gc import collect_garbage
from .policy import CompactionPolicy
from .runner import CompactionRunner

__all__ = ["CompactionPolicy", "CompactionRunner", "collect_garbage"]
