"""When to compact: pending delta rows vs. the packed base.

The memtable (delta segments) serves reads RAM-resident, so small
backlogs are cheap; compaction pays one full re-encode to restore the
write-once fast paths (fused traversal plans, device-resident zero
retraces).  The policy triggers when the backlog reaches a row-group's
worth of rows -- the natural flush unit -- or an outsized fraction of
the base.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass
class CompactionPolicy:
    #: absolute pending-row trigger; None = one row group
    #: (``DeltaSegments.row_group_rows``)
    min_delta_rows: Optional[int] = None
    #: relative trigger: pending >= fraction * base rows
    max_delta_fraction: float = 0.5

    def should_compact(self, pending_rows: int, base_rows: int,
                       row_group_rows: int) -> bool:
        if pending_rows <= 0:
            return False
        threshold = (self.min_delta_rows if self.min_delta_rows is not None
                     else row_group_rows)
        if pending_rows >= threshold:
            return True
        return base_rows > 0 and \
            pending_rows >= self.max_delta_fraction * base_rows
