"""Garbage collection for a :class:`~repro.core.storage.GraphStore`.

After a committed compaction (or a crash partway through one) the store
root can hold files no reader will ever follow: ``.tmp-*`` staging turds
from interrupted atomic writes, generation files that never made it into
the manifest, and legacy / older-generation files superseded by the
committed manifest.  Collection is idempotent -- a crash mid-GC
(``compact.mid_gc`` fault boundary, checked before every unlink) leaves
a subset removed and the next run removes the rest.
"""
from __future__ import annotations

import os
from typing import List, Optional

from repro.ft import faults as ft_faults

from ..storage import _GEN_RE, GraphStore


def collect_garbage(store: GraphStore,
                    faults: "Optional[ft_faults.FaultPlan]" = None
                    ) -> List[str]:
    """Remove unreferenced files from the store root; returns their names.

    Only files the committed manifest renders unreachable are touched:

    * ``*.tmp-*`` -- interrupted atomic-write staging files;
    * generation files (``<name>.g<gen>.gar``) the manifest does not
      reference -- staged by a compaction that never committed, or
      superseded by a later generation;
    * legacy ``<name>.gar`` files whose logical name the manifest now
      maps to a generation file.

    ``graph.yaml``, the manifest itself, and legacy tables outside the
    manifest (e.g. vertex/token tables of a write-once store) survive.
    """
    removed: List[str] = []
    if not os.path.isdir(store.root):
        return removed
    manifest = store.manifest()
    tables = {} if manifest is None else manifest.get("tables", {})
    referenced = set(tables.values())
    for fname in sorted(os.listdir(store.root)):
        if ".tmp-" in fname:
            dead = True
        elif fname in referenced:
            dead = False
        elif _GEN_RE.search(fname):
            dead = True
        elif fname.endswith(".gar") and fname[:-4] in tables:
            dead = True  # legacy file superseded by a committed generation
        else:
            dead = False
        if not dead:
            continue
        ft_faults.check(faults, "compact.mid_gc")
        os.unlink(os.path.join(store.root, fname))
        removed.append(fname)
    return removed
