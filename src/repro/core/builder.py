"""GraphAr construction: raw data -> sorted/encoded tables (paper §6.2.3).

The transformation pipeline has the paper's three steps, individually timed
so the Fig. 10 breakdown can be reproduced:
  1. ``sort``   -- dual-key lexsort of the edge list;
  2. ``offset`` -- build the <offset> index aligned with the vertex table;
  3. ``output`` -- encode (delta / RLE) and write the payload files.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from .edge import (BY_DST, BY_SRC, ENC_GRAPHAR, AdjacencyTable, EdgeTable,
                   build_adjacency, build_offsets, sort_edges)
from .schema import EdgeTypeSchema, GraphSchema, VertexTypeSchema
from .storage import GraphStore
from .vertex import LABEL_ENC_RLE, VertexTable


@dataclasses.dataclass
class TransformTiming:
    sort: float = 0.0
    offset: float = 0.0
    output: float = 0.0

    @property
    def total(self) -> float:
        return self.sort + self.offset + self.output


@dataclasses.dataclass
class Graph:
    """An in-memory LPG in GraphAr layout."""

    schema: GraphSchema
    vertices: Dict[str, VertexTable]
    edges: Dict[str, EdgeTable]

    def vertex(self, type_name: str) -> VertexTable:
        return self.vertices[type_name]

    def edge(self, name: str) -> EdgeTable:
        return self.edges[name]

    def adjacency(self, edge_name: str, order: str = BY_SRC) -> AdjacencyTable:
        return self.edges[edge_name].adjacency(order)

    def read_properties_batch(self, type_name: str, pac, names,
                              meter=None) -> Dict[str, np.ndarray]:
        """Batched multi-property gather over one vertex type: every named
        column fetched for exactly the PAC's ids in a single deduplicated
        pass over the PAC's page set (see
        :meth:`repro.core.vertex.VertexTable.read_properties_batch`)."""
        return self.vertices[type_name].read_properties_batch(
            pac, names, meter)

    def save(self, root: str) -> None:
        store = GraphStore(root)
        store.write_schema_yaml(self.schema)
        for vt in self.vertices.values():
            store.write(vt.table)
        for et in self.edges.values():
            for adj in et.layouts.values():
                store.write(adj.table)
                if adj.offsets is not None:
                    store.write(adj.offsets)


class GraphArBuilder:
    """Assemble a :class:`Graph` from raw numpy data."""

    def __init__(self, name: str, prefix: str = "."):
        self.schema = GraphSchema(name, prefix)
        self._vertices: Dict[str, VertexTable] = {}
        self._edges: Dict[str, EdgeTable] = {}
        self.timing = TransformTiming()

    # -- vertices ---------------------------------------------------------------
    def add_vertices(self, vschema: VertexTypeSchema,
                     properties: Dict[str, object],
                     labels: Optional[Dict[str, np.ndarray]] = None,
                     label_encoding: str = LABEL_ENC_RLE,
                     num_vertices: Optional[int] = None) -> "GraphArBuilder":
        t0 = time.perf_counter()
        vt = VertexTable.build(vschema, properties, labels, label_encoding,
                               num_vertices)
        self.timing.output += time.perf_counter() - t0
        self.schema.add_vertex_type(vschema)
        self._vertices[vschema.name] = vt
        return self

    # -- edges ------------------------------------------------------------------
    def add_edges(self, eschema: EdgeTypeSchema,
                  src: np.ndarray, dst: np.ndarray,
                  properties: Optional[Dict[str, np.ndarray]] = None,
                  encoding: str = ENC_GRAPHAR) -> "GraphArBuilder":
        num_src = self._vertices[eschema.src_type].num_vertices
        num_dst = self._vertices[eschema.dst_type].num_vertices
        layouts: Dict[str, AdjacencyTable] = {}
        for order in eschema.adjacency:
            order = {"by_src": BY_SRC, "by_dst": BY_DST}[order]
            # timed sort (reported in the Fig. 10 breakdown)
            t0 = time.perf_counter()
            perm, sorted_keys = sort_edges(src, dst, order)
            t1 = time.perf_counter()
            nkey = num_src if order == BY_SRC else num_dst
            build_offsets(sorted_keys, nkey)
            t2 = time.perf_counter()
            adj = build_adjacency(src, dst, num_src, num_dst, order=order,
                                  encoding=encoding, properties=properties,
                                  page_size=eschema.page_size,
                                  name=eschema.name)
            t3 = time.perf_counter()
            self.timing.sort += t1 - t0
            self.timing.offset += t2 - t1
            # build_adjacency re-sorts internally; attribute only encode time
            self.timing.output += max(t3 - t2 - (t1 - t0) - (t2 - t1), 0.0)
            layouts[order] = adj
        self.schema.add_edge_type(eschema)
        self._edges[eschema.name] = EdgeTable(eschema, layouts)
        return self

    def build(self) -> Graph:
        return Graph(self.schema, dict(self._vertices), dict(self._edges))
