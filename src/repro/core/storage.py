"""Storage plane: persistence + data-lake media cost model.

Two concerns:

1. **Persistence** -- serialize :class:`~repro.core.table.Table` objects to
   disk and back.  The physical container is one ``.gar`` file per table: a
   binary blob of column-chunk buffers with a JSON footer (mirroring the
   Parquet file/column/page metadata hierarchy of the paper's Fig. 2).

2. **Media cost model** -- the paper evaluates tmpfs / ESSD / OSS (Table 2).
   This container has a single local disk, so remote/cold media are modeled:
   an :class:`IOMeter` accumulates (bytes, requests) from every page-granular
   read, and a :class:`MediaModel` converts that into seconds with the
   bandwidth/latency of the paper's platforms.  Since data-lake reads are
   I/O-bound, "bytes touched" is exactly what the encodings optimize, and
   the modeled speedups track the paper's measured ones.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import struct
from typing import Dict, List, Optional

import numpy as np

from repro.ft import faults as ft_faults

from .encoding import DeltaColumn, DeltaPage, RleColumn
from .table import (BoolPlainColumn, BoolRleColumn, Column, DeltaIntColumn,
                    PlainColumn, StringColumn, Table, TokensColumn)

MAGIC = b"GAR1"


# --------------------------------------------------------------------------
# media cost model
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MediaModel:
    """Seconds = requests * latency + bytes / bandwidth."""

    name: str
    bandwidth: float  # bytes / s
    latency: float    # s / request

    def seconds(self, nbytes: int, nrequests: int) -> float:
        return nrequests * self.latency + nbytes / self.bandwidth


#: Paper §6.1/§6.4 platforms: PL0 ESSD peaks at 180 MB/s; tmpfs is RAM;
#: OSS is S3-like object storage (high latency, moderate bandwidth).
TMPFS = MediaModel("tmpfs", bandwidth=8e9, latency=2e-7)
ESSD = MediaModel("essd", bandwidth=180e6, latency=1e-4)
OSS = MediaModel("oss", bandwidth=40e6, latency=8e-3)
MEDIA = {m.name: m for m in (TMPFS, ESSD, OSS)}


class IOMeter:
    """Accumulates the (bytes, requests) footprint of page-granular reads."""

    def __init__(self) -> None:
        self.nbytes = 0
        self.nrequests = 0

    def record(self, nbytes: int, nrequests: int = 1) -> None:
        self.nbytes += int(nbytes)
        self.nrequests += int(nrequests)

    def reset(self) -> None:
        self.nbytes = 0
        self.nrequests = 0

    def seconds(self, media: MediaModel) -> float:
        return media.seconds(self.nbytes, self.nrequests)

    def __repr__(self) -> str:
        return f"IOMeter(bytes={self.nbytes}, requests={self.nrequests})"


# --------------------------------------------------------------------------
# persistence: .gar single-file container (buffers + JSON footer)
# --------------------------------------------------------------------------

def _np_buf(arr: np.ndarray) -> bytes:
    return np.ascontiguousarray(arr).tobytes()


class _Writer:
    def __init__(self) -> None:
        self.bufs: List[bytes] = []
        self.offset = 0

    def put(self, data: bytes) -> Dict[str, int]:
        ref = {"offset": self.offset, "length": len(data)}
        self.bufs.append(data)
        self.offset += len(data)
        return ref


def _col_meta_and_bufs(col: Column, w: _Writer) -> dict:
    if isinstance(col, DeltaIntColumn):
        enc = col.encoded
        pages_meta = []
        for p in enc.pages:
            pages_meta.append({
                "count": p.count, "first": p.first_value,
                # per-page value statistics (partition plane pruning);
                # readers of files without them fall back to the
                # unknown-hull sentinel, which disables pruning only
                "vmin": p.vmin, "vmax": p.vmax,
                "min_deltas": w.put(_np_buf(p.min_deltas)),
                "bit_widths": w.put(_np_buf(p.bit_widths)),
                "word_offsets": w.put(_np_buf(p.word_offsets)),
                "packed": w.put(_np_buf(p.packed)),
            })
        return {"kind": "delta", "count": enc.count,
                "page_size": enc.page_size, "pages": pages_meta}
    if isinstance(col, BoolRleColumn):
        enc = col.encoded
        return {"kind": "rle", "count": enc.count,
                "first": bool(enc.first_value),
                "positions": w.put(_np_buf(enc.positions))}
    if isinstance(col, BoolPlainColumn):
        return {"kind": "bool_plain", "count": col.count,
                "data": w.put(_np_buf(col.values))}
    if isinstance(col, StringColumn):
        return {"kind": "string", "count": col.count,
                "offsets": w.put(_np_buf(col.offsets)),
                "payload": w.put(col.payload)}
    if isinstance(col, TokensColumn):
        return {"kind": "tokens", "count": col.count,
                "offsets": w.put(_np_buf(col.offsets)),
                "values": w.put(_np_buf(col.values))}
    if isinstance(col, PlainColumn):
        return {"kind": "plain", "count": col.count,
                "dtype": str(col.values.dtype),
                "data": w.put(_np_buf(col.values))}
    raise TypeError(f"unsupported column type {type(col)}")


def _atomic_write_bytes(path: str, blob: bytes, faults=None) -> int:
    """Durable write: temp file + ``os.replace`` (atomic on POSIX).

    Readers never observe a torn file at ``path`` -- they see either the
    old contents or the new ones.  A crash mid-write (exercised via the
    ``store.write`` fault boundary, injected between the two halves of
    the payload) leaves only a ``.tmp-*`` turd that garbage collection
    removes; ``path`` itself is untouched.
    """
    tmp = f"{path}.tmp-{os.getpid()}"
    half = len(blob) // 2
    f = open(tmp, "wb")
    try:
        f.write(blob[:half])
        ft_faults.check(faults, "store.write")
        f.write(blob[half:])
        f.flush()
        os.fsync(f.fileno())
    finally:
        f.close()
    os.replace(tmp, path)
    return len(blob)


def table_blob(table: Table) -> bytes:
    """The full ``.gar`` container bytes of ``table`` (in memory)."""
    w = _Writer()
    cols_meta = {}
    for name, col in table.columns.items():
        m = _col_meta_and_bufs(col, w)
        m["page_size"] = col.page_size
        cols_meta[name] = m
    footer = json.dumps({
        "name": table.name, "num_rows": table.num_rows,
        "page_size": table.page_size, "columns": cols_meta,
    }).encode("utf-8")
    return b"".join([MAGIC, *w.bufs, footer,
                     struct.pack("<I", len(footer)), MAGIC])


def write_table(table: Table, path: str, faults=None) -> int:
    """Serialize ``table`` to ``path`` (.gar), atomically.

    Returns file size in bytes.  The container is staged as a sibling
    temp file and renamed into place, so a crash mid-write never
    corrupts an existing table.
    """
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    return _atomic_write_bytes(path, table_blob(table), faults)


def _read_ref(data: bytes, ref: dict, dtype=None) -> np.ndarray:
    raw = data[ref["offset"]:ref["offset"] + ref["length"]]
    if dtype is None:
        return raw
    return np.frombuffer(raw, dtype=dtype).copy()


def read_table(path: str) -> Table:
    with open(path, "rb") as f:
        blob = f.read()
    if blob[:4] != MAGIC or blob[-4:] != MAGIC:
        raise ValueError(f"{path}: not a GraphAr container")
    (footer_len,) = struct.unpack("<I", blob[-8:-4])
    footer = json.loads(blob[-8 - footer_len:-8].decode("utf-8"))
    body = blob[4:]
    table = Table(footer["name"], footer["num_rows"], footer["page_size"])
    for name, m in footer["columns"].items():
        ps = m.get("page_size", table.page_size)
        kind = m["kind"]
        if kind == "delta":
            pages = []
            for pm in m["pages"]:
                pages.append(DeltaPage(
                    count=pm["count"], first_value=pm["first"],
                    min_deltas=_read_ref(body, pm["min_deltas"], np.int64),
                    bit_widths=_read_ref(body, pm["bit_widths"], np.uint8),
                    word_offsets=_read_ref(body, pm["word_offsets"], np.int32),
                    packed=_read_ref(body, pm["packed"], np.uint32),
                    vmin=pm.get("vmin", 0), vmax=pm.get("vmax", -1)))
            col = DeltaIntColumn.__new__(DeltaIntColumn)
            col.name, col.count, col.page_size = name, m["count"], ps
            col.encoded = DeltaColumn(m["count"], m["page_size"], pages)
        elif kind == "rle":
            col = BoolRleColumn.__new__(BoolRleColumn)
            col.name, col.count, col.page_size = name, m["count"], ps
            col.encoded = RleColumn(m["count"], m["first"],
                                    _read_ref(body, m["positions"], np.int64))
        elif kind == "bool_plain":
            col = BoolPlainColumn(name, _read_ref(body, m["data"], np.bool_),
                                  ps)
        elif kind == "string":
            col = StringColumn.from_parts(
                name, _read_ref(body, m["offsets"], np.int64),
                bytes(_read_ref(body, m["payload"])), ps)
        elif kind == "tokens":
            col = TokensColumn.from_parts(
                name, _read_ref(body, m["offsets"], np.int64),
                _read_ref(body, m["values"], np.int32), ps)
        elif kind == "plain":
            col = PlainColumn(name, _read_ref(body, m["data"],
                                              np.dtype(m["dtype"])), ps)
        else:
            raise ValueError(f"unknown column kind {kind}")
        table.add(col)
    return table


# --------------------------------------------------------------------------
# dataset-level store: a directory of .gar files + graph.yaml
# --------------------------------------------------------------------------

MANIFEST = "manifest.json"
_GEN_RE = re.compile(r"\.g\d+\.gar$")


class GraphStore:
    """Directory layout: ``<root>/graph.yaml`` + ``<root>/<table>.gar``.

    Crash consistency (mutable plane): every file lands via temp +
    ``os.replace``, and multi-file updates (compaction writing a new
    generation of edge tables) commit through **one** atomic manifest
    flip -- ``manifest.json`` maps each logical table name to the
    physical generation file (``<name>.g<gen>.gar``) that serves it.
    Readers follow the manifest when present and fall back to the legacy
    ``<name>.gar`` layout otherwise, so write-once stores keep working
    unchanged.  Files orphaned by a crash (staged generations that never
    got committed, ``.tmp-*`` turds) are removed by
    :func:`repro.core.compaction.gc.collect_garbage`.
    """

    def __init__(self, root: str, faults=None):
        self.root = root
        #: optional :class:`repro.ft.faults.FaultPlan` threaded into
        #: every write this store issues
        self.faults = faults

    def table_path(self, name: str) -> str:
        return os.path.join(self.root, f"{name}.gar")

    # -- manifest (the atomic commit point) --------------------------------
    def manifest_path(self) -> str:
        return os.path.join(self.root, MANIFEST)

    def manifest(self) -> Optional[dict]:
        """The committed manifest, or None for a legacy/fresh store."""
        try:
            with open(self.manifest_path(), "r", encoding="utf-8") as f:
                return json.load(f)
        except FileNotFoundError:
            return None

    def current_generation(self) -> int:
        m = self.manifest()
        return 0 if m is None else int(m.get("generation", 0))

    def commit_manifest(self, tables: Dict[str, str],
                        generation: int) -> None:
        """Atomically flip the manifest pointer -- the single commit
        point of a multi-file update.  ``tables`` maps logical table
        names to physical filenames inside the store root."""
        blob = json.dumps({"generation": int(generation),
                           "tables": dict(tables)},
                          sort_keys=True).encode("utf-8")
        os.makedirs(self.root, exist_ok=True)
        _atomic_write_bytes(self.manifest_path(), blob, self.faults)

    def write(self, table: Table) -> int:
        return write_table(table, self.table_path(table.name),
                           self.faults)

    def write_generation(self, table: Table, generation: int) -> str:
        """Stage one generation file (``<name>.g<gen>.gar``); invisible
        to readers until :meth:`commit_manifest` references it."""
        fname = f"{table.name}.g{int(generation)}.gar"
        write_table(table, os.path.join(self.root, fname), self.faults)
        return fname

    def read(self, name: str) -> Table:
        m = self.manifest()
        if m is not None and name in m.get("tables", {}):
            return read_table(os.path.join(self.root,
                                           m["tables"][name]))
        return read_table(self.table_path(name))

    def write_schema_yaml(self, schema) -> None:
        schema.save(os.path.join(self.root, "graph.yaml"))

    def read_schema_yaml(self):
        from .schema import GraphSchema
        return GraphSchema.load(os.path.join(self.root, "graph.yaml"))

    def list_tables(self) -> List[str]:
        if not os.path.isdir(self.root):
            return []
        m = self.manifest()
        names = set() if m is None else set(m.get("tables", {}))
        for f in os.listdir(self.root):
            # legacy write-once files; generation files only count via
            # the manifest (an uncommitted one is invisible garbage)
            if f.endswith(".gar") and not _GEN_RE.search(f):
                names.add(f[:-4])
        return sorted(names)
