"""Page-aligned collections (PAC) -- paper Definition 1.

A PAC is a list of up to ``m`` collections, one per data page of a target
vertex-table column; collection ``C_i`` holds the internal IDs falling in
page ``i``.  Non-empty collections only are retained (real graphs are
sparse, so most pages are irrelevant).  Each collection is represented as a
**bitmap** (paper §4.3, following selection-pushdown practice): bit ``j`` of
page ``i`` set <=> internal ID ``i * page_size + j`` is in the collection.

Bitmaps are arrays of uint32 words, 32 bits per word, little-endian bit
order within the word -- the exact layout the Pallas kernels produce.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Tuple

import numpy as np

from .encoding import DEFAULT_PAGE_SIZE

_BIT = np.uint32(1)


def words_per_page(page_size: int) -> int:
    return -(-page_size // 32)


def pages_union(pacs: Iterable["PAC"]) -> List[int]:
    """Sorted page set touched by any of several PACs (multi-PAC -> pages).

    The page list drives property-fetch pushdown for a whole batch: pages
    shared by several collections are fetched once.
    """
    pages: set = set()
    for pac in pacs:
        pages.update(pac.bitmaps)
    return sorted(pages)


def ids_to_bitmap(ids: np.ndarray, base: int, page_size: int) -> np.ndarray:
    """Bitmap for one page: ids must lie in [base, base + page_size)."""
    rel = np.asarray(ids, np.int64) - base
    words = np.zeros(words_per_page(page_size), np.uint32)
    np.bitwise_or.at(words, rel >> 5, _BIT << (rel & 31).astype(np.uint32))
    return words


def bitmap_to_ids(words: np.ndarray, base: int) -> np.ndarray:
    """Set-bit positions (ascending) offset by ``base``."""
    w = np.asarray(words, np.uint32)
    bits = np.unpackbits(w.view(np.uint8), bitorder="little")
    return base + np.flatnonzero(bits).astype(np.int64)


def popcount(words: np.ndarray) -> int:
    return int(np.unpackbits(np.asarray(words, np.uint32).view(np.uint8)).sum())


class PAC:
    """Sparse page->bitmap mapping for one target table."""

    def __init__(self, page_size: int = DEFAULT_PAGE_SIZE,
                 bitmaps: Dict[int, np.ndarray] | None = None):
        self.page_size = page_size
        self.bitmaps: Dict[int, np.ndarray] = bitmaps or {}

    # -- constructors --------------------------------------------------------
    @classmethod
    def from_ids(cls, ids: np.ndarray,
                 page_size: int = DEFAULT_PAGE_SIZE) -> "PAC":
        ids = np.asarray(ids, np.int64)
        pac = cls(page_size)
        if ids.size == 0:
            return pac
        pages = ids // page_size
        # ids from neighbor retrieval are sorted; group contiguously.
        boundaries = np.flatnonzero(np.diff(pages)) + 1
        splits = np.split(ids, boundaries)
        for chunk in splits:
            p = int(chunk[0] // page_size)
            pac.bitmaps[p] = ids_to_bitmap(chunk, p * page_size, page_size)
        return pac

    @classmethod
    def from_bitmap_planes(cls, planes: np.ndarray,
                           page_size: int = DEFAULT_PAGE_SIZE,
                           pages: np.ndarray | None = None) -> "PAC":
        """PAC from per-page bitmap planes (the fused kernels' output).

        ``planes`` is ``uint32[n, words_per_page(page_size)]``; row ``i``
        is the bitmap of page ``pages[i]`` (default: page ``i``).  Empty
        planes are dropped -- the kernel writes the dense plane stack, the
        PAC keeps only the sparse non-empty page set.
        """
        planes = np.ascontiguousarray(planes, np.uint32)
        if planes.ndim != 2 or planes.shape[1] != words_per_page(page_size):
            raise ValueError(
                f"planes must be [n, {words_per_page(page_size)}] for "
                f"page_size={page_size}, got {planes.shape}")
        if pages is None:
            pages = np.arange(planes.shape[0], dtype=np.int64)
        nonempty = planes.any(axis=1)
        pac = cls(page_size)
        for p, plane in zip(np.asarray(pages, np.int64)[nonempty],
                            planes[nonempty]):
            pac.bitmaps[int(p)] = plane.copy()
        return pac

    @classmethod
    def from_dense_bitmap(cls, words: np.ndarray,
                          page_size: int = DEFAULT_PAGE_SIZE) -> "PAC":
        """PAC from one dense bitmap over ``[0, 32 * len(words))``.

        Requires ``page_size % 32 == 0`` so page boundaries fall on word
        boundaries; the tail is zero-padded to a whole plane.
        """
        if page_size % 32:
            raise ValueError("page_size must be a multiple of 32")
        words = np.asarray(words, np.uint32)
        wpp = words_per_page(page_size)
        pad = (-len(words)) % wpp
        if pad:
            words = np.concatenate([words, np.zeros(pad, np.uint32)])
        return cls.from_bitmap_planes(words.reshape(-1, wpp), page_size)

    @classmethod
    def from_intervals(cls, starts: np.ndarray, ends: np.ndarray, n: int,
                       page_size: int = DEFAULT_PAGE_SIZE) -> "PAC":
        """PAC covering half-open [start, end) ranges (label filtering)."""
        pac = cls(page_size)
        wpp = words_per_page(page_size)
        for s, e in zip(np.asarray(starts, np.int64),
                        np.asarray(ends, np.int64)):
            s, e = int(s), int(min(e, n))
            if e <= s:
                continue
            for p in range(s // page_size, (e - 1) // page_size + 1):
                base = p * page_size
                lo = max(s - base, 0)
                hi = min(e - base, page_size)
                bm = pac.bitmaps.get(p)
                if bm is None:
                    bm = np.zeros(wpp, np.uint32)
                    pac.bitmaps[p] = bm
                idx = np.arange(lo, hi, dtype=np.int64)
                np.bitwise_or.at(bm, idx >> 5,
                                 _BIT << (idx & 31).astype(np.uint32))
        return pac

    # -- set algebra (page-wise word ops) ------------------------------------
    def intersect(self, other: "PAC") -> "PAC":
        assert self.page_size == other.page_size
        out = PAC(self.page_size)
        for p in self.bitmaps.keys() & other.bitmaps.keys():
            w = self.bitmaps[p] & other.bitmaps[p]
            if w.any():
                out.bitmaps[p] = w
        return out

    def union(self, other: "PAC") -> "PAC":
        assert self.page_size == other.page_size
        out = PAC(self.page_size)
        for p in self.bitmaps.keys() | other.bitmaps.keys():
            a = self.bitmaps.get(p)
            b = other.bitmaps.get(p)
            out.bitmaps[p] = (a | b) if (a is not None and b is not None) \
                else (a if a is not None else b).copy()
        return out

    def difference(self, other: "PAC") -> "PAC":
        out = PAC(self.page_size)
        for p, a in self.bitmaps.items():
            b = other.bitmaps.get(p)
            w = a & ~b if b is not None else a.copy()
            if w.any():
                out.bitmaps[p] = w
        return out

    def union_(self, other: "PAC") -> "PAC":
        """In-place union (merge): OR ``other`` into this PAC."""
        assert self.page_size == other.page_size
        for p, b in other.bitmaps.items():
            a = self.bitmaps.get(p)
            self.bitmaps[p] = b.copy() if a is None else (a | b)
        return self

    @classmethod
    def union_all(cls, pacs: Iterable["PAC"],
                  page_size: int = DEFAULT_PAGE_SIZE) -> "PAC":
        """Merged PAC of many per-vertex PACs (batched retrieval result)."""
        out = None
        for pac in pacs:
            if out is None:
                out = cls(pac.page_size)
            out.union_(pac)
        return out if out is not None else cls(page_size)

    # -- accessors ------------------------------------------------------------
    def pages(self) -> List[int]:
        return sorted(self.bitmaps)

    def count(self) -> int:
        return sum(popcount(w) for w in self.bitmaps.values())

    def to_ids(self) -> np.ndarray:
        parts = [bitmap_to_ids(self.bitmaps[p], p * self.page_size)
                 for p in self.pages()]
        return (np.concatenate(parts) if parts else np.zeros(0, np.int64))

    def select(self, page_values: Dict[int, np.ndarray]) -> np.ndarray:
        """Selection pushdown: gather values whose bit is set, per page."""
        out = []
        for p in self.pages():
            vals = page_values[p]
            rel = bitmap_to_ids(self.bitmaps[p], 0)
            rel = rel[rel < len(vals)]
            out.append(np.asarray(vals)[rel])
        return (np.concatenate(out) if out else np.zeros(0))

    def __len__(self) -> int:
        return len(self.bitmaps)

    def __eq__(self, other) -> bool:
        if not isinstance(other, PAC) or self.page_size != other.page_size:
            return NotImplemented
        if self.bitmaps.keys() != other.bitmaps.keys():
            return False
        return all(np.array_equal(w, other.bitmaps[p])
                   for p, w in self.bitmaps.items())

    # mutable value semantics: equality by content, deliberately unhashable
    __hash__ = None

    def __repr__(self) -> str:
        return (f"PAC(pages={len(self.bitmaps)}, ids={self.count()}, "
                f"page_size={self.page_size})")
