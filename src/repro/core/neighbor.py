"""Neighbor retrieval (paper §4, Definitions 1-2) -- batched plane.

Given vertex ``v``:
  1. the ``<offset>`` index gives the edge-row range ``[lo, hi)``;
  2. only the delta pages of the value column overlapping that range are
     loaded and decoded (I/O metered);
  3. decoded neighbor IDs are grouped into a :class:`PAC` over the *target
     vertex table's* pages, each collection a bitmap;
  4. property fetch touches only the pages with non-empty collections and
     selects within each page by bitmap (selection pushdown, §4.3).

The unit of work is a **batch of vertices**, not a vertex:
``retrieve_neighbors_batch`` performs one vectorized offsets gather, one
page-deduplicated multi-range decode, and returns a merged (unioned) PAC;
``k_hop`` expands whole frontiers with no per-vertex Python loop.  The
single-vertex entry points remain as the batch-of-one special case.

The decode step has three interchangeable engines:
  * ``numpy``  -- the storage-plane oracle (encoding.py),
  * ``jax``    -- jnp reference (kernels/pac_decode/ref.py),
  * ``pallas`` -- fused unpack->scan->bitmap TPU kernel (interpret-mode on
                  CPU), the adaptation of the paper's BMI/SIMD decoder.
"""
from __future__ import annotations

import numpy as np

from .delta_segment import live_delta
from .edge import AdjacencyTable
from .pac import PAC
from .partition import ensure_default_partitions
from .table import DeltaIntColumn
from .vertex import VertexTable


def _mirror_poisoned(adj: AdjacencyTable) -> bool:
    """True when the column's device mirror is marked poisoned (a failed
    or corrupted transfer): kernel paths fall back to the host oracle --
    ids and IOMeter are engine-identical by construction, so degradation
    is invisible to results.  A compaction (or any version bump) rebuilds
    the mirror and heals the route."""
    col = adj.table[adj.value_col]
    if not isinstance(col, DeltaIntColumn):
        return False
    packed = col.encoded.packed_cache
    if packed is not None and packed.poisoned:
        packed.fallbacks += 1
        return True
    return False


def _kernel_column(adj: AdjacencyTable):
    col = adj.table[adj.value_col]
    if not isinstance(col, DeltaIntColumn):
        raise TypeError("kernel engines require a delta-encoded column")
    # REPRO_PARTITIONS default: columns without explicit partitioning
    # pick up the environment's partition count here, so every batched
    # consumer (k_hop, IC-8/BI-2, serving) routes through the partition
    # plane transparently
    ensure_default_partitions(col.encoded)
    return col.encoded


def decode_edge_ranges(adj: AdjacencyTable, los, his, meter=None,
                       engine: str = "numpy", qual=None) -> np.ndarray:
    """Concatenated neighbor IDs over many edge-row ranges (multiplicity
    preserved), decoding the deduplicated page set once.

    This is the shared multi-range primitive under every batched consumer
    (IC-8 hop fan-out, BI-2 interval ranges, k-hop frontiers, serving).

    ``qual`` -- a predicate's half-open qualifying ``[lo, hi)`` id hull
    -- enables page-granular statistics pushdown: pages whose zone map
    cannot intersect it are neither decoded nor charged, and their rows
    (all of which fail the predicate) are dropped from the output.  Only
    callers that go on to filter by that predicate may pass it.  The
    numpy engine then routes through the kernel layer's pruning decode
    (engine-dispatched to the numpy oracle) so accounting stays
    identical across engines by construction.
    """
    if engine != "numpy" and _mirror_poisoned(adj):
        engine = "numpy"  # poisoned device mirror: host oracle decodes
    if engine == "numpy":
        if qual is None or not isinstance(adj.table[adj.value_col],
                                          DeltaIntColumn):
            return np.asarray(
                adj.table[adj.value_col].read_rows_concat(los, his, meter),
                np.int64)
        from repro.kernels.pac_decode import ops as pac_ops
        return pac_ops.decode_row_ranges(_kernel_column(adj), los, his,
                                         meter=meter, engine="numpy",
                                         qual=qual)
    from repro.kernels.pac_decode import ops as pac_ops
    return pac_ops.decode_row_ranges(_kernel_column(adj), los, his,
                                     meter=meter, engine=engine, qual=qual)


def neighbor_ids_batch(adj: AdjacencyTable, vs, meter=None,
                       engine: str = "numpy",
                       unique: bool = True, qual=None) -> np.ndarray:
    """Neighbor IDs of a whole batch of vertices.

    One vectorized offsets gather + one multi-range decode; duplicate
    vertices in ``vs`` and empty adjacencies cost nothing extra.  With
    ``unique`` the result is the sorted union; otherwise the concatenation
    in ``vs`` order (multiplicity preserved).

    Pending delta rows (the mutable plane) are unioned in at this level,
    so every consumer -- the k-hop host loops included -- sees ingested
    edges immediately; delta reads are RAM-resident and charge no lake
    I/O.  The merged per-vertex lists equal a from-scratch rebuild's.

    ``qual`` (unique mode only) pushes a predicate's qualifying hull down
    for statistics pruning -- base pages *and* delta segments outside it
    are skipped; ids that survive still need the caller's exact filter.
    The non-unique merge path never prunes: its per-vertex alignment
    requires every row.
    """
    los, his = adj.edge_ranges_batch(vs, meter)
    ids = decode_edge_ranges(adj, los, his, meter, engine,
                             qual=qual if unique else None)
    delta = live_delta(adj)
    if delta is None:
        return np.unique(ids) if unique else ids
    if unique:
        return np.union1d(ids, delta.unique_ids(vs, qual))
    dvals, dlens = delta.lookup_batch(vs)
    lengths = np.maximum(his - los, 0)
    # per-vertex sorted merge of (base rows, delta rows) -- exactly the
    # per-vertex list the rebuilt dual-key layout would decode
    seg = np.concatenate([np.repeat(np.arange(lengths.size), lengths),
                          np.repeat(np.arange(dlens.size), dlens)])
    allv = np.concatenate([ids, dvals])
    return allv[np.lexsort((allv, seg))]


def retrieve_neighbors_batch(adj: AdjacencyTable, vs,
                             target_page_size: int,
                             meter=None,
                             engine: str = "numpy",
                             fused: bool | None = None,
                             filter=None,
                             resident: bool | None = None) -> PAC:
    """Batched Definition 2: merged PAC of the neighbors of every ``v`` in
    ``vs`` (equal to the union of the per-vertex PACs).

    On the kernel engines the merged PAC comes straight from the fused
    decode->bitmap kernel (one dispatch, bitmap planes consumed via
    ``PAC.from_dense_bitmap``) whenever the adjacency knows its value-side
    vertex count; ``fused=False`` forces the decode + ``PAC.from_ids``
    host path (the oracle).

    ``filter`` -- a :class:`repro.core.labels.LabelFilter` over the
    value-side vertex table -- pushes a label predicate down into the
    retrieval: "neighbors of batch B having label L".  On the fused path
    the predicate bitmap is evaluated and ANDed inside the same kernel
    dispatch (no host round-trip between filtering and retrieval); the
    host path intersects with the host-evaluated filter PAC and serves as
    the oracle.  The filter's label-metadata I/O is charged here, once,
    identically for every engine/path.

    ``resident`` selects the fused path's transfer regime: the
    device-resident column plane (packed pages mirrored on device once,
    dispatches ship page indices only -- the default, see
    ``REPRO_DEVICE_RESIDENT``) or the per-dispatch pack path.  Purely a
    transfer optimization: ids, meters, and PACs are identical."""
    vs = np.asarray(vs, np.int64)
    if engine == "numpy" and fused:
        raise ValueError("fused path requires a kernel engine (jax/pallas)")
    if vs.size == 0:
        return PAC(target_page_size)
    if filter is not None:
        filter.charge(meter)
    los, his = adj.edge_ranges_batch(vs, meter)
    # mutable plane: the batch's pending neighbors, zone-map-pruned by
    # the predicate's qualifying hull then exact-filtered host-side
    # (exact, so base-side statistics pruning can never drop a delta id).
    # RAM-resident -- no lake I/O charged.
    delta = live_delta(adj)
    delta_ids = None
    if delta is not None:
        qual = filter.qual_range() if filter is not None else None
        delta_ids = delta.unique_ids(vs, qual)
        if filter is not None and delta_ids.size:
            delta_ids = delta_ids[filter.mask_ids(delta_ids, engine)]
    if engine != "numpy" and _mirror_poisoned(adj):
        engine = "numpy"  # graceful degradation: host oracle serves
    if engine == "numpy":
        qual = filter.qual_range() if filter is not None else None
        ids = decode_edge_ranges(adj, los, his, meter, engine, qual=qual)
        pac = PAC.from_ids(np.unique(ids), target_page_size) \
            if ids.size else PAC(target_page_size)
        if filter is not None:
            pac = pac.intersect(filter.pac(target_page_size))
        if delta_ids is not None and delta_ids.size:
            pac = pac.union(PAC.from_ids(delta_ids, target_page_size))
        return pac
    from repro.kernels.pac_decode import ops as pac_ops
    return pac_ops.retrieve_pac_batch(_kernel_column(adj), los, his,
                                      target_page_size, meter, engine=engine,
                                      num_targets=adj.num_value_vertices,
                                      fused=fused, label_filter=filter,
                                      resident=resident,
                                      delta_ids=delta_ids)


def retrieve_neighbors(adj: AdjacencyTable, v: int,
                       target_page_size: int,
                       meter=None,
                       engine: str = "numpy") -> PAC:
    """Definition 2: PAC of the neighbor IDs of ``v``."""
    lo, hi = adj.edge_range(v, meter)
    if hi <= lo:
        return PAC(target_page_size)
    if engine == "numpy":
        ids = np.asarray(
            adj.table[adj.value_col].read_range(lo, hi, meter), np.int64)
        return PAC.from_ids(ids, target_page_size)
    # kernel engines decode pages directly to bitmaps without materializing
    # the id list in HBM; they share the same metering (pages touched).
    from repro.kernels.pac_decode import ops as pac_ops
    return pac_ops.retrieve_pac(_kernel_column(adj), lo, hi,
                                target_page_size, meter=meter,
                                use_pallas=(engine == "pallas"))


def retrieve_neighbors_scan(adj: AdjacencyTable, v: int,
                            target_page_size: int, meter=None) -> PAC:
    """Baseline 'plain': no offset index -- scan the whole edge table."""
    ids = adj.neighbor_ids_scan(v, meter)
    return PAC.from_ids(ids, target_page_size)


def fetch_properties(pac: PAC, vt: VertexTable, prop: str,
                     meter=None) -> np.ndarray:
    """Selection pushdown: fetch ``prop`` for exactly the PAC's IDs.

    Works unchanged over merged PACs: a page shared by many vertices of a
    batch appears once in the page set and is fetched once.
    """
    pages = pac.pages()
    page_vals = vt.read_property_pages(prop, pages, meter)
    return pac.select(page_vals)


def fetch_properties_batch(pac: PAC, vt: VertexTable, props,
                           meter=None) -> dict:
    """Batched multi-property selection pushdown: every column in
    ``props`` fetched for exactly the PAC's ids in one deduplicated pass
    over the PAC's page set (page list and per-page selection indices
    computed once and shared across columns; delta columns consult the
    decoded-page LRU).  Per-column results equal :func:`fetch_properties`.
    """
    return vt.read_properties_batch(pac, props, meter)


def neighbor_properties(adj: AdjacencyTable, v: int, vt: VertexTable,
                        prop: str, meter=None,
                        engine: str = "numpy") -> np.ndarray:
    """End-to-end §4.1 workflow: ids -> PAC -> per-page pushdown fetch."""
    pac = retrieve_neighbors(adj, v, vt.page_size, meter, engine)
    return fetch_properties(pac, vt, prop, meter)


def neighbor_properties_batch(adj: AdjacencyTable, vs, vt: VertexTable,
                              prop: str, meter=None,
                              engine: str = "numpy",
                              filter=None,
                              resident: bool | None = None,
                              partitions: int | None = None) -> np.ndarray:
    """Batched §4.1 workflow: one retrieval + one pushdown fetch for the
    whole batch's merged PAC (values in ascending neighbor-id order).

    ``filter`` / ``resident`` / ``partitions`` thread straight through to
    the batched retrieval (the same routing knobs
    :func:`retrieve_neighbors_batch` honors): a label predicate pushed
    into the retrieval dispatch, the transfer regime, and an explicit
    partition count for the adjacency value column."""
    _apply_partitions(adj, partitions)
    pac = retrieve_neighbors_batch(adj, vs, vt.page_size, meter, engine,
                                   filter=filter, resident=resident)
    return fetch_properties(pac, vt, prop, meter)


def _apply_partitions(adj: AdjacencyTable, partitions: int | None) -> None:
    """Explicit partition count for the adjacency value column (None
    keeps whatever is attached / the ``REPRO_PARTITIONS`` default)."""
    if partitions is None:
        return
    col = adj.table[adj.value_col]
    if not isinstance(col, DeltaIntColumn):
        raise TypeError("partitions= requires a delta-encoded column")
    from .partition import partition_column
    partition_column(col.encoded, partitions)


def _per_hop_filters(filter, hops: int) -> list:
    """Normalize ``filter=`` to one entry per hop: a single
    ``LabelFilter`` applies to every hop; a sequence gives hop ``h`` its
    own predicate (None entries leave that hop unfiltered)."""
    if filter is None:
        return [None] * hops
    if isinstance(filter, (list, tuple)):
        if len(filter) != hops:
            raise ValueError(f"filter sequence has {len(filter)} entries "
                             f"for {hops} hops")
        return list(filter)
    return [filter] * hops


def k_hop(adj: AdjacencyTable, seeds: np.ndarray, hops: int,
          meter=None, engine: str = "numpy",
          include_seeds: bool = True,
          filter=None,
          fused: bool | None = None,
          resident: bool | None = None,
          partitions: int | None = None) -> np.ndarray:
    """Multi-hop expansion (IC-8-style traversals). Returns unique IDs.

    On the kernel engines the k hops run as **one** fused
    ``lax.scan``-stepped dispatch over the device-resident frontier
    plane (:mod:`repro.kernels.traversal`): the frontier bitmap is
    expanded, predicate-ANDed, and visited-ANDNOTed on device every hop,
    with no host-side id materialization between hops.  ``fused=False``
    (and the numpy engine) keeps the **host-loop oracle**: each hop one
    batched retrieval over the current frontier with a boolean visited
    mask over the id space -- bit-identical ids and IOMeter to the fused
    path.

    ``include_seeds`` keeps the seed ids in the result (the historical
    behavior); ``include_seeds=False`` returns only discovered vertices.
    ``filter`` -- a :class:`~repro.core.labels.LabelFilter` over the
    value-side table, or a per-hop sequence of them -- drops
    non-qualifying ids from each hop's frontier (ANDed in place on the
    fused path; filtered ids stay unvisited and remain reachable via a
    later hop).  ``resident`` / ``partitions`` follow
    :func:`retrieve_neighbors_batch`'s routing knobs."""
    _apply_partitions(adj, partitions)
    if engine == "numpy" and fused:
        raise ValueError("fused path requires a kernel engine (jax/pallas)")
    filts = _per_hop_filters(filter, hops)
    if fused is None:
        from repro.kernels.pac_decode.ops import DEVICE_RESIDENT
        from repro.kernels.traversal.ops import plan_supported
        fused = (engine != "numpy" and plan_supported(adj)
                 and adj.num_key_vertices == adj.num_value_vertices
                 and (resident if resident is not None
                      else DEVICE_RESIDENT))
    if fused and (live_delta(adj) is not None or _mirror_poisoned(adj)):
        # graceful degradation, two flavors: the fused traversal plan is
        # built over the packed base only, so while delta rows are
        # pending the host loop serves (it unions the mutable plane per
        # hop); a poisoned device mirror routes the same way.  Once
        # compaction drains the plane and bumps the version, the fused
        # plan rebuilds and zero-retrace steady state resumes.  Counted
        # so serving stats show the degradation (``traversal.fallbacks``).
        from repro.kernels.traversal.ops import note_traversal_fallback
        note_traversal_fallback(adj)
        fused = False
    if fused:
        from repro.kernels.traversal.ops import k_hop_fused
        return k_hop_fused(adj, seeds, hops, filts, meter, engine,
                           include_seeds)
    seeds = np.unique(np.asarray(seeds, np.int64))
    if adj.num_value_vertices is None or adj.num_key_vertices is None:
        # no known id space: legacy set-based bookkeeping
        frontier, seen = seeds, seeds
        for h in range(hops):
            if frontier.size == 0:
                break
            if filts[h] is not None:
                filts[h].charge(meter)
            nbrs = neighbor_ids_batch(
                adj, frontier, meter, engine=engine,
                qual=filts[h].qual_range() if filts[h] is not None else None)
            if filts[h] is not None and nbrs.size:
                nbrs = nbrs[filts[h].mask_ids(nbrs, engine)]
            frontier = np.setdiff1d(nbrs, seen, assume_unique=True)
            seen = np.union1d(seen, frontier)
        return seen if include_seeds \
            else seen[~np.isin(seen, seeds, assume_unique=True)]
    # host oracle: boolean visited mask over the id space -- O(ids) per
    # hop instead of the O(n log n) setdiff1d/union1d re-sorts
    m = max(int(adj.num_key_vertices), int(adj.num_value_vertices))
    visited = np.zeros(m, bool)
    visited[seeds] = True
    frontier = seeds
    for h in range(hops):
        if frontier.size == 0:
            break
        if filts[h] is not None:
            filts[h].charge(meter)
        nbrs = neighbor_ids_batch(
            adj, frontier, meter, engine=engine,
            qual=filts[h].qual_range() if filts[h] is not None else None)
        if filts[h] is not None and nbrs.size:
            nbrs = nbrs[filts[h].mask_ids(nbrs, engine)]
        frontier = nbrs[~visited[nbrs]]
        visited[frontier] = True
    if not include_seeds:
        visited[seeds] = False
    return np.flatnonzero(visited).astype(np.int64)


def degrees_topk(adj: AdjacencyTable, k: int = 1) -> np.ndarray:
    """Vertices with the largest degree (paper §6.2.2 queries these)."""
    deg = adj.degrees()
    if k == 1:
        return np.array([int(np.argmax(deg))])
    return np.argsort(deg)[::-1][:k].astype(np.int64)
