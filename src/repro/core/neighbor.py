"""Neighbor retrieval (paper §4, Definitions 1-2).

Given vertex ``v``:
  1. the ``<offset>`` index gives the edge-row range ``[lo, hi)``;
  2. only the delta pages of the value column overlapping that range are
     loaded and decoded (I/O metered);
  3. decoded neighbor IDs are grouped into a :class:`PAC` over the *target
     vertex table's* pages, each collection a bitmap;
  4. property fetch touches only the pages with non-empty collections and
     selects within each page by bitmap (selection pushdown, §4.3).

The decode step has three interchangeable engines:
  * ``numpy``  -- the storage-plane oracle (encoding.py),
  * ``jax``    -- jnp reference (kernels/pac_decode/ref.py),
  * ``pallas`` -- fused unpack->scan->bitmap TPU kernel (interpret-mode on
                  CPU), the adaptation of the paper's BMI/SIMD decoder.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from .edge import AdjacencyTable
from .pac import PAC
from .vertex import VertexTable


def retrieve_neighbors(adj: AdjacencyTable, v: int,
                       target_page_size: int,
                       meter=None,
                       engine: str = "numpy") -> PAC:
    """Definition 2: PAC of the neighbor IDs of ``v``."""
    lo, hi = adj.edge_range(v, meter)
    if hi <= lo:
        return PAC(target_page_size)
    if engine == "numpy":
        ids = np.asarray(
            adj.table[adj.value_col].read_range(lo, hi, meter), np.int64)
        return PAC.from_ids(ids, target_page_size)
    # kernel engines decode pages directly to bitmaps without materializing
    # the id list in HBM; they share the same metering (pages touched).
    from repro.kernels.pac_decode import ops as pac_ops
    col = adj.table[adj.value_col]
    from .table import DeltaIntColumn
    if not isinstance(col, DeltaIntColumn):
        raise TypeError("kernel engines require a delta-encoded column")
    return pac_ops.retrieve_pac(col.encoded, lo, hi, target_page_size,
                                meter=meter,
                                use_pallas=(engine == "pallas"))


def retrieve_neighbors_scan(adj: AdjacencyTable, v: int,
                            target_page_size: int, meter=None) -> PAC:
    """Baseline 'plain': no offset index -- scan the whole edge table."""
    ids = adj.neighbor_ids_scan(v, meter)
    return PAC.from_ids(ids, target_page_size)


def fetch_properties(pac: PAC, vt: VertexTable, prop: str,
                     meter=None) -> np.ndarray:
    """Selection pushdown: fetch ``prop`` for exactly the PAC's IDs."""
    pages = pac.pages()
    page_vals = vt.read_property_pages(prop, pages, meter)
    return pac.select(page_vals)


def neighbor_properties(adj: AdjacencyTable, v: int, vt: VertexTable,
                        prop: str, meter=None,
                        engine: str = "numpy") -> np.ndarray:
    """End-to-end §4.1 workflow: ids -> PAC -> per-page pushdown fetch."""
    pac = retrieve_neighbors(adj, v, vt.page_size, meter, engine)
    return fetch_properties(pac, vt, prop, meter)


def k_hop(adj: AdjacencyTable, seeds: np.ndarray, hops: int,
          meter=None) -> np.ndarray:
    """Multi-hop expansion (IC-8-style traversals). Returns unique IDs."""
    frontier = np.unique(np.asarray(seeds, np.int64))
    seen = frontier
    for _ in range(hops):
        nxt: List[np.ndarray] = []
        for v in frontier:
            nxt.append(adj.neighbor_ids(int(v), meter))
        if not nxt:
            break
        frontier = np.setdiff1d(np.unique(np.concatenate(nxt)), seen,
                                assume_unique=True)
        seen = np.union1d(seen, frontier)
        if frontier.size == 0:
            break
    return seen


def degrees_topk(adj: AdjacencyTable, k: int = 1) -> np.ndarray:
    """Vertices with the largest degree (paper §6.2.2 queries these)."""
    deg = adj.degrees()
    if k == 1:
        return np.array([int(np.argmax(deg))])
    return np.argsort(deg)[::-1][:k].astype(np.int64)
