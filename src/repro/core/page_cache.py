"""Cross-query decoded-page LRU (the batched plane's warm-tick layer).

PR 1 deduplicated pages *within* one batch; serving re-touches the same
hot pages tick after tick and paid the full decode + lake fetch every
time.  A :class:`DecodedPageCache` is a per-column, capacity-bounded LRU
of **decoded** pages: every batched decode path (numpy
``Column._decode_pages``, kernel ``pac_decode.ops.decode_page_list`` /
``decode_row_ranges`` and the fused decode->bitmap entry) consults it and

* decodes / fetches only the cache-miss pages,
* charges the :class:`~repro.core.storage.IOMeter` for **misses only**
  (a hit is RAM-resident -- no lake I/O), with requests counted per
  contiguous run of miss pages,
* inserts the freshly decoded miss pages back, evicting
  least-recently-used entries past capacity.

The cache is deliberately storage-format-agnostic: it maps
``page index -> decoded int64 row array`` and keeps hit/miss/eviction
counters that serving surfaces through ``ServeEngine.stats()``.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


class DecodedPageCache:
    """Capacity-bounded LRU of decoded data pages for one column."""

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._pages: "OrderedDict[int, np.ndarray]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        #: column version the cached decodes correspond to (see
        #: :func:`live_cache`); bumped columns drop every entry.
        self.version = 0

    # -- access ---------------------------------------------------------------
    @staticmethod
    def _key(page: int, part: Optional[int]):
        """Entry key: plain page index on the monolithic paths,
        ``(partition, page)`` on the partition plane -- entries are
        namespaced per partition (together with :attr:`version` this is
        the ``(column_version, partition)`` keying: a version bump clears
        everything, and per-partition backfill stays coherent with the
        device shard that produced it)."""
        return page if part is None else (part, page)

    def get(self, page: int, part: Optional[int] = None
            ) -> Optional[np.ndarray]:
        """Decoded rows of ``page`` or None; counts the probe and bumps
        recency on hit."""
        key = self._key(page, part)
        arr = self._pages.get(key)
        if arr is None:
            self.misses += 1
            return None
        self._pages.move_to_end(key)
        self.hits += 1
        return arr

    def put(self, page: int, rows: np.ndarray,
            part: Optional[int] = None) -> None:
        """Insert (or refresh) a decoded page, evicting LRU past capacity."""
        key = self._key(page, part)
        if key in self._pages:
            self._pages.move_to_end(key)
            self._pages[key] = rows
            return
        self._pages[key] = rows
        while len(self._pages) > self.capacity:
            self._pages.popitem(last=False)
            self.evictions += 1

    def split(self, pages: Sequence[int], owner: Optional[Sequence[int]] = None
              ) -> Tuple[Dict[int, np.ndarray], List[int]]:
        """One probe per page: ``(hit page -> rows, ordered miss list)``.

        ``owner`` (parallel to ``pages``) carries each page's partition
        index on the partition plane; hits/misses are then probed in the
        partition namespace but still reported by global page id."""
        hits: Dict[int, np.ndarray] = {}
        miss: List[int] = []
        for i, p in enumerate(pages):
            arr = self.get(int(p), None if owner is None else int(owner[i]))
            if arr is None:
                miss.append(int(p))
            else:
                hits[int(p)] = arr
        return hits, miss

    # -- bookkeeping ----------------------------------------------------------
    def snapshot(self) -> Tuple:
        """Cheap point-in-time state for speculative consumers (the
        pipelined serving plane prefetches pages under a prediction and
        must be able to rewind exactly): entry *ordering* is part of the
        state -- recency drives eviction -- so the OrderedDict is
        shallow-copied (decoded rows are never mutated in place)."""
        return (OrderedDict(self._pages), self.hits, self.misses,
                self.evictions, self.version)

    def restore(self, state: Tuple) -> None:
        """Rewind to a :meth:`snapshot` (copying again, so one snapshot
        can back out several speculations)."""
        pages, self.hits, self.misses, self.evictions, self.version = state
        self._pages = OrderedDict(pages)

    def clear(self) -> None:
        self._pages.clear()

    def reset_stats(self) -> None:
        self.hits = self.misses = self.evictions = 0

    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "size": len(self._pages),
                "capacity": self.capacity}

    def __len__(self) -> int:
        return len(self._pages)

    def __contains__(self, page: int) -> bool:
        return page in self._pages

    def __repr__(self) -> str:
        return (f"DecodedPageCache(size={len(self._pages)}/{self.capacity}, "
                f"hits={self.hits}, misses={self.misses}, "
                f"evictions={self.evictions})")


def attach_page_cache(col, capacity: int) -> DecodedPageCache:
    """Attach a fresh LRU to a delta column (idempotent on capacity match).

    Accepts either a :class:`~repro.core.encoding.DeltaColumn` or a
    :class:`~repro.core.table.DeltaIntColumn` wrapper.
    """
    enc = getattr(col, "encoded", col)
    cache = getattr(enc, "page_cache", None)
    if cache is not None and cache.capacity == capacity:
        return cache
    cache = DecodedPageCache(capacity)
    cache.version = getattr(enc, "version", 0)
    enc.page_cache = cache
    return cache


def live_cache(col) -> Optional[DecodedPageCache]:
    """The column's decoded-page LRU, coherent with its current version.

    Every decode path consults the cache through this helper: when the
    column's write counter moved since the cache last served (an in-place
    page rewrite -- invisible to the old ``len(pages)`` keying), the
    stale decodes are dropped wholesale before any probe, so mutation can
    never serve stale rows.  Returns None when no cache is attached.
    """
    cache = getattr(col, "page_cache", None)
    if cache is None:
        return None
    v = getattr(col, "version", 0)
    if cache.version != v:
        cache.clear()
        cache.version = v
    return cache


def miss_runs(pages: Sequence[int]) -> int:
    """Read requests for a sorted page list: consecutive pages coalesce
    into one ranged GET (same convention as ``page_set_for_ranges``)."""
    if not len(pages):
        return 0
    return 1 + int(np.sum(np.diff(np.asarray(pages, np.int64)) > 1))
