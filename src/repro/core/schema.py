"""LPG schema metadata (paper §3.2): YAML graph descriptor.

An LPG is ``G = (V, E, T_V, T_E, P, L)``.  The YAML file captures what the
payload format cannot: the graph name, path prefix, the vertex/edge types,
their property definitions, candidate label sets, partition sizes and the
adjacency orderings materialized per edge type (CSR / CSC / COO).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional

import yaml

from .encoding import DEFAULT_PAGE_SIZE

DTYPES = ("int32", "int64", "float32", "float64", "bool", "string", "tokens")


@dataclasses.dataclass
class PropertySchema:
    name: str
    dtype: str  # one of DTYPES

    def __post_init__(self) -> None:
        if self.dtype not in DTYPES:
            raise ValueError(f"unknown dtype {self.dtype!r}")


@dataclasses.dataclass
class VertexTypeSchema:
    name: str
    properties: List[PropertySchema] = dataclasses.field(default_factory=list)
    labels: List[str] = dataclasses.field(default_factory=list)  # candidates
    partition_size: Optional[int] = None  # rows per physical partition
    page_size: int = DEFAULT_PAGE_SIZE

    def property_names(self) -> List[str]:
        return [p.name for p in self.properties]


@dataclasses.dataclass
class EdgeTypeSchema:
    """Edge type ``src_type-<relation>-dst_type`` (paper Fig. 4c)."""

    src_type: str
    relation: str
    dst_type: str
    properties: List[PropertySchema] = dataclasses.field(default_factory=list)
    # which sorted layouts are materialized ("by_src" ~= CSR, "by_dst" ~= CSC)
    adjacency: List[str] = dataclasses.field(
        default_factory=lambda: ["by_src"])
    partition_size: Optional[int] = None
    page_size: int = DEFAULT_PAGE_SIZE

    @property
    def name(self) -> str:
        return f"{self.src_type}-{self.relation}-{self.dst_type}"


@dataclasses.dataclass
class GraphSchema:
    name: str
    prefix: str = "."
    vertex_types: Dict[str, VertexTypeSchema] = dataclasses.field(
        default_factory=dict)
    edge_types: Dict[str, EdgeTypeSchema] = dataclasses.field(
        default_factory=dict)
    version: str = "graphar/v1"

    def add_vertex_type(self, vt: VertexTypeSchema) -> "GraphSchema":
        self.vertex_types[vt.name] = vt
        return self

    def add_edge_type(self, et: EdgeTypeSchema) -> "GraphSchema":
        self.edge_types[et.name] = et
        return self

    # -- YAML round trip ----------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "graphar": self.version,
            "name": self.name,
            "prefix": self.prefix,
            "vertices": [
                {
                    "type": vt.name,
                    "properties": [{"name": p.name, "dtype": p.dtype}
                                   for p in vt.properties],
                    "labels": list(vt.labels),
                    "partition_size": vt.partition_size,
                    "page_size": vt.page_size,
                }
                for vt in self.vertex_types.values()
            ],
            "edges": [
                {
                    "src": et.src_type,
                    "relation": et.relation,
                    "dst": et.dst_type,
                    "properties": [{"name": p.name, "dtype": p.dtype}
                                   for p in et.properties],
                    "adjacency": list(et.adjacency),
                    "partition_size": et.partition_size,
                    "page_size": et.page_size,
                }
                for et in self.edge_types.values()
            ],
        }

    def to_yaml(self) -> str:
        return yaml.safe_dump(self.to_dict(), sort_keys=False)

    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(path, "w") as f:
            f.write(self.to_yaml())

    @classmethod
    def from_dict(cls, d: dict) -> "GraphSchema":
        g = cls(name=d["name"], prefix=d.get("prefix", "."),
                version=d.get("graphar", "graphar/v1"))
        for v in d.get("vertices", []):
            g.add_vertex_type(VertexTypeSchema(
                name=v["type"],
                properties=[PropertySchema(p["name"], p["dtype"])
                            for p in v.get("properties", [])],
                labels=list(v.get("labels", [])),
                partition_size=v.get("partition_size"),
                page_size=v.get("page_size", DEFAULT_PAGE_SIZE)))
        for e in d.get("edges", []):
            g.add_edge_type(EdgeTypeSchema(
                src_type=e["src"], relation=e["relation"], dst_type=e["dst"],
                properties=[PropertySchema(p["name"], p["dtype"])
                            for p in e.get("properties", [])],
                adjacency=list(e.get("adjacency", ["by_src"])),
                partition_size=e.get("partition_size"),
                page_size=e.get("page_size", DEFAULT_PAGE_SIZE)))
        return g

    @classmethod
    def from_yaml(cls, text: str) -> "GraphSchema":
        return cls.from_dict(yaml.safe_load(text))

    @classmethod
    def load(cls, path: str) -> "GraphSchema":
        with open(path) as f:
            return cls.from_yaml(f.read())
