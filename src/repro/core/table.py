"""Parquet-like columnar container.

A :class:`Table` is one logical row group: named column chunks, each split
into fixed-size data pages (the paper's minimum I/O unit, Fig. 2).  Column
chunks carry page statistics for predicate pushdown.  Encodings:

* ``PlainColumn``    -- PLAIN fixed-width values.
* ``StringColumn``   -- PLAIN BYTE_ARRAY (offsets + utf-8 payload).
* ``DeltaIntColumn`` -- DELTA_BINARY_PACKED (see encoding.py).
* ``BoolRleColumn``  -- RLE boolean (interval position list).
* ``TokensColumn``   -- ragged int32 lists (offsets + values), used for the
                        document-token payload of the LM data pipeline.

Every read path is page-granular and reports bytes touched to an optional
:class:`repro.core.storage.IOMeter`, so data-lake I/O cost is modeled
exactly as "pages fetched x page bytes" (paper §4.1).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .encoding import (DEFAULT_PAGE_SIZE, DeltaColumn, RleColumn,
                       delta_decode_column, delta_encode_column,
                       rle_decode_bool, rle_encode_bool)

NUMPY_DTYPES = {
    "int32": np.int32, "int64": np.int64,
    "float32": np.float32, "float64": np.float64, "bool": np.bool_,
}


class Column:
    """Abstract column chunk."""

    name: str
    count: int
    page_size: int

    def nbytes(self) -> int:
        raise NotImplementedError

    def read_all(self, meter=None) -> np.ndarray:
        raise NotImplementedError

    def read_range(self, lo: int, hi: int, meter=None) -> np.ndarray:
        """Decode rows [lo, hi), charging whole pages overlapping the range."""
        raise NotImplementedError

    def read_row_ranges(self, los, his, meter=None) -> List[np.ndarray]:
        """Batched range reads with page de-duplication.

        Pages touched by several ranges are fetched/decoded/charged once;
        requests are counted per contiguous page run (what a real reader
        would issue).  This is the vectorized access pattern of interval
        queries (BI-2): intervals of sorted vertices map to contiguous edge
        ranges sharing pages.
        """
        los = np.asarray(los, np.int64)
        his = np.asarray(his, np.int64)
        ps = self.page_size
        pages = set()
        for lo, hi in zip(los, his):
            if hi > lo:
                pages.update(range(int(lo) // ps, int(hi - 1) // ps + 1))
        if not pages:
            return [np.zeros(0, np.int64) for _ in los]
        plist = sorted(pages)
        decoded = self._decode_pages(plist, meter)
        out = []
        for lo, hi in zip(los, his):
            if hi <= lo:
                out.append(decoded[plist[0]][:0])
                continue
            parts = []
            for p in range(int(lo) // ps, int(hi - 1) // ps + 1):
                vals = decoded[p]
                s = max(int(lo) - p * ps, 0)
                e = min(int(hi) - p * ps, len(vals))
                parts.append(vals[s:e])
            out.append(np.concatenate(parts))
        return out

    def _decode_pages(self, pages: Sequence[int], meter=None):
        """Decode a sorted page list, charging each page once."""
        raise NotImplementedError(type(self))

    def read_rows_concat(self, los, his, meter=None) -> np.ndarray:
        """Concatenation of rows over many [lo, hi) ranges, fully
        vectorized: page set, decode, and gather are all numpy ops (the
        inner loop of vectorized multi-hop expansion, e.g. IC-8/BI-2)."""
        los = np.asarray(los, np.int64)
        his = np.asarray(his, np.int64)
        lengths = np.maximum(his - los, 0)
        total = int(lengths.sum())
        if total == 0:
            return np.zeros(0, np.int64)
        ps = self.page_size
        keep = lengths > 0
        l, h = los[keep], his[keep]
        # unique page list via merged page intervals (numpy-only: sort,
        # running-max to find disjoint segments, ragged arange expansion)
        p0, p1 = l // ps, (h - 1) // ps
        order = np.argsort(p0, kind="stable")
        s, e = p0[order], p1[order] + 1
        cummax = np.maximum.accumulate(e)
        new_seg = np.ones(len(s), bool)
        new_seg[1:] = s[1:] > cummax[:-1]
        seg_idx = np.flatnonzero(new_seg)
        seg_start = s[seg_idx]
        seg_end = np.maximum.reduceat(cummax, seg_idx)
        seg_len = seg_end - seg_start
        tot = int(seg_len.sum())
        w = np.arange(tot) - np.repeat(np.cumsum(seg_len) - seg_len, seg_len)
        pages = (np.repeat(seg_start, seg_len) + w).tolist()
        decoded = self._decode_pages(pages, meter)
        plist = np.asarray(pages, np.int64)
        sizes = np.asarray([len(decoded[p]) for p in pages], np.int64)
        bases = np.concatenate([[0], np.cumsum(sizes)[:-1]])
        concat = np.concatenate([np.asarray(decoded[p]) for p in pages])
        # absolute row index for every output element
        rep = np.repeat(np.arange(len(l)), lengths[keep])
        within = np.arange(total) - np.repeat(
            np.cumsum(lengths[keep]) - lengths[keep], lengths[keep])
        rows = l[rep] + within
        page_of = rows // ps
        pidx = np.searchsorted(plist, page_of)
        pos = bases[pidx] + (rows - page_of * ps)
        return concat[pos]

    def n_pages(self) -> int:
        return -(-self.count // self.page_size) if self.count else 0

    def _charge(self, meter, nbytes: int, n_requests: int = 1) -> None:
        if meter is not None:
            meter.record(nbytes, n_requests)


@dataclasses.dataclass
class PageStats:
    vmin: float
    vmax: float


class PlainColumn(Column):
    def __init__(self, name: str, values: np.ndarray,
                 page_size: int = DEFAULT_PAGE_SIZE):
        self.name = name
        self.values = np.ascontiguousarray(values)
        self.count = len(values)
        self.page_size = page_size
        self._stats: Optional[List[PageStats]] = None

    def nbytes(self) -> int:
        return self.values.nbytes

    def page_stats(self) -> List[PageStats]:
        if self._stats is None:
            ps = self.page_size
            self._stats = [
                PageStats(float(self.values[i:i + ps].min()),
                          float(self.values[i:i + ps].max()))
                for i in range(0, self.count, ps)
            ]
        return self._stats

    def read_all(self, meter=None) -> np.ndarray:
        self._charge(meter, self.nbytes())
        return self.values

    def read_range(self, lo: int, hi: int, meter=None) -> np.ndarray:
        if hi <= lo:
            return self.values[:0]
        ps = self.page_size
        p0, p1 = lo // ps, (hi - 1) // ps + 1
        span_lo, span_hi = p0 * ps, min(p1 * ps, self.count)
        self._charge(meter,
                     (span_hi - span_lo) * self.values.dtype.itemsize, 1)
        return self.values[lo:hi]

    def read_pages(self, pages: Sequence[int], meter=None) -> Dict[int, np.ndarray]:
        """Fetch a set of (possibly non-contiguous) pages -> page values."""
        out = {}
        ps = self.page_size
        nreq = 0
        nbytes = 0
        for p in pages:
            s, e = p * ps, min((p + 1) * ps, self.count)
            out[p] = self.values[s:e]
            nbytes += (e - s) * self.values.dtype.itemsize
            nreq += 1
        self._charge(meter, nbytes, max(nreq, 1))
        return out

    def _decode_pages(self, pages: Sequence[int], meter=None):
        return self.read_pages(pages, meter)


class StringColumn(Column):
    """PLAIN BYTE_ARRAY: int32 offsets + utf-8 payload."""

    def __init__(self, name: str, strings: Sequence[str],
                 page_size: int = DEFAULT_PAGE_SIZE):
        self.name = name
        self.count = len(strings)
        self.page_size = page_size
        payload = bytearray()
        offsets = np.zeros(self.count + 1, np.int64)
        for i, s in enumerate(strings):
            b = s.encode("utf-8")
            payload.extend(b)
            offsets[i + 1] = offsets[i] + len(b)
        self.offsets = offsets
        self.payload = bytes(payload)

    @classmethod
    def from_parts(cls, name: str, offsets: np.ndarray, payload: bytes,
                   page_size: int = DEFAULT_PAGE_SIZE) -> "StringColumn":
        obj = cls.__new__(cls)
        obj.name = name
        obj.offsets = np.asarray(offsets, np.int64)
        obj.payload = payload
        obj.count = len(obj.offsets) - 1
        obj.page_size = page_size
        return obj

    def nbytes(self) -> int:
        # 4B offset per row (as stored) + payload
        return 4 * self.count + len(self.payload)

    def get(self, i: int) -> str:
        s, e = self.offsets[i], self.offsets[i + 1]
        return self.payload[s:e].decode("utf-8")

    def read_all(self, meter=None) -> List[str]:
        self._charge(meter, self.nbytes())
        return [self.get(i) for i in range(self.count)]

    def read_range(self, lo: int, hi: int, meter=None) -> List[str]:
        if hi <= lo:
            return []
        ps = self.page_size
        p0, p1 = lo // ps, (hi - 1) // ps + 1
        s, e = p0 * ps, min(p1 * ps, self.count)
        nbytes = 4 * (e - s) + int(self.offsets[e] - self.offsets[s])
        self._charge(meter, nbytes, 1)
        return [self.get(i) for i in range(lo, hi)]


class DeltaIntColumn(Column):
    def __init__(self, name: str, values: np.ndarray,
                 page_size: int = DEFAULT_PAGE_SIZE):
        self.name = name
        self.count = len(values)
        self.page_size = page_size
        self.encoded: DeltaColumn = delta_encode_column(values, page_size)

    def nbytes(self) -> int:
        return self.encoded.nbytes()

    def read_all(self, meter=None) -> np.ndarray:
        self._charge(meter, self.nbytes())
        return delta_decode_column(self.encoded)

    def read_range(self, lo: int, hi: int, meter=None) -> np.ndarray:
        # routed through _decode_pages so the single-vertex path shares
        # the decoded-page LRU (and its miss-only charging) with the
        # batched paths -- engines must meter identically either way
        if hi <= lo:
            return np.zeros(0, np.int64)
        ps = self.page_size
        p0, p1 = lo // ps, (hi - 1) // ps + 1
        decoded = self._decode_pages(list(range(p0, p1)), meter)
        joined = np.concatenate([decoded[p] for p in range(p0, p1)])
        return joined[lo - p0 * ps: hi - p0 * ps]

    def _decode_pages(self, pages: Sequence[int], meter=None):
        from .encoding import delta_decode_page
        from .page_cache import live_cache, miss_runs
        from .partition import live_partitions
        cache = live_cache(self.encoded)
        part_of = {}
        if cache is None:
            out, miss = {}, [int(p) for p in pages]
        else:
            # partitioned columns namespace their decoded-page LRU
            # entries (partition, page), matching the sharded dispatch
            # paths so the host and kernel planes share warm pages
            parts = live_partitions(self.encoded)
            owner = (parts.part_of_pages(np.asarray(pages, np.int64))
                     if parts is not None else None)
            if owner is not None:
                part_of = {int(p): int(o) for p, o in zip(pages, owner)}
            out, miss = cache.split(pages, owner=owner)
        if miss:
            nbytes = sum(self.encoded.pages[p].nbytes() for p in miss)
            self._charge(meter, nbytes, miss_runs(miss))
            for p in miss:
                d = delta_decode_page(self.encoded.pages[p])
                out[p] = d
                if cache is not None:
                    cache.put(p, d, part=part_of.get(p))
        return out


class BoolRleColumn(Column):
    def __init__(self, name: str, values: np.ndarray,
                 page_size: int = DEFAULT_PAGE_SIZE):
        self.name = name
        self.count = len(values)
        self.page_size = page_size
        self.encoded: RleColumn = rle_encode_bool(values)

    def nbytes(self) -> int:
        return self.encoded.nbytes()

    def read_all(self, meter=None) -> np.ndarray:
        self._charge(meter, self.nbytes())
        return rle_decode_bool(self.encoded)

    def read_range(self, lo: int, hi: int, meter=None) -> np.ndarray:
        # interval metadata is tiny; charge it wholesale (it is the point
        # of RLE that the entire column's metadata is a few KB).
        self._charge(meter, self.nbytes(), 1)
        return rle_decode_bool(self.encoded)[lo:hi]


class BoolPlainColumn(PlainColumn):
    """Baseline 'binary (plain)' of the paper: one byte per row."""

    def __init__(self, name: str, values: np.ndarray,
                 page_size: int = DEFAULT_PAGE_SIZE):
        super().__init__(name, np.asarray(values, np.bool_), page_size)


class TokensColumn(Column):
    """Ragged int32 token lists (offsets + flat values)."""

    def __init__(self, name: str, lists: Sequence[np.ndarray],
                 page_size: int = DEFAULT_PAGE_SIZE):
        self.name = name
        self.count = len(lists)
        self.page_size = page_size
        self.offsets = np.zeros(self.count + 1, np.int64)
        for i, l in enumerate(lists):
            self.offsets[i + 1] = self.offsets[i] + len(l)
        self.values = (np.concatenate([np.asarray(l, np.int32) for l in lists])
                       if lists else np.zeros(0, np.int32))

    @classmethod
    def from_parts(cls, name: str, offsets: np.ndarray, values: np.ndarray,
                   page_size: int = DEFAULT_PAGE_SIZE) -> "TokensColumn":
        obj = cls.__new__(cls)
        obj.name, obj.page_size = name, page_size
        obj.offsets = np.asarray(offsets, np.int64)
        obj.values = np.asarray(values, np.int32)
        obj.count = len(obj.offsets) - 1
        return obj

    def nbytes(self) -> int:
        return 4 * self.count + self.values.nbytes

    def get(self, i: int) -> np.ndarray:
        return self.values[self.offsets[i]:self.offsets[i + 1]]

    def read_all(self, meter=None) -> List[np.ndarray]:
        self._charge(meter, self.nbytes())
        return [self.get(i) for i in range(self.count)]

    def read_range(self, lo: int, hi: int, meter=None) -> List[np.ndarray]:
        if hi <= lo:
            return []
        nbytes = 4 * (hi - lo) + 4 * int(self.offsets[hi] - self.offsets[lo])
        self._charge(meter, nbytes, 1)
        return [self.get(i) for i in range(lo, hi)]

    def read_rows(self, rows: np.ndarray, meter=None) -> List[np.ndarray]:
        rows = np.asarray(rows, np.int64)
        nbytes = 4 * len(rows) + 4 * int(
            (self.offsets[rows + 1] - self.offsets[rows]).sum())
        self._charge(meter, nbytes, len(rows))
        return [self.get(int(i)) for i in rows]


@dataclasses.dataclass
class Table:
    """One logical row group of named column chunks."""

    name: str
    num_rows: int
    page_size: int = DEFAULT_PAGE_SIZE
    columns: Dict[str, Column] = dataclasses.field(default_factory=dict)

    def add(self, col: Column) -> "Table":
        if col.count != self.num_rows:
            raise ValueError(
                f"column {col.name}: {col.count} rows != table {self.num_rows}")
        self.columns[col.name] = col
        return self

    def __getitem__(self, name: str) -> Column:
        return self.columns[name]

    def __contains__(self, name: str) -> bool:
        return name in self.columns

    def nbytes(self) -> int:
        return sum(c.nbytes() for c in self.columns.values())

    def column_names(self) -> List[str]:
        return list(self.columns)

    def n_pages(self) -> int:
        return -(-self.num_rows // self.page_size) if self.num_rows else 0

    def page_bounds(self, page: int) -> Tuple[int, int]:
        s = page * self.page_size
        return s, min(s + self.page_size, self.num_rows)
