"""Frontier: a dense bitmap over a vertex id space (the traversal unit).

Multi-hop traversal's working set -- "which vertices are on the frontier /
already visited" -- is a subset of one id space, and every per-hop
operation on it (expand, union, subtract-visited, predicate mask) is a
bitwise op over that space.  :class:`Frontier` makes the representation
explicit: uint32 words over ``[0, n)`` with the same bit convention as
:class:`~repro.core.pac.PAC` and the label-filter bitmaps (bit ``i & 31``
of word ``i >> 5``), so frontiers, predicate bitmaps, and PAC planes
compose by plain word-wise AND/OR/ANDNOT.

Like ``PackedPages.device``, a frontier keeps **engine-keyed device
mirrors**: ``device_plane(engine)`` is the dense int32 0/1 plane the
traversal kernels consume, placed once per engine and invalidated by any
mutating op (``or_`` / ``andnot`` / ``set_ids``).  The fused k-hop path
never ships planes per hop -- it builds them on device from seed ids --
but retrievers that pin a long-lived frontier (e.g. a "already served"
set) amortize the transfer here.

This type is also the substrate for the frontier-algorithm workloads in
the ROADMAP (BFS levels, shortest-path wavefronts, PageRank active sets).
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from .pac import PAC, bitmap_to_ids, popcount


def _words_for(n: int) -> int:
    return -(-max(n, 0) // 32)


def ids_to_words(ids: np.ndarray, n: int) -> np.ndarray:
    """uint32 bitmap words over ``[0, n)`` with the given bits set."""
    words = np.zeros(_words_for(n), np.uint32)
    ids = np.asarray(ids, np.int64)
    if ids.size:
        np.bitwise_or.at(words, ids >> 5,
                         np.uint32(1) << (ids & 31).astype(np.uint32))
    return words


def plane_to_words(plane: np.ndarray) -> np.ndarray:
    """Dense 0/1 (or bool) plane -> uint32 bitmap words (little-endian
    bit order, matching the PAC / label-filter convention)."""
    bits = np.asarray(plane) != 0
    pad = (-bits.size) % 32
    if pad:
        bits = np.concatenate([bits, np.zeros(pad, bool)])
    return np.packbits(bits, bitorder="little").view(np.uint32)


class Frontier:
    """A set of vertex ids in ``[0, n)`` as a dense uint32 bitmap."""

    __slots__ = ("n", "words", "_device", "device_transfers")

    def __init__(self, n: int, words: "np.ndarray | None" = None):
        self.n = int(n)
        if words is None:
            words = np.zeros(_words_for(n), np.uint32)
        else:
            words = np.asarray(words, np.uint32)
            if words.size != _words_for(n):
                raise ValueError(f"want {_words_for(n)} words for n={n}, "
                                 f"got {words.size}")
        self.words = words
        self._device: Dict[str, object] = {}
        self.device_transfers = 0

    # -- constructors -------------------------------------------------------
    @classmethod
    def from_ids(cls, ids, n: int) -> "Frontier":
        return cls(n, ids_to_words(ids, n))

    @classmethod
    def from_dense_plane(cls, plane, n: "int | None" = None) -> "Frontier":
        """From a 0/1 plane (the representation the kernels carry)."""
        plane = np.asarray(plane)
        if n is None:
            n = plane.size
        return cls(n, plane_to_words(plane[:n]))

    # -- views --------------------------------------------------------------
    def to_ids(self) -> np.ndarray:
        """Sorted member ids (int64)."""
        return bitmap_to_ids(self.words, 0)

    def to_pac(self, page_size: int) -> PAC:
        """The frontier as a PAC over ``page_size`` pages (32-aligned)."""
        return PAC.from_dense_bitmap(self.words, page_size)

    def count(self) -> int:
        """Member count (popcount over the words)."""
        return popcount(self.words)

    def __len__(self) -> int:
        return self.count()

    def __contains__(self, i: int) -> bool:
        return 0 <= i < self.n and bool(
            (self.words[i >> 5] >> np.uint32(i & 31)) & 1)

    def copy(self) -> "Frontier":
        return Frontier(self.n, self.words.copy())

    # -- set algebra (in place; device mirrors are invalidated) -------------
    def or_(self, other: "Frontier") -> "Frontier":
        """``self |= other`` (union)."""
        self._check(other)
        np.bitwise_or(self.words, other.words, out=self.words)
        self._device.clear()
        return self

    def andnot(self, other: "Frontier") -> "Frontier":
        """``self &= ~other`` (difference -- e.g. drop visited ids)."""
        self._check(other)
        np.bitwise_and(self.words, ~other.words, out=self.words)
        self._device.clear()
        return self

    def and_(self, other: "Frontier") -> "Frontier":
        """``self &= other`` (e.g. AND a predicate bitmap in place)."""
        self._check(other)
        np.bitwise_and(self.words, other.words, out=self.words)
        self._device.clear()
        return self

    def set_ids(self, ids) -> "Frontier":
        ids = np.asarray(ids, np.int64)
        if ids.size:
            np.bitwise_or.at(self.words, ids >> 5,
                             np.uint32(1) << (ids & 31).astype(np.uint32))
            self._device.clear()
        return self

    def _check(self, other: "Frontier") -> None:
        if other.n != self.n:
            raise ValueError(f"id-space mismatch: {self.n} vs {other.n}")

    # -- device mirrors (engine-keyed, like PackedPages.device) -------------
    def device_plane(self, engine: str):
        """Dense int32 0/1 plane ``[n]`` on device; placed once per
        engine and reused until the frontier mutates."""
        plane = self._device.get(engine)
        if plane is None:
            import jax.numpy as jnp
            ids = np.arange(self.n, dtype=np.int64)
            host = ((self.words[ids >> 5]
                     >> (ids & 31).astype(np.uint32)) & 1).astype(np.int32)
            plane = jnp.asarray(host)
            self._device[engine] = plane
            self.device_transfers += 1
        return plane

    def device_stats(self) -> Dict[str, int]:
        return {"engines": len(self._device),
                "transfers": self.device_transfers}

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Frontier(n={self.n}, count={self.count()})"
