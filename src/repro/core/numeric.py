"""Numeric predicate pushdown -- the value side of the statistics plane.

Label predicates (:mod:`repro.core.labels`) derive their qualifying-id
hull from RLE interval lists; this module extends the same compiled
filtering plane to **numeric property comparisons**.  A
:class:`NumProp` builder turns comparison operators into frozen
:class:`NumCmp` leaves (half-open value ranges ``lo <= prop < hi``);
the leaves compile through the unchanged :func:`~repro.core.labels.
compile_cond` stack machine (they expose ``leaf_key()``), so AND / OR /
NOT combinations of numeric comparisons evaluate with the same flat
program that label predicates use -- host planes, bitmap words, and
device kernels alike.

:class:`NumericFilter` is the :class:`~repro.core.labels.LabelFilter`
sibling the retrieval plane's ``filter=`` hook consumes.  Evaluation is
itself statistics-pruned: each leaf's value range is compared against
the property column's **per-page zone maps** (``PlainColumn.
page_stats``), and only pages whose ``[vmin, vmax]`` hull can intersect
the leaf's range are ever read -- pages skipped by the zone map are
provably all-False for that leaf, so the per-leaf boolean planes (and
everything derived from them: qualifying intervals, bitmaps, the
kernel :class:`~repro.kernels.label_filter.ops.FilterPlan`) are exact.
The filter's data-page I/O is recorded once at first evaluation and
replayed verbatim by :meth:`NumericFilter.charge` so every engine and
dispatch path charges identically, mirroring the label plane's
metadata-charge discipline.

Downstream, ``NumericFilter.qual_range()`` yields the qualifying-id
hull that drives partition, page, and delta-segment statistics pruning
-- numeric predicates push down exactly like label predicates do.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from .encoding import hull_intersects, rle_encode_bool
from .labels import (Cond, Intervals, LabelFilter, Not, bitmap_to_intervals,
                     compile_cond, eval_program, interval_hull,
                     intervals_to_bitmap)
from .storage import IOMeter
from .vertex import VertexTable

#: sentinels for unbounded comparison sides (well outside any int64
#: property this repo stores, and far from int64 overflow under +-1).
VALUE_LO = -(2 ** 62)
VALUE_HI = 2 ** 62


class NumCmp(Cond):
    """One half-open numeric comparison ``lo <= prop < hi`` (a leaf).

    Frozen and hashable -- :func:`~repro.core.labels.compile_cond`
    dedupes leaves by :meth:`leaf_key`, and kernels specialize on the
    compiled program as a static argument.
    """

    __slots__ = ("prop", "lo", "hi")

    def __init__(self, prop: str, lo: int, hi: int):
        object.__setattr__(self, "prop", prop)
        object.__setattr__(self, "lo", int(lo))
        object.__setattr__(self, "hi", int(hi))

    def __setattr__(self, *a):
        raise AttributeError("NumCmp is immutable")

    def leaf_key(self) -> "NumCmp":
        return self

    def labels(self) -> List[str]:
        return []

    def evaluate(self, env: Dict) -> np.ndarray:
        return env[self]

    def __hash__(self) -> int:
        return hash((NumCmp, self.prop, self.lo, self.hi))

    def __eq__(self, other) -> bool:
        return (isinstance(other, NumCmp) and self.prop == other.prop
                and self.lo == other.lo and self.hi == other.hi)

    def __repr__(self) -> str:
        lo = "" if self.lo <= VALUE_LO else f"{self.lo}<="
        hi = "" if self.hi >= VALUE_HI else f"<{self.hi}"
        return f"({lo}{self.prop}{hi})"


class NumProp:
    """Comparison builder over one numeric vertex property.

    ``NumProp("age") >= 30`` / ``< 18`` / ``== 7`` /
    ``.between(10, 20)`` all yield :class:`NumCmp` leaves composable
    with ``&``, ``|``, ``~`` -- and with label leaves they must *not*
    be mixed inside one filter (each filter evaluates over one plane
    family).
    """

    __slots__ = ("name",)

    def __init__(self, name: str):
        self.name = name

    def __ge__(self, v) -> NumCmp:
        return NumCmp(self.name, int(v), VALUE_HI)

    def __gt__(self, v) -> NumCmp:
        return NumCmp(self.name, int(v) + 1, VALUE_HI)

    def __lt__(self, v) -> NumCmp:
        return NumCmp(self.name, VALUE_LO, int(v))

    def __le__(self, v) -> NumCmp:
        return NumCmp(self.name, VALUE_LO, int(v) + 1)

    def __eq__(self, v) -> NumCmp:  # type: ignore[override]
        return NumCmp(self.name, int(v), int(v) + 1)

    def __ne__(self, v) -> Cond:  # type: ignore[override]
        return Not(NumCmp(self.name, int(v), int(v) + 1))

    def between(self, lo, hi) -> NumCmp:
        """Half-open range ``lo <= prop < hi``."""
        return NumCmp(self.name, int(lo), int(hi))

    def __repr__(self) -> str:
        return f"NumProp({self.name!r})"


class NumericFilter(LabelFilter):
    """A compiled numeric predicate bound to one vertex table.

    Drop-in sibling of :class:`~repro.core.labels.LabelFilter`: the
    retrieval plane's ``filter=`` hook, the fused kernel dispatches
    (via the inherited :meth:`plan`-consuming paths), and the
    statistics pushdown (``qual_range``) all work unchanged.  The leaf
    planes are built once, zone-map-pruned (see the module docstring),
    and the I/O of that one evaluation replays deterministically on
    every :meth:`charge`.
    """

    def __init__(self, vt: VertexTable, cond: Cond):
        self.vt = vt
        self.cond = cond
        self.program = compile_cond(cond)
        bad = [l for l in self.program.labels if not isinstance(l, NumCmp)]
        if bad:
            raise TypeError("NumericFilter conditions must be built from "
                            f"NumProp comparisons; got {bad[0]!r} (label "
                            "and numeric leaves cannot mix in one filter)")
        self._plan = None
        self._bitmaps: Dict[str, np.ndarray] = {}
        self._intervals: "Intervals | None" = None
        self._pacs: Dict = {}
        self._planes: "List[np.ndarray] | None" = None
        self._io: "Tuple[int, int] | None" = None
        #: property zone-map counters (observability only)
        self.prop_pages_read = 0
        self.prop_pages_skipped = 0

    # -- evaluation -----------------------------------------------------------

    def _leaf_planes(self) -> List[np.ndarray]:
        """Per-leaf boolean planes over ``[0, num_vertices)``, built once.

        Leaves grouped per property read the union of their zone-map-
        qualifying pages in one metered fetch; pages outside a leaf's
        hull stay False in its plane (exact -- the zone map proves no
        value there can satisfy the comparison), which keeps NOT safe
        through the program.
        """
        if self._planes is not None:
            return self._planes
        n = self.vt.num_vertices
        meter = IOMeter()
        leaves: List[NumCmp] = list(self.program.labels)
        planes: List = [None] * len(leaves)
        by_prop: Dict[str, List[int]] = {}
        for i, leaf in enumerate(leaves):
            by_prop.setdefault(leaf.prop, []).append(i)
        for prop, idxs in sorted(by_prop.items()):
            col = self.vt.property_column(prop)
            if not hasattr(col, "page_stats"):
                # no zone maps on this encoding: whole-column read
                vals = np.asarray(col.read_all(meter))
                for i in idxs:
                    lf = leaves[i]
                    planes[i] = (vals >= lf.lo) & (vals < lf.hi)
                continue
            stats = col.page_stats()
            ps = col.page_size
            quals = {i: [p for p, s in enumerate(stats)
                         if hull_intersects(s.vmin, s.vmax,
                                            leaves[i].lo, leaves[i].hi)]
                     for i in idxs}
            need = sorted({p for pl in quals.values() for p in pl})
            got = col.read_pages(need, meter) if need else {}
            self.prop_pages_read += len(need)
            self.prop_pages_skipped += len(stats) - len(need)
            for i in idxs:
                lf = leaves[i]
                plane = np.zeros(n, bool)
                for p in quals[i]:
                    seg = np.asarray(got[p])
                    plane[p * ps: p * ps + len(seg)] = \
                        (seg >= lf.lo) & (seg < lf.hi)
                planes[i] = plane
        self._io = (meter.nbytes, meter.nrequests)
        self._planes = planes
        return planes

    # -- LabelFilter interface ------------------------------------------------

    def charge(self, meter) -> None:
        """Replay the evaluation's recorded data-page I/O -- identical
        on every engine and dispatch path, like the label plane's
        metadata charge."""
        self._leaf_planes()
        if meter is not None:
            meter.record(*self._io)

    def plan(self):
        """Kernel-plane inputs: the leaf planes RLE-encoded into the
        exact pos/meta layout label plans use, so the cond kernels (and
        the fused filtered retrieval built on them) run unchanged.  The
        qualifying hull is set eagerly from the host intervals -- the
        lazy label-plane derivation resolves leaves by name and does
        not apply here."""
        if self._plan is None:
            from repro.kernels._pad import next_multiple
            from repro.kernels.label_filter.ops import FilterPlan
            planes = self._leaf_planes()
            n = self.vt.num_vertices
            rles = [rle_encode_bool(pl) for pl in planes]
            n_pos = next_multiple(max(r.positions.size for r in rles), 128)
            pos = np.full((len(rles), n_pos), n, np.int32)
            meta = np.zeros((len(rles), 2), np.int32)
            for i, r in enumerate(rles):
                pos[i, :r.positions.size] = r.positions
                meta[i] = (int(r.first_value), n)
            plan = FilterPlan(self.program, pos, meta, n, vt=self.vt)
            plan._qual = interval_hull(*self.intervals("numpy"))
            self._plan = plan
        return self._plan

    def intervals(self, engine: str = "numpy") -> Intervals:
        if engine == "numpy":
            if self._intervals is None:
                keep = np.asarray(
                    eval_program(self.program.ops, self._leaf_planes()),
                    bool)
                self._intervals = \
                    rle_encode_bool(keep).interval_starts(True)
            return self._intervals
        return bitmap_to_intervals(self.bitmap(engine),
                                   self.vt.num_vertices)

    def bitmap(self, engine: str = "numpy") -> np.ndarray:
        words = self._bitmaps.get(engine)
        if words is None:
            if engine == "numpy":
                words = intervals_to_bitmap(self.intervals("numpy"),
                                            self.vt.num_vertices)
            else:
                plan = self.plan()
                words = np.asarray(
                    plan.device_bitmap(engine, plan.n_words))
            self._bitmaps[engine] = words
        return words

    def __repr__(self) -> str:
        return f"NumericFilter({self.vt.schema.name}, {self.cond})"
