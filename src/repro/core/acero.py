"""Acero-like baseline: a vectorized scan/filter/join/aggregate engine.

The paper benchmarks GraphAr against query plans built with Apache Acero on
plain Parquet files (§6.5.1): scans with predicate pushdown, hash joins for
topology expansion, and string matching for label filtering.  This module is
that baseline, faithfully *without* GraphAr's layout/encoding tricks: tables
are unsorted COO edge lists and plain vertex tables; every operator charges
full column scans (minus page-stat pushdown where a real engine would have
it).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .storage import IOMeter
from .table import PlainColumn, StringColumn, Table


@dataclasses.dataclass
class Relation:
    """A materialized intermediate: named numpy columns of equal length."""

    columns: Dict[str, np.ndarray]

    @property
    def num_rows(self) -> int:
        if not self.columns:
            return 0
        return len(next(iter(self.columns.values())))

    def __getitem__(self, k: str) -> np.ndarray:
        return self.columns[k]

    def take(self, idx: np.ndarray) -> "Relation":
        return Relation({k: v[idx] for k, v in self.columns.items()})


def scan(table: Table, columns: Sequence[str], meter: Optional[IOMeter] = None,
         predicate: Optional[Tuple[str, str, float]] = None) -> Relation:
    """Scan with optional single-column predicate pushdown.

    ``predicate=(col, op, value)`` skips pages whose [min,max] statistics
    cannot satisfy the predicate (Parquet-style page pruning), then applies
    the predicate exactly.
    """
    out: Dict[str, np.ndarray] = {}
    if predicate is not None:
        pcol, op, val = predicate
        col = table[pcol]
        if isinstance(col, PlainColumn):
            stats = col.page_stats()
            ps = col.page_size
            keep_pages = []
            for i, stt in enumerate(stats):
                if op == "==" and not (stt.vmin <= val <= stt.vmax):
                    continue
                if op == ">=" and stt.vmax < val:
                    continue
                if op == "<=" and stt.vmin > val:
                    continue
                keep_pages.append(i)
            # fetch kept pages for every requested column
            rows: List[np.ndarray] = []
            base: List[np.ndarray] = []
            for p in keep_pages:
                s, e = table.page_bounds(p)
                base.append(np.arange(s, e, dtype=np.int64))
            base_idx = (np.concatenate(base) if base
                        else np.zeros(0, np.int64))
            pvals = np.concatenate([
                np.asarray(col.read_range(*table.page_bounds(p), meter))
                for p in keep_pages]) if keep_pages else np.zeros(0)
            if op == "==":
                mask = pvals == val
            elif op == ">=":
                mask = pvals >= val
            else:
                mask = pvals <= val
            sel = base_idx[mask]
            for name in columns:
                c = table[name]
                vals_pages = np.concatenate([
                    np.asarray(c.read_range(*table.page_bounds(p), meter))
                    for p in keep_pages]) if keep_pages else np.zeros(0)
                out[name] = vals_pages[mask]
            out["_row"] = sel
            return Relation(out)
        # non-plain predicate column: fall through to full scan
    for name in columns:
        c = table[name]
        vals = c.read_all(meter)
        out[name] = (np.asarray(vals) if not isinstance(vals, list)
                     else np.asarray(vals, dtype=object))
    out["_row"] = np.arange(table.num_rows, dtype=np.int64)
    return Relation(out)


def filter_rel(rel: Relation, mask: np.ndarray) -> Relation:
    return rel.take(np.flatnonzero(mask))


def hash_join(left: Relation, right: Relation, left_key: str,
              right_key: str, how: str = "inner") -> Relation:
    """Vectorized hash join (sort-based under the hood; same asymptotics)."""
    lk = np.asarray(left[left_key], np.int64)
    rk = np.asarray(right[right_key], np.int64)
    order = np.argsort(rk, kind="stable")
    rk_sorted = rk[order]
    lo = np.searchsorted(rk_sorted, lk, side="left")
    hi = np.searchsorted(rk_sorted, lk, side="right")
    counts = hi - lo
    l_idx = np.repeat(np.arange(len(lk)), counts)
    if len(lk):
        starts = np.repeat(lo, counts)
        within = np.arange(counts.sum()) - np.repeat(
            np.cumsum(counts) - counts, counts)
        r_idx = order[starts + within]
    else:
        r_idx = np.zeros(0, np.int64)
    cols: Dict[str, np.ndarray] = {}
    for k, v in left.columns.items():
        cols[k] = v[l_idx]
    for k, v in right.columns.items():
        cols[k if k not in cols else f"r_{k}"] = v[r_idx]
    return Relation(cols)


def aggregate_count(rel: Relation, key: str,
                    minlength: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """GROUP BY key -> COUNT(*), returned as (keys, counts)."""
    k = np.asarray(rel[key], np.int64)
    counts = np.bincount(k, minlength=minlength)
    keys = np.flatnonzero(counts)
    return keys, counts[keys]


def order_by(rel: Relation, key: str, desc: bool = True) -> Relation:
    idx = np.argsort(rel[key], kind="stable")
    if desc:
        idx = idx[::-1]
    return rel.take(idx)


def string_label_mask(strings: Sequence[str], label: str) -> np.ndarray:
    """Baseline label predicate: split + match per row (paper Fig. 3 step 1)."""
    out = np.zeros(len(strings), bool)
    for i, s in enumerate(strings):
        if s and label in s.split("|"):
            out[i] = True
    return out
