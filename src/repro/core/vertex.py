"""Vertex tables (paper §3.2, Fig. 4b).

Each row is one vertex with a 0-indexed implicit internal ID.  Property
columns are named after properties; label columns are named ``<Label>`` in
angle brackets and stored as RLE booleans (GraphAr) or as the paper's
baselines ("string" concatenation / "binary (plain)").  Partitioning with
trailing "bubbles" is supported via ``partition_size``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from .encoding import DEFAULT_PAGE_SIZE
from .schema import VertexTypeSchema
from .table import (BoolPlainColumn, BoolRleColumn, Column, PlainColumn,
                    StringColumn, Table, TokensColumn)

LABEL_ENC_RLE = "rle"          # GraphAr: binary (RLE)
LABEL_ENC_PLAIN = "plain"      # baseline: binary (plain)
LABEL_ENC_STRING = "string"    # baseline: concatenated string column


def label_col_name(label: str) -> str:
    return f"<{label}>"


@dataclasses.dataclass
class VertexTable:
    schema: VertexTypeSchema
    table: Table
    label_encoding: str = LABEL_ENC_RLE

    @property
    def num_vertices(self) -> int:
        return self.table.num_rows

    @property
    def page_size(self) -> int:
        return self.table.page_size

    # -- construction ---------------------------------------------------------
    @classmethod
    def build(cls, schema: VertexTypeSchema,
              properties: Dict[str, object],
              labels: Optional[Dict[str, np.ndarray]] = None,
              label_encoding: str = LABEL_ENC_RLE,
              num_vertices: Optional[int] = None) -> "VertexTable":
        labels = labels or {}
        if num_vertices is None:
            probe = (next(iter(properties.values()))
                     if properties else next(iter(labels.values())))
            num_vertices = len(probe)
        ps = schema.page_size or DEFAULT_PAGE_SIZE
        t = Table(f"vertex_{schema.name}", num_vertices, ps)
        for prop in schema.properties:
            vals = properties[prop.name]
            if prop.dtype == "string":
                t.add(StringColumn(prop.name, vals, ps))
            elif prop.dtype == "tokens":
                t.add(TokensColumn(prop.name, vals, ps))
            else:
                t.add(PlainColumn(prop.name, np.asarray(vals), ps))
        if label_encoding == LABEL_ENC_STRING:
            # paper baseline: all labels of a vertex in one BYTE_ARRAY column
            mat = np.stack([np.asarray(labels[l], bool)
                            for l in schema.labels], axis=1) \
                if schema.labels else np.zeros((num_vertices, 0), bool)
            strings = ["|".join(l for l, on in zip(schema.labels, row) if on)
                       for row in mat]
            t.add(StringColumn("<labels>", strings, ps))
        else:
            col_cls = (BoolRleColumn if label_encoding == LABEL_ENC_RLE
                       else BoolPlainColumn)
            for l in schema.labels:
                t.add(col_cls(label_col_name(l),
                              np.asarray(labels[l], bool), ps))
        return cls(schema, t, label_encoding)

    # -- access ---------------------------------------------------------------
    def property_column(self, name: str) -> Column:
        return self.table[name]

    def label_column(self, label: str) -> Column:
        if self.label_encoding == LABEL_ENC_STRING:
            return self.table["<labels>"]
        return self.table[label_col_name(label)]

    def label_rle(self, label: str):
        col = self.table[label_col_name(label)]
        if not isinstance(col, BoolRleColumn):
            raise TypeError("label columns are not RLE-encoded")
        return col.encoded

    def labels_nbytes(self) -> int:
        if self.label_encoding == LABEL_ENC_STRING:
            return self.table["<labels>"].nbytes()
        return sum(self.table[label_col_name(l)].nbytes()
                   for l in self.schema.labels)

    def read_property_pages(self, name: str, pages: Sequence[int],
                            meter=None) -> Dict[int, np.ndarray]:
        col = self.table[name]
        if isinstance(col, PlainColumn):
            return col.read_pages(pages, meter)
        out = {}
        for p in pages:
            s, e = self.table.page_bounds(p)
            out[p] = col.read_range(s, e, meter)
        return out

    def read_properties_batch(self, pac, names: Sequence[str],
                              meter=None) -> Dict[str, np.ndarray]:
        """Batched multi-property gather (selection pushdown, paper §4.3).

        Fetches every named property column for exactly the PAC's ids in
        a **single deduplicated pass** over the PAC's page set: the page
        list and the per-page selection indices are derived once -- one
        ``unpackbits`` over the whole bitmap-plane stack -- and shared by
        all columns, instead of re-deriving both per property as the
        per-column ``fetch_properties`` loop does.  Delta-encoded columns
        consult their decoded-page LRU page by page.  Values come back in
        ascending internal-id order, identical per column to
        :func:`repro.core.neighbor.fetch_properties`.
        """
        pages = pac.pages()
        if not pages:
            return {name: np.zeros(0) for name in names}
        planes = np.stack([pac.bitmaps[p] for p in pages])
        bits_per_plane = planes.shape[1] * 32
        flat = np.flatnonzero(
            np.unpackbits(planes.view(np.uint8), bitorder="little"))
        plane_of = flat // bits_per_plane
        # per-page relative indices, computed once for every column
        rel = np.split(flat % bits_per_plane,
                       np.searchsorted(plane_of, np.arange(1, len(pages))))
        out: Dict[str, np.ndarray] = {}
        for name in names:
            page_vals = self.read_property_pages(name, pages, meter)
            parts = []
            for p, r in zip(pages, rel):
                vals = np.asarray(page_vals[p])
                parts.append(vals[r[r < len(vals)]])
            out[name] = np.concatenate(parts) if parts else np.zeros(0)
        return out
