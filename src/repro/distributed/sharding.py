"""Sharding rules: parameter/activation/cache PartitionSpecs.

Strategy (production mesh ``(data=16, model=16)``, multi-pod adds an outer
``pod`` axis folded into data parallelism):

* **FSDP** -- every large parameter's d_model-like dimension is sharded over
  the data axes, so per-chip parameter+optimizer memory scales 1/NxDP.
* **TP**   -- head/ffn/expert dimensions shard over ``model``.
* **EP**   -- MoE expert banks shard their expert dimension over ``model``
  (16 / 64 / 128 experts all divide the 16-way model axis).
* **SP**   -- long-context decode (batch=1) shards the KV-cache *sequence*
  dimension over the data axes (flash-decode style partial attention; GSPMD
  inserts the log-sum-exp-equivalent reductions).
* Vectors (norm scales, A_log, biases) are replicated -- negligible bytes.

Vocab dims are padded to multiples of 256 (``padded_vocab``) so embedding /
head shards divide evenly (Megatron-style vocab padding).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def padded_vocab(cfg: ModelConfig, multiple: int = 256) -> int:
    return -(-cfg.vocab_size // multiple) * multiple


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """All data-parallel axes: ('pod', 'data') on the multi-pod mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _dp(mesh: Mesh):
    ax = data_axes(mesh)
    return ax if len(ax) > 1 else (ax[0] if ax else None)


def param_spec(path: Tuple[str, ...], leaf, mesh: Mesh) -> P:
    """PartitionSpec for one parameter, keyed on its tree path.

    Parameters under ``units``/``enc_units`` are stacked along a leading
    scan axis; rules apply to the trailing dims with a ``None`` prepended.
    """
    dp = _dp(mesh)
    name = "/".join(str(p) for p in path)
    shape = leaf.shape
    # optimizer states nest param paths under m/v/row/col; the scan axis is
    # present whenever 'units'/'enc_units' appears anywhere in the path
    lead = 1 if any(p in ("units", "enc_units") for p in path) else 0

    # int8-quantized moment leaves ({"q": [..., nblk, 128], "scale":
    # [..., nblk, 1]}) inherit the parent matrix's spec: the split last
    # dim (nblk) takes the parent's last-dim axis, the block dim is local.
    if path and str(path[-1]) in ("q", "scale") and len(shape) - lead >= 3:
        class _Dummy:
            pass
        parent = _Dummy()
        parent.shape = shape[:-2] + (shape[-2] * max(shape[-1], 1),)
        pspec = param_spec(path[:-1], parent, mesh)
        entries = list(pspec) + [None] * (len(parent.shape) - len(pspec))
        return P(*entries, None)
    core = len(shape) - lead
    pre = [None] * lead
    # vectors & scalars: replicate
    if core <= 1:
        return P()
    # embeddings: lookup table keeps vocab UNsharded (token gather stays
    # collective-free) with d_model over model; the decoupled head is
    # vocab-parallel so logits land vocab-sharded with no psum.
    if name.endswith("embed"):
        return P(None, "model")
    if name.endswith("lm_head"):
        return P(None, "model")
    # MoE expert banks [E, d_in, d_out]: EP over model + FSDP over data
    if "/moe/" in name and core == 3:
        return P(*pre, "model", dp, None)
    if name.endswith("/moe/router"):
        return P(*pre, dp, None)
    if name.endswith("conv_w"):          # [W, C]: channels over model
        return P(*pre, None, "model")
    # attention / mlp / ssm projections [d_in, d_out]
    if core == 2:
        # contract-side sharding heuristic: project *out of* d_model -> TP on
        # the output dim; project back *into* d_model -> TP on the input dim.
        if name.endswith(("/o", "/down", "/out_proj")):
            return P(*pre, "model", dp)
        return P(*pre, dp, "model")
    return P()


def _validate(spec: P, shape, mesh: Mesh) -> P:
    """Drop mesh axes whose size does not divide the dim (safety net)."""
    out = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            out.append(None if i >= len(shape) else entry)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = int(np.prod([mesh.shape[a] for a in axes]))
        out.append(entry if shape[i] % n == 0 else None)
    return P(*out)


def shard_params(params: Dict, mesh: Mesh) -> Dict:
    """Pytree of NamedShardings matching ``params``."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]

    def spec_for(kp, leaf):
        path = tuple(getattr(k, "key", getattr(k, "idx", str(k)))
                     for k in kp)
        spec = _validate(param_spec(path, leaf, mesh), leaf.shape, mesh)
        return NamedSharding(mesh, spec)

    specs = [spec_for(kp, leaf) for kp, leaf in flat]
    treedef = jax.tree_util.tree_structure(params)
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# activations / batches / caches
# ---------------------------------------------------------------------------

def batch_spec(mesh: Mesh, batch_size: int) -> P:
    """Tokens/labels [B, S]: shard batch over data axes when divisible."""
    dp = _dp(mesh)
    n_dp = int(np.prod([mesh.shape[a] for a in data_axes(mesh)]))
    if batch_size % n_dp == 0 and batch_size >= n_dp:
        return P(dp, None)
    return P(None, None)


def shard_batch(batch_tree: Dict, mesh: Mesh, batch_size: int) -> Dict:
    spec = batch_spec(mesh, batch_size)

    def one(leaf):
        nd = len(leaf.shape)
        return NamedSharding(mesh, P(*(list(spec) + [None] * (nd - 2))))

    return jax.tree.map(one, batch_tree)


def cache_spec(path: Tuple[str, ...], leaf, mesh: Mesh,
               batch_size: int) -> P:
    """KV/SSM cache sharding.

    batch > 1: shard batch over data, head_dim over model.
    batch == 1 (long-context): sequence parallelism -- shard the cache
    sequence dim over data instead.
    """
    dp = _dp(mesh)
    n_dp = int(np.prod([mesh.shape[a] for a in data_axes(mesh)]))
    name = "/".join(str(p) for p in path)
    shape = leaf.shape
    batch_ok = batch_size % n_dp == 0 and batch_size >= n_dp
    if name.endswith("index"):
        return P()
    nd = len(shape)
    # leading axis may be the scan (units) axis: detect via 'units' in path
    scan_off = 1 if "units" in name else 0
    core = nd - scan_off
    lead = [None] * scan_off
    if core == 4 and ("/kv/" in name or "/cross/" in name):
        # [B, L, KV, dh] -- KV-sequence parallelism: the cache length
        # shards over 'model' (flash-decode partial attention; kv-head
        # counts often do not divide the model axis); batch over data
        # when divisible, else (long-context batch=1) L takes every axis.
        if batch_ok:
            if shape[scan_off + 1] % mesh.shape["model"] == 0:
                return P(*lead, dp, "model", None, None)
            return P(*lead, dp, None, None, "model")
        all_ax = tuple(a for a in ("pod", "data", "model")
                       if a in mesh.axis_names)
        n_all = int(np.prod([mesh.shape[a] for a in all_ax]))
        if shape[scan_off + 1] % n_all == 0:
            return P(*lead, None, all_ax, None, None)
        return P(*lead, None, None, None, "model")
    if core == 4 and "/ssm/" in name and name.endswith("state"):
        # [B, H, P, N]
        if batch_ok:
            return P(*lead, dp, "model", None, None)
        return P(*lead, None, "model", None, None)
    if core == 3 and name.endswith("conv"):
        # [B, W-1, C]
        if batch_ok:
            return P(*lead, dp, None, "model")
        return P(*lead, None, None, "model")
    return P()


def shard_cache(cache: Dict, mesh: Mesh, batch_size: int) -> Dict:
    flat = jax.tree_util.tree_flatten_with_path(cache)[0]

    def spec_for(kp, leaf):
        path = tuple(getattr(k, "key", getattr(k, "idx", str(k)))
                     for k in kp)
        return NamedSharding(mesh, cache_spec(path, leaf, mesh, batch_size))

    specs = [spec_for(kp, leaf) for kp, leaf in flat]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(cache), specs)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# in-model activation constraints (mesh-context-aware, no-op without a mesh)
# ---------------------------------------------------------------------------

def _context_mesh() -> Optional[Mesh]:
    try:
        from jax._src import mesh as mesh_lib
        mesh = mesh_lib.thread_resources.env.physical_mesh
        if mesh.empty:
            return None
        return mesh
    except Exception:
        try:
            from jax.interpreters import pxla
            mesh = pxla.thread_resources.env.physical_mesh
            return None if mesh.empty else mesh
        except Exception:
            return None


def constrain_like_params(tree):
    """Constrain a param-shaped pytree (e.g. gradients) to the parameter
    sharding rules against the ambient mesh.  Placing this right where
    gradients are produced makes GSPMD reduce-scatter each dW into its
    FSDP/TP shard instead of all-reducing the full matrix and re-slicing
    (measured ~2x collective bytes on the 123B dense config)."""
    mesh = _context_mesh()
    if mesh is None:
        return tree
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]

    def one(kp, leaf):
        path = tuple(getattr(k, "key", getattr(k, "idx", str(k)))
                     for k in kp)
        spec = _validate(param_spec(path, leaf, mesh), leaf.shape, mesh)
        return jax.lax.with_sharding_constraint(
            leaf, NamedSharding(mesh, spec))

    leaves = [one(kp, leaf) for kp, leaf in flat]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(tree), leaves)


def constrain(x, *axes):
    """``with_sharding_constraint`` against the ambient mesh context.

    ``axes`` entries: "dp" -> all data axes, "model", or None.  Axes not
    present in the ambient mesh (or no mesh at all: smoke tests,
    single-device runs) degrade to a no-op, keeping model code
    mesh-agnostic.
    """
    mesh = _context_mesh()
    if mesh is None:
        return x
    resolved = []
    for a in axes:
        if a == "dp":
            ax = data_axes(mesh)
            resolved.append(ax if len(ax) > 1 else (ax[0] if ax else None))
        elif a is None or a in mesh.axis_names:
            resolved.append(a)
        else:
            resolved.append(None)
    spec = _validate(P(*resolved), x.shape, mesh)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))
