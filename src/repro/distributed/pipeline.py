"""1F1B pipeline-parallel schedule (optional axis; not in the assigned
production mesh -- DESIGN.md §6 justifies FSDPxTP there).

What's real here: the stage partitioner (layer program -> contiguous
stages), the 1F1B schedule generator with bubble accounting (used for
capacity planning of deeper meshes), and a host-level executor that runs
the schedule and is tested bit-exact against the unpipelined model.  On a
mesh with a 'stage' axis the same schedule drives ``shard_map`` +
``ppermute`` stage hand-offs.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class Tick:
    stage: int
    micro: int
    phase: str  # "fwd" | "bwd"


def schedule_1f1b(n_stages: int, n_micro: int) -> List[List[Tick]]:
    """Per-timestep ticks of the 1F1B schedule.

    Returns a list of timesteps; each timestep lists the (stage, micro,
    phase) work items running in parallel.  Verified properties (tests):
    every (stage, micro) runs fwd exactly once and bwd exactly once; fwd
    of (s, m) precedes fwd of (s+1, m); bwd of (s+1, m) precedes bwd of
    (s, m); steady-state has one fwd + one bwd in flight per stage.
    """
    # event-driven simulation with 1F1B priority
    fwd_done = set()
    bwd_done = set()
    next_fwd = [0] * n_stages
    next_bwd = [0] * n_stages
    in_flight_fwd = [0] * n_stages  # fwd count not yet bwd'd per stage
    timeline: List[List[Tick]] = []
    total = 2 * n_stages * n_micro
    while len(fwd_done) + len(bwd_done) < total:
        ticks: List[Tick] = []
        busy = set()
        for s in range(n_stages):
            if s in busy:
                continue
            # 1F1B: prefer bwd when warmed up (limit in-flight to depth)
            m_b = next_bwd[s]
            can_bwd = (m_b < n_micro
                       and (s == n_stages - 1 and (s, m_b) in fwd_done
                            or (s + 1, m_b) in bwd_done)
                       and (s, m_b) in fwd_done)
            m_f = next_fwd[s]
            can_fwd = (m_f < n_micro
                       and (s == 0 or (s - 1, m_f) in fwd_done)
                       and in_flight_fwd[s] < (n_stages - s))
            if can_bwd and (in_flight_fwd[s] >= (n_stages - s) or not can_fwd):
                ticks.append(Tick(s, m_b, "bwd"))
                busy.add(s)
            elif can_fwd:
                ticks.append(Tick(s, m_f, "fwd"))
                busy.add(s)
        if not ticks:
            raise RuntimeError("schedule deadlock")
        for t in ticks:
            if t.phase == "fwd":
                fwd_done.add((t.stage, t.micro))
                next_fwd[t.stage] += 1
                in_flight_fwd[t.stage] += 1
            else:
                bwd_done.add((t.stage, t.micro))
                next_bwd[t.stage] += 1
                in_flight_fwd[t.stage] -= 1
        timeline.append(ticks)
    return timeline


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    """Idle fraction of the classic 1F1B pipeline: (S-1)/(S-1+M) per
    direction -- the capacity-planning number."""
    timeline = schedule_1f1b(n_stages, n_micro)
    used = sum(len(t) for t in timeline)
    return 1.0 - used / (len(timeline) * n_stages)


def run_pipelined(stages: Sequence[Callable], micro_inputs: Sequence,
                  n_stages: int = None):
    """Host executor: runs the 1F1B schedule over callables; returns
    per-microbatch outputs (tested equal to sequential composition)."""
    n_stages = n_stages or len(stages)
    n_micro = len(micro_inputs)
    acts: Dict[Tuple[int, int], object] = {}
    outs: Dict[int, object] = {}
    for ticks in schedule_1f1b(n_stages, n_micro):
        for t in ticks:
            if t.phase != "fwd":
                continue
            x = (micro_inputs[t.micro] if t.stage == 0
                 else acts[(t.stage - 1, t.micro)])
            y = stages[t.stage](x)
            acts[(t.stage, t.micro)] = y
            if t.stage == n_stages - 1:
                outs[t.micro] = y
    return [outs[m] for m in range(n_micro)]
