"""Distributed-optimization utilities: gradient compression + overlap.

Cross-pod (DCN) links are ~2x slower than ICI and carry the pure
data-parallel gradient reduction.  ``compress``/``decompress`` implement
int8 blockwise quantization with **error feedback** (the quantization
residual is carried into the next step), the standard trick that keeps
convergence while cutting cross-pod bytes 4x vs fp32 / 2x vs bf16.

Under jit+GSPMD the all-reduce itself is implicit; the trainer applies
compression at the pod boundary by quantizing the *accumulated* gradient
before the optimizer (the DCN reduction then moves int8+scales).  The
error-feedback state is a pytree sibling of the gradients and checkpoints
with the optimizer state.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

QBLOCK = 256


def _q(x: jnp.ndarray) -> Dict:
    if x.ndim == 0:
        x = x[None]
    pad = (-x.shape[-1]) % QBLOCK
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    blocks = x.reshape(x.shape[:-1] + (-1, QBLOCK))
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=-1, keepdims=True)
                        / 127.0, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32)}


def _dq(s: Dict, like: jnp.ndarray) -> jnp.ndarray:
    full = (s["q"].astype(jnp.float32) * s["scale"])
    full = full.reshape(full.shape[:-2] + (-1,))
    if like.ndim == 0:
        return full[0].reshape(())
    return full[..., : like.shape[-1]].reshape(like.shape)


def init_error_feedback(grads) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_with_feedback(grads, error) -> Tuple[Any, Any]:
    """Returns (compressed pytree, new error feedback state)."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        c = _q(corrected)
        new_e = corrected - _dq(c, corrected)
        return c, new_e
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return treedef.unflatten([p[0] for p in pairs]), \
        treedef.unflatten([p[1] for p in pairs])


def decompress(compressed, like) -> Any:
    flat_c = jax.tree_util.tree_leaves(
        compressed, is_leaf=lambda x: isinstance(x, dict) and "q" in x)
    flat_l, treedef = jax.tree_util.tree_flatten(like)
    return treedef.unflatten([_dq(c, l).astype(l.dtype)
                              for c, l in zip(flat_c, flat_l)])


def compressed_bytes(compressed) -> int:
    total = 0
    for leaf in jax.tree_util.tree_leaves(compressed):
        total += leaf.size * leaf.dtype.itemsize
    return total
