"""Render dry-run JSON reports into the EXPERIMENTS.md tables.

Usage:
  PYTHONPATH=src python -m repro.launch.report \
      --single dryrun_single.json --multi dryrun_multi.json
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional

from repro.configs import get_config
from repro.launch.roofline import HBM_BW, PEAK_FLOPS, active_params
from repro.launch.shapes import SHAPES

V5E_HBM = 16 * 1024 ** 3


def analytic_memory_floor(arch: str, shape_name: str, chips: int,
                          multi_pod: bool) -> Dict[str, float]:
    """Per-device HBM bytes floor: params+opt+cache (exact) + one
    microbatch of saved activations (analytic).  The CPU backend's
    temp_size has no buffer-reuse model, so the fit proof uses this floor
    plus the measured argument sizes."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = active_params(cfg)
    total_params = n
    if cfg.moe:
        moe_layers = sum(1 for s in (list(cfg.prefix)
                                     + list(cfg.unit) * cfg.n_units)
                         if s.moe)
        total_params = n + (cfg.moe.num_experts - cfg.moe.top_k) * 3 \
            * cfg.d_model * cfg.moe.d_expert * moe_layers
    dp = chips  # params FSDP over everything they can shard over
    out: Dict[str, float] = {}
    if shape.kind == "train":
        moment_bytes = {"int8": 2.2, "bfloat16": 4, "float32": 8}
        if total_params > 100e9:
            mb = moment_bytes["int8"]
        elif total_params > 10e9:
            mb = moment_bytes["bfloat16"]
        else:
            mb = moment_bytes["float32"]
        state = total_params * (2 + 2 + mb) / chips  # bf16 p + bf16 g + m,v
        micro_tokens = shape.batch * shape.seq / cfg.train_microbatches
        n_layers = cfg.num_layers
        saved = micro_tokens * cfg.d_model * 2 * n_layers / chips
        logits = micro_tokens * cfg.vocab_size * 6 / chips
        out["state_bytes"] = state
        out["activation_bytes"] = saved + logits
        out["floor_bytes"] = state + saved + logits
    else:
        params_b = total_params * 2 / chips
        # cache bytes: attention layers * 2 * kv * dh * L * batch * 2
        specs = list(cfg.prefix) + list(cfg.unit) * cfg.n_units
        cache = 0.0
        for s in specs:
            if s.kind == "attn":
                cache += (2 * cfg.num_kv_heads * cfg.head_dim * shape.seq
                          * shape.batch * 2)
            else:
                ssm = cfg.ssm
                cache += (ssm.num_heads * ssm.head_dim * ssm.state_dim
                          * 4 * shape.batch)
        cache /= chips
        act = shape.batch * min(shape.seq, 32768) * cfg.d_model * 2 / chips \
            if shape.kind == "prefill" else \
            shape.batch * cfg.d_model * 2
        out["state_bytes"] = params_b
        out["activation_bytes"] = cache + act
        out["floor_bytes"] = params_b + cache + act
    out["fits_floor_16gb"] = out["floor_bytes"] <= V5E_HBM
    return out


def _fmt(x: Optional[float], unit: str = "") -> str:
    if x is None:
        return "-"
    if x == 0:
        return "0"
    for thresh, suffix, div in ((1e12, "T", 1e12), (1e9, "G", 1e9),
                                (1e6, "M", 1e6), (1e3, "k", 1e3)):
        if abs(x) >= thresh:
            return f"{x/div:.2f}{suffix}{unit}"
    return f"{x:.3g}{unit}"


def next_lever(r: Dict) -> str:
    """One sentence: what would move this cell's dominant term down."""
    arch, shape, b = r["arch"], r["shape"], r["bottleneck"]
    cfg = get_config(arch)
    if shape.startswith("decode") or shape.startswith("long"):
        if b == "memory":
            return ("per-token weight streaming floor: raise batch or "
                    "quantize weights (int8 halves bytes/token)")
        return ("flash-decode psums are already small; wider batch or "
                "speculative decoding amortizes the per-token collectives")
    if shape.startswith("prefill"):
        if b == "collective":
            return ("ring-attention K/V hand-off (ppermute) would replace "
                    "the K/V all-gather of sequence-parallel attention")
        return ("fp32 score matrices dominate bytes: the Pallas flash "
                "kernel keeps them in VMEM (excluded from the measured "
                "path only because custom-calls hide flops from "
                "cost_analysis)")
    # train
    if b == "collective":
        if cfg.moe:
            return ("the residual all-to-all is the EP dispatch floor; "
                    "hierarchical (intra-pod first) dispatch or expert "
                    "affinity batching would shrink cross-link bytes")
        return ("overlap FSDP weight gathers with the previous layer's "
                "compute (latency-hiding scheduler) and reduce-scatter "
                "grads in bf16")
    if b == "memory":
        return ("flash-attention kernel + bf16 softmax remove the fp32 "
                "score traffic; remat policy already tuned (see iter 5a)")
    return "compute-bound: at the MXU roof for this shape"


def roofline_table(rows: List[Dict]) -> str:
    out = ["| arch | shape | t_compute (s) | t_memory (s) | t_coll (s) | "
           "bottleneck | MODEL_FLOPS | useful ratio | roofline frac | "
           "coll bytes/dev | what moves the dominant term |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r.get("status") != "ok":
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3g} | "
            f"{r['t_memory_s']:.3g} | {r['t_collective_s']:.3g} | "
            f"{r['bottleneck']} | {_fmt(r['model_flops'])} | "
            f"{r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.4f} | "
            f"{_fmt(r['coll_ici_bytes'] + r['coll_dcn_bytes'], 'B')} | "
            f"{next_lever(r)} |")
    return "\n".join(out)


def dryrun_table(rows: List[Dict], multi_pod: bool) -> str:
    out = ["| arch | shape | status | compile (s) | args/dev | "
           "floor/dev (analytic) | fits 16GB | coll ops |",
           "|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | FAIL | - | - | - |"
                       f" - | - |")
            continue
        mem = r.get("memory") or {}
        args = mem.get("argument_size_in_bytes")
        floor = analytic_memory_floor(r["arch"], r["shape"], r["chips"],
                                      multi_pod)
        fits = floor["fits_floor_16gb"] and \
            (args or 0) <= V5E_HBM
        out.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']:.1f} | "
            f"{_fmt(args, 'B')} | {_fmt(floor['floor_bytes'], 'B')} | "
            f"{'yes' if fits else 'NO'} | {r.get('coll_count', 0)} |")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--single", default="dryrun_single.json")
    ap.add_argument("--multi", default="dryrun_multi.json")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    with open(args.single) as f:
        single = json.load(f)
    with open(args.multi) as f:
        multi = json.load(f)
    parts = [
        "### Dry-run: single pod (16x16 = 256 chips)",
        dryrun_table(single, False), "",
        "### Dry-run: multi-pod (2x16x16 = 512 chips)",
        dryrun_table(multi, True), "",
        "### Roofline (single pod, probe-calibrated)",
        roofline_table(single), "",
    ]
    text = "\n".join(parts)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
    print(text)


if __name__ == "__main__":
    main()
