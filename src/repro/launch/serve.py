"""Serving launcher CLI: continuous-batching engine demo.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
      --reduced --requests 8
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max_new_tokens", type=int, default=16)
    ap.add_argument("--max_len", type=int, default=256)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve.engine import Request, ServeEngine

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.encoder_layers or cfg.num_vision_tokens:
        raise SystemExit("serve CLI demo supports decoder-only archs; "
                         "multimodal prefill needs frames/vision inputs")
    model = build_model(cfg)
    params = model.init(0)
    eng = ServeEngine(model, params, max_slots=args.slots,
                      max_len=args.max_len, eos_id=-1)
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        prompt = rng.integers(4, cfg.vocab_size,
                              size=int(rng.integers(8, 32))).astype(np.int32)
        eng.submit(Request(rid, prompt,
                           max_new_tokens=args.max_new_tokens))
    t0 = time.perf_counter()
    ticks = 0
    while eng.queue or any(s is not None for s in eng.slots):
        eng.step()
        ticks += 1
        if ticks > 10_000:
            break
    dt = time.perf_counter() - t0
    total = args.requests * args.max_new_tokens
    print(f"served {args.requests} requests in {ticks} ticks "
          f"({eng.steps} batched decode steps, {total/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
