"""Roofline analysis from compiled dry-run artifacts (TPU v5e targets).

Three terms, each in seconds for one step:

  compute    = HLO_FLOPs_per_device / PEAK_FLOPS
  memory     = HLO_bytes_per_device / HBM_BW
  collective = ici_bytes_per_device / ICI_BW + dcn_bytes_per_device / DCN_BW

Measured calibration on this backend (see EXPERIMENTS.md §Methodology):
``cost_analysis()`` and the optimized HLO text are both computed on the
SPMD-partitioned *per-device* module (verified: an unsharded compile of
the same probe reports ~chips x more flops).  Per-device flops above the
ideal ``global/chips`` therefore measure *involuntary replication* by the
partitioner -- a real inefficiency the perf loop attacks.  Collective
bytes are NOT in cost_analysis: we parse ``compiled.as_text()`` and sum
the result-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, attributing each to ICI or DCN by whether
its replica groups cross a pod boundary (device id // 256).

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) cross-checks how much of
the compiled compute is useful (remat / redundancy show up as ratio < 1).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

# -------------------- hardware constants (TPU v5e) -------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (per-chip effective)
DCN_BW = 25e9                # bytes/s per chip across pods (assumed)
CHIPS_PER_POD = 256

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}\s")
_GROUPS_ALT_RE = re.compile(r"replica_groups=\[\d+,\d+\]<=\[(\d+)")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of an HLO result type (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _crosses_pod(line: str, chips_per_pod: int) -> bool:
    """True if any replica group spans a pod boundary."""
    m = _GROUPS_RE.search(line)
    if m:
        for grp in m.group(1).split("},{"):
            ids = [int(x) for x in re.findall(r"\d+", grp)]
            if ids and (min(ids) // chips_per_pod
                        != max(ids) // chips_per_pod):
                return True
        return False
    # iota group syntax: replica_groups=[G,N]<=[T] -- contiguous stride-1
    # groups of size N: crosses pods iff N > chips_per_pod (conservative)
    m = _GROUPS_ALT_RE.search(line)
    if m:
        return False
    return False


@dataclasses.dataclass
class CollectiveStats:
    ici_bytes: int = 0
    dcn_bytes: int = 0
    by_op: Dict[str, int] = dataclasses.field(default_factory=dict)
    count: int = 0

    @property
    def total_bytes(self) -> int:
        return self.ici_bytes + self.dcn_bytes


def parse_collectives(hlo_text: str,
                      chips_per_pod: int = CHIPS_PER_POD) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        ls = line.strip()
        # match '<name> = <type> <op>(' with op a collective
        op_found = None
        for op in _COLLECTIVES:
            if f"= " in ls and (f" {op}(" in ls or f"{op}-start(" in ls):
                op_found = op
                break
        if not op_found:
            continue
        # result type = text between '=' and the op name
        try:
            rhs = ls.split("= ", 1)[1]
        except IndexError:
            continue
        type_str = rhs.split(op_found)[0]
        nbytes = _shape_bytes(type_str)
        if nbytes == 0:
            continue
        stats.count += 1
        stats.by_op[op_found] = stats.by_op.get(op_found, 0) + nbytes
        if _crosses_pod(ls, chips_per_pod):
            stats.dcn_bytes += nbytes
        else:
            stats.ici_bytes += nbytes
    return stats


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll: CollectiveStats
    model_flops: float            # 6*N_active*D (global, per step)
    per_device_memory: Optional[Dict[str, float]] = None

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return (self.coll.ici_bytes / ICI_BW
                + self.coll.dcn_bytes / DCN_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        hlo_global = self.flops_per_device * self.chips
        return self.model_flops / hlo_global if hlo_global else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of peak the step would achieve if the dominant term were
        the wall clock: useful_FLOPs / (chips * peak * t_dominant)."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        if t <= 0:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS * t)

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "hlo_flops_per_dev": self.flops_per_device,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "coll_ici_bytes": self.coll.ici_bytes,
            "coll_dcn_bytes": self.coll.dcn_bytes,
            "coll_count": self.coll.count,
            "memory": self.per_device_memory,
        }


# ------------------------- model FLOPs (6*N*D) ------------------------------

def active_params(cfg) -> float:
    """Active (per-token) parameter count: MoE counts top_k + shared only.

    The head is always materialized (decoupled-tied, DESIGN.md §6), so
    embedding params count twice regardless of ``tie_embeddings``.
    """
    d = cfg.d_model
    total = cfg.vocab_size * d * 2
    specs = list(cfg.prefix) + list(cfg.unit) * cfg.n_units
    for i, spec in enumerate(specs):
        if spec.kind == "attn":
            total += d * cfg.head_dim * (cfg.num_heads * 2
                                         + cfg.num_kv_heads * 2)
        else:
            s = cfg.ssm
            din = s.num_heads * s.head_dim
            total += d * (2 * din + 2 * s.n_groups * s.state_dim
                          + s.num_heads) + din * d
        if spec.cross:
            total += d * cfg.head_dim * (cfg.num_heads * 2
                                         + cfg.num_kv_heads * 2)
        if spec.mlp:
            if spec.moe:
                m = cfg.moe
                total += m.top_k * 3 * d * m.d_expert
                if m.num_shared:
                    total += 3 * d * (m.d_shared or m.d_expert)
            else:
                ff = (cfg.prefix_d_ff if (i < len(cfg.prefix)
                                          and cfg.prefix_d_ff) else cfg.d_ff)
                total += (3 if cfg.gated_mlp else 2) * d * ff
    if cfg.encoder_layers:
        total += cfg.encoder_layers * (
            d * cfg.head_dim * (cfg.num_heads * 2 + cfg.num_kv_heads * 2)
            + (3 if cfg.gated_mlp else 2) * d * cfg.d_ff)
    return float(total)


def model_flops(cfg, kind: str, batch: int, seq: int) -> float:
    """6*N_active*D for training; 2*N_active*D for inference steps."""
    n = active_params(cfg)
    if kind == "train":
        tokens = batch * seq
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = batch * seq
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * batch
