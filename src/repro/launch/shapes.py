"""Assigned input-shape set + ShapeDtypeStruct stand-ins (no allocation).

LM transformer shapes (per the assignment):
  train_4k     seq=4,096   global_batch=256   -> train_step
  prefill_32k  seq=32,768  global_batch=32    -> prefill_step
  decode_32k   seq=32,768  global_batch=128   -> serve (decode) step
  long_500k    seq=524,288 global_batch=1     -> serve step, SSM/hybrid/
                                                 local-attn archs only

``input_specs`` builds the exact argument pytrees each step lowers with:
weak-type-correct ShapeDtypeStructs for tokens/labels, modality-stub frame
or patch embeddings for [audio]/[vlm] archs, and KV/SSM caches sized to the
cell's context length.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeDef:
    name: str
    kind: str           # train | prefill | decode
    seq: int
    batch: int


SHAPES: Dict[str, ShapeDef] = {
    "train_4k": ShapeDef("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeDef("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeDef("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeDef("long_500k", "decode", 524_288, 1),
}


def supported_shapes(cfg: ModelConfig) -> List[str]:
    """long_500k only for sub-quadratic archs (DESIGN.md §Arch-applicability)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.supports_long:
        out.append("long_500k")
    return out


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeDef,
                with_labels: bool) -> Dict:
    b, s = shape.batch, shape.seq
    if shape.kind == "decode":
        batch = {"tokens": _sds((b, 1), jnp.int32)}
        return batch
    batch = {"tokens": _sds((b, s), jnp.int32)}
    if with_labels:
        batch["labels"] = _sds((b, s), jnp.int32)
    dt = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    if cfg.encoder_layers:
        batch["frames"] = _sds((b, s, cfg.d_model), dt)
    if cfg.num_vision_tokens:
        batch["vision"] = _sds((b, cfg.num_vision_tokens, cfg.d_model), dt)
    return batch


def cache_specs(model, cfg: ModelConfig, shape: ShapeDef) -> Dict:
    """ShapeDtypeStruct cache for prefill/decode cells (no allocation)."""
    ctx_len = 0
    if cfg.encoder_layers:
        ctx_len = shape.seq
    elif cfg.num_vision_tokens:
        ctx_len = cfg.num_vision_tokens
    return jax.eval_shape(
        lambda: model.init_cache(shape.batch, max_len=shape.seq,
                                 ctx_len=ctx_len, dtype=jnp.bfloat16))
