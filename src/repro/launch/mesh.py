"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches JAX device state.  The production target is TPU v5e:
one pod = 16x16 = 256 chips, multi-pod = 2 pods = 512 chips with the outer
``pod`` axis crossing the DCN.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(*, multi_pod: bool = False):
    """Small mesh for CI on 8 forced host devices."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def describe(mesh) -> str:
    return " x ".join(f"{a}={mesh.shape[a]}" for a in mesh.axis_names)
