"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

NOTE: the first two executable lines below set XLA_FLAGS *before any jax
import* (jax locks the device count at first init); they are intentionally
ahead of every other import.

For every assigned architecture and its supported input shapes this driver:

  1. builds the step function (train / prefill / decode),
  2. builds ShapeDtypeStruct inputs + FSDP/TP/EP/SP NamedShardings,
  3. ``jax.jit(...).lower(...).compile()`` on the production mesh
     (16x16 single pod and 2x16x16 multi-pod),
  4. records ``memory_analysis()`` (fits-in-HBM proof),
     ``cost_analysis()`` (FLOPs / bytes) and the collective footprint
     parsed from the optimized HLO -> roofline terms (§Roofline).

Results append to a JSON report consumed by EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-4b \
      --shape train_4k --mesh single --report out.json
"""
from __future__ import annotations

import os
if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=512").strip()

import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.distributed.sharding import (replicated, shard_batch, shard_cache,
                                        shard_params)
from repro.launch.mesh import describe, make_production_mesh
from repro.launch.roofline import (CHIPS_PER_POD, CollectiveStats, Roofline,
                                   model_flops, parse_collectives)
from repro.launch.shapes import (SHAPES, ShapeDef, batch_specs, cache_specs,
                                 supported_shapes)
from typing import Tuple
from repro.models import build_model
from repro.serve.steps import make_decode_step, make_prefill_step
from repro.train.optimizer import adamw
from repro.train.schedule import warmup_cosine
from repro.train.train_step import make_train_step

V5E_HBM = 16 * 1024 ** 3  # 16 GiB per chip


def _memory_analysis(compiled, chips: int = 1) -> Optional[Dict[str, float]]:
    """Per-device memory estimate.

    All sizes come from the SPMD-partitioned per-device executable
    (argument sizes match (params+opt)/chips).  ``temp_size`` on the CPU
    backend over-estimates a real TPU's footprint in two ways: buffers the
    TPU scheduler would reuse are counted live simultaneously, and
    involuntarily-replicated intermediates (visible as per-device flops
    above ideal) inflate it -- both are reported, and the fit check is
    evaluated against the *arguments + one microbatch activation* bound
    too (``fits_v5e_16gb_args``).
    """
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return None
    if ma is None:
        return None
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    if out:
        live = (out.get("argument_size_in_bytes", 0)
                + out.get("output_size_in_bytes", 0)
                + out.get("temp_size_in_bytes", 0)
                - out.get("alias_size_in_bytes", 0))
        out["live_bytes_per_device"] = live
        out["fits_v5e_16gb"] = bool(live <= V5E_HBM)
        out["fits_v5e_16gb_args"] = bool(
            out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0) <= V5E_HBM)
    return out


def _cost_analysis(compiled) -> Dict[str, float]:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float))}


def moment_dtype_for(cfg) -> str:
    """Optimizer-state policy: int8 moments >=100B, bf16 >=10B, else fp32."""
    from repro.launch.roofline import active_params
    n = active_params(cfg)
    total = n  # dense ~= active; MoE far larger -> use analytic full count
    if cfg.moe:
        total = n + (cfg.moe.num_experts - cfg.moe.top_k) * 3 \
            * cfg.d_model * cfg.moe.d_expert * \
            sum(1 for s in (list(cfg.prefix) + list(cfg.unit) * cfg.n_units)
                if s.moe)
    if total > 100e9:
        return "int8"
    if total > 10e9:
        return "bfloat16"
    return "float32"


def build_cell(cfg, shape: ShapeDef, mesh, *, batch_override: int = None,
               train_opt_only: bool = False):
    """Returns (fn, args, in_shardings, donate) ready to lower."""
    model = build_model(cfg)
    params_sds = jax.eval_shape(lambda: model.init(0))
    b = batch_override or shape.batch
    import dataclasses as _dc
    shape = _dc.replace(shape, batch=b)

    if shape.kind == "train":
        opt = adamw(warmup_cosine(3e-4, 100, 10_000),
                    moment_dtype=moment_dtype_for(cfg))
        opt_sds = jax.eval_shape(opt.init, params_sds)
        if train_opt_only:
            # optimizer-update-only probe (separates update cost from loss)
            def fn(grads, state, params):
                return opt.update(grads, state, params)
            grads_sds = jax.tree.map(
                lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
                params_sds)
            in_sh = (shard_params(grads_sds, mesh),
                     shard_params(opt_sds, mesh),
                     shard_params(params_sds, mesh))
            return fn, (grads_sds, opt_sds, params_sds), in_sh, (1, 2)
        fn = make_train_step(model, opt, n_micro=cfg.train_microbatches,
                             accum_dtype=jnp.bfloat16
                             if cfg.param_dtype == "bfloat16"
                             else jnp.float32)
        batch = batch_specs(cfg, shape, with_labels=True)
        in_sh = (shard_params(params_sds, mesh),
                 shard_params(opt_sds, mesh),
                 shard_batch(batch, mesh, shape.batch))
        return fn, (params_sds, opt_sds, batch), in_sh, (0, 1)

    if shape.kind == "prefill":
        fn = make_prefill_step(model)
        batch = batch_specs(cfg, shape, with_labels=False)
        cache = cache_specs(model, cfg, shape)
        in_sh = (shard_params(params_sds, mesh),
                 shard_batch(batch, mesh, shape.batch),
                 shard_cache(cache, mesh, shape.batch))
        return fn, (params_sds, batch, cache), in_sh, (2,)

    # decode
    fn = make_decode_step(model)
    batch = batch_specs(cfg, shape, with_labels=False)
    cache = cache_specs(model, cfg, shape)
    in_sh = (shard_params(params_sds, mesh),
             shard_batch(batch, mesh, shape.batch),
             shard_cache(cache, mesh, shape.batch))
    return fn, (params_sds, batch["tokens"], cache), \
        (in_sh[0], in_sh[1]["tokens"], in_sh[2]), (2,)


def _compile_cell(cfg, shape, mesh, **kw):
    with mesh:
        fn, args, in_sh, donate = build_cell(cfg, shape, mesh, **kw)
        jitted = jax.jit(fn, in_shardings=in_sh, donate_argnums=donate)
        return jitted.lower(*args).compile()


def _cell_costs(compiled, chips_per_pod) -> Dict[str, float]:
    cost = _cost_analysis(compiled)
    coll = parse_collectives(compiled.as_text(), chips_per_pod)
    return {"flops": cost.get("flops", 0.0),
            "bytes": cost.get("bytes accessed", 0.0),
            "ici": float(coll.ici_bytes), "dcn": float(coll.dcn_bytes),
            "coll_count": float(coll.count),
            "by_op": coll.by_op}


def _affine(c1: Dict, c2: Dict) -> Tuple[Dict, Dict]:
    """Per-unit slope and base from 1-unit / 2-unit probe costs."""
    keys = ("flops", "bytes", "ici", "dcn", "coll_count")
    slope = {k: max(c2[k] - c1[k], 0.0) for k in keys}
    base = {k: max(c1[k] - slope[k], 0.0) for k in keys}
    return base, slope


def probe_roofline(cfg, shape: ShapeDef, mesh, chips_per_pod) -> Dict:
    """Reconstruct true per-step costs from unrolled 1/2-unit probes.

    XLA's cost_analysis counts while-loop bodies once, so the scanned
    production executable under-reports loop costs.  Probes with unrolled
    units (full layer dims!) give exact per-unit costs; full-model cost is
    affine: base + n_units * unit.  Train cells additionally separate the
    optimizer update (probed standalone) and scale the loss part by the
    microbatch count.
    """
    p1, p2 = cfg.probe(1), cfg.probe(2)
    micro_b = (shape.batch // cfg.train_microbatches
               if shape.kind == "train" else None)
    c1 = _cell_costs(_compile_cell(p1, shape, mesh, batch_override=micro_b),
                     chips_per_pod)
    c2 = _cell_costs(_compile_cell(p2, shape, mesh, batch_override=micro_b),
                     chips_per_pod)
    base, slope = _affine(c1, c2)
    n = cfg.n_units
    keys = ("flops", "bytes", "ici", "dcn", "coll_count")
    if shape.kind != "train":
        return {k: base[k] + n * slope[k] for k in keys}
    o1 = _cell_costs(_compile_cell(p1, shape, mesh, batch_override=micro_b,
                                   train_opt_only=True), chips_per_pod)
    o2 = _cell_costs(_compile_cell(p2, shape, mesh, batch_override=micro_b,
                                   train_opt_only=True), chips_per_pod)
    obase, oslope = _affine(o1, o2)
    out = {}
    for k in keys:
        opt_full = obase[k] + n * oslope[k]
        loss_full = max(base[k] + n * slope[k] - opt_full, 0.0)
        out[k] = cfg.train_microbatches * loss_full + opt_full
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             mesh_factory=make_production_mesh,
             with_probes: bool = True) -> Dict:
    mesh = mesh_factory(multi_pod=multi_pod)
    chips = int(len(mesh.devices.reshape(-1)))
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    chips_per_pod = CHIPS_PER_POD if multi_pod else chips + 1

    # 1) production compile (scanned): proves lowering + memory fit
    t0 = time.time()
    compiled = _compile_cell(cfg, shape, mesh)
    elapsed = time.time() - t0
    memory = _memory_analysis(compiled, chips)
    raw = _cell_costs(compiled, chips_per_pod)

    # 2) cost probes (unrolled): true roofline terms
    costs = probe_roofline(cfg, shape, mesh, chips_per_pod) \
        if with_probes else raw

    coll = CollectiveStats(ici_bytes=int(costs["ici"]),
                           dcn_bytes=int(costs["dcn"]),
                           by_op=raw["by_op"], count=int(costs["coll_count"]))
    rf = Roofline(
        arch=arch, shape=shape_name,
        mesh=("2x16x16" if multi_pod else "16x16")
        if mesh_factory is make_production_mesh else describe(mesh),
        chips=chips,
        # calibration (EXPERIMENTS.md §Methodology): cost_analysis is
        # computed on the SPMD-partitioned per-device module (verified:
        # unsharded compile of the same probe reports ~chips x more);
        # involuntary replication therefore shows up as per-device flops
        # above ideal -- exactly what the perf loop drives down.
        flops_per_device=costs["flops"],
        bytes_per_device=costs["bytes"],
        coll=coll,
        model_flops=model_flops(cfg, shape.kind, shape.batch, shape.seq),
        per_device_memory=memory)
    row = rf.row()
    row.update({"status": "ok", "compile_s": elapsed,
                "coll_by_op": raw["by_op"],
                "raw_scanned_flops_per_dev": raw["flops"],
                "probes": bool(with_probes)})
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="single arch id")
    ap.add_argument("--shape", default=None, help="single shape id")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--report", default="dryrun_report.json")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ASSIGNED_ARCHS)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    rows = []
    if os.path.exists(args.report):
        with open(args.report) as f:
            rows = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in rows
            if r.get("status") == "ok"}

    for arch in archs:
        cfg = get_config(arch)
        shapes = ([args.shape] if args.shape
                  else supported_shapes(cfg))
        for shape_name in shapes:
            for multi in meshes:
                mesh_id = "2x16x16" if multi else "16x16"
                if (arch, shape_name, mesh_id) in done:
                    print(f"[skip] {arch} {shape_name} {mesh_id} (cached)")
                    continue
                tag = f"{arch} | {shape_name} | {mesh_id}"
                print(f"[lower+compile] {tag} ...", flush=True)
                try:
                    # roofline probes on the single-pod mesh only (the
                    # multi-pod pass proves the 'pod' axis shards)
                    row = run_cell(arch, shape_name, multi,
                                   with_probes=not multi)
                    print(f"  ok in {row['compile_s']:.1f}s  "
                          f"bottleneck={row['bottleneck']}  "
                          f"t=(c {row['t_compute_s']:.3e}, "
                          f"m {row['t_memory_s']:.3e}, "
                          f"x {row['t_collective_s']:.3e})s  "
                          f"useful={row['useful_flops_ratio']:.2f}",
                          flush=True)
                except Exception as e:  # a failure here is a system bug
                    row = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_id, "status": "FAIL",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                    print(f"  FAIL: {row['error']}", flush=True)
                rows = [r for r in rows
                        if (r["arch"], r["shape"], r["mesh"])
                        != (arch, shape_name, mesh_id)]
                rows.append(row)
                with open(args.report, "w") as f:
                    json.dump(rows, f, indent=1, default=str)

    ok = sum(1 for r in rows if r.get("status") == "ok")
    fail = sum(1 for r in rows if r.get("status") != "ok")
    print(f"\n== dry-run complete: {ok} ok, {fail} failed -> {args.report}")
    if fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
