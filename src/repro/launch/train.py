"""Training launcher CLI.

Laptop-scale end-to-end (real data pipeline + trainer):
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
      --reduced --steps 50

Production lowering check for one cell (no execution):
  PYTHONPATH=src python -m repro.launch.train --arch mistral-large-123b \
      --lower-only
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized reduced config")
    ap.add_argument("--lower-only", action="store_true",
                    help="lower+compile the production train cell and exit")
    ap.add_argument("--seq_len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--checkpoint_dir", default="/tmp/repro_train")
    args = ap.parse_args()

    if args.lower_only:
        # the dry-run driver owns XLA device-count setup
        import subprocess
        import sys
        raise SystemExit(subprocess.call(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch",
             args.arch, "--shape", "train_4k", "--mesh", "single"]))

    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.models import build_model, param_count
    from repro.train.optimizer import adamw
    from repro.train.schedule import warmup_cosine
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    print(f"{cfg.name}: {param_count(model.init(0))/1e6:.1f}M params")

    def batch_fn(step):
        r = np.random.default_rng(step)
        b = {"tokens": jnp.asarray(
            r.integers(0, cfg.vocab_size, (args.batch, args.seq_len)),
            jnp.int32)}
        b["labels"] = jnp.asarray(
            r.integers(0, cfg.vocab_size, (args.batch, args.seq_len)),
            jnp.int32)
        if cfg.encoder_layers:
            b["frames"] = jnp.asarray(r.standard_normal(
                (args.batch, cfg.default_encoder_len, cfg.d_model)),
                jnp.float32)
        if cfg.num_vision_tokens:
            b["vision"] = jnp.asarray(r.standard_normal(
                (args.batch, cfg.num_vision_tokens, cfg.d_model)),
                jnp.float32)
        return b

    opt = adamw(warmup_cosine(3e-4, 10, args.steps))
    trainer = Trainer(model, opt, TrainerConfig(
        total_steps=args.steps, checkpoint_every=max(args.steps // 2, 1),
        checkpoint_dir=args.checkpoint_dir, log_every=10), batch_fn)
    out = trainer.run()
    for h in out["history"]:
        print(f"step {h['step']:>5} loss {h['loss']:.4f} "
              f"({h['sec_per_step']:.2f}s)")


if __name__ == "__main__":
    main()
