"""repro: GraphAr (Li et al., 2023) as the data-lake substrate of a
multi-pod JAX LM training/serving framework.  See README.md."""
__version__ = "1.0.0"
