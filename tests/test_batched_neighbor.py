"""Batched neighbor-retrieval plane: equivalence, I/O accounting, caching."""
import numpy as np
import pytest

from repro.core import (BY_SRC, ENC_GRAPHAR, IOMeter, PAC, build_adjacency,
                        k_hop, neighbor_ids_batch, neighbor_properties_batch,
                        pack_column, pages_union, retrieve_neighbors,
                        retrieve_neighbors_batch)
from repro.core.neighbor import decode_edge_ranges, fetch_properties
from repro.core.table import DeltaIntColumn
from repro.core.vertex import VertexTable
from repro.core.schema import PropertySchema, VertexTypeSchema
from repro.data.synthetic import powerlaw_graph
from repro.kernels.pac_decode import ops as pdo
from _engines import engines

ENGINES = engines()
N = 2000
PAGE = 256


@pytest.fixture(scope="module")
def adj():
    src, dst = powerlaw_graph(N, 6, seed=3)
    # N + 8 key vertices: the tail ids have empty adjacency by construction
    return build_adjacency(src, dst, N + 8, N, BY_SRC, ENC_GRAPHAR,
                           page_size=PAGE)


@pytest.fixture(scope="module")
def batch():
    rng = np.random.default_rng(7)
    vs = rng.integers(0, N, 48)
    # duplicates + guaranteed-empty adjacency vertices in the batch
    return np.concatenate([vs, vs[:7], np.arange(N, N + 8)])


@pytest.mark.parametrize("engine", ENGINES)
def test_batch_equals_pervertex_union(adj, batch, engine):
    got = retrieve_neighbors_batch(adj, batch, 512, engine=engine)
    want = PAC.union_all(
        [retrieve_neighbors(adj, int(v), 512) for v in batch], 512)
    assert got == want
    np.testing.assert_array_equal(got.to_ids(), want.to_ids())


@pytest.mark.parametrize("engine", ENGINES)
def test_decode_edge_ranges_multiplicity(adj, batch, engine):
    los, his = adj.edge_ranges_batch(batch)
    got = decode_edge_ranges(adj, los, his, engine=engine)
    want = np.concatenate(
        [adj.neighbor_ids(int(v)) for v in batch] or
        [np.zeros(0, np.int64)])
    np.testing.assert_array_equal(got, want)


def test_empty_and_singleton_batches(adj):
    assert retrieve_neighbors_batch(adj, np.zeros(0, np.int64), 512) \
        .count() == 0
    assert neighbor_ids_batch(adj, np.zeros(0, np.int64)).size == 0
    # batch of only empty-adjacency vertices
    empty = retrieve_neighbors_batch(adj, np.arange(N, N + 8), 512)
    assert empty.count() == 0 and len(empty) == 0


def test_edge_ranges_batch_matches_scalar(adj, batch):
    los, his = adj.edge_ranges_batch(batch)
    for v, lo, hi in zip(batch, los, his):
        assert (int(lo), int(hi)) == adj.edge_range(int(v))


def test_batched_io_leq_loop_sum(adj, batch):
    m_batch, m_loop = IOMeter(), IOMeter()
    retrieve_neighbors_batch(adj, batch, 512, m_batch)
    for v in batch:
        retrieve_neighbors(adj, int(v), 512, m_loop)
    assert m_batch.nbytes <= m_loop.nbytes
    assert m_batch.nrequests <= m_loop.nrequests


@pytest.mark.parametrize("engine", ENGINES)
def test_kernel_engines_meter_like_numpy(adj, batch, engine):
    m = IOMeter()
    retrieve_neighbors_batch(adj, batch, 512, m, engine=engine)
    m0 = IOMeter()
    retrieve_neighbors_batch(adj, batch, 512, m0, engine="numpy")
    assert (m.nbytes, m.nrequests) == (m0.nbytes, m0.nrequests)


def test_khop_whole_frontier_matches_bruteforce(adj):
    src, dst = powerlaw_graph(N, 6, seed=3)
    seeds = np.array([1, 5, 9])

    def brute(hops):
        seen = set(int(s) for s in seeds)
        frontier = set(seen)
        for _ in range(hops):
            nxt = set()
            for v in frontier:
                nxt.update(dst[src == v].tolist())
            frontier = nxt - seen
            seen |= frontier
        return np.array(sorted(seen), np.int64)

    for hops in (1, 2, 3):
        np.testing.assert_array_equal(k_hop(adj, seeds, hops), brute(hops))


def test_pack_pages_cached_no_rematerialization(adj):
    col: DeltaIntColumn = adj.table["<dst>"]
    enc = col.encoded
    enc.packed_cache = None  # force a cold start
    a = pdo.pack_pages(enc, 0, len(enc.pages))
    cache = enc.packed_cache
    assert cache is not None
    b = pdo.pack_pages(enc, 0, len(enc.pages))
    # repeated queries reuse the same backing arrays: views, not copies
    for x, y in zip(a, b):
        assert np.shares_memory(x, y)
    assert np.shares_memory(b[4], cache.packed)
    assert pack_column(enc) is cache


def test_pac_union_all_and_pages_union():
    a = PAC.from_ids(np.array([1, 2, 700]), 512)
    b = PAC.from_ids(np.array([2, 3, 1500]), 512)
    c = PAC(512)
    u = PAC.union_all([a, b, c], 512)
    np.testing.assert_array_equal(u.to_ids(), [1, 2, 3, 700, 1500])
    assert pages_union([a, b, c]) == [0, 1, 2]
    assert PAC.union_all([], 512).count() == 0


@pytest.mark.parametrize("engine", ENGINES)
def test_fetch_properties_over_merged_pac(adj, batch, engine):
    vals = np.arange(N, dtype=np.int64) * 3 + 1
    vt = VertexTable.build(
        VertexTypeSchema("t", [PropertySchema("x", "int64")],
                         page_size=512),
        {"x": vals})
    got = neighbor_properties_batch(adj, batch, vt, "x", engine=engine)
    ids = neighbor_ids_batch(adj, batch)
    np.testing.assert_array_equal(got, vals[ids])
    # pages fetched once for the whole batch
    m_batch, m_loop = IOMeter(), IOMeter()
    pac = retrieve_neighbors_batch(adj, batch, vt.page_size)
    fetch_properties(pac, vt, "x", m_batch)
    for v in batch:
        fetch_properties(retrieve_neighbors(adj, int(v), vt.page_size),
                         vt, "x", m_loop)
    assert m_batch.nbytes <= m_loop.nbytes
