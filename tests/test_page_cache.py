"""Decoded-page LRU: eviction order, hit/miss counters, and the
miss-only IOMeter accounting shared by every decode path."""
import numpy as np
import pytest

from _engines import engines
from repro.core import (BY_SRC, ENC_GRAPHAR, DecodedPageCache, IOMeter,
                        attach_page_cache, build_adjacency,
                        neighbor_ids_batch)
from repro.core.encoding import delta_encode_column
from repro.core.page_cache import miss_runs
from repro.data.synthetic import powerlaw_graph
from repro.kernels.pac_decode import ops as pdo

PAGE = 256


@pytest.fixture()
def col():
    rng = np.random.default_rng(3)
    vals = np.sort(rng.integers(0, 1 << 20, size=16 * PAGE + 37))
    return delta_encode_column(vals, PAGE)


# ------------------------------ LRU semantics -----------------------------

def test_lru_eviction_order_and_counters():
    c = DecodedPageCache(2)
    a, b, d = (np.arange(3), np.arange(4), np.arange(5))
    c.put(0, a)
    c.put(1, b)
    assert c.get(0) is a and c.hits == 1         # bumps 0 ahead of 1
    c.put(2, d)                                  # evicts 1 (LRU), not 0
    assert c.get(1) is None and c.misses == 1
    assert c.get(0) is a and c.get(2) is d
    assert c.evictions == 1 and len(c) == 2
    assert c.stats() == {"hits": 3, "misses": 1, "evictions": 1,
                         "size": 2, "capacity": 2}
    c.reset_stats()
    assert c.stats()["hits"] == 0 and len(c) == 2
    c.clear()
    assert len(c) == 0


def test_lru_put_refresh_and_validation():
    c = DecodedPageCache(2)
    c.put(0, np.arange(1))
    c.put(1, np.arange(2))
    fresh = np.arange(9)
    c.put(0, fresh)                # refresh bumps recency, no eviction
    c.put(2, np.arange(3))         # evicts 1
    assert 0 in c and 2 in c and 1 not in c
    assert c.get(0) is fresh
    with pytest.raises(ValueError):
        DecodedPageCache(0)


def test_miss_runs_counts_contiguous_gets():
    assert miss_runs([]) == 0
    assert miss_runs([4]) == 1
    assert miss_runs([4, 5, 6]) == 1
    assert miss_runs([1, 2, 9, 10, 40]) == 3


def test_attach_page_cache_idempotent(col):
    c1 = attach_page_cache(col, 8)
    assert attach_page_cache(col, 8) is c1       # same capacity: keep
    c2 = attach_page_cache(col, 16)              # new capacity: replace
    assert c2 is not c1 and col.page_cache is c2
    col.page_cache = None


# --------------------------- miss-only accounting -------------------------

@pytest.mark.parametrize("engine", engines())
def test_no_double_charge_on_repeat(col, engine):
    attach_page_cache(col, 64)
    los = np.array([10, 3 * PAGE + 5, 9 * PAGE])
    his = np.array([2 * PAGE, 4 * PAGE, 9 * PAGE + 40])
    m1, m2 = IOMeter(), IOMeter()
    a = pdo.decode_row_ranges(col, los, his, m1, engine)
    b = pdo.decode_row_ranges(col, los, his, m2, engine)
    np.testing.assert_array_equal(a, b)
    assert m1.nbytes > 0 and m1.nrequests > 0
    assert (m2.nbytes, m2.nrequests) == (0, 0)


@pytest.mark.parametrize("engine", engines())
def test_partial_overlap_charges_new_pages_only(col, engine):
    attach_page_cache(col, 64)
    m1 = IOMeter()
    pdo.decode_row_ranges(col, np.array([0]), np.array([4 * PAGE]), m1,
                          engine)                      # pages 0-3
    m2 = IOMeter()
    pdo.decode_row_ranges(col, np.array([2 * PAGE]), np.array([6 * PAGE]),
                          m2, engine)                  # pages 2-5: 2 new
    want = sum(col.pages[p].nbytes() for p in (4, 5))
    assert (m2.nbytes, m2.nrequests) == (want, 1)


@pytest.mark.parametrize("engine", engines())
def test_eviction_recharges(col, engine):
    cache = attach_page_cache(col, 1)
    pdo.decode_row_ranges(col, np.array([0]), np.array([PAGE]), None, engine)
    pdo.decode_row_ranges(col, np.array([5 * PAGE]), np.array([6 * PAGE]),
                          None, engine)                # evicts page 0
    m = IOMeter()
    pdo.decode_row_ranges(col, np.array([0]), np.array([PAGE]), m, engine)
    assert m.nbytes == col.pages[0].nbytes()
    assert cache.evictions >= 1
    col.page_cache = None


@pytest.mark.parametrize("engine", engines())
def test_warm_cache_values_match_cold(col, engine):
    cold = pdo.decode_row_ranges(col, np.array([5, PAGE]),
                                 np.array([3 * PAGE, 7 * PAGE]),
                                 engine=engine)
    attach_page_cache(col, 64)
    pdo.decode_row_ranges(col, np.array([0]), np.array([8 * PAGE]),
                          engine=engine)               # warm a superset
    warm = pdo.decode_row_ranges(col, np.array([5, PAGE]),
                                 np.array([3 * PAGE, 7 * PAGE]),
                                 engine=engine)
    np.testing.assert_array_equal(cold, warm)
    col.page_cache = None


@pytest.mark.parametrize("engine", engines())
def test_meter_identical_across_engines_same_cache_state(col, engine):
    los = np.array([7, 5 * PAGE, 11 * PAGE + 3])
    his = np.array([2 * PAGE + 9, 5 * PAGE + 1, 13 * PAGE])
    col.page_cache = None
    attach_page_cache(col, 64)
    pdo.decode_row_ranges(col, np.array([0]), np.array([2 * PAGE]),
                          engine="numpy")              # shared warm state
    warm_pages = sorted(col.page_cache._pages)
    m = IOMeter()
    pdo.decode_row_ranges(col, los, his, m, engine)
    col.page_cache = None
    attach_page_cache(col, 64)
    pdo.decode_row_ranges(col, np.array([0]), np.array([2 * PAGE]),
                          engine="numpy")
    assert sorted(col.page_cache._pages) == warm_pages
    m0 = IOMeter()
    pdo.decode_row_ranges(col, los, his, m0, engine="numpy")
    assert (m.nbytes, m.nrequests) == (m0.nbytes, m0.nrequests)
    col.page_cache = None


# --------------------- version keying (staleness fix) ---------------------

def test_packed_cache_stale_on_in_place_page_write(col):
    """Regression: pack_column was keyed only on len(col.pages), so an
    in-place rewrite of the last partial page served stale packed data.
    The version counter keys the cache (and its device mirror) instead."""
    from repro.core.encoding import delta_encode_page
    from repro.core import pack_column
    packed = pack_column(col)
    last = len(col.pages) - 1
    tail = np.sort(np.random.default_rng(11).integers(0, 1 << 20, 37))
    col.set_page(last, delta_encode_page(tail))
    repacked = pack_column(col)
    assert repacked is not packed
    assert repacked.first[last, 0] == tail[0]
    assert pack_column(col) is repacked          # stable until next write


@pytest.mark.parametrize("engine", engines())
def test_lru_never_serves_stale_after_page_write(col, engine):
    from repro.core.encoding import delta_encode_page
    attach_page_cache(col, 64)
    los, his = np.array([15 * PAGE]), np.array([16 * PAGE])
    pdo.decode_row_ranges(col, los, his, engine=engine)   # warm page 15
    tail = np.sort(np.random.default_rng(12).integers(0, 1 << 20, PAGE))
    col.set_page(15, delta_encode_page(tail))
    got = pdo.decode_row_ranges(col, los, his, engine=engine)
    np.testing.assert_array_equal(got, tail)
    col.page_cache = None


# ------------------------ numpy storage-plane path ------------------------

def test_numpy_table_path_consults_cache():
    src, dst = powerlaw_graph(1200, 5, seed=9)
    adj = build_adjacency(src, dst, 1200, 1200, BY_SRC, ENC_GRAPHAR,
                          page_size=PAGE)
    col = adj.table["<dst>"]
    cache = attach_page_cache(col, 128)
    vs = np.arange(0, 600, 3)
    m1, m2 = IOMeter(), IOMeter()
    a = neighbor_ids_batch(adj, vs, m1, engine="numpy")
    b = neighbor_ids_batch(adj, vs, m2, engine="numpy")
    np.testing.assert_array_equal(a, b)
    # the <offset> gather still charges; the value-column decode does not
    assert m2.nbytes < m1.nbytes
    assert cache.hits > 0 and cache.misses > 0
    col.encoded.page_cache = None


def test_single_vertex_read_range_meters_like_kernel_engines():
    from repro.core import retrieve_neighbors
    src, dst = powerlaw_graph(1200, 5, seed=4)
    adj = build_adjacency(src, dst, 1200, 1200, BY_SRC, ENC_GRAPHAR,
                          page_size=PAGE)
    col = adj.table["<dst>"]
    attach_page_cache(col, 64)
    v = int(np.argmax(np.bincount(src)))
    meters = {}
    for engine in ("numpy", "jax", "pallas"):
        col.encoded.page_cache.clear()
        retrieve_neighbors(adj, v, 512, None, engine)      # warm
        m = IOMeter()
        retrieve_neighbors(adj, v, 512, m, engine)          # all hits
        meters[engine] = (m.nbytes, m.nrequests)
    col.encoded.page_cache = None
    # the numpy single-vertex path (read_range) must share the LRU's
    # miss-only accounting with the kernel engines
    assert meters["numpy"] == meters["jax"] == meters["pallas"]


# ---------------- mutation staleness (property-based) ---------------------
#
# The mutable plane's correctness hinges on one rule: every derived cache
# keys on ``DeltaColumn.version``, so no interleaving of in-place page
# writes (``set_page``/``append_page``) and warm-cache reads may ever
# serve stale rows -- on any engine, partitioned or not.

from _hypothesis_shim import given, settings, st
from repro.core import partition_column
from repro.core.encoding import delta_encode_page

SMALL = 32


def _run_staleness_ops(seed, ops, engine, parts):
    # full pages only: the row -> page mapping (row // page_size) is a
    # layout invariant, so appends and in-place rewrites are row-group
    # sized (exactly how the mutable plane's compactor writes them)
    rng = np.random.default_rng(seed)
    mirror = np.sort(rng.integers(0, 1 << 20, 3 * SMALL))
    col = delta_encode_column(np.asarray(mirror, np.int64), SMALL)
    attach_page_cache(col, 64)
    if parts:
        partition_column(col, parts)
    for kind, arg in ops:
        if kind == 0:                       # append a fresh full page
            vals = np.sort(rng.integers(0, 1 << 20, SMALL))
            col.append_page(delta_encode_page(vals))
            mirror = np.concatenate([mirror, vals])
        elif kind == 1:                     # rewrite any page in place
            i = arg % len(col.pages)
            vals = np.sort(rng.integers(0, 1 << 20, SMALL))
            col.set_page(i, delta_encode_page(vals))
            mirror = mirror.copy()
            mirror[i * SMALL:(i + 1) * SMALL] = vals
        else:                               # warm-cache read, checked
            lo = arg % max(col.count, 1)
            hi = min(lo + 1 + (arg % (2 * SMALL)), col.count)
            got = pdo.decode_row_ranges(col, np.asarray([lo]),
                                        np.asarray([hi]), None, engine)
            np.testing.assert_array_equal(got, mirror[lo:hi])
    # final full read must match the mirror exactly
    got = pdo.decode_row_ranges(col, np.asarray([0]),
                                np.asarray([col.count]), None, engine)
    np.testing.assert_array_equal(got, mirror)


@pytest.mark.parametrize("engine", engines())
@pytest.mark.parametrize("parts", [0, 3])
@given(seed=st.integers(min_value=0, max_value=10_000),
       ops=st.lists(st.tuples(st.integers(min_value=0, max_value=2),
                              st.integers(min_value=0, max_value=10_000)),
                    min_size=1, max_size=10))
@settings(max_examples=15, deadline=None)
def test_version_staleness_property(engine, parts, seed, ops):
    _run_staleness_ops(seed, ops, engine, parts)


@pytest.mark.parametrize("engine", engines())
@pytest.mark.parametrize("parts", [0, 3])
def test_version_staleness_seeded(engine, parts):
    """Deterministic driver of the same property (hypothesis optional)."""
    for seed in (0, 7, 23, 91):
        rng = np.random.default_rng(seed + 1000)
        ops = [(int(rng.integers(0, 3)), int(rng.integers(0, 10_000)))
               for _ in range(12)]
        _run_staleness_ops(seed, ops, engine, parts)
