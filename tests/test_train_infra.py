"""Optimizers, schedules, train-step accumulation, checkpoint, reshard, FT."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import (latest_checkpoint,
                                           list_checkpoints,
                                           prune_checkpoints,
                                           restore_checkpoint,
                                           save_checkpoint)
from repro.checkpoint.reshard import plan_reshard
from repro.ft.coordinator import Action, Coordinator
from repro.train.optimizer import adafactor, adamw, global_norm
from repro.train.schedule import warmup_cosine, warmup_linear, warmup_rsqrt
from repro.train.train_step import make_train_step


# ----------------------------- optimizers ---------------------------------

def quad_params():
    return {"w": jnp.asarray([1.0, -2.0, 3.0]), "b": jnp.asarray(0.5)}


def quad_loss(p):
    return jnp.sum(p["w"] ** 2) + p["b"] ** 2


@pytest.mark.parametrize("moment_dtype", ["float32", "bfloat16", "int8"])
def test_adamw_converges_quadratic(moment_dtype):
    opt = adamw(0.1, weight_decay=0.0, moment_dtype=moment_dtype)
    params = quad_params()
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(quad_loss)(params)
        params, state, stats = opt.update(grads, state, params)
    assert quad_loss(params) < 1e-2, f"{moment_dtype}: {quad_loss(params)}"
    assert bool(jnp.isfinite(stats["grad_norm"]))


def test_adamw_int8_state_is_quantized():
    opt = adamw(0.1, moment_dtype="int8")
    params = {"w": jnp.ones((300,))}
    state = opt.init(params)
    assert state["m"]["w"]["q"].dtype == jnp.int8
    # blocks of 128 -> ceil(300/128) = 3 blocks
    assert state["m"]["w"]["q"].shape == (3, 128)


def test_adafactor_converges_and_is_factored():
    opt = adafactor(0.5)
    params = {"w": jnp.full((8, 4), 3.0)}
    state = opt.init(params)
    assert state["v"]["w"]["row"].shape == (8,)
    assert state["v"]["w"]["col"].shape == (4,)
    for _ in range(300):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_grad_clipping():
    opt = adamw(0.0, max_grad_norm=1.0)  # lr 0: only inspect stats
    params = {"w": jnp.ones(4)}
    state = opt.init(params)
    grads = {"w": jnp.full(4, 100.0)}
    _, _, stats = opt.update(grads, state, params)
    assert float(stats["grad_norm"]) == pytest.approx(200.0, rel=1e-3)


def test_schedules_shapes():
    for fn in (warmup_cosine(1e-3, 10, 100), warmup_linear(1e-3, 10, 100),
               warmup_rsqrt(1e-3, 10)):
        v0 = float(fn(jnp.asarray(0)))
        v10 = float(fn(jnp.asarray(10)))
        v90 = float(fn(jnp.asarray(90)))
        assert v0 <= v10 and v90 <= v10
        assert v10 == pytest.approx(1e-3, rel=1e-2)


# -------------------------- grad accumulation ------------------------------

def test_train_step_micro_accumulation_matches_full_batch():
    """n_micro=4 must reproduce the n_micro=1 update (mean-accumulated)."""
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("smollm-360m").reduced().with_(n_units=1)
    model = build_model(cfg)
    params = model.init(0)
    opt = adamw(1e-2)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)),
                              jnp.int32),
    }
    s1 = make_train_step(model, opt, n_micro=1)
    s4 = make_train_step(model, opt, n_micro=4)
    p1, _, m1 = s1(params, opt.init(params), batch)
    p4, _, m4 = s4(params, opt.init(params), batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-5)
    # embedding grads are scatter-adds whose fp32 summation order differs
    # between one call and four accumulated calls -> small atol
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=5e-4)


# ------------------------------ checkpoint ---------------------------------

def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    tree = {"a": np.arange(10, dtype=np.float32),
            "b": {"c": np.ones((3, 4), np.int32)}}
    d = str(tmp_path)
    save_checkpoint(d, 5, tree, extra={"next_step": 5})
    save_checkpoint(d, 10, tree, extra={"next_step": 10})
    assert list_checkpoints(d) == [5, 10]
    got, extra = restore_checkpoint(d, 10, like=tree)
    np.testing.assert_array_equal(got["a"], tree["a"])
    assert extra["next_step"] == 10
    # corrupt a shard -> checksum failure
    import glob
    shard = sorted(glob.glob(os.path.join(d, "step_00000010", "*.npy")))[0]
    with open(shard, "r+b") as f:
        f.seek(100)
        f.write(b"\xff\xff\xff")
    with pytest.raises(IOError):
        restore_checkpoint(d, 10, like=tree)
    # step 5 still intact (atomic commits are independent)
    got5, _ = restore_checkpoint(d, 5, like=tree)
    np.testing.assert_array_equal(got5["b"]["c"], tree["b"]["c"])


def test_checkpoint_prune(tmp_path):
    tree = {"a": np.zeros(3)}
    for s in range(5):
        save_checkpoint(str(tmp_path), s, tree)
    prune_checkpoints(str(tmp_path), keep=2)
    assert list_checkpoints(str(tmp_path)) == [3, 4]


def test_reshard_plan():
    plan = plan_reshard((128, 64), old_spec_shards=4, new_spec_shards=8)
    assert len(plan) == 8
    # every new shard reads exactly its rows, total coverage == 1.0
    assert sum(p["bytes_factor"] for p in plan) == pytest.approx(1.0)
    # scale-down: 8 -> 2
    plan2 = plan_reshard((128, 64), 8, 2)
    assert all(len(p["reads"]) == 4 for p in plan2)


# ------------------------------- FT ----------------------------------------

class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_coordinator_detects_failure_and_restarts():
    clock = FakeClock()
    c = Coordinator(4, heartbeat_timeout=10.0, spares=1, clock=clock)
    for w in range(4):
        c.heartbeat(w, 0, 1.0)
    d = c.tick(latest_committed_step=100)
    assert d.action == Action.CONTINUE
    # worker 2 goes silent
    clock.t = 20.0
    for w in (0, 1, 3):
        c.heartbeat(w, 1, 1.0)
    d = c.tick(latest_committed_step=100)
    assert d.action == Action.RESTART_FROM_CHECKPOINT
    assert d.failed_workers == [2]
    assert d.restore_step == 100
    assert c.healthy_count() == 4  # spare promoted


def test_coordinator_elastic_scale_down_without_spares():
    clock = FakeClock()
    c = Coordinator(4, heartbeat_timeout=10.0, spares=0, clock=clock)
    clock.t = 20.0
    for w in (0, 1):
        c.heartbeat(w, 1, 1.0)
    d = c.tick(latest_committed_step=40)
    assert d.action == Action.ELASTIC_SCALE_DOWN
    assert set(d.failed_workers) == {2, 3}
    assert set(d.surviving_workers) == {0, 1}


def test_coordinator_straggler_detection_and_promotion():
    clock = FakeClock()
    c = Coordinator(4, heartbeat_timeout=1e9, straggler_factor=2.0,
                    strike_limit=2, spares=1, clock=clock)
    for step in range(3):
        clock.t += 1
        for w in range(4):
            c.heartbeat(w, step, 10.0 if w == 3 else 1.0)
        d = c.tick(latest_committed_step=None)
        if d.action == Action.PROMOTE_SPARE:
            break
    assert d.action == Action.PROMOTE_SPARE
    assert 3 in [wid for wid, w in c.workers.items()
                 if w.state.value == "evicted"]


# --------------------------- trainer end-to-end ----------------------------

def test_trainer_failure_recovery_resumes_from_checkpoint(tmp_path):
    from repro.configs import get_config
    from repro.models import build_model
    from repro.train.trainer import Trainer, TrainerConfig
    cfg = get_config("smollm-360m").reduced().with_(n_units=1)
    model = build_model(cfg)
    opt = adamw(1e-3)
    rng = np.random.default_rng(0)

    def batch_fn(step):
        r = np.random.default_rng(step)
        return {"tokens": jnp.asarray(r.integers(0, cfg.vocab_size, (4, 16)),
                                      jnp.int32),
                "labels": jnp.asarray(r.integers(0, cfg.vocab_size, (4, 16)),
                                      jnp.int32)}

    tcfg = TrainerConfig(total_steps=12, checkpoint_every=4,
                         checkpoint_dir=str(tmp_path), log_every=4)
    t = Trainer(model, opt, tcfg, batch_fn)
    out = t.run(simulate_failure_at=6)
    assert out["failures"] == 1
    assert out["final_step"] == 12
    assert latest_checkpoint(str(tmp_path)) == 12
    # determinism: a clean run reaches the same final loss trajectory
    t2 = Trainer(model, opt, TrainerConfig(
        total_steps=12, checkpoint_every=4,
        checkpoint_dir=str(tmp_path) + "_clean", log_every=4), batch_fn)
    out2 = t2.run()
    assert out2["history"][-1]["step"] == out["history"][-1]["step"]
    assert out2["history"][-1]["loss"] == pytest.approx(
        out["history"][-1]["loss"], rel=1e-4)
