"""Predicate-pushdown batched retrieval + batched multi-property gather.

The fused filtered path ("neighbors of batch B having label L" in one
kernel dispatch) must match the host filter-then-intersect oracle
bit-for-bit on ids AND on IOMeter bytes/requests, across engines, with and
without the decoded-page LRU.  The LRU feed-back is pinned by poisoning
the cache: the kernel must consume the host-fed rows, not re-decode.
"""
import numpy as np
import pytest

from _engines import engines
from repro.core import (BY_SRC, ENC_GRAPHAR, IOMeter, L, LabelFilter, PAC,
                        attach_page_cache, build_adjacency,
                        fetch_properties, fetch_properties_batch,
                        retrieve_neighbors_batch)
from repro.core.schema import PropertySchema, VertexTypeSchema
from repro.core.vertex import VertexTable
from repro.data.synthetic import clustered_labels, powerlaw_graph

N = 2000
PAGE = 256
TPS = 512  # target page size
LABELS = ["A", "B", "Z"]


@pytest.fixture(scope="module")
def adj():
    src, dst = powerlaw_graph(N, 6, seed=13)
    return build_adjacency(src, dst, N + 8, N, BY_SRC, ENC_GRAPHAR,
                           page_size=PAGE)


@pytest.fixture(scope="module")
def vt():
    rng = np.random.default_rng(7)
    labels = clustered_labels(N, ["A", "B"], density=0.3, run_scale=64,
                              seed=5)
    labels["Z"] = np.zeros(N, bool)            # a label nobody carries
    return VertexTable.build(
        VertexTypeSchema("v", [PropertySchema("x", "int64"),
                               PropertySchema("y", "int64"),
                               PropertySchema("w", "float64")],
                         labels=LABELS, page_size=PAGE),
        {"x": rng.integers(0, 1000, N), "y": rng.integers(0, 1000, N),
         "w": rng.random(N)}, labels, num_vertices=N)


@pytest.fixture(scope="module")
def batch():
    rng = np.random.default_rng(17)
    vs = rng.integers(0, N, 64)
    return np.concatenate([vs, vs[:9], np.arange(N, N + 8)])


CONDS = [L("A"), L("A") & ~L("B"), (L("A") & ~L("B")) | L("B")]


def _oracle(adj, batch, filt):
    pac = retrieve_neighbors_batch(adj, batch, TPS)
    return pac.intersect(filt.pac(TPS))


@pytest.mark.parametrize("engine", engines(kernel_only=True))
@pytest.mark.parametrize("cond", CONDS, ids=[repr(c) for c in CONDS])
def test_fused_filter_matches_host_oracle(adj, vt, batch, cond, engine):
    filt = LabelFilter(vt, cond)
    fused = retrieve_neighbors_batch(adj, batch, TPS, engine=engine,
                                     fused=True, filter=filt)
    host = retrieve_neighbors_batch(adj, batch, TPS, engine=engine,
                                    fused=False, filter=filt)
    want = _oracle(adj, batch, filt)
    assert fused == host == want
    np.testing.assert_array_equal(fused.to_ids(), want.to_ids())


@pytest.mark.parametrize("engine", engines())
def test_filter_meter_identical_across_paths(adj, vt, batch, engine):
    filt = LabelFilter(vt, CONDS[1])
    m_np = IOMeter()
    want = retrieve_neighbors_batch(adj, batch, TPS, m_np, engine="numpy",
                                    filter=filt)
    for fused in ([None] if engine == "numpy" else [True, False]):
        m = IOMeter()
        got = retrieve_neighbors_batch(adj, batch, TPS, m, engine=engine,
                                       fused=fused, filter=filt)
        assert got == want
        assert (m.nbytes, m.nrequests) == (m_np.nbytes, m_np.nrequests)


@pytest.mark.parametrize("engine", engines(kernel_only=True))
def test_fused_filter_empty_label(adj, vt, batch, engine):
    # the all-false label must yield an empty PAC on every path
    filt = LabelFilter(vt, L("Z"))
    pac = retrieve_neighbors_batch(adj, batch, TPS, engine=engine,
                                   fused=True, filter=filt)
    assert pac.count() == 0 and len(pac) == 0
    # and an all-true complement returns the unfiltered result
    full = retrieve_neighbors_batch(adj, batch, TPS, engine=engine,
                                    fused=True, filter=LabelFilter(vt, ~L("Z")))
    assert full == retrieve_neighbors_batch(adj, batch, TPS)


@pytest.mark.parametrize("engine", engines(kernel_only=True))
def test_fused_filter_with_warm_cache(adj, vt, batch, engine):
    col = adj.table["<dst>"]
    filt = LabelFilter(vt, CONDS[2])
    want = _oracle(adj, batch, filt)
    cache = attach_page_cache(col, 4096)
    try:
        cache.clear()
        p_cold = retrieve_neighbors_batch(adj, batch, TPS, engine=engine,
                                          fused=True, filter=filt)
        m_warm = IOMeter()
        p_warm = retrieve_neighbors_batch(adj, batch, TPS, m_warm,
                                          engine=engine, fused=True,
                                          filter=filt)
        assert p_cold == p_warm == want
        # warm tick pays the <offset> gather + the filter's label metadata
        m_want = IOMeter()
        adj.edge_ranges_batch(batch, m_want)
        filt.charge(m_want)
        assert (m_warm.nbytes, m_warm.nrequests) \
            == (m_want.nbytes, m_want.nrequests)
        assert cache.hits > 0
    finally:
        col.encoded.page_cache = None


@pytest.mark.parametrize("engine", engines(kernel_only=True))
def test_lru_rows_feed_the_kernel_not_redecoded(adj, batch, engine):
    """Poison one cached page: the per-dispatch pack path must consume the
    host-fed rows (skipping the on-device unpack for hits), so the
    poisoned ids must show up in the result.  The device-resident path
    re-decodes hits from the immutable on-device mirror instead of
    shipping cached rows, so it must be immune to the same poisoning."""
    col = adj.table["<dst>"]
    cache = attach_page_cache(col, 4096)
    try:
        cache.clear()
        clean = retrieve_neighbors_batch(adj, batch, TPS, engine=engine,
                                         fused=True, resident=False)
        # keys are plain pages, or (partition, page) when REPRO_PARTITIONS
        # routes this column through the partition plane
        keys = sorted(cache._pages, key=lambda k: k if isinstance(k, tuple)
                      else (-1, k))
        victim_key = keys[0]
        victim, part = ((victim_key[1], victim_key[0])
                        if isinstance(victim_key, tuple)
                        else (victim_key, None))
        fake = np.full(col.encoded.pages[victim].count, N - 1, np.int64)
        cache.put(victim, fake, part=part)
        poisoned = retrieve_neighbors_batch(adj, batch, TPS, engine=engine,
                                            fused=True, resident=False)
        assert poisoned != clean
        assert int(N - 1) in poisoned.to_ids().tolist()
        # resident path: hits decode on device from the packed mirror --
        # the poisoned host rows never reach the kernel
        cache.put(victim, fake, part=part)
        immune = retrieve_neighbors_batch(adj, batch, TPS, engine=engine,
                                          fused=True, resident=True)
        assert immune == clean
    finally:
        col.encoded.page_cache = None


@pytest.mark.parametrize("engine", engines(kernel_only=True))
def test_partial_cache_mixed_hit_miss(adj, batch, engine):
    col = adj.table["<dst>"]
    want = retrieve_neighbors_batch(adj, batch, TPS)
    cache = attach_page_cache(col, 4096)
    try:
        cache.clear()
        # warm only part of the page set, then retrieve the full batch
        retrieve_neighbors_batch(adj, batch[:13], TPS, engine=engine,
                                 fused=True)
        got = retrieve_neighbors_batch(adj, batch, TPS, engine=engine,
                                       fused=True)
        assert got == want
        assert cache.hits > 0 and cache.misses > 0
    finally:
        col.encoded.page_cache = None


def test_filter_requires_matching_target_space(adj, batch):
    small_vt = VertexTable.build(
        VertexTypeSchema("w", [], labels=["Q"], page_size=PAGE),
        {}, {"Q": np.ones(100, bool)}, num_vertices=100)
    with pytest.raises(ValueError):
        retrieve_neighbors_batch(adj, batch, TPS, engine="pallas",
                                 fused=True, filter=LabelFilter(small_vt,
                                                                L("Q")))


# ----------------------------- multi-property gather ----------------------

def test_multi_property_gather_matches_per_column_loop(adj, vt, batch):
    pac = retrieve_neighbors_batch(adj, batch, PAGE)
    m_batch, m_loop = IOMeter(), IOMeter()
    got = fetch_properties_batch(pac, vt, ["x", "y", "w"], m_batch)
    assert list(got) == ["x", "y", "w"]
    for name in ("x", "y", "w"):
        want = fetch_properties(pac, vt, name, m_loop)
        np.testing.assert_array_equal(got[name], want)
    assert (m_batch.nbytes, m_batch.nrequests) \
        == (m_loop.nbytes, m_loop.nrequests)


def test_multi_property_gather_empty_pac(vt):
    out = fetch_properties_batch(PAC(PAGE), vt, ["x", "w"])
    assert out["x"].size == 0 and out["w"].size == 0


def test_multi_property_gather_over_filtered_pac(adj, vt, batch):
    filt = LabelFilter(vt, L("A"))
    pac = retrieve_neighbors_batch(adj, batch, PAGE, filter=filt)
    got = fetch_properties_batch(pac, vt, ["x", "y"])
    ids = pac.to_ids()
    np.testing.assert_array_equal(got["x"],
                                  vt.table["x"].values[ids])
    np.testing.assert_array_equal(got["y"],
                                  vt.table["y"].values[ids])
