"""Fused batched decode->bitmap path vs the numpy oracle.

The fused kernel turns a deduplicated page list + per-row range masks
into target bitmap planes in one dispatch; these tests pin its PAC
output to the host path (decode + ``PAC.from_ids``) across engines,
including empty ranges, duplicate vertices, and cache interplay.
"""
import numpy as np
import pytest

from _engines import engines
from repro.core import (BY_SRC, ENC_GRAPHAR, IOMeter, PAC,
                        attach_page_cache, build_adjacency,
                        retrieve_neighbors, retrieve_neighbors_batch)
from repro.core.encoding import delta_encode_column
from repro.core.pac import words_per_page
from repro.data.synthetic import powerlaw_graph
from repro.kernels.pac_decode import ops as pdo

N = 2000
PAGE = 256


@pytest.fixture(scope="module")
def adj():
    src, dst = powerlaw_graph(N, 6, seed=13)
    return build_adjacency(src, dst, N + 8, N, BY_SRC, ENC_GRAPHAR,
                           page_size=PAGE)


@pytest.fixture(scope="module")
def batch():
    rng = np.random.default_rng(17)
    vs = rng.integers(0, N, 48)
    # duplicates + guaranteed-empty adjacency vertices in the batch
    return np.concatenate([vs, vs[:9], np.arange(N, N + 8)])


def test_adjacency_knows_value_side_size(adj):
    assert adj.num_value_vertices == N


@pytest.mark.parametrize("engine", engines(kernel_only=True))
def test_fused_matches_numpy_oracle(adj, batch, engine):
    got = retrieve_neighbors_batch(adj, batch, 512, engine=engine,
                                   fused=True)
    want = PAC.union_all(
        [retrieve_neighbors(adj, int(v), 512) for v in batch], 512)
    assert got == want
    np.testing.assert_array_equal(got.to_ids(), want.to_ids())


@pytest.mark.parametrize("engine", engines(kernel_only=True))
def test_fused_matches_host_path(adj, batch, engine):
    fused = retrieve_neighbors_batch(adj, batch, 512, engine=engine,
                                     fused=True)
    host = retrieve_neighbors_batch(adj, batch, 512, engine=engine,
                                    fused=False)
    assert fused == host


@pytest.mark.parametrize("engine", engines(kernel_only=True))
def test_fused_meter_identical_to_numpy(adj, batch, engine):
    m_f, m_np = IOMeter(), IOMeter()
    retrieve_neighbors_batch(adj, batch, 512, m_f, engine=engine,
                             fused=True)
    retrieve_neighbors_batch(adj, batch, 512, m_np, engine="numpy")
    assert (m_f.nbytes, m_f.nrequests) == (m_np.nbytes, m_np.nrequests)


@pytest.mark.parametrize("engine", engines(kernel_only=True))
def test_fused_empty_ranges_and_empty_batch(adj, engine):
    # batch of only empty-adjacency vertices
    pac = retrieve_neighbors_batch(adj, np.arange(N, N + 8), 512,
                                   engine=engine, fused=True)
    assert pac.count() == 0 and len(pac) == 0
    # empty batch short-circuits before the kernel
    assert retrieve_neighbors_batch(adj, np.zeros(0, np.int64), 512,
                                    engine=engine, fused=True).count() == 0


@pytest.mark.parametrize("engine", engines(kernel_only=True))
def test_fused_unsorted_duplicated_page_rows(engine):
    # adjacency-like column whose pages interleave many vertices' sorted
    # neighbor runs: ids within one page are neither sorted nor unique
    rng = np.random.default_rng(23)
    vals = rng.integers(0, 1500, size=4096).astype(np.int64)
    col = delta_encode_column(vals, 512)
    los = np.array([0, 10, 700, 700, 4000, 9, 0])
    his = np.array([10, 300, 1400, 1400, 4096, 9, 0])
    for tps in (512, 2048):
        got = pdo.retrieve_pac_batch(col, los, his, tps, engine=engine,
                                     num_targets=1500, fused=True)
        ids = pdo.decode_row_ranges(col, los, his, engine="numpy")
        want = PAC.from_ids(np.unique(ids), tps)
        assert got == want


@pytest.mark.parametrize("engine", engines(kernel_only=True))
def test_fused_target_boundary_ids(engine):
    # ids at the very edge of a non-word-multiple target space
    num_targets = 1000  # not a multiple of 32
    vals = np.array([0, 1, 31, 32, 998, 999] * 10, np.int64)
    col = delta_encode_column(vals, 32)
    got = pdo.retrieve_pac_batch(col, np.array([0]), np.array([60]), 256,
                                 engine=engine, num_targets=num_targets,
                                 fused=True)
    ids = pdo.decode_row_ranges(col, np.array([0]), np.array([60]),
                                engine="numpy")
    assert got == PAC.from_ids(np.unique(ids), 256)


@pytest.mark.parametrize("engine", engines(kernel_only=True))
def test_fused_with_warm_cache_charges_nothing(adj, batch, engine):
    col = adj.table["<dst>"]
    cache = attach_page_cache(col, 4096)
    try:
        cache.clear()
        cache.reset_stats()
        m_cold, m_warm = IOMeter(), IOMeter()
        p1 = retrieve_neighbors_batch(adj, batch, 512, m_cold,
                                      engine=engine, fused=True)
        p2 = retrieve_neighbors_batch(adj, batch, 512, m_warm,
                                      engine=engine, fused=True)
        assert p1 == p2
        # warm tick pays only the (uncached) <offset> index gather; the
        # value-column decode is fully served from the LRU
        m_off = IOMeter()
        adj.edge_ranges_batch(batch, m_off)
        assert m_cold.nbytes > m_off.nbytes
        assert (m_warm.nbytes, m_warm.nrequests) == (m_off.nbytes,
                                                     m_off.nrequests)
        assert cache.hits > 0
    finally:
        col.encoded.page_cache = None


@pytest.mark.parametrize("engine", engines(kernel_only=True))
def test_fused_resident_toggle_bit_identical(adj, batch, engine):
    """The device-resident mirror is a transfer optimization only: the
    fused PAC and IOMeter must be identical with the mirror on and off."""
    m_on, m_off = IOMeter(), IOMeter()
    on = retrieve_neighbors_batch(adj, batch, 512, m_on, engine=engine,
                                  fused=True, resident=True)
    off = retrieve_neighbors_batch(adj, batch, 512, m_off, engine=engine,
                                   fused=True, resident=False)
    assert on == off
    np.testing.assert_array_equal(on.to_ids(), off.to_ids())
    assert (m_on.nbytes, m_on.nrequests) == (m_off.nbytes, m_off.nrequests)


@pytest.mark.parametrize("engine", engines(kernel_only=True))
def test_fused_resident_unsorted_duplicated_page_rows(engine):
    rng = np.random.default_rng(23)
    vals = rng.integers(0, 1500, size=4096).astype(np.int64)
    col = delta_encode_column(vals, 512)
    los = np.array([0, 10, 700, 700, 4000, 9, 0])
    his = np.array([10, 300, 1400, 1400, 4096, 9, 0])
    ids = pdo.decode_row_ranges(col, los, his, engine="numpy")
    want = PAC.from_ids(np.unique(ids), 512)
    for resident in (True, False):
        got = pdo.retrieve_pac_batch(col, los, his, 512, engine=engine,
                                     num_targets=1500, fused=True,
                                     resident=resident)
        assert got == want


def test_pac_from_bitmap_planes_roundtrip():
    wpp = words_per_page(512)
    planes = np.zeros((4, wpp), np.uint32)
    planes[0, 0] = 0b101          # ids 0, 2
    planes[2, 3] = 1 << 7         # id 2*512 + 3*32 + 7
    pac = PAC.from_bitmap_planes(planes, 512)
    assert pac.pages() == [0, 2]  # empty planes dropped
    np.testing.assert_array_equal(pac.to_ids(), [0, 2, 2 * 512 + 103])
    # explicit page indices
    pac2 = PAC.from_bitmap_planes(planes[[0, 2]], 512,
                                  pages=np.array([5, 9]))
    assert pac2.pages() == [5, 9]
    np.testing.assert_array_equal(
        pac2.to_ids(), [5 * 512, 5 * 512 + 2, 9 * 512 + 103])
    with pytest.raises(ValueError):
        PAC.from_bitmap_planes(np.zeros((2, wpp + 1), np.uint32), 512)


def test_pac_from_dense_bitmap_pads_tail():
    words = np.zeros(3, np.uint32)   # 96 ids < one 128-id page
    words[2] = 1 << 5                # id 69
    pac = PAC.from_dense_bitmap(words, 128)
    np.testing.assert_array_equal(pac.to_ids(), [69])
    assert pac.pages() == [0]
    with pytest.raises(ValueError):
        PAC.from_dense_bitmap(words, 100)   # page_size not word-aligned
