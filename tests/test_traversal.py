"""Fused on-device multi-hop traversal (the frontier plane).

The invariant under test everywhere: the fused k-hop -- all k hops one
``lax.scan``-stepped dispatch over the device-resident frontier plane --
returns **bit-identical ids and IOMeter accounting** to the host-loop
oracle (``k_hop`` with ``fused=False``) across engines, partition
counts, hop counts, and per-hop label predicates.  On top of that:
steady-state traversals must never retrace, the meterless/cacheless
fused path must make exactly one device round-trip per traversal (no
host-side id materialization between hops), and the partition plane must
see fused traversals as dispatches.

Runs on any device count: under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the multi-device
CI job) the SPMD traversal tail executes across a real mesh; on one
device the degenerate single-shard tail covers the same interfaces.
"""
import numpy as np
import pytest

from _engines import engines
from _hypothesis_shim import given, settings, st
from repro.core import (BY_SRC, ENC_GRAPHAR, Frontier, IOMeter, L,
                        LabelFilter, attach_page_cache, build_adjacency,
                        k_hop, live_partitions, partition_column)
from repro.core.schema import VertexTypeSchema
from repro.core.vertex import VertexTable
from repro.data.synthetic import clustered_labels, powerlaw_graph
from repro.kernels import _pad
from repro.kernels.pac_decode import ops as pdo
from repro.kernels.traversal import ops as trav

N = 2000
PAGE = 256
PART_COUNTS = (1, 2, 8)
HOPS = (1, 2, 3)


def _edges():
    return powerlaw_graph(N, 6, seed=13)


def _adj():
    src, dst = _edges()
    return build_adjacency(src, dst, N, N, BY_SRC, ENC_GRAPHAR,
                           page_size=PAGE)


@pytest.fixture(scope="module")
def vt():
    labels = clustered_labels(N, ["A", "B"], density=0.3, run_scale=64,
                              seed=7)
    return VertexTable.build(VertexTypeSchema("v", [], labels=["A", "B"]),
                             {}, labels, num_vertices=N)


@pytest.fixture
def forced_spmd(monkeypatch):
    """Force the shard_map traversal tail regardless of column width."""
    monkeypatch.setattr(pdo, "SHARD_MIN_PAGES", 0)


def _meters_equal(a: IOMeter, b: IOMeter) -> bool:
    return a.nbytes == b.nbytes and a.nrequests == b.nrequests


def _brute_khop(src, dst, seeds, hops):
    """Set-based BFS ground truth (independent of every plane)."""
    seen = set(int(s) for s in seeds)
    frontier = set(seen)
    out = {v: set() for v in range(N)}
    for s, d in zip(src, dst):
        out[int(s)].add(int(d))
    for _ in range(hops):
        nxt = set()
        for v in frontier:
            nxt |= out[v]
        frontier = nxt - seen
        seen |= frontier
    return np.array(sorted(seen), np.int64)


# ------------------------------ correctness -------------------------------

def test_oracle_matches_brute_force():
    src, dst = _edges()
    adj = _adj()
    seeds = np.array([3, 17, 999])
    for hops in HOPS:
        np.testing.assert_array_equal(k_hop(adj, seeds, hops),
                                      _brute_khop(src, dst, seeds, hops))


@pytest.mark.parametrize("engine", engines(kernel_only=True))
@pytest.mark.parametrize("parts", PART_COUNTS)
@pytest.mark.parametrize("hops", HOPS)
def test_fused_bit_identical_to_oracle(vt, engine, parts, hops):
    """ids AND meters match across engines x partitions x hops, with a
    random per-hop predicate pattern."""
    adj_o, adj_f = _adj(), _adj()
    rng = np.random.default_rng(parts * 10 + hops)
    choices = (None, LabelFilter(vt, L("A")), LabelFilter(vt, L("B")))
    filts = [choices[rng.integers(len(choices))] for _ in range(hops)]
    seeds = rng.integers(0, N, size=5)
    m_o, m_f = IOMeter(), IOMeter()
    want = k_hop(adj_o, seeds, hops, m_o, filter=filts, partitions=parts,
                 fused=False)
    got = k_hop(adj_f, seeds, hops, m_f, engine=engine, filter=filts,
                partitions=parts)
    np.testing.assert_array_equal(got, want)
    assert _meters_equal(m_o, m_f)


@pytest.mark.parametrize("engine", engines(kernel_only=True))
def test_fused_sharded_matches_oracle(vt, engine, forced_spmd):
    """SPMD tail (real mesh under the forced-8-device job)."""
    adj_o, adj_f = _adj(), _adj()
    seeds = np.array([3, 17, 999, 1500])
    filt = LabelFilter(vt, L("A"))
    m_o, m_f = IOMeter(), IOMeter()
    want = k_hop(adj_o, seeds, 3, m_o, filter=filt, partitions=2,
                 fused=False)
    got = k_hop(adj_f, seeds, 3, m_f, engine=engine, filter=filt,
                partitions=2)
    np.testing.assert_array_equal(got, want)
    assert _meters_equal(m_o, m_f)


@settings(max_examples=15, deadline=None)
@given(seeds=st.lists(st.integers(0, N - 1), min_size=0, max_size=12),
       hops=st.integers(1, 3),
       pattern=st.lists(st.sampled_from(["none", "A", "B"]),
                        min_size=3, max_size=3))
def test_fused_property_matches_oracle(vt, seeds, hops, pattern):
    adj_o, adj_f = _adj(), _adj()
    lut = {"none": None, "A": LabelFilter(vt, L("A")),
           "B": LabelFilter(vt, L("B"))}
    filts = [lut[p] for p in pattern[:hops]]
    seeds = np.asarray(seeds, np.int64)
    m_o, m_f = IOMeter(), IOMeter()
    want = k_hop(adj_o, seeds, hops, m_o, filter=filts, fused=False)
    got = k_hop(adj_f, seeds, hops, m_f, engine="jax", filter=filts)
    np.testing.assert_array_equal(got, want)
    assert _meters_equal(m_o, m_f)


@pytest.mark.parametrize("engine", engines())
def test_empty_frontier_early_exit(engine):
    adj = _adj()
    m = IOMeter()
    out = k_hop(adj, np.zeros(0, np.int64), 3, m, engine=engine)
    assert out.size == 0
    assert m.nbytes == 0 and m.nrequests == 0  # nothing charged


@pytest.mark.parametrize("engine", engines())
def test_seeds_with_no_edges(engine):
    # vertex 4 is isolated: the frontier dies after hop 1's empty expand
    src = np.array([0, 1, 2])
    dst = np.array([1, 2, 3])
    adj = build_adjacency(src, dst, 5, 5, BY_SRC, ENC_GRAPHAR,
                          page_size=32)
    m_o, m_f = IOMeter(), IOMeter()
    want = k_hop(adj, np.array([4]), 3, m_o, fused=False)
    got = k_hop(adj, np.array([4]), 3, m_f, engine=engine)
    np.testing.assert_array_equal(want, [4])
    np.testing.assert_array_equal(got, [4])
    assert _meters_equal(m_o, m_f)
    assert k_hop(adj, np.array([4]), 3, engine=engine,
                 include_seeds=False).size == 0


@pytest.mark.parametrize("engine", engines())
def test_include_seeds_flag(engine):
    adj = _adj()
    seeds = np.array([3, 17, 999])
    full = k_hop(adj, seeds, 2, engine=engine)
    bare = k_hop(adj, seeds, 2, engine=engine, include_seeds=False)
    np.testing.assert_array_equal(
        bare, np.setdiff1d(full, seeds, assume_unique=True))


def test_fused_on_numpy_engine_raises():
    with pytest.raises(ValueError):
        k_hop(_adj(), np.array([0]), 2, engine="numpy", fused=True)


@pytest.mark.parametrize("engine", engines(kernel_only=True))
def test_fused_with_page_cache_matches_oracle(engine):
    """Warm-cache evolution (miss-only charging) matches hop for hop."""
    adj_o, adj_f = _adj(), _adj()
    attach_page_cache(adj_o.table["<dst>"], 64)
    attach_page_cache(adj_f.table["<dst>"], 64)
    rng = np.random.default_rng(3)
    for trial in range(3):                      # cold, then warm runs
        seeds = rng.integers(0, N, size=4)
        m_o, m_f = IOMeter(), IOMeter()
        want = k_hop(adj_o, seeds, 2, m_o, fused=False)
        got = k_hop(adj_f, seeds, 2, m_f, engine=engine)
        np.testing.assert_array_equal(got, want)
        assert _meters_equal(m_o, m_f)


# --------------------------- dispatch-cost plane ---------------------------

@pytest.mark.parametrize("engine", engines(kernel_only=True))
def test_steady_state_traversals_do_not_retrace(engine):
    adj = _adj()
    rng = np.random.default_rng(37)
    batches = [rng.integers(0, N, s) for s in rng.integers(2, 40, size=10)]
    for vs in batches:                          # warm the one size class
        k_hop(adj, vs, 2, engine=engine)
    before = _pad.trace_count()
    for _ in range(10):
        for vs in batches:                      # 100 steady-state runs
            k_hop(adj, vs, 2, engine=engine)
    assert _pad.trace_count() == before


@pytest.mark.parametrize("engine", engines(kernel_only=True))
def test_meterless_fused_single_roundtrip(engine):
    """With no meter and no LRU attached, nothing but the visited plane
    crosses back: one device round-trip per traversal, k hops fused."""
    adj = _adj()
    k_hop(adj, np.array([3]), 3, engine=engine)     # build plan
    plan = trav.traversal_plan(adj, engine)
    d0, r0, h0 = plan.dispatches, plan.device_roundtrips, plan.hops_fused
    k_hop(adj, np.array([17, 999]), 3, engine=engine)
    assert plan.dispatches == d0 + 1
    assert plan.device_roundtrips == r0 + 1         # no per-hop trips
    assert plan.hops_fused == h0 + 3
    assert plan.last_frontier_sizes is not None
    assert len(plan.last_frontier_sizes) == 3


@pytest.mark.parametrize("engine", engines(kernel_only=True))
def test_fused_counts_partition_dispatch(engine):
    adj = _adj()
    partition_column(adj.table["<dst>"].encoded, 2)
    parts = live_partitions(adj.table["<dst>"].encoded)
    before = parts.dispatches
    k_hop(adj, np.array([3, 17]), 2, engine=engine)
    assert parts.dispatches > before


def test_traversal_stats_aggregate():
    adj = _adj()
    assert trav.traversal_stats(adj) is None        # no plans yet
    k_hop(adj, np.array([3]), 2, engine="jax")
    s = trav.traversal_stats(adj)
    assert s["dispatches"] >= 1 and s["hops_fused"] >= 2
    assert s["traversal_device_roundtrips"] >= 1
    assert len(s["frontier_sizes"]) == 2


# ------------------------------ frontier type ------------------------------

def test_frontier_roundtrip_and_setops():
    f = Frontier.from_ids(np.array([1, 5, 64, 1999]), N)
    np.testing.assert_array_equal(f.to_ids(), [1, 5, 64, 1999])
    assert len(f) == 4 and 64 in f and 63 not in f
    g = Frontier.from_ids(np.array([5, 7]), N)
    u = f.copy()
    u.or_(g)
    np.testing.assert_array_equal(u.to_ids(), [1, 5, 7, 64, 1999])
    u.andnot(g)
    np.testing.assert_array_equal(u.to_ids(), [1, 64, 1999])
    u.and_(Frontier.from_ids(np.array([64]), N))
    np.testing.assert_array_equal(u.to_ids(), [64])
    with pytest.raises(ValueError):
        f.or_(Frontier.from_ids(np.array([0]), N + 1))


def test_frontier_pac_and_device_mirror():
    ids = np.array([0, 31, 32, 255, 256])
    f = Frontier.from_ids(ids, 512)
    pac = f.to_pac(64)
    np.testing.assert_array_equal(pac.to_ids(), ids)
    p1 = f.device_plane("jax")
    assert f.device_plane("jax") is p1              # cached per engine
    assert f.device_stats()["transfers"] == 1
    np.testing.assert_array_equal(np.flatnonzero(np.asarray(p1)), ids)
    f.set_ids(np.array([7]))
    assert f.device_plane("jax") is not p1          # mutation invalidates


# ------------------------------- serving tie -------------------------------

def test_retriever_deep_context_pool():
    from repro.serve.retrieval import GraphRetriever
    from repro.core.table import TokensColumn
    src = np.array([0, 1, 2, 3])
    dst = np.array([1, 2, 3, 4])
    adj = build_adjacency(src, dst, 6, 6, BY_SRC, ENC_GRAPHAR,
                          page_size=32)
    tokens = TokensColumn("tokens",
                          [np.arange(4, dtype=np.int32) + 10 * i
                           for i in range(6)], page_size=32)
    deep = GraphRetriever(adj, tokens, max_neighbors=3,
                          tokens_per_neighbor=4, engine="jax", hops=2)
    ctx = deep(np.array([0]))
    # 1-hop neighbor 1 first, then the hop-2 discovery (vertex 2) fills
    # the spare slot from the shared pool
    np.testing.assert_array_equal(ctx[0], np.concatenate(
        [tokens.get(1)[:4], tokens.get(2)[:4]]))
    s = deep.stats()
    assert s["traversal"]["hops_fused"] >= 2
    assert s["traversal"]["deep_pool_last"] == 2    # vertices 1 and 2


def test_retriever_stats_surface_traversal_counters():
    from repro.serve.retrieval import GraphRetriever
    from repro.core.table import TokensColumn
    src, dst = _edges()
    adj = build_adjacency(src, dst, N, N, BY_SRC, ENC_GRAPHAR,
                          page_size=PAGE)
    tokens = TokensColumn("tokens",
                          [np.arange(4, dtype=np.int32)] * N,
                          page_size=PAGE)
    r = GraphRetriever(adj, tokens, max_neighbors=4, engine="jax", hops=2)
    r(np.array([3, 17]))
    s = r.stats()
    assert s["traversal"]["hops"] == 2
    assert s["traversal"]["dispatches"] >= 1
    assert s["traversal"]["traversal_device_roundtrips"] >= 1
    assert len(s["traversal"]["frontier_sizes"]) == 2
