"""Table/PAC/storage-container behaviour tests."""
import os

import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core import (PAC, BoolRleColumn, DeltaIntColumn, GraphStore,
                        IOMeter, PlainColumn, StringColumn, Table,
                        TokensColumn, bitmap_to_ids, ids_to_bitmap)
from repro.core.storage import ESSD, OSS, TMPFS, read_table, write_table


def test_pac_from_ids_roundtrip():
    ids = np.array([3, 5, 2047, 2048, 2049, 10_000], np.int64)
    pac = PAC.from_ids(ids, page_size=2048)
    assert pac.pages() == [0, 1, 4]
    np.testing.assert_array_equal(pac.to_ids(), ids)
    assert pac.count() == len(ids)


def test_pac_set_algebra():
    a = PAC.from_ids(np.array([1, 2, 3, 5000]), 2048)
    b = PAC.from_ids(np.array([2, 3, 4, 9000]), 2048)
    np.testing.assert_array_equal(a.intersect(b).to_ids(), [2, 3])
    np.testing.assert_array_equal(a.union(b).to_ids(),
                                  [1, 2, 3, 4, 5000, 9000])
    np.testing.assert_array_equal(a.difference(b).to_ids(), [1, 5000])


def test_pac_from_intervals():
    pac = PAC.from_intervals(np.array([10, 4000]), np.array([20, 4100]),
                             n=10_000, page_size=2048)
    ids = pac.to_ids()
    expect = np.concatenate([np.arange(10, 20), np.arange(4000, 4100)])
    np.testing.assert_array_equal(ids, expect)


def test_pac_select_pushdown():
    vals = {0: np.arange(2048) * 10, 2: np.arange(2048) * 100}
    pac = PAC(2048, {0: ids_to_bitmap(np.array([5, 7]), 0, 2048),
                     2: ids_to_bitmap(np.array([4096 + 9]), 4096, 2048)})
    out = pac.select(vals)
    np.testing.assert_array_equal(out, [50, 70, 900])


@given(st.lists(st.integers(min_value=0, max_value=100_000), min_size=1,
                max_size=300, unique=True))
@settings(max_examples=50, deadline=None)
def test_pac_roundtrip_property(ids):
    ids = np.sort(np.array(ids, np.int64))
    pac = PAC.from_ids(ids, page_size=512)
    np.testing.assert_array_equal(pac.to_ids(), ids)
    assert pac.count() == len(ids)


def test_iometer_media_model():
    m = IOMeter()
    m.record(180e6, 1)  # one request of 180 MB
    assert abs(m.seconds(ESSD) - (1e-4 + 1.0)) < 1e-6
    assert m.seconds(TMPFS) < m.seconds(ESSD) < m.seconds(OSS)


def test_plain_column_page_reads_metered():
    col = PlainColumn("x", np.arange(10_000, dtype=np.int32), page_size=1024)
    meter = IOMeter()
    out = col.read_range(100, 200, meter)
    np.testing.assert_array_equal(out, np.arange(100, 200))
    assert meter.nbytes == 1024 * 4  # one whole page


def test_delta_column_read_is_cheaper_than_plain():
    rng = np.random.default_rng(0)
    ids = np.sort(rng.integers(0, 1 << 22, size=100_000))
    plain = PlainColumn("x", ids.astype(np.int32), 2048)
    delta = DeltaIntColumn("x", ids, 2048)
    mp, md = IOMeter(), IOMeter()
    np.testing.assert_array_equal(plain.read_range(5000, 6000, mp),
                                  delta.read_range(5000, 6000, md))
    assert md.nbytes < mp.nbytes


def test_table_container_roundtrip(tmp_path):
    n = 5000
    rng = np.random.default_rng(1)
    t = Table("t", n, 1024)
    t.add(PlainColumn("a", rng.standard_normal(n).astype(np.float32), 1024))
    t.add(DeltaIntColumn("ids", np.sort(rng.integers(0, 1 << 20, n)), 1024))
    t.add(BoolRleColumn("<L>", rng.random(n) < 0.2, 1024))
    t.add(StringColumn("s", [f"row{i}" for i in range(n)], 1024))
    t.add(TokensColumn("toks", [np.arange(i % 7) for i in range(n)], 1024))
    path = os.path.join(tmp_path, "t.gar")
    write_table(t, path)
    t2 = read_table(path)
    assert t2.num_rows == n
    np.testing.assert_allclose(t2["a"].read_all(), t["a"].read_all())
    np.testing.assert_array_equal(t2["ids"].read_all(), t["ids"].read_all())
    np.testing.assert_array_equal(t2["<L>"].read_all(), t["<L>"].read_all())
    assert t2["s"].get(42) == "row42"
    np.testing.assert_array_equal(t2["toks"].get(13), np.arange(13 % 7))


def test_graph_store_lists(tmp_path):
    store = GraphStore(str(tmp_path))
    t = Table("edges", 10, 4)
    t.add(PlainColumn("<src>", np.arange(10, dtype=np.int32), 4))
    store.write(t)
    assert store.list_tables() == ["edges"]
    got = store.read("edges")
    np.testing.assert_array_equal(got["<src>"].read_all(), np.arange(10))
