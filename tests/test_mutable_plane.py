"""Mutable graph plane: delta-segment ingest unioned with the packed base.

The invariant every test here leans on: a graph serving with pending
delta rows must return ids **bit-identical** to a from-scratch rebuild
over base + deltas, on every engine, for every read path (batched
neighbors, PAC retrieval, filtered retrieval, k-hop) -- and the IOMeter
footprint must be identical across engines while deltas are pending
(delta reads are RAM-resident and charge no lake I/O, mirroring the
decoded-page LRU's hit convention).
"""
import numpy as np
import pytest

from _engines import engines
from repro.core import (BY_SRC, ENC_GRAPHAR, IOMeter, L, LabelFilter,
                        build_adjacency, k_hop, neighbor_ids_batch,
                        pack_column, retrieve_neighbors_batch)
from repro.core.delta_segment import (attach_delta, all_edges, base_edges,
                                      ingest_edges, live_delta)
from repro.core.schema import PropertySchema, VertexTypeSchema
from repro.core.table import TokensColumn
from repro.core.vertex import VertexTable
from repro.data.synthetic import clustered_labels, powerlaw_graph
from repro.ft.faults import FaultPlan, InjectedFault

N = 600
NVAL = 500
PAGE = 128
TPS = 512


def _graph(seed=3, n_edges=4000):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, N, n_edges)
    dst = rng.integers(0, NVAL, n_edges)
    return build_adjacency(src, dst, N, NVAL, BY_SRC, ENC_GRAPHAR,
                           page_size=PAGE)


def _ingest_some(adj, seed=11, rows=150):
    rng = np.random.default_rng(seed)
    ingest_edges(adj, rng.integers(0, N, rows), rng.integers(0, NVAL, rows))


def _rebuilt(adj):
    """From-scratch oracle over base + pending deltas."""
    return build_adjacency(*all_edges(adj), N, NVAL, BY_SRC, ENC_GRAPHAR,
                           page_size=PAGE)


@pytest.fixture()
def batch():
    rng = np.random.default_rng(5)
    vs = rng.integers(0, N, 48)
    return np.concatenate([vs, vs[:7]])         # duplicates included


# ------------------------- union == rebuild ------------------------------

@pytest.mark.parametrize("engine", engines())
def test_neighbor_union_matches_rebuild(batch, engine):
    adj = _graph()
    _ingest_some(adj)
    oracle = _rebuilt(adj)
    for unique in (True, False):
        got = neighbor_ids_batch(adj, batch, engine=engine, unique=unique)
        want = neighbor_ids_batch(oracle, batch, engine="numpy",
                                  unique=unique)
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("engine", engines())
def test_pac_retrieval_union_matches_rebuild(batch, engine):
    adj = _graph()
    _ingest_some(adj)
    oracle = _rebuilt(adj)
    got = retrieve_neighbors_batch(adj, batch, TPS, engine=engine)
    want = retrieve_neighbors_batch(oracle, batch, TPS, engine="numpy")
    np.testing.assert_array_equal(got.to_ids(), want.to_ids())


@pytest.mark.parametrize("engine", engines())
def test_filtered_retrieval_union_matches_rebuild(batch, engine):
    adj = _graph()
    _ingest_some(adj)
    oracle = _rebuilt(adj)
    labels = clustered_labels(NVAL, ["A", "B"], density=0.3, run_scale=32,
                              seed=9)
    vt = VertexTable.build(
        VertexTypeSchema("v", [PropertySchema("x", "int64")],
                         labels=["A", "B"], page_size=PAGE),
        {"x": np.arange(NVAL)}, labels, num_vertices=NVAL)
    filt = LabelFilter(vt, L("A") & ~L("B"))
    got = retrieve_neighbors_batch(adj, batch, TPS, engine=engine,
                                   filter=filt)
    want = retrieve_neighbors_batch(oracle, batch, TPS, engine="numpy",
                                    filter=LabelFilter(vt, L("A") & ~L("B")))
    np.testing.assert_array_equal(got.to_ids(), want.to_ids())


@pytest.mark.parametrize("engine", engines())
def test_k_hop_union_matches_rebuild(engine):
    # value ids must be valid seeds for hop 2: use a square graph
    rng = np.random.default_rng(21)
    adj = build_adjacency(rng.integers(0, N, 4000),
                          rng.integers(0, N, 4000), N, N, BY_SRC,
                          ENC_GRAPHAR, page_size=PAGE)
    ingest_edges(adj, rng.integers(0, N, 120), rng.integers(0, N, 120))
    oracle = build_adjacency(*all_edges(adj), N, N, BY_SRC, ENC_GRAPHAR,
                             page_size=PAGE)
    seeds = rng.integers(0, N, 9)
    for k in (1, 2, 3):
        got = k_hop(adj, seeds, k, engine=engine)
        want = k_hop(oracle, seeds, k, engine="numpy")
        np.testing.assert_array_equal(got, want)


def test_fused_traversal_degrades_on_pending_deltas():
    """A direct fused-traversal call under pending deltas must not
    error mid-ingest: it degrades to the bit-identical host-loop oracle
    and counts the fallback in the traversal stats."""
    from repro.kernels.traversal.ops import k_hop_fused, plan_supported, \
        traversal_stats
    rng = np.random.default_rng(2)
    adj = build_adjacency(rng.integers(0, N, 2000),
                          rng.integers(0, N, 2000), N, N, BY_SRC,
                          ENC_GRAPHAR, page_size=PAGE)
    assert plan_supported(adj)
    ingest_edges(adj, [1], [2])
    got = k_hop_fused(adj, np.arange(4), 2, [None, None], engine="jax")
    oracle = build_adjacency(*all_edges(adj), N, N, BY_SRC, ENC_GRAPHAR,
                             page_size=PAGE)
    np.testing.assert_array_equal(
        got, k_hop(oracle, np.arange(4), 2, engine="numpy"))
    assert traversal_stats(adj)["fallbacks"] >= 1


# --------------------- accounting under pending writes -------------------

@pytest.mark.parametrize("engine", engines())
def test_meter_identical_across_engines_while_pending(batch, engine):
    """Delta reads are RAM-resident: the lake footprint under pending
    writes is exactly the base footprint, identical on every engine."""
    adj_np = _graph()
    _ingest_some(adj_np)
    adj_e = _graph()
    _ingest_some(adj_e)
    m_np, m_e = IOMeter(), IOMeter()
    neighbor_ids_batch(adj_np, batch, m_np, engine="numpy")
    neighbor_ids_batch(adj_e, batch, m_e, engine=engine)
    assert (m_e.nbytes, m_e.nrequests) == (m_np.nbytes, m_np.nrequests)


def test_zone_maps_prune_segments():
    adj = _graph()
    # two far-apart value bands land in disjoint segment hulls
    ingest_edges(adj, np.arange(40), np.zeros(40, np.int64))
    d = live_delta(adj)
    before = d.segments_pruned
    # a qualifying range far above every ingested value prunes all
    ids = d.unique_ids(np.arange(40), qual=(NVAL - 2, NVAL - 1))
    assert ids.size == 0
    assert d.segments_pruned > before


# ----------------------------- ingest semantics --------------------------

def test_ingest_atomicity_under_fault():
    """A crash mid-append publishes nothing; the retry applies the batch
    exactly once (stage-then-publish, no half/double-apply)."""
    adj = _graph()
    plan = FaultPlan({"ingest.append": 1})
    d = attach_delta(adj, faults=plan)
    src = np.asarray([1, 2, 3, 1], np.int64)
    dst = np.asarray([4, 5, 6, 4], np.int64)
    with pytest.raises(InjectedFault):
        d.ingest(src, dst)
    assert d.pending_rows() == 0 and live_delta(adj) is None
    d.ingest(src, dst)                           # retry: exactly once
    assert d.pending_rows() == 4
    vals, lens = d.lookup_batch(np.asarray([1], np.int64))
    np.testing.assert_array_equal(vals, [4, 4])


def test_ingest_validates_bounds():
    adj = _graph()
    d = attach_delta(adj)
    with pytest.raises(ValueError):
        d.ingest([N + 5], [0])
    with pytest.raises(ValueError):
        d.ingest([0], [NVAL + 5])
    assert d.pending_rows() == 0


def test_write_once_path_untouched_until_first_ingest():
    adj = _graph()
    assert live_delta(adj) is None
    attach_delta(adj)
    assert live_delta(adj) is None               # attached but empty
    ingest_edges(adj, [0], [0])
    assert live_delta(adj) is not None


def test_all_edges_roundtrip():
    adj = _graph()
    b = base_edges(adj)
    _ingest_some(adj, rows=17)
    s, t = all_edges(adj)
    assert s.size == b[0].size + 17 and t.size == b[1].size + 17


# ------------------- poisoned mirror: degrade + heal ---------------------

@pytest.mark.parametrize("engine", engines(kernel_only=True))
def test_poisoned_mirror_falls_back_to_host_oracle(batch, engine):
    adj = _graph()
    oracle = _rebuilt(adj)
    col = adj.table[adj.value_col].encoded
    # materialize the device mirror, then poison it
    neighbor_ids_batch(adj, batch, engine=engine)
    packed = col.packed_cache
    assert packed is not None
    packed.poison()
    got = neighbor_ids_batch(adj, batch, engine=engine)
    want = neighbor_ids_batch(oracle, batch, engine="numpy")
    np.testing.assert_array_equal(got, want)
    assert packed.fallbacks > 0
    assert packed.device_stats()["poisoned"] is True
    # heal: any version bump rebuilds a clean mirror
    ingest_edges(adj, [0], [0])
    oracle2 = _rebuilt(adj)
    got2 = neighbor_ids_batch(adj, batch, engine=engine)
    np.testing.assert_array_equal(
        got2, neighbor_ids_batch(oracle2, batch, engine="numpy"))


# ------------------------- serve-plane integration -----------------------

@pytest.mark.parametrize("engine", engines())
def test_retriever_serves_ingested_edges(engine):
    from repro.serve.retrieval import GraphRetriever
    rng = np.random.default_rng(33)
    adj = _graph()
    tok = TokensColumn("tokens",
                       [rng.integers(0, 99, 6).astype(np.int32)
                        for _ in range(NVAL)], PAGE)
    r = GraphRetriever(adj, tok, max_neighbors=3, engine=engine)
    vs = rng.integers(0, N, 16)
    r(vs)                                        # warm, write-once tick
    r.ingest(rng.integers(0, N, 60), rng.integers(0, NVAL, 60))
    oracle = _rebuilt(adj)
    r2 = GraphRetriever(oracle, tok, max_neighbors=3, engine="numpy")
    got, want = r(vs), r2(vs)
    assert len(got) == len(want)
    for a, b in zip(got, want):
        np.testing.assert_array_equal(a, b)
    mut = r.stats()["mutable"]
    assert mut["ingest_calls"] == 1 and mut["ingest_rows"] == 60
    assert mut["pending_rows"] == 60


def test_serve_engine_ingest_forwarder():
    from repro.serve.engine import ServeEngine

    class _Ctx:
        def __init__(self):
            self.got = None

        def __call__(self, vs):
            return [np.zeros(0, np.int32)] * len(vs)

        def ingest(self, src, dst):
            self.got = (list(src), list(dst))
            return "delta"

    class _LM:
        def init_cache(self, *a, **k):
            return {}

    eng = ServeEngine.__new__(ServeEngine)
    eng.context_fn = _Ctx()
    assert eng.ingest([1, 2], [3, 4]) == "delta"
    assert eng.context_fn.got == ([1, 2], [3, 4])
    eng.context_fn = None
    with pytest.raises(ValueError, match="ingest-capable"):
        eng.ingest([1], [2])
