"""Gradient compression (error feedback) + 1F1B pipeline schedule tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.collectives import (compress_with_feedback,
                                           compressed_bytes, decompress,
                                           init_error_feedback)
from repro.distributed.pipeline import (bubble_fraction, run_pipelined,
                                        schedule_1f1b)


# --------------------------- compression -----------------------------------

def test_compression_roundtrip_accuracy():
    rng = np.random.default_rng(0)
    grads = {"w": jnp.asarray(rng.standard_normal((64, 300)), jnp.float32),
             "b": jnp.asarray(rng.standard_normal(7), jnp.float32)}
    err = init_error_feedback(grads)
    comp, err = compress_with_feedback(grads, err)
    approx = decompress(comp, grads)
    for k in grads:
        rel = float(jnp.abs(approx[k] - grads[k]).max()
                    / jnp.abs(grads[k]).max())
        assert rel < 0.02, f"{k}: {rel}"


def test_compression_saves_bytes():
    grads = {"w": jnp.ones((1024, 1024), jnp.float32)}
    comp, _ = compress_with_feedback(grads, init_error_feedback(grads))
    raw = 1024 * 1024 * 4
    assert compressed_bytes(comp) < 0.35 * raw  # int8 + scales < 35% of f32


def test_error_feedback_removes_bias():
    """Accumulated compressed gradients converge to the true sum --
    error feedback carries what quantization dropped."""
    rng = np.random.default_rng(1)
    true_sum = np.zeros(512, np.float32)
    acc = np.zeros(512, np.float32)
    grads_err = init_error_feedback({"g": jnp.zeros(512)})
    err = grads_err
    for step in range(50):
        g = rng.standard_normal(512).astype(np.float32) * 1e-3
        true_sum += g
        comp, err = compress_with_feedback({"g": jnp.asarray(g)}, err)
        acc += np.asarray(decompress(comp, {"g": jnp.zeros(512)})["g"])
    # without feedback, tiny grads would quantize to ~zero every step
    rel = np.abs(acc - true_sum).max() / np.abs(true_sum).max()
    assert rel < 0.05, rel


# ----------------------------- pipeline -------------------------------------

@pytest.mark.parametrize("s,m", [(2, 4), (4, 8), (4, 2), (3, 3)])
def test_schedule_1f1b_invariants(s, m):
    timeline = schedule_1f1b(s, m)
    fwd_t = {}
    bwd_t = {}
    for ts, ticks in enumerate(timeline):
        stages = [t.stage for t in ticks]
        assert len(stages) == len(set(stages))  # one op per stage per tick
        for t in ticks:
            key = (t.stage, t.micro)
            if t.phase == "fwd":
                assert key not in fwd_t
                fwd_t[key] = ts
            else:
                assert key not in bwd_t
                bwd_t[key] = ts
    assert len(fwd_t) == s * m and len(bwd_t) == s * m
    for (st, mi), ts in fwd_t.items():
        if st + 1 < s:
            assert fwd_t[(st + 1, mi)] > ts          # fwd flows down
        assert bwd_t[(st, mi)] > ts                  # bwd after fwd
        if st + 1 < s:
            assert bwd_t[(st, mi)] > bwd_t[(st + 1, mi)]  # bwd flows up


def test_bubble_fraction_shrinks_with_microbatches():
    b2 = bubble_fraction(4, 4)
    b8 = bubble_fraction(4, 16)
    assert b8 < b2 < 0.6


def test_run_pipelined_matches_sequential():
    stages = [lambda x, i=i: x * 2 + i for i in range(4)]
    micro = [jnp.asarray(float(m)) for m in range(6)]
    got = run_pipelined(stages, micro)
    for m, x in enumerate(micro):
        want = x
        for f in stages:
            want = f(want)
        assert float(got[m]) == float(want)
