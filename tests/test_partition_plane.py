"""Partition plane: explicit graph partitions + sharded batched retrieval.

The invariant under test everywhere: partitioning is *invisible* except
in placement, pruning counters, and wall time.  Sharded retrieval over
any partition count must return bit-identical ids and IOMeter accounting
to the single-device resident path (and the numpy oracle), the
1-partition case must reduce to the monolithic PR 4 path outright, and
statistics pruning may only ever *remove* charged I/O while leaving ids
untouched.

Runs on any device count: under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the multi-device
CI job) the SPMD tail executes across a real mesh; on one device the
degenerate single-shard tail covers the same interfaces.  Forced-SPMD
tests pin ``SHARD_MIN_PAGES`` to 0 so the ``shard_map`` path runs even
for small dispatches.
"""
import numpy as np
import pytest

from _engines import engines
from _hypothesis_shim import given, settings, st
from repro.core import (BY_SRC, ENC_GRAPHAR, IOMeter, L, LabelFilter,
                        attach_page_cache, build_adjacency, k_hop,
                        live_partitions, pack_column, partition_bounds,
                        partition_column, retrieve_neighbors_batch)
from repro.core.encoding import delta_encode_column, delta_encode_page
from repro.core.page_cache import DecodedPageCache
from repro.core.schema import VertexTypeSchema
from repro.core.vertex import VertexTable
from repro.data.synthetic import clustered_labels, powerlaw_graph
from repro.kernels import _pad
from repro.kernels.pac_decode import ops as pdo

N = 2000
PAGE = 256
TPS = 512
PART_COUNTS = (1, 2, 3, 8)


def _graph():
    return powerlaw_graph(N, 6, seed=13)


@pytest.fixture(scope="module")
def adj_pair():
    """(monolithic, partition-ready) adjacencies over the same edges."""
    src, dst = _graph()
    mono = build_adjacency(src, dst, N, N, BY_SRC, ENC_GRAPHAR,
                           page_size=PAGE)
    part = build_adjacency(src, dst, N, N, BY_SRC, ENC_GRAPHAR,
                           page_size=PAGE)
    return mono, part


@pytest.fixture(scope="module")
def vt():
    labels = clustered_labels(N, ["A", "B"], density=0.3, run_scale=64,
                              seed=7)
    return VertexTable.build(VertexTypeSchema("v", [], labels=["A", "B"]),
                             {}, labels, num_vertices=N)


@pytest.fixture
def forced_spmd(monkeypatch):
    """Force the shard_map tail regardless of dispatch size."""
    monkeypatch.setattr(pdo, "SHARD_MIN_PAGES", 0)


def _set_parts(adj, n):
    partition_column(adj.table["<dst>"].encoded, n)


# ------------------------------- construction ------------------------------

def test_partition_bounds_even_split():
    np.testing.assert_array_equal(partition_bounds(10, 4), [0, 3, 6, 9, 10])
    np.testing.assert_array_equal(partition_bounds(8, 2), [0, 4, 8])
    b = partition_bounds(3, 8)              # more partitions than pages
    assert b[-1] == 3 and b[0] == 0 and np.all(np.diff(b) >= 0)


def test_partitions_cover_column_and_record_stats():
    vals = np.sort(np.random.default_rng(0).integers(0, 1 << 20,
                                                     5 * PAGE + 37))
    col = delta_encode_column(vals, PAGE)
    parts = partition_column(col, 3)
    assert parts.n_parts == 3
    assert int(parts.bounds[-1]) == len(col.pages)
    covered = 0
    for p in parts.parts:
        assert p.packed.n_pages == p.page_hi - p.page_lo
        covered += p.n_pages
        # per-partition value hull matches the decoded slice
        lo, hi = p.row_lo, min(p.row_hi, len(vals))
        if hi > lo:
            assert p.vmin == int(vals[lo:hi].min())
            assert p.vmax == int(vals[lo:hi].max())
    assert covered == len(col.pages)


def test_pack_column_records_page_minmax():
    vals = np.sort(np.random.default_rng(1).integers(0, 1 << 20,
                                                     3 * PAGE + 11))
    col = delta_encode_column(vals, PAGE)
    packed = pack_column(col)
    for i, pg in enumerate(col.pages):
        s, e = i * PAGE, min((i + 1) * PAGE, len(vals))
        assert packed.page_min[i] == int(vals[s:e].min())
        assert packed.page_max[i] == int(vals[s:e].max())


def test_single_partition_detaches_to_monolithic():
    vals = np.sort(np.random.default_rng(2).integers(0, 1 << 20, 2 * PAGE))
    col = delta_encode_column(vals, PAGE)
    partition_column(col, 4)
    assert live_partitions(col) is not None
    assert partition_column(col, 1) is None     # the PR 4 path IS 1 partition
    assert live_partitions(col) is None and col.partitions == 0


def test_partition_cache_rebuilds_on_version_bump():
    vals = np.sort(np.random.default_rng(3).integers(0, 1 << 20,
                                                     3 * PAGE + 17))
    col = delta_encode_column(vals, PAGE)
    parts = partition_column(col, 3)
    new_tail = np.sort(np.random.default_rng(4).integers(0, 1 << 20, 17))
    col.set_page(len(col.pages) - 1, delta_encode_page(new_tail))
    fresh = live_partitions(col)
    assert fresh is not parts                   # keyed on the write counter
    assert fresh.version == col.version
    last = len(col.pages) - 1
    k = int(fresh.part_of_pages(np.array([last]))[0])
    local = last - int(fresh.bounds[k])
    assert fresh.parts[k].packed.page_min[local] == int(new_tail.min())


def test_mesh_size_is_largest_divisor():
    vals = np.sort(np.random.default_rng(5).integers(0, 1 << 20, 8 * PAGE))
    col = delta_encode_column(vals, PAGE)
    parts = partition_column(col, 6)
    assert parts.mesh_size(1) == 1
    assert parts.mesh_size(2) == 2
    assert parts.mesh_size(4) == 3              # largest divisor of 6 <= 4
    assert parts.mesh_size(8) == 6
    assert parts.stack_rows == 6 * parts.pmax


# ----------------- sharded == single-device resident == oracle -------------

@pytest.mark.parametrize("engine", engines())
@pytest.mark.parametrize("n_parts", PART_COUNTS)
def test_sharded_bit_identical_to_resident(adj_pair, engine, n_parts):
    mono, part = adj_pair
    _set_parts(part, n_parts)
    vs = np.random.default_rng(17).integers(0, N, 64)
    kw = {} if engine == "numpy" else dict(fused=True, resident=True)
    m_mono, m_part = IOMeter(), IOMeter()
    want = retrieve_neighbors_batch(mono, vs, TPS, m_mono, engine=engine,
                                    **kw)
    got = retrieve_neighbors_batch(part, vs, TPS, m_part, engine=engine,
                                   **kw)
    assert got == want
    np.testing.assert_array_equal(got.to_ids(), want.to_ids())
    assert (m_part.nbytes, m_part.nrequests) == (m_mono.nbytes,
                                                 m_mono.nrequests)


@pytest.mark.parametrize("engine", engines(kernel_only=True))
@given(seed=st.integers(0, 2**32 - 1),
       n_parts=st.sampled_from(PART_COUNTS),
       size=st.integers(1, 96))
@settings(max_examples=12, deadline=None)
def test_sharded_property_random_batches(adj_pair, forced_spmd, engine,
                                         seed, n_parts, size):
    """Satellite: hypothesis property -- sharded retrieval over random
    partition counts and random batches is bit-identical (ids + IOMeter)
    to the single-device resident path."""
    mono, part = adj_pair
    rng = np.random.default_rng(seed)
    vs = rng.integers(0, N, size)
    _set_parts(part, n_parts)
    m_mono, m_part = IOMeter(), IOMeter()
    want = retrieve_neighbors_batch(mono, vs, TPS, m_mono, engine=engine,
                                    fused=True, resident=True)
    got = retrieve_neighbors_batch(part, vs, TPS, m_part, engine=engine,
                                   fused=True, resident=True)
    assert got == want
    assert (m_part.nbytes, m_part.nrequests) == (m_mono.nbytes,
                                                 m_mono.nrequests)


@pytest.mark.parametrize("engine", engines(kernel_only=True))
@pytest.mark.parametrize("n_parts", (2, 8))
def test_sharded_filtered_bit_identical(adj_pair, vt, engine, n_parts,
                                        forced_spmd):
    mono, part = adj_pair
    _set_parts(part, n_parts)
    vs = np.random.default_rng(23).integers(0, N, 64)
    cond = L("A") | ~L("B")
    m_mono, m_part = IOMeter(), IOMeter()
    want = retrieve_neighbors_batch(mono, vs, TPS, m_mono, engine=engine,
                                    fused=True, resident=True,
                                    filter=LabelFilter(vt, cond))
    got = retrieve_neighbors_batch(part, vs, TPS, m_part, engine=engine,
                                   fused=True, resident=True,
                                   filter=LabelFilter(vt, cond))
    assert got == want
    # ~L("B") qualifies ids across the whole range, so the hull prunes
    # nothing and the meters stay bit-identical
    assert (m_part.nbytes, m_part.nrequests) == (m_mono.nbytes,
                                                 m_mono.nrequests)


@pytest.mark.parametrize("engine", engines(kernel_only=True))
def test_khop_routes_through_partitions(adj_pair, engine, forced_spmd):
    mono, part = adj_pair
    _set_parts(part, 3)
    seeds = np.random.default_rng(29).integers(0, N, 8)
    np.testing.assert_array_equal(k_hop(mono, seeds, 2, engine=engine),
                                  k_hop(part, seeds, 2, engine=engine))
    parts = live_partitions(part.table["<dst>"].encoded)
    assert parts.dispatches > 0                 # decode went through the plane


# ------------------------------ decoded-page LRU ---------------------------

@pytest.mark.parametrize("engine", engines(kernel_only=True))
def test_warm_lru_charges_nothing_and_keys_by_partition(adj_pair, engine,
                                                        forced_spmd):
    _, part = adj_pair
    _set_parts(part, 2)
    col = part.table["<dst>"]
    cache = attach_page_cache(col, 4096)
    try:
        cache.clear()
        vs = np.random.default_rng(31).integers(0, N, 64)
        p1 = retrieve_neighbors_batch(part, vs, TPS, engine=engine,
                                      fused=True, resident=True)
        m_warm = IOMeter()
        p2 = retrieve_neighbors_batch(part, vs, TPS, m_warm, engine=engine,
                                      fused=True, resident=True)
        assert p1 == p2
        m_off = IOMeter()
        part.edge_ranges_batch(vs, m_off)
        assert (m_warm.nbytes, m_warm.nrequests) == (m_off.nbytes,
                                                     m_off.nrequests)
        # entries are namespaced (partition, page)
        keys = list(cache._pages)
        assert keys and all(isinstance(k, tuple) and len(k) == 2
                            for k in keys)
        parts = live_partitions(col.encoded)
        for k, p in keys:
            assert parts.bounds[k] <= p < parts.bounds[k + 1]
    finally:
        col.encoded.page_cache = None


def test_page_cache_partition_namespace_isolated():
    cache = DecodedPageCache(8)
    cache.put(3, np.array([1]), part=0)
    cache.put(3, np.array([2]), part=1)
    cache.put(3, np.array([3]))
    assert cache.get(3, part=0)[0] == 1
    assert cache.get(3, part=1)[0] == 2
    assert cache.get(3)[0] == 3


# --------------------------- statistics pushdown ---------------------------

def _local_ring(n):
    """Perfectly local graph: partition value hulls track src ranges."""
    src = np.repeat(np.arange(n), 2)
    dst = np.stack([np.arange(n), (np.arange(n) + 1) % n], 1).ravel()
    return src, dst


@pytest.mark.parametrize("engine", engines(kernel_only=True))
def test_stats_pruning_skips_partitions_and_reduces_io(engine):
    n = 2048
    src, dst = _local_ring(n)
    labels = {"A": np.arange(n) < n // 4}
    lvt = VertexTable.build(VertexTypeSchema("v", [], labels=["A"]), {},
                            labels, num_vertices=n)
    mono = build_adjacency(src, dst, n, n, BY_SRC, ENC_GRAPHAR,
                           page_size=PAGE)
    part = build_adjacency(src, dst, n, n, BY_SRC, ENC_GRAPHAR,
                           page_size=PAGE)
    _set_parts(part, 8)
    vs = np.arange(0, n, 7)
    m_none, m_mono, m_part = IOMeter(), IOMeter(), IOMeter()
    # unpruned baseline: same retrieval, no predicate pushed down
    retrieve_neighbors_batch(mono, vs, TPS, m_none, engine=engine,
                             fused=True, resident=True)
    want = retrieve_neighbors_batch(mono, vs, TPS, m_mono, engine=engine,
                                    fused=True, resident=True,
                                    filter=LabelFilter(lvt, L("A")))
    got = retrieve_neighbors_batch(part, vs, TPS, m_part, engine=engine,
                                   fused=True, resident=True,
                                   filter=LabelFilter(lvt, L("A")))
    assert got == want                          # pruning never changes ids
    parts = live_partitions(part.table["<dst>"].encoded)
    assert parts.stats_pruned > 0
    # page-granular zone maps refine the partition hulls to the *same*
    # final page set on both layouts (partition-pruned pages are a subset
    # of page-pruned ones), so the filtered meters agree -- and both beat
    # the unpruned baseline
    assert m_part.nbytes == m_mono.nbytes
    assert m_mono.nbytes < m_none.nbytes
    assert mono.table["<dst>"].encoded.prune_stats.pages_pruned > 0


def test_filter_qual_range_matches_host_intervals(vt):
    filt = LabelFilter(vt, L("A") | ~L("B"))
    starts, ends = filt.intervals("numpy")
    lo, hi = filt.qual_range()
    assert (lo, hi) == (int(starts[0]), int(ends[-1]))


def test_stats_pruning_everything_yields_empty_pac(vt):
    n = 2048
    src, dst = _local_ring(n)
    labels = {"Z": np.zeros(n, bool)}           # nothing qualifies
    lvt = VertexTable.build(VertexTypeSchema("v", [], labels=["Z"]), {},
                            labels, num_vertices=n)
    part = build_adjacency(src, dst, n, n, BY_SRC, ENC_GRAPHAR,
                           page_size=PAGE)
    _set_parts(part, 4)
    got = retrieve_neighbors_batch(part, np.arange(0, n, 9), TPS,
                                   engine="jax", fused=True, resident=True,
                                   filter=LabelFilter(lvt, L("Z")))
    assert got.count() == 0


def test_page_stats_survive_serialization(tmp_path):
    from repro.core.storage import read_table, write_table
    n = 2048
    src, dst = _local_ring(n)
    adj = build_adjacency(src, dst, n, n, BY_SRC, ENC_GRAPHAR,
                          page_size=PAGE)
    path = str(tmp_path / "edges.gar")
    write_table(adj.table, path)
    rt = read_table(path)
    col = rt["<dst>"].encoded
    for orig, back in zip(adj.table["<dst>"].encoded.pages, col.pages):
        assert (back.vmin, back.vmax) == (orig.vmin, orig.vmax)
    parts = partition_column(col, 4)
    assert all(p.stats_known for p in parts.parts)


def test_unknown_page_stats_never_prune():
    """A column whose pages carry no value stats (e.g. deserialized from
    a pre-stats file) must disable hull pruning, not prune everything."""
    n = 2048
    src, dst = _local_ring(n)
    labels = {"A": np.arange(n) < n // 4}
    lvt = VertexTable.build(VertexTypeSchema("v", [], labels=["A"]), {},
                            labels, num_vertices=n)
    mono = build_adjacency(src, dst, n, n, BY_SRC, ENC_GRAPHAR,
                           page_size=PAGE)
    part = build_adjacency(src, dst, n, n, BY_SRC, ENC_GRAPHAR,
                           page_size=PAGE)
    for pg in part.table["<dst>"].encoded.pages:
        pg.vmin, pg.vmax = 0, -1            # simulate a pre-stats file
    parts = partition_column(part.table["<dst>"].encoded, 8)
    assert not any(p.stats_known for p in parts.parts)
    vs = np.arange(0, n, 7)
    want = retrieve_neighbors_batch(mono, vs, TPS, engine="jax",
                                    fused=True, resident=True,
                                    filter=LabelFilter(lvt, L("A")))
    got = retrieve_neighbors_batch(part, vs, TPS, engine="jax",
                                   fused=True, resident=True,
                                   filter=LabelFilter(lvt, L("A")))
    assert got == want
    assert parts.stats_pruned == 0


# --------------------------- dispatch-cost plane ---------------------------

@pytest.mark.parametrize("engine", engines(kernel_only=True))
def test_sharded_steady_state_does_not_retrace(adj_pair, engine,
                                               forced_spmd):
    _, part = adj_pair
    _set_parts(part, 2)
    rng = np.random.default_rng(37)
    batches = [rng.integers(0, N, s) for s in rng.integers(40, 64, size=8)]
    for vs in batches:                          # warm every size class
        retrieve_neighbors_batch(part, vs, TPS, engine=engine, fused=True,
                                 resident=True)
    before = _pad.trace_count()
    for vs in batches:
        retrieve_neighbors_batch(part, vs, TPS, engine=engine, fused=True,
                                 resident=True)
    assert _pad.trace_count() == before


def test_device_plan_placed_once_per_engine():
    vals = np.sort(np.random.default_rng(41).integers(0, 1 << 20, 4 * PAGE))
    col = delta_encode_column(vals, PAGE)
    parts = partition_column(col, 2)
    t0 = parts.device_transfers
    plan1 = parts.device_plan("jax")
    assert parts.device_plan("jax") is plan1    # exactly once
    assert parts.device_transfers == t0 + 1
    single = parts.device_plan_single("jax")
    assert parts.device_plan_single("jax") is single
    # a degenerate one-device mesh reuses the sharded placement outright
    # (same bytes, same device); a real mesh places a second copy
    expected = t0 + 1 if plan1[0].devices.size == 1 else t0 + 2
    assert parts.device_transfers == expected
    assert all(p.device is not None for p in parts.parts)


def test_page_class_caps_at_stack():
    assert pdo._page_class(53, 160) == 64       # pow2 ladder below the cap
    assert pdo._page_class(157, 160) == 160     # capped: 256 would be waste
    assert pdo._page_class(3, 160) == 8         # floor intact


# ------------------------------ serving stats ------------------------------

def test_retriever_surfaces_partition_counters():
    from repro.core import EdgeTypeSchema, GraphArBuilder, PropertySchema
    from repro.data.synthetic import document_graph
    from repro.serve.retrieval import GraphRetriever
    lake = document_graph(num_docs=300, vocab=256, mean_len=8, seed=3)
    b = GraphArBuilder("docs")
    b.add_vertices(
        VertexTypeSchema("doc", [PropertySchema("tokens", "tokens")],
                         labels=list(lake.labels), page_size=128),
        {"tokens": lake.tokens}, lake.labels)
    b.add_edges(EdgeTypeSchema("doc", "links", "doc", page_size=128),
                lake.links_src, lake.links_dst)
    g = b.build()
    adj = g.adjacency("doc-links-doc", BY_SRC)
    retr = GraphRetriever(adj, g.vertex("doc").table["tokens"],
                          engine="jax", partitions=4)
    retr(np.arange(12))
    s = retr.stats()
    assert s["partitions"]["n_parts"] == 4
    assert "partitions_pruned" in s["partitions"]
    assert s["partitions"]["dispatches"] >= 1


def test_env_default_partitions(monkeypatch):
    import repro.core.partition as cpart
    src, dst = _graph()
    adj = build_adjacency(src, dst, N, N, BY_SRC, ENC_GRAPHAR,
                          page_size=PAGE)
    monkeypatch.setattr(cpart, "DEFAULT_PARTITIONS", 2)
    retrieve_neighbors_batch(adj, np.arange(16), TPS, engine="jax",
                             fused=True, resident=True)
    parts = live_partitions(adj.table["<dst>"].encoded)
    assert parts is not None and parts.n_parts == 2
