"""Data pipeline (GraphAr -> batches) + serving engine behaviour tests."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (EdgeTypeSchema, GraphArBuilder, L, PropertySchema,
                        VertexTypeSchema)
from repro.data.pipeline import GraphCorpusPipeline, PipelineConfig
from repro.data.synthetic import document_graph
from repro.data.tokenizer import EOS, HashTokenizer


@pytest.fixture(scope="module")
def doc_graph():
    lake = document_graph(num_docs=3000, vocab=512, mean_len=64, seed=0)
    b = GraphArBuilder("docs")
    b.add_vertices(
        VertexTypeSchema("doc", [PropertySchema("tokens", "tokens"),
                                 PropertySchema("quality", "float32")],
                         labels=list(lake.labels), page_size=256),
        {"tokens": lake.tokens, "quality": lake.quality}, lake.labels)
    b.add_edges(EdgeTypeSchema("doc", "links", "doc", page_size=256),
                lake.links_src, lake.links_dst)
    return b.build(), lake


def test_pipeline_filters_and_packs(doc_graph):
    g, lake = doc_graph
    cond = (L("HighQuality") | L("News")) & ~L("Spam")
    cfg = PipelineConfig(seq_len=128, batch_size=4, seed=1)
    pipe = GraphCorpusPipeline(g, cond, cfg)
    expect = np.flatnonzero(
        (lake.labels["HighQuality"] | lake.labels["News"])
        & ~lake.labels["Spam"])
    np.testing.assert_array_equal(pipe.eligible, expect)
    it = pipe.batches()
    for _ in range(3):
        batch = next(it)
        assert batch["tokens"].shape == (4, 128)
        assert batch["labels"].shape == (4, 128)
        # next-token alignment
        np.testing.assert_array_equal(batch["tokens"][:, 1:],
                                      batch["labels"][:, :-1])
    assert pipe.io_stats().nbytes > 0


def test_pipeline_deterministic_resume(doc_graph):
    g, _ = doc_graph
    cfg = PipelineConfig(seq_len=64, batch_size=2, seed=7)
    a = GraphCorpusPipeline(g, None, cfg)
    b = GraphCorpusPipeline(g, None, cfg)
    ia = a.batches(start_step=0)
    for _ in range(5):
        last_a = next(ia)
    ib = b.batches(start_step=4)  # resume at step 4 reproduces batch 5
    last_b = next(ib)
    np.testing.assert_array_equal(last_a["tokens"], last_b["tokens"])


def test_pipeline_sharding_disjoint(doc_graph):
    g, _ = doc_graph
    cfg0 = PipelineConfig(seq_len=64, batch_size=2, shard_id=0, num_shards=2)
    cfg1 = PipelineConfig(seq_len=64, batch_size=2, shard_id=1, num_shards=2)
    p0 = GraphCorpusPipeline(g, None, cfg0)
    p1 = GraphCorpusPipeline(g, None, cfg1)
    assert set(p0.eligible).isdisjoint(set(p1.eligible))


def test_tokenizer_deterministic():
    tok = HashTokenizer(512)
    a = tok.encode("hello graph world")
    b = tok.encode("hello graph world")
    np.testing.assert_array_equal(a, b)
    assert a[0] == 1 and a[-1] == EOS
    assert (a < 512).all()


# ------------------------------ serving ------------------------------------

def test_serve_engine_continuous_batching():
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve.engine import Request, ServeEngine

    cfg = get_config("smollm-360m").reduced().with_(n_units=2)
    model = build_model(cfg)
    params = model.init(0)
    eng = ServeEngine(model, params, max_slots=2, max_len=96, eos_id=-1)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(4, cfg.vocab_size, size=8 + 3 * i)
                    .astype(np.int32), max_new_tokens=6)
            for i in range(5)]
    for r in reqs:
        eng.submit(r)
    for _ in range(200):
        eng.step()
        if not eng.queue and all(s is None for s in eng.slots):
            break
    assert all(len(r.output) >= 1 for r in reqs)
    assert all(r.done for r in reqs)
    # decode ticks were batched: fewer ticks than total generated tokens
    total_tokens = sum(len(r.output) for r in reqs)
    assert eng.steps < total_tokens


def test_serve_engine_matches_sequential_decode():
    """Engine output for a single request == plain prefill+decode loop."""
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve.engine import Request, ServeEngine

    cfg = get_config("smollm-360m").reduced().with_(n_units=2)
    model = build_model(cfg)
    params = model.init(0)
    rng = np.random.default_rng(3)
    prompt = rng.integers(4, cfg.vocab_size, size=12).astype(np.int32)

    # reference: batch-1 greedy decode
    cache = model.init_cache(1, 64, dtype=jnp.float32)
    logits, cache = model.prefill(
        params, {"tokens": jnp.asarray(prompt)[None]}, cache)
    ref = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(4):
        tok = jnp.asarray([[ref[-1]]], jnp.int32)
        logits, cache = model.decode_step(params, tok, cache)
        ref.append(int(jnp.argmax(logits[0, -1])))

    eng = ServeEngine(model, params, max_slots=2, max_len=64, eos_id=-1)
    req = Request(0, prompt, max_new_tokens=5)
    eng.submit(req)
    for _ in range(20):
        eng.step()
        if req.done:
            break
    assert req.output == ref
