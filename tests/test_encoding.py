"""Codec round-trip + property tests (paper §4.2/§5.1 encodings)."""
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core.encoding import (ALLOWED_WIDTHS, DEFAULT_PAGE_SIZE, MINIBLOCK,
                                 bitpack, bitunpack, delta_decode_column,
                                 delta_decode_page, delta_decode_range,
                                 delta_encode_column, delta_encode_page,
                                 rle_decode_bool, rle_encode_bool)


@pytest.mark.parametrize("bw", [1, 2, 4, 8, 16, 32])
def test_bitpack_roundtrip(bw):
    rng = np.random.default_rng(bw)
    hi = (1 << bw) - 1
    vals = rng.integers(0, hi + 1, size=101, dtype=np.uint64)
    words = bitpack(vals, bw)
    out = bitunpack(words, bw, len(vals))
    np.testing.assert_array_equal(out, vals.astype(np.uint32))


def test_bitpack_alignment_no_straddle():
    # power-of-two widths -> whole number of values per 32-bit word
    for bw in (1, 2, 4, 8, 16, 32):
        assert 32 % bw == 0


def test_delta_page_roundtrip_sorted():
    rng = np.random.default_rng(0)
    vals = np.sort(rng.integers(0, 1 << 30, size=2048))
    page = delta_encode_page(vals)
    out = delta_decode_page(page)
    np.testing.assert_array_equal(out, vals)


def test_delta_page_negative_deltas():
    # dst column: sorted within src groups, drops across group boundaries
    vals = np.array([100, 105, 107, 3, 9, 12, 2000, 2001], np.int64)
    page = delta_encode_page(vals)
    np.testing.assert_array_equal(delta_decode_page(page), vals)


def test_delta_page_widths_are_allowed():
    rng = np.random.default_rng(1)
    vals = np.sort(rng.integers(0, 1 << 20, size=4096))
    page = delta_encode_page(vals[:2048])
    for w in page.bit_widths:
        assert int(w) in ALLOWED_WIDTHS


def test_delta_compression_on_local_ids():
    # clustered neighbor ids => small deltas => far fewer bytes than plain
    rng = np.random.default_rng(2)
    base = np.cumsum(rng.integers(1, 16, size=100_000)).astype(np.int64)
    col = delta_encode_column(base)
    plain_bytes = base.size * 4
    assert col.nbytes() < 0.45 * plain_bytes  # paper: 58.1%-81.0% reduction


def test_delta_column_range_decode():
    rng = np.random.default_rng(3)
    vals = np.sort(rng.integers(0, 1 << 28, size=10_000))
    col = delta_encode_column(vals, page_size=1024)
    for lo, hi in [(0, 1), (1023, 1025), (5000, 5001), (0, 10_000),
                   (9999, 10_000), (2048, 4096)]:
        np.testing.assert_array_equal(delta_decode_range(col, lo, hi),
                                      vals[lo:hi])


def test_rle_roundtrip():
    v = np.array([1, 1, 0, 0, 0, 1, 0, 1, 1, 1], bool)
    col = rle_encode_bool(v)
    np.testing.assert_array_equal(rle_decode_bool(col), v)
    starts, ends = col.interval_starts(True)
    got = []
    for s, e in zip(starts, ends):
        got.extend(range(s, e))
    np.testing.assert_array_equal(np.flatnonzero(v), got)


def test_rle_interval_counts():
    v = np.zeros(1000, bool)
    v[100:200] = True
    v[300:301] = True
    col = rle_encode_bool(v)
    assert col.n_runs == 5
    s, e = col.interval_starts(True)
    assert list(s) == [100, 300] and list(e) == [200, 301]
    s0, e0 = col.interval_starts(False)
    assert list(s0) == [0, 200, 301] and list(e0) == [100, 300, 1000]


# ---------------- property-based (hypothesis) ----------------

@given(st.lists(st.integers(min_value=0, max_value=(1 << 31) - 1),
                min_size=1, max_size=500))
@settings(max_examples=60, deadline=None)
def test_delta_roundtrip_property(xs):
    vals = np.sort(np.array(xs, np.int64))
    page = delta_encode_page(vals)
    np.testing.assert_array_equal(delta_decode_page(page), vals)


@given(st.lists(st.integers(min_value=-(1 << 30), max_value=1 << 30),
                min_size=1, max_size=300))
@settings(max_examples=40, deadline=None)
def test_delta_roundtrip_unsorted_property(xs):
    vals = np.array(xs, np.int64)  # arbitrary order: negatives via min_delta
    page = delta_encode_page(vals)
    np.testing.assert_array_equal(delta_decode_page(page), vals)


@given(st.lists(st.booleans(), min_size=0, max_size=400))
@settings(max_examples=60, deadline=None)
def test_rle_roundtrip_property(bits):
    v = np.array(bits, bool)
    col = rle_encode_bool(v)
    np.testing.assert_array_equal(rle_decode_bool(col), v)
    # interval invariants: positions strictly increasing, bounded by n
    p = col.positions
    assert p[0] == 0 and p[-1] == len(v)
    assert (np.diff(p) > 0).all() or len(v) == 0


@given(st.integers(min_value=1, max_value=5000),
       st.integers(min_value=0, max_value=10_000))
@settings(max_examples=30, deadline=None)
def test_delta_column_random_range_property(n, seed):
    rng = np.random.default_rng(seed)
    vals = np.sort(rng.integers(0, 1 << 26, size=n))
    col = delta_encode_column(vals, page_size=256)
    lo = int(rng.integers(0, n))
    hi = int(rng.integers(lo, n)) + 1
    np.testing.assert_array_equal(delta_decode_range(col, lo, hi),
                                  vals[lo:hi])
