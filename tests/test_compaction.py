"""Crash-consistent compaction: atomic swap, durability, fault recovery.

The acceptance invariant: a serving schedule of ticks interleaved with
ingest and background compaction returns ids bit-identical to a
from-scratch rebuild of the graph visible at each tick -- under every
injected fault boundary -- with the compactor recovering via
retry/backoff, and the IOMeter footprint of settled (post-compaction)
serving bit-identical to the rebuilt graph's.
"""
import os

import numpy as np
import pytest

from _engines import engines
from repro.core import (BY_SRC, ENC_GRAPHAR, IOMeter, build_adjacency,
                        neighbor_ids_batch, retrieve_neighbors_batch)
from repro.core.compaction import (CompactionPolicy, CompactionRunner,
                                   collect_garbage)
from repro.core.delta_segment import (attach_delta, all_edges, ingest_edges,
                                      live_delta)
from repro.core.storage import GraphStore, read_table, write_table
from repro.ft.backoff import Backoff
from repro.ft.faults import BOUNDARIES, FaultPlan, InjectedFault
from repro.kernels import _pad

N = 300
PAGE = 128
TPS = 512


def _graph(seed=3, n_edges=2500):
    rng = np.random.default_rng(seed)
    return build_adjacency(rng.integers(0, N, n_edges),
                           rng.integers(0, N, n_edges), N, N, BY_SRC,
                           ENC_GRAPHAR, page_size=PAGE)


def _rebuilt(adj):
    return build_adjacency(*all_edges(adj), N, N, BY_SRC, ENC_GRAPHAR,
                           page_size=PAGE)


# ------------------------ the swap itself --------------------------------

def test_compacted_layout_bit_identical_to_rebuild():
    adj = _graph()
    rng = np.random.default_rng(9)
    ingest_edges(adj, rng.integers(0, N, 200), rng.integers(0, N, 200))
    oracle = _rebuilt(adj)
    assert CompactionRunner(adj).compact()
    assert live_delta(adj) is None
    for name in ("<src>", "<dst>"):
        a, b = adj.table[name].encoded, oracle.table[name].encoded
        assert len(a.pages) == len(b.pages)
        for pa, pb in zip(a.pages, b.pages):
            assert pa.count == pb.count
            assert pa.first_value == pb.first_value
            assert (pa.vmin, pa.vmax) == (pb.vmin, pb.vmax)
            np.testing.assert_array_equal(pa.packed, pb.packed)
    np.testing.assert_array_equal(
        adj.offsets["<offset>"].values, oracle.offsets["<offset>"].values)


def test_swap_bumps_version_and_invalidates_caches():
    adj = _graph()
    col = adj.table[adj.value_col].encoded
    v0 = col.version
    neighbor_ids_batch(adj, np.arange(20), engine="jax")  # device mirror
    assert col.packed_cache is not None
    ingest_edges(adj, [1], [2])
    assert CompactionRunner(adj).compact()
    assert col.version == v0 + 1
    assert col.packed_cache is None              # mirrors re-ship lazily


def test_rows_ingested_after_snapshot_survive_compaction():
    """drop_rows removes exactly the frozen snapshot -- later ingests
    keep serving from the delta path (multiset difference, not prefix)."""
    adj = _graph()
    d = attach_delta(adj)
    d.ingest([1, 1, 2], [5, 5, 6])
    frozen = d.snapshot()
    d.ingest([1, 3], [5, 7])                     # post-snapshot, one a dup
    d.drop_rows(frozen)
    assert d.pending_rows() == 2
    vals, _ = d.lookup_batch(np.asarray([1, 3], np.int64))
    np.testing.assert_array_equal(vals, [5, 7])


def test_policy_gates_compaction():
    adj = _graph()
    runner = CompactionRunner(adj, policy=CompactionPolicy(min_delta_rows=50))
    assert not runner.maybe_compact()            # nothing pending
    ingest_edges(adj, np.arange(10), np.arange(10))
    assert not runner.maybe_compact()            # below threshold
    assert live_delta(adj) is not None
    rng = np.random.default_rng(0)
    ingest_edges(adj, rng.integers(0, N, 45), rng.integers(0, N, 45))
    assert runner.maybe_compact()                # 55 >= 50
    assert live_delta(adj) is None


# -------------------- interleaved serving invariant ----------------------

def _schedule(adj, runner, plan_ticks, engine, meter):
    """serve/ingest/compact schedule; returns per-serve-tick ids and the
    per-tick (bytes, requests) deltas the schedule charged."""
    rng = np.random.default_rng(55)
    ids, costs, oracle_edges = [], [], []
    for op in plan_ticks:
        if op == "serve":
            vs = rng.integers(0, N, 24)
            b0, r0 = meter.nbytes, meter.nrequests
            ids.append(neighbor_ids_batch(adj, vs, meter, engine=engine))
            costs.append((meter.nbytes - b0, meter.nrequests - r0))
            oracle_edges.append(all_edges(adj))
        elif op == "ingest":
            s, d = rng.integers(0, N, 40), rng.integers(0, N, 40)
            for _ in range(4):
                try:
                    ingest_edges(adj, s, d)
                    break
                except InjectedFault:
                    continue                     # atomic: retry same batch
        elif op == "compact":
            runner.compact()
    return ids, costs, oracle_edges


SCHEDULE = ["serve", "ingest", "serve", "ingest", "serve", "compact",
            "serve", "ingest", "serve", "compact", "serve"]


@pytest.mark.parametrize("engine", engines())
@pytest.mark.parametrize("boundary", BOUNDARIES)
def test_interleaved_serving_invariant_under_fault(tmp_path, engine,
                                                   boundary):
    """Every serve tick's ids equal a from-scratch rebuild of the edges
    visible at that tick, under a fault at every boundary; and the
    schedule's per-tick meter trace is identical to the no-fault run."""
    plan = FaultPlan({boundary: 2})
    adj = _graph()
    store = GraphStore(str(tmp_path / "lake"), faults=plan)
    attach_delta(adj, faults=plan)
    runner = CompactionRunner(adj, store=store, faults=plan,
                              sleep=lambda _s: None)
    meter = IOMeter()
    ids, costs, edges = _schedule(adj, runner, SCHEDULE, engine, meter)

    # no-fault reference run (fresh graph, same deterministic schedule)
    adj2 = _graph()
    runner2 = CompactionRunner(adj2, sleep=lambda _s: None)
    meter2 = IOMeter()
    ids2, costs2, _ = _schedule(adj2, runner2, SCHEDULE, engine, meter2)

    for i, (got, (s, d)) in enumerate(zip(ids, edges)):
        # rebuild the graph visible at tick i from its recorded edge set
        oracle = build_adjacency(s, d, N, N, BY_SRC, ENC_GRAPHAR,
                                 page_size=PAGE)
        np.testing.assert_array_equal(got, ids2[i])
        want = neighbor_ids_batch(oracle, _serve_batch(i), engine="numpy")
        np.testing.assert_array_equal(got, want)
    assert costs == costs2                       # fault-invariant footprint
    # schedule ends compacted: the lake holds a committed generation and
    # no torn temp files, whatever the fault plan did
    files = sorted(os.listdir(store.root))
    assert not any(".tmp-" in f for f in files), files
    if runner.compactions:
        assert store.current_generation() >= 1


def _serve_batch(i):
    """The i-th serve tick's batch under SCHEDULE's deterministic rng."""
    rng = np.random.default_rng(55)
    out = None
    k = 0
    for op in SCHEDULE:
        if op == "serve":
            vs = rng.integers(0, N, 24)
            if k == i:
                out = vs
            k += 1
        elif op == "ingest":
            rng.integers(0, N, 40)
            rng.integers(0, N, 40)
    return out


@pytest.mark.parametrize("engine", engines())
def test_seeded_fault_plan_from_env_matrix(engine):
    """The CI fault matrix: REPRO_FAULT_SEED derives a boundary->trips
    plan; serving + compaction must end bit-identical to the rebuild
    whatever the seed draws."""
    seed = int(os.environ.get("REPRO_FAULT_SEED", "1"))
    plan = FaultPlan.from_seed(seed)
    adj = _graph(seed=seed)
    attach_delta(adj, faults=plan)
    runner = CompactionRunner(adj, faults=plan, max_attempts=8,
                              sleep=lambda _s: None)
    rng = np.random.default_rng(seed)
    for _ in range(3):
        try:
            ingest_edges(adj, rng.integers(0, N, 30),
                         rng.integers(0, N, 30))
        except InjectedFault:
            ingest_edges(adj, rng.integers(0, N, 30),
                         rng.integers(0, N, 30))  # retry a fresh batch
        runner.compact()
    oracle = _rebuilt(adj)
    vs = rng.integers(0, N, 32)
    got = neighbor_ids_batch(adj, vs, engine=engine)
    want = neighbor_ids_batch(oracle, vs, engine="numpy")
    np.testing.assert_array_equal(got, want)


# ---------------- settled state: meters + zero retrace -------------------

@pytest.mark.parametrize("engine", engines())
def test_settled_meter_bit_identical_to_rebuild(engine):
    adj = _graph()
    rng = np.random.default_rng(4)
    ingest_edges(adj, rng.integers(0, N, 90), rng.integers(0, N, 90))
    oracle = _rebuilt(adj)
    assert CompactionRunner(adj).compact()
    vs = rng.integers(0, N, 40)
    m1, m2 = IOMeter(), IOMeter()
    np.testing.assert_array_equal(
        neighbor_ids_batch(adj, vs, m1, engine=engine),
        neighbor_ids_batch(oracle, vs, m2, engine=engine))
    assert (m1.nbytes, m1.nrequests) == (m2.nbytes, m2.nrequests)


@pytest.mark.parametrize("engine", engines(kernel_only=True))
def test_zero_retrace_steady_state_after_compaction(engine):
    adj = _graph()
    rng = np.random.default_rng(6)
    batches = [rng.integers(0, N, s) for s in rng.integers(40, 64, 6)]
    for vs in batches:
        retrieve_neighbors_batch(adj, vs, TPS, engine=engine, fused=True,
                                 resident=True)
    ingest_edges(adj, rng.integers(0, N, 50), rng.integers(0, N, 50))
    assert CompactionRunner(adj).compact()
    for vs in batches:                           # re-warm the new epoch
        retrieve_neighbors_batch(adj, vs, TPS, engine=engine, fused=True,
                                 resident=True)
    before = _pad.trace_count()
    for vs in batches:
        retrieve_neighbors_batch(adj, vs, TPS, engine=engine, fused=True,
                                 resident=True)
    assert _pad.trace_count() == before          # jit cache hits only


# ------------------------- durability + GC -------------------------------

def test_store_write_crash_leaves_old_file_intact(tmp_path):
    adj = _graph()
    path = str(tmp_path / "edges.gar")
    write_table(adj.table, path)
    before = open(path, "rb").read()
    adj2 = _graph(seed=8)
    with pytest.raises(InjectedFault):
        write_table(adj2.table, path, FaultPlan({"store.write": 1}))
    assert open(path, "rb").read() == before     # old contents intact
    turds = [f for f in os.listdir(tmp_path) if ".tmp-" in f]
    assert turds                                 # torn staging file left
    store = GraphStore(str(tmp_path))
    assert sorted(collect_garbage(store)) == sorted(turds)
    write_table(adj2.table, path)                # retry goes through
    t = read_table(path)
    np.testing.assert_array_equal(t["<dst>"].read_all(),
                                  adj2.table["<dst>"].read_all())


def test_manifest_flip_and_generation_gc(tmp_path):
    adj = _graph()
    store = GraphStore(str(tmp_path / "lake"))
    store.write(adj.table)                       # legacy layout first
    store.write(adj.offsets)
    name = adj.table.name
    runner = CompactionRunner(adj, store=store, sleep=lambda _s: None)
    rng = np.random.default_rng(12)
    ingest_edges(adj, rng.integers(0, N, 60), rng.integers(0, N, 60))
    assert runner.compact()
    assert store.current_generation() == 1
    files = set(os.listdir(store.root))
    assert f"{name}.g1.gar" in files
    assert f"{name}.gar" not in files            # superseded legacy GC'd
    ingest_edges(adj, rng.integers(0, N, 60), rng.integers(0, N, 60))
    assert runner.compact()
    assert store.current_generation() == 2
    files = set(os.listdir(store.root))
    assert f"{name}.g2.gar" in files
    assert f"{name}.g1.gar" not in files         # old generation GC'd
    # the committed generation round-trips to exactly the live layout
    t = store.read(name)
    np.testing.assert_array_equal(t["<dst>"].read_all(),
                                  adj.table["<dst>"].read_all())
    assert store.list_tables() == sorted({name, adj.offsets.name})


def test_uncommitted_generation_is_invisible_and_collected(tmp_path):
    adj = _graph()
    store = GraphStore(str(tmp_path / "lake"))
    store.write(adj.table)
    store.write_generation(adj.table, 7)         # staged, never committed
    assert store.list_tables() == [adj.table.name]
    t = store.read(adj.table.name)               # legacy file still serves
    assert t.num_rows == adj.table.num_rows
    removed = collect_garbage(store)
    assert removed == [f"{adj.table.name}.g7.gar"]


# ------------------------- retry / backoff -------------------------------

def test_compactor_retries_follow_seeded_backoff_schedule():
    adj = _graph()
    plan = FaultPlan({"compact.merge": 2})
    attach_delta(adj)
    ingest_edges(adj, [1], [2])
    slept = []
    runner = CompactionRunner(adj, faults=plan,
                              backoff=Backoff(base=0.01, max_delay=0.25,
                                              seed=42),
                              sleep=slept.append)
    assert runner.compact()
    ref = Backoff(base=0.01, max_delay=0.25, seed=42)
    assert slept == [ref.delay(0), ref.delay(1)]
    assert runner.faults_hit == 2 and runner.compactions == 1


def test_compactor_gives_up_gracefully_and_resumes():
    adj = _graph()
    plan = FaultPlan({"compact.merge": 99})
    attach_delta(adj, faults=plan)
    rng = np.random.default_rng(1)
    ingest_edges(adj, rng.integers(0, N, 30), rng.integers(0, N, 30))
    oracle = _rebuilt(adj)
    runner = CompactionRunner(adj, faults=plan, max_attempts=3,
                              sleep=lambda _s: None)
    assert not runner.compact()                  # exhausted, no exception
    assert runner.gave_up == 1
    d = live_delta(adj)
    assert d is not None and d.pending_rows() == 30
    vs = rng.integers(0, N, 20)                  # delta path keeps serving
    np.testing.assert_array_equal(
        neighbor_ids_batch(adj, vs),
        neighbor_ids_batch(oracle, vs))
    runner.faults = FaultPlan({})                # faults cleared: resume
    assert runner.compact()
    assert live_delta(adj) is None
