"""Admission-controlled multi-tenant serving: fairness as an invariant.

Three layers under test, bottom up:

* :class:`~repro.ft.backoff.TokenBucket` -- deterministic under an
  explicit tick clock (no wall-clock reads anywhere: the same submit
  schedule replays to the same admit/reject/retry-after decisions);
* :class:`~repro.serve.tenancy.TenantScheduler` -- DWRR is
  work-conserving (pop(k) == min(k, pending)), starvation-free, and
  *exactly* weight-proportional when every tenant is backlogged --
  including across arbitrarily-chunked pop() calls (the mid-visit
  resume must not re-credit the head tenant's quantum);
* :class:`~repro.serve.engine.ServeEngine` -- typed submit outcomes,
  tick-boundary deadline enforcement (queued and in-slot), the overload
  degradation ladder, and ``run_until_drained`` raising a typed
  :class:`~repro.serve.engine.UndrainedError` instead of silently
  returning a partial drain.
"""
import numpy as np
import pytest

from _hypothesis_shim import HAVE_HYPOTHESIS, given, settings, st
from repro.core import (BY_SRC, EdgeTypeSchema, GraphArBuilder, IOMeter,
                        PropertySchema, VertexTypeSchema)
from repro.data.synthetic import document_graph
from repro.ft.backoff import TokenBucket
from repro.serve.engine import Request, ServeEngine, UndrainedError
from repro.serve.overload import LADDER, OverloadConfig, OverloadController
from repro.serve.retrieval import GraphRetriever
from repro.serve.tenancy import (RejectReason, RequestStatus, SubmitStatus,
                                 TenantConfig, TenantScheduler)

MAX_LEN = 64


def _req(i, tenant="default", deadline=None, size=4):
    return Request(i, np.full(size, 7, np.int32), max_new_tokens=2,
                   tenant=tenant, deadline_ticks=deadline)


def _sched(*cfgs):
    return TenantScheduler(list(cfgs))


# ------------------------------ token bucket -------------------------------

def test_token_bucket_rate_burst_and_retry_after():
    b = TokenBucket(rate=0.5, burst=2.0)
    assert b.try_take(0) == (True, 0.0)      # burst admits immediately
    assert b.try_take(0) == (True, 0.0)
    ok, wait = b.try_take(0)                 # empty: 1 token / 0.5 rate
    assert not ok and wait == pytest.approx(2.0)
    ok, _ = b.try_take(2.0)                  # waiting retry_after works
    assert ok
    assert not b.try_take(2.0)[0]


def test_token_bucket_zero_rate_never_refills():
    b = TokenBucket(rate=0.0, burst=1.0)
    assert b.try_take(0)[0]
    ok, wait = b.try_take(1e9)
    assert not ok and wait == float("inf")


def test_token_bucket_level_never_exceeds_burst():
    b = TokenBucket(rate=100.0, burst=3.0)
    b.try_take(0)
    b.refill(1e6)
    assert b.level == 3.0


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 20), st.integers(1, 40),
       st.lists(st.integers(0, 5), min_size=1, max_size=40))
def test_token_bucket_deterministic_replay(rate10, burst10, gaps):
    """Two fresh buckets fed the identical (seeded) submit schedule make
    identical decisions with identical retry hints -- determinism is the
    chaos tests' foundation."""
    rate, burst = rate10 / 10.0, burst10 / 10.0
    ticks = np.cumsum(gaps)

    def run():
        b = TokenBucket(rate=rate, burst=burst)
        return [b.try_take(float(t)) for t in ticks]

    a, b = run(), run()
    assert a == b
    for ok, wait in a:
        assert ok == (wait == 0.0)


# ----------------------------- DWRR scheduling -----------------------------

def test_dwrr_exact_weight_shares_when_backlogged():
    """All tenants backlogged: one full round serves exactly ``weight``
    requests per tenant -- fairness as an equality."""
    sched = _sched(TenantConfig("a", weight=3, max_queue=100),
                   TenantConfig("b", weight=2, max_queue=100),
                   TenantConfig("c", weight=1, max_queue=100))
    for i in range(60):
        name = "abc"[i % 3]
        assert sched.submit(_req(i, name), 0).admitted
    rounds = 3
    got = sched.pop(rounds * 6, 1)           # W = 3 + 2 + 1
    counts = {n: sum(1 for r in got if r.tenant == n) for n in "abc"}
    assert counts == {"a": 3 * rounds, "b": 2 * rounds, "c": 1 * rounds}


def test_dwrr_chunked_pops_do_not_recredit_head():
    """pop(1) x N must serve the same weighted shares as one pop(N): a
    mid-visit resume must not grant the head tenant a fresh quantum."""
    def serve(chunks):
        sched = _sched(TenantConfig("a", weight=3, max_queue=100),
                       TenantConfig("b", weight=1, max_queue=100))
        for i in range(40):
            sched.submit(_req(i, "ab"[i % 2]), 0)
        out = []
        for c in chunks:
            out.extend(sched.pop(c, 1))
        return [r.tenant for r in out]

    assert serve([1] * 16) == serve([16]) == serve([5, 3, 7, 1])
    counts = {n: serve([1] * 16).count(n) for n in "ab"}
    assert counts == {"a": 12, "b": 4}       # 4 rounds of W=4


def test_dwrr_work_conserving_and_starvation_free():
    sched = _sched(TenantConfig("hog", weight=8, max_queue=100),
                   TenantConfig("mouse", weight=1, max_queue=100))
    for i in range(30):
        sched.submit(_req(i, "hog" if i < 25 else "mouse"), 0)
    got = sched.pop(12, 1)
    assert len(got) == 12                    # work-conserving
    assert any(r.tenant == "mouse" for r in got)   # served within a round
    # an idle tenant donates: only the hog remains after the mice drain
    rest = sched.pop(100, 2)
    assert len(rest) == 30 - 12
    assert sched.pending() == 0


if HAVE_HYPOTHESIS:
    _mixes = st.lists(
        st.tuples(st.integers(1, 6), st.integers(0, 12)),
        min_size=1, max_size=5)

    @settings(max_examples=60, deadline=None)
    @given(_mixes, st.integers(0, 40))
    def test_dwrr_work_conserving_property(mix, k):
        """Across random weight/backlog mixes, pop(k) always returns
        min(k, pending) -- no tenant mix can strand schedulable work."""
        cfgs = [TenantConfig(f"t{j}", weight=w, max_queue=1000)
                for j, (w, _) in enumerate(mix)]
        sched = _sched(*cfgs)
        i = 0
        for j, (_, backlog) in enumerate(mix):
            for _ in range(backlog):
                assert sched.submit(_req(i, f"t{j}"), 0).admitted
                i += 1
        pending = sched.pending()
        got = sched.pop(k, 1)
        assert len(got) == min(k, pending)
        assert sched.pending() == pending - len(got)
        # no duplicates, nothing invented
        ids = [r.request_id for r in got]
        assert len(set(ids)) == len(ids)

    @settings(max_examples=60, deadline=None)
    @given(_mixes, st.lists(st.integers(1, 7), min_size=1, max_size=8))
    def test_dwrr_peek_matches_pop_across_chunks(mix, chunks):
        """peek(k) previews exactly what the next pops return, even when
        the pops are split into arbitrary chunks (the pipelined engine's
        speculative admission relies on this)."""
        def build():
            cfgs = [TenantConfig(f"t{j}", weight=w, max_queue=1000)
                    for j, (w, _) in enumerate(mix)]
            s = _sched(*cfgs)
            i = 0
            for j, (_, backlog) in enumerate(mix):
                for _ in range(backlog):
                    s.submit(_req(i, f"t{j}"), 0)
                    i += 1
            return s

        k = sum(chunks)
        want = [r.request_id for r in build().peek(k)]
        sched = build()
        got = []
        for c in chunks:
            # a peek before every chunked pop must agree with the pop
            p = [r.request_id for r in sched.peek(c)]
            popped = [r.request_id for r in sched.pop(c, 1)]
            assert p == popped
            got.extend(popped)
        assert got == want


# --------------------------- admission gating ------------------------------

def test_submit_rejects_with_typed_retry_after():
    sched = _sched(TenantConfig("t", rate=1.0, burst=2.0, max_queue=10))
    assert sched.submit(_req(0, "t"), 0).admitted
    assert sched.submit(_req(1, "t"), 0).admitted
    out = sched.submit(_req(2, "t"), 0)      # bucket empty at tick 0
    assert out.status is SubmitStatus.REJECTED
    assert out.reason is RejectReason.RATE_LIMITED
    assert out.retry_after == 1              # ceil(1 token / rate 1)
    # waiting the hint makes the next submit admissible
    assert sched.submit(_req(3, "t"), 0 + out.retry_after).admitted


def test_submit_sheds_on_bounded_queue():
    sched = _sched(TenantConfig("t", max_queue=2))
    assert sched.submit(_req(0, "t"), 0).admitted
    assert sched.submit(_req(1, "t"), 0).admitted
    out = sched.submit(_req(2, "t"), 0)
    assert out.status is SubmitStatus.REJECTED
    assert out.reason is RejectReason.QUEUE_FULL
    assert out.retry_after >= 1
    sched.pop(1, 1)                          # a slot drains
    assert sched.submit(_req(3, "t"), 1).admitted


def test_submit_unknown_tenant_typed():
    out = _sched(TenantConfig("t")).submit(_req(0, "nope"), 0)
    assert out.status is SubmitStatus.REJECTED
    assert out.reason is RejectReason.UNKNOWN_TENANT
    assert out.retry_after is None           # retrying cannot help


def test_queue_expiry_is_typed_and_counted():
    sched = _sched(TenantConfig("t", deadline_ticks=2, max_queue=10))
    sched.submit(_req(0, "t"), 0)
    sched.submit(_req(1, "t", deadline=100), 0)   # per-request override
    assert sched.expire(2) == []             # now == deadline_at: still live
    expired = sched.expire(3)
    assert [r.request_id for r in expired] == [0]
    assert sched.pending() == 1
    assert sched.stats()["t"]["expired"] == 1


def test_tenant_config_validation():
    with pytest.raises(ValueError):
        TenantConfig("t", weight=0)
    with pytest.raises(ValueError):
        TenantConfig("t", max_queue=0)
    with pytest.raises(ValueError):
        TenantConfig("t", rate=0.0)
    with pytest.raises(ValueError):
        TenantScheduler([TenantConfig("t"), TenantConfig("t")])
    with pytest.raises(ValueError):
        TenantScheduler([])


# ------------------------- overload ladder (unit) --------------------------

def _tiny_retriever():
    lake = document_graph(num_docs=60, vocab=128, mean_len=8, seed=3)
    b = GraphArBuilder("docs")
    b.add_vertices(
        VertexTypeSchema("doc", [PropertySchema("tokens", "tokens")],
                         labels=list(lake.labels), page_size=64),
        {"tokens": lake.tokens}, lake.labels)
    b.add_edges(EdgeTypeSchema("doc", "links", "doc", page_size=64),
                lake.links_src, lake.links_dst)
    g = b.build()
    return GraphRetriever(g.adjacency("doc-links-doc", BY_SRC),
                          g.vertex("doc").table["tokens"],
                          max_neighbors=8, tokens_per_neighbor=4,
                          engine="numpy", page_cache_pages=None, hops=2)


class _StubEngine:
    """Just enough engine surface for the controller: the knob targets."""

    def __init__(self, retr):
        self.context_fn = retr
        self.spec_disabled = False
        self.tick_no = 0

    def _discard_prefetch(self):
        pass


def test_overload_ladder_degrades_and_restores_in_order():
    retr = _tiny_retriever()
    eng = _StubEngine(retr)
    ctl = OverloadController(eng, OverloadConfig(
        target_p99_ms=10.0, window=8, patience=2))
    for _ in range(30):                      # sustained overload
        ctl.observe(100.0)
    assert ctl.level == len(LADDER) == 3
    assert ctl.degrade_steps == 3 and ctl.restore_steps == 0
    assert retr.hops == 1                    # rung 1
    assert eng.spec_disabled                 # rung 2
    assert retr.max_neighbors == 4           # rung 3: halved from 8
    for _ in range(60):                      # sustained recovery
        ctl.observe(0.5)
    assert ctl.level == 0 and ctl.restore_steps == 3
    assert retr.hops == 2                    # every knob restored
    assert not eng.spec_disabled
    assert retr.max_neighbors == 8
    steps = [(h["dir"], h["step"]) for h in ctl.stats()["transitions"]]
    assert steps == [("degrade", "cap_hops"),
                     ("degrade", "no_speculation"),
                     ("degrade", "shrink_context"),
                     ("restore", "shrink_context"),
                     ("restore", "no_speculation"),
                     ("restore", "cap_hops")]


def test_overload_single_slow_tick_is_debounced():
    ctl = OverloadController(_StubEngine(_tiny_retriever()),
                             OverloadConfig(target_p99_ms=10.0, window=8,
                                            patience=3))
    for _ in range(20):
        ctl.observe(1.0)
    ctl.observe(500.0)                       # one compile-like spike
    for _ in range(20):
        ctl.observe(1.0)
    assert ctl.level == 0 and ctl.degrade_steps == 0


def test_set_knob_rejects_unknown_and_degenerate():
    retr = _tiny_retriever()
    with pytest.raises(ValueError):
        retr.set_knob("meter", 0)
    with pytest.raises(ValueError):
        retr.set_knob("max_neighbors", 0)
    assert retr.set_knob("max_neighbors", 4) == 8
    assert retr.stats()["knobs"]["max_neighbors"] == 4


# ------------------------- engine integration ------------------------------

@pytest.fixture(scope="module")
def engine_parts():
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("smollm-360m").reduced().with_(n_units=2)
    model = build_model(cfg)
    return cfg, model, model.init(0)


def _mk(model, params, **kw):
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("eos_id", -1)
    return ServeEngine(model, params, **kw)


def _prompts(cfg, n, seed=0, mnt=2):
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(4, cfg.vocab_size, size=5)
                    .astype(np.int32), max_new_tokens=mnt)
            for i in range(n)]


def test_engine_fairness_under_saturation(engine_parts):
    """Saturated two-tenant engine: admitted slots split by weight, no
    tenant starves, and stats()['tenants'] carries the full field set."""
    cfg, model, params = engine_parts
    eng = _mk(model, params,
              tenants=[TenantConfig("prod", weight=3, max_queue=64),
                       TenantConfig("best_effort", weight=1, max_queue=64)])
    reqs = _prompts(cfg, 32, mnt=2)
    for i, r in enumerate(reqs):
        r.tenant = "prod" if i % 2 == 0 else "best_effort"
        assert eng.submit(r).admitted
    fin = eng.run_until_drained()
    assert len(fin) == 32
    assert all(r.status is RequestStatus.OK for r in fin)
    ts = eng.stats()["tenants"]
    # DWRR order: within the first half of retirements prod leads ~3:1
    first = [r.tenant for r in fin[:16]]
    assert first.count("prod") == 12 and first.count("best_effort") == 4
    for name in ("prod", "best_effort"):
        for field in ("weight", "queue_depth", "submitted", "admitted",
                      "rejected_rate", "rejected_queue_full", "expired",
                      "scheduled", "finished_ok", "finished_failed",
                      "bucket_level", "deficit", "rate", "max_queue"):
            assert field in ts[name]
    assert ts["prod"]["finished_ok"] == 16


def test_engine_typed_rejection_and_backpressure(engine_parts):
    cfg, model, params = engine_parts
    eng = _mk(model, params,
              tenants=[TenantConfig("t", rate=1.0, burst=2.0, max_queue=2)])
    reqs = _prompts(cfg, 4)
    for r in reqs:
        r.tenant = "t"
    outs = [eng.submit(r) for r in reqs]
    assert [o.status for o in outs] == [
        SubmitStatus.ADMITTED, SubmitStatus.ADMITTED,
        SubmitStatus.REJECTED, SubmitStatus.REJECTED]
    assert outs[2].retry_after == 1
    assert len(eng.rejected) == 2
    assert all(r.status is RequestStatus.REJECTED for r in eng.rejected)
    fin = eng.run_until_drained()
    # every admitted request accounted for, none lost, none doubled
    assert sorted(r.request_id for r in fin) == [0, 1]
    assert eng.stats()["rejected"] == 2
    # backpressure cleared: the bucket refilled while serving ticked
    late = _prompts(cfg, 1, seed=9)[0]
    late.tenant = "t"
    assert eng.submit(late).admitted


def test_engine_deadline_exceeded_in_slot_and_queue(engine_parts):
    """A slot request past its deadline finishes with the typed status
    and frees the slot that same tick; queued requests expire without
    ever holding a slot."""
    cfg, model, params = engine_parts
    eng = _mk(model, params, max_slots=1,
              tenants=[TenantConfig("t", max_queue=16, deadline_ticks=3)])
    long, short, queued = _prompts(cfg, 3, mnt=40)
    long.deadline_ticks = 4                  # expires while decoding
    short.deadline_ticks = 100
    short.max_new_tokens = 2
    queued.deadline_ticks = 2                # expires while queued
    for r in (long, short, queued):
        r.tenant = "t"
        assert eng.submit(r).admitted
    fin = eng.run_until_drained()
    by_id = {r.request_id: r for r in fin}
    assert by_id[long.request_id].status is RequestStatus.DEADLINE_EXCEEDED
    assert 0 < len(by_id[long.request_id].output) < 40   # partial, typed
    assert by_id[queued.request_id].status is \
        RequestStatus.DEADLINE_EXCEEDED
    assert by_id[queued.request_id].output == []         # never held a slot
    assert by_id[short.request_id].status is RequestStatus.OK
    s = eng.stats()
    assert s["deadline_exceeded"] == 2 and s["expired_in_queue"] == 1
    assert s["tenants"]["t"]["finished_failed"] >= 1
    # the engine keeps ticking after deadline shedding
    nxt = _prompts(cfg, 1, seed=7)[0]
    nxt.tenant = "t"
    assert eng.submit(nxt).admitted
    assert len(eng.run_until_drained()) == 1


def test_engine_deadlines_without_tenancy(engine_parts):
    """deadline_ticks works on the legacy single-queue path too."""
    cfg, model, params = engine_parts
    eng = _mk(model, params, max_slots=1)
    a, b_ = _prompts(cfg, 2, mnt=30)
    a.deadline_ticks = 3
    b_.deadline_ticks = 1                    # expires before a slot frees
    assert eng.submit(a).admitted and eng.submit(b_).admitted
    fin = eng.run_until_drained()
    by_id = {r.request_id: r for r in fin}
    assert by_id[0].status is RequestStatus.DEADLINE_EXCEEDED
    assert by_id[1].status is RequestStatus.DEADLINE_EXCEEDED
    assert by_id[1].output == []


def test_single_unmetered_tenant_matches_legacy_queue(engine_parts):
    """One unmetered tenant with a roomy queue reduces to the legacy
    FIFO: same retirement order, same outputs."""
    cfg, model, params = engine_parts

    def run(**kw):
        eng = _mk(model, params, **kw)
        for r in _prompts(cfg, 8, mnt=3):
            assert eng.submit(r).admitted
        return eng.run_until_drained()

    legacy = run()
    tenant = run(tenants=[TenantConfig("default", max_queue=64)])
    assert [r.request_id for r in legacy] == [r.request_id for r in tenant]
    for a, b_ in zip(legacy, tenant):
        assert a.output == b_.output


def test_run_until_drained_raises_typed_undrained(engine_parts):
    cfg, model, params = engine_parts
    eng = _mk(model, params, max_slots=1)
    for r in _prompts(cfg, 4, mnt=8):
        eng.submit(r)
    with pytest.raises(UndrainedError) as ei:
        eng.run_until_drained(max_ticks=3)
    err = ei.value
    assert err.max_ticks == 3
    stuck = set(err.queued_ids) | set(err.active_ids)
    assert stuck and stuck <= {0, 1, 2, 3}
    assert err.active_ids                    # someone holds the slot
    # the report is diagnosis, not corruption: draining still completes
    fin = eng.run_until_drained()
    assert len(fin) + 0 == 4 - 0 or len(eng.finished) == 4


def test_engine_overload_integration(engine_parts):
    """An impossible latency target drives the engine down the whole
    ladder mid-drain; serving completes and stats() shows the trace."""
    cfg, model, params = engine_parts
    eng = _mk(model, params,
              tenants=[TenantConfig("t", max_queue=64)],
              overload=OverloadConfig(target_p99_ms=1e-6, window=4,
                                      patience=1))
    for r in _prompts(cfg, 12, mnt=3):
        r.tenant = "t"
        eng.submit(r)
    fin = eng.run_until_drained()
    assert len(fin) == 12
    ov = eng.stats()["overload"]
    assert ov["level"] == 3 and ov["degrade_steps"] == 3
    assert ov["active_steps"] == list(LADDER)
    assert eng.spec_disabled
