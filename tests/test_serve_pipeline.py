"""Pipelined serving plane: overlap is free, semantics are identical.

The contract under test: with ``pipeline=True`` the engine issues tick
t+1's batched retrieval while tick t's decode is in flight, yet ids,
tokens, and IOMeter accounting stay **bit-identical** to the sequential
engine -- across engines, across partition counts, and across
mis-speculations (which restore the retrieval plane's snapshot and replay
the synchronous path).
"""
import numpy as np
import pytest

from _engines import engines
from repro.core import (BY_SRC, EdgeTypeSchema, GraphArBuilder, IOMeter,
                        PropertySchema, VertexTypeSchema)
from repro.data.synthetic import document_graph
from repro.serve.engine import Request, ServeEngine
from repro.serve.retrieval import GraphRetriever

MAX_LEN = 96


@pytest.fixture(scope="module")
def engine_parts():
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("smollm-360m").reduced().with_(n_units=2)
    model = build_model(cfg)
    return cfg, model, model.init(0)


def _fresh_lake(num_docs=200, seed=5):
    """A fresh graph per engine instance: the decoded-page LRU attaches
    to the adjacency column, so paired sequential/pipelined runs must not
    share one."""
    lake = document_graph(num_docs=num_docs, vocab=512, mean_len=32,
                          seed=seed)
    b = GraphArBuilder("docs")
    b.add_vertices(
        VertexTypeSchema("doc", [PropertySchema("tokens", "tokens")],
                         labels=list(lake.labels), page_size=128),
        {"tokens": lake.tokens}, lake.labels)
    b.add_edges(EdgeTypeSchema("doc", "links", "doc", page_size=128),
                lake.links_src, lake.links_dst)
    g = b.build()
    return g.adjacency("doc-links-doc", BY_SRC), \
        g.vertex("doc").table["tokens"]


def _retriever(engine, partitions, meter):
    adj, tok = _fresh_lake()
    return GraphRetriever(adj, tok, max_neighbors=2, tokens_per_neighbor=8,
                          meter=meter, engine=engine, page_cache_pages=64,
                          partitions=partitions)


def _requests(cfg, adj, n, mnt=3, seed=0):
    rng = np.random.default_rng(seed)
    seeds = np.flatnonzero(adj.degrees() > 0)
    vs = seeds[rng.integers(0, len(seeds), n)]
    return [Request(i, rng.integers(4, cfg.vocab_size, size=6)
                    .astype(np.int32), max_new_tokens=mnt,
                    context_vertex=int(v))
            for i, v in enumerate(vs)]


def _run(model, params, cfg, engine, partitions, pipeline, n=10):
    meter = IOMeter()
    retr = _retriever(engine, partitions, meter)
    eng = ServeEngine(model, params, max_slots=3, max_len=MAX_LEN,
                      eos_id=-1, context_fn=retr, pipeline=pipeline)
    for r in _requests(cfg, retr.adj, n):
        eng.submit(r)
    finished = eng.run_until_drained()
    return eng, retr, meter, finished


def _assert_identical(fin_a, fin_b, m_a, m_b, r_a, r_b):
    assert [r.request_id for r in fin_a] == [r.request_id for r in fin_b]
    for a, b in zip(fin_a, fin_b):
        np.testing.assert_array_equal(a.prompt, b.prompt)
        assert a.output == b.output
        assert a.context_tokens == b.context_tokens
    assert (m_a.nbytes, m_a.nrequests) == (m_b.nbytes, m_b.nrequests)
    assert r_a.calls == r_b.calls
    assert r_a.vertices_seen == r_b.vertices_seen
    ca, cb = r_a.page_cache, r_b.page_cache
    assert (ca.hits, ca.misses) == (cb.hits, cb.misses)


# --------------------- pipelined == sequential oracle ---------------------

@pytest.mark.parametrize("partitions", [1, 2, 8])
@pytest.mark.parametrize("engine", engines())
def test_pipelined_bit_identical_to_sequential(engine_parts, engine,
                                               partitions):
    cfg, model, params = engine_parts
    eng_s, retr_s, m_s, fin_s = _run(model, params, cfg, engine,
                                     partitions, pipeline=False)
    eng_p, retr_p, m_p, fin_p = _run(model, params, cfg, engine,
                                     partitions, pipeline=True)
    assert len(fin_s) == len(fin_p) == 10
    _assert_identical(fin_s, fin_p, m_s, m_p, retr_s, retr_p)
    # the pipeline actually pipelined: speculative retrievals were
    # consumed by the predicted admissions, not just rolled back
    pstats = eng_p.stats()["pipeline"]
    assert pstats["enabled"] and pstats["prefetch_hits"] > 0
    assert pstats["prefetch_issued"] == \
        pstats["prefetch_hits"] + pstats["mis_speculations"]
    sstats = eng_s.stats()["pipeline"]
    assert not sstats["enabled"] and sstats["prefetch_issued"] == 0


# ------------------------- mis-speculation paths --------------------------

def test_mis_speculation_on_graph_mutation(engine_parts):
    """An ingest between prefetch and consumption moves the mutation
    epoch: the engine must restore and fall back synchronously, landing
    bit-identical to a sequential run with the same interleaving."""
    cfg, model, params = engine_parts

    def run(pipeline):
        meter = IOMeter()
        retr = _retriever("numpy", None, meter)
        eng = ServeEngine(model, params, max_slots=1, max_len=MAX_LEN,
                          eos_id=-1, context_fn=retr, pipeline=pipeline)
        for r in _requests(cfg, retr.adj, 2, mnt=2):
            eng.submit(r)
        eng.step()                       # prefetch for req 1 issued here
        eng.ingest([0], [1])             # epoch moves under the prefetch
        eng.run_until_drained()
        return eng, retr, meter, eng.finished

    eng_s, retr_s, m_s, fin_s = run(False)
    eng_p, retr_p, m_p, fin_p = run(True)
    assert len(fin_p) == 2
    _assert_identical(fin_s, fin_p, m_s, m_p, retr_s, retr_p)
    p = eng_p.stats()["pipeline"]
    assert p["mis_speculations"] >= 1


def test_mis_speculation_on_queue_change(engine_parts):
    """A cancelled/replaced queue entry invalidates the predicted batch:
    the actual admission differs from the prefetched one, so the engine
    rolls back and retrieves synchronously for the real batch."""
    cfg, model, params = engine_parts

    def run(pipeline):
        meter = IOMeter()
        retr = _retriever("numpy", None, meter)
        eng = ServeEngine(model, params, max_slots=1, max_len=MAX_LEN,
                          eos_id=-1, context_fn=retr, pipeline=pipeline)
        reqs = _requests(cfg, retr.adj, 3, mnt=2)
        eng.submit(reqs[0])
        eng.submit(reqs[1])
        eng.step()                       # prefetch speculated for reqs[1]
        eng.queue.clear()                # reqs[1] cancelled...
        eng.submit(reqs[2])              # ...a different request replaces it
        eng.run_until_drained()
        return eng, retr, meter, eng.finished

    eng_s, retr_s, m_s, fin_s = run(False)
    eng_p, retr_p, m_p, fin_p = run(True)
    assert [r.request_id for r in fin_p] == [0, 2]
    _assert_identical(fin_s, fin_p, m_s, m_p, retr_s, retr_p)
    p = eng_p.stats()["pipeline"]
    assert p["mis_speculations"] >= 1


def test_prefetch_skipped_without_snapshot_support(engine_parts):
    """A context_fn without snapshot/restore cannot be rolled back, so
    the engine must never speculate against it."""
    cfg, model, params = engine_parts
    calls = []

    def ctx(vs):
        calls.append(np.asarray(vs).copy())
        return [np.zeros(0, np.int32)] * len(vs)

    eng = ServeEngine(model, params, max_slots=2, max_len=MAX_LEN,
                      eos_id=-1, context_fn=ctx, pipeline=True)
    for r in _requests(cfg, _fresh_lake()[0], 4, mnt=2):
        eng.submit(r)
    finished = eng.run_until_drained()
    assert len(finished) == 4
    p = eng.stats()["pipeline"]
    assert p["prefetch_issued"] == 0 and p["mis_speculations"] == 0
    assert len(calls) == 2               # one synchronous batch per admit


# ------------- double buffering + steady state without retraces -----------

def test_steady_state_double_buffered_no_retraces(engine_parts):
    """~100 warm ticks of pipelined serving: the dispatch plane must
    reuse exactly two staged output buffers per (engine, class) -- never
    a single aliased one -- and kernel trace counts must stay flat (no
    retrace per tick)."""
    from repro.kernels._pad import reset_trace_counts, trace_count
    from repro.kernels.pac_decode import ops as pac_ops
    cfg, model, params = engine_parts
    retr = _retriever("jax", None, IOMeter())
    eng = ServeEngine(model, params, max_slots=2, max_len=MAX_LEN,
                      eos_id=-1, context_fn=retr, pipeline=True)
    # one shared seed vertex -> constant prompt length; slots retire and
    # refill every other tick, so retrieval stays on the hot path
    v = int(np.flatnonzero(retr.adj.degrees() > 0)[0])
    rng = np.random.default_rng(1)
    reqs = [Request(i, rng.integers(4, cfg.vocab_size, size=6)
                    .astype(np.int32), max_new_tokens=3, context_vertex=v)
            for i in range(110)]
    for r in reqs[:10]:
        eng.submit(r)
    pac_ops.reset_dispatch_pools()
    reset_trace_counts()
    for _ in range(8):                   # warmup: traces + pool fill
        eng.step()
    warm = trace_count()
    ticks = 0
    for r in reqs[10:]:
        eng.submit(r)
    while (eng.queue or any(s is not None for s in eng.slots)) \
            and ticks < 200:
        eng.step()
        ticks += 1
    assert ticks >= 90
    assert trace_count() == warm         # zero retraces in steady state
    assert len(eng.finished) == len(reqs)
    assert eng.stats()["pipeline"]["prefetch_hits"] > 0


def test_words_pool_double_buffered_non_aliasing():
    """The fused dispatch's bitmap output ring must hold TWO distinct
    device buffers: donating the most recent output back into the next
    dispatch would alias a buffer the pipelined engine may still be
    consuming.  Steady state alternates between exactly two buffers per
    (engine, n_words) class, results staying bit-identical."""
    from repro.core import retrieve_neighbors_batch
    from repro.kernels.pac_decode import ops as pac_ops
    adj, _ = _fresh_lake()
    vs = np.flatnonzero(adj.degrees() > 0)[:16]
    want = retrieve_neighbors_batch(adj, vs, 128, engine="numpy").to_ids()
    pac_ops.reset_dispatch_pools()
    for _ in range(5):
        got = retrieve_neighbors_batch(adj, vs, 128, engine="jax")
        np.testing.assert_array_equal(got.to_ids(), want)
    rings = [r for r in pac_ops._WORDS_POOL.values() if len(r)]
    assert rings
    for ring in rings:
        assert len(ring) == 2            # steady state: exactly 2 planes
        a, b = ring
        assert a is not b
        assert a.unsafe_buffer_pointer() != b.unsafe_buffer_pointer()


# --------------------- admission clamping regression ----------------------

def test_admission_clamps_prompt_and_max_new_tokens(engine_parts):
    """A prompt at/over max_len used to overflow the slot's cache rows
    (silently dropped writes); admission now clamps the prompt to
    max_len - 2 and max_new_tokens to the remaining rows."""
    cfg, model, params = engine_parts
    max_len = 24
    eng = ServeEngine(model, params, max_slots=1, max_len=max_len,
                      eos_id=-1)
    rng = np.random.default_rng(3)
    req = Request(0, rng.integers(4, cfg.vocab_size, size=max_len + 5)
                  .astype(np.int32), max_new_tokens=10_000)
    eng.submit(req)
    finished = eng.run_until_drained()
    assert len(finished) == 1 and finished[0].done
    assert len(req.prompt) == max_len - 2
    assert req.max_new_tokens == max_len - 1 - len(req.prompt)
    assert len(req.output) <= req.max_new_tokens
    assert len(req.prompt) + len(req.output) <= max_len


def test_context_budget_respects_clamped_tokens(engine_parts):
    """Context attachment happens after clamping, so the context budget
    is computed from the clamped prompt/max_new_tokens pair and the slot
    still fits."""
    cfg, model, params = engine_parts
    max_len = 32
    retr = _retriever("numpy", None, None)
    eng = ServeEngine(model, params, max_slots=1, max_len=max_len,
                      eos_id=-1, context_fn=retr)
    v = int(np.flatnonzero(retr.adj.degrees() > 0)[0])
    rng = np.random.default_rng(4)
    req = Request(0, rng.integers(4, cfg.vocab_size, size=max_len * 2)
                  .astype(np.int32), max_new_tokens=99, context_vertex=v)
    eng.submit(req)
    finished = eng.run_until_drained()
    assert len(finished) == 1 and finished[0].done
    assert len(req.prompt) + len(req.output) <= max_len


# ------------------------------ env default -------------------------------

def test_pipeline_env_default(engine_parts, monkeypatch):
    cfg, model, params = engine_parts

    def mk(**kw):
        return ServeEngine(model, params, max_slots=1, max_len=16, **kw)

    monkeypatch.delenv("REPRO_PIPELINE", raising=False)
    assert mk().pipeline is True
    monkeypatch.setenv("REPRO_PIPELINE", "0")
    assert mk().pipeline is False
    assert mk(pipeline=True).pipeline is True      # explicit arg wins
    monkeypatch.setenv("REPRO_PIPELINE", "off")
    assert mk().pipeline is False
    monkeypatch.setenv("REPRO_PIPELINE", "1")
    assert mk().pipeline is True
    assert mk(pipeline=False).pipeline is False
    s = mk().stats()["pipeline"]
    for k in ("enabled", "prefetch_issued", "prefetch_hits",
              "mis_speculations", "pipeline_overlap_ms", "last_tick",
              "totals"):
        assert k in s
