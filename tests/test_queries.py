"""End-to-end query equivalence: GraphAr vs Acero-like baseline (§6.5)."""
import numpy as np
import pytest

from repro.core import IOMeter
from repro.core.query import (bi2_acero, bi2_graphar, build_snb_baseline,
                              build_snb_graphar, ic8_acero, ic8_graphar,
                              is3_acero, is3_graphar)
from repro.data.synthetic import ldbc_like


@pytest.fixture(scope="module")
def snb():
    return ldbc_like(scale=1, seed=0)


@pytest.fixture(scope="module")
def g(snb):
    return build_snb_graphar(snb, page_size=1024)


@pytest.fixture(scope="module")
def base(snb):
    return build_snb_baseline(snb, page_size=1024)


def test_is3_equivalence(snb, g, base):
    # probe several persons incl. a high-degree one
    deg = np.bincount(snb.knows_src, minlength=snb.num_persons)
    persons = [0, 17, int(np.argmax(deg))]
    for p in persons:
        f1, d1 = is3_graphar(g, p)
        f2, d2 = is3_acero(base, p)
        np.testing.assert_array_equal(np.sort(f1), np.sort(f2))
        np.testing.assert_array_equal(d1, d2)  # identical date ordering


def test_is3_io_advantage(snb, g, base):
    deg = np.bincount(snb.knows_src, minlength=snb.num_persons)
    p = int(np.argmax(deg))
    m1, m2 = IOMeter(), IOMeter()
    is3_graphar(g, p, m1)
    is3_acero(base, p, m2)
    assert m1.nbytes < m2.nbytes


def test_ic8_equivalence(snb, g, base):
    creators = np.unique(snb.has_creator_person)
    for p in [int(creators[0]), int(creators[len(creators) // 2])]:
        r1, d1 = ic8_graphar(g, p)
        r2, d2 = ic8_acero(base, p)
        np.testing.assert_array_equal(r1, r2)
        np.testing.assert_array_equal(d1, d2)


def test_ic8_label_pushdown_equivalence(snb, g, base):
    # reply_label pushes the predicate into the hop-2 batched retrieval;
    # every engine must agree with the string-label acero baseline
    from _engines import engines
    creators = np.unique(snb.has_creator_person)
    p = int(creators[len(creators) // 3])
    r2, d2 = ic8_acero(base, p, reply_label="TagClass1")
    for engine in engines():
        r1, d1 = ic8_graphar(g, p, engine=engine, reply_label="TagClass1")
        np.testing.assert_array_equal(r1, r2)
        np.testing.assert_array_equal(d1, d2)


def test_bi2_equivalence(snb, g, base):
    from _engines import engines
    for cls in ["TagClass0", "TagClass3"]:
        c2 = bi2_acero(base, cls)
        for engine in engines():
            c1 = bi2_graphar(g, cls, engine=engine)
            assert c1 == c2


def test_bi2_io_advantage(snb, g, base):
    m1, m2 = IOMeter(), IOMeter()
    bi2_graphar(g, "TagClass1", m1)
    bi2_acero(base, "TagClass1", m2)
    assert m1.nbytes < m2.nbytes
