"""Device-resident column plane: mirror lifecycle, version invalidation,
bit-identical resident/non-resident results, and zero-retrace dispatch.

Residency is a *transfer* optimization: the packed column crosses to the
device once per (column build, engine) and every dispatch ships only
page-index / row-position vectors.  Nothing observable may change --
ids, PACs, and IOMeter accounting are pinned against both the
per-dispatch pack path and the numpy oracle.
"""
import numpy as np
import pytest

from _engines import engines
from repro.core import (BY_SRC, ENC_GRAPHAR, IOMeter, L, LabelFilter, PAC,
                        attach_page_cache, build_adjacency, pack_column,
                        retrieve_neighbors_batch)
from repro.core.encoding import delta_encode_column, delta_encode_page
from repro.core.page_cache import live_cache
from repro.data.synthetic import clustered_labels, powerlaw_graph
from repro.kernels import _pad
from repro.kernels.pac_decode import ops as pdo

N = 2000
PAGE = 256
TPS = 512


@pytest.fixture(scope="module")
def adj():
    src, dst = powerlaw_graph(N, 6, seed=13)
    return build_adjacency(src, dst, N, N, BY_SRC, ENC_GRAPHAR,
                           page_size=PAGE)


@pytest.fixture(scope="module")
def vt():
    from repro.core.schema import VertexTypeSchema
    from repro.core.vertex import VertexTable
    labels = clustered_labels(N, ["A", "B"], density=0.3, run_scale=64,
                              seed=7)
    return VertexTable.build(VertexTypeSchema("v", [], labels=["A", "B"]),
                             {}, labels, num_vertices=N)


@pytest.fixture(scope="module")
def batch():
    rng = np.random.default_rng(17)
    return rng.integers(0, N, 64)


# ------------------------------ mirror lifecycle ---------------------------

def test_mirror_lazy_once_per_engine():
    vals = np.sort(np.random.default_rng(0).integers(0, 1 << 20, 4 * PAGE))
    col = delta_encode_column(vals, PAGE)
    packed = pack_column(col)
    assert packed.device_transfers == 0          # lazy: nothing yet
    m1 = packed.device("jax")
    assert packed.device_transfers == 1
    assert packed.device("jax") is m1            # exactly once per engine
    m2 = packed.device("pallas")
    assert m2 is not m1
    assert packed.device_transfers == 2
    assert packed.device_stats()["engines"] == ["jax", "pallas"]
    np.testing.assert_array_equal(np.asarray(m1[4]), packed.packed)
    # the decode-ready unpack plan is mirrored the same way (what the
    # resident dispatch paths actually consume)
    p1 = packed.device_plan("jax")
    assert packed.device_plan("jax") is p1
    assert packed.device_transfers == 3


def test_unpack_plan_decodes_like_the_oracle():
    from repro.core.encoding import (POS_BW_MASK, POS_SHIFT_SHIFT,
                                     POS_WIDX_SHIFT, delta_decode_column)
    vals = np.sort(np.random.default_rng(8).integers(0, 1 << 20,
                                                     3 * PAGE + 11))
    col = delta_encode_column(vals, PAGE)
    first, pos, mind, packed = pack_column(col).unpack_plan()
    widx = pos >> POS_WIDX_SHIFT
    shift = ((pos >> POS_SHIFT_SHIFT) & 31).astype(np.uint32)
    bw = (pos & POS_BW_MASK).astype(np.uint64)
    mask = ((np.uint64(1) << bw) - 1).astype(np.uint32)
    mask[bw >= 32] = np.uint32(0xFFFFFFFF)
    words = np.take_along_axis(packed, widx, axis=1)
    resid = ((words >> shift) & mask).astype(np.int64)
    ids = np.concatenate(
        [np.zeros((len(col.pages), 1), np.int64),
         np.cumsum(resid + mind, axis=1)], axis=1) + first
    flat = np.concatenate([ids[i, :p.count]
                           for i, p in enumerate(col.pages)])
    np.testing.assert_array_equal(flat, delta_decode_column(col))


def test_mirror_invalidated_on_version_bump():
    vals = np.sort(np.random.default_rng(1).integers(0, 1 << 20,
                                                     3 * PAGE + 17))
    col = delta_encode_column(vals, PAGE)
    packed = pack_column(col)
    old_mirror = packed.device("jax")
    # in-place rewrite of the last partial page: page count unchanged
    new_tail = np.sort(np.random.default_rng(2).integers(0, 1 << 20, 17))
    col.set_page(len(col.pages) - 1, delta_encode_page(new_tail))
    repacked = pack_column(col)
    assert repacked is not packed                # cache keyed on version
    assert repacked.version == col.version
    fresh = repacked.device("jax")
    assert fresh is not old_mirror               # mirror died with the build
    got = np.asarray(fresh[0][-1, 0])
    assert got == new_tail[0]
    fresh_plan = repacked.device_plan("jax")
    assert np.asarray(fresh_plan[0][-1, 0]) == new_tail[0]


# --------------------- staleness regression (satellite) --------------------

@pytest.mark.parametrize("engine", engines())
def test_in_place_page_write_never_serves_stale(engine):
    vals = np.sort(np.random.default_rng(3).integers(0, 1 << 20,
                                                     3 * PAGE + 29))
    col = delta_encode_column(vals, PAGE)
    attach_page_cache(col, 64)
    los = np.array([0, 3 * PAGE])
    his = np.array([PAGE, 3 * PAGE + 29])
    before = pdo.decode_row_ranges(col, los, his, engine=engine)
    new_tail = np.sort(np.random.default_rng(4).integers(0, 1 << 20, 29))
    col.set_page(3, delta_encode_page(new_tail))
    after = pdo.decode_row_ranges(col, los, his, engine=engine)
    np.testing.assert_array_equal(after[:PAGE], before[:PAGE])
    np.testing.assert_array_equal(after[PAGE:], new_tail)
    col.page_cache = None


def test_live_cache_drops_entries_on_version_bump():
    vals = np.sort(np.random.default_rng(5).integers(0, 1 << 20, 2 * PAGE))
    col = delta_encode_column(vals, PAGE)
    cache = attach_page_cache(col, 8)
    pdo.decode_row_ranges(col, np.array([0]), np.array([2 * PAGE]),
                          engine="numpy")
    assert len(cache) == 2
    col.bump_version()
    assert live_cache(col) is cache              # same object, emptied
    assert len(cache) == 0 and cache.version == col.version
    col.page_cache = None


def test_version_bump_recharges_io():
    vals = np.sort(np.random.default_rng(6).integers(0, 1 << 20, 2 * PAGE))
    col = delta_encode_column(vals, PAGE)
    attach_page_cache(col, 8)
    pdo.decode_row_ranges(col, np.array([0]), np.array([2 * PAGE]),
                          engine="numpy")
    m = IOMeter()
    pdo.decode_row_ranges(col, np.array([0]), np.array([2 * PAGE]), m,
                          engine="numpy")
    assert m.nbytes == 0                         # warm: all hits
    col.bump_version()
    m2 = IOMeter()
    pdo.decode_row_ranges(col, np.array([0]), np.array([2 * PAGE]), m2,
                          engine="numpy")
    assert m2.nbytes == col.nbytes()             # stale decodes re-fetched
    col.page_cache = None


# ------------------- resident == per-dispatch == oracle --------------------

@pytest.mark.parametrize("engine", engines(kernel_only=True))
def test_resident_bit_identical_and_meters_unchanged(adj, batch, engine):
    want = retrieve_neighbors_batch(adj, batch, TPS)         # numpy oracle
    m_res, m_leg, m_np = IOMeter(), IOMeter(), IOMeter()
    res = retrieve_neighbors_batch(adj, batch, TPS, m_res, engine=engine,
                                   fused=True, resident=True)
    leg = retrieve_neighbors_batch(adj, batch, TPS, m_leg, engine=engine,
                                   fused=True, resident=False)
    retrieve_neighbors_batch(adj, batch, TPS, m_np)
    assert res == leg == want
    np.testing.assert_array_equal(res.to_ids(), want.to_ids())
    assert (m_res.nbytes, m_res.nrequests) \
        == (m_leg.nbytes, m_leg.nrequests) \
        == (m_np.nbytes, m_np.nrequests)


@pytest.mark.parametrize("engine", engines(kernel_only=True))
def test_resident_filtered_bit_identical(adj, vt, batch, engine):
    cond = L("A") | ~L("B")
    m_res, m_np = IOMeter(), IOMeter()
    res = retrieve_neighbors_batch(adj, batch, TPS, m_res, engine=engine,
                                   fused=True, resident=True,
                                   filter=LabelFilter(vt, cond))
    want = retrieve_neighbors_batch(adj, batch, TPS, m_np,
                                    filter=LabelFilter(vt, cond))
    assert res == want
    assert (m_res.nbytes, m_res.nrequests) == (m_np.nbytes, m_np.nrequests)


@pytest.mark.parametrize("engine", engines(kernel_only=True))
def test_resident_with_warm_lru_matches_and_charges_nothing(adj, batch,
                                                            engine):
    col = adj.table["<dst>"]
    cache = attach_page_cache(col, 4096)
    try:
        cache.clear()
        want = retrieve_neighbors_batch(adj, batch, TPS)
        p1 = retrieve_neighbors_batch(adj, batch, TPS, engine=engine,
                                      fused=True, resident=True)
        m_warm = IOMeter()
        p2 = retrieve_neighbors_batch(adj, batch, TPS, m_warm,
                                      engine=engine, fused=True,
                                      resident=True)
        assert p1 == p2 == want
        m_off = IOMeter()
        adj.edge_ranges_batch(batch, m_off)
        assert (m_warm.nbytes, m_warm.nrequests) == (m_off.nbytes,
                                                     m_off.nrequests)
        assert cache.hits > 0
    finally:
        col.encoded.page_cache = None


def test_filter_plan_device_bitmap_cached_once(vt):
    filt = LabelFilter(vt, L("A") & L("B"))
    plan = filt.plan()
    w1 = plan.device_bitmap("jax", plan.n_words)
    assert plan.device_bitmap("jax", plan.n_words) is w1
    # matches the host-evaluated bitmap bit for bit
    np.testing.assert_array_equal(np.asarray(w1), filt.bitmap("numpy"))


# --------------------------- dispatch-cost plane ---------------------------

@pytest.mark.parametrize("engine", engines(kernel_only=True))
def test_steady_state_dispatches_do_not_retrace(adj, engine):
    rng = np.random.default_rng(23)
    sizes = rng.integers(40, 64, size=8)         # one pow2 class of ranges
    batches = [rng.integers(0, N, s) for s in sizes]
    for vs in batches:                            # warm every size class
        retrieve_neighbors_batch(adj, vs, TPS, engine=engine, fused=True,
                                 resident=True)
    before = _pad.trace_count()
    for vs in batches:
        retrieve_neighbors_batch(adj, vs, TPS, engine=engine, fused=True,
                                 resident=True)
    assert _pad.trace_count() == before          # jit cache hits only


def test_size_class_floors_collapse_small_shapes():
    assert _pad.size_class(3, 8) == 8
    assert _pad.size_class(9, 8) == 16
    assert _pad.size_class(0, 1) == 1
    assert _pad.next_pow2(0) == 1 and _pad.next_pow2(5) == 8
    assert _pad.next_multiple(5, 4) == 8


def test_empty_batch_and_empty_ranges_resident(adj):
    pac = retrieve_neighbors_batch(adj, np.zeros(0, np.int64), TPS,
                                   engine="jax", fused=True, resident=True)
    assert pac.count() == 0
    got = pdo.retrieve_pac_batch(
        adj.table["<dst>"].encoded, np.array([5]), np.array([5]), TPS,
        engine="jax", num_targets=N, fused=True, resident=True)
    assert got == PAC(TPS)
