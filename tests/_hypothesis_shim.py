"""Optional-import shim for ``hypothesis``.

The property-based tests use hypothesis when it is installed; without it
the modules must still collect so the deterministic tests run.  Importing
``given``/``settings``/``st`` from here instead of ``hypothesis`` keeps
both worlds working: with hypothesis present this re-exports the real
objects; without it, ``@given`` marks the test as skipped and ``st``
degrades to an inert strategy stub.
"""
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised when hypothesis absent
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _StrategyStub:
        """Accepts any attribute/call chain (st.lists(st.integers()).map(...))."""

        def __getattr__(self, _name):
            return self

        def __call__(self, *_args, **_kwargs):
            return self

    st = _StrategyStub()
