"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles +
cross-checks against the numpy storage-plane codecs."""
import numpy as np
import pytest
import jax.numpy as jnp
from _hypothesis_shim import given, settings, st

from repro.core.encoding import (delta_decode_column, delta_encode_column,
                                 rle_encode_bool)
from repro.core.pac import PAC, bitmap_to_ids
from repro.kernels.pac_decode import kernel as pdk
from repro.kernels.pac_decode import ops as pdo
from repro.kernels.pac_decode import ref as pdr
from repro.kernels.rle_filter import ops as rfo
from repro.kernels.bitmap_select import kernel as bsk
from repro.kernels.bitmap_select import ops as bso
from repro.kernels.bitmap_select import ref as bsr
from repro.kernels.flash_attention import kernel as fak
from repro.kernels.flash_attention import ref as far


# ------------------------------ pac_decode -------------------------------

@pytest.mark.parametrize("page_size", [256, 1024, 2048])
@pytest.mark.parametrize("spread", [8, 4096, 1 << 18])  # ids stay < 2^31
def test_delta_decode_kernel_matches_numpy(page_size, spread):
    rng = np.random.default_rng(page_size + spread)
    n = 3 * page_size + 17   # partial last page
    vals = np.sort(rng.integers(0, spread * n, size=n))
    col = delta_encode_column(vals, page_size)
    got = pdo.decode_pages(col, 0, len(col.pages), use_pallas=True)
    np.testing.assert_array_equal(got, vals)
    got_ref = pdo.decode_pages(col, 0, len(col.pages), use_pallas=False)
    np.testing.assert_array_equal(got_ref, vals)


def test_delta_decode_kernel_vs_ref_same_inputs():
    rng = np.random.default_rng(7)
    vals = np.sort(rng.integers(0, 1 << 26, size=4096))
    col = delta_encode_column(vals, 1024)
    args = [jnp.asarray(a) for a in pdo.pack_pages(col, 0, len(col.pages))]
    out_k = pdk.delta_decode_pallas(*args, page_size=1024)
    out_r = pdr.decode_pages_ref(*args, page_size=1024)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


@given(st.integers(min_value=1, max_value=3000),
       st.integers(min_value=0, max_value=1 << 16))
@settings(max_examples=20, deadline=None)
def test_delta_decode_kernel_property(n, seed):
    rng = np.random.default_rng(seed)
    vals = np.sort(rng.integers(0, 1 << 24, size=n))
    col = delta_encode_column(vals, 512)
    got = pdo.decode_pages(col, 0, len(col.pages), use_pallas=True)
    np.testing.assert_array_equal(got, vals)


def test_bitmap_kernel_matches_pac():
    rng = np.random.default_rng(3)
    ids = np.unique(rng.integers(0, 40_000, size=2000)).astype(np.int64)
    n_words = -(-40_000 // 32)
    bm_k = pdo.ids_to_bitmap(ids, 0, n_words, use_pallas=True)
    bm_r = pdo.ids_to_bitmap(ids, 0, n_words, use_pallas=False)
    np.testing.assert_array_equal(bm_k, bm_r)
    np.testing.assert_array_equal(bitmap_to_ids(bm_k, 0), ids)


def test_bitmap_kernel_with_duplicates_and_base():
    ids = np.array([64, 64, 64, 100, 4000, 4000], np.int64)
    bm = pdo.ids_to_bitmap(ids, 64, 256, use_pallas=True)
    np.testing.assert_array_equal(bitmap_to_ids(bm, 64),
                                  np.unique(ids))


def test_fused_decode_bitmap_page_aligned():
    rng = np.random.default_rng(11)
    vals = np.sort(rng.integers(0, 30_000, size=2048))
    vals = np.unique(vals)
    pad = 2048 - len(vals) % 2048 if len(vals) % 2048 else 0
    col = delta_encode_column(vals, 1024)
    lo, hi = 0, col.count
    n_words = -(-30_000 // 32)
    bm_k = pdo.decode_range_to_bitmap(col, lo, hi, 0, n_words,
                                      use_pallas=True)
    bm_r = pdo.decode_range_to_bitmap(col, lo, hi, 0, n_words,
                                      use_pallas=False)
    np.testing.assert_array_equal(bm_k, bm_r)
    np.testing.assert_array_equal(bitmap_to_ids(bm_k, 0), vals)


def test_retrieve_pac_engines_agree():
    rng = np.random.default_rng(5)
    vals = np.sort(rng.integers(0, 100_000, size=10_000))
    col = delta_encode_column(vals, 2048)
    lo, hi = 3000, 7003
    pac_np = PAC.from_ids(vals[lo:hi], 2048)
    pac_k = pdo.retrieve_pac(col, lo, hi, 2048, use_pallas=True)
    np.testing.assert_array_equal(pac_k.to_ids(), pac_np.to_ids())


# ------------------------------ rle_filter -------------------------------

@pytest.mark.parametrize("n", [100, 2048, 50_000])
@pytest.mark.parametrize("want", [True, False])
def test_rle_filter_kernel_matches_dense(n, want):
    rng = np.random.default_rng(n)
    dense = rng.random(n) < 0.3
    col = rle_encode_bool(dense)
    bm_k = rfo.rle_to_bitmap(col, want, use_pallas=True)
    bm_r = rfo.rle_to_bitmap(col, want, use_pallas=False)
    np.testing.assert_array_equal(bm_k, bm_r)
    expect = np.flatnonzero(dense == want)
    np.testing.assert_array_equal(bitmap_to_ids(bm_k, 0), expect)


@given(st.lists(st.booleans(), min_size=1, max_size=500))
@settings(max_examples=20, deadline=None)
def test_rle_filter_property(bits):
    dense = np.array(bits, bool)
    col = rle_encode_bool(dense)
    bm = rfo.rle_to_bitmap(col, True, use_pallas=True)
    np.testing.assert_array_equal(bitmap_to_ids(bm, 0), np.flatnonzero(dense))


# ----------------------------- bitmap_select -----------------------------

@pytest.mark.parametrize("page_size", [256, 2048])
def test_bitmap_select_matches_ref(page_size):
    rng = np.random.default_rng(page_size)
    n_pages = 3
    vals = rng.standard_normal((n_pages, page_size)).astype(np.float32)
    dense = rng.random((n_pages, page_size)) < 0.2
    words = np.zeros((n_pages, page_size // 32), np.uint32)
    for p in range(n_pages):
        idx = np.flatnonzero(dense[p])
        np.bitwise_or.at(words[p], idx >> 5,
                         np.uint32(1) << (idx & 31).astype(np.uint32))
    out_k, cnt_k = bsk.bitmap_select_pallas(jnp.asarray(vals),
                                            jnp.asarray(words),
                                            page_size=page_size)
    out_r, cnt_r = bsr.bitmap_select_ref(jnp.asarray(vals),
                                         jnp.asarray(words), page_size)
    np.testing.assert_array_equal(np.asarray(cnt_k).ravel(),
                                  np.asarray(cnt_r).ravel())
    for p in range(n_pages):
        c = int(np.asarray(cnt_k)[p, 0])
        np.testing.assert_allclose(np.asarray(out_k)[p, :c],
                                   vals[p][dense[p]])
        np.testing.assert_allclose(np.asarray(out_k)[p, :c],
                                   np.asarray(out_r)[p, :c])


def test_bitmap_select_ops_end_to_end():
    rng = np.random.default_rng(1)
    n = 10_000
    vals = rng.standard_normal(n).astype(np.float32)
    ids = np.unique(rng.integers(0, n, 500))
    pac = PAC.from_ids(ids, 2048)
    pages = {p: vals[p * 2048:(p + 1) * 2048] for p in pac.pages()}
    got = bso.select_from_pages(pac, pages, use_pallas=True)
    np.testing.assert_allclose(got, vals[ids])


# ---------------------------- flash_attention ----------------------------

@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("seq,d", [(128, 64), (256, 64), (384, 128)])
def test_flash_attention_matches_ref(causal, seq, d):
    rng = np.random.default_rng(seq + d)
    bh = 2
    q = rng.standard_normal((bh, seq, d)).astype(np.float32)
    k = rng.standard_normal((bh, seq, d)).astype(np.float32)
    v = rng.standard_normal((bh, seq, d)).astype(np.float32)
    out = fak.flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                              causal=causal, block_q=128, block_k=128)
    ref = far.attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                            causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 256, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 256, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 256, 64)), jnp.bfloat16)
    out = fak.flash_attention(q, k, v, causal=True)
    ref = far.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=0.1, atol=0.1)


def test_flash_attention_gqa_wrapper():
    from repro.kernels.flash_attention import ops as fao
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.standard_normal((2, 8, 128, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 2, 128, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 2, 128, 64)), jnp.float32)
    out = fao.mha(q, k, v, causal=True, use_pallas=True)
    ref = fao.mha(q, k, v, causal=True, use_pallas=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
