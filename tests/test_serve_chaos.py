"""Serving-plane chaos: every admitted request finishes or fails typed.

Each test arms one serving-plane fault boundary (``serve.retrieval``,
``serve.prefill``, ``serve.spec_commit``, ``serve.ingest``) with a
seeded :class:`~repro.ft.faults.FaultPlan` against a multi-tenant,
pipelined engine, then asserts the full chaos invariant:

* every admitted request either finishes with tokens **bit-identical**
  to an unthrottled sequential oracle (no faults, no tenancy, no
  pipeline) or is reported failed with a typed
  :class:`~repro.serve.tenancy.RequestStatus` -- none lost, none
  double-answered;
* the armed boundary actually fired (a chaos test that never injects is
  a placebo) and every injection was recovered;
* the engine keeps ticking afterwards: fresh submissions drain clean.

The oracle works per request id, not per batch: a request's retrieval
depends only on its own ``context_vertex`` and its decode only on its
own cache rows, so DWRR reordering and different batch grouping must not
change any request's tokens.  ``REPRO_FAULT_SEED`` varies the per-
boundary trip counts, as in the CI fault matrix.
"""
import os

import numpy as np
import pytest

from _engines import engines
from repro.core import (BY_SRC, EdgeTypeSchema, GraphArBuilder, IOMeter,
                        PropertySchema, VertexTypeSchema)
from repro.data.synthetic import document_graph
from repro.ft.faults import SERVE_BOUNDARIES, FaultPlan
from repro.serve.engine import Request, ServeEngine
from repro.serve.retrieval import GraphRetriever
from repro.serve.tenancy import RequestStatus, TenantConfig

MAX_LEN = 96
SEED = int(os.environ.get("REPRO_FAULT_SEED", "1"))


@pytest.fixture(scope="module")
def engine_parts():
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("smollm-360m").reduced().with_(n_units=2)
    model = build_model(cfg)
    return cfg, model, model.init(0)


def _fresh_lake(num_docs=200, seed=5):
    lake = document_graph(num_docs=num_docs, vocab=512, mean_len=32,
                          seed=seed)
    b = GraphArBuilder("docs")
    b.add_vertices(
        VertexTypeSchema("doc", [PropertySchema("tokens", "tokens")],
                         labels=list(lake.labels), page_size=128),
        {"tokens": lake.tokens}, lake.labels)
    b.add_edges(EdgeTypeSchema("doc", "links", "doc", page_size=128),
                lake.links_src, lake.links_dst)
    g = b.build()
    return g.adjacency("doc-links-doc", BY_SRC), \
        g.vertex("doc").table["tokens"]


def _retriever(engine):
    adj, tok = _fresh_lake()
    return GraphRetriever(adj, tok, max_neighbors=2, tokens_per_neighbor=8,
                          meter=IOMeter(), engine=engine,
                          page_cache_pages=64)


def _requests(cfg, adj, n, mnt=3, tenants=("prod", "batch")):
    """Deterministic request set; rebuilt fresh for each engine run
    (the engine mutates Request objects in place).  Seed vertices come
    from the first half of the id space so ingested edges rooted in the
    second half can never touch a request's context."""
    rng = np.random.default_rng(11)
    deg = adj.degrees()
    seeds = np.flatnonzero(deg[:len(deg) // 2] > 0)
    vs = seeds[rng.integers(0, len(seeds), n)]
    out = []
    for i, v in enumerate(vs):
        r = Request(i, rng.integers(4, cfg.vocab_size, size=6)
                    .astype(np.int32), max_new_tokens=mnt,
                    context_vertex=int(v))
        r.tenant = tenants[i % len(tenants)]
        out.append(r)
    return out


def _ingest_edges(adj):
    """An edge batch rooted strictly outside the seed-vertex half: the
    mutation epoch moves (prefetches invalidate + roll back) but no
    request's retrieved context changes, so the no-ingest oracle stays
    valid."""
    n = len(adj.degrees())
    src = [n - 1, n - 2]
    dst = [0, 1]
    return src, dst


def _oracle(model, params, cfg, engine, n):
    """Unthrottled, sequential, fault-free ground truth, per request id."""
    retr = _retriever(engine)
    eng = ServeEngine(model, params, max_slots=3, max_len=MAX_LEN,
                      eos_id=-1, context_fn=retr, pipeline=False)
    for r in _requests(cfg, retr.adj, n):
        r.tenant = "default"
        assert eng.submit(r).admitted
    fin = eng.run_until_drained()
    assert len(fin) == n
    return {r.request_id: r for r in fin}


def _check_against_oracle(fin, oracle):
    for r in fin:
        if r.status is not RequestStatus.OK:
            continue
        o = oracle[r.request_id]
        np.testing.assert_array_equal(r.prompt, o.prompt)
        assert r.output == o.output, f"request {r.request_id} diverged"
        assert r.context_tokens == o.context_tokens


def _tenants():
    return [TenantConfig("prod", weight=3, max_queue=64),
            TenantConfig("batch", weight=1, max_queue=64)]


@pytest.fixture(scope="module")
def oracles(engine_parts):
    cfg, model, params = engine_parts
    return {e: _oracle(model, params, cfg, e, 10) for e in engines()}


# ----------------------- one boundary at a time ---------------------------

@pytest.mark.parametrize("boundary", SERVE_BOUNDARIES)
@pytest.mark.parametrize("engine", engines())
def test_chaos_boundary_bit_identical_or_typed(engine_parts, oracles,
                                               engine, boundary):
    cfg, model, params = engine_parts
    k = SERVE_BOUNDARIES.index(boundary)
    trips = 1 + (SEED + k) % 2
    plan = FaultPlan({boundary: trips})
    retr = _retriever(engine)
    eng = ServeEngine(model, params, max_slots=3, max_len=MAX_LEN,
                      eos_id=-1, context_fn=retr, pipeline=True,
                      tenants=_tenants(), faults=plan)
    reqs = _requests(cfg, retr.adj, 10)
    for r in reqs:
        assert eng.submit(r).admitted
    eng.step()
    eng.step()
    eng.ingest(*_ingest_edges(retr.adj))   # mid-drain mutation
    eng.run_until_drained()
    fin = eng.finished                     # includes the manual-step ticks

    # none lost, none double-answered
    ids = sorted(r.request_id for r in fin)
    assert ids == [r.request_id for r in reqs]
    assert all(r.status is RequestStatus.OK for r in fin)
    _check_against_oracle(fin, oracles[engine])

    # the armed boundary fired and every injection recovered
    assert eng.fault_hits.get(boundary, 0) >= 1, \
        f"{boundary} never injected -- placebo chaos"
    s = eng.stats()["faults"]
    assert s["plan"]["fired"][boundary] == trips
    assert s["plan"]["remaining"] == 0
    assert s["recovered"] == sum(s["injected"].values())

    # the engine keeps ticking after the chaos drain
    more = _requests(cfg, retr.adj, 2)
    for r in more:
        r.request_id += 100
        assert eng.submit(r).admitted
    fin2 = eng.run_until_drained()
    assert sorted(r.request_id for r in fin2) == [100, 101]
    assert all(r.status is RequestStatus.OK for r in fin2)


# -------------------- all boundaries armed together -----------------------

@pytest.mark.parametrize("engine", engines())
def test_chaos_all_boundaries_with_deadlines(engine_parts, oracles, engine):
    """Everything at once: all four serving boundaries armed, rate limits
    and deadlines live.  Every submitted request ends in exactly one
    typed bucket (OK / DEADLINE_EXCEEDED / REJECTED); the OK ones are
    bit-identical to the oracle."""
    cfg, model, params = engine_parts
    plan = FaultPlan.from_seed(SEED, boundaries=SERVE_BOUNDARIES,
                               max_trips=2)
    retr = _retriever(engine)
    tenants = [TenantConfig("prod", weight=3, max_queue=64),
               TenantConfig("batch", weight=1, rate=2.0, burst=6.0,
                            max_queue=4, deadline_ticks=30)]
    eng = ServeEngine(model, params, max_slots=3, max_len=MAX_LEN,
                      eos_id=-1, context_fn=retr, pipeline=True,
                      tenants=tenants, faults=plan)
    reqs = _requests(cfg, retr.adj, 10)
    admitted, rejected = [], []
    for r in reqs:
        (admitted if eng.submit(r).admitted else rejected).append(r)
    eng.step()
    eng.ingest(*_ingest_edges(retr.adj))
    eng.run_until_drained()
    fin = eng.finished                     # includes the manual-step tick

    # exactly-one-bucket accounting over every submitted id
    fin_ids = [r.request_id for r in fin]
    rej_ids = [r.request_id for r in eng.rejected]
    assert sorted(fin_ids + rej_ids) == [r.request_id for r in reqs]
    assert rej_ids == [r.request_id for r in rejected]
    for r in fin:
        assert r.status in (RequestStatus.OK,
                            RequestStatus.DEADLINE_EXCEEDED)
    for r in eng.rejected:
        assert r.status is RequestStatus.REJECTED
    _check_against_oracle(fin, oracles[engine])

    # at least one boundary fired (from_seed arms >= 1 trip somewhere)
    assert sum(eng.fault_hits.values()) >= 1
    s = eng.stats()["faults"]
    assert s["recovered"] == sum(s["injected"].values())

    # tenant accounting agrees with the typed buckets
    ts = eng.stats()["tenants"]
    assert sum(t["finished_ok"] + t["finished_failed"]
               for t in ts.values()) == len(fin)
    assert sum(t["rejected_rate"] + t["rejected_queue_full"]
               for t in ts.values()) == len(rejected)


# --------------------- fault during ingest is atomic ----------------------

def test_chaos_ingest_fault_preserves_batch_atomicity(engine_parts):
    """A serve.ingest injection happens *before* the delta-plane append:
    after retry the batch lands exactly once -- neighbor sets show no
    duplicate edges and the epoch moved exactly once per batch."""
    cfg, model, params = engine_parts
    retr = _retriever("numpy")
    eng = ServeEngine(model, params, max_slots=2, max_len=MAX_LEN,
                      eos_id=-1, context_fn=retr,
                      faults=FaultPlan({"serve.ingest": 2}))
    src, dst = _ingest_edges(retr.adj)
    delta = eng.ingest(src, dst)
    assert eng.fault_hits.get("serve.ingest", 0) == 2
    # the batch landed exactly once, not once per retry attempt
    assert retr.ingest_calls == 1
    assert delta.pending_rows() == len(src)
