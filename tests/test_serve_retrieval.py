"""Serving engine: run_until_drained + batched per-tick context retrieval."""
import numpy as np
import pytest

from repro.core import (BY_SRC, EdgeTypeSchema, GraphArBuilder,
                        PropertySchema, VertexTypeSchema)
from repro.data.synthetic import document_graph
from repro.serve.retrieval import GraphRetriever


@pytest.fixture(scope="module")
def engine_parts():
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("smollm-360m").reduced().with_(n_units=2)
    model = build_model(cfg)
    return cfg, model, model.init(0)


@pytest.fixture(scope="module")
def doc_graph():
    lake = document_graph(num_docs=400, vocab=512, mean_len=32, seed=5)
    b = GraphArBuilder("docs")
    b.add_vertices(
        VertexTypeSchema("doc", [PropertySchema("tokens", "tokens")],
                         labels=list(lake.labels), page_size=128),
        {"tokens": lake.tokens}, lake.labels)
    b.add_edges(EdgeTypeSchema("doc", "links", "doc", page_size=128),
                lake.links_src, lake.links_dst)
    return b.build(), lake


@pytest.fixture(scope="module")
def doc_lake(doc_graph):
    g, _ = doc_graph
    return g.adjacency("doc-links-doc", BY_SRC), \
        g.vertex("doc").table["tokens"]


def test_run_until_drained_returns_finished(engine_parts):
    from repro.serve.engine import Request, ServeEngine
    cfg, model, params = engine_parts
    eng = ServeEngine(model, params, max_slots=2, max_len=96, eos_id=-1)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(4, cfg.vocab_size, size=6 + i)
                    .astype(np.int32), max_new_tokens=4)
            for i in range(5)]
    for r in reqs:
        eng.submit(r)
    finished = eng.run_until_drained()
    assert len(finished) == len(reqs)
    assert {r.request_id for r in finished} == {r.request_id for r in reqs}
    assert all(r.done and len(r.output) >= 1 for r in finished)
    assert not eng.queue and all(s is None for s in eng.slots)
    # a second drain returns only newly retired requests
    assert eng.run_until_drained() == []


def test_graph_retriever_batches_per_call(doc_lake):
    adj, tokens_col = doc_lake
    r = GraphRetriever(adj, tokens_col, max_neighbors=2,
                       tokens_per_neighbor=8)
    vs = np.array([0, 3, 3, 7])
    ctx = r(vs)
    assert r.calls == 1 and r.vertices_seen == 4
    assert len(ctx) == len(vs)
    for v, c in zip(vs, ctx):
        nbrs = adj.neighbor_ids(int(v))[:2]
        want = (np.concatenate([tokens_col.get(int(n))[:8] for n in nbrs])
                if len(nbrs) else np.zeros(0, np.int32))
        np.testing.assert_array_equal(c, want.astype(np.int32))


def test_engine_attaches_context_one_retrieval_per_tick(engine_parts,
                                                        doc_lake):
    from repro.serve.engine import Request, ServeEngine
    cfg, model, params = engine_parts
    adj, tokens_col = doc_lake
    retr = GraphRetriever(adj, tokens_col, max_neighbors=1,
                          tokens_per_neighbor=4)
    eng = ServeEngine(model, params, max_slots=4, max_len=96, eos_id=-1,
                      context_fn=retr)
    # pick seeds that definitely have neighbors
    deg = adj.degrees()
    seeds = np.flatnonzero(deg > 0)[:4]
    for i, v in enumerate(seeds):
        eng.submit(Request(i, np.arange(4, 10, dtype=np.int32),
                           max_new_tokens=3, context_vertex=int(v)))
    finished = eng.run_until_drained()
    assert len(finished) == len(seeds)
    # all 4 admitted in tick 1 -> exactly one batched retrieval
    assert retr.calls == 1
    assert retr.vertices_seen == len(seeds)
    assert all(r.context_tokens > 0 for r in finished)
    # engine surfaces the retrieval plane's counters
    stats = eng.stats()
    assert stats["finished"] == len(seeds)
    assert stats["retrieval"]["calls"] == 1
    assert "page_cache" in stats["retrieval"]


def test_retriever_warm_ticks_charge_less(doc_lake):
    from repro.core import IOMeter
    adj, tokens_col = doc_lake
    m = IOMeter()
    r = GraphRetriever(adj, tokens_col, max_neighbors=2,
                       tokens_per_neighbor=8, meter=m, page_cache_pages=64)
    r.page_cache.clear()
    r.page_cache.reset_stats()
    vs = np.flatnonzero(adj.degrees() > 0)[:8]
    c1 = r(vs)
    cold = m.nbytes
    c2 = r(vs)
    warm = m.nbytes - cold
    assert warm < cold                     # decode served from the LRU
    for a, b in zip(c1, c2):
        np.testing.assert_array_equal(a, b)
    s = r.stats()
    assert s["calls"] == 2
    assert s["page_cache"]["hits"] > 0


def test_retriever_cache_opt_out_detaches(doc_lake):
    adj, tokens_col = doc_lake
    GraphRetriever(adj, tokens_col, page_cache_pages=16)   # leaves a cache
    r = GraphRetriever(adj, tokens_col, page_cache_pages=None)
    # opt-out must actually detach: decode paths consult the column cache
    assert adj.table[adj.value_col].encoded.page_cache is None
    assert r.page_cache is None
    assert "page_cache" not in r.stats()


def test_retriever_label_scoped_context(doc_graph, doc_lake):
    from repro.core import L
    g, lake = doc_graph
    adj, tokens_col = doc_lake
    vt = g.vertex("doc")
    r = GraphRetriever(adj, tokens_col, max_neighbors=3,
                       tokens_per_neighbor=8, page_cache_pages=None,
                       filter_vt=vt, filter_cond=L("HighQuality"))
    vs = np.flatnonzero(adj.degrees() > 0)[:16]
    ctx = r(vs)
    assert len(ctx) == len(vs)
    hq = lake.labels["HighQuality"]
    for v, c in zip(vs, ctx):
        nbrs = adj.neighbor_ids(int(v))[:3]
        keep = [int(n) for n in nbrs if hq[int(n)]]
        want = (np.concatenate([tokens_col.get(n)[:8] for n in keep])
                if keep else np.zeros(0, np.int32))
        np.testing.assert_array_equal(c, want.astype(np.int32))
    s = r.stats()
    assert s["filter"]["considered"] >= s["filter"]["kept"] > 0
    # the bitmap is cached across ticks: label metadata charged once
    from repro.core import IOMeter
    m = IOMeter()
    r2 = GraphRetriever(adj, tokens_col, max_neighbors=3, meter=m,
                        page_cache_pages=None, filter_vt=vt,
                        filter_cond=L("HighQuality"))
    r2(vs)
    first = m.nbytes
    r2(vs)
    assert m.nbytes - first < first    # no second label-metadata charge


def test_retriever_filter_requires_vt(doc_lake):
    from repro.core import L
    adj, tokens_col = doc_lake
    with pytest.raises(ValueError):
        GraphRetriever(adj, tokens_col, filter_cond=L("HighQuality"))


def test_retriever_stats_track_live_cache(doc_lake):
    from repro.core import attach_page_cache
    adj, tokens_col = doc_lake
    r = GraphRetriever(adj, tokens_col, page_cache_pages=64)
    # a later re-attach with another capacity replaces the column's cache;
    # stats() must follow the cache the decode paths actually consult
    fresh = attach_page_cache(adj.table[adj.value_col], 32)
    assert r.page_cache is fresh
    assert r.stats()["page_cache"]["capacity"] == 32
    adj.table[adj.value_col].encoded.page_cache = None
