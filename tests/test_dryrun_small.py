"""Dry-run machinery on a small forced-device mesh (subprocess: jax locks
device count at first init, so the 8-device env must be set before import).

Covers: build_cell for train/prefill/decode kinds, sharding validity,
lower+compile success, roofline term extraction, and collective parsing.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import repro.launch.dryrun as dr
    from repro.launch.mesh import make_test_mesh

    rows = []
    for arch, shape in [("smollm-360m", "train_4k"),
                        ("mamba2-2.7b", "decode_32k"),
                        ("whisper-small", "prefill_32k")]:
        row = dr.run_cell(arch, shape, multi_pod=False,
                          mesh_factory=make_test_mesh, with_probes=False)
        rows.append({k: row[k] for k in
                     ("arch", "shape", "status", "bottleneck",
                      "t_compute_s", "t_memory_s", "t_collective_s",
                      "coll_count", "model_flops")})
    # multi-pod ("pod" axis) pass on the 2x2x2 test mesh
    row = dr.run_cell("smollm-360m", "train_4k", multi_pod=True,
                      mesh_factory=make_test_mesh, with_probes=False)
    rows.append({"arch": "smollm-360m", "shape": "train_4k+pod",
                 "status": row["status"], "bottleneck": row["bottleneck"],
                 "t_compute_s": row["t_compute_s"],
                 "t_memory_s": row["t_memory_s"],
                 "t_collective_s": row["t_collective_s"],
                 "coll_count": row["coll_count"],
                 "model_flops": row["model_flops"]})
    print("RESULT_JSON:" + json.dumps(rows))
""")


@pytest.fixture(scope="module")
def dryrun_rows():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT_JSON:")][0]
    return json.loads(line[len("RESULT_JSON:"):])


def test_all_cells_compile(dryrun_rows):
    assert len(dryrun_rows) == 4
    for r in dryrun_rows:
        assert r["status"] == "ok", r


def test_roofline_terms_positive(dryrun_rows):
    for r in dryrun_rows:
        assert r["t_compute_s"] > 0, r
        assert r["t_memory_s"] > 0, r
        assert r["bottleneck"] in ("compute", "memory", "collective")
        assert r["model_flops"] > 0


def test_collectives_present_on_sharded_train(dryrun_rows):
    train = [r for r in dryrun_rows if r["shape"].startswith("train")]
    for r in train:
        assert r["coll_count"] > 0  # FSDP/TP must produce collectives


def test_collective_parser_units():
    from repro.launch.roofline import parse_collectives
    hlo = """
    %all-reduce.1 = f32[128,256]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}} , to_apply=%add
    %all-gather.2 = bf16[64]{0} all-gather(%y), replica_groups={{0,256}} , dimensions={0}
    %dot.3 = f32[8,8]{1,0} dot(%a, %b)
    """
    stats = parse_collectives(hlo, chips_per_pod=256)
    assert stats.count == 2
    assert stats.ici_bytes == 128 * 256 * 4
    assert stats.dcn_bytes == 64 * 2  # group {0,256} crosses the pod
    assert stats.by_op["all-reduce"] == 128 * 256 * 4


def test_analytic_memory_floor():
    from repro.launch.report import analytic_memory_floor
    floor = analytic_memory_floor("jamba-1.5-large-398b", "train_4k",
                                  256, False)
    # 398B params with int8 moments + bf16 grads across 256 chips
    assert floor["state_bytes"] < 16 * 1024 ** 3
    assert floor["fits_floor_16gb"], floor
    floor2 = analytic_memory_floor("mistral-large-123b", "decode_32k",
                                   256, False)
    assert floor2["fits_floor_16gb"], floor2
