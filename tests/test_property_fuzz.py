"""Property-based fuzzing of the storage plane invariants."""
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core import (BY_SRC, ENC_GRAPHAR, DeltaIntColumn, IOMeter,
                        PlainColumn, Table, build_adjacency)
from repro.core.storage import read_table, write_table


@given(st.integers(min_value=1, max_value=2000),
       st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=1, max_value=6))
@settings(max_examples=25, deadline=None)
def test_read_rows_concat_matches_naive(n, seed, n_ranges):
    rng = np.random.default_rng(seed)
    vals = np.sort(rng.integers(0, 1 << 24, size=n))
    col = DeltaIntColumn("x", vals, page_size=128)
    los = rng.integers(0, n, n_ranges)
    his = np.minimum(los + rng.integers(0, 300, n_ranges), n)
    got = col.read_rows_concat(los, his)
    want = (np.concatenate([vals[l:h] for l, h in zip(los, his)])
            if n_ranges else np.zeros(0))
    np.testing.assert_array_equal(got, want)


@given(st.integers(min_value=1, max_value=2000),
       st.integers(min_value=0, max_value=10_000))
@settings(max_examples=20, deadline=None)
def test_container_roundtrip_fuzz(n, seed):
    import os
    import tempfile
    rng = np.random.default_rng(seed)
    t = Table("t", n, page_size=64)
    t.add(PlainColumn("f", rng.standard_normal(n).astype(np.float32), 64))
    t.add(DeltaIntColumn("i", np.sort(rng.integers(0, 1 << 20, n)), 64))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.gar")
        write_table(t, path)
        t2 = read_table(path)
        np.testing.assert_allclose(t2["f"].read_all(), t["f"].read_all())
        np.testing.assert_array_equal(t2["i"].read_all(),
                                      t["i"].read_all())


@given(st.integers(min_value=2, max_value=400),
       st.integers(min_value=0, max_value=5000))
@settings(max_examples=20, deadline=None)
def test_adjacency_offsets_invariants(n, seed):
    rng = np.random.default_rng(seed)
    m = int(rng.integers(1, 4 * n))
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    adj = build_adjacency(src, dst, n, n, BY_SRC, ENC_GRAPHAR, page_size=64)
    off = np.asarray(adj.offsets["<offset>"].read_all())
    # monotone, bounded, degree-consistent
    assert off[0] == 0 and off[-1] == m
    assert (np.diff(off) >= 0).all()
    deg = np.bincount(src, minlength=n)
    np.testing.assert_array_equal(np.diff(off), deg)
    # random vertex neighbor check
    v = int(rng.integers(0, n))
    np.testing.assert_array_equal(adj.neighbor_ids(v), np.sort(dst[src == v]))


def test_io_meter_monotone_under_page_growth():
    rng = np.random.default_rng(0)
    vals = np.sort(rng.integers(0, 1 << 22, size=50_000))
    col = DeltaIntColumn("x", vals, page_size=1024)
    m_small, m_big = IOMeter(), IOMeter()
    col.read_range(100, 200, m_small)
    col.read_range(100, 5000, m_big)
    assert m_big.nbytes >= m_small.nbytes
