"""Decode-engine selection for the test suite.

The engine-matrix CI job runs the suite once per engine by exporting
``REPRO_ENGINE`` (numpy / jax / pallas); locally, with the variable
unset, every parametrized test covers all three engines in one run.
"""
import os

ALL_ENGINES = ("numpy", "jax", "pallas")
KERNEL_ENGINES = ("jax", "pallas")


def engines(kernel_only: bool = False):
    pool = KERNEL_ENGINES if kernel_only else ALL_ENGINES
    e = os.environ.get("REPRO_ENGINE")
    if e:
        if e not in ALL_ENGINES:
            raise ValueError(f"REPRO_ENGINE={e!r}; want one of {ALL_ENGINES}")
        return [e] if e in pool else []
    return list(pool)
