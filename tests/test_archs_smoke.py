"""Per-architecture smoke tests: reduced config, one forward + train step +
decode consistency on CPU; asserts output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import build_model, param_count

B, S = 2, 32


def make_batch(cfg, rng):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
    }
    if cfg.encoder_layers:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.default_encoder_len, cfg.d_model)),
            jnp.float32)
    if cfg.num_vision_tokens:
        batch["vision"] = jnp.asarray(
            rng.standard_normal((B, cfg.num_vision_tokens, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_arch_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    rng = np.random.default_rng(0)
    params = model.init(0)
    assert param_count(params) > 0
    batch = make_batch(cfg, rng)

    logits, aux = model.apply(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(aux)), f"{arch}: non-finite aux"

    # one SGD train step through value_and_grad
    def loss_fn(p):
        loss, m = model.loss(p, batch)
        return loss, m

    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0, \
        f"{arch}: bad grad norm {gnorm}"
    new_params = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype),
                              params, grads)
    loss2, _ = model.loss(new_params, batch)
    assert bool(jnp.isfinite(loss2))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_arch_prefill_decode_shapes(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    rng = np.random.default_rng(1)
    params = model.init(0)
    batch = make_batch(cfg, rng)
    ctx_len = (cfg.default_encoder_len if cfg.encoder_layers
               else cfg.num_vision_tokens)
    cache = model.init_cache(B, max_len=S + 8, ctx_len=ctx_len,
                             dtype=jnp.float32)
    logits, cache = model.prefill(params, batch, cache)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for _ in range(3):
        logits, cache = model.decode_step(params, tok, cache)
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all())
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    assert int(cache["index"]) == S + 3


@pytest.mark.parametrize("arch", ["smollm-360m", "gemma3-4b",
                                  "stablelm-1.6b", "mamba2-2.7b",
                                  "whisper-small", "llama-3.2-vision-11b"])
def test_prefill_decode_matches_full_forward(arch):
    """Teacher-forced decode must reproduce full-forward logits (non-MoE:
    MoE capacity depends on batch shape, so exact equality is not expected
    there)."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    rng = np.random.default_rng(2)
    params = model.init(0)
    batch = make_batch(cfg, rng)
    full_logits, _ = model.apply(params, batch)

    split = S // 2
    prefill_batch = dict(batch)
    prefill_batch["tokens"] = batch["tokens"][:, :split]
    ctx_len = (cfg.default_encoder_len if cfg.encoder_layers
               else cfg.num_vision_tokens)
    cache = model.init_cache(B, max_len=S, ctx_len=ctx_len,
                             dtype=jnp.float32)
    logits_p, cache = model.prefill(params, prefill_batch, cache)
    np.testing.assert_allclose(np.asarray(logits_p[:, 0]),
                               np.asarray(full_logits[:, split - 1]),
                               rtol=2e-4, atol=2e-4)
    for t in range(split, S):
        tok = batch["tokens"][:, t:t + 1]
        logits_d, cache = model.decode_step(params, tok, cache)
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0]), np.asarray(full_logits[:, t]),
            rtol=2e-4, atol=2e-4, err_msg=f"{arch} step {t}")


def test_param_counts_full_configs_close_to_nameplate():
    """Full (non-reduced) configs should be near their nameplate sizes.

    Verified analytically (no allocation): embedding + per-layer matmuls.
    """
    import math

    def analytic(cfg):
        d = cfg.d_model
        # head is always materialized (decoupled-tied; DESIGN.md §6)
        total = cfg.vocab_size * d * 2
        specs = list(cfg.prefix) + list(cfg.unit) * cfg.n_units
        for i, spec in enumerate(specs):
            if spec.kind == "attn":
                total += d * cfg.head_dim * (cfg.num_heads * 2
                                             + cfg.num_kv_heads * 2)
            else:
                s = cfg.ssm
                din = s.num_heads * s.head_dim
                total += d * (2 * din + 2 * s.n_groups * s.state_dim
                              + s.num_heads) + din * d
            if spec.cross:
                total += d * cfg.head_dim * (cfg.num_heads * 2
                                             + cfg.num_kv_heads * 2)
            if spec.mlp:
                if spec.moe:
                    m = cfg.moe
                    total += m.num_experts * 3 * d * m.d_expert
                    if m.num_shared:
                        total += 3 * d * (m.d_shared or m.d_expert)
                else:
                    ff = cfg.prefix_d_ff if i < len(cfg.prefix) and \
                        cfg.prefix_d_ff else cfg.d_ff
                    total += 3 * d * ff if cfg.gated_mlp else 2 * d * ff
        if cfg.encoder_layers:
            total += cfg.encoder_layers * (
                d * cfg.head_dim * (cfg.num_heads * 2 + cfg.num_kv_heads * 2)
                + (3 if cfg.gated_mlp else 2) * d * cfg.d_ff)
        return total

    expect = {
        "jamba-1.5-large-398b": 398e9, "mistral-large-123b": 123e9,
        "qwen3-moe-30b-a3b": 30.5e9, "deepseek-moe-16b": 16.4e9,
        "mamba2-2.7b": 2.7e9, "gemma3-4b": 4.3e9, "smollm-360m": 0.36e9,
        "stablelm-1.6b": 1.6e9, "whisper-small": 0.24e9,
        "llama-3.2-vision-11b": 9.8e9,  # text tower only (vision stubbed)
    }
    for arch, nameplate in expect.items():
        cfg = get_config(arch)
        got = analytic(cfg)
        ratio = got / nameplate
        assert 0.55 < ratio < 1.45, \
            f"{arch}: analytic {got/1e9:.2f}B vs nameplate " \
            f"{nameplate/1e9:.2f}B (ratio {ratio:.2f})"
