"""Graph-level behaviour: builder, neighbor retrieval, label filtering."""
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core import (BY_DST, BY_SRC, ENC_GRAPHAR, ENC_OFFSET, ENC_PLAIN,
                        EdgeTypeSchema, GraphArBuilder, GraphSchema, IOMeter,
                        L, PropertySchema, VertexTypeSchema, build_adjacency,
                        degrees_topk, fetch_properties, filter_binary_columns,
                        filter_rle_interval, filter_string, intervals_to_ids,
                        k_hop, neighbor_properties, retrieve_neighbors,
                        retrieve_neighbors_scan)
from repro.core.vertex import (LABEL_ENC_PLAIN, LABEL_ENC_RLE,
                               LABEL_ENC_STRING, VertexTable)
from repro.data.synthetic import clustered_labels, powerlaw_graph


def small_graph(seed=0, n=3000, deg=8):
    src, dst = powerlaw_graph(n, deg, seed=seed)
    return n, src, dst


def brute_neighbors(src, dst, v):
    return np.sort(dst[src == v])


@pytest.mark.parametrize("encoding", [ENC_OFFSET, ENC_GRAPHAR])
def test_adjacency_neighbors_match_bruteforce(encoding):
    n, src, dst = small_graph()
    adj = build_adjacency(src, dst, n, n, BY_SRC, encoding, page_size=256)
    for v in [0, 1, 17, n - 1, int(np.argmax(np.bincount(src, minlength=n)))]:
        np.testing.assert_array_equal(adj.neighbor_ids(v),
                                      brute_neighbors(src, dst, v))


def test_csc_layout_incoming_neighbors():
    n, src, dst = small_graph(seed=2)
    adj = build_adjacency(src, dst, n, n, BY_DST, ENC_GRAPHAR, page_size=256)
    v = int(dst[0])
    np.testing.assert_array_equal(adj.neighbor_ids(v), np.sort(src[dst == v]))


def test_plain_scan_baseline_matches():
    n, src, dst = small_graph(seed=3)
    plain = build_adjacency(src, dst, n, n, BY_SRC, ENC_PLAIN, page_size=256)
    v = int(src[5])
    np.testing.assert_array_equal(plain.neighbor_ids_scan(v),
                                  brute_neighbors(src, dst, v))


def test_retrieval_pac_and_pushdown():
    n, src, dst = small_graph(seed=4)
    adj = build_adjacency(src, dst, n, n, BY_SRC, ENC_GRAPHAR, page_size=256)
    vschema = VertexTypeSchema("doc", [PropertySchema("score", "float32")],
                               page_size=256)
    score = np.arange(n, dtype=np.float32) * 0.5
    vt = VertexTable.build(vschema, {"score": score})
    v = int(src[0])
    pac = retrieve_neighbors(adj, v, vt.page_size)
    np.testing.assert_array_equal(pac.to_ids(), brute_neighbors(src, dst, v))
    vals = fetch_properties(pac, vt, "score")
    np.testing.assert_allclose(vals, score[brute_neighbors(src, dst, v)])


def test_retrieval_io_ordering_plain_vs_offset_vs_delta():
    """Fig. 9's mechanism: scan >> offset-plain > offset-delta in bytes."""
    n, src, dst = small_graph(seed=5, n=20_000, deg=16)
    plain = build_adjacency(src, dst, n, n, BY_SRC, ENC_PLAIN, page_size=2048)
    offset = build_adjacency(src, dst, n, n, BY_SRC, ENC_OFFSET,
                             page_size=2048)
    graphar = build_adjacency(src, dst, n, n, BY_SRC, ENC_GRAPHAR,
                              page_size=2048)
    v = int(degrees_topk(offset)[0])
    m1, m2, m3 = IOMeter(), IOMeter(), IOMeter()
    a = retrieve_neighbors_scan(plain, v, 2048, m1).to_ids()
    b = retrieve_neighbors(offset, v, 2048, m2).to_ids()
    c = retrieve_neighbors(graphar, v, 2048, m3).to_ids()
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(b, c)
    assert m1.nbytes > 5 * m2.nbytes    # offset index avoids the full scan
    assert m2.nbytes > m3.nbytes        # delta shrinks the touched pages


def test_khop_traversal():
    n, src, dst = small_graph(seed=6)
    adj = build_adjacency(src, dst, n, n, BY_SRC, ENC_GRAPHAR, page_size=256)
    seeds = np.array([int(src[0])])
    one = k_hop(adj, seeds, 1)
    two = k_hop(adj, seeds, 2)
    assert set(one) <= set(two)
    expect1 = np.union1d(seeds, brute_neighbors(src, dst, int(seeds[0])))
    np.testing.assert_array_equal(one, expect1)


# --------------------------- label filtering -----------------------------

def make_vertex_tables(n=20_000, seed=7):
    names = ["Asian", "Enrollee", "Student"]
    labels = clustered_labels(n, names, density=0.3, run_scale=512, seed=seed)
    schema = VertexTypeSchema("person", [], labels=names, page_size=1024)
    vts = {
        enc: VertexTable.build(schema, {}, labels, enc, num_vertices=n)
        for enc in (LABEL_ENC_RLE, LABEL_ENC_PLAIN, LABEL_ENC_STRING)
    }
    return vts, labels


@pytest.mark.parametrize("cond_fn", [
    lambda: L("Asian"),
    lambda: ~L("Asian"),
    lambda: L("Asian") & L("Enrollee"),
    lambda: (L("Asian") & ~L("Enrollee")) | L("Student"),
])
def test_label_filtering_all_methods_agree(cond_fn):
    vts, labels = make_vertex_tables()
    cond = cond_fn()
    env = {k: np.asarray(v, bool) for k, v in labels.items()}
    expect = np.flatnonzero(cond.evaluate(env))
    got_interval = intervals_to_ids(filter_rle_interval(vts["rle"], cond))
    got_plain = filter_binary_columns(vts["plain"], cond)
    got_rle_scan = filter_binary_columns(vts["rle"], cond)
    got_string = filter_string(vts["string"], cond)
    np.testing.assert_array_equal(got_interval, expect)
    np.testing.assert_array_equal(got_plain, expect)
    np.testing.assert_array_equal(got_rle_scan, expect)
    np.testing.assert_array_equal(got_string, expect)


def test_label_storage_ordering():
    """Fig. 11: RLE << binary(plain) << string for clustered labels."""
    vts, _ = make_vertex_tables()
    rle = vts["rle"].labels_nbytes()
    plain = vts["plain"].labels_nbytes()
    string = vts["string"].labels_nbytes()
    assert rle < plain < string


@given(st.integers(min_value=0, max_value=1000))
@settings(max_examples=25, deadline=None)
def test_complex_filter_property(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(64, 4000))
    names = ["A", "B", "C"]
    labels = {m: rng.random(n) < rng.random() for m in names}
    schema = VertexTypeSchema("v", [], labels=names, page_size=128)
    vt = VertexTable.build(schema, {}, labels, LABEL_ENC_RLE, num_vertices=n)
    cond = (L("A") & ~L("B")) | L("C")
    env = {k: np.asarray(v, bool) for k, v in labels.items()}
    expect = np.flatnonzero(cond.evaluate(env))
    got = intervals_to_ids(filter_rle_interval(vt, cond))
    np.testing.assert_array_equal(got, expect)


# --------------------------- builder/YAML --------------------------------

def test_builder_end_to_end_and_yaml(tmp_path):
    n, src, dst = small_graph(seed=8, n=2000, deg=6)
    names = ["Hot"]
    labels = clustered_labels(n, names, seed=1)
    b = GraphArBuilder("g")
    b.add_vertices(
        VertexTypeSchema("doc", [PropertySchema("score", "float32")],
                         labels=names, page_size=256),
        {"score": np.arange(n, dtype=np.float32)}, labels)
    b.add_edges(EdgeTypeSchema("doc", "links", "doc", page_size=256,
                               adjacency=["by_src", "by_dst"]), src, dst)
    g = b.build()
    assert b.timing.total >= 0
    v = int(src[0])
    np.testing.assert_array_equal(
        g.adjacency("doc-links-doc", BY_SRC).neighbor_ids(v),
        brute_neighbors(src, dst, v))
    # YAML round trip
    y = g.schema.to_yaml()
    g2 = GraphSchema.from_yaml(y)
    assert "doc-links-doc" in g2.edge_types
    assert g2.vertex_types["doc"].labels == ["Hot"]
    # persistence round trip
    g.save(str(tmp_path))
    from repro.core import GraphStore
    store = GraphStore(str(tmp_path))
    assert "vertex_doc" in store.list_tables()
    schema = store.read_schema_yaml()
    assert schema.name == "g"


# ---------------------- vectorized intervals_to_ids ----------------------

def _intervals_to_ids_oracle(starts, ends):
    """The pre-vectorization loop: one np.arange per interval."""
    if len(starts) == 0:
        return np.zeros(0, np.int64)
    return np.concatenate([np.arange(s, e, dtype=np.int64)
                           for s, e in zip(starts, ends)]
                          or [np.zeros(0, np.int64)])


@given(st.lists(st.tuples(st.integers(min_value=0, max_value=5000),
                          st.integers(min_value=0, max_value=60)),
                min_size=0, max_size=50))
@settings(max_examples=60, deadline=None)
def test_intervals_to_ids_matches_loop_oracle(pairs):
    starts = np.array([s for s, _ in pairs], np.int64)
    ends = starts + np.array([l for _, l in pairs], np.int64)
    got = intervals_to_ids((starts, ends))
    np.testing.assert_array_equal(got,
                                  _intervals_to_ids_oracle(starts, ends))


def test_intervals_to_ids_edge_cases():
    empty = np.zeros(0, np.int64)
    assert intervals_to_ids((empty, empty)).size == 0
    # empty intervals interleaved with real ones, unordered and overlapping
    starts = np.array([9, 3, 3, 20, 5], np.int64)
    ends = np.array([9, 6, 3, 23, 7], np.int64)
    np.testing.assert_array_equal(
        intervals_to_ids((starts, ends)),
        np.array([3, 4, 5, 20, 21, 22, 5, 6], np.int64))
