"""Page-granular statistics pushdown (PR 10).

A predicate's qualifying-id hull intersects per-page min/max zone maps
at plan time, dropping pages from the deduplicated page list *before*
staging -- pruned pages are never gathered, decoded, or charged.  The
invariants pinned here:

* pruned retrieval ids are bit-identical to the unpruned oracle, across
  engines x partition counts x label and numeric predicates (the fuzz
  test), and the three granularities (partition hull -> page zone map
  -> delta segment) compose without double-dropping;
* IOMeter bytes are <= the unpruned cost, and exactly equal when no
  page prunes;
* numeric predicates (:class:`repro.core.numeric.NumericFilter`) push
  down through every path the label plane serves;
* pruning ships as shorter staged vectors under the existing pow2
  padding ladder -- steady-state dispatches never retrace.
"""
import numpy as np
import pytest
from _engines import engines
from _hypothesis_shim import given, settings, st

from repro.core import (BY_SRC, ENC_GRAPHAR, IOMeter, L, LabelFilter,
                        NumericFilter, NumProp, VertexTable,
                        build_adjacency, k_hop, live_partitions,
                        partition_column, retrieve_neighbors_batch)
from repro.core.encoding import page_hulls, prune_page_list
from repro.core.schema import PropertySchema, VertexTypeSchema

N = 1024
PAGE = 128
TPS = 256
DEG = 6
PART_COUNTS = (1, 2, 8)


def _local_graph():
    """Community-local ring: dst pages have tight id hulls, so selective
    predicates prune most of the page set."""
    off = np.concatenate([np.arange(-(DEG // 2), 0),
                          np.arange(1, DEG - DEG // 2 + 1)])
    dst = np.clip(np.arange(N)[:, None] + off[None, :], 0, N - 1).ravel()
    src = np.repeat(np.arange(N), DEG)
    return build_adjacency(src, dst, N, N, BY_SRC, ENC_GRAPHAR,
                           page_size=PAGE)


def _vt():
    rng = np.random.default_rng(3)
    age = (np.arange(N) // 4).astype(np.int64)       # id-correlated
    score = rng.integers(0, 50, N).astype(np.int64)  # uncorrelated
    labels = {"A": np.arange(N) < N // 6,            # tight hull
              "R": rng.random(N) < 0.4,              # wide hull
              "Z": np.zeros(N, bool)}                # empty hull
    return VertexTable.build(
        VertexTypeSchema("v", [PropertySchema("age", "int64"),
                               PropertySchema("score", "int64")],
                         labels=["A", "R", "Z"], page_size=PAGE),
        {"age": age, "score": score}, labels, num_vertices=N)


@pytest.fixture(scope="module")
def adj():
    return _local_graph()


@pytest.fixture(scope="module")
def vt():
    return _vt()


AGE = NumProp("age")
SCORE = NumProp("score")


def _predicate(vt, kind: int, rng):
    """One random predicate from the label / numeric pools."""
    if kind % 2 == 0:
        conds = [L("A"), L("R"), L("A") | L("R"), ~L("A"),
                 L("A") & ~L("R"), ~L("Z")]
        return LabelFilter(vt, conds[kind // 2 % len(conds)])
    lo = int(rng.integers(0, N // 4))
    w = int(rng.integers(1, N // 8))
    conds = [AGE.between(lo, lo + w), AGE >= lo, AGE < lo + w,
             AGE.between(lo, lo + w) | (AGE == 2 * lo + 7),
             ~(AGE < lo), AGE.between(lo, lo + w) & (SCORE >= 10)]
    return NumericFilter(vt, conds[kind // 2 % len(conds)])


# --------------------------- the property fuzz ----------------------------

@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=0, max_value=23))
@settings(max_examples=12, deadline=None)
def test_pruned_retrieval_bit_identical_and_never_costlier(seed, kind):
    adj = _local_graph()
    vt = _vt()
    col = adj.table[adj.value_col].encoded
    rng = np.random.default_rng(seed)
    filt = _predicate(vt, kind, rng)
    vs = np.sort(rng.choice(N, int(rng.integers(1, 200)), replace=False))
    # unpruned oracle: unfiltered retrieval intersected host-side, its
    # meter + the filter's charge = the pre-pushdown filtered cost
    m_un = IOMeter()
    want = retrieve_neighbors_batch(adj, vs, TPS, m_un) \
        .intersect(filt.pac(TPS))
    filt.charge(m_un)
    base = None
    for parts in PART_COUNTS:
        if parts > 1:
            partition_column(col, parts)
        pobj = live_partitions(col)
        for engine in engines():
            m = IOMeter()
            pg_before = col.prune_stats.pages_pruned
            pt_before = pobj.stats_pruned if pobj is not None else 0
            got = retrieve_neighbors_batch(adj, vs, TPS, m, engine,
                                           filter=filt)
            np.testing.assert_array_equal(got.to_ids(), want.to_ids())
            assert m.nbytes <= m_un.nbytes
            pruned_any = (
                col.prune_stats.pages_pruned > pg_before
                or (pobj is not None and pobj.stats_pruned > pt_before))
            if not pruned_any:
                # nothing pruned at either granularity: pushdown must
                # cost exactly the oracle
                assert (m.nbytes, m.nrequests) \
                    == (m_un.nbytes, m_un.nrequests)
            if base is None:
                base = (m.nbytes, m.nrequests)
            else:  # identical across engines AND partition counts
                assert (m.nbytes, m.nrequests) == base


# ------------------------ deterministic invariants ------------------------

@pytest.mark.parametrize("engine", engines())
def test_selective_filter_prunes_pages_and_bytes(adj, vt, engine):
    col = adj.table[adj.value_col].encoded
    vs = np.arange(0, N, 3)
    filt = LabelFilter(vt, L("A"))
    m_un, m = IOMeter(), IOMeter()
    retrieve_neighbors_batch(adj, vs, TPS, m_un)
    filt.charge(m_un)
    before = col.prune_stats.as_dict()
    got = retrieve_neighbors_batch(adj, vs, TPS, m, engine, filter=filt)
    after = col.prune_stats.as_dict()
    assert after["pages_pruned"] > before["pages_pruned"]
    assert after["io_saved_bytes"] > before["io_saved_bytes"]
    assert m.nbytes < m_un.nbytes
    want = retrieve_neighbors_batch(adj, vs, TPS).intersect(filt.pac(TPS))
    assert got == want


@pytest.mark.parametrize("engine", engines())
def test_all_true_filter_costs_exactly_the_oracle(adj, vt, engine):
    # full qualifying hull: no page can prune, meters must match the
    # unpruned cost to the byte and request
    vs = np.arange(0, N, 5)
    filt = LabelFilter(vt, ~L("Z"))
    m_un, m = IOMeter(), IOMeter()
    want = retrieve_neighbors_batch(adj, vs, TPS, m_un)
    filt.charge(m_un)
    got = retrieve_neighbors_batch(adj, vs, TPS, m, engine, filter=filt)
    assert got == want
    assert (m.nbytes, m.nrequests) == (m_un.nbytes, m_un.nrequests)


@pytest.mark.parametrize("engine", engines())
def test_empty_hull_prunes_every_page(adj, vt, engine):
    col = adj.table[adj.value_col].encoded
    filt = LabelFilter(vt, L("Z"))
    m = IOMeter()
    before = col.prune_stats.pages_pruned
    got = retrieve_neighbors_batch(adj, np.arange(0, N, 3), TPS, m,
                                   engine, filter=filt)
    assert got.count() == 0
    assert col.prune_stats.pages_pruned > before
    # only the offsets gather + label metadata are left to charge
    m_meta = IOMeter()
    adj.edge_ranges_batch(np.arange(0, N, 3), m_meta)
    filt.charge(m_meta)
    assert (m.nbytes, m.nrequests) == (m_meta.nbytes, m_meta.nrequests)


@pytest.mark.parametrize("engine", engines())
def test_numeric_filter_matches_bruteforce(adj, vt, engine):
    age = np.asarray(vt.table["age"].values)
    score = np.asarray(vt.table["score"].values)
    filt = NumericFilter(vt, AGE.between(30, 90) & (SCORE >= 10))
    qual = (age >= 30) & (age < 90) & (score >= 10)
    np.testing.assert_array_equal(
        np.flatnonzero(filt.mask_ids(np.arange(N), engine)),
        np.flatnonzero(qual))
    vs = np.arange(0, N, 4)
    got = retrieve_neighbors_batch(adj, vs, TPS, engine=engine,
                                   filter=filt)
    want = retrieve_neighbors_batch(adj, vs, TPS).intersect(filt.pac(TPS))
    assert got == want
    np.testing.assert_array_equal(got.to_ids(), want.to_ids())


def test_numeric_filter_zone_maps_skip_property_pages(vt):
    # the filter's own evaluation is statistics-pruned: an id-correlated
    # property with a narrow range reads only the qualifying pages
    filt = NumericFilter(vt, AGE.between(0, 16))
    filt.charge(None)
    assert filt.prop_pages_skipped > 0
    stats = vt.table["age"].page_stats()
    assert filt.prop_pages_read < len(stats)
    # and the charge replays identically
    m1, m2 = IOMeter(), IOMeter()
    filt.charge(m1)
    filt.charge(m2)
    assert (m1.nbytes, m1.nrequests) == (m2.nbytes, m2.nrequests)
    assert m1.nbytes > 0


def test_numeric_filter_rejects_label_leaves(vt):
    with pytest.raises(TypeError):
        NumericFilter(vt, L("A") & (AGE >= 3))


def test_unknown_page_stats_never_prune(adj):
    col = adj.table[adj.value_col].encoded
    pages = np.arange(len(col.pages), dtype=np.int64)
    kept, mask = prune_page_list(col, pages, (0, 1))
    assert mask is not None and len(kept) < len(pages)
    # degrade one surviving page's stats to unknown (vmax < vmin with
    # rows present): it must be kept no matter the hull
    victim = int(kept[0])
    pg = col.pages[victim]
    saved = (pg.vmin, pg.vmax)
    pg.vmin, pg.vmax = 0, -1
    col._hull_cache = None
    try:
        kept2, _ = prune_page_list(col, pages, (N + 5, N + 6))
        assert victim in kept2.tolist()
        pmin, pmax, prunable = page_hulls(col)
        assert not prunable[victim]
    finally:
        pg.vmin, pg.vmax = saved
        col._hull_cache = None


@pytest.mark.parametrize("engine", engines())
def test_khop_pruning_parity_host_vs_fused(adj, vt, engine):
    filt = LabelFilter(vt, L("A"))
    seeds = np.arange(0, N, 11)
    m_host = IOMeter()
    want = k_hop(adj, seeds, 2, m_host, engine="numpy", filter=filt)
    m = IOMeter()
    got = k_hop(adj, seeds, 2, m, engine=engine, filter=filt,
                fused=None if engine != "numpy" else False)
    np.testing.assert_array_equal(got, want)
    assert (m.nbytes, m.nrequests) == (m_host.nbytes, m_host.nrequests)


@pytest.mark.parametrize("engine", engines())
def test_delta_union_respects_all_three_granularities(vt, engine):
    # partition hulls + page zone maps on the base, segment zone maps on
    # the mutable plane -- ids still equal the exact oracle
    from repro.core.delta_segment import attach_delta
    adj = _local_graph()
    col = adj.table[adj.value_col].encoded
    partition_column(col, 4)
    delta = attach_delta(adj)
    rng = np.random.default_rng(9)
    src = rng.integers(0, N, 64)
    dst = rng.integers(N // 2, N, 64)  # provably outside L("A")'s hull
    delta.ingest(src, dst)
    filt = LabelFilter(vt, L("A"))
    vs = np.arange(0, N, 7)
    before = delta.segments_pruned
    got = retrieve_neighbors_batch(adj, vs, TPS, engine=engine,
                                   filter=filt)
    # brute-force oracle over base + delta edges
    base = retrieve_neighbors_batch(adj, vs, TPS)
    want = base.intersect(filt.pac(TPS)).to_ids()
    np.testing.assert_array_equal(got.to_ids(), want)
    assert col.prune_stats.pages_pruned > 0
    assert delta.segments_pruned > before


@pytest.mark.parametrize("engine", engines(kernel_only=True))
def test_pruned_steady_state_does_not_retrace(adj, vt, engine):
    from repro.kernels import _pad
    filt = LabelFilter(vt, L("A"))
    rng = np.random.default_rng(1)

    def tick():
        vs = np.sort(rng.choice(N, int(rng.integers(20, 60)),
                                replace=False))
        retrieve_neighbors_batch(adj, vs, TPS, engine=engine, filter=filt)

    # warm the pow2 ladder until varying batches stop tracing: the
    # pruned page mask must ship as staged *data*, never as a shape
    stable = 0
    for _ in range(30):
        before = _pad.trace_count()
        tick()
        stable = stable + 1 if _pad.trace_count() == before else 0
        if stable >= 3:
            break
    assert stable >= 3  # the size classes converged at all
    before = _pad.trace_count()
    for _ in range(5):
        tick()
    assert _pad.trace_count() == before


@pytest.mark.parametrize("engine", engines())
def test_serving_surfaces_pruning_counters(adj, vt, engine):
    from repro.serve.retrieval import GraphRetriever
    from repro.core.table import TokensColumn
    tok = TokensColumn("tokens",
                       [np.arange(8, dtype=np.int32)] * N, PAGE)
    r = GraphRetriever(adj, tok, max_neighbors=2, engine=engine,
                       meter=IOMeter(), page_cache_pages=None,
                       filter_vt=vt, filter_cond=L("A"), hops=2)
    r(np.arange(0, N, 13))
    s = r.stats()
    assert "pruning" in s
    p = s["pruning"]
    assert p["pages_pruned"] > 0 and p["io_saved_bytes"] > 0
    assert p["pages_considered"] >= p["pages_pruned"]
    assert "delta_segments_pruned" in p and "partitions_stats_pruned" in p
