"""Direct unit tests for the shared fault-tolerance primitives
(repro/ft/backoff.py) and the fault-injection harness (repro/ft/faults.py)."""
import numpy as np
import pytest

from repro.ft.backoff import (Backoff, HeartbeatTracker, StrikeCounter,
                              retry_call)
from repro.ft.faults import BOUNDARIES, FaultPlan, InjectedFault, check


# -- Backoff ----------------------------------------------------------------

def test_backoff_exponential_growth_and_cap():
    bo = Backoff(base=0.1, factor=2.0, max_delay=1.0, jitter=0.0)
    assert bo.delay(0) == pytest.approx(0.1)
    assert bo.delay(1) == pytest.approx(0.2)
    assert bo.delay(2) == pytest.approx(0.4)
    assert bo.delay(10) == pytest.approx(1.0)  # clamped


def test_backoff_jitter_bounds_and_seed_determinism():
    a = Backoff(base=0.1, factor=2.0, max_delay=10.0, jitter=0.5, seed=7)
    b = Backoff(base=0.1, factor=2.0, max_delay=10.0, jitter=0.5, seed=7)
    seq_a = [a.delay(i) for i in range(8)]
    seq_b = [b.delay(i) for i in range(8)]
    assert seq_a == seq_b  # seeded schedule replays exactly
    for i, d in enumerate(seq_a):
        nominal = min(0.1 * 2.0 ** i, 10.0)
        assert 0.5 * nominal <= d <= 1.5 * nominal


def test_backoff_delays_generator_matches_delay():
    bo = Backoff(base=0.05, factor=3.0, max_delay=5.0, jitter=0.0)
    gen = bo.delays()
    assert [next(gen) for _ in range(4)] == \
        [bo.delay(i) for i in range(4)]


def test_backoff_rejects_bad_params():
    with pytest.raises(ValueError):
        Backoff(base=-1.0)
    with pytest.raises(ValueError):
        Backoff(factor=0.5)
    with pytest.raises(ValueError):
        Backoff(jitter=1.0)


# -- retry_call -------------------------------------------------------------

def test_retry_call_retries_then_succeeds():
    calls, slept = [], []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise RuntimeError("boom")
        return "ok"

    out = retry_call(flaky, retries=5,
                     backoff=Backoff(base=0.1, factor=2.0, jitter=0.0),
                     sleep=slept.append)
    assert out == "ok"
    assert len(calls) == 3
    assert slept == pytest.approx([0.1, 0.2])


def test_retry_call_exhausts_and_raises():
    slept = []
    with pytest.raises(RuntimeError):
        retry_call(lambda: (_ for _ in ()).throw(RuntimeError("always")),
                   retries=2, backoff=Backoff(jitter=0.0),
                   sleep=slept.append)
    assert len(slept) == 2  # one sleep per retry, none after the last


def test_retry_call_only_catches_retry_on():
    with pytest.raises(KeyError):
        retry_call(lambda: (_ for _ in ()).throw(KeyError("x")),
                   retries=5, retry_on=(RuntimeError,),
                   sleep=lambda s: None)


def test_retry_call_on_retry_observer():
    seen = []

    def fail_twice(state={"n": 0}):
        state["n"] += 1
        if state["n"] <= 2:
            raise RuntimeError("x")
        return state["n"]

    retry_call(fail_twice, retries=5, backoff=Backoff(jitter=0.0),
               sleep=lambda s: None,
               on_retry=lambda a, d, e: seen.append((a, type(e))))
    assert seen == [(0, RuntimeError), (1, RuntimeError)]


# -- HeartbeatTracker -------------------------------------------------------

def test_heartbeat_tracker_expiry():
    t = {"now": 0.0}
    hb = HeartbeatTracker(timeout=10.0, clock=lambda: t["now"])
    hb.register("a")
    hb.register("b")
    t["now"] = 5.0
    hb.beat("b")
    t["now"] = 11.0
    assert hb.is_expired("a")
    assert not hb.is_expired("b")
    assert hb.expired() == ["a"]
    t["now"] = 16.0
    assert sorted(hb.expired()) == ["a", "b"]
    hb.drop("a")
    assert hb.expired() == ["b"]


# -- StrikeCounter ----------------------------------------------------------

def test_strike_counter_trip_and_clear():
    s = StrikeCounter(3)
    assert not s.strike()
    assert not s.strike()
    assert s.strike()      # third strike trips
    assert s.tripped
    s.clear()
    assert not s.tripped
    assert s.strikes == 0
    with pytest.raises(ValueError):
        StrikeCounter(0)


# -- FaultPlan --------------------------------------------------------------

def test_fault_plan_trips_then_clears():
    plan = FaultPlan({"compact.pre_swap": 2})
    for hit in (1, 2):
        with pytest.raises(InjectedFault) as ei:
            plan.check("compact.pre_swap")
        assert ei.value.boundary == "compact.pre_swap"
        assert ei.value.hit == hit
    plan.check("compact.pre_swap")  # trips consumed: no longer raises
    assert plan.fired == {"compact.pre_swap": 2}
    assert plan.remaining() == 0
    assert plan.history == ["compact.pre_swap"] * 2


def test_fault_plan_unarmed_boundary_is_silent():
    plan = FaultPlan({"compact.mid_gc": 1})
    plan.check("ingest.append")  # not armed
    assert plan.total_fired() == 0


def test_fault_plan_from_seed_deterministic():
    a = FaultPlan.from_seed(11)
    b = FaultPlan.from_seed(11)
    assert a.trips == b.trips
    assert set(a.trips) <= set(BOUNDARIES)
    # across seeds, at least one differing pattern exists
    patterns = {tuple(sorted(FaultPlan.from_seed(s).trips.items()))
                for s in range(8)}
    assert len(patterns) > 1


def test_fault_plan_from_env(monkeypatch):
    monkeypatch.delenv("REPRO_FAULT_SEED", raising=False)
    assert FaultPlan.from_env() is None
    assert FaultPlan.from_env(default_seed=3).trips == \
        FaultPlan.from_seed(3).trips
    monkeypatch.setenv("REPRO_FAULT_SEED", "5")
    assert FaultPlan.from_env().trips == FaultPlan.from_seed(5).trips


def test_check_helper_none_safe():
    check(None, "compact.pre_swap")  # no plan: no-op
    with pytest.raises(InjectedFault):
        check(FaultPlan({"store.write": 1}), "store.write")
