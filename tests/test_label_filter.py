"""The filtering plane: compiled Cond programs vs the legacy oracle.

Deterministic tests pin the compiled stack-machine plane (numpy run-merge
engine + jax/pallas bitmap kernels) to the legacy per-node ``evaluate(env)``
recursion, including IOMeter identity across engines.  The hypothesis
tests assert the Cond algebra -- De Morgan, double negation, and-or
distribution -- holds between the compiled kernel plane and the oracle for
randomly generated label columns and condition trees.
"""
import numpy as np
import pytest

from _engines import engines
from _hypothesis_shim import given, settings, st

from repro.core import (IOMeter, L, LabelFilter, bitmap_to_intervals,
                        compile_cond, complex_filter_intervals, eval_program,
                        evaluate_filter_intervals, filter_rle_interval,
                        intervals_to_bitmap, intervals_to_ids)
from repro.core.schema import VertexTypeSchema
from repro.core.vertex import VertexTable
from repro.kernels.label_filter import ops as lf_ops

NAMES = ("A", "B", "C")
N = 4000


def make_vt(n=N, seed=0, run=64, page_size=256):
    rng = np.random.default_rng(seed)
    cols = {m: np.repeat(rng.random(n // run + 1) < 0.4, run)[:n]
            for m in NAMES}
    return VertexTable.build(
        VertexTypeSchema("v", [], labels=list(NAMES), page_size=page_size),
        {}, cols, num_vertices=n)


@pytest.fixture(scope="module")
def vt():
    return make_vt()


CONDS = [
    L("A"),
    ~L("B"),
    L("A") & L("B"),
    L("A") | ~L("C"),
    (L("A") & ~L("B")) | L("C"),
    ~(L("A") | L("B")) & L("C"),
    ~~L("C") | (L("A") & L("A")),
]


def _random_cond(rng, depth=3):
    if depth == 0 or rng.random() < 0.3:
        return L(NAMES[int(rng.integers(len(NAMES)))])
    k = int(rng.integers(3))
    if k == 0:
        return ~_random_cond(rng, depth - 1)
    a = _random_cond(rng, depth - 1)
    b = _random_cond(rng, depth - 1)
    return (a & b) if k == 1 else (a | b)


# ----------------------------- compilation --------------------------------

def test_compile_dedups_labels_and_is_postfix():
    prog = compile_cond((L("A") & ~L("B")) | (L("A") & L("C")))
    assert prog.labels == ("A", "B", "C")      # first-use order, deduped
    # postfix evaluation over plain numpy bool planes
    out = eval_program(prog.ops, [np.array([1, 0, 0], bool),
                                  np.array([0, 0, 0], bool),
                                  np.array([0, 1, 0], bool)])
    np.testing.assert_array_equal(out, [True, False, False])


def test_compile_rejects_foreign_nodes():
    with pytest.raises(TypeError):
        compile_cond("not a cond")


def test_eval_program_rejects_malformed():
    with pytest.raises(ValueError):
        eval_program((("leaf", 0), ("leaf", 0)), [np.ones(2, bool)])


@pytest.mark.parametrize("cond", CONDS, ids=[repr(c) for c in CONDS])
def test_compiled_plane_matches_legacy_oracle(vt, cond):
    got = complex_filter_intervals(vt, cond)
    want = evaluate_filter_intervals(vt, cond)
    np.testing.assert_array_equal(got[0], want[0])
    np.testing.assert_array_equal(got[1], want[1])


# ----------------------------- engine dispatch ----------------------------

@pytest.mark.parametrize("engine", engines())
@pytest.mark.parametrize("cond", CONDS[:5], ids=[repr(c) for c in CONDS[:5]])
def test_engine_bitmap_matches_oracle(vt, cond, engine):
    want = intervals_to_bitmap(evaluate_filter_intervals(vt, cond),
                               vt.num_vertices)
    got = lf_ops.label_filter_bitmap(vt, cond, engine=engine)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("engine", engines())
def test_engine_intervals_and_meter_identical(vt, engine):
    cond = (L("A") & ~L("B")) | L("C")
    m_np, m_e = IOMeter(), IOMeter()
    want = filter_rle_interval(vt, cond, m_np, engine="numpy")
    got = filter_rle_interval(vt, cond, m_e, engine=engine)
    np.testing.assert_array_equal(got[0], want[0])
    np.testing.assert_array_equal(got[1], want[1])
    assert (m_e.nbytes, m_e.nrequests) == (m_np.nbytes, m_np.nrequests)


@pytest.mark.parametrize("engine", engines())
def test_simple_condition_engine_paths_agree(vt, engine):
    for cond in (L("B"), ~L("A")):
        got = filter_rle_interval(vt, cond, engine=engine)
        want = filter_rle_interval(vt, cond, engine="numpy")
        np.testing.assert_array_equal(intervals_to_ids(got),
                                      intervals_to_ids(want))


def test_label_filter_caches_bitmap_and_masks(vt):
    f = LabelFilter(vt, L("A") | L("B"))
    w1 = f.bitmap()
    assert f.bitmap() is w1                     # cached per engine
    ids = intervals_to_ids(evaluate_filter_intervals(vt, f.cond))
    np.testing.assert_array_equal(
        np.flatnonzero(f.mask_ids(np.arange(vt.num_vertices))), ids)


# ----------------------------- plane conversions --------------------------

@pytest.mark.parametrize("n", [0, 1, 31, 32, 1000, N])
def test_interval_bitmap_roundtrip(n):
    rng = np.random.default_rng(n)
    cut = np.unique(rng.integers(0, max(n, 1), 12))
    starts, ends = cut[:-1:2], cut[1::2]
    k = min(len(starts), len(ends))
    iv = (starts[:k].astype(np.int64), ends[:k].astype(np.int64))
    words = intervals_to_bitmap(iv, n)
    assert words.size == -(-n // 32)
    back = bitmap_to_intervals(words, n)
    np.testing.assert_array_equal(intervals_to_ids(back),
                                  intervals_to_ids(iv))


# ----------------------------- Cond algebra (hypothesis) ------------------

def _assert_equiv(vt, lhs, rhs, engine):
    """lhs and rhs must produce identical planes, both equal to the legacy
    oracle of lhs."""
    a = lf_ops.label_filter_bitmap(vt, lhs, engine=engine)
    b = lf_ops.label_filter_bitmap(vt, rhs, engine=engine)
    want = intervals_to_bitmap(evaluate_filter_intervals(vt, lhs),
                               vt.num_vertices)
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(a, want)


@given(seed=st.integers(0, 2**32 - 1))
@settings(max_examples=8, deadline=None)
def test_algebra_de_morgan(seed):
    rng = np.random.default_rng(seed)
    vt = make_vt(n=int(rng.integers(64, 1500)), seed=seed, run=16)
    a, b = _random_cond(rng, 2), _random_cond(rng, 2)
    for engine in engines():
        _assert_equiv(vt, ~(a & b), ~a | ~b, engine)
        _assert_equiv(vt, ~(a | b), ~a & ~b, engine)


@given(seed=st.integers(0, 2**32 - 1))
@settings(max_examples=8, deadline=None)
def test_algebra_double_negation(seed):
    rng = np.random.default_rng(seed)
    vt = make_vt(n=int(rng.integers(64, 1500)), seed=seed, run=16)
    a = _random_cond(rng, 3)
    for engine in engines():
        _assert_equiv(vt, ~~a, a, engine)


@given(seed=st.integers(0, 2**32 - 1))
@settings(max_examples=8, deadline=None)
def test_algebra_and_or_distribution(seed):
    rng = np.random.default_rng(seed)
    vt = make_vt(n=int(rng.integers(64, 1500)), seed=seed, run=16)
    a, b, c = (_random_cond(rng, 1) for _ in range(3))
    for engine in engines():
        _assert_equiv(vt, a & (b | c), (a & b) | (a & c), engine)


@given(seed=st.integers(0, 2**32 - 1))
@settings(max_examples=10, deadline=None)
def test_compiled_matches_oracle_fuzz(seed):
    rng = np.random.default_rng(seed)
    vt = make_vt(n=int(rng.integers(33, 2000)), seed=seed, run=8)
    cond = _random_cond(rng, 4)
    got = complex_filter_intervals(vt, cond)
    want = evaluate_filter_intervals(vt, cond)
    np.testing.assert_array_equal(got[0], want[0])
    np.testing.assert_array_equal(got[1], want[1])
