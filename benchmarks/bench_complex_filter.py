"""Fig. 13 -- complex-condition filtering: speedups over the 'string'
baseline for two-label conditions (AND / OR / AND-NOT-OR)."""
from __future__ import annotations

import numpy as np

from repro.core import L, VertexTypeSchema, filter_binary_columns, \
    filter_rle_interval, filter_string, intervals_to_ids
from repro.core.vertex import (LABEL_ENC_PLAIN, LABEL_ENC_RLE,
                               LABEL_ENC_STRING, VertexTable)

from .graphs import LABEL_GRAPHS, labels
from .util import emit, timeit


def run() -> None:
    for name in LABEL_GRAPHS:
        n, names, cols = labels(name)
        schema = VertexTypeSchema("v", [], labels=names)
        vts = {enc: VertexTable.build(schema, {}, cols, enc, num_vertices=n)
               for enc in (LABEL_ENC_STRING, LABEL_ENC_PLAIN, LABEL_ENC_RLE)}
        conds = {
            "and": L(names[0]) & L(names[1]),
            "or": L(names[0]) | L(names[1]),
            "and_not_or": (L(names[0]) & ~L(names[1])) | L(names[2 % len(names)]),
        }
        for cname, cond in conds.items():
            # verify equivalence before timing
            a = filter_string(vts["string"], cond)
            b = intervals_to_ids(filter_rle_interval(vts["rle"], cond))
            np.testing.assert_array_equal(a, b)
            t_str = timeit(lambda: filter_string(vts["string"], cond),
                           repeats=3)
            t_pl = timeit(lambda: filter_binary_columns(vts["plain"], cond))
            t_rle = timeit(lambda: filter_binary_columns(vts["rle"], cond))
            t_int = timeit(lambda: filter_rle_interval(vts["rle"], cond))
            emit(f"fig13_complex_{name}_{cname}_interval", t_int,
                 f"speedup_vs_string={t_str/t_int:.1f};"
                 f"speedup_vs_plain={t_pl/t_int:.1f};"
                 f"speedup_vs_rle={t_rle/t_int:.1f}")
