"""Table 3 -- end-to-end LDBC-SNB workloads: IS-3 / IC-8 / BI-2,
GraphAr hand-written vs Acero-like join plans, wall time + ESSD model."""
from __future__ import annotations

import numpy as np

from repro.core import IOMeter
from repro.core.query import (bi2_acero, bi2_graphar, build_snb_baseline,
                              build_snb_graphar, ic8_acero, ic8_graphar,
                              is3_acero, is3_graphar)
from repro.core.storage import ESSD

from .graphs import snb
from .util import emit, timeit


def run() -> None:
    data = snb(scale=2)
    g = build_snb_graphar(data)
    base = build_snb_baseline(data)
    deg = np.bincount(data.knows_src, minlength=data.num_persons)
    person = int(np.argmax(deg))
    creator = int(np.argmax(np.bincount(data.has_creator_person,
                                        minlength=data.num_persons)))

    cases = {
        "is3": (lambda m=None: is3_graphar(g, person, m),
                lambda m=None: is3_acero(base, person, m)),
        "ic8": (lambda m=None: ic8_graphar(g, creator, 20, m),
                lambda m=None: ic8_acero(base, creator, 20, m)),
        "bi2": (lambda m=None: bi2_graphar(g, "TagClass1", m),
                lambda m=None: bi2_acero(base, "TagClass1", m)),
    }
    for qname, (gar_fn, acero_fn) in cases.items():
        t_gar = timeit(gar_fn, repeats=3) / 1e6
        t_ace = timeit(acero_fn, repeats=3) / 1e6
        m_gar, m_ace = IOMeter(), IOMeter()
        gar_fn(m_gar)
        acero_fn(m_ace)
        e_gar = t_gar + m_gar.seconds(ESSD)
        e_ace = t_ace + m_ace.seconds(ESSD)
        emit(f"table3_{qname}_acero", t_ace * 1e6,
             f"essd_total_s={e_ace:.4f}")
        emit(f"table3_{qname}_graphar", t_gar * 1e6,
             f"essd_total_s={e_gar:.4f};cpu_speedup={t_ace/t_gar:.1f}x;"
             f"essd_speedup={e_ace/e_gar:.1f}x")
