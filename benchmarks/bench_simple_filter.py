"""Fig. 12 -- simple-condition label filtering across the four methods."""
from __future__ import annotations

import numpy as np

from repro.core import L, VertexTypeSchema, filter_binary_columns, \
    filter_rle_interval, filter_string, intervals_to_ids
from repro.core.vertex import (LABEL_ENC_PLAIN, LABEL_ENC_RLE,
                               LABEL_ENC_STRING, VertexTable)

from .graphs import LABEL_GRAPHS, labels
from .util import emit, timeit


def run() -> None:
    for name in LABEL_GRAPHS:
        n, names, cols = labels(name)
        schema = VertexTypeSchema("v", [], labels=names)
        vts = {enc: VertexTable.build(schema, {}, cols, enc, num_vertices=n)
               for enc in (LABEL_ENC_STRING, LABEL_ENC_PLAIN, LABEL_ENC_RLE)}
        # median label (paper reports the middle value across labels)
        times = {m: [] for m in ("string", "plain", "rle_scan", "interval")}
        for label in names:
            cond = L(label)
            times["string"].append(
                timeit(lambda: filter_string(vts["string"], cond),
                       repeats=3))
            times["plain"].append(
                timeit(lambda: filter_binary_columns(vts["plain"], cond)))
            times["rle_scan"].append(
                timeit(lambda: filter_binary_columns(vts["rle"], cond)))
            times["interval"].append(
                timeit(lambda: filter_rle_interval(vts["rle"], cond)))
        med = {m: float(np.median(v)) for m, v in times.items()}
        emit(f"fig12_simple_{name}_string", med["string"], "")
        emit(f"fig12_simple_{name}_binary_plain", med["plain"],
             f"speedup_vs_string={med['string']/med['plain']:.1f}")
        emit(f"fig12_simple_{name}_binary_rle", med["rle_scan"],
             f"speedup_vs_string={med['string']/med['rle_scan']:.1f}")
        emit(f"fig12_simple_{name}_interval", med["interval"],
             f"speedup_vs_string={med['string']/med['interval']:.1f};"
             f"speedup_vs_rle={med['rle_scan']/med['interval']:.1f}")
