"""Benchmark helpers: timing + CSV/JSON emission."""
from __future__ import annotations

import json
import time
from typing import Callable, Dict, List, Optional

ROWS: List[str] = []

#: machine-readable mirror of the CSV: suite -> row name -> us_per_call
RESULTS: Dict[str, Dict[str, float]] = {}
_CURRENT_SUITE = "default"


def set_suite(name: str) -> None:
    """Route subsequent :func:`emit` rows to ``RESULTS[name]``."""
    global _CURRENT_SUITE
    _CURRENT_SUITE = name
    RESULTS.setdefault(name, {})


def timeit(fn: Callable, repeats: int = 5, warmup: int = 1) -> float:
    """Median wall time in microseconds."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.2f},{derived}"
    ROWS.append(row)
    RESULTS.setdefault(_CURRENT_SUITE, {})[name] = round(us_per_call, 2)
    print(row, flush=True)


def write_json(path: str) -> None:
    """Merge ``RESULTS`` (suite -> name -> us_per_call) into ``path``.

    Suite-level merge with the existing file, so a partial ``--only`` run
    refreshes just the suites it ran instead of clobbering the rest of
    the tracked trajectory."""
    merged: Dict[str, Dict[str, float]] = {}
    try:
        with open(path) as f:
            merged = json.load(f)
    except (OSError, ValueError):
        pass
    merged.update(RESULTS)
    with open(path, "w") as f:
        json.dump(merged, f, indent=2, sort_keys=True)
        f.write("\n")


def header() -> None:
    print("name,us_per_call,derived", flush=True)
