"""Benchmark helpers: timing + CSV emission."""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

ROWS: List[str] = []


def timeit(fn: Callable, repeats: int = 5, warmup: int = 1) -> float:
    """Median wall time in microseconds."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.2f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def header() -> None:
    print("name,us_per_call,derived", flush=True)
