"""Benchmark harness: one module per paper table/figure.

Emits ``name,us_per_call,derived`` CSV plus a machine-readable JSON
(``suite -> name -> us_per_call``, default ``BENCH_PR2.json``) so the
perf trajectory is tracked across PRs.  See EXPERIMENTS.md for the
mapping to the paper's Figures 8-14 and Tables 2-3.
"""
from __future__ import annotations

import argparse
import os
import time

from . import (bench_batch_scaling, bench_complex_filter, bench_e2e,
               bench_ingest, bench_kernels, bench_label_filter,
               bench_label_scaling, bench_label_storage, bench_media,
               bench_neighbor, bench_partition, bench_pipeline,
               bench_pruning, bench_resident, bench_serving,
               bench_simple_filter, bench_storage, bench_transform,
               bench_traversal)
from .util import header, set_suite, write_json

SUITES = {
    "fig8_storage": bench_storage.run,
    "fig9_neighbor": bench_neighbor.run,
    "fig10_transform": bench_transform.run,
    "fig11_label_storage": bench_label_storage.run,
    "fig12_simple_filter": bench_simple_filter.run,
    "fig13_complex_filter": bench_complex_filter.run,
    "fig14_label_scaling": bench_label_scaling.run,
    "batch_scaling": bench_batch_scaling.run,
    "label_filter": bench_label_filter.run_filter,
    "filtered_retrieval": bench_label_filter.run_retrieval,
    "resident": bench_resident.run,
    "partition": bench_partition.run,
    "pruning": bench_pruning.run,
    "traversal": bench_traversal.run,
    "ingest": bench_ingest.run,
    "table2_media": bench_media.run,
    "table3_e2e": bench_e2e.run,
    "pipeline": bench_pipeline.run,
    "kernels": bench_kernels.run,
    "serving": bench_serving.run,
    "overload": bench_serving.run_overload,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    ap.add_argument("--json", default=None,
                    help="machine-readable results path ('' to skip); "
                         "defaults to BENCH_PR10.json, or bench_smoke.json "
                         "under REPRO_BENCH_SMOKE so shrunk-workload rows "
                         "never overwrite the tracked trajectory")
    args = ap.parse_args()
    if args.json is None:
        args.json = ("bench_smoke.json" if os.environ.get("REPRO_BENCH_SMOKE")
                     else "BENCH_PR10.json")
    names = (args.only.split(",") if args.only else list(SUITES))
    header()
    t0 = time.perf_counter()
    for name in names:
        set_suite(name)
        SUITES[name]()
    print(f"# total_wall_s={time.perf_counter()-t0:.1f}", flush=True)
    if args.json:
        write_json(args.json)
        print(f"# wrote {args.json}", flush=True)


if __name__ == '__main__':
    main()
