"""Fig. 9 -- neighbor retrieval time: plain scan / +offset / GraphAr
(delta decode), plus the Pallas fused-decode engine and the modeled ESSD
I/O seconds (the paper's data-lake setting is I/O-bound)."""
from __future__ import annotations

import numpy as np

from repro.core import (BY_SRC, ENC_GRAPHAR, ENC_OFFSET, ENC_PLAIN, IOMeter,
                        build_adjacency, degrees_topk, retrieve_neighbors,
                        retrieve_neighbors_batch, retrieve_neighbors_scan)
from repro.core.storage import ESSD

from .graphs import TOPOLOGY_GRAPHS, topology
from .util import emit, timeit


def run() -> None:
    for name in TOPOLOGY_GRAPHS:
        n, src, dst = topology(name)
        plain = build_adjacency(src, dst, n, n, BY_SRC, ENC_PLAIN)
        offset = build_adjacency(src, dst, n, n, BY_SRC, ENC_OFFSET)
        graphar = build_adjacency(src, dst, n, n, BY_SRC, ENC_GRAPHAR)
        v = int(degrees_topk(offset)[0])

        t_scan = timeit(lambda: retrieve_neighbors_scan(plain, v, 2048),
                        repeats=3)
        t_off = timeit(lambda: retrieve_neighbors(offset, v, 2048))
        t_gar = timeit(lambda: retrieve_neighbors(graphar, v, 2048))
        t_pal = timeit(lambda: retrieve_neighbors(graphar, v, 2048,
                                                  engine="pallas"),
                       repeats=3)

        m_scan, m_off, m_gar = IOMeter(), IOMeter(), IOMeter()
        retrieve_neighbors_scan(plain, v, 2048, m_scan)
        retrieve_neighbors(offset, v, 2048, m_off)
        retrieve_neighbors(graphar, v, 2048, m_gar)
        io_scan = m_scan.seconds(ESSD)
        io_off = m_off.seconds(ESSD)
        io_gar = m_gar.seconds(ESSD)

        emit(f"fig9_neighbor_{name}_plain_scan", t_scan,
             f"essd_io_s={io_scan:.5f}")
        emit(f"fig9_neighbor_{name}_plain_offset", t_off,
             f"essd_io_s={io_off:.5f};speedup_vs_scan={t_scan/t_off:.1f}")
        emit(f"fig9_neighbor_{name}_graphar", t_gar,
             f"essd_io_s={io_gar:.5f};io_speedup_vs_offset="
             f"{io_off/io_gar:.2f}")
        emit(f"fig9_neighbor_{name}_graphar_pallas", t_pal,
             "interpret_mode=1")
        # end-to-end modeled (I/O + decode) speedup, the paper's headline
        e2e_plain = io_scan + t_scan / 1e6
        e2e_gar = io_gar + t_gar / 1e6
        emit(f"fig9_neighbor_{name}_e2e_modeled_speedup", 0.0,
             f"{e2e_plain/e2e_gar:.1f}x")

        # batched plane: 64 high-degree vertices as ONE retrieval vs a
        # per-vertex loop (detailed scaling: benchmarks/bench_batch_scaling)
        vs = degrees_topk(graphar, 64)
        t_loop = timeit(lambda: [retrieve_neighbors(graphar, int(v), 2048)
                                 for v in vs], repeats=3)
        t_bat = timeit(lambda: retrieve_neighbors_batch(graphar, vs, 2048),
                       repeats=3)
        m_loop, m_bat = IOMeter(), IOMeter()
        for v in vs:
            retrieve_neighbors(graphar, int(v), 2048, m_loop)
        retrieve_neighbors_batch(graphar, vs, 2048, m_bat)
        emit(f"fig9_neighbor_{name}_graphar_batch64", t_bat,
             f"loop_us={t_loop:.2f};speedup={t_loop/t_bat:.2f};"
             f"io_bytes_saved={m_loop.nbytes - m_bat.nbytes}")
