"""The filtering plane (PR 3): compiled label predicates + pushdown.

Two suites:

* ``label_filter`` -- the compiled Cond plane against the legacy per-node
  ``evaluate(env)`` recursion and the paper's string baseline, per engine
  (numpy run-merge vs jax/pallas bitmap kernels), results cross-checked
  before timing;

* ``filtered_retrieval`` -- "neighbors of batch B having label L":
  graphar-pushdown (the fused decode->bitmap->AND dispatch) vs the
  host-oracle filter-then-intersect path vs an acero-style string-label
  scan+join baseline, with the IOMeter cross-checked against the numpy
  engine (identical by construction -- the rows assert it); plus the
  batched multi-property gather against the per-column ``fetch_properties``
  loop.

``REPRO_BENCH_SMOKE=1`` shrinks graphs and batch sizes so CI can run both
suites in seconds as a regression tripwire.
"""
from __future__ import annotations

import os

import numpy as np

from repro.core import (BY_SRC, ENC_GRAPHAR, ENC_PLAIN, IOMeter, L,
                        LabelFilter, build_adjacency, fetch_properties,
                        fetch_properties_batch, filter_rle_interval,
                        filter_string, intervals_to_ids,
                        retrieve_neighbors_batch)
from repro.core.labels import evaluate_filter_intervals
from repro.core.schema import PropertySchema, VertexTypeSchema
from repro.core.vertex import LABEL_ENC_STRING, VertexTable
from repro.kernels.label_filter import ops as lf_ops

from .util import emit, timeit

SMOKE = bool(os.environ.get("REPRO_BENCH_SMOKE"))
ENGINES = ("numpy", "jax", "pallas")

# label_filter suite workloads
FILTER_GRAPHS = {"BL": (40_000, 8, 0.25, 512)} if not SMOKE else \
    {"BL": (4_000, 4, 0.25, 128)}

# filtered_retrieval suite workload.  Batch sizes sit in the fused
# regime (>= 64, past FUSED_MIN_RANGES): right at the 64 crossover the
# dispatch's fixed cost still eats ~1/3 of the win (~2.8x); from ~128 up
# the pushdown clears 3x on both kernel engines.
N = 2_000 if SMOKE else 20_000
DEG = 8 if SMOKE else 16
PAGE = 512 if SMOKE else 2048
BATCH_SIZES = (8,) if SMOKE else (128, 512)


def _label_tables(name):
    from repro.data.synthetic import clustered_labels
    n, k, dens, run = FILTER_GRAPHS[name]
    names = [f"L{i}" for i in range(k)]
    cols = clustered_labels(n, names, density=dens, run_scale=run, seed=3)
    schema = VertexTypeSchema("v", [], labels=names)
    rle = VertexTable.build(schema, {}, cols, num_vertices=n)
    string = VertexTable.build(schema, {}, cols, LABEL_ENC_STRING,
                               num_vertices=n)
    return n, names, rle, string


def run_filter() -> None:
    for gname in FILTER_GRAPHS:
        n, names, vt, vt_str = _label_tables(gname)
        conds = {
            "and": L(names[0]) & L(names[1]),
            "and_not_or": (L(names[0]) & ~L(names[1])) | L(names[2]),
        }
        for cname, cond in conds.items():
            # cross-check every engine against the legacy oracle first
            want = intervals_to_ids(evaluate_filter_intervals(vt, cond))
            for engine in ENGINES:
                got = intervals_to_ids(
                    filter_rle_interval(vt, cond, engine=engine))
                np.testing.assert_array_equal(got, want)
            t_legacy = timeit(
                lambda: evaluate_filter_intervals(vt, cond))
            t_string = timeit(lambda: filter_string(vt_str, cond), repeats=3)
            for engine in ENGINES:
                reps = 3 if engine == "pallas" else 5
                if engine == "numpy":
                    t = timeit(lambda: filter_rle_interval(
                        vt, cond, engine="numpy"), repeats=reps)
                else:
                    t = timeit(lambda: lf_ops.label_filter_bitmap(
                        vt, cond, engine=engine), repeats=reps)
                emit(f"label_filter_{gname}_{cname}_{engine}", t,
                     f"legacy_us={t_legacy:.2f};"
                     f"vs_legacy={t_legacy / t:.2f};"
                     f"vs_string={t_string / t:.1f}")


def _retrieval_fixture():
    from repro.data.synthetic import clustered_labels, powerlaw_graph
    rng = np.random.default_rng(29)
    src, dst = powerlaw_graph(N, DEG, locality=0.85, seed=11)
    adj = build_adjacency(src, dst, N, N, BY_SRC, ENC_GRAPHAR,
                          page_size=PAGE)
    labels = clustered_labels(N, ["A", "B", "C"], density=0.3,
                              run_scale=max(PAGE // 8, 64), seed=7)
    vt = VertexTable.build(
        VertexTypeSchema("v", [PropertySchema("x", "int64"),
                               PropertySchema("y", "int64"),
                               PropertySchema("w", "float64")],
                         labels=["A", "B", "C"], page_size=PAGE),
        {"x": rng.integers(0, 1 << 20, N), "y": rng.integers(0, 1 << 20, N),
         "w": rng.random(N)}, labels, num_vertices=N)
    vt_str = VertexTable.build(
        VertexTypeSchema("v", [], labels=["A", "B", "C"], page_size=PAGE),
        {}, labels, LABEL_ENC_STRING, num_vertices=N)
    coo = build_adjacency(src, dst, N, N, BY_SRC, ENC_PLAIN, page_size=PAGE)
    return adj, vt, vt_str, coo


def _acero_filtered(coo, vt_str, vs, label):
    """String-label baseline: full COO scan + isin + string-label join."""
    keys = np.asarray(coo.table["<src>"].read_all())
    vals = np.asarray(coo.table["<dst>"].read_all())
    dst = vals[np.isin(keys, vs)]
    strings = vt_str.table["<labels>"].read_all()
    mask = np.array([label in s.split("|") if s else False
                     for s in strings])
    return np.unique(dst[mask[dst]])


def run_retrieval() -> None:
    adj, vt, vt_str, coo = _retrieval_fixture()
    cond = L("A") | L("C")
    for bs in BATCH_SIZES:
        vs = np.random.default_rng(bs).integers(0, N, bs)
        filt = LabelFilter(vt, cond)
        t_acero = timeit(lambda: _acero_filtered(coo, vt_str, vs, "A"),
                         repeats=3)
        # numpy host plane (filter-then-intersect, the oracle route)
        t_numpy = timeit(lambda: retrieve_neighbors_batch(
            adj, vs, PAGE, filter=filt), repeats=3)
        for engine in ("jax", "pallas"):
            t_push = timeit(lambda: retrieve_neighbors_batch(
                adj, vs, PAGE, engine=engine, fused=True, filter=filt),
                repeats=9, warmup=2)
            t_host = timeit(lambda: retrieve_neighbors_batch(
                adj, vs, PAGE, engine=engine, fused=False, filter=filt),
                repeats=9, warmup=2)
            # equality + IOMeter identity with the numpy engine
            m_push, m_np = IOMeter(), IOMeter()
            p1 = retrieve_neighbors_batch(adj, vs, PAGE, m_push,
                                          engine=engine, fused=True,
                                          filter=filt)
            p2 = retrieve_neighbors_batch(adj, vs, PAGE, m_np,
                                          engine="numpy", filter=filt)
            assert p1 == p2, "pushdown must match the host oracle"
            assert (m_push.nbytes, m_push.nrequests) \
                == (m_np.nbytes, m_np.nrequests), \
                "pushdown must charge exactly what the numpy engine does"
            emit(f"filtered_pushdown_{engine}_bs{bs}", t_push,
                 f"host_us={t_host:.2f};"
                 f"pushdown_over_host={t_host / t_push:.2f};"
                 f"numpy_us={t_numpy:.2f};acero_us={t_acero:.2f};"
                 f"vs_acero={t_acero / t_push:.1f};"
                 f"io_bytes={m_push.nbytes};io_identical=1")
            emit(f"filtered_host_{engine}_bs{bs}", t_host, "")
        emit(f"filtered_numpy_bs{bs}", t_numpy,
             f"acero_us={t_acero:.2f};vs_acero={t_acero / t_numpy:.1f}")

    # ---- batched multi-property gather vs per-column loop -----------------
    vs = np.random.default_rng(1).integers(0, N, max(BATCH_SIZES))
    pac = retrieve_neighbors_batch(adj, vs, PAGE)
    props = ["x", "y", "w"]
    got = fetch_properties_batch(pac, vt, props)
    for p in props:
        np.testing.assert_array_equal(got[p], fetch_properties(pac, vt, p))
    t_batch = timeit(lambda: fetch_properties_batch(pac, vt, props))
    t_loop = timeit(lambda: [fetch_properties(pac, vt, p) for p in props])
    emit("multiprop_gather_batch", t_batch,
         f"loop_us={t_loop:.2f};batch_over_loop={t_loop / t_batch:.2f};"
         f"ids={pac.count()};props={len(props)}")
    emit("multiprop_gather_loop", t_loop, "")
