"""Mutable graph plane (PR 7): serving cost under pending writes.

Four sections:

* ``ingest_append_*`` -- raw delta-segment ingest throughput (staged
  sorted-merge + zone-map update, per batch);
* ``ingest_read_*`` -- the acceptance rows: the batched neighbor read
  with a row-group's worth of pending delta rows (union at dispatch
  time) against the write-once baseline on the same base graph, cold
  (no decoded-page LRU) and warm, per engine.  The delta path reads a
  RAM-resident memtable, so the paired ratio must stay small (the PR
  acceptance bound: never worse than 1.5x write-once);
* ``ingest_compact_*`` -- one full merge -> swap compaction;
* ``ingest_sustained_*`` -- an ingest+serve loop with the compactor on
  (policy-gated ``maybe_compact`` folds the backlog and restores the
  write-once path) vs off (the backlog only grows).

Every timed read is preceded by a bit-identity assertion against a
from-scratch rebuild -- pending writes must be invisible except in wall
time.  ``REPRO_BENCH_SMOKE=1`` shrinks the graph so CI runs in seconds.
"""
from __future__ import annotations

import os

import numpy as np

from repro.core import (BY_SRC, ENC_GRAPHAR, attach_page_cache,
                        build_adjacency, neighbor_ids_batch)
from repro.core.compaction import CompactionPolicy, CompactionRunner
from repro.core.delta_segment import all_edges, attach_delta, live_delta

from .bench_resident import _paired
from .util import emit, timeit

SMOKE = bool(os.environ.get("REPRO_BENCH_SMOKE"))
N = 2_000 if SMOKE else 20_000
DEG = 8 if SMOKE else 16
PAGE = 512 if SMOKE else 2048
BATCH = 8 if SMOKE else 64
INGEST_ROWS = 128 if SMOKE else 1024
ENGINES = ("numpy", "jax")
SUSTAINED_TICKS = 6 if SMOKE else 30


def _base(seed=11):
    from repro.data.synthetic import powerlaw_graph
    src, dst = powerlaw_graph(N, DEG, locality=0.85, seed=seed)
    return build_adjacency(src, dst, N, N, BY_SRC, ENC_GRAPHAR,
                           page_size=PAGE)


def _with_backlog(seed=11, rows=None):
    """Base graph + one row-group's worth of pending delta rows."""
    adj = _base(seed)
    rows = PAGE if rows is None else rows
    rng = np.random.default_rng(seed + 1)
    attach_delta(adj).ingest(rng.integers(0, N, rows),
                             rng.integers(0, N, rows))
    return adj


def _check_identity(adj, vs, engine):
    oracle = build_adjacency(*all_edges(adj), N, N, BY_SRC, ENC_GRAPHAR,
                             page_size=PAGE)
    np.testing.assert_array_equal(
        neighbor_ids_batch(adj, vs, engine=engine),
        neighbor_ids_batch(oracle, vs, engine="numpy"))


def run() -> None:
    rng = np.random.default_rng(3)
    vs = rng.integers(0, N, BATCH)

    # -- raw ingest throughput --------------------------------------------
    adj = _base()
    delta = attach_delta(adj)
    batches = [(rng.integers(0, N, INGEST_ROWS),
                rng.integers(0, N, INGEST_ROWS)) for _ in range(8)]
    it = iter(range(1 << 30))
    us = timeit(lambda: delta.ingest(*batches[next(it) % len(batches)]),
                repeats=8, warmup=1)
    emit(f"ingest_append_rows{INGEST_ROWS}", us,
         f"rows_per_s={INGEST_ROWS / (us / 1e6):.0f}")

    # -- read under pending writes vs write-once (the acceptance rows) ----
    for engine in ENGINES:
        for cache, label in ((None, "cold"), (256, "warm")):
            base = _base()
            under = _with_backlog()
            if cache:
                attach_page_cache(base.table[base.value_col], cache)
                attach_page_cache(under.table[under.value_col], cache)
            _check_identity(under, vs, engine)
            a, b, ratio = _paired(
                lambda: neighbor_ids_batch(base, vs, engine=engine),
                lambda: neighbor_ids_batch(under, vs, engine=engine))
            emit(f"ingest_read_writeonce_{label}_{engine}_b{BATCH}", a, "")
            emit(f"ingest_read_underwrite_{label}_{engine}_b{BATCH}", b,
                 f"vs_writeonce={ratio:.2f}x")

    # -- one compaction ----------------------------------------------------
    us = timeit(lambda: CompactionRunner(_with_backlog()).compact(),
                repeats=3, warmup=1)
    emit(f"ingest_compact_rows{PAGE}", us, "merge+swap")

    # -- sustained ingest+serve: compactor on vs off ----------------------
    def sustained(compact_on: bool):
        adj = _base()
        attach_delta(adj)
        runner = CompactionRunner(
            adj, policy=CompactionPolicy(min_delta_rows=PAGE),
            sleep=lambda _s: None)
        r = np.random.default_rng(7)
        for _ in range(SUSTAINED_TICKS):
            adj.delta.ingest(r.integers(0, N, INGEST_ROWS),
                             r.integers(0, N, INGEST_ROWS))
            neighbor_ids_batch(adj, r.integers(0, N, BATCH),
                               engine="numpy")
            if compact_on:
                runner.maybe_compact()
        return adj

    a, b, ratio = _paired(lambda: sustained(True),
                          lambda: sustained(False), reps=4)
    adj_on = sustained(True)
    pending = (live_delta(adj_on).pending_rows()
               if live_delta(adj_on) else 0)
    emit(f"ingest_sustained_compact_on_t{SUSTAINED_TICKS}", a,
         f"end_pending={pending}")
    emit(f"ingest_sustained_compact_off_t{SUSTAINED_TICKS}", b,
         f"off_over_on={ratio:.2f}x")
